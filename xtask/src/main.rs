//! `xtask lint` — dependency-free static-analysis pass over `rust/src`.
//!
//! The rule catalog (see `docs/ARCHITECTURE.md` § Correctness tooling):
//!
//! | rule                        | enforces                                             |
//! |-----------------------------|------------------------------------------------------|
//! | `spmd-collective`           | no collective call under a rank-conditional branch   |
//! | `lease-blocking-collective` | no blocking collective while a pool lease is live    |
//! | `raw-tag-literal`           | tag arithmetic only via `collectives::tags`          |
//! | `deprecated-shim`           | no `#[allow(deprecated)]` shim usage in the library  |
//! | `unwrap-in-harness`         | no `unwrap`/`expect` in CLI/bench-harness modules    |
//! | `hot-path-alloc`            | no allocation in `// xtask: hot_path`-marked fns     |
//!
//! The pass works on a comment/string-blanked copy of each file (so
//! nothing inside literals or docs can trigger a rule), skips
//! `#[cfg(test)] mod` bodies, and honors line-scoped suppressions:
//! a `// xtask: allow(<rule>)` comment on the offending line or the
//! line above silences that one finding.
//!
//! Usage:
//!
//! ```text
//! cargo run -p xtask -- lint                 # scan rust/src, exit 1 on findings
//! cargo run -p xtask -- lint --json out.json # also write a machine-readable report
//! cargo run -p xtask -- lint --self-test     # prove each rule catches its fixture
//! ```

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const RULE_SPMD: &str = "spmd-collective";
const RULE_LEASE: &str = "lease-blocking-collective";
const RULE_RAWTAG: &str = "raw-tag-literal";
const RULE_DEPRECATED: &str = "deprecated-shim";
const RULE_UNWRAP: &str = "unwrap-in-harness";
const RULE_HOTPATH: &str = "hot-path-alloc";

const ALL_RULES: [&str; 6] =
    [RULE_SPMD, RULE_LEASE, RULE_RAWTAG, RULE_DEPRECATED, RULE_UNWRAP, RULE_HOTPATH];

/// Blocking collective entry points on `Communicator` (the `_async`
/// variants are matched by full method name, so they never hit).
const COLLECTIVES: [&str; 12] = [
    "split",
    "split_with_span",
    "try_split",
    "try_split_with_span",
    "all_to_all",
    "all_gather",
    "all_reduce",
    "scatter",
    "gather",
    "broadcast",
    "reduce",
    "barrier",
];

/// One diagnostic: `file:line: [rule] message`.
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// Lexical preprocessing
// ---------------------------------------------------------------------------

/// Blank comments, string/char literals, and raw strings to spaces,
/// preserving length and newlines, so the rules can do positional
/// matching without tripping on text inside literals or docs.
fn strip(code: &str) -> Vec<u8> {
    let b = code.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], a: usize, z: usize| {
        for slot in out[a..z.min(n)].iter_mut() {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'r' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') {
            // Raw string r"..." / r#"..."# (or a raw identifier — skipped).
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let mut close = vec![b'"'];
                close.extend(std::iter::repeat(b'#').take(hashes));
                let end = find_bytes(&b[j..], &close).map(|k| j + k + close.len()).unwrap_or(n);
                blank(&mut out, i + 1, end);
                i = end;
            } else {
                i += 1;
            }
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i + 1, j.saturating_sub(1));
            i = j;
        } else if c == b'\'' {
            if i + 2 < n && b[i + 1] == b'\\' {
                // Escaped char literal '\n', '\u{..}', ...
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i + 1, j);
                i = j + 1;
            } else if i + 2 < n && b[i + 2] == b'\'' {
                // Simple char literal 'x'.
                blank(&mut out, i + 1, i + 2);
                i += 3;
            } else {
                // Lifetime.
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn find_bytes(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (0..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

fn is_ident(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Whether `word` occurs at `pos` with identifier boundaries.
fn word_at(clean: &[u8], pos: usize, word: &str) -> bool {
    let w = word.as_bytes();
    if pos + w.len() > clean.len() || &clean[pos..pos + w.len()] != w {
        return false;
    }
    let before_ok = pos == 0 || !is_ident(clean[pos - 1]);
    let after_ok = pos + w.len() == clean.len() || !is_ident(clean[pos + w.len()]);
    before_ok && after_ok
}

/// All boundary-respecting occurrences of `word`.
fn find_words(clean: &[u8], word: &str) -> Vec<usize> {
    let first = word.as_bytes()[0];
    (0..clean.len())
        .filter(|&i| clean[i] == first && word_at(clean, i, word))
        .collect()
}

fn contains_word(clean: &[u8], word: &str) -> bool {
    let first = word.as_bytes()[0];
    (0..clean.len()).any(|i| clean[i] == first && word_at(clean, i, word))
}

/// Position of the `}` matching the `{` at `open` (or end of input).
fn matching_brace(clean: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut k = open;
    while k < clean.len() {
        match clean[k] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
        k += 1;
    }
    clean.len()
}

/// 1-based line number of byte `pos`.
fn line_of(code: &str, pos: usize) -> usize {
    code.as_bytes()[..pos.min(code.len())].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Byte ranges of `#[cfg(test…)] mod … { … }` bodies — rule-exempt.
fn test_ranges(clean: &[u8]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    let open = b"#[cfg(";
    while let Some(off) = find_bytes(&clean[i..], open) {
        let at = i + off;
        let inner = at + open.len();
        i = inner;
        let is_test = word_at(clean, inner, "test")
            || (word_at(clean, inner, "all")
                && clean.get(inner + 3) == Some(&b'(')
                && word_at(clean, inner + 4, "test"));
        if !is_test {
            continue;
        }
        // Find the attribute's closing `]`, then require a `mod` item.
        let Some(close) = clean[inner..].iter().position(|&c| c == b']') else { continue };
        let mut j = inner + close + 1;
        loop {
            while j < clean.len() && clean[j].is_ascii_whitespace() {
                j += 1;
            }
            if clean.get(j) == Some(&b'#') && clean.get(j + 1) == Some(&b'[') {
                match clean[j..].iter().position(|&c| c == b']') {
                    Some(e) => j += e + 1,
                    None => break,
                }
            } else {
                break;
            }
        }
        if word_at(clean, j, "pub") {
            j += 3;
            while j < clean.len() && clean[j].is_ascii_whitespace() {
                j += 1;
            }
        }
        if !word_at(clean, j, "mod") {
            continue;
        }
        let Some(brace) = clean[j..].iter().position(|&c| c == b'{') else { continue };
        ranges.push((at, matching_brace(clean, j + brace)));
    }
    ranges
}

fn in_test(ranges: &[(usize, usize)], pos: usize) -> bool {
    ranges.iter().any(|&(a, b)| a <= pos && pos <= b)
}

/// `// xtask: allow(<rule>)` markers, as (line, rule) pairs, read from
/// the RAW code (markers live in comments, which `strip` blanks).
fn allow_markers(code: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (idx, line) in code.lines().enumerate() {
        if let Some(at) = line.find("xtask: allow(") {
            let rest = &line[at + "xtask: allow(".len()..];
            if let Some(end) = rest.find(')') {
                out.push((idx + 1, rest[..end].trim().to_string()));
            }
        }
    }
    out
}

fn suppressed(markers: &[(usize, String)], finding: &Finding) -> bool {
    markers
        .iter()
        .any(|(l, r)| r == finding.rule && (*l == finding.line || *l + 1 == finding.line))
}

// ---------------------------------------------------------------------------
// Call-site scanning
// ---------------------------------------------------------------------------

/// Method-call sites `.name(`/`.name::<` in `clean[range]`, returned as
/// (position of `.`, method name).
fn method_calls(clean: &[u8], from: usize, to: usize) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let to = to.min(clean.len());
    for i in from..to {
        if clean[i] != b'.' {
            continue;
        }
        let mut j = i + 1;
        while j < to && is_ident(clean[j]) {
            j += 1;
        }
        if j == i + 1 {
            continue;
        }
        let mut k = j;
        while k < to && (clean[k] == b' ' || clean[k] == b'\n') {
            k += 1;
        }
        if k < to && (clean[k] == b'(' || clean[k] == b':' || clean[k] == b'<') {
            out.push((i, String::from_utf8_lossy(&clean[i + 1..j]).into_owned()));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// SPMD discipline: a collective call site lexically inside an `if`/
/// `while` whose condition mentions the caller's rank diverges the
/// ranks' collective schedules — every rank must reach every collective
/// in the same order. (The collectives' own internals are exempt: the
/// implementation layer legitimately branches on rank.)
fn rule_spmd(rel: &str, code: &str, clean: &[u8], tr: &[(usize, usize)]) -> Vec<Finding> {
    if rel.starts_with("collectives/") {
        return Vec::new();
    }
    let mut scopes: Vec<(usize, usize, usize)> = Vec::new(); // (open, close, kw)
    for kw in ["if", "while"] {
        for pos in find_words(clean, kw) {
            // Condition runs from the keyword to the first `{` at
            // paren/bracket depth 0.
            let mut depth = 0i32;
            let mut k = pos + kw.len();
            while k < clean.len() {
                match clean[k] {
                    b'(' | b'[' => depth += 1,
                    b')' | b']' => depth -= 1,
                    b'{' if depth == 0 => break,
                    _ => {}
                }
                k += 1;
            }
            let cond = &clean[pos + kw.len()..k.min(clean.len())];
            if contains_word(cond, "rank")
                || contains_word(cond, "locality")
                || contains_word(cond, "my_global")
            {
                scopes.push((k, matching_brace(clean, k), pos));
            }
        }
    }
    let mut out = Vec::new();
    for (dot, name) in method_calls(clean, 0, clean.len()) {
        if !COLLECTIVES.contains(&name.as_str()) || in_test(tr, dot) {
            continue;
        }
        for &(a, b, kw) in &scopes {
            if a < dot && dot < b {
                out.push(Finding {
                    file: rel.to_string(),
                    line: line_of(code, dot),
                    rule: RULE_SPMD,
                    message: format!(
                        "collective `.{name}` under the rank-conditional branch opened on \
                         line {} — every rank must reach every collective",
                        line_of(code, kw)
                    ),
                });
                break;
            }
        }
    }
    out
}

/// No blocking collective while a pool lease is live in the same scope:
/// a rank blocked in a collective while holding a leased pool can
/// starve the job that needs that pool to unblock the collective's
/// peer — the cross-job deadlock the runtime conformance checker
/// diagnoses dynamically (`collectives::conformance`).
fn rule_lease(rel: &str, code: &str, clean: &[u8], tr: &[(usize, usize)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for pos in find_words(clean, "lease_pools") {
        let mut k = pos + "lease_pools".len();
        while k < clean.len() && clean[k].is_ascii_whitespace() {
            k += 1;
        }
        if clean.get(k) != Some(&b'(') {
            continue;
        }
        // The lease is live from the call to the end of the enclosing
        // scope (walk forward until brace depth goes negative).
        let mut depth = 0i32;
        let mut end = k;
        while end < clean.len() {
            match clean[end] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        break;
                    }
                }
                _ => {}
            }
            end += 1;
        }
        for (dot, name) in method_calls(clean, k, end) {
            if !COLLECTIVES.contains(&name.as_str()) || in_test(tr, dot) {
                continue;
            }
            out.push(Finding {
                file: rel.to_string(),
                line: line_of(code, dot),
                rule: RULE_LEASE,
                message: format!(
                    "blocking collective `.{name}` while the pool lease taken on line {} \
                     is live — release the lease first or use the async variant",
                    line_of(code, pos)
                ),
            });
        }
    }
    out
}

/// Tag-space arithmetic must go through `collectives::tags` — a raw
/// span literal (`1 << 32`, `1 << 48`, or their decimal/hex spellings)
/// silently desynchronizes from the shared constants.
fn rule_rawtag(rel: &str, code: &str, clean: &[u8], tr: &[(usize, usize)]) -> Vec<Finding> {
    if rel == "collectives/tags.rs" {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut push = |pos: usize, lit: &str| {
        if !in_test(tr, pos) {
            out.push(Finding {
                file: rel.to_string(),
                line: line_of(code, pos),
                rule: RULE_RAWTAG,
                message: format!(
                    "raw tag-span literal `{lit}` — use the named constants in \
                     `collectives::tags`"
                ),
            });
        }
    };
    // Shift form: `1[suffix] << (32|48)`.
    let n = clean.len();
    for i in 0..n.saturating_sub(1) {
        if clean[i] != b'<' || clean[i + 1] != b'<' {
            continue;
        }
        // Left operand: skip spaces back, then read the token.
        let mut l = i;
        while l > 0 && clean[l - 1] == b' ' {
            l -= 1;
        }
        let mut start = l;
        while start > 0 && is_ident(clean[start - 1]) {
            start -= 1;
        }
        let lhs = &clean[start..l];
        let lhs_ok = matches!(lhs, b"1" | b"1u64" | b"1u32" | b"1usize" | b"1i64");
        // Right operand: skip spaces forward, read the number.
        let mut r = i + 2;
        while r < n && clean[r] == b' ' {
            r += 1;
        }
        let mut stop = r;
        while stop < n && is_ident(clean[stop]) {
            stop += 1;
        }
        let rhs = &clean[r..stop];
        if lhs_ok && (rhs == b"32" || rhs == b"48") {
            push(start, &format!("1 << {}", String::from_utf8_lossy(rhs)));
        }
    }
    for lit in ["4294967296", "281474976710656", "0x1_0000_0000"] {
        let first = lit.as_bytes()[0];
        for i in 0..n {
            if clean[i] == first && word_at(clean, i, lit) {
                push(i, lit);
            }
        }
    }
    out
}

/// The deprecated compatibility shims are quarantined: library code may
/// not opt back into them with `#[allow(deprecated)]` (benches that
/// exercise the shim path on purpose live outside `rust/src`).
fn rule_deprecated(rel: &str, code: &str, clean: &[u8], tr: &[(usize, usize)]) -> Vec<Finding> {
    let mut out = Vec::new();
    let needle = b"#[allow(deprecated)]";
    let mut i = 0;
    while let Some(off) = find_bytes(&clean[i..], needle) {
        let at = i + off;
        i = at + needle.len();
        if !in_test(tr, at) {
            out.push(Finding {
                file: rel.to_string(),
                line: line_of(code, at),
                rule: RULE_DEPRECATED,
                message: "`#[allow(deprecated)]` re-enables a quarantined shim — migrate to \
                          the replacement API"
                    .to_string(),
            });
        }
    }
    out
}

/// CLI and bench-harness modules parse user input; a stray `unwrap`/
/// `expect` there turns a bad flag into a panic instead of a typed
/// error naming the flag.
fn rule_unwrap(rel: &str, code: &str, clean: &[u8], tr: &[(usize, usize)]) -> Vec<Finding> {
    let harness = rel == "main.rs"
        || rel.starts_with("cli/")
        || rel.starts_with("bench_harness/")
        || rel.starts_with("config/");
    if !harness {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (dot, name) in method_calls(clean, 0, clean.len()) {
        if (name == "unwrap" || name == "expect") && !in_test(tr, dot) {
            let line_start = code[..dot].rfind('\n').map(|p| p + 1).unwrap_or(0);
            let line_end = code[dot..].find('\n').map(|p| dot + p).unwrap_or(code.len());
            let snippet: String = code[line_start..line_end].trim().chars().take(90).collect();
            out.push(Finding {
                file: rel.to_string(),
                line: line_of(code, dot),
                rule: RULE_UNWRAP,
                message: format!("`.{name}` in a user-input harness: {snippet}"),
            });
        }
    }
    out
}

/// Allocation hygiene in `// xtask: hot_path`-marked functions: the
/// steady-state kernels must not allocate (the dynamic twin of this
/// rule is `tests/alloc_free.rs`'s counting allocator).
fn rule_hotpath(rel: &str, code: &str, clean: &[u8], _tr: &[(usize, usize)]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut byte = 0usize;
    for line in code.split_inclusive('\n') {
        let marker = line.contains("xtask: hot_path");
        let after = byte + line.len();
        byte = after;
        if !marker {
            continue;
        }
        // The next `fn` at/after the marker line is the marked kernel.
        let Some(fn_off) = find_words(&clean[after..], "fn").first().copied() else { continue };
        let fn_pos = after + fn_off;
        let Some(brace_off) = clean[fn_pos..].iter().position(|&c| c == b'{') else { continue };
        let open = fn_pos + brace_off;
        let close = matching_brace(clean, open);
        let mut name_at = fn_pos + 2;
        while name_at < clean.len() && clean[name_at].is_ascii_whitespace() {
            name_at += 1;
        }
        let mut name_end = name_at;
        while name_end < clean.len() && is_ident(clean[name_end]) {
            name_end += 1;
        }
        let fn_name = String::from_utf8_lossy(&clean[name_at..name_end]).into_owned();
        let mut push = |pos: usize, what: &str| {
            out.push(Finding {
                file: rel.to_string(),
                line: line_of(code, pos),
                rule: RULE_HOTPATH,
                message: format!("`{what}` allocates inside hot-path fn `{fn_name}`"),
            });
        };
        for word in ["Vec", "Box"] {
            for pos in find_words(&clean[open..close], word) {
                let at = open + pos;
                let rest = &clean[at + word.len()..close.min(clean.len())];
                for assoc in [&b"::new"[..], &b"::with_capacity"[..]] {
                    if rest.len() >= assoc.len() && &rest[..assoc.len()] == assoc {
                        push(at, &format!("{word}{}", String::from_utf8_lossy(assoc)));
                    }
                }
            }
        }
        for pos in find_words(&clean[open..close], "vec") {
            let at = open + pos;
            if clean.get(at + 3) == Some(&b'!') {
                push(at, "vec!");
            }
        }
        for (dot, name) in method_calls(clean, open, close) {
            if name == "to_vec" || name == "clone" {
                push(dot, &format!(".{name}()"));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Lint every `.rs` file under `root`; paths in findings are relative.
fn scan(root: &Path) -> (usize, Vec<Finding>) {
    let mut files = Vec::new();
    collect_rs(root, &mut files);
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let Ok(code) = fs::read_to_string(path) else { continue };
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let clean = strip(&code);
        let tr = test_ranges(&clean);
        let markers = allow_markers(&code);
        for rule in [
            rule_spmd,
            rule_lease,
            rule_rawtag,
            rule_deprecated,
            rule_unwrap,
            rule_hotpath,
        ] {
            for f in rule(&rel, &code, &clean, &tr) {
                if !suppressed(&markers, &f) {
                    findings.push(f);
                }
            }
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    (files.len(), findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Hand-rolled report writer (the crate is dependency-free by design).
fn write_json(path: &Path, root: &Path, files_scanned: usize, findings: &[Finding]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"root\": \"{}\",\n", json_escape(&root.display().to_string())));
    s.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    s.push_str(&format!(
        "  \"rules\": [{}],\n",
        ALL_RULES.map(|r| format!("\"{r}\"")).join(", ")
    ));
    s.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        s.push_str(if i == 0 { "\n" } else { ",\n" });
        s.push_str(&format!(
            "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.message)
        ));
    }
    s.push_str(if findings.is_empty() { "]\n}\n" } else { "\n  ]\n}\n" });
    if let Err(e) = fs::write(path, s) {
        eprintln!("xtask: failed to write {}: {e}", path.display());
    }
}

/// Locate `rust/src` from the current directory or from the workspace
/// this binary was built in.
fn default_root() -> PathBuf {
    let cwd_rel = PathBuf::from("rust/src");
    if cwd_rel.is_dir() {
        return cwd_rel;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src")
}

fn fixtures_root() -> PathBuf {
    let cwd_rel = PathBuf::from("xtask/fixtures");
    if cwd_rel.is_dir() {
        return cwd_rel;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

/// Prove every rule catches its seeded fixture and that the clean
/// fixture (near-misses plus a suppression) produces no findings.
fn self_test() -> ExitCode {
    let root = fixtures_root();
    let (files, findings) = scan(&root);
    let expected: [(&str, &str); 6] = [
        ("spmd.rs", RULE_SPMD),
        ("lease.rs", RULE_LEASE),
        ("rawtag.rs", RULE_RAWTAG),
        ("deprecated.rs", RULE_DEPRECATED),
        ("cli/unwrap.rs", RULE_UNWRAP),
        ("hotpath.rs", RULE_HOTPATH),
    ];
    let mut failed = false;
    for (file, rule) in expected {
        let hit = findings.iter().any(|f| f.file == file && f.rule == rule);
        println!("self-test: {rule:<28} in {file:<16} {}", if hit { "CAUGHT" } else { "MISSED" });
        failed |= !hit;
    }
    let false_positives: Vec<_> = findings.iter().filter(|f| f.file == "clean.rs").collect();
    for f in &false_positives {
        println!("self-test: FALSE POSITIVE {f}");
    }
    failed |= !false_positives.is_empty();
    println!(
        "self-test: {files} fixture files, {} findings, {}",
        findings.len(),
        if failed { "FAILED" } else { "ok" }
    );
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("lint") {
        eprintln!("usage: xtask lint [--self-test] [--root PATH] [--json PATH]");
        return ExitCode::from(2);
    }
    let mut root: Option<PathBuf> = None;
    let mut json: Option<PathBuf> = None;
    let mut selftest = false;
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--self-test" => selftest = true,
            "--root" => root = it.next().map(PathBuf::from),
            "--json" => json = it.next().map(PathBuf::from),
            other => {
                eprintln!("xtask: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    if selftest {
        return self_test();
    }
    let root = root.unwrap_or_else(default_root);
    if !root.is_dir() {
        eprintln!("xtask: lint root {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    let (files, findings) = scan(&root);
    for f in &findings {
        println!("{}/{f}", root.display());
    }
    if let Some(path) = json {
        write_json(&path, &root, files, &findings);
        println!("report written to {}", path.display());
    }
    println!(
        "xtask lint: {files} files, {} finding(s) across {} rules",
        findings.len(),
        ALL_RULES.len()
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, code: &str) -> Vec<Finding> {
        let clean = strip(code);
        let tr = test_ranges(&clean);
        let markers = allow_markers(code);
        let mut out = Vec::new();
        for rule in [
            rule_spmd,
            rule_lease,
            rule_rawtag,
            rule_deprecated,
            rule_unwrap,
            rule_hotpath,
        ] {
            let found = rule(rel, code, &clean, &tr);
            out.extend(found.into_iter().filter(|f| !suppressed(&markers, f)));
        }
        out
    }

    #[test]
    fn strip_blanks_comments_and_strings_preserving_length() {
        let code = "let x = \"1 << 32\"; // 1 << 32\nlet y = '\\n';";
        let clean = strip(code);
        assert_eq!(clean.len(), code.len());
        let s = String::from_utf8(clean).unwrap();
        assert!(!s.contains("1 << 32"), "{s}");
        assert!(s.contains("let x ="), "{s}");
        assert_eq!(s.matches('\n').count(), code.matches('\n').count());
    }

    #[test]
    fn strip_handles_raw_strings_and_lifetimes() {
        let code = "fn f<'a>(s: &'a str) { let r = r#\"if rank { .barrier( }\"#; }";
        let s = String::from_utf8(strip(code)).unwrap();
        assert!(!s.contains("barrier"), "{s}");
        assert!(s.contains("fn f<'a>"), "{s}");
    }

    #[test]
    fn test_ranges_cover_cfg_test_mods() {
        let code = "fn a() {}\n#[cfg(test)]\nmod tests { fn b() { x.unwrap(); } }";
        let clean = strip(code);
        let tr = test_ranges(&clean);
        assert_eq!(tr.len(), 1);
        let pos = code.find("unwrap").unwrap();
        assert!(in_test(&tr, pos));
        assert!(!in_test(&tr, 0));
    }

    #[test]
    fn test_ranges_cover_cfg_all_test_mods() {
        let code = "#[cfg(all(test, any(debug_assertions, feature = \"conformance\")))]\n\
                    mod t { fn b() { q.expect(\"x\"); } }";
        let clean = strip(code);
        let tr = test_ranges(&clean);
        assert_eq!(tr.len(), 1, "gated test mod must be exempt");
    }

    #[test]
    fn spmd_catches_rank_conditional_collective() {
        let code = "fn f() { if rank == 0 { comm.barrier(); } }";
        let out = lint_str("runtime/x.rs", code);
        let shown: Vec<String> = out.iter().map(|f| f.to_string()).collect();
        assert_eq!(out.len(), 1, "{shown:?}");
        assert_eq!(out[0].rule, RULE_SPMD);
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn spmd_ignores_unconditional_and_collectives_layer() {
        assert!(lint_str("runtime/x.rs", "fn f() { comm.barrier(); }").is_empty());
        assert!(lint_str(
            "collectives/comm.rs",
            "fn f() { if rank == 0 { comm.barrier(); } }"
        )
        .is_empty());
        // Condition not about rank: fine.
        assert!(lint_str("runtime/x.rs", "fn f() { if n > 2 { comm.all_gather(v); } }").is_empty());
    }

    #[test]
    fn lease_catches_blocking_collective_in_scope() {
        let code = "fn f() { let (a, b) = lease_pools(&sh, 4);\n comm.all_gather(x); }";
        let out = lint_str("runtime/x.rs", code);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RULE_LEASE);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn lease_scope_ends_at_enclosing_brace() {
        let code = "fn f() { let p = lease_pools(&sh, 4); }\nfn g() { comm.all_gather(x); }";
        assert!(lint_str("runtime/x.rs", code).is_empty());
    }

    #[test]
    fn rawtag_catches_span_literals_everywhere_but_tags_rs() {
        for lit in ["1 << 32", "1u64 << 48", "4294967296", "0x1_0000_0000"] {
            let code = format!("const S: u64 = {lit};");
            let out = lint_str("hpx/parcel.rs", &code);
            assert_eq!(out.len(), 1, "literal {lit}");
            assert_eq!(out[0].rule, RULE_RAWTAG);
        }
        assert!(lint_str("collectives/tags.rs", "const S: u64 = 1 << 32;").is_empty());
        // Unrelated shifts do not fire.
        assert!(lint_str("hpx/parcel.rs", "const S: u64 = 1 << 16; let x = n << 32;").is_empty());
    }

    #[test]
    fn deprecated_shim_flagged_outside_tests() {
        let out = lint_str("dist_fft/driver.rs", "#[allow(deprecated)]\nfn f() {}");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RULE_DEPRECATED);
        let test_only = "#[cfg(test)]\nmod tests { #[allow(deprecated)] fn f() {} }";
        assert!(lint_str("dist_fft/driver.rs", test_only).is_empty());
    }

    #[test]
    fn unwrap_scoped_to_harness_modules() {
        let code = "fn f() { let v = s.parse::<u64>().unwrap(); }";
        assert_eq!(lint_str("cli/args.rs", code).len(), 1);
        assert_eq!(lint_str("bench_harness/fig3.rs", code).len(), 1);
        assert_eq!(lint_str("main.rs", code).len(), 1);
        assert!(lint_str("fft/plan.rs", code).is_empty(), "library code is out of scope");
    }

    #[test]
    fn hotpath_marker_forbids_allocation() {
        let code = "// xtask: hot_path\nfn kernel(x: &[u32]) { let y = x.to_vec(); }";
        let out = lint_str("fft/simd.rs", code);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RULE_HOTPATH);
        assert!(out[0].message.contains("kernel"), "{}", out[0].message);
        // Unmarked functions may allocate freely.
        assert!(lint_str("fft/simd.rs", "fn scratch() -> Vec<u32> { Vec::new() }").is_empty());
        // Marked allocation-free kernels pass.
        let clean = "// xtask: hot_path\nfn kernel(x: &mut [u32]) { for v in x { *v += 1; } }";
        assert!(lint_str("fft/simd.rs", clean).is_empty());
    }

    #[test]
    fn allow_marker_suppresses_one_line() {
        let code = "// xtask: allow(raw-tag-literal)\nconst S: u64 = 1 << 32;";
        assert!(lint_str("hpx/parcel.rs", code).is_empty());
        // A marker for a different rule does not suppress.
        let other = "// xtask: allow(spmd-collective)\nconst S: u64 = 1 << 32;";
        assert_eq!(lint_str("hpx/parcel.rs", other).len(), 1);
    }

    #[test]
    fn json_report_is_escaped_and_structured() {
        let dir = std::env::temp_dir().join("xtask-json-test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        let findings = vec![Finding {
            file: "a.rs".into(),
            line: 3,
            rule: RULE_RAWTAG,
            message: "raw \"literal\"".into(),
        }];
        write_json(&path, Path::new("root"), 2, &findings);
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"files_scanned\": 2"), "{text}");
        assert!(text.contains("\\\"literal\\\""), "{text}");
        assert!(text.contains("\"line\": 3"), "{text}");
    }
}
