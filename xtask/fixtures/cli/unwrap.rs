//! Seeded violation for `unwrap-in-harness` (`xtask lint --self-test`).
//! Lives under `cli/` because the rule is scoped to user-input
//! harnesses. Not compiled — scanned as data.

fn parse_size(raw: &str) -> usize {
    // BAD: a mistyped flag value panics instead of producing a typed
    // error that names the flag.
    raw.parse::<usize>().unwrap()
}
