//! Seeded violation for `hot-path-alloc` (`xtask lint --self-test`).
//! Not compiled — scanned as data.

// xtask: hot_path
fn butterfly_pass(src: &[Complex32], dst: &mut [Complex32]) {
    // BAD: clones the input inside a marked steady-state kernel.
    let scratch = src.to_vec();
    dst.copy_from_slice(&scratch);
}
