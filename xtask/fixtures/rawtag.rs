//! Seeded violation for `raw-tag-literal` (`xtask lint --self-test`).
//! Not compiled — scanned as data.

/// BAD: re-derives the chunk-tag span instead of importing
/// `collectives::tags::CHUNK_TAG_SPAN`.
const LOCAL_SPAN: u64 = 1 << 32;

fn base_for(index: u64) -> u64 {
    index * LOCAL_SPAN
}
