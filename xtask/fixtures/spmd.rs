//! Seeded violation for `spmd-collective` (`xtask lint --self-test`).
//! Not compiled into any crate — scanned as data by the lint pass.

fn diverge(comm: &Communicator) {
    // BAD: only rank 0 reaches the barrier; ranks 1.. hang in their
    // next collective waiting for a peer that is parked here.
    if comm.rank() == 0 {
        comm.barrier();
    }
}
