//! Seeded violation for `lease-blocking-collective`
//! (`xtask lint --self-test`). Not compiled — scanned as data.

fn hold_and_block(comm: &Communicator, shared: &Shared) {
    let (pool, shadow) = lease_pools(shared, 4);
    // BAD: blocking collective while the lease above is live — a peer
    // job waiting for these pools can never run the rank this
    // all_gather is waiting on.
    let gathered = comm.all_gather(local_rows());
    consume(pool, shadow, gathered);
}
