//! Near-misses for every rule — the self-test asserts this file yields
//! zero findings. Not compiled — scanned as data.

/// Unconditional collective: fine (every rank reaches it).
fn spmd_ok(comm: &Communicator) {
    comm.barrier();
    // Rank used to pick data, not to skip the collective: fine.
    let mine = comm.rank();
    if mine == 0 {
        log_leader();
    }
    comm.all_gather(mine);
}

/// Lease released (scope ends) before the collective: fine.
fn lease_ok(comm: &Communicator, shared: &Shared) {
    {
        let (pool, shadow) = lease_pools(shared, 4);
        compute(pool, shadow);
    }
    comm.all_gather(done());
}

/// Small shifts and strings are not tag spans.
fn rawtag_ok() -> u64 {
    let block = 1u64 << 16;
    let label = "span is 1 << 32 wide"; // literal text: blanked, ignored
    // An explicitly waived use keeps working under suppression:
    // xtask: allow(raw-tag-literal)
    let waived = 1 << 32;
    block + waived + label.len() as u64
}

/// `unwrap` outside harness paths and inside tests is out of scope.
fn hotpath_unmarked_may_allocate(n: usize) -> Vec<u64> {
    let mut v = Vec::with_capacity(n);
    v.extend(0..n as u64);
    v
}

// xtask: hot_path
fn marked_kernel_allocation_free(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.mul_add(2.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    /// Test code may unwrap and may exercise deprecated shims.
    #[allow(deprecated)]
    fn in_tests_everything_is_relaxed() {
        let v: usize = "7".parse().unwrap();
        assert_eq!(v, 7);
    }
}
