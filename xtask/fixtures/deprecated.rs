//! Seeded violation for `deprecated-shim` (`xtask lint --self-test`).
//! Not compiled — scanned as data.

// BAD: opts back into a quarantined compatibility shim in library code.
#[allow(deprecated)]
fn call_legacy_entry_point() {
    legacy_transform();
}
