//! End-to-end driver: every layer of the stack on one real workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The full three-layer composition the repo exists to demonstrate:
//!
//! - **L1/L2** (build time): the Pallas four-step FFT kernel inside the
//!   JAX model, AOT-lowered to `artifacts/*.hlo.txt`;
//! - **runtime**: the Rust PJRT service loads and compiles the artifacts
//!   (no Python anywhere in this process);
//! - **L3**: an HPX-style cluster of localities runs the distributed
//!   2-D FFT, with the per-locality row FFTs executed *through the PJRT
//!   artifact*, chunks moved by the LCI parcelport under the calibrated
//!   InfiniBand wire model, and the result verified against the native
//!   serial reference.
//!
//! Reports per-variant latency and grid throughput; recorded in
//! EXPERIMENTS.md §End-to-end.

use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy};
use hpx_fft::dist_fft::driver::{run, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant};
use hpx_fft::metrics::table::Table;
use hpx_fft::parcelport::{NetModel, PortKind};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    anyhow::ensure!(
        std::path::Path::new(&artifacts).join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let (rows, cols, nodes) = (256usize, 256usize, 4usize);
    println!(
        "end-to-end: {rows}×{cols} grid on {nodes} localities, PJRT engine from {artifacts}/\n"
    );

    let mut table = Table::new(&["variant", "port", "engine", "latency", "throughput", "rel err"]);
    for variant in [Variant::AllToAll, Variant::Scatter] {
        for engine in [ComputeEngine::Native, ComputeEngine::Pjrt(artifacts.clone())] {
            let config = DistFftConfig {
                rows,
                cols,
                localities: nodes,
                port: PortKind::Lci,
                variant,
                algo: AllToAllAlgo::HpxRoot,
                chunk: ChunkPolicy::default(),
                exec: ExecutionMode::Blocking,
                domain: Domain::Complex,
                threads_per_locality: 2,
                net: Some(NetModel::infiniband_hdr()),
                engine: engine.clone(),
                verify: true,
            };
            // Warm once (PJRT compile, plan cache), measure second run.
            let _ = run(&config)?;
            let report = run(&config)?;
            let err = report.rel_error.expect("verified");
            anyhow::ensure!(err < 1e-4, "verification failed: {err}");
            let total_us = report.critical_path.total_us;
            // 2-D FFT work: 5·R·C·log2(R·C) FLOP.
            let flops = 5.0 * (rows * cols) as f64 * ((rows * cols) as f64).log2();
            table.row(&[
                variant.name().into(),
                "lci".into(),
                match &engine {
                    ComputeEngine::Native => "native".into(),
                    ComputeEngine::Pjrt(_) => "pjrt".into(),
                },
                format!("{:.2} ms", total_us / 1e3),
                format!("{:.2} GFLOP/s", flops / total_us / 1e3),
                format!("{err:.1e}"),
            ]);
        }
    }
    print!("{}", table.render());
    println!("\nend_to_end OK — all layers composed (Pallas kernel → JAX model → HLO → PJRT → HPX coordinator)");
    Ok(())
}
