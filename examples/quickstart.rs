//! Quickstart: a distributed 2-D FFT on four simulated localities.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Boots an LCI-parcelport cluster, runs the paper's four-step algorithm
//! with the N-scatter variant, verifies against the serial reference, and
//! prints per-step timings — the smallest complete tour of the system.

use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy};
use hpx_fft::dist_fft::driver::{run, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant};
use hpx_fft::parcelport::PortKind;

fn main() -> anyhow::Result<()> {
    let config = DistFftConfig {
        rows: 256,
        cols: 256,
        localities: 4,
        port: PortKind::Lci,
        variant: Variant::Scatter,
        algo: AllToAllAlgo::HpxRoot,
        chunk: ChunkPolicy::default(),
        exec: ExecutionMode::Blocking,
        domain: Domain::Complex,
        threads_per_locality: 2,
        net: None,
        engine: ComputeEngine::Native,
        verify: true,
    };

    println!("four-step distributed FFT (paper Fig. 1):");
    println!("  1. row FFTs on each locality's slab");
    println!("  2. N-scatter communication ((1 - 1/N) of local data moves)");
    println!("  3. chunk transposes, overlapped with the scatters");
    println!("  4. row FFTs of the transposed slab\n");

    let report = run(&config)?;
    println!("{}", report.config_summary);
    for (rank, t) in report.per_rank.iter().enumerate() {
        println!(
            "  locality {rank}: total {:7.2} ms  (fft1 {:6.2} | comm+transpose {:6.2} | fft2 {:6.2})",
            t.total_us / 1e3,
            t.fft1_us / 1e3,
            t.comm_us / 1e3,
            t.fft2_us / 1e3
        );
    }
    println!(
        "traffic: {} parcels, {} payload bytes, {} protocol copies",
        report.stats.msgs_sent, report.stats.bytes_sent, report.stats.payload_copies
    );

    let err = report.rel_error.expect("verification enabled");
    println!("verification vs serial reference: rel L2 error = {err:.2e}");
    anyhow::ensure!(err < 1e-4, "verification failed");
    println!("quickstart OK");
    Ok(())
}
