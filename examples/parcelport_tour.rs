//! Tour of the HPX substrate: parcels, AGAS, and the three parcelports.
//!
//! ```sh
//! cargo run --release --example parcelport_tour
//! ```
//!
//! Demonstrates, per backend: point-to-point parcels, AGAS name
//! resolution, a collective, and each port's characteristic protocol
//! behaviour (TCP's copies, MPI's eager/rendezvous split, LCI's
//! zero-copy hand-off), straight from the port statistics.

use hpx_fft::collectives::{AllToAllAlgo, Communicator};
use hpx_fft::hpx::agas::GlobalAddress;
use hpx_fft::hpx::parcel::Payload;
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::parcelport::{mpi::EAGER_THRESHOLD, PortKind};

fn main() -> anyhow::Result<()> {
    for port in PortKind::ALL {
        println!("=== {} parcelport ===", port);
        let cluster = Cluster::new(4, port, None)?;

        // 1. Parcels + AGAS: every locality registers a component and
        //    pings its ring neighbour.
        let pings = cluster.run(|ctx| {
            ctx.agas.register(
                &format!("/tour/{}", ctx.rank),
                GlobalAddress { locality: ctx.rank, component: 0 },
            );
            let next = (ctx.rank + 1) % ctx.n;
            let addr = ctx.agas.resolve(&format!("/tour/{next}"));
            ctx.send(addr.locality, 1, Payload::from_f32(&[ctx.rank as f32]));
            let prev = (ctx.rank + ctx.n - 1) % ctx.n;
            ctx.recv(prev, 1).to_f32()[0]
        });
        println!("  ring ping (AGAS-resolved): {pings:?}");

        // 2. A collective with both small (eager) and large (rendezvous-
        //    sized) chunks.
        for &bytes in &[1024usize, EAGER_THRESHOLD + 1] {
            let before = cluster.fabric().stats();
            cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                let chunks: Vec<Payload> =
                    (0..ctx.n).map(|_| Payload::new(vec![ctx.rank as u8; bytes])).collect();
                let recv = comm.all_to_all(chunks, AllToAllAlgo::Pairwise);
                assert!(recv.iter().enumerate().all(|(src, p)| p.as_bytes()[0] == src as u8));
            });
            let d = cluster.fabric().stats().since(&before);
            println!(
                "  all-to-all ({:>7} B chunks): {} msgs, {} copies, {} eager, {} rendezvous",
                bytes, d.msgs_sent, d.payload_copies, d.eager_sends, d.rendezvous_handshakes
            );
        }
        println!();
    }
    println!("parcelport_tour OK");
    Ok(())
}
