//! Regenerate paper Figs. 4 & 5 (strong scaling, both variants).
//!
//! ```sh
//! cargo run --release --example fig45_strong_scaling            # full
//! cargo run --release --example fig45_strong_scaling -- quick   # smoke
//! ```
//!
//! Live hybrid runs at laptop scale + simnet predictions at the paper's
//! 2^14×2^14 on 1–16 buran nodes, for every parcelport and the
//! FFTW3-like baseline.

use hpx_fft::bench_harness::fig45;
use hpx_fft::config::BenchConfig;
use hpx_fft::dist_fft::driver::Variant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    for variant in [Variant::AllToAll, Variant::Scatter] {
        let fig = match variant {
            Variant::AllToAll => "Fig. 4",
            Variant::Scatter => "Fig. 5",
        };
        println!("=== {fig}: {} variant ===\n", variant.name());
        let points = fig45::run(&config, variant)?;
        print!("{}", fig45::report(&points, variant, &config, &config.out_dir)?);
        println!();
    }
    Ok(())
}
