//! Execute the AOT-compiled 2-D FFT artifact directly via PJRT.
//!
//! ```sh
//! make artifacts && cargo run --release --example pjrt_fft
//! ```
//!
//! Loads `artifacts/fft2_t_r256_c256.hlo.txt` (the whole four-step
//! pipeline as a single compiled program: Pallas FFT kernel → Pallas
//! tiled transpose → Pallas FFT kernel), runs it on a synthetic grid,
//! and checks the numbers against the native serial reference.

use hpx_fft::dist_fft::partition::Slab;
use hpx_fft::dist_fft::verify::{rel_error, serial_fft2_transposed};
use hpx_fft::fft::complex::{from_planes, to_planes};
use hpx_fft::runtime::ComputeService;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let (rows, cols) = (256usize, 256usize);

    let t0 = std::time::Instant::now();
    let service = ComputeService::shared(&artifacts)?;
    println!("compiled artifacts in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    let grid = Slab::whole(rows, cols).data;
    let (re, im) = to_planes(&grid);

    let t0 = std::time::Instant::now();
    let (out_re, out_im) = service.fft2_transposed(rows, cols, re, im)?;
    let pjrt_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = std::time::Instant::now();
    let reference = serial_fft2_transposed(&grid, rows, cols);
    let native_ms = t0.elapsed().as_secs_f64() * 1e3;

    let got = from_planes(&out_re, &out_im);
    let err = rel_error(&got, &reference);
    println!("{rows}×{cols} transposed 2-D FFT:");
    println!("  pjrt artifact : {pjrt_ms:.2} ms");
    println!("  native serial : {native_ms:.2} ms");
    println!("  rel L2 error  : {err:.2e}");
    anyhow::ensure!(err < 1e-4, "numerics mismatch");
    println!("pjrt_fft OK");
    Ok(())
}
