//! Regenerate paper Fig. 3 (chunk-size scaling) — example wrapper around
//! the benchmark harness.
//!
//! ```sh
//! cargo run --release --example fig3_chunk_size            # full sweep
//! cargo run --release --example fig3_chunk_size -- quick   # smoke
//! ```

use hpx_fft::bench_harness::fig3;
use hpx_fft::config::BenchConfig;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    println!(
        "Fig. 3: scatter chunk-size sweep on 2 localities, {} reps/point\n",
        config.reps
    );
    let points = fig3::run(&config)?;
    print!("{}", fig3::report(&points, &config.out_dir)?);
    println!("CSV: {}/fig3_chunk_size.csv", config.out_dir);
    Ok(())
}
