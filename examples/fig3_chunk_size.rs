//! Regenerate paper Fig. 3 (chunk-size scaling) — example wrapper around
//! the benchmark harness.
//!
//! Every (port × payload size) point is measured with both scatter
//! algorithms: `linear` (the paper's monolithic scatter) and `pipelined`
//! (policy-sized zero-copy wire chunks drained by the send pool), so the
//! sweep shows where pipelining amortizes the per-message overheads.
//!
//! ```sh
//! cargo run --release --example fig3_chunk_size            # full sweep
//! cargo run --release --example fig3_chunk_size -- quick   # smoke
//! ```

use hpx_fft::bench_harness::fig3;
use hpx_fft::config::BenchConfig;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    println!(
        "Fig. 3: scatter chunk-size sweep on 2 localities, {} reps/point,\n\
         algorithms: linear + pipelined ({} B wire chunks × {} in flight)\n",
        config.reps, config.pipeline.chunk_bytes, config.pipeline.inflight
    );
    let points = fig3::run(&config)?;
    print!("{}", fig3::report(&points, &config.out_dir)?);
    println!("CSV: {}/fig3_chunk_size.csv", config.out_dir);
    Ok(())
}
