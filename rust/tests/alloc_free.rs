//! Steady-state allocation audit — proof that the compute hot paths are
//! allocation-free once warm, enforced by a counting global allocator.
//!
//! Every allocation in this test binary bumps a global counter; a test
//! warms a path (first call grows plan tables and scratch buffers to
//! their high-water mark), then asserts the warm path's allocation delta
//! is exactly zero. The libtest harness runs tests on several threads
//! and its own bookkeeping allocates, so each measuring test (a) holds a
//! serializing lock and (b) takes the *minimum* delta over several
//! repetitions — a genuinely allocating hot path scores ≥ 1 on every
//! repetition, while harness noise would have to pollute all of them to
//! produce a false failure.

#![deny(unsafe_op_in_unsafe_fn)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use hpx_fft::config::TransformSpec;
use hpx_fft::dist_fft::grid3::{place_t1_slice, place_t2_slice, Grid3, PencilDims, ProcGrid};
use hpx_fft::dist_fft::transpose::{place_chunk_slice_transposed, place_chunk_transposed};
use hpx_fft::dist_fft::TransformRequest;
use hpx_fft::fft::plan::{Direction, Plan, PlanCache};
use hpx_fft::fft::{Complex32, FftScratch, RealPlan};
use hpx_fft::util::rng::Pcg32;

/// Counts every heap acquisition (alloc, alloc_zeroed, realloc) and
/// delegates the actual work to the system allocator. Frees are not
/// counted: the property under test is "no new memory", not "no frees".
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to the system allocator plus a relaxed
// counter bump — every contract obligation (layout validity, pointer
// provenance) is forwarded unchanged to `System`, whose own caller
// obligations are exactly ours.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same contract as ours; `layout` is forwarded verbatim.
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr` came from our `alloc`, which delegated to
        // `System` with this same `layout`.
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: `ptr`/`layout` originate from our delegating `alloc`.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same contract as ours; `layout` is forwarded verbatim.
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the measuring tests against each other.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Minimum allocation delta of `f` over `reps` runs (see module doc).
fn min_delta(reps: usize, mut f: impl FnMut()) -> u64 {
    (0..reps)
        .map(|_| {
            let before = ALLOCS.load(Ordering::SeqCst);
            f();
            ALLOCS.load(Ordering::SeqCst) - before
        })
        .min()
        .expect("reps >= 1")
}

fn signal(n: usize, seed: u64) -> Vec<Complex32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
}

/// Every planner kernel — split-radix (pow2), mixed-radix (composite),
/// Bluestein (prime) — runs allocation-free against a warm caller-owned
/// scratch.
#[test]
fn warm_plan_execute_with_scratch_is_allocation_free() {
    let _guard = serial();
    for n in [1024usize, 1000, 1013] {
        let plan = Plan::new(n, Direction::Forward);
        let mut scratch = FftScratch::new();
        let mut buf = signal(n, 1);
        plan.execute_with_scratch(&mut buf, &mut scratch);
        plan.execute_with_scratch(&mut buf, &mut scratch);
        let delta = min_delta(5, || plan.execute_with_scratch(&mut buf, &mut scratch));
        assert_eq!(delta, 0, "warm execute_with_scratch allocated (n={n})");
    }
}

/// The scratch-less entry point reuses the thread's persistent scratch,
/// so it too is allocation-free once this thread has run a transform of
/// each shape.
#[test]
fn warm_thread_local_execute_is_allocation_free() {
    let _guard = serial();
    for n in [512usize, 1000, 1013] {
        let plan = Plan::new(n, Direction::Forward);
        let mut buf = signal(n, 2);
        plan.execute(&mut buf);
        plan.execute(&mut buf);
        let delta = min_delta(5, || plan.execute(&mut buf));
        assert_eq!(delta, 0, "warm thread-local execute allocated (n={n})");
    }
}

/// The packed r2c path (pack → half-size complex FFT → unpack) against a
/// warm caller-owned scratch.
#[test]
fn warm_real_plan_execute_packed_is_allocation_free() {
    let _guard = serial();
    for n in [256usize, 1000] {
        let plan = RealPlan::new(n);
        let mut scratch = FftScratch::new();
        let x: Vec<f32> = (0..n).map(|i| (i % 13) as f32 - 6.0).collect();
        let mut out = vec![Complex32::ZERO; n / 2];
        plan.execute_packed(&x, &mut out, &mut scratch);
        plan.execute_packed(&x, &mut out, &mut scratch);
        let delta = min_delta(5, || plan.execute_packed(&x, &mut out, &mut scratch));
        assert_eq!(delta, 0, "warm execute_packed allocated (n={n})");
    }
}

/// A warm plan-cache lookup hands back the memoized `Arc` without
/// touching the heap.
#[test]
fn warm_plan_cache_lookup_is_allocation_free() {
    let _guard = serial();
    let cache = PlanCache::new();
    drop(cache.plan(512, Direction::Forward));
    let delta = min_delta(5, || drop(cache.plan(512, Direction::Forward)));
    assert_eq!(delta, 0, "warm plan-cache lookup allocated");
}

/// The transpose placement primitives write into caller-owned slabs and
/// never allocate — not even cold.
#[test]
fn chunk_placement_is_allocation_free() {
    let _guard = serial();
    let (rows, cols) = (96usize, 80usize);
    let chunk = signal(rows * cols, 3);
    let mut slab = vec![Complex32::ZERO; cols * rows];
    let delta = min_delta(3, || {
        place_chunk_transposed(&chunk, rows, cols, &mut slab, rows, 0);
        place_chunk_slice_transposed(&chunk[17..], 17, rows, cols, &mut slab, rows, 0);
    });
    assert_eq!(delta, 0, "chunk placement allocated");
}

/// The 3-D pencil placement reductions delegate to the same primitive
/// and inherit the property.
#[test]
fn pencil_placement_is_allocation_free() {
    let _guard = serial();
    let dims = PencilDims::new(Grid3::new(8, 8, 8), ProcGrid::new(2, 2)).expect("dims");
    let t1 = signal(dims.t1_chunk_elems(), 4);
    let t2 = signal(dims.t2_chunk_elems(), 5);
    let mut stage_y = vec![Complex32::ZERO; dims.d0 * dims.d2c * dims.grid.n1];
    let mut stage_x = vec![Complex32::ZERO; dims.d2c * dims.d1r * dims.grid.n0];
    let delta = min_delta(3, || {
        place_t1_slice(&t1, 0, &dims, &mut stage_y, 1);
        place_t2_slice(&t2, 0, &dims, &mut stage_x, 1);
    });
    assert_eq!(delta, 0, "pencil placement allocated");
}

/// Disabled-mode tracing primitives never touch the heap: the gate
/// check is one relaxed atomic load, the guard carries `None`, and no
/// ring buffer or open-span table is consulted. This is what licenses
/// leaving span constructors compiled into every hot layer. (Nothing in
/// this binary ever enables the gate, so the path measured here is the
/// one every untraced run takes.)
#[test]
fn disabled_tracing_is_allocation_free() {
    let _guard = serial();
    let delta = min_delta(5, || {
        let _g = hpx_fft::obs::span("alloc", "span", 0);
        let _g2 = hpx_fft::obs::span_args("alloc", "span_args", 1, 2, 3, 4);
        hpx_fft::obs::instant("alloc", "instant", 0);
        hpx_fft::obs::instant_args("alloc", "instant_args", 1, 2, 3, 4);
    });
    assert_eq!(delta, 0, "disabled tracing allocated");
}

/// The end-to-end steady-state gate: a warm multi-tenant-API transform
/// run should eventually allocate nothing. The distributed pipeline
/// still allocates per run (cluster threads, wire buffers, report
/// strings), so this is `#[ignore]`d — an audit hook, run explicitly
/// with `cargo test --test alloc_free -- --ignored` to measure how far
/// the hot path has come.
#[test]
#[ignore = "end-to-end pipeline still allocates per run; explicit audit hook"]
fn warm_transform_request_run_is_allocation_free() {
    let _guard = serial();
    let transform = TransformRequest::grid(64, 64)
        .spec(TransformSpec { threads_per_locality: 1, verify: false, ..TransformSpec::default() })
        .localities(2)
        .build()
        .expect("build transform");
    transform.run().expect("warm run");
    let delta = min_delta(3, || {
        transform.run().expect("steady-state run");
    });
    assert_eq!(delta, 0, "warm TransformRequest::run allocated {delta} times");
}
