//! Crate-level integration tests: cross-module behaviour that unit tests
//! inside each module cannot see — ports × collectives × FFT × baseline
//! consistency, and the figure harnesses end to end.

use hpx_fft::baseline::fftw_like::{self, FftwLikeConfig};
use hpx_fft::bench_harness::{fig3, fig45};
use hpx_fft::collectives::AllToAllAlgo;
use hpx_fft::config::BenchConfig;
use hpx_fft::dist_fft::driver::{self, ComputeEngine, DistFftConfig, Variant};
use hpx_fft::parcelport::{NetModel, PortKind};

/// Every (port × variant × algorithm) combination computes the identical
/// transform: the full equivalence matrix of the communication layer.
#[test]
fn full_equivalence_matrix() {
    let mut reference: Option<f64> = None;
    for port in PortKind::ALL {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            for algo in [AllToAllAlgo::Linear, AllToAllAlgo::Pairwise, AllToAllAlgo::HpxRoot] {
                let config = DistFftConfig {
                    rows: 32,
                    cols: 32,
                    localities: 4,
                    port,
                    variant,
                    algo,
                    threads_per_locality: 1,
                    net: None,
                    engine: ComputeEngine::Native,
                    verify: true,
                };
                let report = driver::run(&config).unwrap();
                let err = report.rel_error.unwrap();
                assert!(err < 1e-4, "{port} {variant:?} {algo:?}: rel err {err}");
                match reference {
                    None => reference = Some(err),
                    Some(r) => assert_eq!(err, r, "all paths do identical arithmetic"),
                }
            }
        }
    }
}

/// The baseline and the HPX variants agree on the math.
#[test]
fn baseline_agrees_with_hpx() {
    let report = fftw_like::run(&FftwLikeConfig {
        rows: 64,
        cols: 64,
        localities: 4,
        threads: 2,
        net: None,
        verify: true,
    })
    .unwrap();
    assert!(report.rel_error.unwrap() < 1e-4);
}

/// The hybrid wire model does not change results, only timing.
#[test]
fn wire_model_is_numerically_transparent() {
    let base = DistFftConfig {
        rows: 32,
        cols: 32,
        localities: 2,
        threads_per_locality: 1,
        verify: true,
        ..Default::default()
    };
    let without = driver::run(&base).unwrap();
    let with = driver::run(&DistFftConfig {
        net: Some(NetModel::infiniband_hdr()),
        ..base
    })
    .unwrap();
    assert_eq!(without.rel_error, with.rel_error);
    assert!(with.stats.modeled_wire_us > 0, "wire model must be charged");
    assert_eq!(without.stats.modeled_wire_us, 0);
}

/// Fig. 3 harness end to end (tiny): produces the paper's ordering.
#[test]
fn fig3_harness_ordering() {
    let cfg = BenchConfig {
        reps: 3,
        warmup: 1,
        chunk_sizes: vec![4096],
        ..BenchConfig::quick()
    };
    let points = fig3::run(&cfg).unwrap();
    let mean = |port| {
        points.iter().find(|p| p.port == port).unwrap().live.mean()
    };
    assert!(mean(PortKind::Lci) < mean(PortKind::Tcp));
}

/// Figs. 4/5 harness end to end (tiny): the three paper findings hold in
/// the simnet predictions at paper scale.
#[test]
fn fig45_harness_paper_findings() {
    let cfg = BenchConfig {
        reps: 1,
        warmup: 0,
        live_grid: 32,
        live_nodes: vec![2],
        sim_nodes: vec![16],
        threads: 1,
        ..BenchConfig::quick()
    };
    let fig4 = fig45::run(&cfg, Variant::AllToAll).unwrap();
    let fig5 = fig45::run(&cfg, Variant::Scatter).unwrap();
    let sim = |points: &[fig45::ScalingPoint], sys: fig45::System| {
        points.iter().find(|p| p.system == sys).unwrap().sim_us
    };
    use fig45::System;
    // (1) LCI is the fastest parcelport in both variants.
    for points in [&fig4, &fig5] {
        assert!(sim(points, System::Hpx(PortKind::Lci)) <= sim(points, System::Hpx(PortKind::Mpi)));
        assert!(sim(points, System::Hpx(PortKind::Lci)) <= sim(points, System::Hpx(PortKind::Tcp)));
    }
    // (2) The scatter variant beats the all-to-all variant.
    for port in PortKind::ALL {
        assert!(sim(&fig5, System::Hpx(port)) < sim(&fig4, System::Hpx(port)));
    }
    // (3) HPX+LCI (scatter) beats the FFTW3 reference.
    assert!(sim(&fig5, System::Hpx(PortKind::Lci)) < sim(&fig5, System::Fftw3));
}

/// PJRT engine in the distributed driver (gated on artifacts).
#[test]
fn distributed_fft_through_pjrt_engine() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let config = DistFftConfig {
        rows: 256,
        cols: 256,
        localities: 4,
        port: PortKind::Lci,
        variant: Variant::Scatter,
        threads_per_locality: 1,
        engine: ComputeEngine::Pjrt(dir.to_str().unwrap().to_string()),
        verify: true,
        ..Default::default()
    };
    let report = driver::run(&config).unwrap();
    assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
}

/// Stress: repeated runs on one fabric (leak/ordering regression guard).
#[test]
fn repeated_runs_stable() {
    let cluster =
        hpx_fft::hpx::runtime::Cluster::new(4, PortKind::Lci, None).unwrap();
    let config = DistFftConfig {
        rows: 32,
        cols: 32,
        localities: 4,
        threads_per_locality: 1,
        verify: true,
        ..Default::default()
    };
    for _ in 0..10 {
        let report = driver::run_on(&cluster, &config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
    }
    // Mailboxes must be fully drained between runs.
    for rank in 0..4 {
        assert_eq!(cluster.fabric().mailbox(rank).pending(), 0, "leftover parcels at {rank}");
    }
}
