//! Crate-level integration tests: cross-module behaviour that unit tests
//! inside each module cannot see — ports × collectives × FFT × baseline
//! consistency, and the figure harnesses end to end.

use hpx_fft::baseline::fftw_like::{self, FftwLikeConfig};
use hpx_fft::bench_harness::{fig3, fig45};
use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy, Communicator, ScatterAlgo};
use hpx_fft::config::BenchConfig;
use hpx_fft::dist_fft::driver::{
    self, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant,
};
use hpx_fft::dist_fft::TransformRequest;
use hpx_fft::hpx::parcel::Payload;
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::parcelport::{NetModel, PortKind, PortStatsSnapshot};
use hpx_fft::runtime::{AdmissionError, FftService, ServiceConfig};

/// Every (port × variant × algorithm) combination computes the identical
/// transform: the full equivalence matrix of the communication layer.
/// The chunk policy is set small enough that the chunked algorithms'
/// wire traffic really splits (32×32 on 4 ranks → 512 B messages over
/// 128 B chunks).
// The direct driver/variant entry points survive as `#[deprecated]`
// shims over the `TransformRequest` internals; the matrix tests below
// call them on purpose — they are the shims' coverage.
#[test]
#[allow(deprecated)]
fn full_equivalence_matrix() {
    let mut reference: Option<f64> = None;
    for port in PortKind::ALL {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            for algo in [
                AllToAllAlgo::Linear,
                AllToAllAlgo::Pairwise,
                AllToAllAlgo::PairwiseChunked,
                AllToAllAlgo::HpxRoot,
            ] {
                let config = DistFftConfig {
                    rows: 32,
                    cols: 32,
                    localities: 4,
                    port,
                    variant,
                    algo,
                    chunk: ChunkPolicy::new(128, 2),
                    exec: ExecutionMode::Blocking,
                    domain: Domain::Complex,
                    threads_per_locality: 1,
                    net: None,
                    engine: ComputeEngine::Native,
                    verify: true,
                };
                let report = driver::run(&config).unwrap();
                let err = report.rel_error.unwrap();
                assert!(err < 1e-4, "{port} {variant:?} {algo:?}: rel err {err}");
                match reference {
                    None => reference = Some(err),
                    Some(r) => assert_eq!(err, r, "all paths do identical arithmetic"),
                }
            }
        }
    }
}

/// Mixed-radix acceptance: both distributed variants produce
/// DFT-oracle-verified results on a non-power-of-two grid over all
/// three parcelports. The oracle is the O(n²) f64-accumulating DFT
/// (row DFTs → transpose → row DFTs), not the fast planner — so this
/// pins the whole distributed pipeline against ground truth.
#[test]
#[allow(deprecated)]
fn non_pow2_grid_dft_verified_all_ports_both_variants() {
    use hpx_fft::dist_fft::driver::NativeRowFft;
    use hpx_fft::dist_fft::partition::Slab;
    use hpx_fft::dist_fft::transpose::transpose;
    use hpx_fft::dist_fft::verify::rel_error;
    use hpx_fft::fft::complex::Complex32;
    use hpx_fft::fft::dft::dft;

    let (rows, cols, parts) = (12usize, 20usize, 4usize);
    let grid = Slab::whole(rows, cols).data;
    let mut work: Vec<Complex32> = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        work.extend(dft(&grid[r * cols..(r + 1) * cols]));
    }
    let t = transpose(&work, rows, cols);
    let mut oracle: Vec<Complex32> = Vec::with_capacity(rows * cols);
    for c in 0..cols {
        oracle.extend(dft(&t[c * rows..(c + 1) * rows]));
    }

    for port in PortKind::ALL {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            let cluster = Cluster::new(parts, port, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                match variant {
                    Variant::Scatter => {
                        hpx_fft::dist_fft::scatter_variant::run(&comm, &slab, 2, &NativeRowFft).0
                    }
                    Variant::AllToAll => {
                        hpx_fft::dist_fft::all_to_all_variant::run(
                            &comm,
                            &slab,
                            AllToAllAlgo::PairwiseChunked,
                            2,
                            &NativeRowFft,
                        )
                        .0
                    }
                }
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let err = rel_error(&assembled, &oracle);
            assert!(err < 1e-4, "{port} {variant:?}: rel err {err} vs DFT oracle");
        }
    }
}

/// The async-equivalence acceptance matrix: for every parcelport × the
/// three pipelined communication shapes — *flat* (linear all-to-all),
/// *pairwise-chunked* (chunked all-to-all), *pipelined* (the N-scatter
/// variant with chunk-pipelined scatters) — the futures execution mode
/// must produce **byte-identical** results to the blocking mode, and
/// both must match the O(n²) f64-accumulating DFT oracle, on a
/// non-power-of-two grid.
#[test]
#[allow(deprecated)]
fn async_equivalence_dft_verified_all_ports_all_shapes() {
    use hpx_fft::dist_fft::driver::NativeRowFft;
    use hpx_fft::dist_fft::partition::Slab;
    use hpx_fft::dist_fft::transpose::transpose;
    use hpx_fft::dist_fft::verify::rel_error;
    use hpx_fft::fft::complex::Complex32;
    use hpx_fft::fft::dft::dft;
    use hpx_fft::dist_fft::{all_to_all_variant, scatter_variant};

    let (rows, cols, parts) = (12usize, 24usize, 4usize);
    let grid = Slab::whole(rows, cols).data;
    let mut work: Vec<Complex32> = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        work.extend(dft(&grid[r * cols..(r + 1) * cols]));
    }
    let t = transpose(&work, rows, cols);
    let mut oracle: Vec<Complex32> = Vec::with_capacity(rows * cols);
    for c in 0..cols {
        oracle.extend(dft(&t[c * rows..(c + 1) * rows]));
    }

    #[derive(Clone, Copy, Debug)]
    enum Shape {
        Flat,            // linear all-to-all, one monolithic message per peer
        PairwiseChunked, // chunked all-to-all wire protocol
        Pipelined,       // N-scatter with chunk-pipelined scatters
    }

    for port in PortKind::ALL {
        for shape in [Shape::Flat, Shape::PairwiseChunked, Shape::Pipelined] {
            let run_mode = |async_mode: bool| -> Vec<Vec<Complex32>> {
                let cluster = Cluster::new(parts, port, None).unwrap();
                cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    // Small wire chunks: the chunked shapes really split.
                    comm.set_chunk_policy(ChunkPolicy::new(96, 2));
                    let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                    match (shape, async_mode) {
                        (Shape::Flat, false) => {
                            all_to_all_variant::run(
                                &comm, &slab, AllToAllAlgo::Linear, 1, &NativeRowFft,
                            )
                            .0
                        }
                        (Shape::Flat, true) => {
                            all_to_all_variant::run_async(
                                &comm, &slab, AllToAllAlgo::Linear, 1, &NativeRowFft,
                            )
                            .0
                        }
                        (Shape::PairwiseChunked, false) => {
                            all_to_all_variant::run(
                                &comm, &slab, AllToAllAlgo::PairwiseChunked, 1, &NativeRowFft,
                            )
                            .0
                        }
                        (Shape::PairwiseChunked, true) => {
                            all_to_all_variant::run_async(
                                &comm, &slab, AllToAllAlgo::PairwiseChunked, 1, &NativeRowFft,
                            )
                            .0
                        }
                        (Shape::Pipelined, false) => {
                            scatter_variant::run(&comm, &slab, 1, &NativeRowFft).0
                        }
                        (Shape::Pipelined, true) => {
                            scatter_variant::run_async(&comm, &slab, 1, &NativeRowFft).0
                        }
                    }
                })
            };
            let blocking = run_mode(false);
            let async_ = run_mode(true);
            assert_eq!(blocking, async_, "{port} {shape:?}: async deviates from blocking");
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in async_ {
                assembled.extend(p);
            }
            let err = rel_error(&assembled, &oracle);
            assert!(err < 1e-4, "{port} {shape:?}: rel err {err} vs DFT oracle");
        }
    }
}

/// Async collectives must return in O(posting) time and still settle.
/// (The per-collective behaviour is unit-tested in
/// `collectives::nonblocking`; this pins the driver-level contract: an
/// async dist-FFT run over every port stays oracle-correct and reports a
/// non-negative overlap.)
#[test]
#[allow(deprecated)]
fn async_exec_driver_all_ports() {
    for port in PortKind::ALL {
        let config = DistFftConfig {
            rows: 12,
            cols: 20,
            localities: 4,
            port,
            exec: ExecutionMode::Async,
            chunk: ChunkPolicy::new(128, 2),
            threads_per_locality: 1,
            ..Default::default()
        };
        let report = driver::run(&config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{port}: {:?}", report.rel_error);
        assert!(report.critical_path.overlap_us >= 0.0);
    }
}

/// The async acceptance timing check: on the NetModel-charged LCI port
/// the future-chained scatter variant must both *hide* wall time
/// (`overlap_us > 0`) and beat the blocking schedule end to end. Like the
/// chunked-beats-monolithic check, the spin-based wire model needs spare
/// cores, so the wall-clock half is `#[ignore]`d in the default suite and
/// exercised explicitly (CI bench-smoke job; also demonstrated by
/// `cargo bench --bench hotpath`).
#[test]
#[ignore = "wall-clock comparison; needs an unloaded machine — run with --ignored"]
#[allow(deprecated)]
fn async_beats_blocking_scatter_under_netmodel() {
    let n = 4;
    let net = NetModel { time_scale: 16.0, ..NetModel::infiniband_hdr() };
    let cluster = Cluster::new(n, PortKind::Lci, Some(net)).unwrap();
    let base = DistFftConfig {
        rows: 256,
        cols: 256,
        localities: n,
        port: PortKind::Lci,
        chunk: ChunkPolicy::new(8 * 1024, 4),
        threads_per_locality: 1,
        net: Some(net),
        verify: false,
        ..Default::default()
    };
    let best = |exec: ExecutionMode| -> (f64, f64) {
        let cfg = DistFftConfig { exec, ..base.clone() };
        (0..3)
            .map(|_| {
                let r = driver::run_on(&cluster, &cfg).unwrap();
                (r.critical_path.total_us, r.critical_path.overlap_us)
            })
            .fold((f64::INFINITY, 0.0), |acc, x| if x.0 < acc.0 { x } else { acc })
    };
    let (blocking_us, _) = best(ExecutionMode::Blocking);
    let (async_us, overlap_us) = best(ExecutionMode::Async);
    assert!(overlap_us > 0.0, "async run hid no wall time");
    assert!(
        async_us < blocking_us,
        "async scatter variant must beat blocking: {async_us:.0} µs vs {blocking_us:.0} µs"
    );
}

/// Plan-cache reuse across runs: a second lookup of the same
/// `(length, direction)` is pointer-identical and counted as a hit.
#[test]
fn plan_cache_reused_across_runs() {
    use hpx_fft::fft::{Direction, PlanCache};
    let a = PlanCache::global().plan(1000, Direction::Forward);
    let h0 = PlanCache::global().hits();
    let b = PlanCache::global().plan(1000, Direction::Forward);
    assert!(std::sync::Arc::ptr_eq(&a, &b), "cache must reuse the plan");
    assert!(PlanCache::global().hits() > h0, "hit counter must advance");
}

/// The baseline and the HPX variants agree on the math.
#[test]
fn baseline_agrees_with_hpx() {
    let report = fftw_like::run(&FftwLikeConfig {
        rows: 64,
        cols: 64,
        localities: 4,
        threads: 2,
        net: None,
        verify: true,
    })
    .unwrap();
    assert!(report.rel_error.unwrap() < 1e-4);
}

/// The hybrid wire model does not change results, only timing.
#[test]
#[allow(deprecated)]
fn wire_model_is_numerically_transparent() {
    let base = DistFftConfig {
        rows: 32,
        cols: 32,
        localities: 2,
        threads_per_locality: 1,
        verify: true,
        ..Default::default()
    };
    let without = driver::run(&base).unwrap();
    let with = driver::run(&DistFftConfig {
        net: Some(NetModel::infiniband_hdr()),
        ..base
    })
    .unwrap();
    assert_eq!(without.rel_error, with.rel_error);
    assert!(with.stats.modeled_wire_us > 0, "wire model must be charged");
    assert_eq!(without.stats.modeled_wire_us, 0);
}

/// Fig. 3 harness end to end (tiny): produces the paper's ordering.
#[test]
fn fig3_harness_ordering() {
    let cfg = BenchConfig {
        reps: 3,
        warmup: 1,
        chunk_sizes: vec![4096],
        ..BenchConfig::quick()
    };
    let points = fig3::run(&cfg).unwrap();
    let mean = |port| {
        points.iter().find(|p| p.port == port).unwrap().live.mean()
    };
    assert!(mean(PortKind::Lci) < mean(PortKind::Tcp));
}

/// Figs. 4/5 harness end to end (tiny): the three paper findings hold in
/// the simnet predictions at paper scale.
#[test]
fn fig45_harness_paper_findings() {
    let cfg = BenchConfig {
        reps: 1,
        warmup: 0,
        live_grid: 32,
        live_nodes: vec![2],
        sim_nodes: vec![16],
        threads: 1,
        ..BenchConfig::quick()
    };
    let fig4 = fig45::run(&cfg, Variant::AllToAll).unwrap();
    let fig5 = fig45::run(&cfg, Variant::Scatter).unwrap();
    let sim = |points: &[fig45::ScalingPoint], sys: fig45::System| {
        points.iter().find(|p| p.system == sys).unwrap().sim_us
    };
    use fig45::System;
    // (1) LCI is the fastest parcelport in both variants.
    for points in [&fig4, &fig5] {
        assert!(sim(points, System::Hpx(PortKind::Lci)) <= sim(points, System::Hpx(PortKind::Mpi)));
        assert!(sim(points, System::Hpx(PortKind::Lci)) <= sim(points, System::Hpx(PortKind::Tcp)));
    }
    // (2) The scatter variant beats the all-to-all variant.
    for port in PortKind::ALL {
        assert!(sim(&fig5, System::Hpx(port)) < sim(&fig4, System::Hpx(port)));
    }
    // (3) HPX+LCI (scatter) beats the FFTW3 reference.
    assert!(sim(&fig5, System::Hpx(PortKind::Lci)) < sim(&fig5, System::Fftw3));
}

/// PJRT engine in the distributed driver (gated on artifacts).
#[test]
#[allow(deprecated)]
fn distributed_fft_through_pjrt_engine() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let config = DistFftConfig {
        rows: 256,
        cols: 256,
        localities: 4,
        port: PortKind::Lci,
        variant: Variant::Scatter,
        threads_per_locality: 1,
        engine: ComputeEngine::Pjrt(dir.to_str().unwrap().to_string()),
        verify: true,
        ..Default::default()
    };
    let report = match driver::run(&config) {
        Ok(report) => report,
        // Skip only the stub's build-without-feature error; any other
        // failure (engine crash, bad artifacts) must fail the test.
        Err(e) if format!("{e:#}").contains("not compiled in") => {
            eprintln!("skipping: pjrt engine unavailable ({e})");
            return;
        }
        Err(e) => panic!("pjrt distributed run failed: {e:#}"),
    };
    assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
}

/// The zero-copy acceptance check: chunking a collective adds protocol
/// copies on the copying ports (TCP framing, MPI eager bounce buffers)
/// but must add **zero** copied bytes on LCI, whose wire chunks are
/// Arc-backed `Payload::slice` views handed through as-is.
#[test]
fn chunking_copy_accounting_per_port() {
    let n = 2;
    let bytes = 256 * 1024; // monolithic MPI takes the zero-copy rendezvous path
    for kind in PortKind::ALL {
        let run_once = |chunked: bool| -> PortStatsSnapshot {
            let cluster = Cluster::new(n, kind, None).unwrap();
            let before = cluster.fabric().stats();
            cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                // 32 KiB chunks: MPI-eager-sized, 8 per message.
                comm.set_chunk_policy(ChunkPolicy::new(32 * 1024, 2));
                let chunks: Vec<Payload> =
                    (0..n).map(|_| Payload::new(vec![7u8; bytes])).collect();
                let algo =
                    if chunked { AllToAllAlgo::PairwiseChunked } else { AllToAllAlgo::Pairwise };
                comm.all_to_all(chunks, algo);
            });
            cluster.fabric().stats().since(&before)
        };
        let mono = run_once(false);
        let chunked = run_once(true);
        match kind {
            PortKind::Lci => {
                assert_eq!(mono.bytes_copied, 0, "LCI monolithic must not copy");
                assert_eq!(chunked.bytes_copied, 0, "LCI chunking must add zero copies");
            }
            PortKind::Mpi | PortKind::Tcp => {
                assert!(
                    chunked.bytes_copied > mono.bytes_copied,
                    "{kind}: chunking must surface protocol copies \
                     (mono {} vs chunked {})",
                    mono.bytes_copied,
                    chunked.bytes_copied
                );
            }
        }
        assert!(chunked.msgs_sent > mono.msgs_sent, "{kind}: chunking splits messages");
    }
}

/// One exchange+unpack round of the acceptance workload: every received
/// byte lands in a destination buffer (the benchmark stand-in for the
/// FFT's transpose-unpack). Setup (communicator, send pool, buffers) is
/// excluded from the timing; returns the slowest rank's exchange+unpack
/// wall-clock in µs and asserts the delivered contents.
fn exchange_and_unpack_once(cluster: &Cluster, n: usize, per_rank: usize, chunked: bool) -> f64 {
    let times = cluster.run(|ctx| {
        let comm = Communicator::from_ctx(ctx);
        comm.set_chunk_policy(ChunkPolicy::new(1 << 20, 4)); // tuned: 1 MiB × 4
        comm.warm_chunk_pool();
        let chunks: Vec<Payload> =
            (0..n).map(|_| Payload::new(vec![ctx.rank as u8; per_rank])).collect();
        let mut dest = vec![0u8; n * per_rank];
        let t0 = std::time::Instant::now();
        if chunked {
            comm.all_to_all_chunked_each(chunks, |src, off, p| {
                dest[src * per_rank + off..src * per_rank + off + p.len()]
                    .copy_from_slice(p.as_bytes());
            });
        } else {
            let received = comm.all_to_all(chunks, AllToAllAlgo::Pairwise);
            for (src, p) in received.into_iter().enumerate() {
                dest[src * per_rank..(src + 1) * per_rank].copy_from_slice(p.as_bytes());
            }
        }
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let delivered = (0..n).all(|src| {
            dest[src * per_rank] == src as u8 && dest[(src + 1) * per_rank - 1] == src as u8
        });
        assert!(delivered, "unpacked bytes must carry the source rank");
        us
    });
    times.into_iter().fold(0.0, f64::max)
}

/// Deterministic half of the acceptance check, always run: the chunked
/// exchange+unpack delivers the right bytes and leaves LCI's
/// copied-bytes counter untouched (every wire chunk is a zero-copy
/// `Payload::slice` handed through the fabric as-is).
#[test]
fn chunked_exchange_zero_copy_and_correct() {
    let n = 8;
    let per_rank = 4 << 20; // 4 MiB per-rank buffers (the ISSUE scenario)
    let cluster = Cluster::new(n, PortKind::Lci, Some(NetModel::infiniband_hdr())).unwrap();
    exchange_and_unpack_once(&cluster, n, per_rank, false);
    exchange_and_unpack_once(&cluster, n, per_rank, true);
    assert_eq!(cluster.fabric().stats().bytes_copied, 0);
}

/// The timing half: on the in-process LCI fabric with the IB-HDR wire
/// model (N=8 localities, 4 MiB per-rank buffers), the pipelined chunked
/// exchange beats the monolithic pairwise exchange wall-clock — chunk
/// sends spin the modeled wire time concurrently on the send pool, and
/// the receiver unpacks chunk *k* while chunk *k+1* is still on the
/// wire. The spin-based wire model needs spare cores to show the
/// overlap, so this wall-clock comparison is `#[ignore]`d in the default
/// suite and exercised explicitly (CI bench-smoke job; also demonstrated
/// by `cargo bench --bench hotpath`).
#[test]
#[ignore = "wall-clock comparison; needs an unloaded machine — run with --ignored"]
fn pairwise_chunked_beats_monolithic_under_netmodel() {
    let n = 8;
    let per_rank = 4 << 20;
    let cluster = Cluster::new(n, PortKind::Lci, Some(NetModel::infiniband_hdr())).unwrap();
    let best = |chunked: bool| -> f64 {
        (0..3)
            .map(|_| exchange_and_unpack_once(&cluster, n, per_rank, chunked))
            .fold(f64::INFINITY, f64::min)
    };
    let mono = best(false);
    let chunked = best(true);
    assert!(
        chunked < mono,
        "pipelined chunked exchange+unpack must beat monolithic: \
         {chunked:.0} µs vs {mono:.0} µs"
    );
}

/// Pipelined scatter agrees with linear scatter across ports (the Fig. 3
/// building block, chunked).
#[test]
fn pipelined_scatter_matches_linear_across_ports() {
    for kind in PortKind::ALL {
        let cluster = Cluster::new(3, kind, None).unwrap();
        let mut results: Vec<Vec<Vec<u8>>> = Vec::new();
        for algo in ScatterAlgo::ALL {
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(1000, 2));
                let chunks = (ctx.rank == 2).then(|| {
                    (0..3).map(|i| Payload::new(vec![i as u8; 777 * (i + 1)])).collect()
                });
                comm.scatter_with_algo(2, chunks, algo).as_bytes().to_vec()
            });
            results.push(got);
        }
        assert_eq!(results[0], results[1], "{kind}: pipelined deviates from linear");
    }
}

/// The fig6/pencil acceptance matrix: on the non-power-of-two 12×8×24
/// grid, for every `Pr×Pc` shape in {1×4, 2×2, 4×1} and both execution
/// modes, the 3-D pencil FFT is **bitwise identical across ports and
/// modes** and matches the O(n²) f64-accumulating 3-D DFT oracle.
#[test]
fn pencil3d_bitwise_stable_across_ports_and_modes_all_shapes() {
    use hpx_fft::dist_fft::grid3::{Grid3, PencilDims, ProcGrid};
    use hpx_fft::dist_fft::pencil::{self, Pencil3Config};
    use hpx_fft::dist_fft::verify::{oracle_fft3_transposed, rel_error};
    use hpx_fft::fft::complex::Complex32;

    let grid = Grid3::new(12, 8, 24);

    // O(n²) f64-accumulating DFT oracle, transposed [i2][i1][i0] layout.
    let data = hpx_fft::dist_fft::grid3::whole_grid(grid);
    let oracle = oracle_fft3_transposed(&data, grid);

    for (pr, pc) in [(1usize, 4usize), (2, 2), (4, 1)] {
        let proc = ProcGrid::new(pr, pc);
        let dims = PencilDims::new(grid, proc).unwrap();
        let expected = pencil::distribute_transposed(&oracle, &dims);
        let mut reference: Option<Vec<Vec<Complex32>>> = None;
        for port in PortKind::ALL {
            for exec in ExecutionMode::ALL {
                let config = Pencil3Config {
                    grid,
                    proc,
                    port,
                    chunk: ChunkPolicy::new(256, 2),
                    exec,
                    domain: Domain::Complex,
                    threads_per_locality: 1,
                    net: None,
                    engine: ComputeEngine::Native,
                    verify: false,
                };
                let cluster = Cluster::new(proc.n(), port, None).unwrap();
                let (_report, pieces) =
                    pencil::run_on_collect(&cluster, &config).unwrap();
                // DFT-oracle verification (once per shape is enough, but
                // it is cheap — assert every combination).
                let assembled: Vec<Complex32> =
                    pieces.iter().flat_map(|p| p.iter().copied()).collect();
                let err = rel_error(&assembled, &expected);
                assert!(err < 1e-4, "{pr}x{pc} {port} {}: rel err {err}", exec.name());
                // Bitwise stability across ports and execution modes.
                match &reference {
                    None => reference = Some(pieces),
                    Some(r) => assert_eq!(
                        r,
                        &pieces,
                        "{pr}x{pc} {port} {} deviates bitwise",
                        exec.name()
                    ),
                }
            }
        }
    }
}

/// Concurrent row/column sub-communicator traffic on one fabric must
/// not disturb a subsequent world-communicator collective — split tag
/// spaces and the world tag space stay disjoint end to end.
#[test]
fn split_comms_then_world_collective_stay_clean() {
    for port in PortKind::ALL {
        let (pr, pc) = (2usize, 2usize);
        let cluster = Cluster::new(pr * pc, port, None).unwrap();
        let got = cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            world.set_chunk_policy(ChunkPolicy::new(16, 2));
            let (r, c) = (ctx.rank / pc, ctx.rank % pc);
            let row = world.split(r as u64, c as u64);
            let col = world.split(c as u64, r as u64);
            // Sub-communicator chunked traffic in both directions.
            let row_got = row.all_to_all(
                (0..row.size())
                    .map(|j| Payload::from_f32(&vec![(ctx.rank * 10 + j) as f32; 9]))
                    .collect(),
                AllToAllAlgo::PairwiseChunked,
            );
            let col_got = col.all_to_all(
                (0..col.size())
                    .map(|j| Payload::from_f32(&vec![(ctx.rank * 100 + j) as f32; 9]))
                    .collect(),
                AllToAllAlgo::PairwiseChunked,
            );
            // World-wide collective afterwards: must see clean mailboxes.
            let all = world.all_gather(Payload::from_f32(&[ctx.rank as f32]));
            let world_vals: Vec<f32> = all.iter().map(|p| p.to_f32()[0]).collect();
            (
                row_got.iter().map(|p| p.to_f32()[0]).collect::<Vec<f32>>(),
                col_got.iter().map(|p| p.to_f32()[0]).collect::<Vec<f32>>(),
                world_vals,
            )
        });
        for (rank, (row_vals, col_vals, world_vals)) in got.iter().enumerate() {
            let (r, c) = (rank / pc, rank % pc);
            let row_expect: Vec<f32> = (0..pc).map(|j| ((r * pc + j) * 10 + c) as f32).collect();
            let col_expect: Vec<f32> =
                (0..pr).map(|j| ((j * pc + c) * 100 + r) as f32).collect();
            assert_eq!(row_vals, &row_expect, "{port} rank {rank} row");
            assert_eq!(col_vals, &col_expect, "{port} rank {rank} col");
            assert_eq!(world_vals, &vec![0.0, 1.0, 2.0, 3.0], "{port} rank {rank} world");
        }
        for rank in 0..pr * pc {
            assert_eq!(
                cluster.fabric().mailbox(rank).pending(),
                0,
                "{port}: leftover parcels at {rank}"
            );
        }
    }
}

/// The real-domain acceptance matrix: the r2c distributed FFT is
/// bitwise identical across TCP/MPI/LCI ports and Blocking/Async
/// execution modes, for the 2-D scatter variant, the 2-D all-to-all
/// variant, and the 3-D pencil pipeline — and every result verifies
/// against its packed serial reference.
#[test]
#[allow(deprecated)]
fn real_domain_bitwise_identical_across_ports_and_modes() {
    use hpx_fft::dist_fft::driver::NativeRowFft;
    use hpx_fft::dist_fft::verify::{rel_error, serial_rfft2_packed_transposed};
    use hpx_fft::dist_fft::{all_to_all_variant, scatter_variant, FftInput, RealSlab};

    // 2-D: both variants, 16×32 real grid → 16 packed columns on 4
    // ranks; the raw per-rank output pieces must agree to the bit.
    let (rows, cols, parts) = (16usize, 32usize, 4usize);
    let serial = serial_rfft2_packed_transposed(&RealSlab::whole(rows, cols).data, rows, cols);
    for variant in [Variant::AllToAll, Variant::Scatter] {
        let mut reference: Option<Vec<hpx_fft::fft::Complex32>> = None;
        for port in PortKind::ALL {
            for exec in ExecutionMode::ALL {
                let cluster = Cluster::new(parts, port, None).unwrap();
                let pieces = cluster.run(move |ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    comm.set_chunk_policy(ChunkPolicy::new(96, 2));
                    comm.warm_chunk_pool();
                    let slab = RealSlab::synthetic(rows, cols, parts, ctx.rank);
                    let input = FftInput::Real(&slab);
                    match (variant, exec) {
                        (Variant::AllToAll, ExecutionMode::Blocking) => {
                            all_to_all_variant::run_input(
                                &comm,
                                &input,
                                AllToAllAlgo::PairwiseChunked,
                                1,
                                &NativeRowFft,
                            )
                            .0
                        }
                        (Variant::AllToAll, ExecutionMode::Async) => {
                            all_to_all_variant::run_async_input(
                                &comm,
                                &input,
                                AllToAllAlgo::PairwiseChunked,
                                1,
                                &NativeRowFft,
                            )
                            .0
                        }
                        (Variant::Scatter, ExecutionMode::Blocking) => {
                            scatter_variant::run_input(&comm, &input, 1, &NativeRowFft).0
                        }
                        (Variant::Scatter, ExecutionMode::Async) => {
                            scatter_variant::run_async_input(&comm, &input, 1, &NativeRowFft).0
                        }
                    }
                });
                let assembled: Vec<hpx_fft::fft::Complex32> =
                    pieces.into_iter().flatten().collect();
                let err = rel_error(&assembled, &serial);
                assert!(err < 1e-4, "{port} {variant:?} {exec:?}: rel err {err}");
                match &reference {
                    None => reference = Some(assembled),
                    Some(r) => assert_eq!(
                        r, &assembled,
                        "{port} {variant:?} {exec:?}: real-domain outputs must be bitwise stable"
                    ),
                }
            }
        }
    }

    // 3-D pencil: raw pieces compared bitwise across ports and modes.
    use hpx_fft::dist_fft::pencil::{self, Pencil3Config};
    use hpx_fft::dist_fft::{Grid3, ProcGrid};
    let mut reference: Option<Vec<Vec<hpx_fft::fft::Complex32>>> = None;
    for port in PortKind::ALL {
        for exec in ExecutionMode::ALL {
            let cfg = Pencil3Config {
                grid: Grid3::new(12, 8, 24),
                proc: ProcGrid::new(2, 2),
                port,
                exec,
                domain: Domain::Real,
                chunk: ChunkPolicy::new(256, 2),
                threads_per_locality: 1,
                ..Default::default()
            };
            let cluster = Cluster::new(cfg.proc.n(), port, None).unwrap();
            let (report, pieces) = pencil::run_on_collect(&cluster, &cfg).unwrap();
            assert!(
                report.rel_error.unwrap() < 1e-4,
                "{port} {exec:?}: {:?}",
                report.rel_error
            );
            match &reference {
                None => reference = Some(pieces),
                Some(r) => {
                    assert_eq!(r, &pieces, "{port} {exec:?}: real pencil must be bitwise stable")
                }
            }
        }
    }
}

/// The acceptance wire check at the driver level: a real-domain run
/// moves ≤ 55% of the complex-domain `bytes_sent` on the same grid
/// (measured by `PortStats`, every port, both variants).
#[test]
#[allow(deprecated)]
fn real_domain_wire_bytes_at_most_55_percent_of_complex() {
    for port in PortKind::ALL {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            let bytes = |domain: Domain| {
                let config = DistFftConfig {
                    rows: 32,
                    cols: 64,
                    localities: 4,
                    port,
                    variant,
                    domain,
                    threads_per_locality: 1,
                    verify: false,
                    ..Default::default()
                };
                driver::run(&config).unwrap().stats.bytes_sent
            };
            let (complex, real) = (bytes(Domain::Complex), bytes(Domain::Real));
            assert!(
                (real as f64) <= 0.55 * complex as f64,
                "{port} {variant:?}: real {real} B vs complex {complex} B"
            );
            assert!(real > 0, "{port} {variant:?}: real run must move bytes");
        }
    }
}

/// Ground truth for the real domain: unpack the distributed
/// packed-transposed output into true `C/2 + 1` bins, compare against
/// the complexified O(n²) DFT oracle, and check the Hermitian
/// self-symmetry a real input's spectrum must satisfy.
#[test]
#[allow(deprecated)]
fn real_domain_unpacked_output_matches_oracle_and_is_hermitian() {
    use hpx_fft::dist_fft::verify::{
        hermitian_symmetry_error, oracle_fft2_transposed, rel_error, unpack_packed2_transposed,
    };
    use hpx_fft::dist_fft::RealSlab;
    use hpx_fft::fft::Complex32;

    let (rows, cols) = (12usize, 24usize);
    let config = DistFftConfig {
        rows,
        cols,
        localities: 4,
        domain: Domain::Real,
        threads_per_locality: 1,
        verify: true,
        ..Default::default()
    };
    // Chain of custody: the distributed run is pinned to the packed
    // serial reference (rel_error below), and the reference's unpacked
    // bins are pinned to the O(n²) oracle — so the distributed output
    // is oracle-verified end to end.
    let report = driver::run(&config).unwrap();
    assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
    assert!(report.stats.msgs_sent > 0);
    let packed = hpx_fft::dist_fft::verify::serial_rfft2_packed_transposed(
        &RealSlab::whole(rows, cols).data,
        rows,
        cols,
    );
    let half = unpack_packed2_transposed(&packed, rows, cols);

    let cx: Vec<Complex32> = RealSlab::whole(rows, cols)
        .data
        .iter()
        .map(|&v| Complex32::new(v, 0.0))
        .collect();
    let full = oracle_fft2_transposed(&cx, rows, cols);
    let err = rel_error(&half, &full[..(cols / 2 + 1) * rows]);
    assert!(err < 1e-4, "unpacked spectrum vs oracle: rel err {err}");
    let sym = hermitian_symmetry_error(&half, rows, cols);
    assert!(sym < 1e-3, "Hermitian deviation {sym}");
}

/// The split-sub-communicator hardening satellite: non-power-of-two
/// `Bruck` and ring-schedule `Pairwise` all-to-alls on *row and column
/// sub-communicators* at N ∈ {3, 6} — bitwise against the
/// transpose-of-the-chunk-matrix oracle, on every port. (Existing
/// coverage ran these algorithms on world communicators only; the
/// sub-communicator path additionally exercises rank→locality
/// translation and the split tag spaces.)
#[test]
fn bruck_and_pairwise_bitwise_on_split_subcomms_non_pow2() {
    let (pr, pc) = (2usize, 3usize); // 6 localities, row comms of 3
    for port in PortKind::ALL {
        for algo in [AllToAllAlgo::Bruck, AllToAllAlgo::Pairwise] {
            let cluster = Cluster::new(pr * pc, port, None).unwrap();
            let got = cluster.run(move |ctx| {
                let world = Communicator::from_ctx(ctx);
                let (r, c) = (ctx.rank / pc, ctx.rank % pc);
                // Row communicator: N = 3 (non-pow2 → Bruck's log rounds
                // carry ragged blocks; Pairwise takes the ring schedule).
                let row = world.split(r as u64, c as u64);
                let row_got = row.all_to_all(
                    (0..row.size())
                        .map(|j| Payload::from_f32(&[(ctx.rank * 100 + j) as f32, 0.5]))
                        .collect(),
                    algo,
                );
                // Column communicator: N = 2.
                let col = world.split(c as u64, r as u64);
                let col_got = col.all_to_all(
                    (0..col.size())
                        .map(|j| Payload::from_f32(&[(ctx.rank * 1000 + j) as f32]))
                        .collect(),
                    algo,
                );
                // Whole-world split: N = 6, still non-pow2.
                let whole = world.split(7, ctx.rank as u64);
                let whole_got = whole.all_to_all(
                    (0..whole.size())
                        .map(|j| Payload::from_f32(&[(ctx.rank * 10 + j) as f32]))
                        .collect(),
                    algo,
                );
                (
                    row_got.iter().map(|p| p.to_f32()).collect::<Vec<_>>(),
                    col_got.iter().map(|p| p.to_f32()).collect::<Vec<_>>(),
                    whole_got.iter().map(|p| p.to_f32()).collect::<Vec<_>>(),
                )
            });
            for (rank, (row_vals, col_vals, whole_vals)) in got.iter().enumerate() {
                let (r, c) = (rank / pc, rank % pc);
                // Oracle: slot j holds what in-group rank j addressed to me.
                let row_expect: Vec<Vec<f32>> =
                    (0..pc).map(|j| vec![((r * pc + j) * 100 + c) as f32, 0.5]).collect();
                let col_expect: Vec<Vec<f32>> =
                    (0..pr).map(|j| vec![((j * pc + c) * 1000 + r) as f32]).collect();
                let whole_expect: Vec<Vec<f32>> =
                    (0..pr * pc).map(|j| vec![(j * 10 + rank) as f32]).collect();
                assert_eq!(row_vals, &row_expect, "{port} {algo:?} rank {rank} row comm");
                assert_eq!(col_vals, &col_expect, "{port} {algo:?} rank {rank} col comm");
                assert_eq!(whole_vals, &whole_expect, "{port} {algo:?} rank {rank} N=6 comm");
            }
        }
    }
}

/// Stress: repeated runs on one fabric (leak/ordering regression guard).
#[test]
#[allow(deprecated)]
fn repeated_runs_stable() {
    let cluster =
        hpx_fft::hpx::runtime::Cluster::new(4, PortKind::Lci, None).unwrap();
    let config = DistFftConfig {
        rows: 32,
        cols: 32,
        localities: 4,
        threads_per_locality: 1,
        verify: true,
        ..Default::default()
    };
    for _ in 0..10 {
        let report = driver::run_on(&cluster, &config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
    }
    // Mailboxes must be fully drained between runs.
    for rank in 0..4 {
        assert_eq!(cluster.fabric().mailbox(rank).pending(), 0, "leftover parcels at {rank}");
    }
}

// ---------------------------------------------------------------------
// FFT as a service: the resident multi-tenant scheduler, exercised end
// to end through the public API (`hpx_fft::runtime`).
// ---------------------------------------------------------------------

/// The service stress matrix: on every parcelport, four tenants share
/// one resident fabric while 2-D slab and 3-D pencil jobs in both
/// domains and both execution modes run concurrently — and every job's
/// output is **bitwise identical** to a single-shot run of the same
/// request on a throwaway cluster. The scheduler may interleave jobs
/// freely, but it must never perturb the math.
#[test]
fn service_stress_matrix_bitwise_vs_single_shot_all_ports() {
    use hpx_fft::dist_fft::grid3::{Grid3, ProcGrid};
    use hpx_fft::fft::Complex32;

    for port in PortKind::ALL {
        // 2-D/3-D × Complex/Real × Blocking/Async on a 4-locality
        // fabric (one entry occupies only a 2-locality sub-grid).
        let menu: Vec<TransformRequest> = vec![
            TransformRequest::grid(16, 16).localities(4),
            TransformRequest::grid(16, 32).localities(4).domain(Domain::Real),
            TransformRequest::grid(24, 24).localities(2).exec(ExecutionMode::Async),
            TransformRequest::grid3(Grid3::new(8, 8, 8)).proc_grid(ProcGrid::new(2, 2)),
            TransformRequest::grid3(Grid3::new(8, 8, 16))
                .proc_grid(ProcGrid::new(2, 2))
                .domain(Domain::Real)
                .exec(ExecutionMode::Async),
        ]
        .into_iter()
        .map(|r| r.port(port).threads(1).verify(false).collect_outputs(true))
        .collect();

        // Single-shot references, one throwaway cluster per entry.
        let expected: Vec<Vec<Vec<Complex32>>> = menu
            .iter()
            .map(|r| r.clone().build().unwrap().run().unwrap().outputs.unwrap())
            .collect();

        let svc = FftService::new(ServiceConfig { port, ..ServiceConfig::default() }).unwrap();
        let tenants = ["alice", "bob", "carol", "dave"];
        let handles: Vec<(usize, _)> = (0..4 * menu.len())
            .map(|j| {
                let entry = j % menu.len();
                let handle = svc.submit(tenants[j % tenants.len()], menu[entry].clone()).unwrap();
                (entry, handle)
            })
            .collect();
        for (entry, handle) in handles {
            let out = handle.wait().unwrap_or_else(|e| panic!("{port} entry {entry}: {e}"));
            assert_eq!(
                out.report.outputs.as_ref().unwrap(),
                &expected[entry],
                "{port} entry {entry}: service output deviates from single-shot"
            );
            assert!(out.report.stats.bytes_sent > 0, "{port} entry {entry}: empty stats scope");
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.len(), tenants.len());
        assert_eq!(
            metrics.iter().map(|m| m.completed).sum::<u64>(),
            (4 * menu.len()) as u64,
            "{port}: every job must complete"
        );
        assert!(metrics.iter().all(|m| m.failed == 0 && m.pending == 0));
    }
}

/// Per-job stats scopes under concurrency (the fig7 acceptance check,
/// service edition): a real-domain job and a complex-domain job on the
/// same grid run **concurrently** on one resident fabric, and each
/// report's scoped counters still attribute the wire bytes to the job
/// that moved them — the real job moves ≤ 55% of the complex job's.
#[test]
fn service_concurrent_real_and_complex_jobs_keep_scoped_wire_bytes() {
    let svc = FftService::new(ServiceConfig::default()).unwrap();
    // Pause so both jobs enter the dispatch log before any gate opens;
    // with max_inflight ≥ 2 they then execute concurrently.
    svc.pause();
    let base = || TransformRequest::grid(32, 64).localities(4).threads(1).verify(false);
    let hc = svc.submit("complex", base()).unwrap();
    let hr = svc.submit("real", base().domain(Domain::Real)).unwrap();
    svc.resume();
    let complex = hc.wait().unwrap().report.stats.bytes_sent;
    let real = hr.wait().unwrap().report.stats.bytes_sent;
    assert!(real > 0 && complex > 0, "both jobs must move bytes");
    assert!(
        (real as f64) <= 0.55 * complex as f64,
        "scoped counters must stay per-job under concurrency: \
         real {real} B vs complex {complex} B"
    );
    // The fabric-global counters saw both jobs' traffic; the scopes
    // partition the payload bytes between them.
    assert!(svc.fabric_stats().bytes_sent >= real + complex);
    svc.shutdown();
}

/// Admission control through the public API: oversized requests are
/// refused against the fabric size, a full tenant queue rejects with a
/// typed error (never a panic), and a paused service still drains.
#[test]
fn service_admission_control_rejects_typed_and_drains() {
    let svc = FftService::new(ServiceConfig {
        localities: 2,
        queue_limit: 2,
        ..ServiceConfig::default()
    })
    .unwrap();
    let plane = || TransformRequest::grid(16, 16).localities(2).threads(1);
    match svc.submit("t", plane().localities(4)) {
        Err(AdmissionError::TooLarge { needed: 4, available: 2 }) => {}
        other => panic!("expected TooLarge, got {:?}", other.map(|h| h.id())),
    }
    svc.pause();
    let accepted: Vec<_> = (0..2).map(|_| svc.submit("t", plane()).unwrap()).collect();
    match svc.submit("t", plane()) {
        Err(AdmissionError::QueueFull { limit: 2, .. }) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|h| h.id())),
    }
    svc.resume();
    for h in accepted {
        h.wait().unwrap();
    }
    let m = svc.shutdown();
    assert_eq!((m[0].completed, m[0].rejected, m[0].pending), (2, 2, 0));
}

/// Tag-space exhaustion inside a job fails that job's handle with a
/// typed error and leaves the service (and the world communicator's
/// tag space) alive — provoked by granting each job a single chunk-tag
/// block, far less than a whole transform's collectives consume.
#[test]
fn service_survives_in_job_tag_exhaustion() {
    use hpx_fft::collectives::tags::CHUNK_TAG_SPAN;
    let svc = FftService::new(ServiceConfig {
        localities: 2,
        job_tag_span: Some(CHUNK_TAG_SPAN),
        ..ServiceConfig::default()
    })
    .unwrap();
    let plane = || TransformRequest::grid(16, 16).localities(2).threads(1);
    for _ in 0..3 {
        let err = svc.submit("t", plane()).unwrap().wait().unwrap_err();
        assert!(err.message.contains("tag space exhausted"), "{err}");
    }
    let m = svc.shutdown();
    assert_eq!((m[0].failed, m[0].completed), (3, 0));
}
