//! Loom model-checking of the promise/future cell and the worker pool.
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"` (CI's
//! `loom-tests` job, which adds the `loom` dev-dependency on the fly —
//! the offline build image does not carry it):
//!
//! ```text
//! cargo add loom --dev
//! RUSTFLAGS="--cfg loom" cargo test --test loom --release
//! ```
//!
//! Under that cfg, `src/util/sync.rs` swaps `std::sync` for loom's mock
//! primitives inside `task/future.rs` and `task/pool.rs`, and
//! `loom::model` exhaustively explores every thread interleaving of the
//! bodies below — the machine-checked version of the reentrancy and
//! anti-starvation arguments in the `task::future` module docs.

#![cfg(loom)]

use hpx_fft::task::{Promise, ThreadPool};
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

/// A consuming `get` racing `Promise::set` observes the value on every
/// interleaving — no lost wakeup, no double-take.
#[test]
fn promise_set_vs_consuming_get() {
    loom::model(|| {
        let (p, f) = Promise::new();
        let getter = thread::spawn(move || f.get());
        p.set(7usize);
        assert_eq!(getter.join().unwrap(), 7);
    });
}

/// The draining protocol: a consuming `get` racing `set` can never
/// starve an already-registered continuation of the value. This is the
/// `State::draining` hold-back, model-checked.
#[test]
fn continuation_never_starved_by_racing_get() {
    loom::model(|| {
        let (p, f) = Promise::new();
        let seen = Arc::new(AtomicUsize::new(0));
        let s = Arc::clone(&seen);
        f.then_inline(move |&v: &usize| {
            s.store(v, Ordering::SeqCst);
        });
        let f2 = f.clone();
        let getter = thread::spawn(move || f2.get());
        p.set(5);
        assert_eq!(getter.join().unwrap(), 5);
        assert_eq!(seen.load(Ordering::SeqCst), 5, "continuation lost the race for the value");
    });
}

/// `wait` (non-consuming) concurrent with a consuming `get`: both must
/// return, and the consumer gets the value exactly once.
#[test]
fn wait_and_get_coexist() {
    loom::model(|| {
        let (p, f) = Promise::new();
        let f2 = f.clone();
        let waiter = thread::spawn(move || f2.wait());
        p.set(3usize);
        waiter.join().unwrap();
        assert_eq!(f.get(), 3);
    });
}

/// `ThreadPool::run_scoped`: every enqueued borrowing task runs to
/// completion before the call returns, on every interleaving of the
/// single worker against the submitting thread — the join-on-drop
/// structure that makes the `'env` transmute in `run_scoped` sound.
#[test]
fn run_scoped_joins_every_task() {
    loom::model(|| {
        let pool = ThreadPool::new(1);
        let mut data = [0usize; 2];
        {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .iter_mut()
                .map(|slot| {
                    Box::new(move || {
                        *slot += 1;
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(data, [1, 1], "a scoped task escaped the join barrier");
    });
}

/// Queue handoff: a spawned job's result is visible through the future
/// on every worker/submitter interleaving (including pool teardown
/// racing the final `get`).
#[test]
fn spawn_result_survives_pool_drop() {
    loom::model(|| {
        let pool = ThreadPool::new(1);
        let f = pool.spawn(|| 21usize);
        drop(pool);
        assert_eq!(f.get(), 21);
    });
}
