//! SIMD/scalar equivalence suite — the correctness gate for the
//! lane-parallel butterfly kernels and the cache-blocked data movement.
//!
//! CI runs this file under both the default codegen flags and
//! `RUSTFLAGS="-C target-cpu=native"`, so the dispatched vector path and
//! the scalar twins are both exercised on identical inputs. Every
//! dispatched op is asserted **bitwise** equal to its scalar twin — the
//! AVX2 kernels use mul + addsub (never FMA) precisely so this holds —
//! which is the induction step that makes every planned transform
//! reproduce bit-for-bit across SIMD tiers. (`HPXFFT_SIMD=scalar`
//! covers the third corner: forcing the scalar tier at runtime.)

use std::sync::Arc;

use hpx_fft::dist_fft::transpose::{
    place_chunk_slice_transposed, place_chunk_transposed, transpose, transpose_naive, BLOCK,
};
use hpx_fft::fft::plan::{Direction, Plan, PlanCache};
use hpx_fft::fft::twiddle::TwiddleCache;
use hpx_fft::fft::{dft, radix2, simd, twiddle, Complex32};
use hpx_fft::util::rng::Pcg32;
use hpx_fft::util::testkit::assert_close;

fn signal(n: usize, seed: u64) -> Vec<Complex32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
}

fn flat(xs: &[Complex32]) -> Vec<f32> {
    xs.iter().flat_map(|c| [c.re, c.im]).collect()
}

/// Bit patterns, so the comparison cannot be softened by `-0.0 == 0.0`.
fn bits(xs: &[Complex32]) -> Vec<(u32, u32)> {
    xs.iter().map(|c| (c.re.to_bits(), c.im.to_bits())).collect()
}

/// Lane lengths chosen to hit every code path in the vector kernels:
/// empty, below one vector, exact vector multiples, and ragged tails.
const LANE_LENS: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 13, 16, 100, 255];

#[test]
fn radix2_dispatch_matches_scalar_twin_bitwise() {
    for len in LANE_LENS {
        let tw = signal(len, 900 + len as u64);
        let (mut lo, mut hi) = (signal(len, 1), signal(len, 2));
        let (mut lo_s, mut hi_s) = (lo.clone(), hi.clone());
        simd::butterfly_radix2(&mut lo, &mut hi, &tw);
        simd::butterfly_radix2_scalar(&mut lo_s, &mut hi_s, &tw);
        assert_eq!(bits(&lo), bits(&lo_s), "radix2 lo len={len}");
        assert_eq!(bits(&hi), bits(&hi_s), "radix2 hi len={len}");
    }
}

#[test]
fn radix4_dispatch_matches_scalar_twin_bitwise_both_directions() {
    for len in LANE_LENS {
        for inverse in [false, true] {
            let (w1, w2, w3) = (signal(len, 20), signal(len, 21), signal(len, 22));
            let (mut d0, mut d1, mut d2, mut d3) =
                (signal(len, 10), signal(len, 11), signal(len, 12), signal(len, 13));
            let (mut e0, mut e1, mut e2, mut e3) =
                (d0.clone(), d1.clone(), d2.clone(), d3.clone());
            simd::butterfly_radix4(&mut d0, &mut d1, &mut d2, &mut d3, &w1, &w2, &w3, inverse);
            simd::butterfly_radix4_scalar(
                &mut e0,
                &mut e1,
                &mut e2,
                &mut e3,
                &w1,
                &w2,
                &w3,
                inverse,
            );
            assert_eq!(bits(&d0), bits(&e0), "radix4 d0 len={len} inverse={inverse}");
            assert_eq!(bits(&d1), bits(&e1), "radix4 d1 len={len} inverse={inverse}");
            assert_eq!(bits(&d2), bits(&e2), "radix4 d2 len={len} inverse={inverse}");
            assert_eq!(bits(&d3), bits(&e3), "radix4 d3 len={len} inverse={inverse}");
        }
    }
}

#[test]
fn split_radix_combine_dispatch_matches_scalar_twin_bitwise() {
    for len in LANE_LENS {
        for inverse in [false, true] {
            let (w1, w3) = (signal(len, 23), signal(len, 24));
            let (mut u0, mut u1, mut z1, mut z3) =
                (signal(len, 30), signal(len, 31), signal(len, 32), signal(len, 33));
            let (mut v0, mut v1, mut y1, mut y3) =
                (u0.clone(), u1.clone(), z1.clone(), z3.clone());
            simd::split_radix_combine(&mut u0, &mut u1, &mut z1, &mut z3, &w1, &w3, inverse);
            simd::split_radix_combine_scalar(
                &mut v0,
                &mut v1,
                &mut y1,
                &mut y3,
                &w1,
                &w3,
                inverse,
            );
            assert_eq!(bits(&u0), bits(&v0), "sr u0 len={len} inverse={inverse}");
            assert_eq!(bits(&u1), bits(&v1), "sr u1 len={len} inverse={inverse}");
            assert_eq!(bits(&z1), bits(&y1), "sr z1 len={len} inverse={inverse}");
            assert_eq!(bits(&z3), bits(&y3), "sr z3 len={len} inverse={inverse}");
        }
    }
}

#[test]
fn pointwise_ops_dispatch_matches_scalar_twin_bitwise() {
    for len in LANE_LENS {
        let b = signal(len, 41);
        let mut a = signal(len, 40);
        let mut a_s = a.clone();
        simd::pointwise_mul(&mut a, &b);
        simd::pointwise_mul_scalar(&mut a_s, &b);
        assert_eq!(bits(&a), bits(&a_s), "pointwise_mul len={len}");

        let mut s = signal(len, 42);
        let mut s_s = s.clone();
        simd::scale_in_place(&mut s, 0.37);
        simd::scale_in_place_scalar(&mut s_s, 0.37);
        assert_eq!(bits(&s), bits(&s_s), "scale_in_place len={len}");
    }
}

/// Every kernel the planner can dispatch to — identity, split-radix
/// (pow2), mixed-radix (composite), and Bluestein (large prime) — against
/// the O(n²) oracle, both directions, with SIMD active as detected.
#[test]
fn plans_match_dft_oracle_across_kernel_paths() {
    for n in [1usize, 2, 4, 6, 8, 16, 64, 256, 1024, 1000, 1013] {
        let x = signal(n, 7 + n as u64);
        for dir in [Direction::Forward, Direction::Inverse] {
            let plan = Plan::new(n, dir);
            let mut y = x.clone();
            plan.execute(&mut y);
            let oracle =
                if dir == Direction::Forward { dft::dft(&x) } else { dft::idft(&x) };
            assert_close(&flat(&y), &flat(&oracle), 2e-2, 2e-3);
        }
    }
}

/// The split-radix plan against the retired iterative radix-2 reference
/// kernel: different butterfly orderings, same transform to f32 accuracy.
#[test]
fn split_radix_plan_matches_legacy_radix2_kernel() {
    for log2n in [1usize, 3, 6, 10] {
        let n = 1usize << log2n;
        for inverse in [false, true] {
            let dir = if inverse { Direction::Inverse } else { Direction::Forward };
            let plan = Plan::new(n, dir);
            assert_eq!(plan.kernel_name(), "split-radix", "n={n}");
            let x = signal(n, 50 + n as u64);
            let mut a = x.clone();
            plan.execute(&mut a);
            let mut b = x;
            radix2::fft_in_place_dir(
                &mut b,
                &twiddle::half_table(n, inverse),
                &twiddle::bit_reverse_table(n),
                inverse,
            );
            if inverse {
                // The legacy kernel is unnormalized in both directions;
                // the plan folds the 1/n in.
                simd::scale_in_place_scalar(&mut b, 1.0 / n as f32);
            }
            assert_close(&flat(&a), &flat(&b), 1e-3, 1e-3);
        }
    }
}

/// The tiled transpose against the untiled textbook loop, on shapes that
/// are non-square and not multiples of the tile edge — including
/// degenerate single-row/column matrices. Pure data movement, so the
/// equality is exact.
#[test]
fn tiled_transpose_matches_naive_on_awkward_shapes() {
    for (r, c) in [
        (1usize, 1usize),
        (3, 5),
        (BLOCK - 1, BLOCK + 1),
        (129, 67),
        (96, 2 * BLOCK + 5),
        (1, 70),
        (70, 1),
    ] {
        let data = signal(r * c, (r * 1000 + c) as u64);
        assert_eq!(
            bits(&transpose(&data, r, c)),
            bits(&transpose_naive(&data, r, c)),
            "shape {r}×{c}"
        );
    }
}

/// Feeding a chunk through `place_chunk_slice_transposed` in windows of
/// any size — sub-row, row-aligned, row-straddling, or one giant slice —
/// must land every element exactly where the one-shot placement puts it.
#[test]
fn windowed_slice_placement_matches_whole_chunk_placement() {
    let (rows, cols) = (100usize, 37usize);
    let chunk = signal(rows * cols, 5);
    let slab_cols = rows + 9;
    let col0 = 4;
    let mut whole = vec![Complex32::ZERO; cols * slab_cols];
    place_chunk_transposed(&chunk, rows, cols, &mut whole, slab_cols, col0);
    for window in [1usize, rows - 1, rows, rows + 1, 3 * rows + 11, 501, chunk.len()] {
        let mut sliced = vec![Complex32::ZERO; cols * slab_cols];
        let mut off = 0;
        while off < chunk.len() {
            let take = window.min(chunk.len() - off);
            place_chunk_slice_transposed(
                &chunk[off..off + take],
                off,
                rows,
                cols,
                &mut sliced,
                slab_cols,
                col0,
            );
            off += take;
        }
        assert_eq!(bits(&whole), bits(&sliced), "window={window}");
    }
}

/// Satellite: plan-cache hit/miss accounting over split-radix plans, on
/// a fresh cache so the counters are exact.
#[test]
fn plan_cache_hit_miss_accounting_covers_split_radix() {
    let cache = PlanCache::new();
    let p1 = cache.plan(2048, Direction::Forward);
    assert_eq!(p1.kernel_name(), "split-radix");
    assert_eq!((cache.hits(), cache.misses()), (0, 1));
    let p2 = cache.plan(2048, Direction::Forward);
    assert!(Arc::ptr_eq(&p1, &p2), "second lookup must return the memoized plan");
    assert_eq!((cache.hits(), cache.misses()), (1, 1));
    cache.plan(2048, Direction::Inverse);
    assert_eq!((cache.hits(), cache.misses()), (1, 2));
    cache.plan(2048, Direction::Inverse);
    assert_eq!((cache.hits(), cache.misses()), (2, 2));
}

/// Satellite: a size-n/2 split-radix plan finds every one of its twiddle
/// tables already resident from a size-n plan — table-level sharing
/// through the global [`TwiddleCache`]. Counters are global, and other
/// tests in this binary run concurrently, so the assertions are
/// lower bounds on the deltas.
#[test]
fn split_radix_plans_share_twiddle_tables_across_sizes() {
    let tc = TwiddleCache::global();
    let _big = Plan::new(1 << 13, Direction::Forward);
    let hits_before = tc.hits();
    // Levels 4096, 2048, …, 8: ten half-tables, all resident from the
    // 8192 plan's level stack.
    let _small = Plan::new(1 << 12, Direction::Forward);
    assert!(
        tc.hits() >= hits_before + 10,
        "expected ≥10 twiddle-cache hits building the half-size plan, got {}",
        tc.hits() - hits_before
    );
}
