//! Observability acceptance suite — the end-to-end properties the
//! tracing layer promises.
//!
//! - A traced async 2-D scatter run exports valid Chrome trace JSON in
//!   which chunk-send ("wire") spans demonstrably overlap FFT band
//!   spans — the driver's `overlap_us` as visible timeline geometry,
//!   asserted by interval intersection.
//! - The bytes carried by traced `port/send` spans reconcile exactly
//!   with the parcelport's own [`PortStatsSnapshot::bytes_sent`], per
//!   port × all-to-all algorithm (the invariant audit).
//! - The same exporter handles a simulated 512-locality collective.
//! - `TransformRequest::trace(true)` self-captures and reports the
//!   exported artifact path.
//!
//! The trace gate and ring buffers are process-global, so every test
//! that runs a live cluster takes a serializing lock: without it a
//! concurrent run would leak foreign events into an open session (the
//! sim capture records engine-side and needs no lock).
//!
//! [`PortStatsSnapshot::bytes_sent`]: hpx_fft::parcelport::PortStatsSnapshot

use std::sync::Mutex;

use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy};
use hpx_fft::config::TransformSpec;
use hpx_fft::dist_fft::{ExecutionMode, TransformRequest, Variant};
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::obs::{self, chrome};
use hpx_fft::parcelport::{NetModel, PortKind};
use hpx_fft::simnet::{run_sim_traced, AdversaryConfig, SimCollective, SimConfig, SimData};

/// Serializes the live-cluster tests against each other (see module doc).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hpxfft-obs-{tag}-{}", std::process::id()))
}

/// Acceptance: the async scatter variant's communication/compute overlap
/// is visible in the exported timeline. A wire model with a fat
/// per-message constant keeps each posted chunk on the (modeled) wire
/// for ~300 µs while the main thread transforms later bands, so some
/// `wire/chunk` span must intersect an `fft/band` span on the same rank.
#[test]
fn async_scatter_wire_spans_overlap_fft_bands() {
    let _guard = serial();
    let session = obs::session();
    let transform = TransformRequest::grid(256, 256)
        .spec(TransformSpec {
            exec: ExecutionMode::Async,
            chunk: ChunkPolicy::new(4096, 8),
            threads_per_locality: 1,
            net: Some(NetModel { alpha_us: 300.0, ..NetModel::infiniband_hdr() }),
            verify: false,
            ..TransformSpec::default()
        })
        .variant(Variant::Scatter)
        .localities(2)
        .build()
        .expect("build transform");
    transform.run().expect("traced async run");
    let events = session.finish();

    let wires: Vec<_> =
        events.iter().filter(|e| e.is_span() && e.cat == "wire" && e.name == "chunk").collect();
    let bands: Vec<_> =
        events.iter().filter(|e| e.is_span() && e.cat == "fft" && e.name == "band").collect();
    assert!(!wires.is_empty(), "async scatter posted no wire chunks");
    assert!(!bands.is_empty(), "async scatter recorded no band spans");
    let overlapping = wires.iter().any(|w| {
        bands.iter().any(|b| b.rank == w.rank && w.ts_ns < b.end_ns() && b.ts_ns < w.end_ns())
    });
    assert!(overlapping, "no wire chunk span overlapped an FFT band span on any rank");

    // The same capture, through the exporter: valid Chrome trace JSON
    // with each locality on its own track.
    let dir = temp_dir("overlap");
    let path = dir.join("async_scatter.trace.json");
    chrome::export(&events, &path).expect("export trace");
    let summary = chrome::validate_file(&path).expect("exported trace must validate");
    assert!(summary.spans >= wires.len() + bands.len(), "exporter lost spans");
    assert!(summary.tracks >= 2, "two localities must land on separate tracks");
    std::fs::remove_dir_all(&dir).ok();
}

/// Invariant audit: for every port × all-to-all algorithm, summing the
/// `bytes` of traced `port/send` spans reproduces the fabric's own
/// `bytes_sent` counter exactly. The span is emitted adjacent to
/// `PortStats::record_send` with the same payload length (self-sends
/// included on both sides), so any divergence means an instrumentation
/// gap — a send path without a span, or a span with the wrong size.
#[test]
fn traced_send_bytes_reconcile_with_port_stats() {
    let _guard = serial();
    for port in [PortKind::Tcp, PortKind::Mpi, PortKind::Lci] {
        for algo in AllToAllAlgo::ALL {
            let cluster = Cluster::new(3, port, None).expect("cluster");
            let transform = TransformRequest::grid(24, 24)
                .spec(TransformSpec {
                    port,
                    threads_per_locality: 1,
                    verify: false,
                    ..TransformSpec::default()
                })
                .variant(Variant::AllToAll)
                .algo(algo)
                .localities(3)
                .build()
                .expect("build transform");
            let dropped_before = obs::dropped_events();
            let session = obs::session();
            transform.run_on(&cluster).expect("run");
            let events = session.finish();
            assert_eq!(
                obs::dropped_events(),
                dropped_before,
                "ring overflow voids the audit ({port:?}, {algo:?})"
            );
            let traced: u64 = events
                .iter()
                .filter(|e| e.is_span() && e.cat == "port" && e.name == "send")
                .map(|e| e.bytes as u64)
                .sum();
            let stats = cluster.fabric().stats();
            assert_eq!(
                traced, stats.bytes_sent,
                "traced send bytes diverge from PortStats ({port:?}, {algo:?})"
            );
        }
    }
}

/// Acceptance: the exporter that serves live runs handles a simulated
/// 512-locality collective, and the sim's wire-byte reconciliation
/// holds at that scale too. The capture is engine-side (no global
/// session), so this test needs no serialization.
#[test]
fn sim_trace_exports_at_512_localities() {
    let cfg = SimConfig {
        localities: 512,
        port: PortKind::Lci,
        net: NetModel::infiniband_hdr(),
        policy: ChunkPolicy::new(1 << 16, 4),
        adversary: AdversaryConfig::none(7),
        collective: SimCollective::AllToAll(AllToAllAlgo::Bruck),
        data: SimData::Uniform(4096),
    };
    let (report, events) = run_sim_traced(&cfg);
    assert!(!events.is_empty(), "a 512-rank all-to-all must cross the wire");
    let traced: u64 = events.iter().filter(|e| e.is_span()).map(|e| e.bytes as u64).sum();
    assert_eq!(traced, report.stats.wire_bytes, "sim trace bytes diverge from engine stats");

    let dir = temp_dir("sim512");
    let path = dir.join("sim_a2a_512.trace.json");
    chrome::export(&events, &path).expect("export sim trace");
    let summary = chrome::validate_file(&path).expect("sim trace must validate");
    assert!(summary.spans > 0, "sim trace carries no spans");
    std::fs::remove_dir_all(&dir).ok();
}

/// The service-facing opt-in: `.trace(true)` claims its own capture
/// window around the run, exports, and hands the artifact path back in
/// the report — no caller-side session management.
#[test]
fn transform_trace_flag_reports_artifact_path() {
    let _guard = serial();
    let transform = TransformRequest::grid(32, 32)
        .spec(TransformSpec { threads_per_locality: 1, verify: false, ..TransformSpec::default() })
        .localities(2)
        .trace(true)
        .build()
        .expect("build transform");
    let report = transform.run().expect("traced run");
    let path = report.trace_path.expect("trace(true) must report an artifact path");
    let summary = chrome::validate_file(&path).expect("reported artifact must validate");
    assert!(summary.spans > 0, "a 2-locality run must record spans");
    std::fs::remove_file(&path).ok();
}
