//! Deterministic pseudo-random number generators.
//!
//! `SplitMix64` is used for seeding and quick hashes; `Pcg32` (PCG-XSH-RR)
//! is the general-purpose generator for workload synthesis and property
//! tests. Both are tiny, well-studied, and allocation-free.

/// SplitMix64 — Steele et al., used as a seeder / stream splitter.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — O'Neill 2014. Small state, good statistical quality.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Stream selector used by [`Pcg32::new`].
    pub const DEFAULT_STREAM: u64 = 0xDA3E_39CB_94B9_5BDB;

    /// Generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, Self::DEFAULT_STREAM)
    }

    /// Independent stream per `stream` value (must differ in low bits).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next 32 uniform bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[-1, 1)` — the canonical FFT test-signal amplitude.
    #[inline]
    pub fn next_signal(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values from the canonical splitmix64.c with seed 1234567.
        let mut r = SplitMix64::new(1234567);
        let v = r.next_u64();
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(v, r2.next_u64());
        assert_ne!(v, r.next_u64());
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::with_stream(7, 1);
        let mut b = Pcg32::with_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be near-independent, got {same} collisions");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Pcg32::new(99);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear in 1000 draws");
    }

    #[test]
    fn next_f32_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Pcg32::new(11);
        for _ in 0..500 {
            let v = r.range(3, 17);
            assert!((3..17).contains(&v));
        }
    }
}
