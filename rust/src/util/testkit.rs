//! Micro property-testing kit (offline stand-in for `proptest`).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it reports the case index and a
//! debug rendering of the failing input so the run can be replayed with
//! the same fixed seed. Shrinking is deliberately out of scope — inputs
//! here are small enough to eyeball.

use super::rng::Pcg32;
use std::fmt::Debug;

/// Run `prop` over `cases` inputs drawn by `gen` from a seeded RNG.
///
/// # Panics
/// Propagates the property's panic, prefixed with the failing case.
pub fn check<T: Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T),
) {
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&input)));
        if let Err(err) = result {
            eprintln!("testkit: property failed at case {case}/{cases}, seed {seed}");
            eprintln!("testkit: input = {input:#?}");
            std::panic::resume_unwind(err);
        }
    }
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_close(actual: &[f32], expected: &[f32], atol: f32, rtol: f32) {
    assert_eq!(actual.len(), expected.len(), "length mismatch");
    for (i, (&a, &e)) in actual.iter().zip(expected).enumerate() {
        let tol = atol + rtol * e.abs();
        assert!(
            (a - e).abs() <= tol,
            "element {i}: actual {a} vs expected {e} (|diff| {} > tol {tol})",
            (a - e).abs()
        );
    }
}

/// Max absolute difference between two slices (0.0 for empty).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Run `f` on a helper thread and panic if it does not finish within
/// `timeout` — the bounded-wait guard for tests that drive blocking
/// machinery which must *never* hang (e.g. [`crate::runtime::FftService`]
/// jobs over a fault-injected fabric). On success the helper thread is
/// joined and `f`'s value returned; on timeout the test dies with a
/// diagnostic naming `label` instead of wedging the whole test binary
/// until the harness is killed.
///
/// # Panics
/// If `f` exceeds `timeout` or panics (the panic is propagated).
pub fn with_watchdog<T: Send + 'static>(
    label: &str,
    timeout: std::time::Duration,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(format!("watchdog-{label}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn watchdog subject thread");
    match rx.recv_timeout(timeout) {
        Ok(value) => {
            handle.join().expect("watchdog subject thread panicked after replying");
            value
        }
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            // Dump whatever trace spans are still open: on a wedged
            // collective these name the blocked operation (category,
            // name, rank, tag, chunk) — far more actionable than a bare
            // timeout. Empty unless tracing was enabled.
            let mut dump = String::new();
            // If an armed conformance checker can see a wait-for cycle,
            // lead with the typed diagnosis — it names both sides of the
            // deadlock (and any held pool leases), not just our spans.
            if let Some(d) = crate::collectives::conformance::diagnose() {
                dump.push_str(&format!("\n  deadlock diagnosis: {d}"));
            }
            for s in crate::obs::open_spans() {
                dump.push_str(&format!(
                    "\n  open span: {}/{} rank {} tag {} chunk {} (started {:.1} µs ago)",
                    s.cat,
                    s.name,
                    s.rank,
                    s.tag,
                    s.chunk,
                    s.open_for_ns() as f64 / 1e3,
                ));
            }
            panic!("watchdog: {label:?} still running after {timeout:?} — likely hang{dump}")
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            // The subject dropped the sender without replying: it
            // panicked. Join to propagate the original panic payload.
            match handle.join() {
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(()) => unreachable!("subject exited cleanly without sending its result"),
            }
        }
    }
}

/// Relative L2 error ‖a−b‖ / ‖b‖ — the standard FFT accuracy metric.
pub fn rel_l2_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check(1, 25, |r| r.next_below(10), |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check(1, 10, |r| r.next_below(10), |&v| assert!(v < 5));
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn assert_close_reports_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 0.0);
    }

    #[test]
    fn watchdog_returns_value_and_reports_hangs() {
        let v = with_watchdog("quick", std::time::Duration::from_secs(5), || 41 + 1);
        assert_eq!(v, 42);
    }

    #[test]
    #[should_panic(expected = "likely hang")]
    fn watchdog_times_out() {
        with_watchdog("stuck", std::time::Duration::from_millis(50), || {
            std::thread::sleep(std::time::Duration::from_secs(10));
        });
    }

    #[test]
    fn watchdog_timeout_dumps_open_spans() {
        // Hold the trace session on this thread so the open-span table
        // is ours for the duration; the stuck subject arms a span and
        // never drops it — the timeout panic must name it.
        let session = crate::obs::session();
        let result = std::panic::catch_unwind(|| {
            with_watchdog("stuck-traced", std::time::Duration::from_millis(50), || {
                let _g = crate::obs::span_args("t_wd", "recv", 1, 9, 3, crate::obs::NO_ARG);
                std::thread::sleep(std::time::Duration::from_secs(2));
            });
        });
        drop(session.finish());
        let payload = result.expect_err("watchdog must time out");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .expect("timeout panic carries a String payload");
        assert!(msg.contains("likely hang"), "{msg}");
        assert!(msg.contains("open span: t_wd/recv rank 1 tag 9 chunk 3"), "{msg}");
    }

    // Requires the real conformance checker (stubbed out of plain
    // release builds, where no diagnosis can ever be stored).
    #[cfg(any(debug_assertions, feature = "conformance"))]
    #[test]
    fn watchdog_timeout_reports_stored_deadlock_diagnosis() {
        use crate::collectives::conformance as conf;
        let _arm = conf::arm();
        // Deterministically store a diagnosis: build a two-rank wait
        // cycle by hand and swallow the panic the closing edge raises.
        let _e1 = conf::on_recv_enter(0xD0C, 0, 0, 1, 7);
        let closing = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _e2 = conf::on_recv_enter(0xD0C, 0, 1, 0, 9);
        }));
        assert!(closing.is_err(), "closing the cycle must panic");
        let payload = std::panic::catch_unwind(|| {
            with_watchdog("stuck-deadlocked", std::time::Duration::from_millis(50), || {
                std::thread::sleep(std::time::Duration::from_secs(2));
            });
        })
        .expect_err("watchdog must time out");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .expect("timeout panic carries a String payload");
        assert!(msg.contains("deadlock diagnosis: wait-for cycle across 2 rank(s)"), "{msg}");
        assert!(msg.contains("rank 1 waits on rank 0 (tag 9)"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "subject blew up")]
    fn watchdog_propagates_subject_panic() {
        with_watchdog("exploder", std::time::Duration::from_secs(5), || {
            panic!("subject blew up");
        });
    }

    #[test]
    fn rel_l2_zero_for_identical() {
        assert_eq!(rel_l2_error(&[1.0, -2.0, 3.0], &[1.0, -2.0, 3.0]), 0.0);
    }

    #[test]
    fn rel_l2_scales() {
        let e = rel_l2_error(&[1.1], &[1.0]);
        assert!((e - 0.1).abs() < 1e-5, "{e}");
    }
}
