//! Sync-primitive indirection for `loom` model checking.
//!
//! The promise/future cell ([`crate::task`]) and the worker pool are the
//! two pieces of hand-rolled blocking synchronization in the codebase;
//! `tests/loom.rs` exhaustively model-checks their interleavings. Loom
//! works by substituting its own mock `Mutex`/`Condvar`/`Arc`/threads,
//! so those modules import the primitives from here instead of
//! `std::sync`: a plain build re-exports `std`, a `--cfg loom` build
//! (CI's `loom-tests` job) re-exports the mocks. Nothing else changes —
//! the checked code is byte-for-byte the production code.

#[cfg(loom)]
pub(crate) use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
pub(crate) use loom::thread;

#[cfg(not(loom))]
pub(crate) use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
pub(crate) use std::thread;
