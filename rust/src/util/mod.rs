//! Small self-contained utilities: deterministic RNG, byte conversion,
//! and a micro property-testing kit.
//!
//! The build environment is offline, so instead of `rand`/`proptest` we
//! carry our own seeded generators and a tiny property-test driver. All
//! randomized tests in this repo go through [`testkit`] with a fixed seed,
//! making every test run reproducible.

pub mod bytes;
pub mod rng;
pub(crate) mod sync;
pub mod testkit;

pub use bytes::{bytes_to_f32, f32_to_bytes};
pub use rng::{Pcg32, SplitMix64};
