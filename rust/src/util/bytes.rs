//! Byte-level conversions between typed FFT payloads and wire buffers.
//!
//! All parcel payloads travel as little-endian byte buffers; these helpers
//! are the (single, counted) serialization copy on the send side and the
//! matching parse on the receive side.

/// Serialize an `f32` slice to little-endian bytes.
pub fn f32_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Parse a little-endian byte buffer into `f32`s.
///
/// # Panics
/// If the buffer length is not a multiple of 4.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 4 == 0, "byte buffer length {} not a multiple of 4", bytes.len());
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Append a `u64` little-endian.
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Read a `u64` little-endian at `off`, advancing it.
pub fn get_u64(buf: &[u8], off: &mut usize) -> u64 {
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().expect("short buffer"));
    *off += 8;
    v
}

/// Append a `u32` little-endian.
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Read a `u32` little-endian at `off`, advancing it.
pub fn get_u32(buf: &[u8], off: &mut usize) -> u32 {
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().expect("short buffer"));
    *off += 4;
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE, 1e30, -0.0];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&xs)), xs);
    }

    #[test]
    fn f32_roundtrip_preserves_nan_bits() {
        let xs = vec![f32::NAN];
        let back = bytes_to_f32(&f32_to_bytes(&xs));
        assert!(back[0].is_nan());
    }

    #[test]
    fn empty_roundtrip() {
        assert!(bytes_to_f32(&f32_to_bytes(&[])).is_empty());
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn ragged_buffer_panics() {
        bytes_to_f32(&[1, 2, 3]);
    }

    #[test]
    fn u64_u32_roundtrip() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 0xDEAD_BEEF_CAFE_F00D);
        put_u32(&mut buf, 0x1234_5678);
        let mut off = 0;
        assert_eq!(get_u64(&buf, &mut off), 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(get_u32(&buf, &mut off), 0x1234_5678);
        assert_eq!(off, buf.len());
    }
}
