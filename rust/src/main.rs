//! `repro` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! - `repro info` — cluster spec (paper Fig. 2), parcelport cost table,
//!   artifact status.
//! - `repro fft ...` — one distributed FFT run (any port / variant /
//!   engine), with verification.
//! - `repro baseline ...` — the FFTW3-MPI+pthreads reference.
//! - `repro kernels` — compute-kernel dispatch report: runtime SIMD
//!   tier, cache-tile geometry, per-size throughput, cache counters.
//! - `repro bench chunk-size` — regenerate Fig. 3.
//! - `repro bench strong-scaling --variant all-to-all|scatter` —
//!   regenerate Fig. 4 / Fig. 5.
//! - `repro bench collectives` — all-to-all algorithm ablation.
//! - `repro serve` — resident multi-tenant FFT service reading job
//!   lines from stdin (`metrics` on a line by itself prints a
//!   Prometheus-style snapshot).
//! - `repro load` — multi-tenant service load generator (latency
//!   percentiles + bitwise output audit; `--trace` captures the burst's
//!   timeline and metrics snapshot).
//! - `repro trace` — one traced run: exports a Chrome/Perfetto trace of
//!   per-chunk wire, placement, and FFT-band spans plus a per-phase
//!   summary table.
//!
//! Run `repro help` for flags.

use anyhow::{bail, Error, Result};
use hpx_fft::baseline::fftw_like::{self, FftwLikeConfig};
use hpx_fft::bench_harness::{fig3, fig45, fig6, fig7, load, runner::measure};
use hpx_fft::cli::Args;
use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy, Communicator};
use hpx_fft::config::{BenchConfig, ClusterSpec, TransformSpec};
use hpx_fft::dist_fft::driver::{ComputeEngine, Domain, ExecutionMode, Variant};
use hpx_fft::dist_fft::grid3::{Grid3, ProcGrid};
use hpx_fft::dist_fft::TransformRequest;
use hpx_fft::hpx::parcel::Payload;
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::parcelport::{NetModel, PortKind};
use hpx_fft::runtime::{FftService, JobHandle, ServiceConfig};

const HELP: &str = "\
repro — HPX communication benchmark reproduction (Strack & Pflüger 2025)

USAGE:
  repro info
  repro fft [--rows N] [--cols N] [--nodes N] [--port tcp|mpi|lci]
            [--variant all-to-all|scatter] [--exec blocking|async]
            [--domain complex|real]
            [--algo linear|pairwise|pairwise-chunked|bruck|hpx-root]
            [--chunk-bytes N] [--inflight N]
            [--threads N] [--engine native|pjrt] [--artifacts DIR]
            [--net] [--no-verify]
            (grid lengths may be anything divisible by --nodes — the
             planner is mixed-radix, e.g. --rows 12 --cols 96;
             --exec async runs the future-chained task graph and reports
             the comm/compute overlap window; --domain real runs the
             r2c transform — packed half-spectrum transposes, ~half the
             wire bytes; needs even --cols with cols/2 divisible by N)
  repro fft3 [--grid3 N0xN1xN2] [--proc-grid PRxPC] [--port tcp|mpi|lci]
             [--exec blocking|async] [--domain complex|real]
             [--chunk-bytes N] [--inflight N]
             [--threads N] [--net] [--no-verify]
            (3-D pencil-decomposition FFT on a PrxPc process grid:
             FFT(z) → row-comm transpose → FFT(y) → column-comm
             transpose → FFT(x); constraints Pr|n0, Pr|n1, Pc|n1, Pc|n2;
             --domain real additionally needs even n2 with n2/2
             divisible by Pc)
  repro baseline [--rows N] [--cols N] [--nodes N] [--threads N] [--net]
  repro kernels  [--sizes 256,1024,4096,1000,1013] [--reps N]
                 (compute-kernel report: the SIMD tier runtime dispatch
                  selected, transpose cache-tile geometry, per-size
                  kernel + measured single-core GFLOP/s, and the
                  twiddle/plan cache counters the sweep left behind;
                  HPXFFT_SIMD=scalar forces the scalar tier)
  repro bench chunk-size      [--quick] [--reps N] [--out DIR]
                              [--chunk-bytes N] [--inflight N]
                              [--exec blocking|async] [--trace]
  repro bench strong-scaling  --variant all-to-all|scatter
                              [--quick] [--reps N] [--grid N] [--out DIR]
                              [--exec blocking|async] [--trace]
  repro bench fig6            [--quick] [--reps N] [--grid3 N0xN1xN2]
                              [--shapes 1x4,2x2,4x1] [--threads N]
                              [--out DIR] [--chunk-bytes N] [--inflight N]
                              [--trace]
                              (sweeps every shape × port × exec mode)
  repro bench fig7            [--quick] [--reps N] [--grid N] [--out DIR]
                              [--threads N] [--chunk-bytes N] [--inflight N]
                              [--trace]
                              (real-vs-complex sweep: every port × exec
                               mode × domain, with measured wire bytes;
                               writes fig7_real.csv;
                               --trace on any bench writes the sweep's
                               span timeline as {csv stem}.trace.json)
  repro bench collectives     [--nodes N] [--bytes N] [--reps N]
                              [--chunk-bytes N] [--inflight N]
  repro simulate [--grid N] [--port tcp|mpi|lci] [--domain complex|real]
                 [--variant all-to-all|scatter|fftw3] [--nodes-list 1,2,4,8,16]
  repro simulate --engine event
                 [--figs fig4,fig5,fig6] [--port tcp|mpi|lci]
                 [--localities N | --localities-list 512,1024,2048]
                 [--seed N] [--adversary none|light|hostile]
                 [--faults delay,dup,drop,slow] [--out DIR] [--trace]
                 (discrete-event engine: runs the real collective state
                  machines at 512-4096 simulated localities under a
                  seeded adversary, prints per-run trace hashes,
                  slope-checks fig4/5/6 against the closed-form model,
                  and writes sim_scaling.csv with --out; --trace exports
                  one representative point's wire timeline as Chrome
                  trace JSON — same format as live traces)
  repro serve    [--nodes N] [--port tcp|mpi|lci] [--queue-limit N]
                 [--inflight-jobs N]
                 (resident multi-tenant FFT service; reads one job per
                  stdin line: `[tenant=T] grid=RxC|grid3=N0xN1xN2
                  [nodes=N|proc=PRxPC] [domain=..] [exec=..] [threads=N]
                  [verify=..]`, # comments and blank lines skipped;
                  `metrics` on a line by itself prints a Prometheus-style
                  snapshot of per-tenant counters and latency histograms;
                  prints each job's report as it finishes, EOF drains
                  and prints per-tenant metrics)
  repro load     [--tenants N] [--jobs N] [--nodes N] [--port tcp|mpi|lci]
                 [--queue-limit N] [--inflight-jobs N] [--threads N]
                 [--out DIR] [--trace]
                 (service load generator: mixed 2-D/3-D × complex/real ×
                  blocking/async jobs from N synthetic tenants, audited
                  bitwise vs single-shot runs; writes service_load.csv;
                  --trace additionally writes service_load.trace.json and
                  service_metrics.prom)
  repro trace    [--rows N --cols N | --grid3 N0xN1xN2] [flags of
                 fft/fft3] [--out DIR]
                 (one traced run: captures per-chunk wire/place spans and
                  FFT band spans, writes DIR/repro_trace.trace.json —
                  loadable in Perfetto or chrome://tracing — and prints a
                  per-phase time table; on an async run the wire spans
                  visibly overlap the FFT bands)
  repro help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        None | Some("help") => {
            print!("{HELP}");
            Ok(())
        }
        Some("info") => cmd_info(),
        Some("fft") => cmd_fft(&args),
        Some("fft3") => cmd_fft3(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("kernels") => cmd_kernels(&args),
        Some("bench") => match args.positional.get(1).map(|s| s.as_str()) {
            Some("chunk-size") => cmd_bench_chunk(&args),
            Some("strong-scaling") => cmd_bench_scaling(&args),
            Some("fig6") | Some("pencil") => cmd_bench_fig6(&args),
            Some("fig7") | Some("real") => cmd_bench_fig7(&args),
            Some("collectives") => cmd_bench_collectives(&args),
            other => bail!("unknown bench target {other:?}; see `repro help`"),
        },
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("load") => cmd_load(&args),
        Some("trace") => cmd_trace(&args),
        Some(other) => bail!("unknown subcommand {other:?}; see `repro help`"),
    }
}

fn cmd_info() -> Result<()> {
    let spec = ClusterSpec::buran();
    println!("Reproduction target (paper Fig. 2):\n");
    print!("{}", spec.render());

    println!("\nParcelport cost models (calibrated, DESIGN.md §6):\n");
    let mut t = hpx_fft::metrics::table::Table::new(&[
        "port", "sw overhead", "protocol copies", "eager limit", "rdv RTTs",
    ]);
    for port in PortKind::ALL {
        let c = port.cost_model();
        t.row(&[
            port.name().into(),
            format!("{} µs", c.sw_overhead_us),
            c.protocol_copies.to_string(),
            if c.eager_threshold == u64::MAX {
                "∞".into()
            } else {
                fig3::human_bytes(c.eager_threshold)
            },
            c.rendezvous_rtts.to_string(),
        ]);
    }
    print!("{}", t.render());

    println!("\nAOT artifacts:");
    match hpx_fft::runtime::load_manifest("artifacts") {
        Ok(entries) => {
            for e in entries {
                println!("  {:?} {}×{} — {}", e.kind, e.dim0, e.dim1, e.path.display());
            }
        }
        Err(e) => println!("  (none: {e})"),
    }
    Ok(())
}

fn parse_engine(args: &Args) -> Result<ComputeEngine> {
    match args.get("engine").unwrap_or("native") {
        "native" => Ok(ComputeEngine::Native),
        "pjrt" => {
            Ok(ComputeEngine::Pjrt(args.get("artifacts").unwrap_or("artifacts").to_string()))
        }
        other => bail!("unknown engine {other:?} (native|pjrt)"),
    }
}

/// Parse the `--chunk-bytes` / `--inflight` pair into a [`ChunkPolicy`],
/// rejecting zeros here — at parse time, with the flag named — instead
/// of letting them reach the wire protocol's clamp.
fn parse_chunk_policy(args: &Args) -> Result<ChunkPolicy> {
    let default = ChunkPolicy::default();
    let chunk_bytes: usize = args.get_or("chunk-bytes", default.chunk_bytes)?;
    let inflight: usize = args.get_or("inflight", default.inflight)?;
    anyhow::ensure!(
        chunk_bytes > 0,
        "--chunk-bytes must be ≥ 1 (a zero wire chunk can never carry data; \
         the default is {} bytes)",
        default.chunk_bytes
    );
    anyhow::ensure!(
        inflight > 0,
        "--inflight must be ≥ 1 (zero in-flight chunks would stall every \
         transfer; the default is {})",
        default.inflight
    );
    Ok(ChunkPolicy::new(chunk_bytes, inflight))
}

/// Parse the shared execution-settings flags (port, chunking, exec,
/// domain, threads, wire model, engine, verify) into a
/// [`TransformSpec`] — what both `repro fft` and `repro fft3` feed the
/// request builder.
fn parse_spec(args: &Args) -> Result<TransformSpec> {
    Ok(TransformSpec {
        port: args.get_or("port", PortKind::Lci)?,
        chunk: parse_chunk_policy(args)?,
        exec: args.get_or("exec", ExecutionMode::Blocking)?,
        domain: args.get_or("domain", Domain::Complex)?,
        threads_per_locality: args.get_or("threads", 2usize)?,
        net: args.get_bool("net").then(NetModel::infiniband_hdr),
        engine: parse_engine(args)?,
        verify: !args.get_bool("no-verify"),
    })
}

fn cmd_fft(args: &Args) -> Result<()> {
    args.check_known(&[
        "rows", "cols", "nodes", "port", "variant", "exec", "domain", "algo", "chunk-bytes",
        "inflight", "threads", "engine", "artifacts", "net", "no-verify",
    ])?;
    let spec = parse_spec(args)?;
    let is_async = spec.exec == ExecutionMode::Async;
    let (rows, cols) = (args.get_or("rows", 256usize)?, args.get_or("cols", 256usize)?);
    let report = TransformRequest::grid(rows, cols)
        .spec(spec)
        .localities(args.get_or("nodes", 4usize)?)
        .variant(args.get_or("variant", Variant::Scatter)?)
        .algo(args.get_or("algo", AllToAllAlgo::HpxRoot)?)
        .build()?
        .run()?;
    println!("{}", report.summary);
    let cp = report
        .timings
        .plane_critical_path()
        .ok_or_else(|| anyhow::anyhow!("2-D transform report carries no plane timings"))?;
    println!(
        "critical path: total {:.2} ms  (fft1 {:.2} | comm {:.2} | transpose {:.2} | fft2 {:.2})",
        cp.total_us / 1e3,
        cp.fft1_us / 1e3,
        cp.comm_us / 1e3,
        cp.transpose_us / 1e3,
        cp.fft2_us / 1e3
    );
    if is_async {
        println!(
            "overlap: {} of compute ran while collective traffic was in flight",
            hpx_fft::metrics::table::fmt_us(cp.overlap_us)
        );
    }
    println!(
        "traffic: {} msgs, {} bytes, {} copies ({} B copied), {} rendezvous",
        report.stats.msgs_sent,
        report.stats.bytes_sent,
        report.stats.payload_copies,
        report.stats.bytes_copied,
        report.stats.rendezvous_handshakes
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

fn cmd_fft3(args: &Args) -> Result<()> {
    args.check_known(&[
        "grid3", "proc-grid", "port", "exec", "domain", "chunk-bytes", "inflight", "threads",
        "net", "no-verify",
    ])?;
    let spec = parse_spec(args)?;
    let is_async = spec.exec == ExecutionMode::Async;
    let report = TransformRequest::grid3(args.get_or("grid3", Grid3::new(32, 32, 32))?)
        .spec(spec)
        .proc_grid(args.get_or("proc-grid", ProcGrid::new(2, 2))?)
        .build()?
        .run()?;
    println!("{}", report.summary);
    let cp = report
        .timings
        .pencil_critical_path()
        .ok_or_else(|| anyhow::anyhow!("3-D transform report carries no pencil timings"))?;
    println!(
        "critical path: total {:.2} ms  (fftz {:.2} | t1 {:.2} (place {:.2}) | \
         ffty {:.2} | t2 {:.2} (place {:.2}) | fftx {:.2})",
        cp.total_us / 1e3,
        cp.fft_z_us / 1e3,
        cp.t1_comm_us / 1e3,
        cp.t1_place_us / 1e3,
        cp.fft_y_us / 1e3,
        cp.t2_comm_us / 1e3,
        cp.t2_place_us / 1e3,
        cp.fft_x_us / 1e3
    );
    if is_async {
        println!(
            "overlap: {} of compute ran while transpose traffic was in flight",
            hpx_fft::metrics::table::fmt_us(cp.overlap_us)
        );
    }
    println!(
        "traffic: {} msgs, {} bytes, {} copies ({} B copied), {} rendezvous",
        report.stats.msgs_sent,
        report.stats.bytes_sent,
        report.stats.payload_copies,
        report.stats.bytes_copied,
        report.stats.rendezvous_handshakes
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    args.check_known(&["rows", "cols", "nodes", "threads", "net", "no-verify"])?;
    let config = FftwLikeConfig {
        rows: args.get_or("rows", 256usize)?,
        cols: args.get_or("cols", 256usize)?,
        localities: args.get_or("nodes", 4usize)?,
        threads: args.get_or("threads", 2usize)?,
        net: args.get_bool("net").then(NetModel::infiniband_hdr),
        verify: !args.get_bool("no-verify"),
    };
    let report = fftw_like::run(&config)?;
    let cp = report.critical_path;
    println!(
        "fftw3-like baseline: total {:.2} ms  (fft1 {:.2} | comm {:.2} | transpose {:.2} | fft2 {:.2})",
        cp.total_us / 1e3,
        cp.fft1_us / 1e3,
        cp.comm_us / 1e3,
        cp.transpose_us / 1e3,
        cp.fft2_us / 1e3
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

/// `repro kernels` — report what the compute layer actually dispatches
/// to on this machine: the runtime-detected SIMD tier, the transpose
/// cache-blocking geometry, the kernel and measured single-core
/// throughput for each requested transform size, and the twiddle/plan
/// cache counters left behind by the sweep itself.
fn cmd_kernels(args: &Args) -> Result<()> {
    use hpx_fft::dist_fft::transpose::BLOCK;
    use hpx_fft::fft::plan::{Direction, PlanCache};
    use hpx_fft::fft::twiddle::TwiddleCache;
    use hpx_fft::fft::{batch, simd};
    args.check_known(&["sizes", "reps"])?;
    let sizes: Vec<usize> = match args.get("sizes") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--sizes: {e}")))
            .collect::<Result<_>>()?,
        None => vec![256, 1024, 4096, 1000, 1013],
    };
    let reps: usize = args.get_or("reps", 200usize)?;
    anyhow::ensure!(reps > 0, "--reps must be ≥ 1");
    let tier = simd::tier();
    println!("simd tier: {} ({} complex lanes per vector op)", tier.name(), tier.lanes());
    println!(
        "cache blocking: {BLOCK}×{BLOCK} transpose tiles ({} KiB per src+dst tile pair)",
        2 * BLOCK * BLOCK * 8 / 1024
    );
    println!();
    let mut t = hpx_fft::metrics::table::Table::new(&["n", "kernel", "GFLOP/s (1 core)"]);
    for &n in &sizes {
        anyhow::ensure!(n >= 1, "--sizes entries must be ≥ 1");
        let plan = PlanCache::global().plan(n, Direction::Forward);
        let gflops = batch::measure_row_throughput(n, reps) / 1e9;
        t.row(&[n.to_string(), plan.kernel_name().into(), format!("{gflops:.2}")]);
    }
    print!("{}", t.render());
    let tc = TwiddleCache::global();
    println!(
        "\ntwiddle cache: {} hits, {} tables computed, {} derived from resident 2n tables",
        tc.hits(),
        tc.computed(),
        tc.derived()
    );
    let pc = PlanCache::global();
    println!("plan cache:    {} hits, {} misses", pc.hits(), pc.misses());
    Ok(())
}

fn bench_config(args: &Args) -> Result<BenchConfig> {
    let mut cfg = if args.get_bool("quick") { BenchConfig::quick() } else { BenchConfig::default() };
    // Config file first, explicit CLI flags override it.
    if let Some(path) = args.get("config") {
        cfg.apply_file(path)?;
    }
    cfg.reps = args.get_or("reps", cfg.reps)?;
    cfg.live_grid = args.get_or("grid", cfg.live_grid)?;
    cfg.threads = args.get_or("threads", cfg.threads)?;
    cfg.exec = args.get_or("exec", cfg.exec)?;
    cfg.pipeline.chunk_bytes = args.get_or("chunk-bytes", cfg.pipeline.chunk_bytes)?;
    cfg.pipeline.inflight = args.get_or("inflight", cfg.pipeline.inflight)?;
    anyhow::ensure!(
        cfg.pipeline.chunk_bytes > 0 && cfg.pipeline.inflight > 0,
        "--chunk-bytes/--inflight must be positive"
    );
    if let Some(out) = args.get("out") {
        cfg.out_dir = out.to_string();
    }
    Ok(cfg)
}

/// Run a fig harness inside a trace-capture session when `--trace` was
/// given, exporting the timeline next to the harness's CSV as
/// `{stem}.trace.json`. Without the flag this is a plain call to `f`.
fn with_bench_trace<T>(
    args: &Args,
    out_dir: &str,
    stem: &str,
    f: impl FnOnce() -> Result<T>,
) -> Result<T> {
    if !args.get_bool("trace") {
        return f();
    }
    let session = hpx_fft::obs::session();
    let result = f();
    let events = session.finish();
    let value = result?;
    let path = format!("{out_dir}/{stem}.trace.json");
    hpx_fft::obs::chrome::export(&events, &path)?;
    let dropped = hpx_fft::obs::dropped_events();
    if dropped > 0 {
        println!("warning: {dropped} trace event(s) dropped by full ring buffers");
    }
    println!("trace written to {path}");
    Ok(value)
}

fn cmd_bench_chunk(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight", "exec",
        "trace",
    ])?;
    let cfg = bench_config(args)?;
    println!(
        "Fig. 3 sweep ({} exec): {} reps/point, chunk sizes {:?}\n",
        cfg.exec.name(),
        cfg.reps,
        cfg.chunk_sizes
    );
    let points = with_bench_trace(args, &cfg.out_dir, "fig3_chunk_size", || fig3::run(&cfg))?;
    print!("{}", fig3::report(&points, &cfg.out_dir)?);
    println!("CSV written to {}/fig3_chunk_size.csv", cfg.out_dir);
    Ok(())
}

fn cmd_bench_scaling(args: &Args) -> Result<()> {
    args.check_known(&[
        "variant", "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight",
        "exec", "trace",
    ])?;
    let variant: Variant = args.get_or("variant", Variant::Scatter)?;
    let cfg = bench_config(args)?;
    println!(
        "strong scaling ({}, {} exec): live {}² on {:?} localities, sim {}² on {:?} nodes, {} reps\n",
        variant.name(),
        cfg.exec.name(),
        cfg.live_grid,
        cfg.live_nodes,
        cfg.sim_grid,
        cfg.sim_nodes,
        cfg.reps
    );
    let points =
        with_bench_trace(args, &cfg.out_dir, "fig45_scaling", || fig45::run(&cfg, variant))?;
    print!("{}", fig45::report(&points, variant, &cfg, &cfg.out_dir)?);
    Ok(())
}

fn cmd_bench_fig6(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid3", "shapes", "threads", "out", "config", "chunk-bytes",
        "inflight", "trace",
    ])?;
    let mut cfg = bench_config(args)?;
    cfg.grid3 = args.get_or("grid3", cfg.grid3)?;
    if let Some(s) = args.get("shapes") {
        cfg.proc_shapes = s
            .split(',')
            .map(|t| t.trim().parse::<ProcGrid>().map_err(anyhow::Error::msg))
            .collect::<Result<_>>()?;
    }
    let shapes: Vec<String> = cfg.proc_shapes.iter().map(|p| p.to_string()).collect();
    println!(
        "fig6 sweep: {} grid, shapes [{}], {} reps/point, all ports, blocking + async\n",
        cfg.grid3,
        shapes.join(", "),
        cfg.reps
    );
    let points = with_bench_trace(args, &cfg.out_dir, "fig6_pencil", || fig6::run(&cfg))?;
    print!("{}", fig6::report(&points, &cfg, &cfg.out_dir)?);
    println!("CSV written to {}/fig6_pencil.csv", cfg.out_dir);
    Ok(())
}

fn cmd_bench_fig7(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight", "trace",
    ])?;
    let cfg = bench_config(args)?;
    println!(
        "fig7 sweep: {0}×{0} grid, {1} localities, all ports, blocking + async, \
         complex + real domains, {2} reps/point\n",
        cfg.live_grid,
        fig7::FIG7_NODES,
        cfg.reps
    );
    let points = with_bench_trace(args, &cfg.out_dir, "fig7_real", || fig7::run(&cfg))?;
    print!("{}", fig7::report(&points, &cfg, &cfg.out_dir)?);
    println!("CSV written to {}/fig7_real.csv", cfg.out_dir);
    Ok(())
}

/// Direct access to the cluster-scale DES: per-node-count makespan,
/// comm-blocked time, and wire volume for one system (the numbers behind
/// the Figs. 4/5 series, with the breakdown the figures hide).
/// `--engine event` switches to the discrete-event engine, which runs
/// the real protocol state machines under a seeded adversary.
fn cmd_simulate(args: &Args) -> Result<()> {
    use hpx_fft::simnet::fft_model::{predict_fft, FftModelParams, ModelVariant};
    match args.get("engine").unwrap_or("closed-form") {
        "event" => return cmd_simulate_event(args),
        "closed-form" => {}
        other => bail!("unknown --engine {other:?} (closed-form|event)"),
    }
    args.check_known(&["engine", "grid", "port", "variant", "domain", "nodes-list"])?;
    let grid: usize = args.get_or("grid", 1usize << 14)?;
    let port: PortKind = args.get_or("port", PortKind::Lci)?;
    let domain: Domain = args.get_or("domain", Domain::Complex)?;
    let variant = match args.get("variant").unwrap_or("scatter") {
        "scatter" => ModelVariant::Scatter,
        "all-to-all" | "a2a" => ModelVariant::AllToAll(AllToAllAlgo::HpxRoot),
        "fftw3" => ModelVariant::FftwBaseline,
        other => bail!("unknown variant {other:?} (scatter|all-to-all|fftw3)"),
    };
    let nodes_list: Vec<usize> = args
        .get("nodes-list")
        .unwrap_or("1,2,4,8,16")
        .split(',')
        .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--nodes-list: {e}")))
        .collect::<Result<_>>()?;

    let spec = ClusterSpec::buran();
    println!(
        "simnet: {grid}×{grid} grid, {port} port, {variant:?}, {} domain, \
         buran wire+compute model\n",
        domain.name()
    );
    let mut t = hpx_fft::metrics::table::Table::new(&[
        "nodes", "makespan", "max blocked (comm)", "wire bytes", "chunk",
    ]);
    for nodes in nodes_list {
        anyhow::ensure!(grid % nodes == 0, "grid {grid} not divisible by {nodes} nodes");
        if domain == Domain::Real {
            anyhow::ensure!(
                grid % 2 == 0 && (grid / 2) % nodes == 0,
                "real-domain grid {grid}: packed spectrum {} must divide by {nodes} nodes",
                grid / 2
            );
        }
        let params = FftModelParams {
            rows: grid,
            cols: grid,
            nodes,
            domain,
            compute: spec.compute_model(),
            net: spec.net_model(),
        };
        let r = predict_fft(&params, port, variant);
        let blocked = r.node_blocked_us.iter().copied().fold(0.0, f64::max);
        t.row(&[
            nodes.to_string(),
            format!("{:.1} ms", r.makespan_us / 1e3),
            format!("{:.1} ms", blocked / 1e3),
            format!("{}", r.wire_bytes),
            fig3::human_bytes(params.chunk_bytes()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// The `--engine event` branch of `repro simulate`: the real collective
/// state machines on the deterministic event engine at cluster scale,
/// with seeded adversarial schedules and fault injection.
fn cmd_simulate_event(args: &Args) -> Result<()> {
    use hpx_fft::bench_harness::sim_scaling::{self, SimFig, SimScalingOpts};
    use hpx_fft::simnet::AdversaryConfig;
    args.check_known(&[
        "engine", "port", "figs", "localities", "localities-list", "seed", "adversary", "faults",
        "out", "trace",
    ])?;
    let port: PortKind = args.get_or("port", PortKind::Lci)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let adversary = match (args.get("faults"), args.get("adversary")) {
        (Some(spec), _) => AdversaryConfig::from_fault_spec(spec, seed).map_err(Error::msg)?,
        (None, Some(name)) => AdversaryConfig::preset(name, seed).map_err(Error::msg)?,
        (None, None) => AdversaryConfig::none(seed),
    };
    let localities: Vec<usize> = match (args.get("localities-list"), args.get("localities")) {
        (Some(list), _) => list
            .split(',')
            .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--localities-list: {e}")))
            .collect::<Result<_>>()?,
        (None, Some(_)) => vec![args.get_or("localities", 1024usize)?],
        (None, None) => vec![512, 1024, 2048],
    };
    let figs: Vec<SimFig> = match args.get("figs") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse().map_err(Error::msg))
            .collect::<Result<_>>()?,
        None => SimFig::ALL.to_vec(),
    };
    let opts = SimScalingOpts {
        figs,
        localities,
        port,
        adversary,
        out_dir: args.get("out").map(|s| s.to_string()),
    };
    println!(
        "event engine: localities {:?}, {port} port, seed {seed}, adversary \
         delay{}%/dup{}%/drop{}%/slow{}%\n",
        opts.localities,
        adversary.delay_prob_pct,
        adversary.dup_prob_pct,
        adversary.drop_prob_pct,
        adversary.slow_rank_pct
    );
    let rows = sim_scaling::run(&opts)?;
    for r in &rows {
        println!(
            "trace {} @{} localities: {:016x}",
            r.fig.name(),
            r.localities,
            r.stats.trace_hash
        );
    }
    if opts.localities.len() >= 2 {
        sim_scaling::validate_slopes(&rows, 0.5)?;
        println!("\nslope check vs closed-form comm-only model: OK (tol 0.5 log2 units)");
    }
    if args.get_bool("trace") {
        // A separate traced engine run of one representative point —
        // the sweep's own rows (and sim_scaling.csv) are untouched.
        let dir = args.get("out").unwrap_or("bench_out");
        let path = sim_scaling::export_trace(&opts, dir)?;
        println!("sim trace written to {path}");
    }
    Ok(())
}

/// Extra ablation: compare all-to-all algorithms head to head (the
/// design-choice study DESIGN.md calls out).
fn cmd_bench_collectives(args: &Args) -> Result<()> {
    args.check_known(&["nodes", "bytes", "reps", "port", "chunk-bytes", "inflight"])?;
    let nodes: usize = args.get_or("nodes", 4usize)?;
    let bytes: usize = args.get_or("bytes", 256 * 1024usize)?;
    let reps: usize = args.get_or("reps", 20usize)?;
    let port: PortKind = args.get_or("port", PortKind::Lci)?;
    let policy = parse_chunk_policy(args)?;
    let cluster = Cluster::new(nodes, port, Some(NetModel::infiniband_hdr()))?;
    println!(
        "all-to-all ablation: {nodes} localities, {} per chunk, {port} port, \
         pipeline {} × {} in flight\n",
        fig3::human_bytes(bytes as u64),
        fig3::human_bytes(policy.chunk_bytes as u64),
        policy.inflight
    );
    let mut t = hpx_fft::metrics::table::Table::new(&["algorithm", "mean", "±95% CI"]);
    for algo in AllToAllAlgo::ALL {
        let stats = measure(2, reps, || {
            let times = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(policy);
                // The futures engine drives every algorithm through the
                // send pool; spawn it outside the timed region.
                comm.warm_chunk_pool();
                let chunks: Vec<Payload> =
                    (0..nodes).map(|_| Payload::new(vec![0u8; bytes])).collect();
                let t0 = std::time::Instant::now();
                let _ = comm.all_to_all(chunks, algo);
                t0.elapsed().as_secs_f64() * 1e6
            });
            times.into_iter().fold(0.0, f64::max)
        });
        t.row(&[
            algo.name().into(),
            format!("{:.1} µs", stats.mean()),
            format!("{:.1}", stats.ci95()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `repro trace` — run one transform (2-D unless `--grid3` is given)
/// with the tracing layer live, export the timeline as Chrome
/// trace-event JSON, and print a per-phase span summary. The capture
/// session is held *here* rather than via the request builder's
/// `.trace(true)` so the events stay in hand for the summary table
/// instead of only landing in the file.
fn cmd_trace(args: &Args) -> Result<()> {
    args.check_known(&[
        "rows", "cols", "nodes", "grid3", "proc-grid", "port", "variant", "exec", "domain",
        "algo", "chunk-bytes", "inflight", "threads", "engine", "artifacts", "net", "no-verify",
        "out",
    ])?;
    let spec = parse_spec(args)?;
    let request = if args.get("grid3").is_some() {
        TransformRequest::grid3(args.get_or("grid3", Grid3::new(32, 32, 32))?)
            .spec(spec)
            .proc_grid(args.get_or("proc-grid", ProcGrid::new(2, 2))?)
    } else {
        TransformRequest::grid(args.get_or("rows", 256usize)?, args.get_or("cols", 256usize)?)
            .spec(spec)
            .localities(args.get_or("nodes", 4usize)?)
            .variant(args.get_or("variant", Variant::Scatter)?)
            .algo(args.get_or("algo", AllToAllAlgo::HpxRoot)?)
    };
    let transform = request.build()?;

    let session = hpx_fft::obs::session();
    let result = transform.run();
    let events = session.finish();
    let report = result?;

    let out_dir = args.get("out").unwrap_or("bench_out");
    let path = format!("{out_dir}/repro_trace.trace.json");
    hpx_fft::obs::chrome::export(&events, &path)?;
    let summary = hpx_fft::obs::chrome::validate_file(&path).map_err(Error::msg)?;

    println!("{}", report.summary);
    println!("\nper-phase span summary:\n");
    let mut t = hpx_fft::metrics::table::Table::new(&["phase", "spans", "total", "max"]);
    for r in hpx_fft::obs::chrome::phase_table(&events) {
        t.row(&[
            format!("{}/{}", r.cat, r.name),
            r.count.to_string(),
            hpx_fft::metrics::table::fmt_us(r.total_us),
            hpx_fft::metrics::table::fmt_us(r.max_us),
        ]);
    }
    print!("{}", t.render());
    let dropped = hpx_fft::obs::dropped_events();
    if dropped > 0 {
        println!("warning: {dropped} event(s) dropped by full ring buffers");
    }
    println!(
        "\ntrace: {} events ({} spans) on {} tracks → {path}",
        summary.events, summary.spans, summary.tracks
    );
    println!("open in Perfetto (ui.perfetto.dev) or chrome://tracing");
    Ok(())
}

/// Parse one `repro serve` stdin line into `(tenant, request)`.
/// Tokens are whitespace-separated `key=value` pairs; exactly one of
/// `grid=RxC` (2-D) or `grid3=N0xN1xN2` (3-D) is required.
fn parse_serve_line(line: &str) -> Result<(String, TransformRequest)> {
    let mut tenant = "default".to_string();
    let mut grid: Option<(usize, usize)> = None;
    let mut grid3: Option<Grid3> = None;
    let mut nodes: Option<usize> = None;
    let mut proc: Option<ProcGrid> = None;
    let mut spec = TransformSpec { threads_per_locality: 1, ..TransformSpec::default() };
    for tok in line.split_whitespace() {
        let (key, value) =
            tok.split_once('=').ok_or_else(|| anyhow::anyhow!("token {tok:?} is not key=value"))?;
        match key {
            "tenant" => tenant = value.to_string(),
            "grid" => {
                let (r, c) = value
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("grid wants RxC, got {value:?}"))?;
                grid = Some((r.parse()?, c.parse()?));
            }
            "grid3" => grid3 = Some(value.parse().map_err(anyhow::Error::msg)?),
            "nodes" => nodes = Some(value.parse()?),
            "proc" => proc = Some(value.parse().map_err(anyhow::Error::msg)?),
            "port" => spec.port = value.parse().map_err(anyhow::Error::msg)?,
            "domain" => spec.domain = value.parse().map_err(anyhow::Error::msg)?,
            "exec" => spec.exec = value.parse().map_err(anyhow::Error::msg)?,
            "threads" => spec.threads_per_locality = value.parse()?,
            "verify" => spec.verify = value.parse()?,
            other => bail!(
                "unknown key {other:?} \
                 (tenant|grid|grid3|nodes|proc|port|domain|exec|threads|verify)"
            ),
        }
    }
    let mut request = match (grid, grid3) {
        (Some((rows, cols)), None) => TransformRequest::grid(rows, cols),
        (None, Some(g)) => TransformRequest::grid3(g),
        _ => bail!("each job needs exactly one of grid=RxC or grid3=N0xN1xN2"),
    };
    request = request.spec(spec);
    if let Some(n) = nodes {
        request = request.localities(n);
    }
    if let Some(p) = proc {
        request = request.proc_grid(p);
    }
    Ok((tenant, request))
}

/// Print every finished job's outcome and drop its handle; with
/// `block`, wait for all of them.
fn reap(handles: &mut Vec<JobHandle>, block: bool) {
    let mut i = 0;
    while i < handles.len() {
        if block || handles[i].is_done() {
            let h = handles.swap_remove(i);
            let (id, tenant) = (h.id(), h.tenant().to_string());
            match h.wait() {
                Ok(out) => println!(
                    "job {id} [{tenant}] done in {:.1} ms — {}",
                    out.latency_us / 1e3,
                    out.report.summary
                ),
                Err(e) => println!("job {id} [{tenant}] FAILED: {e}"),
            }
        } else {
            i += 1;
        }
    }
}

/// `repro serve` — a resident multi-tenant FFT service fed from stdin
/// (one job per line), the interactive face of
/// [`hpx_fft::runtime::FftService`]. EOF drains the service and prints
/// per-tenant metrics.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::BufRead;
    args.check_known(&["nodes", "port", "queue-limit", "inflight-jobs"])?;
    let service = FftService::new(ServiceConfig {
        localities: args.get_or("nodes", 4usize)?,
        port: args.get_or("port", PortKind::Lci)?,
        net: None,
        queue_limit: args.get_or("queue-limit", 64usize)?,
        max_inflight: args.get_or("inflight-jobs", 4usize)?,
        job_tag_span: None,
        fault: None,
    })?;
    println!(
        "fft service up: {} localities, {} port; one job per stdin line\n\
           [tenant=T] grid=RxC|grid3=N0xN1xN2 [nodes=N|proc=PRxPC] [domain=complex|real]\n\
           [exec=blocking|async] [threads=N] [verify=true|false]   (# starts a comment)\n\
           `metrics` alone on a line prints a Prometheus-style snapshot",
        service.localities(),
        service.port()
    );
    let mut handles: Vec<JobHandle> = Vec::new();
    for (lineno, line) in std::io::stdin().lock().lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "metrics" {
            // Prometheus-style text snapshot: per-tenant counters,
            // queue gauges, and latency histograms.
            print!("{}", service.metrics_text());
            reap(&mut handles, false);
            continue;
        }
        match parse_serve_line(line) {
            Ok((tenant, request)) => match service.submit(&tenant, request) {
                Ok(h) => {
                    println!("job {} [{}] accepted", h.id(), h.tenant());
                    handles.push(h);
                }
                Err(e) => println!("line {}: rejected: {e}", lineno + 1),
            },
            Err(e) => println!("line {}: {e:#}", lineno + 1),
        }
        reap(&mut handles, false);
    }
    reap(&mut handles, true);
    let metrics = service.shutdown();
    println!("\nper-tenant metrics:");
    let mut t = hpx_fft::metrics::table::Table::new(&[
        "tenant", "submitted", "done", "failed", "rejected", "p50", "p99", "wire bytes",
    ]);
    for m in &metrics {
        let (p50, p99) = match &m.latency {
            Some(l) => {
                (format!("{:.1} ms", l.p50() / 1e3), format!("{:.1} ms", l.p99() / 1e3))
            }
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            m.tenant.clone(),
            m.submitted.to_string(),
            m.completed.to_string(),
            m.failed.to_string(),
            m.rejected.to_string(),
            p50,
            p99,
            m.wire_bytes.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `repro load` — the multi-tenant service load generator
/// ([`hpx_fft::bench_harness::load`]); exits nonzero if any job's
/// output differs bitwise from its single-shot reference.
fn cmd_load(args: &Args) -> Result<()> {
    args.check_known(&[
        "tenants", "jobs", "nodes", "port", "queue-limit", "inflight-jobs", "threads", "out",
        "trace",
    ])?;
    let cfg = load::LoadConfig {
        localities: args.get_or("nodes", 4usize)?,
        port: args.get_or("port", PortKind::Lci)?,
        tenants: args.get_or("tenants", 4usize)?,
        jobs: args.get_or("jobs", 1000usize)?,
        queue_limit: args.get_or("queue-limit", 64usize)?,
        max_inflight: args.get_or("inflight-jobs", 4usize)?,
        threads: args.get_or("threads", 1usize)?,
        out_dir: args.get("out").unwrap_or("bench_out").to_string(),
        trace: args.get_bool("trace"),
    };
    println!(
        "service load: {} jobs over {} tenants, {}-locality {} fabric, {} jobs in flight\n",
        cfg.jobs, cfg.tenants, cfg.localities, cfg.port, cfg.max_inflight
    );
    let rows = load::run(&cfg)?;
    print!("{}", load::report(&rows, &cfg.out_dir)?);
    println!("\nCSV written to {}/service_load.csv", cfg.out_dir);
    if cfg.trace {
        println!(
            "trace written to {0}/service_load.trace.json, metrics to {0}/service_metrics.prom",
            cfg.out_dir
        );
    }
    let mismatches: usize = rows.iter().map(|r| r.mismatches).sum();
    anyhow::ensure!(
        mismatches == 0,
        "{mismatches} job(s) returned outputs differing bitwise from the single-shot reference"
    );
    Ok(())
}
