//! `repro` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! - `repro info` — cluster spec (paper Fig. 2), parcelport cost table,
//!   artifact status.
//! - `repro fft ...` — one distributed FFT run (any port / variant /
//!   engine), with verification.
//! - `repro baseline ...` — the FFTW3-MPI+pthreads reference.
//! - `repro bench chunk-size` — regenerate Fig. 3.
//! - `repro bench strong-scaling --variant all-to-all|scatter` —
//!   regenerate Fig. 4 / Fig. 5.
//! - `repro bench collectives` — all-to-all algorithm ablation.
//!
//! Run `repro help` for flags.

use anyhow::{bail, Result};
use hpx_fft::baseline::fftw_like::{self, FftwLikeConfig};
use hpx_fft::bench_harness::{fig3, fig45, fig6, fig7, runner::measure};
use hpx_fft::cli::Args;
use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy, Communicator};
use hpx_fft::config::{BenchConfig, ClusterSpec};
use hpx_fft::dist_fft::driver::{self, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant};
use hpx_fft::dist_fft::grid3::{Grid3, ProcGrid};
use hpx_fft::dist_fft::pencil::{self, Pencil3Config};
use hpx_fft::hpx::parcel::Payload;
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::parcelport::{NetModel, PortKind};

const HELP: &str = "\
repro — HPX communication benchmark reproduction (Strack & Pflüger 2025)

USAGE:
  repro info
  repro fft [--rows N] [--cols N] [--nodes N] [--port tcp|mpi|lci]
            [--variant all-to-all|scatter] [--exec blocking|async]
            [--domain complex|real]
            [--algo linear|pairwise|pairwise-chunked|bruck|hpx-root]
            [--chunk-bytes N] [--inflight N]
            [--threads N] [--engine native|pjrt] [--artifacts DIR]
            [--net] [--no-verify]
            (grid lengths may be anything divisible by --nodes — the
             planner is mixed-radix, e.g. --rows 12 --cols 96;
             --exec async runs the future-chained task graph and reports
             the comm/compute overlap window; --domain real runs the
             r2c transform — packed half-spectrum transposes, ~half the
             wire bytes; needs even --cols with cols/2 divisible by N)
  repro fft3 [--grid3 N0xN1xN2] [--proc-grid PRxPC] [--port tcp|mpi|lci]
             [--exec blocking|async] [--domain complex|real]
             [--chunk-bytes N] [--inflight N]
             [--threads N] [--net] [--no-verify]
            (3-D pencil-decomposition FFT on a PrxPc process grid:
             FFT(z) → row-comm transpose → FFT(y) → column-comm
             transpose → FFT(x); constraints Pr|n0, Pr|n1, Pc|n1, Pc|n2;
             --domain real additionally needs even n2 with n2/2
             divisible by Pc)
  repro baseline [--rows N] [--cols N] [--nodes N] [--threads N] [--net]
  repro bench chunk-size      [--quick] [--reps N] [--out DIR]
                              [--chunk-bytes N] [--inflight N]
                              [--exec blocking|async]
  repro bench strong-scaling  --variant all-to-all|scatter
                              [--quick] [--reps N] [--grid N] [--out DIR]
                              [--exec blocking|async]
  repro bench fig6            [--quick] [--reps N] [--grid3 N0xN1xN2]
                              [--shapes 1x4,2x2,4x1] [--threads N]
                              [--out DIR] [--chunk-bytes N] [--inflight N]
                              (sweeps every shape × port × exec mode)
  repro bench fig7            [--quick] [--reps N] [--grid N] [--out DIR]
                              [--threads N] [--chunk-bytes N] [--inflight N]
                              (real-vs-complex sweep: every port × exec
                               mode × domain, with measured wire bytes;
                               writes fig7_real.csv)
  repro bench collectives     [--nodes N] [--bytes N] [--reps N]
                              [--chunk-bytes N] [--inflight N]
  repro simulate [--grid N] [--port tcp|mpi|lci] [--domain complex|real]
                 [--variant all-to-all|scatter|fftw3] [--nodes-list 1,2,4,8,16]
  repro help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        None | Some("help") => {
            print!("{HELP}");
            Ok(())
        }
        Some("info") => cmd_info(),
        Some("fft") => cmd_fft(&args),
        Some("fft3") => cmd_fft3(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("bench") => match args.positional.get(1).map(|s| s.as_str()) {
            Some("chunk-size") => cmd_bench_chunk(&args),
            Some("strong-scaling") => cmd_bench_scaling(&args),
            Some("fig6") | Some("pencil") => cmd_bench_fig6(&args),
            Some("fig7") | Some("real") => cmd_bench_fig7(&args),
            Some("collectives") => cmd_bench_collectives(&args),
            other => bail!("unknown bench target {other:?}; see `repro help`"),
        },
        Some("simulate") => cmd_simulate(&args),
        Some(other) => bail!("unknown subcommand {other:?}; see `repro help`"),
    }
}

fn cmd_info() -> Result<()> {
    let spec = ClusterSpec::buran();
    println!("Reproduction target (paper Fig. 2):\n");
    print!("{}", spec.render());

    println!("\nParcelport cost models (calibrated, DESIGN.md §6):\n");
    let mut t = hpx_fft::metrics::table::Table::new(&[
        "port", "sw overhead", "protocol copies", "eager limit", "rdv RTTs",
    ]);
    for port in PortKind::ALL {
        let c = port.cost_model();
        t.row(&[
            port.name().into(),
            format!("{} µs", c.sw_overhead_us),
            c.protocol_copies.to_string(),
            if c.eager_threshold == u64::MAX {
                "∞".into()
            } else {
                fig3::human_bytes(c.eager_threshold)
            },
            c.rendezvous_rtts.to_string(),
        ]);
    }
    print!("{}", t.render());

    println!("\nAOT artifacts:");
    match hpx_fft::runtime::load_manifest("artifacts") {
        Ok(entries) => {
            for e in entries {
                println!("  {:?} {}×{} — {}", e.kind, e.dim0, e.dim1, e.path.display());
            }
        }
        Err(e) => println!("  (none: {e})"),
    }
    Ok(())
}

fn parse_engine(args: &Args) -> Result<ComputeEngine> {
    match args.get("engine").unwrap_or("native") {
        "native" => Ok(ComputeEngine::Native),
        "pjrt" => {
            Ok(ComputeEngine::Pjrt(args.get("artifacts").unwrap_or("artifacts").to_string()))
        }
        other => bail!("unknown engine {other:?} (native|pjrt)"),
    }
}

/// Parse the `--chunk-bytes` / `--inflight` pair into a [`ChunkPolicy`],
/// rejecting zeros here — at parse time, with the flag named — instead
/// of letting them reach the wire protocol's clamp.
fn parse_chunk_policy(args: &Args) -> Result<ChunkPolicy> {
    let default = ChunkPolicy::default();
    let chunk_bytes: usize = args.get_or("chunk-bytes", default.chunk_bytes)?;
    let inflight: usize = args.get_or("inflight", default.inflight)?;
    anyhow::ensure!(
        chunk_bytes > 0,
        "--chunk-bytes must be ≥ 1 (a zero wire chunk can never carry data; \
         the default is {} bytes)",
        default.chunk_bytes
    );
    anyhow::ensure!(
        inflight > 0,
        "--inflight must be ≥ 1 (zero in-flight chunks would stall every \
         transfer; the default is {})",
        default.inflight
    );
    Ok(ChunkPolicy::new(chunk_bytes, inflight))
}

fn cmd_fft(args: &Args) -> Result<()> {
    args.check_known(&[
        "rows", "cols", "nodes", "port", "variant", "exec", "domain", "algo", "chunk-bytes",
        "inflight", "threads", "engine", "artifacts", "net", "no-verify",
    ])?;
    let config = DistFftConfig {
        rows: args.get_or("rows", 256usize)?,
        cols: args.get_or("cols", 256usize)?,
        localities: args.get_or("nodes", 4usize)?,
        port: args.get_or("port", PortKind::Lci)?,
        variant: args.get_or("variant", Variant::Scatter)?,
        algo: args.get_or("algo", AllToAllAlgo::HpxRoot)?,
        chunk: parse_chunk_policy(args)?,
        exec: args.get_or("exec", ExecutionMode::Blocking)?,
        domain: args.get_or("domain", Domain::Complex)?,
        threads_per_locality: args.get_or("threads", 2usize)?,
        net: args.get_bool("net").then(NetModel::infiniband_hdr),
        engine: parse_engine(args)?,
        verify: !args.get_bool("no-verify"),
    };
    let report = driver::run(&config)?;
    println!("{}", report.config_summary);
    let cp = report.critical_path;
    println!(
        "critical path: total {:.2} ms  (fft1 {:.2} | comm {:.2} | transpose {:.2} | fft2 {:.2})",
        cp.total_us / 1e3,
        cp.fft1_us / 1e3,
        cp.comm_us / 1e3,
        cp.transpose_us / 1e3,
        cp.fft2_us / 1e3
    );
    if config.exec == ExecutionMode::Async {
        println!(
            "overlap: {} of compute ran while collective traffic was in flight",
            hpx_fft::metrics::table::fmt_us(cp.overlap_us)
        );
    }
    println!(
        "traffic: {} msgs, {} bytes, {} copies ({} B copied), {} rendezvous",
        report.stats.msgs_sent,
        report.stats.bytes_sent,
        report.stats.payload_copies,
        report.stats.bytes_copied,
        report.stats.rendezvous_handshakes
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

fn cmd_fft3(args: &Args) -> Result<()> {
    args.check_known(&[
        "grid3", "proc-grid", "port", "exec", "domain", "chunk-bytes", "inflight", "threads",
        "net", "no-verify",
    ])?;
    let config = Pencil3Config {
        grid: args.get_or("grid3", Grid3::new(32, 32, 32))?,
        proc: args.get_or("proc-grid", ProcGrid::new(2, 2))?,
        port: args.get_or("port", PortKind::Lci)?,
        chunk: parse_chunk_policy(args)?,
        exec: args.get_or("exec", ExecutionMode::Blocking)?,
        domain: args.get_or("domain", Domain::Complex)?,
        threads_per_locality: args.get_or("threads", 2usize)?,
        net: args.get_bool("net").then(NetModel::infiniband_hdr),
        engine: ComputeEngine::Native,
        verify: !args.get_bool("no-verify"),
    };
    let report = pencil::run(&config)?;
    println!("{}", report.config_summary);
    let cp = report.critical_path;
    println!(
        "critical path: total {:.2} ms  (fftz {:.2} | t1 {:.2} (place {:.2}) | \
         ffty {:.2} | t2 {:.2} (place {:.2}) | fftx {:.2})",
        cp.total_us / 1e3,
        cp.fft_z_us / 1e3,
        cp.t1_comm_us / 1e3,
        cp.t1_place_us / 1e3,
        cp.fft_y_us / 1e3,
        cp.t2_comm_us / 1e3,
        cp.t2_place_us / 1e3,
        cp.fft_x_us / 1e3
    );
    if config.exec == ExecutionMode::Async {
        println!(
            "overlap: {} of compute ran while transpose traffic was in flight",
            hpx_fft::metrics::table::fmt_us(cp.overlap_us)
        );
    }
    println!(
        "traffic: {} msgs, {} bytes, {} copies ({} B copied), {} rendezvous",
        report.stats.msgs_sent,
        report.stats.bytes_sent,
        report.stats.payload_copies,
        report.stats.bytes_copied,
        report.stats.rendezvous_handshakes
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    args.check_known(&["rows", "cols", "nodes", "threads", "net", "no-verify"])?;
    let config = FftwLikeConfig {
        rows: args.get_or("rows", 256usize)?,
        cols: args.get_or("cols", 256usize)?,
        localities: args.get_or("nodes", 4usize)?,
        threads: args.get_or("threads", 2usize)?,
        net: args.get_bool("net").then(NetModel::infiniband_hdr),
        verify: !args.get_bool("no-verify"),
    };
    let report = fftw_like::run(&config)?;
    let cp = report.critical_path;
    println!(
        "fftw3-like baseline: total {:.2} ms  (fft1 {:.2} | comm {:.2} | transpose {:.2} | fft2 {:.2})",
        cp.total_us / 1e3,
        cp.fft1_us / 1e3,
        cp.comm_us / 1e3,
        cp.transpose_us / 1e3,
        cp.fft2_us / 1e3
    );
    match report.rel_error {
        Some(err) if err < 1e-3 => println!("verification: OK (rel L2 err {err:.2e})"),
        Some(err) => bail!("verification FAILED: rel L2 err {err:.2e}"),
        None => println!("verification: skipped"),
    }
    Ok(())
}

fn bench_config(args: &Args) -> Result<BenchConfig> {
    let mut cfg = if args.get_bool("quick") { BenchConfig::quick() } else { BenchConfig::default() };
    // Config file first, explicit CLI flags override it.
    if let Some(path) = args.get("config") {
        cfg.apply_file(path)?;
    }
    cfg.reps = args.get_or("reps", cfg.reps)?;
    cfg.live_grid = args.get_or("grid", cfg.live_grid)?;
    cfg.threads = args.get_or("threads", cfg.threads)?;
    cfg.exec = args.get_or("exec", cfg.exec)?;
    cfg.pipeline.chunk_bytes = args.get_or("chunk-bytes", cfg.pipeline.chunk_bytes)?;
    cfg.pipeline.inflight = args.get_or("inflight", cfg.pipeline.inflight)?;
    anyhow::ensure!(
        cfg.pipeline.chunk_bytes > 0 && cfg.pipeline.inflight > 0,
        "--chunk-bytes/--inflight must be positive"
    );
    if let Some(out) = args.get("out") {
        cfg.out_dir = out.to_string();
    }
    Ok(cfg)
}

fn cmd_bench_chunk(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight", "exec",
    ])?;
    let cfg = bench_config(args)?;
    println!(
        "Fig. 3 sweep ({} exec): {} reps/point, chunk sizes {:?}\n",
        cfg.exec.name(),
        cfg.reps,
        cfg.chunk_sizes
    );
    let points = fig3::run(&cfg)?;
    print!("{}", fig3::report(&points, &cfg.out_dir)?);
    println!("CSV written to {}/fig3_chunk_size.csv", cfg.out_dir);
    Ok(())
}

fn cmd_bench_scaling(args: &Args) -> Result<()> {
    args.check_known(&[
        "variant", "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight",
        "exec",
    ])?;
    let variant: Variant = args.get_or("variant", Variant::Scatter)?;
    let cfg = bench_config(args)?;
    println!(
        "strong scaling ({}, {} exec): live {}² on {:?} localities, sim {}² on {:?} nodes, {} reps\n",
        variant.name(),
        cfg.exec.name(),
        cfg.live_grid,
        cfg.live_nodes,
        cfg.sim_grid,
        cfg.sim_nodes,
        cfg.reps
    );
    let points = fig45::run(&cfg, variant)?;
    print!("{}", fig45::report(&points, variant, &cfg, &cfg.out_dir)?);
    Ok(())
}

fn cmd_bench_fig6(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid3", "shapes", "threads", "out", "config", "chunk-bytes",
        "inflight",
    ])?;
    let mut cfg = bench_config(args)?;
    cfg.grid3 = args.get_or("grid3", cfg.grid3)?;
    if let Some(s) = args.get("shapes") {
        cfg.proc_shapes = s
            .split(',')
            .map(|t| t.trim().parse::<ProcGrid>().map_err(anyhow::Error::msg))
            .collect::<Result<_>>()?;
    }
    let shapes: Vec<String> = cfg.proc_shapes.iter().map(|p| p.to_string()).collect();
    println!(
        "fig6 sweep: {} grid, shapes [{}], {} reps/point, all ports, blocking + async\n",
        cfg.grid3,
        shapes.join(", "),
        cfg.reps
    );
    let points = fig6::run(&cfg)?;
    print!("{}", fig6::report(&points, &cfg, &cfg.out_dir)?);
    println!("CSV written to {}/fig6_pencil.csv", cfg.out_dir);
    Ok(())
}

fn cmd_bench_fig7(args: &Args) -> Result<()> {
    args.check_known(&[
        "quick", "reps", "grid", "threads", "out", "config", "chunk-bytes", "inflight",
    ])?;
    let cfg = bench_config(args)?;
    println!(
        "fig7 sweep: {0}×{0} grid, {1} localities, all ports, blocking + async, \
         complex + real domains, {2} reps/point\n",
        cfg.live_grid,
        fig7::FIG7_NODES,
        cfg.reps
    );
    let points = fig7::run(&cfg)?;
    print!("{}", fig7::report(&points, &cfg, &cfg.out_dir)?);
    println!("CSV written to {}/fig7_real.csv", cfg.out_dir);
    Ok(())
}

/// Direct access to the cluster-scale DES: per-node-count makespan,
/// comm-blocked time, and wire volume for one system (the numbers behind
/// the Figs. 4/5 series, with the breakdown the figures hide).
fn cmd_simulate(args: &Args) -> Result<()> {
    use hpx_fft::simnet::fft_model::{predict_fft, FftModelParams, ModelVariant};
    args.check_known(&["grid", "port", "variant", "domain", "nodes-list"])?;
    let grid: usize = args.get_or("grid", 1usize << 14)?;
    let port: PortKind = args.get_or("port", PortKind::Lci)?;
    let domain: Domain = args.get_or("domain", Domain::Complex)?;
    let variant = match args.get("variant").unwrap_or("scatter") {
        "scatter" => ModelVariant::Scatter,
        "all-to-all" | "a2a" => ModelVariant::AllToAll(AllToAllAlgo::HpxRoot),
        "fftw3" => ModelVariant::FftwBaseline,
        other => bail!("unknown variant {other:?} (scatter|all-to-all|fftw3)"),
    };
    let nodes_list: Vec<usize> = args
        .get("nodes-list")
        .unwrap_or("1,2,4,8,16")
        .split(',')
        .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--nodes-list: {e}")))
        .collect::<Result<_>>()?;

    let spec = ClusterSpec::buran();
    println!(
        "simnet: {grid}×{grid} grid, {port} port, {variant:?}, {} domain, \
         buran wire+compute model\n",
        domain.name()
    );
    let mut t = hpx_fft::metrics::table::Table::new(&[
        "nodes", "makespan", "max blocked (comm)", "wire bytes", "chunk",
    ]);
    for nodes in nodes_list {
        anyhow::ensure!(grid % nodes == 0, "grid {grid} not divisible by {nodes} nodes");
        if domain == Domain::Real {
            anyhow::ensure!(
                grid % 2 == 0 && (grid / 2) % nodes == 0,
                "real-domain grid {grid}: packed spectrum {} must divide by {nodes} nodes",
                grid / 2
            );
        }
        let params = FftModelParams {
            rows: grid,
            cols: grid,
            nodes,
            domain,
            compute: spec.compute_model(),
            net: spec.net_model(),
        };
        let r = predict_fft(&params, port, variant);
        let blocked = r.node_blocked_us.iter().copied().fold(0.0, f64::max);
        t.row(&[
            nodes.to_string(),
            format!("{:.1} ms", r.makespan_us / 1e3),
            format!("{:.1} ms", blocked / 1e3),
            format!("{}", r.wire_bytes),
            fig3::human_bytes(params.chunk_bytes()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// Extra ablation: compare all-to-all algorithms head to head (the
/// design-choice study DESIGN.md calls out).
fn cmd_bench_collectives(args: &Args) -> Result<()> {
    args.check_known(&["nodes", "bytes", "reps", "port", "chunk-bytes", "inflight"])?;
    let nodes: usize = args.get_or("nodes", 4usize)?;
    let bytes: usize = args.get_or("bytes", 256 * 1024usize)?;
    let reps: usize = args.get_or("reps", 20usize)?;
    let port: PortKind = args.get_or("port", PortKind::Lci)?;
    let policy = parse_chunk_policy(args)?;
    let cluster = Cluster::new(nodes, port, Some(NetModel::infiniband_hdr()))?;
    println!(
        "all-to-all ablation: {nodes} localities, {} per chunk, {port} port, \
         pipeline {} × {} in flight\n",
        fig3::human_bytes(bytes as u64),
        fig3::human_bytes(policy.chunk_bytes as u64),
        policy.inflight
    );
    let mut t = hpx_fft::metrics::table::Table::new(&["algorithm", "mean", "±95% CI"]);
    for algo in AllToAllAlgo::ALL {
        let stats = measure(2, reps, || {
            let times = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(policy);
                // The futures engine drives every algorithm through the
                // send pool; spawn it outside the timed region.
                comm.warm_chunk_pool();
                let chunks: Vec<Payload> =
                    (0..nodes).map(|_| Payload::new(vec![0u8; bytes])).collect();
                let t0 = std::time::Instant::now();
                let _ = comm.all_to_all(chunks, algo);
                t0.elapsed().as_secs_f64() * 1e6
            });
            times.into_iter().fold(0.0, f64::max)
        });
        t.row(&[
            algo.name().into(),
            format!("{:.1} µs", stats.mean()),
            format!("{:.1}", stats.ci95()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
