//! API-compatible stand-in for [`ComputeService`]/[`PjrtRowFft`] when the
//! crate is built **without** the `pjrt` feature.
//!
//! The real service (`service.rs`) owns an `xla::PjRtClient`, which needs
//! the `xla` crate and an XLA installation — neither available in the
//! offline build image. This stub keeps the public surface identical so
//! the CLI, driver, and examples compile unchanged; every constructor
//! fails with a clear message, and code paths gated on
//! `artifacts/manifest.txt` (the tests, `examples/end_to_end.rs`) skip
//! before ever reaching it.

use crate::dist_fft::driver::RowFft;
use crate::fft::complex::Complex32;
use anyhow::{bail, Result};
use std::sync::Arc;

type Planes = (Vec<f32>, Vec<f32>);

const UNAVAILABLE: &str = "PJRT support is not compiled in: rebuild with `--features pjrt` \
     (requires the `xla` crate and an XLA toolchain)";

/// Stub handle; construction always fails.
pub struct ComputeService {}

impl ComputeService {
    /// Always fails: PJRT is not compiled in.
    pub fn start(_dir: impl AsRef<std::path::Path>) -> Result<Arc<Self>> {
        bail!(UNAVAILABLE)
    }

    /// Always fails: PJRT is not compiled in.
    pub fn shared(_dir: &str) -> Result<Arc<Self>> {
        bail!(UNAVAILABLE)
    }

    /// Compiled shapes for `kind` (always empty in the stub).
    pub fn shapes(&self, _kind: super::artifact::ArtifactKind) -> Vec<(usize, usize)> {
        Vec::new()
    }

    /// Always fails: PJRT is not compiled in.
    pub fn fft_rows(
        &self,
        _batch: usize,
        _len: usize,
        _re: Vec<f32>,
        _im: Vec<f32>,
    ) -> Result<Planes> {
        bail!(UNAVAILABLE)
    }

    /// Always fails: PJRT is not compiled in.
    pub fn fft2_transposed(
        &self,
        _rows: usize,
        _cols: usize,
        _re: Vec<f32>,
        _im: Vec<f32>,
    ) -> Result<Planes> {
        bail!(UNAVAILABLE)
    }
}

/// Stub engine; construction always fails, so [`RowFft`] is never invoked.
pub struct PjrtRowFft {}

impl PjrtRowFft {
    /// Always fails: PJRT is not compiled in.
    pub fn new(_dir: &str) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

impl RowFft for PjrtRowFft {
    fn fft_rows(&self, _data: &mut [Complex32], _row_len: usize, _nthreads: usize) {
        unreachable!("stub PjrtRowFft cannot be constructed")
    }

    fn name(&self) -> &'static str {
        "pjrt-unavailable"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_missing_feature() {
        let err = ComputeService::shared("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(PjrtRowFft::new("artifacts").is_err());
    }
}
