//! `FftService` — a resident, multi-tenant distributed-FFT scheduler.
//!
//! The figure harnesses run one transform at a time on a throwaway
//! cluster. This module keeps one fabric *resident* and schedules many
//! concurrent transform jobs over it, the way an HPX application keeps
//! its runtime alive across task graphs:
//!
//! - **One fabric, many jobs.** The service owns a parcelport fabric
//!   and one long-lived *world* communicator per locality, driven by a
//!   pinned worker thread. Every accepted job is dispatched by
//!   splitting the world ([`Communicator::split_with_span`]) into a
//!   per-job sub-communicator with a disjoint tag space, then wrapped
//!   in a stats scope ([`Communicator::with_stats_scope`]) so its wire
//!   bytes are attributed to the submitting tenant.
//! - **Dataflow job nodes.** A submission becomes a
//!   [`JobEntry`](super::job) that traverses `Queued → Dispatched →
//!   Running → Completed/Failed`; the caller holds a [`JobHandle`]
//!   future. Mixed shapes (2-D slab / 3-D pencil), domains
//!   (complex/real), and execution modes (blocking/async) coexist on
//!   the same fabric.
//! - **Admission control.** Per-tenant queues are bounded
//!   ([`ServiceConfig::queue_limit`]); overflow, oversized transforms,
//!   invalid requests, and submissions during drain are rejected with
//!   a typed [`AdmissionError`] instead of panicking. A rank panic
//!   inside a job (tag-space exhaustion included) fails *that job's*
//!   handle and leaves the service running.
//! - **Shared infrastructure.** Row-FFT plan caches are process-global
//!   already; chunk/shadow send pools are *leased* to a job's ranks for
//!   the job's duration and returned for reuse, so worker threads
//!   amortize across thousands of jobs. Pools are never shared by two
//!   concurrent jobs: a pool runs offloaded blocking collectives, and
//!   two jobs interleaving those on one pool can deadlock (job A's
//!   collective queued behind job B's blocked one on one rank, the
//!   reverse on another).
//!
//! Dispatch order is the admission order, identical on every worker:
//! the split that carves a job's sub-communicator is a collective over
//! the world, so all workers must reach it in lock-step. The first
//! worker with a free inflight slot opens a job's dispatch gate; the
//! remaining workers follow the gate unconditionally, which keeps the
//! order deterministic without a central dispatcher thread.
//!
//! Tag budget: by default each job's split carves
//! [`crate::collectives::tags::SPLIT_TAG_SPAN`] (2⁴⁸) tags from the
//! world's 2⁶⁴ counter, so a service instance admits ~65 000 jobs over
//! its lifetime — far beyond any benchmark run. Set
//! [`ServiceConfig::job_tag_span`] to trade per-job headroom for job
//! count (or, in tests, to provoke in-job exhaustion cheaply).

use super::job::{
    AdmissionError, JobEntry, JobError, JobHandle, JobOutput, JobPlan, JobState, RankTimings,
};
use crate::collectives::Communicator;
use crate::dist_fft::driver::{self, RowFft, StepTimings};
use crate::dist_fft::pencil::{self, PencilTimings};
use crate::dist_fft::{TransformReport, TransformRequest, TransformTimings};
use crate::fft::complex::Complex32;
use crate::hpx::parcel::Tag;
use crate::metrics::RunStats;
use crate::obs::{Histogram, MetricsRegistry};
use crate::parcelport::{
    self, FaultSpec, FaultyPort, NetModel, Parcelport, PortKind, PortStats, PortStatsSnapshot,
};
use crate::task::{Promise, ThreadPool};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Configuration of an [`FftService`] instance.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Localities the resident fabric spans (jobs may use fewer).
    pub localities: usize,
    /// Parcelport backend of the resident fabric.
    pub port: PortKind,
    /// Optional hybrid wire model applied to the fabric.
    pub net: Option<NetModel>,
    /// Per-tenant bound on queued-or-running jobs; submissions beyond
    /// it are rejected with [`AdmissionError::QueueFull`].
    pub queue_limit: usize,
    /// Service-wide bound on concurrently executing jobs.
    pub max_inflight: usize,
    /// Tag-space grant per job (`None`: the default split span, 2⁴⁸).
    pub job_tag_span: Option<Tag>,
    /// Optional fault injection on the resident fabric
    /// ([`FaultyPort`] decorator: seeded delayed chunks and slow
    /// ranks). Jobs must still complete or fail typed — never hang.
    pub fault: Option<FaultSpec>,
}

impl Default for ServiceConfig {
    /// 4 localities on the LCI port, 64-job tenant queues, 4 jobs in
    /// flight — the load-generator defaults.
    fn default() -> Self {
        Self {
            localities: 4,
            port: PortKind::Lci,
            net: None,
            queue_limit: 64,
            max_inflight: 4,
            job_tag_span: None,
            fault: None,
        }
    }
}

/// Per-tenant bookkeeping (guarded by the scheduler mutex).
#[derive(Default)]
struct TenantAccount {
    submitted: u64,
    completed: u64,
    failed: u64,
    rejected: u64,
    pending: usize,
    wire_bytes: u64,
    latencies_us: Vec<f64>,
    latency_hist: Histogram,
}

/// One tenant's slice of [`FftService::metrics`].
#[derive(Clone, Debug)]
pub struct TenantMetrics {
    /// Tenant name.
    pub tenant: String,
    /// Total `submit` calls (accepted + rejected).
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that failed (a rank panicked).
    pub failed: u64,
    /// Submissions rejected by admission control.
    pub rejected: u64,
    /// Jobs currently queued or running.
    pub pending: usize,
    /// Scoped wire bytes over all finished jobs.
    pub wire_bytes: u64,
    /// Submit-to-completion latencies (µs) of finished jobs — p50/p95/
    /// p99 via [`RunStats::percentile`]. `None` until a job finishes.
    pub latency: Option<RunStats>,
    /// The same latencies as an exponential-bucket [`Histogram`] — the
    /// shared quantile path (`p50 ≤ p95 ≤ p99` holds by construction),
    /// and what [`FftService::metrics_text`] renders.
    pub latency_hist: Histogram,
}

/// Scheduler state (one mutex; the condvar signals every transition).
struct SchedState {
    /// Append-only dispatch log. Workers walk it by cursor, so every
    /// rank splits the world for every job in the same order.
    jobs: Vec<Arc<JobEntry>>,
    next_id: u64,
    draining: bool,
    paused: bool,
    inflight: usize,
    finished: usize,
    tenants: BTreeMap<String, TenantAccount>,
}

/// An idle chunk/shadow pool pair, keyed by worker width.
struct PoolLease {
    width: usize,
    chunk: Arc<ThreadPool>,
    shadow: Arc<ThreadPool>,
}

/// State shared between the service handle, its workers, and job rank
/// threads.
struct Shared {
    config: ServiceConfig,
    state: Mutex<SchedState>,
    cv: Condvar,
    pools: Mutex<Vec<PoolLease>>,
    /// Live service metrics — counters, gauges, and latency histograms
    /// keyed `family{tenant="..."}`, rendered by
    /// [`FftService::metrics_text`]. A leaf lock: it is only ever taken
    /// while (optionally) holding the scheduler mutex, never the other
    /// way around.
    registry: MetricsRegistry,
}

/// A validated submission, ready to enter the dispatch log.
struct Prepared {
    plan: JobPlan,
    engine: Arc<dyn RowFft + Send>,
    collect_outputs: bool,
}

/// The resident multi-tenant FFT scheduler (see the [module docs]).
///
/// Dropping the service drains it: accepted jobs run to completion
/// first ([`shutdown`](Self::shutdown) does the same and returns the
/// final per-tenant metrics).
///
/// [module docs]: self
pub struct FftService {
    shared: Arc<Shared>,
    fabric: Arc<dyn Parcelport>,
    workers: Vec<JoinHandle<()>>,
}

impl FftService {
    /// Build the fabric and start one worker thread per locality.
    pub fn new(config: ServiceConfig) -> anyhow::Result<FftService> {
        anyhow::ensure!(config.localities >= 1, "service needs at least one locality");
        anyhow::ensure!(config.queue_limit >= 1, "queue_limit must be at least 1");
        anyhow::ensure!(config.max_inflight >= 1, "max_inflight must be at least 1");
        if let Some(span) = config.job_tag_span {
            anyhow::ensure!(span > 0, "job_tag_span must be positive");
        }
        let fabric = parcelport::build(config.port, config.localities, config.net)?;
        let fabric: Arc<dyn Parcelport> = match config.fault {
            Some(spec) => FaultyPort::wrap(fabric, spec),
            None => fabric,
        };
        let n = config.localities;
        let shared = Arc::new(Shared {
            config,
            state: Mutex::new(SchedState {
                jobs: Vec::new(),
                next_id: 0,
                draining: false,
                paused: false,
                inflight: 0,
                finished: 0,
                tenants: BTreeMap::new(),
            }),
            cv: Condvar::new(),
            pools: Mutex::new(Vec::new()),
            registry: MetricsRegistry::new(),
        });
        let workers = (0..n)
            .map(|rank| {
                let shared = Arc::clone(&shared);
                let fabric = Arc::clone(&fabric);
                std::thread::Builder::new()
                    .name(format!("fft-svc-{rank}"))
                    .spawn(move || worker_loop(rank, n, fabric, shared))
                    .expect("spawn service worker")
            })
            .collect();
        Ok(FftService { shared, fabric, workers })
    }

    /// Localities the resident fabric spans.
    pub fn localities(&self) -> usize {
        self.shared.config.localities
    }

    /// Parcelport backend of the resident fabric.
    pub fn port(&self) -> PortKind {
        self.shared.config.port
    }

    /// Fabric-global traffic counters (all tenants; protocol overheads
    /// included). Per-job counters live in each job's report.
    pub fn fabric_stats(&self) -> PortStatsSnapshot {
        self.fabric.stats()
    }

    /// Submit a transform under `tenant`. Returns the job's handle, or
    /// a typed rejection — never panics, never blocks on FFT work.
    pub fn submit(
        &self,
        tenant: &str,
        request: TransformRequest,
    ) -> Result<JobHandle, AdmissionError> {
        // Validate / build engines outside the scheduler lock.
        let prepared = self.prepare(request);
        let limit = self.shared.config.queue_limit;
        let mut st = self.shared.state.lock().unwrap();
        let draining = st.draining;
        let acct = st.tenants.entry(tenant.to_string()).or_default();
        acct.submitted += 1;
        self.shared.registry.add(&tenant_key("fft_jobs_submitted_total", tenant), 1);
        if draining {
            acct.rejected += 1;
            self.shared.registry.add(&tenant_key("fft_jobs_rejected_total", tenant), 1);
            return Err(AdmissionError::ShuttingDown);
        }
        let prepared = match prepared {
            Ok(p) => p,
            Err(e) => {
                acct.rejected += 1;
                self.shared.registry.add(&tenant_key("fft_jobs_rejected_total", tenant), 1);
                return Err(e);
            }
        };
        if acct.pending >= limit {
            acct.rejected += 1;
            self.shared.registry.add(&tenant_key("fft_jobs_rejected_total", tenant), 1);
            return Err(AdmissionError::QueueFull { tenant: tenant.to_string(), limit });
        }
        acct.pending += 1;
        let pending = acct.pending;
        self.shared.registry.set_gauge(&tenant_key("fft_jobs_pending", tenant), pending as f64);
        let id = st.next_id;
        st.next_id += 1;
        crate::obs::instant_args(
            "job",
            "submit",
            crate::obs::SERVICE_RANK,
            id as i64,
            crate::obs::NO_ARG,
            crate::obs::NO_ARG,
        );
        let (promise, future) = Promise::new();
        st.jobs.push(Arc::new(JobEntry::new(
            id,
            tenant.to_string(),
            prepared.plan,
            prepared.engine,
            prepared.collect_outputs,
            promise,
        )));
        drop(st);
        self.shared.cv.notify_all();
        Ok(JobHandle { id, tenant: tenant.to_string(), future })
    }

    /// Stop opening new dispatch gates (running jobs continue). Makes
    /// queue-level admission behavior deterministic in tests.
    pub fn pause(&self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    /// Resume dispatching after [`pause`](Self::pause).
    pub fn resume(&self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.cv.notify_all();
    }

    /// Per-tenant metrics snapshot, tenant-name order.
    pub fn metrics(&self) -> Vec<TenantMetrics> {
        let st = self.shared.state.lock().unwrap();
        st.tenants
            .iter()
            .map(|(name, a)| TenantMetrics {
                tenant: name.clone(),
                submitted: a.submitted,
                completed: a.completed,
                failed: a.failed,
                rejected: a.rejected,
                pending: a.pending,
                wire_bytes: a.wire_bytes,
                latency: (!a.latencies_us.is_empty())
                    .then(|| RunStats::new(a.latencies_us.clone())),
                latency_hist: a.latency_hist.clone(),
            })
            .collect()
    }

    /// Prometheus-style text snapshot of the live metrics registry —
    /// per-tenant job counters, pending/inflight gauges, and latency
    /// histograms. This is what the `metrics` verb of `repro serve`
    /// answers with.
    pub fn metrics_text(&self) -> String {
        self.shared.registry.render()
    }

    /// Graceful drain: reject new submissions, run every accepted job
    /// to completion, stop the workers, and return the final metrics.
    pub fn shutdown(mut self) -> Vec<TenantMetrics> {
        self.drain();
        self.metrics()
    }

    /// Validate a request against the service fabric and freeze it into
    /// a dispatchable plan.
    fn prepare(&self, request: TransformRequest) -> Result<Prepared, AdmissionError> {
        let transform = request.build().map_err(AdmissionError::Invalid)?;
        let needed = transform.localities();
        let available = self.shared.config.localities;
        if needed > available {
            return Err(AdmissionError::TooLarge { needed, available });
        }
        if transform.port() != self.shared.config.port {
            return Err(AdmissionError::Invalid(anyhow::anyhow!(
                "request targets the {} port but the service fabric is {}; submit a matching \
                 request or start the service on that port",
                transform.port(),
                self.shared.config.port
            )));
        }
        let (plan, engine) = if let Some(config) = transform.plane_config() {
            let engine = config.engine.build().map_err(AdmissionError::Invalid)?;
            (JobPlan::Plane(config.clone()), engine)
        } else {
            let config = transform.pencil_config().expect("transform is plane or pencil").clone();
            let (dims_in, dims) =
                pencil::validate_config(&config).map_err(AdmissionError::Invalid)?;
            let engine = config.engine.build().map_err(AdmissionError::Invalid)?;
            (JobPlan::Pencil { config, dims_in, dims }, engine)
        };
        Ok(Prepared { plan, engine, collect_outputs: transform.collects_outputs() })
    }

    /// Drain and join the workers (idempotent; called by `shutdown` and
    /// `Drop`).
    fn drain(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.draining = true;
            // A paused service must still drain.
            st.paused = false;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers exit only after dispatching every logged job; now wait
        // for the in-flight rank threads to deliver their reports.
        let mut st = self.shared.state.lock().unwrap();
        while st.finished < st.jobs.len() {
            st = self.shared.cv.wait(st).unwrap();
        }
    }
}

impl Drop for FftService {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.drain();
        }
    }
}

/// One pinned worker per locality: walk the dispatch log in admission
/// order, split the world for every job (collective — all workers must
/// do this in lock-step), and hand participating ranks to job threads.
fn worker_loop(rank: usize, n: usize, fabric: Arc<dyn Parcelport>, shared: Arc<Shared>) {
    let world = Communicator::new(fabric, rank, n);
    let mut cursor = 0usize;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if cursor < st.jobs.len() {
                    // Another worker already opened this job's gate —
                    // follow it unconditionally to keep dispatch order
                    // identical on every rank.
                    if st.jobs[cursor].dispatch_open.load(Ordering::Acquire) {
                        break Arc::clone(&st.jobs[cursor]);
                    }
                    if !st.paused && st.inflight < shared.config.max_inflight {
                        st.inflight += 1;
                        shared.registry.set_gauge("fft_jobs_inflight", st.inflight as f64);
                        let entry = Arc::clone(&st.jobs[cursor]);
                        entry.advance_state(JobState::Dispatched);
                        entry.dispatch_open.store(true, Ordering::Release);
                        // One dispatch instant per job (the gate opener's).
                        crate::obs::instant_args(
                            "job",
                            "dispatch",
                            crate::obs::SERVICE_RANK,
                            entry.id as i64,
                            crate::obs::NO_ARG,
                            crate::obs::NO_ARG,
                        );
                        shared.cv.notify_all();
                        break entry;
                    }
                } else if st.draining {
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        cursor += 1;
        dispatch_job(&world, rank, &job, &shared);
    }
}

/// Carve the job's sub-communicator out of the world (collective over
/// *all* workers — non-participating ranks split into a parked color
/// and return) and launch the participating rank's job thread.
fn dispatch_job(world: &Communicator, rank: usize, job: &Arc<JobEntry>, shared: &Arc<Shared>) {
    let n_job = job.plan.localities();
    let participating = rank < n_job;
    let color = u64::from(!participating);
    let sub = match shared.config.job_tag_span {
        Some(span) => world.split_with_span(color, rank as u64, span),
        None => world.split(color, rank as u64),
    };
    if !participating {
        return;
    }
    let (comm, scope) = sub.with_stats_scope();
    let width = job.plan.pool_width();
    let (chunk, shadow) = lease_pools(shared, width);
    comm.install_pools(Arc::clone(&chunk), Arc::clone(&shadow));
    let job = Arc::clone(job);
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("fft-job{}-r{rank}", job.id))
        .spawn(move || {
            // Label the leased pool pair for the duration of the rank's
            // run: if an armed conformance checker catches this thread
            // in a cross-job wait cycle, the diagnosis names the lease.
            let lease =
                crate::collectives::conformance::lease(&format!("job {} pool lease", job.id));
            run_job_rank(comm, &scope, &job, &shared);
            drop(lease);
            return_pools(&shared, width, chunk, shadow);
        })
        .expect("spawn job rank thread");
}

/// Take an idle pool pair of the right width off the registry, or spin
/// up a fresh pair. Exclusive while leased — see the module docs for
/// why concurrent jobs must not share one.
fn lease_pools(shared: &Shared, width: usize) -> (Arc<ThreadPool>, Arc<ThreadPool>) {
    let mut pools = shared.pools.lock().unwrap();
    if let Some(i) = pools.iter().position(|l| l.width == width) {
        let lease = pools.swap_remove(i);
        return (lease.chunk, lease.shadow);
    }
    drop(pools);
    (Arc::new(ThreadPool::new(width)), Arc::new(ThreadPool::new(width)))
}

/// Return a leased pool pair for the next job of the same width.
fn return_pools(shared: &Shared, width: usize, chunk: Arc<ThreadPool>, shadow: Arc<ThreadPool>) {
    shared.pools.lock().unwrap().push(PoolLease { width, chunk, shadow });
}

/// One rank's share of one job: run the transform, deposit the piece
/// into the job's rendezvous, and — on the last rank in — assemble the
/// report and fulfil the handle. Panics (FFT asserts, tag exhaustion)
/// are caught and fail the job, not the service; the SPMD lock-step
/// discipline makes every rank of the job panic at the same allocation
/// point, so no peer is left blocked on a vanished sender.
fn run_job_rank(comm: Communicator, scope: &PortStats, job: &Arc<JobEntry>, shared: &Arc<Shared>) {
    job.advance_state(JobState::Running);
    let rank = comm.rank();
    let engine = Arc::clone(&job.engine);
    let outcome = catch_unwind(AssertUnwindSafe(|| match &job.plan {
        JobPlan::Plane(config) => {
            let (piece, t) = driver::run_rank(&comm, config, engine.as_ref());
            (piece, RankTimings::Plane(t))
        }
        JobPlan::Pencil { config, dims_in, dims } => {
            let (piece, t) = pencil::run_rank(&comm, dims_in, dims, config, engine.as_ref());
            (piece, RankTimings::Pencil(t))
        }
    }));
    let snapshot = scope.snapshot();
    let n_job = job.plan.localities();
    let last_in = {
        let mut g = job.gather.lock().unwrap();
        match outcome {
            Ok((piece, t)) => {
                g.pieces[rank] = Some(piece);
                g.timings[rank] = Some(t);
            }
            Err(payload) => g.failures.push(format!("rank {rank}: {}", panic_text(&*payload))),
        }
        g.scopes[rank] = Some(snapshot);
        g.done += 1;
        g.done == n_job
    };
    if last_in {
        finish_job(job, shared);
    }
}

/// Registry key for a per-tenant metric: `family{tenant="name"}`, the
/// label-embedded form [`MetricsRegistry`] renders as Prometheus labels.
fn tenant_key(family: &str, tenant: &str) -> String {
    format!("{family}{{tenant=\"{tenant}\"}}")
}

/// Best-effort text of a caught panic payload.
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Last rank in: drain the rendezvous, assemble the report (or the
/// failure), settle the tenant's account, and fulfil the handle.
fn finish_job(job: &Arc<JobEntry>, shared: &Arc<Shared>) {
    // Pull everything out of the rendezvous, then assemble without any
    // lock held (verification reruns a serial reference transform).
    let (pieces, timings, stats, failures) = {
        let mut g = job.gather.lock().unwrap();
        let stats = sum_scopes(g.scopes.iter().flatten());
        let pieces: Vec<_> = g.pieces.iter_mut().map(Option::take).collect();
        let timings: Vec<_> = g.timings.iter_mut().map(Option::take).collect();
        (pieces, timings, stats, std::mem::take(&mut g.failures))
    };
    let result = if failures.is_empty() {
        job.advance_state(JobState::Completed);
        Ok(assemble_report(job, pieces, timings, stats))
    } else {
        job.advance_state(JobState::Failed);
        Err(JobError { job_id: job.id, message: failures.join("; ") })
    };
    let ok = result.is_ok();
    let latency_us = job.submitted.elapsed().as_secs_f64() * 1e6;
    {
        let mut st = shared.state.lock().unwrap();
        st.inflight -= 1;
        st.finished += 1;
        shared.registry.set_gauge("fft_jobs_inflight", st.inflight as f64);
        let acct = st.tenants.get_mut(&job.tenant).expect("tenant account outlives its jobs");
        acct.pending -= 1;
        if ok {
            acct.completed += 1;
        } else {
            acct.failed += 1;
        }
        acct.wire_bytes += stats.bytes_sent;
        acct.latencies_us.push(latency_us);
        acct.latency_hist.observe(latency_us);
        let tenant = &job.tenant;
        let family = if ok { "fft_jobs_completed_total" } else { "fft_jobs_failed_total" };
        shared.registry.add(&tenant_key(family, tenant), 1);
        shared.registry.add(&tenant_key("fft_wire_bytes_total", tenant), stats.bytes_sent);
        shared.registry.observe(&tenant_key("fft_job_latency_us", tenant), latency_us);
        shared.registry.set_gauge(&tenant_key("fft_jobs_pending", tenant), acct.pending as f64);
    }
    shared.cv.notify_all();
    crate::obs::instant_args(
        "job",
        if ok { "done" } else { "failed" },
        crate::obs::SERVICE_RANK,
        job.id as i64,
        crate::obs::NO_ARG,
        crate::obs::NO_ARG,
    );
    let promise = job.promise.lock().unwrap().take().expect("a job finishes exactly once");
    promise.set(result.map(|report| JobOutput { job_id: job.id, report, latency_us }));
}

/// Field-wise sum of per-rank scoped counters (only the send-side
/// fields are populated by a scope — see `parcelport::scoped`).
fn sum_scopes<'a>(parts: impl Iterator<Item = &'a PortStatsSnapshot>) -> PortStatsSnapshot {
    let mut out = PortStatsSnapshot::default();
    for s in parts {
        out.msgs_sent += s.msgs_sent;
        out.bytes_sent += s.bytes_sent;
        out.payload_copies += s.payload_copies;
        out.bytes_copied += s.bytes_copied;
        out.rendezvous_handshakes += s.rendezvous_handshakes;
        out.eager_sends += s.eager_sends;
        out.modeled_wire_us += s.modeled_wire_us;
    }
    out
}

/// Build the unified [`TransformReport`] from the ranks' deposits —
/// the same shape `Transform::run` returns, so service and single-shot
/// results are interchangeable.
fn assemble_report(
    job: &JobEntry,
    pieces: Vec<Option<Vec<Complex32>>>,
    timings: Vec<Option<RankTimings>>,
    stats: PortStatsSnapshot,
) -> TransformReport {
    let pieces: Vec<Vec<Complex32>> =
        pieces.into_iter().map(|p| p.expect("every rank deposited its piece")).collect();
    let engine = job.engine.name();
    match &job.plan {
        JobPlan::Plane(config) => {
            let per_rank: Vec<StepTimings> = timings
                .into_iter()
                .map(|t| match t.expect("every rank deposited timings") {
                    RankTimings::Plane(t) => t,
                    RankTimings::Pencil(_) => unreachable!("plane job with pencil timings"),
                })
                .collect();
            let critical_path = StepTimings::max(&per_rank);
            let rel_error = config.verify.then(|| driver::verify_pieces(config, &pieces));
            TransformReport {
                summary: driver::summary_line(config, engine),
                timings: TransformTimings::Plane { per_rank, critical_path },
                rel_error,
                stats,
                outputs: job.collect_outputs.then_some(pieces),
                trace_path: None,
            }
        }
        JobPlan::Pencil { config, dims, .. } => {
            let per_rank: Vec<PencilTimings> = timings
                .into_iter()
                .map(|t| match t.expect("every rank deposited timings") {
                    RankTimings::Pencil(t) => t,
                    RankTimings::Plane(_) => unreachable!("pencil job with plane timings"),
                })
                .collect();
            let critical_path = PencilTimings::max(&per_rank);
            let rel_error = config.verify.then(|| pencil::verify_pieces(config, dims, &pieces));
            TransformReport {
                summary: pencil::summary_line(config, engine),
                timings: TransformTimings::Pencil { per_rank, critical_path },
                rel_error,
                stats,
                outputs: job.collect_outputs.then_some(pieces),
                trace_path: None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::driver::Domain;
    use crate::dist_fft::{Grid3, ProcGrid};

    fn small_service(localities: usize) -> FftService {
        FftService::new(ServiceConfig { localities, ..ServiceConfig::default() }).unwrap()
    }

    fn small_plane(localities: usize) -> TransformRequest {
        TransformRequest::grid(16, 16).localities(localities).threads(1)
    }

    #[test]
    fn runs_one_job_end_to_end() {
        let svc = small_service(2);
        let handle = svc.submit("acme", small_plane(2)).unwrap();
        assert_eq!(handle.tenant(), "acme");
        let out = handle.wait().unwrap();
        assert!(out.report.rel_error.unwrap() < 1e-4);
        assert!(out.report.stats.bytes_sent > 0, "scoped stats must see the job's wire bytes");
        assert!(out.latency_us > 0.0);
        let metrics = svc.shutdown();
        assert_eq!(metrics.len(), 1);
        assert_eq!(metrics[0].completed, 1);
        assert_eq!(metrics[0].pending, 0);
        assert!(metrics[0].latency.as_ref().unwrap().p50() > 0.0);
    }

    #[test]
    fn mixed_shapes_and_domains_share_the_fabric() {
        let svc = small_service(4);
        let handles = vec![
            svc.submit("a", small_plane(2)).unwrap(),
            svc.submit("b", small_plane(4).domain(Domain::Real)).unwrap(),
            svc.submit(
                "c",
                TransformRequest::grid3(Grid3::new(8, 8, 8))
                    .proc_grid(ProcGrid::new(2, 2))
                    .threads(1),
            )
            .unwrap(),
        ];
        for h in handles {
            let out = h.wait().unwrap();
            assert!(out.report.rel_error.unwrap() < 1e-4, "{}", out.report.summary);
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 3);
    }

    #[test]
    fn service_output_is_bitwise_identical_to_single_shot() {
        let request = || small_plane(2).collect_outputs(true);
        let single = request().build().unwrap().run().unwrap().outputs.unwrap();
        let svc = small_service(2);
        let out = svc.submit("t", request()).unwrap().wait().unwrap();
        assert_eq!(out.report.outputs.unwrap(), single, "service must not perturb the math");
    }

    #[test]
    fn admission_rejects_oversized_invalid_and_wrong_port() {
        let svc = small_service(2);
        match svc.submit("t", small_plane(4)) {
            Err(AdmissionError::TooLarge { needed: 4, available: 2 }) => {}
            other => panic!("expected TooLarge, got {other:?}", other = other.map(|h| h.id())),
        }
        match svc.submit("t", TransformRequest::grid(30, 32)) {
            Err(AdmissionError::Invalid(_)) => {}
            other => panic!("expected Invalid, got {other:?}", other = other.map(|h| h.id())),
        }
        match svc.submit("t", small_plane(2).port(PortKind::Tcp)) {
            Err(AdmissionError::Invalid(e)) => {
                assert!(e.to_string().contains("service fabric"), "{e:#}");
            }
            other => panic!("expected Invalid, got {other:?}", other = other.map(|h| h.id())),
        }
        let m = svc.shutdown();
        assert_eq!(m[0].rejected, 3);
        assert_eq!(m[0].submitted, 3);
    }

    #[test]
    fn queue_limit_rejects_then_resume_drains() {
        let svc = FftService::new(ServiceConfig {
            localities: 2,
            queue_limit: 2,
            ..ServiceConfig::default()
        })
        .unwrap();
        svc.pause();
        let h1 = svc.submit("t", small_plane(2)).unwrap();
        let h2 = svc.submit("t", small_plane(2)).unwrap();
        match svc.submit("t", small_plane(2)) {
            Err(AdmissionError::QueueFull { limit: 2, .. }) => {}
            other => panic!("expected QueueFull, got {other:?}", other = other.map(|h| h.id())),
        }
        // While paused, nothing dispatches.
        assert!(!h1.is_done());
        {
            let st = svc.shared.state.lock().unwrap();
            assert_eq!(st.jobs[0].state(), JobState::Queued);
        }
        svc.resume();
        h1.wait().unwrap();
        h2.wait().unwrap();
        let m = svc.shutdown();
        assert_eq!((m[0].completed, m[0].rejected), (2, 1));
    }

    #[test]
    fn shutdown_rejects_new_work_but_finishes_accepted() {
        let svc = small_service(2);
        let handles: Vec<_> =
            (0..3).map(|_| svc.submit("t", small_plane(2)).unwrap()).collect();
        let metrics = svc.shutdown();
        assert_eq!(metrics[0].completed, 3);
        for h in handles {
            assert!(h.is_done());
            h.wait().unwrap();
        }
    }

    #[test]
    fn tag_exhaustion_fails_the_job_not_the_service() {
        use crate::collectives::tags::CHUNK_TAG_SPAN;
        // One chunk-tag block is far too small for a whole transform:
        // the job's ranks all trip the lock-step tag-space assertion at
        // the same allocation point, the panic is caught, and the job
        // fails cleanly.
        let svc = FftService::new(ServiceConfig {
            localities: 2,
            job_tag_span: Some(CHUNK_TAG_SPAN),
            ..ServiceConfig::default()
        })
        .unwrap();
        let err = svc.submit("t", small_plane(2)).unwrap().wait().unwrap_err();
        assert!(err.message.contains("tag space exhausted"), "{err}");
        // The service survives and the next job fails the same way
        // (the world communicator's tag space is still healthy).
        let err = svc.submit("t", small_plane(2)).unwrap().wait().unwrap_err();
        assert!(err.message.contains("tag space exhausted"), "{err}");
        let m = svc.shutdown();
        assert_eq!(m[0].failed, 2);
    }

    #[test]
    fn jobs_complete_over_a_fault_injected_fabric() {
        use crate::util::testkit::with_watchdog;
        use std::time::Duration;
        // Hostile fabric: 40% of sends delayed up to 150 µs, half the
        // localities slowed 200 µs per send. Delivery stays reliable,
        // so every job must still complete (bitwise-correct) — and must
        // do so within the watchdog bound, never hang.
        let metrics = with_watchdog("faulty-fabric jobs", Duration::from_secs(120), || {
            let svc = FftService::new(ServiceConfig {
                localities: 4,
                fault: Some(crate::parcelport::FaultSpec::hostile(11)),
                ..ServiceConfig::default()
            })
            .unwrap();
            let single = small_plane(4).collect_outputs(true).build().unwrap().run().unwrap();
            let handles: Vec<_> = (0..3)
                .map(|_| svc.submit("t", small_plane(4).collect_outputs(true)).unwrap())
                .collect();
            for h in handles {
                let out = h.wait().unwrap();
                assert_eq!(
                    out.report.outputs,
                    single.outputs,
                    "faults perturb timing, never the math"
                );
            }
            svc.shutdown()
        });
        assert_eq!(metrics[0].completed, 3);
        assert_eq!(metrics[0].failed, 0);
    }

    #[test]
    fn fault_injected_job_failure_is_typed_not_a_hang() {
        use crate::collectives::tags::CHUNK_TAG_SPAN;
        use crate::util::testkit::with_watchdog;
        use std::time::Duration;
        // Combine the hostile fabric with a starved per-job tag budget:
        // the job dies of tag exhaustion *while* sends are being
        // delayed. The failure must surface as a typed JobError within
        // the watchdog bound — the delayed schedule must not convert a
        // clean lock-step panic into a wedged peer.
        let (err, metrics) =
            with_watchdog("faulty-fabric failure", Duration::from_secs(120), || {
                let svc = FftService::new(ServiceConfig {
                    localities: 2,
                    job_tag_span: Some(CHUNK_TAG_SPAN),
                    fault: Some(crate::parcelport::FaultSpec::delayed_chunks(23)),
                    ..ServiceConfig::default()
                })
                .unwrap();
                let err = svc.submit("t", small_plane(2)).unwrap().wait().unwrap_err();
                (err, svc.shutdown())
            });
        assert!(err.message.contains("tag space exhausted"), "{err}");
        assert_eq!((metrics[0].failed, metrics[0].completed), (1, 0));
    }

    #[test]
    fn metrics_text_renders_per_tenant_counters_and_histograms() {
        let svc = small_service(2);
        svc.submit("acme", small_plane(2)).unwrap().wait().unwrap();
        let text = svc.metrics_text();
        assert!(text.contains("fft_jobs_submitted_total{tenant=\"acme\"} 1"), "{text}");
        assert!(text.contains("fft_jobs_completed_total{tenant=\"acme\"} 1"), "{text}");
        assert!(text.contains("fft_job_latency_us_count{tenant=\"acme\"} 1"), "{text}");
        assert!(text.contains("fft_wire_bytes_total{tenant=\"acme\"}"), "{text}");
        let m = svc.shutdown();
        let h = &m[0].latency_hist;
        assert_eq!(h.count(), 1);
        assert!(h.percentile(50.0) <= h.percentile(95.0));
        assert!(h.percentile(95.0) <= h.percentile(99.0));
    }

    #[test]
    fn per_tenant_metrics_separate_and_pools_are_reused() {
        let svc = small_service(2);
        let ha = svc.submit("alpha", small_plane(2)).unwrap();
        let hb = svc.submit("beta", small_plane(2)).unwrap();
        ha.wait().unwrap();
        hb.wait().unwrap();
        {
            let pools = svc.shared.pools.lock().unwrap();
            assert!(!pools.is_empty(), "finished jobs return their pool leases");
        }
        let m = svc.shutdown();
        let names: Vec<_> = m.iter().map(|t| t.tenant.as_str()).collect();
        assert_eq!(names, ["alpha", "beta"], "metrics are tenant-name ordered");
        assert!(m.iter().all(|t| t.completed == 1 && t.wire_bytes > 0));
    }
}
