//! The compute service: a dedicated thread owning the PJRT client.
//!
//! `xla::PjRtClient` and its executables hold raw C pointers and are not
//! `Send`; localities, by contrast, are OS threads. The service therefore
//! runs the PJRT stack on one dedicated thread and exposes a channel API:
//! localities ship (re, im) planes in, the service executes the matching
//! compiled artifact, planes come back. The CPU PJRT client parallelizes
//! internally (Eigen thread pool), so a single submission lane does not
//! serialize the math — it serializes only dispatch, which `benches/
//! hotpath.rs` shows is ~µs against ~ms executions.
//!
//! Services are memoized per artifact directory ([`ComputeService::shared`])
//! so repeated driver runs reuse compiled executables ("compile once,
//! execute many" — the PJRT analog of FFTW plan reuse).

use super::artifact::{load_manifest, ArtifactKind};
use crate::dist_fft::driver::RowFft;
use crate::fft::complex::Complex32;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex, OnceLock};

type Planes = (Vec<f32>, Vec<f32>);

enum Request {
    /// Execute an artifact of `kind` with shape (dim0, dim1).
    Execute {
        kind: ArtifactKind,
        dim0: usize,
        dim1: usize,
        re: Vec<f32>,
        im: Vec<f32>,
        reply: SyncSender<Result<Planes>>,
    },
    Shutdown,
}

/// Handle to the compute thread. Cheap to clone via `Arc`.
pub struct ComputeService {
    tx: Mutex<Sender<Request>>,
    /// Shapes available per kind (from the manifest).
    shapes: Vec<(ArtifactKind, usize, usize)>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ComputeService {
    /// Start a service for `dir`, compiling every artifact in its
    /// manifest. Fails fast (before returning) if anything cannot be
    /// loaded or compiled.
    pub fn start(dir: impl AsRef<std::path::Path>) -> Result<Arc<Self>> {
        let entries = load_manifest(&dir)?;
        let shapes: Vec<_> = entries.iter().map(|e| (e.kind, e.dim0, e.dim1)).collect();
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = std::sync::mpsc::sync_channel::<Result<()>>(1);

        let handle = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || service_thread(entries, rx, ready_tx))
            .context("spawn pjrt compute thread")?;

        ready_rx
            .recv()
            .map_err(|_| anyhow!("compute thread died during startup"))?
            .context("compiling artifacts")?;

        Ok(Arc::new(Self {
            tx: Mutex::new(tx),
            shapes,
            handle: Mutex::new(Some(handle)),
        }))
    }

    /// Memoized service per artifact directory.
    pub fn shared(dir: &str) -> Result<Arc<Self>> {
        static CACHE: OnceLock<Mutex<HashMap<String, Arc<ComputeService>>>> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut cache = cache.lock().unwrap();
        if let Some(svc) = cache.get(dir) {
            return Ok(Arc::clone(svc));
        }
        let svc = Self::start(dir)?;
        cache.insert(dir.to_string(), Arc::clone(&svc));
        Ok(svc)
    }

    /// Shapes available for `kind`, as (dim0, dim1) pairs.
    pub fn shapes(&self, kind: ArtifactKind) -> Vec<(usize, usize)> {
        self.shapes
            .iter()
            .filter(|(k, _, _)| *k == kind)
            .map(|&(_, a, b)| (a, b))
            .collect()
    }

    fn execute(
        &self,
        kind: ArtifactKind,
        dim0: usize,
        dim1: usize,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<Planes> {
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute { kind, dim0, dim1, re, im, reply: reply_tx })
            .map_err(|_| anyhow!("compute thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
    }

    /// Row-wise FFT through the `fft_rows` artifact of exactly this shape.
    pub fn fft_rows(&self, batch: usize, len: usize, re: Vec<f32>, im: Vec<f32>) -> Result<Planes> {
        self.execute(ArtifactKind::FftRows, batch, len, re, im)
    }

    /// Full 2-D transposed FFT through the `fft2_t` artifact.
    pub fn fft2_transposed(
        &self,
        rows: usize,
        cols: usize,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<Planes> {
        self.execute(ArtifactKind::Fft2Transposed, rows, cols, re, im)
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The thread that owns the PJRT stack.
fn service_thread(
    entries: Vec<super::artifact::ManifestEntry>,
    rx: Receiver<Request>,
    ready: SyncSender<Result<()>>,
) {
    // Build client + compile everything; report startup outcome.
    let setup = (|| -> Result<_> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for entry in &entries {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.path.display()))?;
            exes.insert((entry.kind, entry.dim0, entry.dim1), exe);
        }
        Ok((client, exes))
    })();

    let (_client, exes) = match setup {
        Ok(ok) => {
            let _ = ready.send(Ok(()));
            ok
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => return,
            Request::Execute { kind, dim0, dim1, re, im, reply } => {
                let result = run_one(&exes, kind, dim0, dim1, &re, &im);
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    exes: &HashMap<(ArtifactKind, usize, usize), xla::PjRtLoadedExecutable>,
    kind: ArtifactKind,
    dim0: usize,
    dim1: usize,
    re: &[f32],
    im: &[f32],
) -> Result<Planes> {
    let exe = exes.get(&(kind, dim0, dim1)).ok_or_else(|| {
        anyhow!(
            "no artifact for {kind:?} {dim0}×{dim1}; available shapes: {:?} — \
             re-run `make artifacts` with matching --rows-shapes",
            exes.keys().collect::<Vec<_>>()
        )
    })?;
    if re.len() != dim0 * dim1 || im.len() != dim0 * dim1 {
        bail!("plane length {} != {dim0}×{dim1}", re.len());
    }
    let lit_re = xla::Literal::vec1(re)
        .reshape(&[dim0 as i64, dim1 as i64])
        .map_err(|e| anyhow!("reshape re: {e:?}"))?;
    let lit_im = xla::Literal::vec1(im)
        .reshape(&[dim0 as i64, dim1 as i64])
        .map_err(|e| anyhow!("reshape im: {e:?}"))?;
    let result = exe
        .execute::<xla::Literal>(&[lit_re, lit_im])
        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e:?}"))?;
    // AOT lowers with return_tuple=True → a 2-tuple of planes.
    let (out_re, out_im) = result.to_tuple2().map_err(|e| anyhow!("untuple: {e:?}"))?;
    Ok((
        out_re.to_vec::<f32>().map_err(|e| anyhow!("re to_vec: {e:?}"))?,
        out_im.to_vec::<f32>().map_err(|e| anyhow!("im to_vec: {e:?}"))?,
    ))
}

/// [`RowFft`] engine backed by the artifact service: the distributed
/// driver's step-1/step-4 kernels run through PJRT.
pub struct PjrtRowFft {
    service: Arc<ComputeService>,
}

impl PjrtRowFft {
    /// Engine over the shared compute service for `dir`'s artifacts.
    pub fn new(dir: &str) -> Result<Self> {
        Ok(Self { service: ComputeService::shared(dir)? })
    }

    /// Pick the largest available batch for `row_len` that divides `rows`.
    fn pick_batch(&self, rows: usize, row_len: usize) -> Option<usize> {
        self.service
            .shapes(ArtifactKind::FftRows)
            .into_iter()
            .filter(|&(b, l)| l == row_len && rows % b == 0)
            .map(|(b, _)| b)
            .max()
    }
}

impl RowFft for PjrtRowFft {
    fn fft_rows(&self, data: &mut [Complex32], row_len: usize, _nthreads: usize) {
        let rows = data.len() / row_len;
        if rows == 0 {
            return;
        }
        let batch = self.pick_batch(rows, row_len).unwrap_or_else(|| {
            panic!(
                "no fft_rows artifact for row_len {row_len} dividing {rows} rows; \
                 available: {:?} — re-run `make artifacts` with --rows-shapes \
                 including {rows}x{row_len}",
                self.service.shapes(ArtifactKind::FftRows)
            )
        });
        for group in data.chunks_mut(batch * row_len) {
            let (re, im) = crate::fft::complex::to_planes(group);
            let (out_re, out_im) = self
                .service
                .fft_rows(batch, row_len, re, im)
                .expect("pjrt fft_rows execution failed");
            for (c, (r, i)) in group.iter_mut().zip(out_re.iter().zip(&out_im)) {
                *c = Complex32::new(*r, *i);
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    //! Gated on `artifacts/manifest.txt` (built by `make artifacts`);
    //! every test no-ops with a note when artifacts are absent so
    //! `cargo test` stays green on a fresh checkout.

    use super::*;
    use crate::dist_fft::driver::NativeRowFft;
    use crate::util::rng::Pcg32;

    fn artifacts_dir() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            Some(dir.to_str().unwrap().to_string())
        } else {
            eprintln!("skipping pjrt test: run `make artifacts` first");
            None
        }
    }

    fn random_planes(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        ((0..n).map(|_| rng.next_signal()).collect(), (0..n).map(|_| rng.next_signal()).collect())
    }

    #[test]
    fn fft_rows_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = ComputeService::shared(&dir).unwrap();
        let (batch, len) = (64, 256);
        let (re, im) = random_planes(1, batch * len);
        let (out_re, out_im) = svc.fft_rows(batch, len, re.clone(), im.clone()).unwrap();

        // Native reference on the same data.
        let mut native = crate::fft::complex::from_planes(&re, &im);
        NativeRowFft.fft_rows(&mut native, len, 1);
        let (want_re, want_im) = crate::fft::complex::to_planes(&native);

        let err_re = crate::util::testkit::rel_l2_error(&out_re, &want_re);
        let err_im = crate::util::testkit::rel_l2_error(&out_im, &want_im);
        assert!(err_re < 1e-4 && err_im < 1e-4, "rel err {err_re} / {err_im}");
    }

    #[test]
    fn pjrt_row_fft_engine_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = PjrtRowFft::new(&dir).unwrap();
        let (re, im) = random_planes(2, 64 * 256);
        let mut via_pjrt = crate::fft::complex::from_planes(&re, &im);
        engine.fft_rows(&mut via_pjrt, 256, 1);

        let mut via_native = crate::fft::complex::from_planes(&re, &im);
        NativeRowFft.fft_rows(&mut via_native, 256, 1);

        let (pr, pi) = crate::fft::complex::to_planes(&via_pjrt);
        let (nr, ni) = crate::fft::complex::to_planes(&via_native);
        assert!(crate::util::testkit::rel_l2_error(&pr, &nr) < 1e-4);
        assert!(crate::util::testkit::rel_l2_error(&pi, &ni) < 1e-4);
    }

    #[test]
    fn engine_batches_multiple_groups() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = PjrtRowFft::new(&dir).unwrap();
        // 256 rows with only a 64/128/256-batch artifact → must still work
        // (pick_batch finds a divisor) and match native.
        let (re, im) = random_planes(3, 256 * 256);
        let mut via_pjrt = crate::fft::complex::from_planes(&re, &im);
        engine.fft_rows(&mut via_pjrt, 256, 1);
        let mut via_native = crate::fft::complex::from_planes(&re, &im);
        NativeRowFft.fft_rows(&mut via_native, 256, 1);
        let (pr, _) = crate::fft::complex::to_planes(&via_pjrt);
        let (nr, _) = crate::fft::complex::to_planes(&via_native);
        assert!(crate::util::testkit::rel_l2_error(&pr, &nr) < 1e-4);
    }

    #[test]
    fn missing_shape_is_reported() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = ComputeService::shared(&dir).unwrap();
        let err = svc.fft_rows(3, 7, vec![0.0; 21], vec![0.0; 21]).unwrap_err().to_string();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn fft2_artifact_matches_serial() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = ComputeService::shared(&dir).unwrap();
        let (rows, cols) = (256, 256);
        let (re, im) = random_planes(4, rows * cols);
        let (out_re, out_im) = svc.fft2_transposed(rows, cols, re.clone(), im.clone()).unwrap();

        let grid = crate::fft::complex::from_planes(&re, &im);
        let want = crate::dist_fft::verify::serial_fft2_transposed(&grid, rows, cols);
        let got = crate::fft::complex::from_planes(&out_re, &out_im);
        let err = crate::dist_fft::verify::rel_error(&got, &want);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn shared_service_is_memoized() {
        let Some(dir) = artifacts_dir() else { return };
        let a = ComputeService::shared(&dir).unwrap();
        let b = ComputeService::shared(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
