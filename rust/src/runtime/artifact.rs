//! Artifact manifest: what `python -m compile.aot` produced.
//!
//! `artifacts/manifest.txt` is a whitespace-separated table written by
//! the AOT step (one line per artifact: `kind batch len file`). Parsing
//! it here — instead of globbing filenames — keeps the naming scheme in
//! exactly one place on each side of the language boundary.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Artifact entry-point kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `fft_rows_model(batch, len)` — row-wise FFT.
    FftRows,
    /// `fft2_transposed_model(rows, cols)` — full 2-D pipeline.
    Fft2Transposed,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "fft_rows" => Ok(ArtifactKind::FftRows),
            "fft2_t" => Ok(ArtifactKind::Fft2Transposed),
            other => bail!("unknown artifact kind {other:?} in manifest"),
        }
    }
}

/// One compiled-shape artifact.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Which entry point this artifact implements.
    pub kind: ArtifactKind,
    /// First shape dim (batch for FftRows, rows for Fft2Transposed).
    pub dim0: usize,
    /// Second shape dim (row length / cols).
    pub dim1: usize,
    /// Artifact file path.
    pub path: PathBuf,
}

/// Parse `<dir>/manifest.txt`.
pub fn load_manifest(dir: impl AsRef<Path>) -> Result<Vec<ManifestEntry>> {
    let dir = dir.as_ref();
    let manifest_path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&manifest_path).with_context(|| {
        format!("reading {} — run `make artifacts` first", manifest_path.display())
    })?;
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 4 {
            bail!("manifest line {} malformed: {line:?}", lineno + 1);
        }
        let entry = ManifestEntry {
            kind: ArtifactKind::parse(fields[0])?,
            dim0: fields[1].parse().context("bad dim0")?,
            dim1: fields[2].parse().context("bad dim1")?,
            path: dir.join(fields[3]),
        };
        if !entry.path.exists() {
            bail!("manifest references missing artifact {}", entry.path.display());
        }
        entries.push(entry);
    }
    if entries.is_empty() {
        bail!("manifest {} lists no artifacts", manifest_path.display());
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str, files: &[&str]) {
        for f in files {
            std::fs::File::create(dir.join(f)).unwrap();
        }
        let mut m = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        m.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hpxfft-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_valid_manifest() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            "# comment\nfft_rows 64 256 a.hlo.txt\nfft2_t 16 32 b.hlo.txt\n",
            &["a.hlo.txt", "b.hlo.txt"],
        );
        let entries = load_manifest(&d).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kind, ArtifactKind::FftRows);
        assert_eq!((entries[0].dim0, entries[0].dim1), (64, 256));
        assert_eq!(entries[1].kind, ArtifactKind::Fft2Transposed);
    }

    #[test]
    fn missing_file_rejected() {
        let d = tmpdir("missing");
        write_manifest(&d, "fft_rows 64 256 ghost.hlo.txt\n", &[]);
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn malformed_line_rejected() {
        let d = tmpdir("malformed");
        write_manifest(&d, "fft_rows 64\n", &[]);
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn unknown_kind_rejected() {
        let d = tmpdir("kind");
        write_manifest(&d, "conv2d 3 3 a.hlo.txt\n", &["a.hlo.txt"]);
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn empty_manifest_rejected() {
        let d = tmpdir("empty");
        write_manifest(&d, "# nothing\n", &[]);
        assert!(load_manifest(&d).is_err());
    }

    #[test]
    fn absent_dir_has_helpful_error() {
        let err = load_manifest("/nonexistent/dir").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_artifacts_parse_when_present() {
        // Gated: only meaningful after `make artifacts`.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let entries = load_manifest(&dir).unwrap();
            assert!(entries.iter().any(|e| e.kind == ArtifactKind::FftRows));
        }
    }
}
