//! Resident runtime services: the multi-tenant FFT scheduler and the
//! PJRT compute backend.
//!
//! The scheduler half turns the one-shot figure-harness drivers into a
//! resident service:
//!
//! - [`scheduler`] — [`FftService`], a multi-tenant job scheduler that
//!   keeps one parcelport fabric alive and runs many concurrent
//!   transform jobs over per-job sub-communicators,
//! - [`job`] — the job-node lifecycle types behind it
//!   ([`JobHandle`], [`AdmissionError`], ...).
//!
//! The PJRT half makes the AOT-compiled JAX/Pallas artifacts executable
//! from the Rust request path with no Python anywhere near it (Python
//! runs once at build time, `make artifacts`):
//!
//! - [`artifact`] — parses `artifacts/manifest.txt` and owns the naming
//!   scheme,
//! - [`service`] — a dedicated compute thread that owns the (non-`Send`)
//!   `PjRtClient` and the compiled executables, fed by a channel; plus
//!   [`service::PjrtRowFft`], the [`crate::dist_fft::driver::RowFft`]
//!   engine that lets the distributed driver run its step-1/step-4 row
//!   FFTs through the artifact instead of the native kernel.

pub mod artifact;
pub mod job;
pub mod scheduler;

// The real compute service needs the `xla` crate (PJRT C bindings),
// which the offline build image does not ship. The `pjrt` cargo feature
// gates it; without the feature an API-compatible stub keeps every
// caller compiling and reports at runtime that PJRT is unavailable.
#[cfg(feature = "pjrt")]
pub mod service;
#[cfg(not(feature = "pjrt"))]
#[path = "service_stub.rs"]
pub mod service;

pub use artifact::{load_manifest, ArtifactKind, ManifestEntry};
pub use job::{AdmissionError, JobError, JobHandle, JobOutput, JobState};
pub use scheduler::{FftService, ServiceConfig, TenantMetrics};
pub use service::{ComputeService, PjrtRowFft};
