//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the resulting HLO-text artifacts executable from the Rust request path
//! with no Python anywhere near it:
//!
//! - [`artifact`] — parses `artifacts/manifest.txt` and owns the naming
//!   scheme,
//! - [`service`] — a dedicated compute thread that owns the (non-`Send`)
//!   `PjRtClient` and the compiled executables, fed by a channel; plus
//!   [`service::PjrtRowFft`], the [`crate::dist_fft::driver::RowFft`]
//!   engine that lets the distributed driver run its step-1/step-4 row
//!   FFTs through the artifact instead of the native kernel.

pub mod artifact;

// The real compute service needs the `xla` crate (PJRT C bindings),
// which the offline build image does not ship. The `pjrt` cargo feature
// gates it; without the feature an API-compatible stub keeps every
// caller compiling and reports at runtime that PJRT is unavailable.
#[cfg(feature = "pjrt")]
pub mod service;
#[cfg(not(feature = "pjrt"))]
#[path = "service_stub.rs"]
pub mod service;

pub use artifact::{load_manifest, ArtifactKind, ManifestEntry};
pub use service::{ComputeService, PjrtRowFft};
