//! Job-side types of the multi-tenant FFT service.
//!
//! A submitted transform becomes a *job node* that moves through an
//! explicit lifecycle, mirroring the dataflow node model of
//! HPX-style schedulers (SNIPPETS.md snippet 2): admission builds the
//! node, the scheduler dispatches it onto a sub-communicator carved
//! from the service fabric, per-rank threads run the transform, and
//! the last rank to finish assembles the [`TransformReport`] and
//! fulfils the caller's [`JobHandle`].
//!
//! Everything here is shape-agnostic: a [`JobPlan`] is either a 2-D
//! slab ([`DistFftConfig`]) or a 3-D pencil ([`Pencil3Config`]) plan,
//! and the scheduler treats both identically.

use crate::dist_fft::driver::{DistFftConfig, RowFft, StepTimings};
use crate::dist_fft::grid3::PencilDims;
use crate::dist_fft::pencil::{Pencil3Config, PencilTimings};
use crate::dist_fft::TransformReport;
use crate::fft::complex::Complex32;
use crate::parcelport::PortStatsSnapshot;
use crate::task::TaskFuture;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Lifecycle state of a service job (the dataflow-node states every
/// job traverses in order; `Failed` replaces `Completed` when any rank
/// panics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted by admission control, waiting in the dispatch log.
    Queued,
    /// Claimed by the scheduler; the world split is under way.
    Dispatched,
    /// At least one rank thread is executing the transform.
    Running,
    /// All ranks finished and the report was assembled.
    Completed,
    /// At least one rank panicked; the handle resolves to a [`JobError`].
    Failed,
}

impl JobState {
    fn from_u8(v: u8) -> JobState {
        match v {
            0 => JobState::Queued,
            1 => JobState::Dispatched,
            2 => JobState::Running,
            3 => JobState::Completed,
            _ => JobState::Failed,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            JobState::Queued => 0,
            JobState::Dispatched => 1,
            JobState::Running => 2,
            JobState::Completed => 3,
            JobState::Failed => 4,
        }
    }
}

/// Why admission control rejected a submission (returned by
/// `FftService::submit` — never a panic).
#[derive(Debug)]
pub enum AdmissionError {
    /// The tenant already has `limit` jobs queued or running.
    QueueFull {
        /// The tenant whose queue is full.
        tenant: String,
        /// The per-tenant bound it hit (`ServiceConfig::queue_limit`).
        limit: usize,
    },
    /// The transform wants more localities than the service fabric has.
    TooLarge {
        /// Localities the transform needs.
        needed: usize,
        /// Localities the service was built with.
        available: usize,
    },
    /// The request failed validation (same errors
    /// `TransformRequest::build` produces) or is incompatible with the
    /// service fabric.
    Invalid(anyhow::Error),
    /// The service is draining; no new work is accepted.
    ShuttingDown,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { tenant, limit } => {
                write!(f, "tenant {tenant:?} queue is full ({limit} jobs pending)")
            }
            AdmissionError::TooLarge { needed, available } => {
                write!(
                    f,
                    "transform needs {needed} localities but the service fabric has {available}"
                )
            }
            AdmissionError::Invalid(e) => write!(f, "invalid request: {e:#}"),
            AdmissionError::ShuttingDown => f.write_str("service is shutting down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// A job that finished without producing a report: some rank panicked
/// (FFT-internal assertion, tag-space exhaustion, ...). The service
/// survives; only this job fails.
#[derive(Clone, Debug)]
pub struct JobError {
    /// The failed job's id.
    pub job_id: u64,
    /// The panic message(s), one per failed rank.
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {} failed: {}", self.job_id, self.message)
    }
}

impl std::error::Error for JobError {}

/// A completed job's result.
#[derive(Clone, Debug)]
pub struct JobOutput {
    /// The job's id (service-wide, monotonically increasing).
    pub job_id: u64,
    /// The unified transform report; `report.stats` holds the job's own
    /// scoped wire counters, not fabric-global ones.
    pub report: TransformReport,
    /// Submit-to-completion latency in µs (queueing included).
    pub latency_us: f64,
}

/// The caller's handle to a submitted job. Await it with
/// [`wait`](Self::wait), or poll [`is_done`](Self::is_done).
pub struct JobHandle {
    pub(crate) id: u64,
    pub(crate) tenant: String,
    pub(crate) future: TaskFuture<Result<JobOutput, JobError>>,
}

impl JobHandle {
    /// The job's service-wide id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The tenant the job was submitted under.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Whether the job has finished (completed or failed).
    pub fn is_done(&self) -> bool {
        self.future.is_ready()
    }

    /// Block until the job finishes and take its result.
    pub fn wait(self) -> Result<JobOutput, JobError> {
        self.future.get()
    }
}

/// The validated execution plan of one job — either transform shape,
/// plus everything pre-derived at admission so dispatch is infallible.
#[derive(Clone, Debug)]
pub(crate) enum JobPlan {
    /// 2-D slab transform.
    Plane(DistFftConfig),
    /// 3-D pencil transform, with the input/output pencil extents
    /// derived once at admission.
    Pencil {
        /// Validated pencil configuration.
        config: Pencil3Config,
        /// Input (x-pencil) extents per locality.
        dims_in: PencilDims,
        /// Output (z-pencil) extents per locality.
        dims: PencilDims,
    },
}

impl JobPlan {
    /// Localities the job occupies (= its sub-communicator size).
    pub(crate) fn localities(&self) -> usize {
        match self {
            JobPlan::Plane(c) => c.localities,
            JobPlan::Pencil { config, .. } => config.proc.n(),
        }
    }

    /// Chunk-send pool width the job's communicators will ask for.
    pub(crate) fn pool_width(&self) -> usize {
        match self {
            JobPlan::Plane(c) => c.chunk.inflight.max(1),
            JobPlan::Pencil { config, .. } => config.chunk.inflight.max(1),
        }
    }

}

/// Per-rank timing detail, shape-tagged (collected into
/// [`crate::dist_fft::TransformTimings`] at assembly).
#[derive(Clone, Debug)]
pub(crate) enum RankTimings {
    /// 2-D four-step timings.
    Plane(StepTimings),
    /// 3-D five-phase timings.
    Pencil(PencilTimings),
}

/// What the per-rank threads deposit as they finish; the last one in
/// assembles the report from it.
pub(crate) struct JobGather {
    /// Each rank's spectral piece (`None` until that rank finishes).
    pub(crate) pieces: Vec<Option<Vec<Complex32>>>,
    /// Each rank's timings.
    pub(crate) timings: Vec<Option<RankTimings>>,
    /// Each rank's scoped wire counters.
    pub(crate) scopes: Vec<Option<PortStatsSnapshot>>,
    /// Panic messages from failed ranks.
    pub(crate) failures: Vec<String>,
    /// Ranks finished so far (success or failure).
    pub(crate) done: usize,
}

/// One node in the scheduler's dispatch log.
pub(crate) struct JobEntry {
    /// Service-wide job id.
    pub(crate) id: u64,
    /// Owning tenant.
    pub(crate) tenant: String,
    /// The validated plan.
    pub(crate) plan: JobPlan,
    /// Row-FFT engine, built once at admission and shared by all ranks.
    pub(crate) engine: std::sync::Arc<dyn RowFft + Send>,
    /// Whether the report should carry the raw per-rank outputs.
    pub(crate) collect_outputs: bool,
    /// Admission timestamp (latency accounting).
    pub(crate) submitted: Instant,
    /// Current lifecycle state (encoded [`JobState`]).
    state: AtomicU8,
    /// Dispatch gate: set by the first worker to claim the job, read by
    /// the remaining workers so all ranks split the world for it.
    pub(crate) dispatch_open: AtomicBool,
    /// The rank rendezvous.
    pub(crate) gather: Mutex<JobGather>,
    /// The promise behind the caller's [`JobHandle`], taken exactly
    /// once by the assembling rank.
    pub(crate) promise: Mutex<Option<crate::task::Promise<Result<JobOutput, JobError>>>>,
}

impl JobEntry {
    /// Build a fresh `Queued` entry for `plan`.
    pub(crate) fn new(
        id: u64,
        tenant: String,
        plan: JobPlan,
        engine: std::sync::Arc<dyn RowFft + Send>,
        collect_outputs: bool,
        promise: crate::task::Promise<Result<JobOutput, JobError>>,
    ) -> JobEntry {
        let n = plan.localities();
        JobEntry {
            id,
            tenant,
            plan,
            engine,
            collect_outputs,
            submitted: Instant::now(),
            state: AtomicU8::new(JobState::Queued.as_u8()),
            dispatch_open: AtomicBool::new(false),
            gather: Mutex::new(JobGather {
                pieces: vec![None; n],
                timings: vec![None; n],
                scopes: vec![None; n],
                failures: Vec::new(),
                done: 0,
            }),
            promise: Mutex::new(Some(promise)),
        }
    }

    /// The job's current lifecycle state.
    pub(crate) fn state(&self) -> JobState {
        JobState::from_u8(self.state.load(Ordering::Acquire))
    }

    /// Advance the lifecycle (monotonic: a later state never regresses
    /// to an earlier one, so racing ranks may all call this).
    pub(crate) fn advance_state(&self, to: JobState) {
        self.state.fetch_max(to.as_u8(), Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_and_monotonic_advance() {
        for s in [
            JobState::Queued,
            JobState::Dispatched,
            JobState::Running,
            JobState::Completed,
            JobState::Failed,
        ] {
            assert_eq!(JobState::from_u8(s.as_u8()), s);
        }
        let (promise, _future) = crate::task::Promise::new();
        let entry = JobEntry::new(
            7,
            "t".into(),
            JobPlan::Plane(DistFftConfig::default()),
            std::sync::Arc::new(crate::dist_fft::driver::NativeRowFft),
            false,
            promise,
        );
        assert_eq!(entry.state(), JobState::Queued);
        entry.advance_state(JobState::Running);
        entry.advance_state(JobState::Dispatched); // late riser must not regress
        assert_eq!(entry.state(), JobState::Running);
    }

    #[test]
    fn admission_error_messages_are_actionable() {
        let e = AdmissionError::QueueFull { tenant: "acme".into(), limit: 8 };
        assert!(e.to_string().contains("acme"));
        assert!(e.to_string().contains('8'));
        let e = AdmissionError::TooLarge { needed: 8, available: 4 };
        assert!(e.to_string().contains("8 localities"));
        assert!(AdmissionError::ShuttingDown.to_string().contains("shutting down"));
    }
}
