//! Observability: always-on tracing spans, Chrome/Perfetto trace export,
//! and a live metrics registry.
//!
//! The paper's analysis stops at end-to-end runtimes per parcelport;
//! explaining *why* LCI beats MPI/TCP needs per-message visibility —
//! where a chunk waits, which FFT band hid which send. This module is
//! that substrate:
//!
//! - [`trace`] — typed span/instant events recorded into per-thread ring
//!   buffers behind a single relaxed-atomic gate. When tracing is
//!   disabled (the default) an emission site costs one relaxed atomic
//!   load and allocates nothing — cheap enough to leave compiled into
//!   every hot path (parcelport sends, per-chunk wire work, FFT bands,
//!   transpose placement, scheduler job lifecycle).
//! - [`chrome`] — exports drained events as Chrome trace-event JSON,
//!   loadable in Perfetto / `chrome://tracing`. One process per
//!   locality, one track per thread; chunk spans nest under collective
//!   spans by time containment, which makes the driver's `overlap_us`
//!   *visible* as overlapping tracks instead of a single number.
//! - [`metrics`] — counters, gauges, and exponential-bucket latency
//!   histograms behind [`MetricsRegistry`], rendered as a
//!   Prometheus-style text snapshot (the `metrics` verb of
//!   `repro serve`).
//!
//! The discrete-event simulator records the same event shape (see
//! [`crate::simnet::run_sim_traced`]), so a simulated 1024-locality run
//! exports through the identical pipeline as a live run.
//!
//! ## Capturing a trace
//!
//! ```
//! use hpx_fft::obs;
//!
//! let session = obs::session(); // drains stale events, enables the gate
//! {
//!     let _span = obs::span("fft", "band", 0);
//!     obs::instant("chunk", "post", 0);
//! }
//! let events = session.finish(); // disables the gate, drains
//! assert_eq!(events.len(), 2);
//! let json = obs::chrome::to_json(&events);
//! assert!(json.contains("\"traceEvents\""));
//! ```

pub mod chrome;
pub mod metrics;
pub mod trace;

pub use metrics::{Histogram, MetricsRegistry};
pub use trace::{
    disable, drain, dropped_events, enable, enabled, instant, instant_args, open_spans, session,
    span, span_args, Event, EventKind, OpenSpan, SpanGuard, TraceSession, NO_ARG, SERVICE_RANK,
};
