//! Counters, gauges, and exponential-bucket histograms — the metrics
//! half of the observability layer.
//!
//! [`Histogram`] is the shared latency-summary type: fixed power-of-two
//! buckets (so recording is a single index increment, merging is
//! element-wise addition, and quantiles never need the raw samples),
//! used by the per-tenant service accounts, the load harness's
//! percentile reporting, and the `metrics` verb of `repro serve`.
//! [`MetricsRegistry`] holds named counters/gauges/histograms (labels
//! embedded Prometheus-style in the name, e.g.
//! `fft_jobs_done_total{tenant="acme"}`) and renders the whole state as
//! a Prometheus text-format snapshot.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Number of exponential buckets: bucket `i` covers `[2^i, 2^(i+1))`
/// units (bucket 0 additionally absorbs everything below 1). With
/// microsecond samples the top bucket starts at ≈ 4.6 days.
pub const BUCKETS: usize = 48;

/// Fixed-footprint latency histogram with power-of-two buckets.
///
/// Quantile estimates interpolate linearly inside the winning bucket
/// and clamp to the observed min/max, so for any `p ≤ q`,
/// `quantile(p) ≤ quantile(q)` holds by construction — the property the
/// load harness's p50/p95/p99 regression test pins down.
///
/// ```
/// use hpx_fft::obs::Histogram;
///
/// let mut h = Histogram::new();
/// for v in [100.0, 200.0, 400.0, 800.0] {
///     h.observe(v);
/// }
/// assert_eq!(h.count(), 4);
/// let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
/// assert!(p50 <= p95 && p95 <= p99);
/// assert!(p99 <= 800.0);
/// ```
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self { counts: [0; BUCKETS], count: 0, sum: 0.0, min: f64::INFINITY, max: 0.0 }
    }

    fn bucket(value: f64) -> usize {
        if value < 1.0 {
            0
        } else {
            (value.log2().floor() as usize).min(BUCKETS - 1)
        }
    }

    /// Record one sample (negative values clamp to 0).
    pub fn observe(&mut self, value: f64) {
        let value = if value.is_finite() { value.max(0.0) } else { 0.0 };
        self.counts[Self::bucket(value)] += 1;
        self.count += 1;
        self.sum += value;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of all recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Estimated value at quantile `q ∈ [0, 1]` — linear interpolation
    /// inside the bucket holding the `⌈q·count⌉`-th sample, clamped to
    /// the observed range. Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= target {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let into = (target - (seen - c)) as f64 / c as f64;
                return (lo + (hi - lo) * into).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// [`Histogram::quantile`] with a percent argument (`p ∈ [0, 100]`).
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }

    /// Append Prometheus text-format `_bucket`/`_sum`/`_count` lines.
    /// `family` is the metric name without labels, `labels` the
    /// `key="value"` list (possibly empty, without braces).
    fn render_into(&self, out: &mut String, family: &str, labels: &str) {
        use std::fmt::Write as _;
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        let top = self.counts.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        for (i, &c) in self.counts.iter().enumerate().take(top) {
            cum += c;
            let le = 1u64 << (i + 1);
            let _ = writeln!(out, "{family}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "{family}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", self.count);
        let _ = writeln!(out, "{family}_sum{{{labels}}} {}", self.sum);
        let _ = writeln!(out, "{family}_count{{{labels}}} {}", self.count);
    }
}

/// Split a metric name into `(family, labels)`:
/// `f{a="b"}` → `("f", "a=\"b\"")`, `f` → `("f", "")`.
fn split_name(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Named counters, gauges, and histograms behind one lock — the
/// process-wide metrics surface the FFT service exposes through its
/// `metrics` verb. Interior mutability so layers share it behind `Arc`
/// without threading `&mut` through the scheduler.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn inner(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Add `delta` to the named monotone counter (created at 0).
    pub fn add(&self, name: &str, delta: u64) {
        let mut inner = self.inner();
        match inner.counters.get_mut(name) {
            Some(c) => *c += delta,
            None => {
                inner.counters.insert(name.to_string(), delta);
            }
        }
    }

    /// Set the named gauge to `value`.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.inner().gauges.insert(name.to_string(), value);
    }

    /// Record one sample into the named histogram (created empty).
    pub fn observe(&self, name: &str, value: f64) {
        self.inner().hists.entry(name.to_string()).or_default().observe(value);
    }

    /// Current value of the named counter (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner().counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of the named gauge, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner().gauges.get(name).copied()
    }

    /// Snapshot of the named histogram, if any sample was recorded.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.inner().hists.get(name).cloned()
    }

    /// Render the whole registry as a Prometheus text-format snapshot:
    /// one `# TYPE` header per metric family, counters and gauges as
    /// single samples, histograms as cumulative `_bucket`/`_sum`/
    /// `_count` series.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let inner = self.inner();
        let mut out = String::new();
        let mut last_family = String::new();
        let mut type_line = |out: &mut String, family: &str, kind: &str| {
            if family != last_family {
                let _ = writeln!(out, "# TYPE {family} {kind}");
                last_family = family.to_string();
            }
        };
        for (name, value) in &inner.counters {
            let (family, _) = split_name(name);
            type_line(&mut out, family, "counter");
            let _ = writeln!(out, "{name} {value}");
        }
        for (name, value) in &inner.gauges {
            let (family, _) = split_name(name);
            type_line(&mut out, family, "gauge");
            let _ = writeln!(out, "{name} {value}");
        }
        for (name, hist) in &inner.hists {
            let (family, labels) = split_name(name);
            type_line(&mut out, family, "histogram");
            hist.render_into(&mut out, family, labels);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn quantiles_are_monotone_in_q() {
        let mut rng = Pcg32::new(7);
        let mut h = Histogram::new();
        for _ in 0..5000 {
            h.observe((rng.next_signal() as f64).abs() * 10_000.0);
        }
        let mut last = 0.0;
        for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= last, "quantile({q}) = {v} < {last}");
            last = v;
        }
        assert!(h.quantile(1.0) <= h.max());
    }

    #[test]
    fn quantile_brackets_exact_value() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.observe(300.0);
        }
        // All mass in bucket [256, 512); estimate must stay in range.
        let p50 = h.quantile(0.5);
        assert!((256.0..=512.0).contains(&p50), "p50 = {p50}");
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.observe(10.0);
        b.observe(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.sum() - 1010.0).abs() < 1e-9);
        assert!(a.quantile(0.0) <= a.quantile(1.0));
    }

    #[test]
    fn registry_renders_prometheus_text() {
        let reg = MetricsRegistry::new();
        reg.add("jobs_total{tenant=\"acme\"}", 3);
        reg.add("jobs_total{tenant=\"labs\"}", 1);
        reg.set_gauge("queue_depth{tenant=\"acme\"}", 2.0);
        reg.observe("latency_us{tenant=\"acme\"}", 900.0);
        reg.observe("latency_us{tenant=\"acme\"}", 90.0);
        let text = reg.render();
        assert!(text.contains("# TYPE jobs_total counter"));
        assert!(text.contains("jobs_total{tenant=\"acme\"} 3"));
        assert!(text.contains("# TYPE queue_depth gauge"));
        assert!(text.contains("# TYPE latency_us histogram"));
        assert!(text.contains("latency_us_bucket{tenant=\"acme\",le=\"+Inf\"} 2"));
        assert!(text.contains("latency_us_count{tenant=\"acme\"} 2"));
        assert_eq!(reg.counter("jobs_total{tenant=\"acme\"}"), 3);
        assert_eq!(reg.histogram("latency_us{tenant=\"acme\"}").unwrap().count(), 2);
    }
}
