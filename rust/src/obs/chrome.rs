//! Chrome trace-event JSON export — the timeline format Perfetto and
//! `chrome://tracing` load directly.
//!
//! Mapping from [`Event`]s:
//!
//! - every locality is a *process* (`pid` = rank; service-level events
//!   get the reserved pid [`SERVICE_PID`]), every recording thread a
//!   *track* (`tid`);
//! - closed spans become `"ph": "X"` complete events (`ts` + `dur`, in
//!   microseconds) — by construction every exported span is closed,
//!   because span events are only emitted when their guard drops;
//! - instants become `"ph": "i"` thread-scoped events;
//! - `"ph": "M"` metadata names each process (`locality N` / `service`)
//!   and thread.
//!
//! Chunk spans nest under collective/FFT-phase spans purely by time
//! containment on a track, which is exactly how the viewers render
//! nesting — so the driver's `overlap_us` number becomes visible as a
//! wire-chunk track overlapping an FFT track.
//!
//! [`validate_file`] re-reads an exported file with a small
//! self-contained JSON parser and checks it against the trace-event
//! schema (required keys per phase type, non-negative durations,
//! per-track timestamp monotonicity) — used by tests, `repro trace`,
//! and the CI `obs` job.

use super::trace::{Event, EventKind};
use std::fmt::Write as _;
use std::path::Path;

/// `pid` used for service-level events (rank `u32::MAX`).
pub const SERVICE_PID: u64 = 999_999;

fn pid_of(rank: u32) -> u64 {
    if rank == u32::MAX {
        SERVICE_PID
    } else {
        rank as u64
    }
}

fn push_args(out: &mut String, e: &Event) {
    out.push_str("\"args\":{");
    let mut first = true;
    for (key, val) in [("tag", e.tag), ("chunk", e.chunk), ("bytes", e.bytes)] {
        if val >= 0 {
            if !first {
                out.push(',');
            }
            let _ = write!(out, "\"{key}\":{val}");
            first = false;
        }
    }
    out.push('}');
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Serialize events as a complete Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`), with one metadata record per process and
/// per track, events sorted by track then timestamp.
pub fn to_json(events: &[Event]) -> String {
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| (pid_of(e.rank), e.tid, e.ts_ns));

    let mut procs: Vec<u64> = sorted.iter().map(|e| pid_of(e.rank)).collect();
    procs.dedup();
    procs.sort_unstable();
    procs.dedup();
    let mut tracks: Vec<(u64, u32)> = sorted.iter().map(|e| (pid_of(e.rank), e.tid)).collect();
    tracks.dedup();

    let mut out = String::with_capacity(128 + events.len() * 96);
    out.push_str("{\"traceEvents\":[\n");
    let mut first = true;
    let mut sep = |out: &mut String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
    };
    for pid in &procs {
        sep(&mut out, &mut first);
        let pname = if *pid == SERVICE_PID { "service".into() } else { format!("locality {pid}") };
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
             \"args\":{{\"name\":\"{pname}\"}}}}"
        );
    }
    for (pid, tid) in &tracks {
        sep(&mut out, &mut first);
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"thread {tid}\"}}}}"
        );
    }
    for e in &sorted {
        sep(&mut out, &mut first);
        let (pid, tid) = (pid_of(e.rank), e.tid);
        let ts = e.ts_ns as f64 / 1e3;
        let (cat, name) = (escape(e.cat), escape(e.name));
        match e.kind {
            EventKind::Span { dur_ns } => {
                let dur = dur_ns as f64 / 1e3;
                let _ = write!(
                    out,
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts:.3},\
                     \"dur\":{dur:.3},\"cat\":\"{cat}\",\"name\":\"{name}\","
                );
            }
            EventKind::Instant => {
                let _ = write!(
                    out,
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts:.3},\
                     \"s\":\"t\",\"cat\":\"{cat}\",\"name\":\"{name}\","
                );
            }
        }
        push_args(&mut out, e);
        out.push('}');
    }
    out.push_str("\n]}\n");
    out
}

/// Serialize `events` with [`to_json`] and write the document to `path`,
/// creating parent directories as needed.
pub fn export(events: &[Event], path: impl AsRef<Path>) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, to_json(events))
}

/// What [`validate_file`] / [`validate_str`] found in a valid document.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// Total non-metadata events.
    pub events: usize,
    /// `"ph": "X"` complete spans among them.
    pub spans: usize,
    /// Distinct `(pid, tid)` tracks carrying events.
    pub tracks: usize,
}

/// Validate an exported trace file against the trace-event schema. See
/// [`validate_str`].
pub fn validate_file(path: impl AsRef<Path>) -> Result<TraceSummary, String> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
    validate_str(&text)
}

/// Validate a trace-event JSON document: it must parse, carry a
/// `traceEvents` array whose entries each have `ph`/`pid`/`tid`/`name`,
/// every `"X"` span a non-negative `dur` (i.e. every span closed), and
/// timestamps non-decreasing per `(pid, tid)` track in document order.
pub fn validate_str(text: &str) -> Result<TraceSummary, String> {
    let doc = json::parse(text)?;
    let top = doc.as_obj().ok_or("top level must be an object")?;
    let events = top
        .iter()
        .find(|(k, _)| k == "traceEvents")
        .ok_or("missing \"traceEvents\" key")?
        .1
        .as_arr()
        .ok_or("\"traceEvents\" must be an array")?;

    let mut summary = TraceSummary::default();
    let mut last_ts: Vec<((f64, f64), f64)> = Vec::new(); // ((pid, tid), last ts)
    for (i, ev) in events.iter().enumerate() {
        let obj = ev.as_obj().ok_or_else(|| format!("event {i}: not an object"))?;
        let field = |k: &str| obj.iter().find(|(key, _)| key == k).map(|(_, v)| v);
        let num = |k: &str| field(k).and_then(json::Value::as_num);
        let ph = field("ph")
            .and_then(json::Value::as_str)
            .ok_or_else(|| format!("event {i}: missing \"ph\""))?;
        field("name").and_then(json::Value::as_str).ok_or_else(|| format!("event {i}: no name"))?;
        let pid = num("pid").ok_or_else(|| format!("event {i}: missing \"pid\""))?;
        let tid = num("tid").ok_or_else(|| format!("event {i}: missing \"tid\""))?;
        match ph {
            "M" => continue, // metadata carries no timestamp
            "X" => {
                let dur = num("dur").ok_or_else(|| format!("event {i}: span without dur"))?;
                if dur < 0.0 {
                    return Err(format!("event {i}: negative dur {dur}"));
                }
                summary.spans += 1;
            }
            "i" => {}
            other => return Err(format!("event {i}: unexpected phase {other:?}")),
        }
        let ts = num("ts").ok_or_else(|| format!("event {i}: missing \"ts\""))?;
        summary.events += 1;
        match last_ts.iter_mut().find(|(track, _)| *track == (pid, tid)) {
            Some((_, last)) => {
                if ts < *last {
                    return Err(format!(
                        "event {i}: ts {ts} < {last} — not monotone on track ({pid}, {tid})"
                    ));
                }
                *last = ts;
            }
            None => last_ts.push(((pid, tid), ts)),
        }
    }
    summary.tracks = last_ts.len();
    Ok(summary)
}

/// One row of [`phase_table`]: aggregate statistics for every distinct
/// `(cat, name)` span kind in a capture.
#[derive(Clone, Copy, Debug)]
pub struct PhaseRow {
    /// Event category.
    pub cat: &'static str,
    /// Event name.
    pub name: &'static str,
    /// Number of spans of this kind.
    pub count: u64,
    /// Summed span duration, µs.
    pub total_us: f64,
    /// Longest single span, µs.
    pub max_us: f64,
}

/// Aggregate spans by `(cat, name)` — the per-phase summary `repro
/// trace` prints. Rows are sorted by descending total time.
pub fn phase_table(events: &[Event]) -> Vec<PhaseRow> {
    let mut rows: Vec<PhaseRow> = Vec::new();
    for e in events {
        let EventKind::Span { dur_ns } = e.kind else { continue };
        let us = dur_ns as f64 / 1e3;
        match rows.iter_mut().find(|r| r.cat == e.cat && r.name == e.name) {
            Some(row) => {
                row.count += 1;
                row.total_us += us;
                row.max_us = row.max_us.max(us);
            }
            None => {
                rows.push(PhaseRow { cat: e.cat, name: e.name, count: 1, total_us: us, max_us: us })
            }
        }
    }
    rows.sort_by(|a, b| b.total_us.total_cmp(&a.total_us));
    rows
}

/// Minimal recursive-descent JSON parser — just enough to validate the
/// exporter's own output without external dependencies. Numbers are
/// f64, objects keep insertion order.
mod json {
    /// A parsed JSON value.
    pub enum Value {
        /// `null`.
        Null,
        /// `true` / `false`.
        Bool(bool),
        /// Any number (parsed as f64).
        Num(f64),
        /// A string.
        Str(String),
        /// An array.
        Arr(Vec<Value>),
        /// An object, insertion-ordered.
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        pub fn as_obj(&self) -> Option<&[(String, Value)]> {
            match self {
                Value::Obj(o) => Some(o),
                _ => None,
            }
        }
        pub fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }
        pub fn as_num(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    struct Parser<'a> {
        s: &'a [u8],
        i: usize,
    }

    impl Parser<'_> {
        fn skip_ws(&mut self) {
            while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
                self.i += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.s.get(self.i).copied()
        }

        fn eat(&mut self, c: u8) -> Result<(), String> {
            if self.peek() == Some(c) {
                self.i += 1;
                Ok(())
            } else {
                Err(format!("expected {:?} at byte {}", c as char, self.i))
            }
        }

        fn value(&mut self) -> Result<Value, String> {
            match self.peek().ok_or("unexpected end of input")? {
                b'{' => self.object(),
                b'[' => self.array(),
                b'"' => Ok(Value::Str(self.string()?)),
                b't' => self.keyword("true", Value::Bool(true)),
                b'f' => self.keyword("false", Value::Bool(false)),
                b'n' => self.keyword("null", Value::Null),
                _ => self.number(),
            }
        }

        fn keyword(&mut self, word: &str, v: Value) -> Result<Value, String> {
            if self.s[self.i..].starts_with(word.as_bytes()) {
                self.i += word.len();
                Ok(v)
            } else {
                Err(format!("bad keyword at byte {}", self.i))
            }
        }

        fn object(&mut self) -> Result<Value, String> {
            self.eat(b'{')?;
            let mut out = Vec::new();
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(Value::Obj(out));
            }
            loop {
                self.skip_ws();
                let key = self.string()?;
                self.skip_ws();
                self.eat(b':')?;
                self.skip_ws();
                out.push((key, self.value()?));
                self.skip_ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b'}') => {
                        self.i += 1;
                        return Ok(Value::Obj(out));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
                }
            }
        }

        fn array(&mut self) -> Result<Value, String> {
            self.eat(b'[')?;
            let mut out = Vec::new();
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Value::Arr(out));
            }
            loop {
                self.skip_ws();
                out.push(self.value()?);
                self.skip_ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b']') => {
                        self.i += 1;
                        return Ok(Value::Arr(out));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
                }
            }
        }

        fn string(&mut self) -> Result<String, String> {
            self.eat(b'"')?;
            let mut out = String::new();
            loop {
                match self.peek().ok_or("unterminated string")? {
                    b'"' => {
                        self.i += 1;
                        return Ok(out);
                    }
                    b'\\' => {
                        self.i += 1;
                        match self.peek().ok_or("unterminated escape")? {
                            b'"' => out.push('"'),
                            b'\\' => out.push('\\'),
                            b'/' => out.push('/'),
                            b'n' => out.push('\n'),
                            b't' => out.push('\t'),
                            b'r' => out.push('\r'),
                            b'b' => out.push('\u{8}'),
                            b'f' => out.push('\u{c}'),
                            b'u' => {
                                let hex = self
                                    .s
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("short \\u escape")?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                    16,
                                )
                                .map_err(|_| "bad \\u escape")?;
                                out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                                self.i += 4;
                            }
                            c => return Err(format!("bad escape \\{}", c as char)),
                        }
                        self.i += 1;
                    }
                    _ => {
                        // Consume one UTF-8 scalar (the input is a &str,
                        // so boundaries are valid by construction).
                        let rest = std::str::from_utf8(&self.s[self.i..])
                            .map_err(|_| "invalid utf-8")?;
                        let c = rest.chars().next().ok_or("unterminated string")?;
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn number(&mut self) -> Result<Value, String> {
            let start = self.i;
            while self
                .peek()
                .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            {
                self.i += 1;
            }
            std::str::from_utf8(&self.s[start..self.i])
                .ok()
                .and_then(|t| t.parse().ok())
                .map(Value::Num)
                .ok_or_else(|| format!("bad number at byte {start}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::NO_ARG;

    fn span(ts_ns: u64, dur_ns: u64, rank: u32, tid: u32) -> Event {
        Event {
            ts_ns,
            kind: EventKind::Span { dur_ns },
            cat: "t",
            name: "s",
            rank,
            tid,
            tag: 7,
            chunk: NO_ARG,
            bytes: 64,
        }
    }

    #[test]
    fn export_roundtrips_through_validator() {
        let events = vec![
            span(1_000, 5_000, 0, 0),
            span(2_000, 1_000, 0, 0),
            span(1_500, 2_000, 1, 3),
            Event { kind: EventKind::Instant, ..span(9_000, 0, u32::MAX, 2) },
        ];
        let doc = to_json(&events);
        let summary = validate_str(&doc).expect("exporter output must validate");
        assert_eq!(summary, TraceSummary { events: 4, spans: 3, tracks: 3 });
        assert!(doc.contains("\"name\":\"service\""), "service pseudo-process must be named");
        assert!(doc.contains("\"name\":\"locality 1\""));
    }

    #[test]
    fn validator_rejects_broken_documents() {
        assert!(validate_str("{}").is_err(), "missing traceEvents");
        assert!(validate_str("{\"traceEvents\":[{\"pid\":0}]}").is_err(), "missing ph");
        let bad_dur = "{\"traceEvents\":[{\"ph\":\"X\",\"pid\":0,\"tid\":0,\"ts\":1,\
                        \"dur\":-2,\"name\":\"x\"}]}";
        assert!(validate_str(bad_dur).is_err(), "negative dur");
        let bad_order = "{\"traceEvents\":[\
            {\"ph\":\"i\",\"pid\":0,\"tid\":0,\"ts\":5,\"name\":\"a\"},\
            {\"ph\":\"i\",\"pid\":0,\"tid\":0,\"ts\":4,\"name\":\"b\"}]}";
        assert!(validate_str(bad_order).is_err(), "non-monotone track");
        assert!(validate_str("not json").is_err());
    }

    #[test]
    fn phase_table_aggregates_by_kind() {
        let mut e2 = span(10, 4_000, 0, 1);
        e2.name = "other";
        let rows = phase_table(&[span(0, 2_000, 0, 0), span(5, 6_000, 1, 0), e2]);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].name, rows[0].count), ("s", 2));
        assert!((rows[0].total_us - 8.0).abs() < 1e-9);
        assert!((rows[0].max_us - 6.0).abs() < 1e-9);
    }
}
