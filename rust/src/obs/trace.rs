//! Span/instant recording into per-thread ring buffers.
//!
//! ## Design
//!
//! - **Gate.** A single global [`AtomicBool`] read with `Relaxed`
//!   ordering. Every emission site checks it first; when tracing is off
//!   (the default) a [`span`] or [`instant`] call is one atomic load and
//!   zero allocations — verified by `tests/alloc_free.rs` and the
//!   `benches/hotpath.rs` overhead guard.
//! - **Rings.** Each thread owns a bounded ring of [`RING_CAP`] events.
//!   The owning thread is the only writer, so the per-ring mutex is
//!   uncontended on the hot path; cross-thread locking happens only when
//!   [`drain`] collects. When a ring is full the oldest event is
//!   overwritten and [`dropped_events`] ticks — recording never blocks
//!   and never grows without bound.
//! - **Timestamps.** Nanoseconds from a process-wide monotonic epoch
//!   ([`Instant`]), so events from different threads and localities
//!   share one timeline and the exporter can sort tracks globally.
//! - **Open-span registry.** Armed [`SpanGuard`]s register themselves
//!   until dropped; [`open_spans`] snapshots what is currently in
//!   flight. `testkit::with_watchdog` dumps this on timeout, turning a
//!   bare "likely hang" panic into "chunk 3 of tag 71 from rank 2 never
//!   closed".

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// Maximum buffered events per thread; the oldest events are overwritten
/// once a ring is full (see [`dropped_events`]).
pub const RING_CAP: usize = 1 << 15;

/// Sentinel for an absent numeric argument on an [`Event`].
pub const NO_ARG: i64 = -1;

/// Pseudo-rank for events not tied to a locality (service-level job
/// lifecycle events); the exporter gives them their own process track.
pub const SERVICE_RANK: usize = usize::MAX;

static GATE: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static OPEN: Mutex<Vec<OpenSpan>> = Mutex::new(Vec::new());
static SESSION: Mutex<()> = Mutex::new(());

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = register_thread();
    static TID: u32 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Whether the tracing gate is currently open (relaxed load — the only
/// cost a disabled emission site pays).
#[inline(always)]
pub fn enabled() -> bool {
    GATE.load(Ordering::Relaxed)
}

/// Open the tracing gate: subsequent [`span`]/[`instant`] calls record.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    GATE.store(true, Ordering::SeqCst);
}

/// Close the tracing gate; buffered events stay until [`drain`]ed.
pub fn disable() {
    GATE.store(false, Ordering::SeqCst);
}

/// Nanoseconds since the process-wide monotonic epoch.
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn rank32(rank: usize) -> u32 {
    if rank == SERVICE_RANK {
        u32::MAX
    } else {
        rank as u32
    }
}

fn current_tid() -> u32 {
    TID.with(|t| *t)
}

/// What an [`Event`] marks on the timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A closed interval `[ts_ns, ts_ns + dur_ns]` — always complete:
    /// span events are only emitted when their guard drops.
    Span {
        /// Span duration, nanoseconds.
        dur_ns: u64,
    },
    /// A point in time.
    Instant,
}

/// One recorded trace event. `cat`/`name` are static so recording never
/// copies strings; the three numeric arguments use [`NO_ARG`] when
/// absent and surface in the exporter's `args` object.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Start time (spans) or occurrence time (instants), ns since epoch.
    pub ts_ns: u64,
    /// Span-with-duration or instant.
    pub kind: EventKind,
    /// Category — the layer that emitted ("port", "wire", "fft", ...).
    pub cat: &'static str,
    /// Event name within the category.
    pub name: &'static str,
    /// Locality the event belongs to (`u32::MAX` = service-level).
    pub rank: u32,
    /// Stable per-thread track id.
    pub tid: u32,
    /// Wire tag, or [`NO_ARG`].
    pub tag: i64,
    /// Chunk index within a transfer, or [`NO_ARG`].
    pub chunk: i64,
    /// Payload bytes, or [`NO_ARG`].
    pub bytes: i64,
}

impl Event {
    /// End time: `ts_ns + dur` for spans, `ts_ns` for instants.
    pub fn end_ns(&self) -> u64 {
        match self.kind {
            EventKind::Span { dur_ns } => self.ts_ns + dur_ns,
            EventKind::Instant => self.ts_ns,
        }
    }

    /// Whether this event is a (closed) span.
    pub fn is_span(&self) -> bool {
        matches!(self.kind, EventKind::Span { .. })
    }
}

/// A span currently in flight (guard created, not yet dropped) — the
/// watchdog's hang diagnosis.
#[derive(Clone, Copy, Debug)]
pub struct OpenSpan {
    /// Unique id (used internally to unregister on close).
    pub id: u64,
    /// Category of the open span.
    pub cat: &'static str,
    /// Name of the open span.
    pub name: &'static str,
    /// Locality the span belongs to (`u32::MAX` = service-level).
    pub rank: u32,
    /// Wire tag, or [`NO_ARG`].
    pub tag: i64,
    /// Chunk index, or [`NO_ARG`].
    pub chunk: i64,
    /// Start time, ns since epoch.
    pub start_ns: u64,
}

impl OpenSpan {
    /// Nanoseconds this span has been open so far.
    pub fn open_for_ns(&self) -> u64 {
        now_ns().saturating_sub(self.start_ns)
    }
}

/// Bounded per-thread event buffer. `next` indexes the oldest event once
/// the ring has wrapped.
struct Ring {
    buf: Vec<Event>,
    next: usize,
    wrapped: bool,
}

impl Ring {
    const fn new() -> Self {
        Self { buf: Vec::new(), next: 0, wrapped: false }
    }

    fn push(&mut self, e: Event) {
        if self.buf.len() < RING_CAP {
            self.buf.push(e);
        } else {
            self.buf[self.next] = e;
            self.next = (self.next + 1) % RING_CAP;
            self.wrapped = true;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Remove and return all buffered events in recording order.
    fn take(&mut self) -> Vec<Event> {
        let buf = std::mem::take(&mut self.buf);
        let next = std::mem::replace(&mut self.next, 0);
        if std::mem::replace(&mut self.wrapped, false) {
            let mut out = Vec::with_capacity(buf.len());
            out.extend_from_slice(&buf[next..]);
            out.extend_from_slice(&buf[..next]);
            out
        } else {
            buf
        }
    }
}

fn register_thread() -> Arc<Mutex<Ring>> {
    let ring = Arc::new(Mutex::new(Ring::new()));
    lock(&REGISTRY).push(Arc::clone(&ring));
    ring
}

fn emit(e: Event) {
    LOCAL.with(|ring| lock(ring).push(e));
}

/// Events overwritten because a thread's ring was full, process-lifetime
/// total. Non-zero means a capture outgrew [`RING_CAP`] — shorten the
/// traced region or drain mid-run.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// RAII guard for an in-flight span. Created by [`span`]/[`span_args`];
/// the span event (with its measured duration) is emitted when the guard
/// drops. A guard created while tracing was disabled is inert.
#[must_use = "the span closes (and is recorded) when this guard drops"]
pub struct SpanGuard {
    meta: Option<SpanMeta>,
}

struct SpanMeta {
    start_ns: u64,
    cat: &'static str,
    name: &'static str,
    rank: u32,
    tag: i64,
    chunk: i64,
    bytes: i64,
    open_id: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(m) = self.meta.take() {
            let dur_ns = now_ns().saturating_sub(m.start_ns);
            lock(&OPEN).retain(|s| s.id != m.open_id);
            emit(Event {
                ts_ns: m.start_ns,
                kind: EventKind::Span { dur_ns },
                cat: m.cat,
                name: m.name,
                rank: m.rank,
                tid: current_tid(),
                tag: m.tag,
                chunk: m.chunk,
                bytes: m.bytes,
            });
        }
    }
}

/// Open a span with no numeric arguments. See [`span_args`].
#[inline]
pub fn span(cat: &'static str, name: &'static str, rank: usize) -> SpanGuard {
    span_args(cat, name, rank, NO_ARG, NO_ARG, NO_ARG)
}

/// Open a span on the current thread's track. When the gate is closed
/// this returns an inert guard without touching any lock or allocating;
/// when open, the span registers in the open-span table and is emitted
/// with its duration on drop.
#[inline]
pub fn span_args(
    cat: &'static str,
    name: &'static str,
    rank: usize,
    tag: i64,
    chunk: i64,
    bytes: i64,
) -> SpanGuard {
    if !enabled() {
        return SpanGuard { meta: None };
    }
    let start_ns = now_ns();
    let open_id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let rank = rank32(rank);
    lock(&OPEN).push(OpenSpan { id: open_id, cat, name, rank, tag, chunk, start_ns });
    SpanGuard { meta: Some(SpanMeta { start_ns, cat, name, rank, tag, chunk, bytes, open_id }) }
}

/// Record an instant with no numeric arguments. See [`instant_args`].
#[inline]
pub fn instant(cat: &'static str, name: &'static str, rank: usize) {
    instant_args(cat, name, rank, NO_ARG, NO_ARG, NO_ARG);
}

/// Record a point event on the current thread's track. A no-op (one
/// relaxed atomic load, zero allocations) when the gate is closed.
#[inline]
pub fn instant_args(
    cat: &'static str,
    name: &'static str,
    rank: usize,
    tag: i64,
    chunk: i64,
    bytes: i64,
) {
    if !enabled() {
        return;
    }
    emit(Event {
        ts_ns: now_ns(),
        kind: EventKind::Instant,
        cat,
        name,
        rank: rank32(rank),
        tid: current_tid(),
        tag,
        chunk,
        bytes,
    });
}

/// Snapshot of all spans currently in flight, for hang diagnosis.
pub fn open_spans() -> Vec<OpenSpan> {
    lock(&OPEN).clone()
}

/// Collect (and remove) all buffered events from every thread's ring,
/// globally sorted by timestamp.
pub fn drain() -> Vec<Event> {
    let rings: Vec<_> = lock(&REGISTRY).iter().map(Arc::clone).collect();
    let mut out = Vec::new();
    for ring in rings {
        out.append(&mut lock(&ring).take());
    }
    out.sort_by_key(|e| (e.ts_ns, e.tid));
    out
}

/// Exclusive capture window: holds a process-wide session lock (so
/// concurrent captures — e.g. tests in one binary — serialize instead of
/// stealing each other's events), drains stale events, clears the
/// open-span table, and opens the gate. Obtain via [`session`].
pub struct TraceSession {
    guard: Option<MutexGuard<'static, ()>>,
}

/// Begin an exclusive capture window. Blocks until any other session
/// ends. The gate closes again when the returned [`TraceSession`] is
/// finished or dropped.
pub fn session() -> TraceSession {
    let guard = lock(&SESSION);
    disable();
    drop(drain()); // discard events leaked from before this window
    lock(&OPEN).clear();
    enable();
    TraceSession { guard: Some(guard) }
}

impl TraceSession {
    /// Close the gate and return every event recorded in this window,
    /// sorted by timestamp.
    pub fn finish(mut self) -> Vec<Event> {
        disable();
        let events = drain();
        self.guard = None;
        events
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        disable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Each test filters on its own category: the gate and rings are
    // process-global, so a concurrently running test's events may land
    // in this test's session window (and vice versa).

    #[test]
    fn disabled_gate_records_nothing() {
        let s = session();
        drop(s.finish()); // gate now closed (unless another session opens it)
        let _g = span("t_gate", "closed", 0);
        instant("t_gate", "closed", 0);
        let s = session();
        let stray = s.finish().iter().filter(|e| e.cat == "t_gate").count();
        assert_eq!(stray, 0, "events recorded through a closed gate");
    }

    #[test]
    fn span_records_duration_and_args() {
        let s = session();
        {
            let _g = span_args("t_args", "work", 3, 7, 2, 4096);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        instant_args("t_args", "mark", 3, NO_ARG, NO_ARG, NO_ARG);
        let events: Vec<_> = s.finish().into_iter().filter(|e| e.cat == "t_args").collect();
        assert_eq!(events.len(), 2);
        let sp = events.iter().find(|e| e.is_span()).expect("span event");
        assert_eq!((sp.cat, sp.name, sp.rank), ("t_args", "work", 3));
        assert_eq!((sp.tag, sp.chunk, sp.bytes), (7, 2, 4096));
        match sp.kind {
            EventKind::Span { dur_ns } => assert!(dur_ns >= 1_000_000, "slept 2ms, got {dur_ns}ns"),
            EventKind::Instant => unreachable!(),
        }
    }

    #[test]
    fn open_spans_visible_until_drop() {
        let s = session();
        let g = span_args("t_open", "inflight", 1, 42, 5, NO_ARG);
        let open: Vec<_> = open_spans().into_iter().filter(|o| o.cat == "t_open").collect();
        assert_eq!(open.len(), 1);
        assert_eq!((open[0].name, open[0].rank, open[0].tag, open[0].chunk), ("inflight", 1, 42, 5));
        drop(g);
        assert!(open_spans().iter().all(|o| o.cat != "t_open"));
        drop(s.finish());
    }

    #[test]
    fn drain_merges_threads_in_time_order() {
        let s = session();
        let handles: Vec<_> = (0..4)
            .map(|r| {
                std::thread::spawn(move || {
                    let _g = span("t_drain", "thread", r);
                    instant("t_drain", "tick", r);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let events = s.finish();
        assert!(events.iter().filter(|e| e.cat == "t_drain").count() >= 8);
        assert!(events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns), "drain must sort by time");
    }
}
