//! fig6 — process-grid-shape scaling of the 3-D pencil FFT.
//!
//! The 2-D slab benchmark (Figs. 4/5) has one communicator and one
//! transpose; the pencil pipeline has two transpose rounds scoped to
//! *split sub-communicators*, so the communication volume and its
//! concurrency depend on the `Pr × Pc` shape: round 1 ships
//! `(1 − 1/Pc)` and round 2 `(1 − 1/Pr)` of every locality's data. This
//! harness sweeps the configured shapes (default `1×4`, `2×2`, `4×1`)
//! over every parcelport in **both** execution modes, and emits:
//!
//! - paper-style rows (mean ± 95% CI over reps) with the per-round
//!   transpose timings,
//! - a `fig6_pencil.csv` series carrying every phase column plus
//!   `overlap_us` for the async rows,
//! - a simnet prediction per point at the paper-scale 512³ cube.

use super::runner::measure;
use crate::config::{BenchConfig, ClusterSpec};
use crate::dist_fft::driver::ExecutionMode;
use crate::dist_fft::grid3::{PencilDims, ProcGrid};
use crate::dist_fft::pencil::PencilTimings;
use crate::dist_fft::TransformRequest;
use crate::hpx::runtime::Cluster;
use crate::metrics::{csv::write_csv, RunStats};
use crate::parcelport::PortKind;
use crate::simnet::fft_model::{predict_pencil3, Pencil3ModelParams};

/// One measured point of the fig6 sweep.
#[derive(Clone, Debug)]
pub struct Fig6Point {
    /// Parcelport measured.
    pub port: PortKind,
    /// Process-grid shape.
    pub proc: ProcGrid,
    /// Execution mode of the live runs.
    pub exec: ExecutionMode,
    /// Live hybrid end-to-end statistics.
    pub live: RunStats,
    /// Mean critical-path phase timings over the measured reps (the
    /// per-round transpose columns of the CSV).
    pub phases: PencilTimings,
    /// Simnet prediction at the paper-scale 512³ cube, µs — `None` when
    /// the shape does not divide the sim cube (the live sweep still
    /// runs; the CSV column is left empty).
    pub sim_us: Option<f64>,
}

/// Element-wise mean of critical-path timings over measured reps.
fn mean_timings(ts: &[PencilTimings]) -> PencilTimings {
    let k = ts.len().max(1) as f64;
    let mut out = PencilTimings::default();
    for t in ts {
        out.fft_z_us += t.fft_z_us / k;
        out.t1_comm_us += t.t1_comm_us / k;
        out.t1_place_us += t.t1_place_us / k;
        out.fft_y_us += t.fft_y_us / k;
        out.t2_comm_us += t.t2_comm_us / k;
        out.t2_place_us += t.t2_place_us / k;
        out.fft_x_us += t.fft_x_us / k;
        out.overlap_us += t.overlap_us / k;
        out.total_us += t.total_us / k;
    }
    out
}

/// Run the full fig6 sweep: every port × configured shape × execution
/// mode. Shapes that do not divide the configured grid are skipped with
/// a notice (never an error — the sweep is exploratory).
pub fn run(config: &BenchConfig) -> anyhow::Result<Vec<Fig6Point>> {
    let spec = ClusterSpec::buran();
    let net = spec.net_model();
    let mut points = Vec::new();
    for &proc in &config.proc_shapes {
        if let Err(e) = PencilDims::new(config.grid3, proc) {
            println!("  (skipping {} on {proc}: {e})", config.grid3);
            continue;
        }
        // The prediction depends only on (shape, port); shapes that
        // divide the live grid but not the 512³ sim cube omit it.
        let sim_params = Pencil3ModelParams {
            proc,
            compute: spec.compute_model(),
            net,
            ..Pencil3ModelParams::paper(proc)
        };
        let sim_divides = PencilDims::new(sim_params.grid, proc).is_ok();
        for port in PortKind::ALL {
            let cluster = Cluster::new(proc.n(), port, Some(net))?;
            let sim_us = sim_divides.then(|| predict_pencil3(&sim_params, port).makespan_us);
            for exec in ExecutionMode::ALL {
                let mut spec = config.transform_spec();
                spec.port = port;
                spec.exec = exec;
                spec.net = Some(net);
                spec.verify = false;
                // Built once per point, outside the measure loop —
                // validation is not timed.
                let transform = TransformRequest::grid3(config.grid3)
                    .spec(spec)
                    .proc_grid(proc)
                    .build()?;
                let mut crit: Vec<PencilTimings> = Vec::new();
                // Run failures park here and surface as a typed error
                // after the loop (the measure closure returns f64).
                let mut run_err: Option<anyhow::Error> = None;
                let stats = measure(config.warmup, config.reps, || {
                    let outcome = transform.run_on(&cluster).and_then(|report| {
                        report
                            .timings
                            .pencil_critical_path()
                            .copied()
                            .ok_or_else(|| anyhow::anyhow!("report carries no pencil timings"))
                    });
                    match outcome {
                        Ok(cp) => {
                            crit.push(cp);
                            cp.total_us
                        }
                        Err(e) => {
                            run_err.get_or_insert(e);
                            0.0
                        }
                    }
                });
                if let Some(e) = run_err {
                    return Err(e.context(format!("pencil3d run on {port} ({exec:?})")));
                }
                // Warmup reps are recorded by the closure like every
                // call; drop them to match the RunStats discipline.
                let phases = mean_timings(&crit[config.warmup.min(crit.len())..]);
                points.push(Fig6Point { port, proc, exec, live: stats, phases, sim_us });
            }
        }
    }
    Ok(points)
}

/// Paper-style report: table + overlap bars + CSV.
pub fn report(
    points: &[Fig6Point],
    config: &BenchConfig,
    out_dir: &str,
) -> anyhow::Result<String> {
    use crate::metrics::table::{fmt_us, Table};
    let mut table = Table::new(&[
        "port", "shape", "exec", "live mean", "±95% CI", "t1 comm", "t2 comm", "overlap",
        "sim (512³)",
    ]);
    let mut rows = Vec::new();
    for p in points {
        table.row(&[
            p.port.name().into(),
            p.proc.to_string(),
            p.exec.name().into(),
            format!("{:.2} ms", p.live.mean() / 1e3),
            format!("{:.2}", p.live.ci95() / 1e3),
            fmt_us(p.phases.t1_comm_us),
            fmt_us(p.phases.t2_comm_us),
            fmt_us(p.phases.overlap_us),
            p.sim_us.map(|s| format!("{:.1} ms", s / 1e3)).unwrap_or("-".into()),
        ]);
        rows.push(vec![
            p.port.name().to_string(),
            p.proc.pr.to_string(),
            p.proc.pc.to_string(),
            p.exec.name().to_string(),
            p.live.mean().to_string(),
            p.live.ci95().to_string(),
            p.phases.fft_z_us.to_string(),
            p.phases.t1_comm_us.to_string(),
            p.phases.t1_place_us.to_string(),
            p.phases.fft_y_us.to_string(),
            p.phases.t2_comm_us.to_string(),
            p.phases.t2_place_us.to_string(),
            p.phases.fft_x_us.to_string(),
            p.phases.overlap_us.to_string(),
            p.sim_us.map(|s| s.to_string()).unwrap_or_default(),
        ]);
    }
    write_csv(
        format!("{out_dir}/fig6_pencil.csv"),
        &[
            "port",
            "pr",
            "pc",
            "exec",
            "live_mean_us",
            "live_ci95_us",
            "fft_z_us",
            "t1_comm_us",
            "t1_place_us",
            "fft_y_us",
            "t2_comm_us",
            "t2_place_us",
            "fft_x_us",
            "overlap_us",
            "sim_us",
        ],
        &rows,
    )?;

    let mut out = String::new();
    out.push_str(&format!(
        "fig6 — 3-D pencil FFT, {} grid, shapes × ports × exec\n\n",
        config.grid3
    ));
    out.push_str(&table.render());

    // Async rows: how much wall time each (port, shape) hid.
    let bars: Vec<(String, f64, f64)> = points
        .iter()
        .filter(|p| p.exec == ExecutionMode::Async)
        .map(|p| {
            (format!("{}/{}", p.port.name(), p.proc), p.phases.overlap_us, p.live.mean())
        })
        .collect();
    if !bars.is_empty() {
        out.push('\n');
        out.push_str(&super::plot::overlap_bars(
            "wall time hidden behind compute (async pencil runs)",
            &bars,
        ));
    }

    // Headline: best shape per port by blocking live mean.
    for port in PortKind::ALL {
        let mut blocking: Vec<&Fig6Point> = points
            .iter()
            .filter(|p| p.port == port && p.exec == ExecutionMode::Blocking)
            .collect();
        blocking.sort_by(|a, b| a.live.mean().total_cmp(&b.live.mean()));
        if let (Some(best), Some(worst)) = (blocking.first(), blocking.last()) {
            out.push_str(&format!(
                "\nshape effect @ {port}: best {} ({:.2} ms) vs worst {} ({:.2} ms)",
                best.proc,
                best.live.mean() / 1e3,
                worst.proc,
                worst.live.mean() / 1e3,
            ));
        }
    }
    out.push('\n');
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::grid3::Grid3;

    fn tiny() -> BenchConfig {
        BenchConfig {
            reps: 2,
            warmup: 0,
            threads: 1,
            grid3: Grid3::new(8, 8, 8),
            proc_shapes: vec![ProcGrid::new(1, 2), ProcGrid::new(2, 1)],
            ..BenchConfig::quick()
        }
    }

    #[test]
    fn sweep_produces_all_points() {
        let points = run(&tiny()).unwrap();
        // 3 ports × 2 shapes × 2 exec modes.
        assert_eq!(points.len(), 3 * 2 * 2);
        for p in &points {
            assert!(p.live.mean() > 0.0);
            assert!(p.sim_us.unwrap() > 0.0, "512-dividing shapes carry a prediction");
            assert!(p.phases.total_us > 0.0);
        }
    }

    #[test]
    fn async_points_carry_overlap_blocking_do_not() {
        let points = run(&tiny()).unwrap();
        for p in &points {
            if p.exec == ExecutionMode::Blocking {
                assert_eq!(p.phases.overlap_us, 0.0, "{}/{}", p.port, p.proc);
            }
        }
        assert!(
            points.iter().any(|p| p.exec == ExecutionMode::Async),
            "sweep must cover async rows"
        );
    }

    #[test]
    fn shapes_not_dividing_sim_cube_run_live_without_prediction() {
        // 3×1 divides the 9³ live grid but not the 512³ sim cube: the
        // live sweep must still run (no panic), just with an empty
        // prediction column.
        let cfg = BenchConfig {
            grid3: Grid3::new(9, 9, 9),
            proc_shapes: vec![ProcGrid::new(3, 1)],
            ..tiny()
        };
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 3 * 2);
        assert!(points.iter().all(|p| p.sim_us.is_none() && p.live.mean() > 0.0));
    }

    #[test]
    fn indivisible_shapes_are_skipped_not_fatal() {
        let cfg = BenchConfig {
            proc_shapes: vec![ProcGrid::new(3, 1), ProcGrid::new(2, 2)],
            ..tiny()
        };
        // 8 % 3 != 0 → the 3×1 shape is skipped; 2×2 still measured.
        let points = run(&cfg).unwrap();
        assert_eq!(points.len(), 3 * 2);
        assert!(points.iter().all(|p| p.proc == ProcGrid::new(2, 2)));
    }

    #[test]
    fn report_renders_and_writes_csv() {
        let cfg = tiny();
        let points = run(&cfg).unwrap();
        let dir = std::env::temp_dir().join(format!("hpxfft-fig6-{}", std::process::id()));
        let text = report(&points, &cfg, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("fig6"));
        assert!(text.contains("shape effect"));
        assert!(text.contains("hidden"), "async overlap bars present");
        let csv = std::fs::read_to_string(dir.join("fig6_pencil.csv")).unwrap();
        assert!(csv.starts_with("port,pr,pc,exec,live_mean_us"), "{csv}");
        for col in ["t1_comm_us", "t2_comm_us", "t1_place_us", "overlap_us", "sim_us"] {
            assert!(csv.contains(col), "missing column {col}");
        }
        // Async rows exist in the CSV.
        assert!(csv.lines().any(|l| l.contains(",async,")), "{csv}");
    }
}
