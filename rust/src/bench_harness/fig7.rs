//! fig7 — real-input (r2c) vs complex (c2c) distributed FFT: the wire
//! halving the real domain buys on every port.
//!
//! The paper's FFTW3+MPI reference transforms *real* input, so the
//! reproduction's complex-only runs used to ship twice the bytes the
//! reference does. This harness quantifies the fix: it sweeps
//! **port × execution mode × domain** on one grid (the scatter variant,
//! the paper's proposed schedule), and emits:
//!
//! - paper-style rows (mean ± 95% CI over reps) with the per-step
//!   timings and the measured per-run `PortStats` wire volume,
//! - a `fig7_real.csv` series whose `wire_bytes` column is sourced from
//!   the parcelport counters (not a formula) — the acceptance check
//!   "real moves ≤ 55% of complex" reads exactly this column,
//! - a simnet prediction per (port, domain) at the paper-scale grid.

use super::runner::measure;
use crate::config::{BenchConfig, ClusterSpec};
use crate::dist_fft::driver::{Domain, ExecutionMode, StepTimings, Variant};
use crate::dist_fft::TransformRequest;
use crate::hpx::runtime::Cluster;
use crate::metrics::{csv::write_csv, RunStats};
use crate::parcelport::PortKind;
use crate::simnet::fft_model::{predict_fft, FftModelParams, ModelVariant};

/// Localities of the live fig7 sweep (the acceptance topology).
pub const FIG7_NODES: usize = 4;

/// One measured point of the fig7 sweep.
#[derive(Clone, Debug)]
pub struct Fig7Point {
    /// Parcelport measured.
    pub port: PortKind,
    /// Execution mode of the live runs.
    pub exec: ExecutionMode,
    /// Input domain.
    pub domain: Domain,
    /// Live hybrid end-to-end statistics.
    pub live: RunStats,
    /// Mean critical-path step timings over the measured reps.
    pub steps: StepTimings,
    /// Payload bytes one run put on the wire (`PortStats::bytes_sent`,
    /// per-run diff — the column the ≤ 55% acceptance check reads).
    pub wire_bytes: u64,
    /// Parcels one run sent.
    pub msgs_sent: u64,
    /// Simnet prediction at the paper-scale grid, µs.
    pub sim_us: f64,
}

/// Element-wise mean of critical-path step timings over measured reps.
fn mean_steps(ts: &[StepTimings]) -> StepTimings {
    let k = ts.len().max(1) as f64;
    let mut out = StepTimings::default();
    for t in ts {
        out.fft1_us += t.fft1_us / k;
        out.comm_us += t.comm_us / k;
        out.transpose_us += t.transpose_us / k;
        out.fft2_us += t.fft2_us / k;
        out.overlap_us += t.overlap_us / k;
        out.total_us += t.total_us / k;
    }
    out
}

/// Run the full fig7 sweep: every port × execution mode × domain on the
/// configured live grid (rows = cols = `config.live_grid`,
/// [`FIG7_NODES`] localities, scatter variant).
pub fn run(config: &BenchConfig) -> anyhow::Result<Vec<Fig7Point>> {
    let spec = ClusterSpec::buran();
    let net = spec.net_model();
    let grid = config.live_grid;
    anyhow::ensure!(
        grid % FIG7_NODES == 0 && (grid / 2) % FIG7_NODES == 0,
        "fig7 grid {grid} must keep both {grid} and its packed half \
         divisible by {FIG7_NODES} localities (use a multiple of {})",
        2 * FIG7_NODES
    );
    // The sim grid feeds real-domain predictions too — reject it here
    // instead of panicking inside predict_fft mid-sweep.
    anyhow::ensure!(
        config.sim_grid % FIG7_NODES == 0 && (config.sim_grid / 2) % FIG7_NODES == 0,
        "fig7 sim grid {} must keep both it and its packed half divisible \
         by {FIG7_NODES} nodes (use a multiple of {})",
        config.sim_grid,
        2 * FIG7_NODES
    );
    let mut points = Vec::new();
    for port in PortKind::ALL {
        let cluster = Cluster::new(FIG7_NODES, port, Some(net))?;
        for domain in Domain::ALL {
            let sim_params = FftModelParams {
                rows: config.sim_grid,
                cols: config.sim_grid,
                nodes: FIG7_NODES,
                domain,
                compute: spec.compute_model(),
                net,
            };
            let sim_us = predict_fft(&sim_params, port, ModelVariant::Scatter).makespan_us;
            for exec in ExecutionMode::ALL {
                let mut spec = config.transform_spec();
                spec.port = port;
                spec.exec = exec;
                spec.domain = domain;
                spec.net = Some(net);
                spec.verify = false;
                // Built once, outside the measure loop — validation is
                // not part of the timed region.
                let transform = TransformRequest::grid(grid, grid)
                    .spec(spec)
                    .localities(FIG7_NODES)
                    .variant(Variant::Scatter)
                    .build()?;
                let mut crit: Vec<StepTimings> = Vec::new();
                let mut wire = (0u64, 0u64);
                // Run failures park here and surface as a typed error
                // after the loop (the measure closure returns f64).
                let mut run_err: Option<anyhow::Error> = None;
                let stats = measure(config.warmup, config.reps, || {
                    let outcome = transform.run_on(&cluster).and_then(|report| {
                        report
                            .timings
                            .plane_critical_path()
                            .copied()
                            .ok_or_else(|| anyhow::anyhow!("report carries no plane timings"))
                            .map(|cp| (cp, report.stats.bytes_sent, report.stats.msgs_sent))
                    });
                    match outcome {
                        Ok((cp, bytes, msgs)) => {
                            crit.push(cp);
                            wire = (bytes, msgs);
                            cp.total_us
                        }
                        Err(e) => {
                            run_err.get_or_insert(e);
                            0.0
                        }
                    }
                });
                if let Some(e) = run_err {
                    return Err(e.context(format!("fig7 run on {port} ({exec:?})")));
                }
                // Warmup reps are recorded by the closure like every
                // call; drop them to match the RunStats discipline.
                let steps = mean_steps(&crit[config.warmup.min(crit.len())..]);
                points.push(Fig7Point {
                    port,
                    exec,
                    domain,
                    live: stats,
                    steps,
                    wire_bytes: wire.0,
                    msgs_sent: wire.1,
                    sim_us,
                });
            }
        }
    }
    Ok(points)
}

/// Paper-style report: table + per-(port, exec) wire-savings lines +
/// CSV (`fig7_real.csv`).
pub fn report(
    points: &[Fig7Point],
    config: &BenchConfig,
    out_dir: &str,
) -> anyhow::Result<String> {
    use crate::metrics::table::{fmt_us, Table};
    let mut table = Table::new(&[
        "port", "exec", "domain", "live mean", "±95% CI", "comm", "overlap", "wire bytes",
        "sim",
    ]);
    let mut rows = Vec::new();
    for p in points {
        table.row(&[
            p.port.name().into(),
            p.exec.name().into(),
            p.domain.name().into(),
            format!("{:.2} ms", p.live.mean() / 1e3),
            format!("{:.2}", p.live.ci95() / 1e3),
            fmt_us(p.steps.comm_us),
            fmt_us(p.steps.overlap_us),
            p.wire_bytes.to_string(),
            format!("{:.1} ms", p.sim_us / 1e3),
        ]);
        rows.push(vec![
            p.port.name().to_string(),
            p.exec.name().to_string(),
            p.domain.name().to_string(),
            config.live_grid.to_string(),
            config.live_grid.to_string(),
            p.live.mean().to_string(),
            p.live.ci95().to_string(),
            p.steps.fft1_us.to_string(),
            p.steps.comm_us.to_string(),
            p.steps.transpose_us.to_string(),
            p.steps.fft2_us.to_string(),
            p.steps.overlap_us.to_string(),
            p.wire_bytes.to_string(),
            p.msgs_sent.to_string(),
            p.sim_us.to_string(),
        ]);
    }
    write_csv(
        format!("{out_dir}/fig7_real.csv"),
        &[
            "port",
            "exec",
            "domain",
            "rows",
            "cols",
            "live_mean_us",
            "live_ci95_us",
            "fft1_us",
            "comm_us",
            "transpose_us",
            "fft2_us",
            "overlap_us",
            "wire_bytes",
            "msgs_sent",
            "sim_us",
        ],
        &rows,
    )?;

    let mut out = String::new();
    out.push_str(&format!(
        "fig7 — real (r2c) vs complex distributed FFT, {0}×{0} grid, {1} localities\n\n",
        config.live_grid, FIG7_NODES
    ));
    out.push_str(&table.render());

    // The headline: measured wire savings per (port, exec).
    for port in PortKind::ALL {
        for exec in ExecutionMode::ALL {
            let find = |domain| {
                points
                    .iter()
                    .find(|p| p.port == port && p.exec == exec && p.domain == domain)
            };
            if let (Some(c), Some(r)) = (find(Domain::Complex), find(Domain::Real)) {
                out.push_str(&format!(
                    "\nwire savings @ {port}/{}: real {} B vs complex {} B ({:.1}% of complex)",
                    exec.name(),
                    r.wire_bytes,
                    c.wire_bytes,
                    100.0 * r.wire_bytes as f64 / c.wire_bytes.max(1) as f64,
                ));
            }
        }
    }
    out.push('\n');
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchConfig {
        BenchConfig { reps: 2, warmup: 0, live_grid: 32, threads: 1, ..BenchConfig::quick() }
    }

    #[test]
    fn sweep_covers_the_full_matrix() {
        let points = run(&tiny()).unwrap();
        // 3 ports × 2 domains × 2 exec modes.
        assert_eq!(points.len(), 3 * 2 * 2);
        for p in &points {
            assert!(p.live.mean() > 0.0);
            assert!(p.wire_bytes > 0);
            assert!(p.sim_us > 0.0);
            if p.exec == ExecutionMode::Blocking {
                assert_eq!(p.steps.overlap_us, 0.0, "{}/{}", p.port, p.domain.name());
            }
        }
    }

    /// The acceptance criterion, read off the measured counters: on the
    /// same grid, the real domain moves ≤ 55% of the complex domain's
    /// wire bytes for every port and execution mode.
    #[test]
    fn real_wire_bytes_at_most_55_percent_of_complex() {
        let points = run(&tiny()).unwrap();
        for port in PortKind::ALL {
            for exec in ExecutionMode::ALL {
                let bytes = |domain| {
                    points
                        .iter()
                        .find(|p| p.port == port && p.exec == exec && p.domain == domain)
                        .unwrap()
                        .wire_bytes
                };
                let (c, r) = (bytes(Domain::Complex), bytes(Domain::Real));
                assert!(
                    (r as f64) <= 0.55 * c as f64,
                    "{port}/{}: real {r} vs complex {c}",
                    exec.name()
                );
            }
        }
    }

    #[test]
    fn indivisible_grid_rejected() {
        let err = run(&BenchConfig { live_grid: 36, ..tiny() }).unwrap_err().to_string();
        assert!(err.contains("packed half"), "{err}");
    }

    #[test]
    fn report_renders_and_writes_csv() {
        let cfg = tiny();
        let points = run(&cfg).unwrap();
        let dir = std::env::temp_dir().join(format!("hpxfft-fig7-{}", std::process::id()));
        let text = report(&points, &cfg, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("fig7"));
        assert!(text.contains("wire savings"));
        let csv = std::fs::read_to_string(dir.join("fig7_real.csv")).unwrap();
        assert!(csv.starts_with("port,exec,domain,rows,cols,live_mean_us"), "{csv}");
        for col in ["wire_bytes", "msgs_sent", "overlap_us", "sim_us"] {
            assert!(csv.contains(col), "missing column {col}");
        }
        assert!(csv.lines().any(|l| l.contains(",real,")), "{csv}");
        assert!(csv.lines().any(|l| l.contains(",complex,")), "{csv}");
    }
}
