//! Figure-regeneration harness.
//!
//! One driver per paper figure, shared between the `repro bench` CLI,
//! `examples/`, and `cargo bench` targets:
//!
//! - [`fig3`] — chunk-size scaling of the scatter collective on two
//!   nodes (paper Fig. 3): live hybrid measurement of all three
//!   parcelports + the simnet/analytic prediction.
//! - [`fig45`] — strong scaling of the distributed FFT (paper Figs. 4
//!   and 5): live hybrid runs at laptop scale, simnet predictions at the
//!   paper's 2^14×2^14 on up to 16 nodes, both against the FFTW3-like
//!   baseline.
//! - [`fig6`] — the 3-D pencil FFT's process-grid-shape sweep
//!   (`Pr × Pc` × port × exec mode) with per-round transpose timings
//!   and the paper-scale simnet prediction.
//! - [`fig7`] — real-input (r2c) vs complex distributed FFT
//!   (port × exec × domain), with the measured `PortStats` wire volume
//!   per point — the ~2× traffic saving of the packed half-spectrum.
//! - [`load`] — the `repro load` multi-tenant service load generator:
//!   thousands of mixed-shape jobs through one resident
//!   [`crate::runtime::FftService`], audited bitwise against
//!   single-shot references, with per-tenant latency percentiles.
//! - [`sim_scaling`] — the event-engine cluster sweep
//!   (`repro simulate --engine event`): fig4/5/6 communication patterns
//!   at 512–4096 simulated localities, slope-validated against the
//!   closed-form comm-only model and written to `sim_scaling.csv`.
//!
//! Every driver reports paper-style rows (mean ± 95% CI over N reps),
//! writes CSV series, and renders an ASCII log plot so the figure shape
//! is visible in the terminal.

pub mod fig3;
pub mod fig45;
pub mod fig6;
pub mod fig7;
pub mod load;
pub mod plot;
pub mod runner;
pub mod sim_scaling;

pub use runner::measure;
