//! ASCII log-log series plots — the figures, in a terminal.

/// One plotted series.
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Single-character plot marker.
    pub symbol: char,
    /// (x, y) points; both must be positive for log scaling.
    pub points: Vec<(f64, f64)>,
}

/// Render series on a log-log grid.
pub fn log_log_plot(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    const W: usize = 64;
    const H: usize = 20;
    let all: Vec<(f64, f64)> =
        series.iter().flat_map(|s| s.points.iter().copied()).filter(|&(x, y)| x > 0.0 && y > 0.0).collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    // Pad degenerate ranges.
    if x0 == x1 {
        x1 *= 2.0;
    }
    if y0 == y1 {
        y1 *= 2.0;
    }
    let (lx0, lx1, ly0, ly1) = (x0.log10(), x1.log10(), y0.log10(), y1.log10());
    let mut grid = vec![vec![' '; W]; H];
    for s in series {
        for &(x, y) in &s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = ((x.log10() - lx0) / (lx1 - lx0) * (W - 1) as f64).round() as usize;
            let cy = ((y.log10() - ly0) / (ly1 - ly0) * (H - 1) as f64).round() as usize;
            let row = H - 1 - cy.min(H - 1);
            grid[row][cx.min(W - 1)] = s.symbol;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("{ylabel} (log, {:.3e} .. {:.3e})\n", y0, y1));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(W));
    out.push('\n');
    out.push_str(&format!("{xlabel} (log, {:.3e} .. {:.3e})\n", x0, x1));
    for s in series {
        out.push_str(&format!("  {} = {}\n", s.symbol, s.label));
    }
    out
}

/// ASCII horizontal-bar view of comm/compute overlap: one row per
/// labelled measurement, showing how much of the communication window
/// (`comm_us`) was hidden behind compute (`overlap_us`) — `#` for the
/// hidden share, `.` for the exposed remainder. Rows whose comm window is
/// zero (e.g. single-rank runs) are rendered empty.
pub fn overlap_bars(title: &str, rows: &[(String, f64, f64)]) -> String {
    const W: usize = 40;
    let mut out = format!("{title}\n");
    let label_w = rows.iter().map(|(l, _, _)| l.len()).max().unwrap_or(0);
    for (label, overlap_us, comm_us) in rows {
        let frac = if *comm_us > 0.0 { (overlap_us / comm_us).clamp(0.0, 1.0) } else { 0.0 };
        let filled = (frac * W as f64).round() as usize;
        let bar = format!("{}{}", "#".repeat(filled), ".".repeat(W - filled));
        out.push_str(&format!(
            "  {label:<label_w$} |{bar}| {:5.1}% hidden ({:.1} of {:.1} µs)\n",
            frac * 100.0,
            overlap_us,
            comm_us
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_symbols() {
        let s = Series {
            label: "lci".into(),
            symbol: 'L',
            points: vec![(1024.0, 10.0), (4096.0, 20.0), (16384.0, 45.0)],
        };
        let plot = log_log_plot("Fig 3", "bytes", "µs", &[s]);
        assert!(plot.contains('L'));
        assert!(plot.contains("Fig 3"));
        assert!(plot.contains("lci"));
    }

    #[test]
    fn empty_series_safe() {
        let plot = log_log_plot("t", "x", "y", &[]);
        assert!(plot.contains("no data"));
    }

    #[test]
    fn single_point_safe() {
        let s = Series { label: "one".into(), symbol: 'o', points: vec![(5.0, 5.0)] };
        let plot = log_log_plot("t", "x", "y", &[s]);
        assert!(plot.contains('o'));
    }

    #[test]
    fn overlap_bars_render_fraction() {
        let rows = vec![
            ("lci".to_string(), 50.0, 100.0),
            ("tcp".to_string(), 0.0, 100.0),
            ("one-rank".to_string(), 0.0, 0.0),
        ];
        let out = overlap_bars("overlap", &rows);
        assert!(out.contains("overlap"));
        assert!(out.contains("50.0% hidden"), "{out}");
        assert!(out.contains("0.0% hidden"));
        // Half the bar filled for the 50% row.
        assert!(out.contains(&format!("|{}{}|", "#".repeat(20), ".".repeat(20))), "{out}");
    }
}
