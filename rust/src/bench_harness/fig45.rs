//! Figs. 4 & 5 — strong scaling of the distributed 2-D FFT.
//!
//! Fig. 4: the HPX *all-to-all* variant per parcelport vs the FFTW3
//! MPI+pthreads reference. Fig. 5: the same with the *N-scatter*
//! variant. Each produces:
//!
//! - **live hybrid** measurements at a laptop-sized grid (default
//!   2^10×2^10, every parcelport + baseline, mean ± CI over reps), and
//! - **simnet predictions** at the paper's true 2^14×2^14 problem on
//!   1–16 nodes of the buran model.

use super::plot::{log_log_plot, overlap_bars, Series};
use super::runner::measure;
use crate::baseline::fftw_like::{run_on as baseline_run_on, FftwLikeConfig};
use crate::collectives::AllToAllAlgo;
use crate::config::{BenchConfig, ClusterSpec};
use crate::dist_fft::driver::{Domain, ExecutionMode, Variant};
use crate::dist_fft::TransformRequest;
use crate::hpx::runtime::Cluster;
use crate::metrics::{csv::write_csv, RunStats};
use crate::parcelport::PortKind;
use crate::simnet::fft_model::{predict_fft, FftModelParams, ModelVariant};

/// Which system one scaling series belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    /// The HPX reproduction over one parcelport.
    Hpx(PortKind),
    /// The FFTW3 MPI+pthreads reference.
    Fftw3,
}

impl System {
    /// Every plotted system, in legend order.
    pub const ALL: [System; 4] =
        [System::Hpx(PortKind::Tcp), System::Hpx(PortKind::Mpi), System::Hpx(PortKind::Lci), System::Fftw3];

    /// Legend label.
    pub fn label(&self) -> String {
        match self {
            System::Hpx(p) => format!("hpx-{p}"),
            System::Fftw3 => "fftw3-mpi+x".into(),
        }
    }

    /// Single-character plot marker.
    pub fn symbol(&self) -> char {
        match self {
            System::Hpx(PortKind::Tcp) => 'T',
            System::Hpx(PortKind::Mpi) => 'M',
            System::Hpx(PortKind::Lci) => 'L',
            System::Fftw3 => 'F',
        }
    }
}

/// One strong-scaling point.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// System this point belongs to.
    pub system: System,
    /// Locality count.
    pub nodes: usize,
    /// Execution mode of the live measurement (`--exec` axis).
    pub exec: ExecutionMode,
    /// Live hybrid measurement (None for sim-only points).
    pub live: Option<RunStats>,
    /// Mean critical-path `overlap_us` of the live runs — wire time the
    /// execution mode hid behind compute (None for sim-only points;
    /// always 0 for the blocking mode and the FFTW3 baseline).
    pub live_overlap_us: Option<f64>,
    /// Simnet prediction at paper scale, µs.
    pub sim_us: f64,
}

/// Run one figure's sweep (Fig. 4 = `Variant::AllToAll`, Fig. 5 =
/// `Variant::Scatter`) in the configured execution mode.
pub fn run(config: &BenchConfig, variant: Variant) -> anyhow::Result<Vec<ScalingPoint>> {
    let spec = ClusterSpec::buran();
    let net = spec.net_model();
    let mut points = Vec::new();

    for system in System::ALL {
        // Live hybrid at laptop scale.
        let mut live: std::collections::HashMap<usize, (RunStats, f64)> = Default::default();
        for &nodes in &config.live_nodes {
            if config.live_grid % nodes != 0 {
                continue;
            }
            let entry = match system {
                System::Hpx(port) => {
                    let cluster = Cluster::new(nodes, port, Some(net))?;
                    let mut spec = config.transform_spec();
                    spec.port = port;
                    spec.net = Some(net);
                    spec.verify = false;
                    // Built once per (port, nodes) point, outside the
                    // measure loop — validation is not timed.
                    let transform = TransformRequest::grid(config.live_grid, config.live_grid)
                        .spec(spec)
                        .localities(nodes)
                        .variant(variant)
                        .algo(AllToAllAlgo::HpxRoot)
                        .build()?;
                    let mut overlaps = Vec::new();
                    // The measure closure returns a plain f64, so run
                    // failures park in this slot and surface as a typed
                    // error after the loop instead of panicking mid-rep.
                    let mut run_err: Option<anyhow::Error> = None;
                    let stats = measure(config.warmup, config.reps, || {
                        match transform.run_on(&cluster) {
                            Ok(report) => {
                                overlaps.push(report.overlap_us());
                                report.total_us()
                            }
                            Err(e) => {
                                run_err.get_or_insert(e);
                                0.0
                            }
                        }
                    });
                    if let Some(e) = run_err {
                        return Err(e.context(format!("live {variant:?} run at {nodes} nodes")));
                    }
                    // Warmup reps are recorded by the closure like every
                    // call; drop them to match the RunStats discipline.
                    let measured = &overlaps[config.warmup.min(overlaps.len())..];
                    let overlap =
                        measured.iter().sum::<f64>() / measured.len().max(1) as f64;
                    (stats, overlap)
                }
                System::Fftw3 => {
                    let cluster = Cluster::new(nodes, PortKind::Mpi, Some(net))?;
                    let cfg = FftwLikeConfig {
                        rows: config.live_grid,
                        cols: config.live_grid,
                        localities: nodes,
                        threads: config.threads,
                        net: Some(net),
                        verify: false,
                    };
                    let mut run_err: Option<anyhow::Error> = None;
                    let stats = measure(config.warmup, config.reps, || {
                        match baseline_run_on(&cluster, &cfg) {
                            Ok(report) => report.critical_path.total_us,
                            Err(e) => {
                                run_err.get_or_insert(e);
                                0.0
                            }
                        }
                    });
                    if let Some(e) = run_err {
                        return Err(e.context(format!("baseline run at {nodes} nodes")));
                    }
                    // The baseline is synchronous by construction.
                    (stats, 0.0)
                }
            };
            live.insert(nodes, entry);
        }

        // Simnet prediction at paper scale.
        for &nodes in &config.sim_nodes {
            let params = FftModelParams {
                rows: config.sim_grid,
                cols: config.sim_grid,
                nodes,
                domain: Domain::Complex,
                compute: spec.compute_model(),
                net,
            };
            let model_variant = match (system, variant) {
                (System::Fftw3, _) => ModelVariant::FftwBaseline,
                (System::Hpx(_), Variant::AllToAll) => {
                    ModelVariant::AllToAll(AllToAllAlgo::HpxRoot)
                }
                (System::Hpx(_), Variant::Scatter) => ModelVariant::Scatter,
            };
            let port = match system {
                System::Hpx(p) => p,
                System::Fftw3 => PortKind::Mpi,
            };
            let sim = predict_fft(&params, port, model_variant);
            let entry = live.get(&nodes).cloned();
            points.push(ScalingPoint {
                system,
                nodes,
                // The FFTW3 baseline is synchronous by construction: its
                // rows stay labeled `blocking` whatever the sweep mode,
                // so grouping the CSV by `exec` never compares the same
                // baseline numbers against themselves.
                exec: match system {
                    System::Fftw3 => ExecutionMode::Blocking,
                    System::Hpx(_) => config.exec,
                },
                live: entry.as_ref().map(|(s, _)| s.clone()),
                live_overlap_us: entry.map(|(_, o)| o),
                sim_us: sim.makespan_us,
            });
        }
    }
    Ok(points)
}

/// Paper-style report: table + ASCII figures + CSV.
pub fn report(
    points: &[ScalingPoint],
    variant: Variant,
    config: &BenchConfig,
    out_dir: &str,
) -> anyhow::Result<String> {
    let fig = match variant {
        Variant::AllToAll => "Fig. 4",
        Variant::Scatter => "Fig. 5",
    };
    let mut table = crate::metrics::table::Table::new(&[
        "system", "nodes", "exec", "live mean", "±95% CI", "overlap", "sim (2^14²)",
    ]);
    let mut rows = Vec::new();
    for p in points {
        table.row(&[
            p.system.label(),
            p.nodes.to_string(),
            p.exec.name().into(),
            p.live.as_ref().map(|s| format!("{:.2} ms", s.mean() / 1e3)).unwrap_or("-".into()),
            p.live.as_ref().map(|s| format!("{:.2}", s.ci95() / 1e3)).unwrap_or("-".into()),
            p.live_overlap_us.map(crate::metrics::table::fmt_us).unwrap_or("-".into()),
            format!("{:.1} ms", p.sim_us / 1e3),
        ]);
        rows.push(vec![
            p.system.label(),
            p.nodes.to_string(),
            p.exec.name().to_string(),
            p.live.as_ref().map(|s| s.mean().to_string()).unwrap_or_default(),
            p.live.as_ref().map(|s| s.ci95().to_string()).unwrap_or_default(),
            p.live_overlap_us.map(|o| o.to_string()).unwrap_or_default(),
            p.sim_us.to_string(),
        ]);
    }
    let tag = variant.name().replace('-', "_");
    write_csv(
        format!("{out_dir}/{}_strong_scaling_{tag}.csv", fig.replace([' ', '.'], "").to_lowercase()),
        &["system", "nodes", "exec", "live_mean_us", "live_ci95_us", "overlap_us", "sim_us"],
        &rows,
    )?;

    let series: Vec<Series> = System::ALL
        .iter()
        .map(|&system| Series {
            label: format!("{} (sim, {}²)", system.label(), config.sim_grid),
            symbol: system.symbol(),
            points: points
                .iter()
                .filter(|p| p.system == system)
                .map(|p| (p.nodes as f64, p.sim_us))
                .collect(),
        })
        .collect();

    let mut out = String::new();
    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&log_log_plot(
        &format!("{fig} — strong scaling, {} variant", variant.name()),
        "nodes",
        "runtime [µs]",
        &series,
    ));

    // Async live runs: per-system overlap bars at the largest live node
    // count — the share of each run's wall time the futures graph hid.
    let live_async: Vec<&ScalingPoint> = points
        .iter()
        .filter(|p| p.exec == ExecutionMode::Async && p.live.is_some())
        .collect();
    if let Some(max_live) = live_async.iter().map(|p| p.nodes).max() {
        let bars: Vec<(String, f64, f64)> = live_async
            .iter()
            .filter(|p| p.nodes == max_live)
            .map(|p| {
                (
                    p.system.label(),
                    p.live_overlap_us.unwrap_or(0.0),
                    p.live.as_ref().map(|s| s.mean()).unwrap_or(0.0),
                )
            })
            .collect();
        out.push('\n');
        out.push_str(&overlap_bars(
            &format!("wall time hidden behind compute @ {max_live} localities (live)"),
            &bars,
        ));
    }

    // Headline: LCI-vs-FFTW3 speedup at the largest node count.
    let max_nodes = points.iter().map(|p| p.nodes).max().unwrap_or(1);
    let lci = points
        .iter()
        .find(|p| p.system == System::Hpx(PortKind::Lci) && p.nodes == max_nodes)
        .map(|p| p.sim_us);
    let fftw = points
        .iter()
        .find(|p| p.system == System::Fftw3 && p.nodes == max_nodes)
        .map(|p| p.sim_us);
    if let (Some(l), Some(f)) = (lci, fftw) {
        out.push_str(&format!(
            "\nheadline @ {max_nodes} nodes: hpx-lci {:.1} ms vs fftw3 {:.1} ms → speedup {:.2}×\n",
            l / 1e3,
            f / 1e3,
            f / l
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchConfig {
        BenchConfig {
            reps: 2,
            warmup: 0,
            live_grid: 64,
            live_nodes: vec![1, 2],
            sim_nodes: vec![2, 4, 16],
            threads: 1,
            ..BenchConfig::quick()
        }
    }

    #[test]
    fn scatter_sweep_produces_points() {
        let points = run(&tiny(), Variant::Scatter).unwrap();
        // 4 systems × 3 sim node counts.
        assert_eq!(points.len(), 12);
        assert!(points.iter().all(|p| p.sim_us > 0.0));
        // Live stats present where live_nodes ∩ sim_nodes.
        assert!(points.iter().any(|p| p.live.is_some()));
    }

    #[test]
    fn report_contains_headline() {
        let cfg = tiny();
        let points = run(&cfg, Variant::Scatter).unwrap();
        let dir = std::env::temp_dir().join(format!("hpxfft-fig45-{}", std::process::id()));
        let text = report(&points, Variant::Scatter, &cfg, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("Fig. 5"));
        assert!(text.contains("headline @ 16 nodes"));
        assert!(text.contains("speedup"));
    }

    #[test]
    fn async_live_points_record_overlap() {
        let cfg = BenchConfig { exec: ExecutionMode::Async, ..tiny() };
        let points = run(&cfg, Variant::Scatter).unwrap();
        // HPX points carry the sweep mode; the synchronous FFTW3 baseline
        // stays labeled blocking.
        for p in &points {
            match p.system {
                System::Hpx(_) => assert_eq!(p.exec, ExecutionMode::Async),
                System::Fftw3 => assert_eq!(p.exec, ExecutionMode::Blocking),
            }
        }
        assert!(
            points.iter().any(|p| matches!(p.system, System::Hpx(_))
                && p.live.is_some()
                && p.live_overlap_us.is_some()),
            "live async points must carry an overlap estimate"
        );
        let dir = std::env::temp_dir().join(format!("hpxfft-fig45a-{}", std::process::id()));
        let text = report(&points, Variant::Scatter, &cfg, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("hidden"), "async report shows overlap bars");
    }

    #[test]
    fn fig4_uses_hpx_root_and_loses_to_fig5() {
        let cfg = tiny();
        let fig4 = run(&cfg, Variant::AllToAll).unwrap();
        let fig5 = run(&cfg, Variant::Scatter).unwrap();
        let sim = |points: &[ScalingPoint], sys: System| {
            points.iter().find(|p| p.system == sys && p.nodes == 16).unwrap().sim_us
        };
        // Scatter variant faster than all-to-all for HPX ports (the
        // paper's Fig. 4 vs 5 finding) at paper scale.
        for port in PortKind::ALL {
            assert!(
                sim(&fig5, System::Hpx(port)) < sim(&fig4, System::Hpx(port)),
                "{port}"
            );
        }
        // The FFTW3 baseline is the same in both figures.
        assert_eq!(sim(&fig4, System::Fftw3), sim(&fig5, System::Fftw3));
    }
}
