//! `repro load` — the multi-tenant service load generator.
//!
//! Drives thousands of transform jobs from several synthetic tenants
//! through one resident [`FftService`], mixing every shape the service
//! accepts (2-D/3-D × complex/real × blocking/async), and audits the
//! results *bitwise*: each distinct request in the mix is run once
//! single-shot through [`Transform::run`], and every service job's raw
//! output must equal that reference exactly — concurrency must not
//! perturb a single bit. The acceptance run
//! (`repro load --tenants 4 --jobs 1000`) passes only with zero
//! mismatches.
//!
//! Per tenant, the harness reports completed/rejected/failed counts,
//! p50/p95/p99 and mean submit-to-completion latency, throughput, and
//! scoped wire bytes, and writes the `service_load.csv` series
//! (columns documented in the README).
//!
//! Backpressure: when a tenant's queue is full the generator retries
//! the submission after a short sleep, so every generated job
//! eventually runs; the service's `rejected` counter then records how
//! often admission control pushed back.
//!
//! [`Transform::run`]: crate::dist_fft::Transform::run

use crate::dist_fft::driver::{Domain, ExecutionMode};
use crate::dist_fft::{Grid3, ProcGrid, TransformRequest};
use crate::fft::complex::Complex32;
use crate::metrics::csv::write_csv;
use crate::parcelport::PortKind;
use crate::runtime::{AdmissionError, FftService, JobHandle, ServiceConfig};
use std::time::Instant;

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Localities of the resident service fabric.
    pub localities: usize,
    /// Parcelport backend.
    pub port: PortKind,
    /// Number of synthetic tenants (`tenant-0` ... `tenant-{n-1}`).
    pub tenants: usize,
    /// Total jobs generated across all tenants.
    pub jobs: usize,
    /// Per-tenant admission queue bound.
    pub queue_limit: usize,
    /// Service-wide concurrent-job bound.
    pub max_inflight: usize,
    /// Row-FFT threads per locality inside each job.
    pub threads: usize,
    /// Output directory for `service_load.csv`.
    pub out_dir: String,
    /// Capture the whole burst under the process-wide trace session and
    /// write `service_load.trace.json` plus a `service_metrics.prom`
    /// registry snapshot next to the CSV (`--trace`).
    pub trace: bool,
}

impl Default for LoadConfig {
    /// The acceptance-run shape: 4 tenants on a 4-locality LCI fabric.
    fn default() -> Self {
        Self {
            localities: 4,
            port: PortKind::Lci,
            tenants: 4,
            jobs: 1000,
            queue_limit: 64,
            max_inflight: 4,
            threads: 1,
            out_dir: "bench_out".to_string(),
            trace: false,
        }
    }
}

/// One tenant's results (one row of `service_load.csv`).
#[derive(Clone, Debug)]
pub struct TenantLoadReport {
    /// Tenant name.
    pub tenant: String,
    /// Jobs the generator assigned to this tenant.
    pub jobs: usize,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Submissions admission control rejected (queue-full retries).
    pub rejected: u64,
    /// Jobs that failed (a rank panicked).
    pub failed: u64,
    /// Completed jobs whose output differed from the single-shot
    /// reference (must be 0).
    pub mismatches: usize,
    /// Median submit-to-completion latency, µs.
    pub p50_us: f64,
    /// 95th-percentile latency, µs.
    pub p95_us: f64,
    /// 99th-percentile latency, µs.
    pub p99_us: f64,
    /// Mean latency, µs.
    pub mean_us: f64,
    /// Completed jobs per second over the whole run's wall time.
    pub throughput: f64,
    /// Scoped wire bytes over the tenant's finished jobs.
    pub wire_bytes: u64,
}

/// The request mix: every transform shape the service accepts, all
/// sized to fit a `localities`-rank fabric (entries needing more ranks
/// than available are skipped). Deterministic — job `j` always maps to
/// entry `j % menu.len()`, so reruns generate identical workloads.
fn menu(cfg: &LoadConfig) -> Vec<TransformRequest> {
    let base = |r: TransformRequest| r.port(cfg.port).threads(cfg.threads).verify(false);
    let mut menu = vec![
        base(TransformRequest::grid(16, 16).localities(2)),
        base(TransformRequest::grid(16, 32).localities(2).domain(Domain::Real)),
        base(TransformRequest::grid(24, 24).localities(2).exec(ExecutionMode::Async)),
    ];
    if cfg.localities >= 4 {
        menu.push(base(TransformRequest::grid(32, 16).localities(4)));
        menu.push(base(
            TransformRequest::grid3(Grid3::new(8, 8, 8)).proc_grid(ProcGrid::new(2, 2)),
        ));
        menu.push(base(
            TransformRequest::grid3(Grid3::new(8, 8, 16))
                .proc_grid(ProcGrid::new(2, 2))
                .domain(Domain::Real)
                .exec(ExecutionMode::Async),
        ));
    }
    menu
}

/// Run the load: memoize single-shot reference outputs for each menu
/// entry, start the service, drive `cfg.jobs` submissions round-robin
/// across the tenants (retrying on queue-full backpressure), and audit
/// every completed job bitwise against its reference.
pub fn run(cfg: &LoadConfig) -> anyhow::Result<Vec<TenantLoadReport>> {
    anyhow::ensure!(cfg.tenants >= 1, "need at least one tenant");
    anyhow::ensure!(cfg.localities >= 2, "the mix needs at least 2 localities");
    let menu = menu(cfg);

    // Single-shot references, one per distinct request in the mix.
    let mut expected: Vec<Vec<Vec<Complex32>>> = Vec::with_capacity(menu.len());
    for request in &menu {
        let report = request.clone().collect_outputs(true).build()?.run()?;
        let outputs = report
            .outputs
            .ok_or_else(|| anyhow::anyhow!("reference run returned no outputs"))?;
        expected.push(outputs);
    }

    let service = FftService::new(ServiceConfig {
        localities: cfg.localities,
        port: cfg.port,
        net: None,
        queue_limit: cfg.queue_limit,
        max_inflight: cfg.max_inflight,
        job_tag_span: None,
        fault: None,
    })?;

    // The trace session covers the burst itself, not the single-shot
    // reference runs above it.
    let session = cfg.trace.then(crate::obs::session);
    let started = Instant::now();
    let mut handles: Vec<(usize, usize, JobHandle)> = Vec::with_capacity(cfg.jobs);
    let mut assigned = vec![0usize; cfg.tenants];
    for j in 0..cfg.jobs {
        let tenant_idx = j % cfg.tenants;
        let tenant = format!("tenant-{tenant_idx}");
        let entry = j % menu.len();
        assigned[tenant_idx] += 1;
        let request = menu[entry].clone().collect_outputs(true);
        // Queue-full is backpressure, not failure: retry until admitted.
        let handle = loop {
            match service.submit(&tenant, request.clone()) {
                Ok(h) => break h,
                Err(AdmissionError::QueueFull { .. }) => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => anyhow::bail!("job {j} for {tenant} rejected: {e}"),
            }
        };
        handles.push((tenant_idx, entry, handle));
    }

    // Failures are counted by the service metrics; the audit only
    // compares outputs that exist.
    let mut mismatches = vec![0usize; cfg.tenants];
    for (tenant_idx, entry, handle) in handles {
        if let Ok(out) = handle.wait() {
            let got = out
                .report
                .outputs
                .ok_or_else(|| anyhow::anyhow!("completed job returned no outputs"))?;
            if got != expected[entry] {
                mismatches[tenant_idx] += 1;
            }
        }
    }
    let wall_s = started.elapsed().as_secs_f64();
    if let Some(session) = session {
        let events = session.finish();
        crate::obs::chrome::export(&events, format!("{}/service_load.trace.json", cfg.out_dir))?;
        std::fs::write(
            format!("{}/service_metrics.prom", cfg.out_dir),
            service.metrics_text(),
        )?;
    }
    let metrics = service.shutdown();

    let mut rows = Vec::with_capacity(cfg.tenants);
    for t in 0..cfg.tenants {
        let name = format!("tenant-{t}");
        let m = metrics
            .iter()
            .find(|m| m.tenant == name)
            .ok_or_else(|| anyhow::anyhow!("no metrics for {name}"))?;
        // Percentiles come from the tenant's shared latency histogram —
        // one quantile implementation for the service's `metrics` verb
        // and this table, monotone in p by construction. (The previous
        // path re-sorted a raw sample vector per percentile call.)
        let h = &m.latency_hist;
        let (p50, p95, p99, mean) = if h.count() > 0 {
            (h.percentile(50.0), h.percentile(95.0), h.percentile(99.0), h.mean())
        } else {
            (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
        };
        rows.push(TenantLoadReport {
            tenant: name,
            jobs: assigned[t],
            completed: m.completed,
            rejected: m.rejected,
            failed: m.failed,
            mismatches: mismatches[t],
            p50_us: p50,
            p95_us: p95,
            p99_us: p99,
            mean_us: mean,
            throughput: m.completed as f64 / wall_s.max(f64::EPSILON),
            wire_bytes: m.wire_bytes,
        });
    }
    Ok(rows)
}

/// Render the per-tenant table and write `service_load.csv`.
pub fn report(rows: &[TenantLoadReport], out_dir: &str) -> anyhow::Result<String> {
    use crate::metrics::table::Table;
    let mut table = Table::new(&[
        "tenant", "jobs", "done", "rejected", "failed", "mismatch", "p50", "p95", "p99",
        "jobs/s", "wire bytes",
    ]);
    let mut csv_rows = Vec::new();
    for r in rows {
        table.row(&[
            r.tenant.clone(),
            r.jobs.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.failed.to_string(),
            r.mismatches.to_string(),
            format!("{:.1} ms", r.p50_us / 1e3),
            format!("{:.1} ms", r.p95_us / 1e3),
            format!("{:.1} ms", r.p99_us / 1e3),
            format!("{:.1}", r.throughput),
            r.wire_bytes.to_string(),
        ]);
        csv_rows.push(vec![
            r.tenant.clone(),
            r.jobs.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.failed.to_string(),
            r.mismatches.to_string(),
            r.p50_us.to_string(),
            r.p95_us.to_string(),
            r.p99_us.to_string(),
            r.mean_us.to_string(),
            r.throughput.to_string(),
            r.wire_bytes.to_string(),
        ]);
    }
    write_csv(
        format!("{out_dir}/service_load.csv"),
        &[
            "tenant",
            "jobs",
            "completed",
            "rejected",
            "failed",
            "mismatches",
            "p50_us",
            "p95_us",
            "p99_us",
            "mean_us",
            "throughput_jobs_s",
            "wire_bytes",
        ],
        &csv_rows,
    )?;

    let total_jobs: usize = rows.iter().map(|r| r.jobs).sum();
    let total_done: u64 = rows.iter().map(|r| r.completed).sum();
    let total_mismatch: usize = rows.iter().map(|r| r.mismatches).sum();
    let mut out = String::new();
    out.push_str(&format!(
        "service load: {total_jobs} jobs over {} tenants — {total_done} completed, \
         {total_mismatch} output mismatches vs single-shot reference\n\n",
        rows.len()
    ));
    out.push_str(&table.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_load_runs_clean_and_writes_csv() {
        let dir = std::env::temp_dir().join(format!("hpxfft-load-{}", std::process::id()));
        let cfg = LoadConfig {
            tenants: 2,
            jobs: 8,
            queue_limit: 4,
            out_dir: dir.to_str().unwrap().to_string(),
            ..LoadConfig::default()
        };
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows.iter().map(|r| r.completed).sum::<u64>(), 8);
        assert_eq!(rows.iter().map(|r| r.mismatches).sum::<usize>(), 0, "bitwise audit");
        assert!(rows.iter().all(|r| r.failed == 0 && r.wire_bytes > 0));
        let text = report(&rows, cfg.out_dir.as_str()).unwrap();
        assert!(text.contains("0 output mismatches"), "{text}");
        let csv = std::fs::read_to_string(dir.join("service_load.csv")).unwrap();
        assert!(csv.starts_with("tenant,jobs,completed,rejected,failed,mismatches,p50_us"));
        assert_eq!(csv.lines().count(), 3, "header + one row per tenant");
    }

    /// Regression: the table's percentiles route through the shared
    /// exponential-bucket histogram, so p50 ≤ p95 ≤ p99 can never
    /// invert — the ordering bug the old per-call resort left possible.
    #[test]
    fn traced_load_percentiles_monotone_and_artifacts_written() {
        let dir = std::env::temp_dir().join(format!("hpxfft-load-tr-{}", std::process::id()));
        let cfg = LoadConfig {
            tenants: 2,
            jobs: 6,
            queue_limit: 4,
            trace: true,
            out_dir: dir.to_str().unwrap().to_string(),
            ..LoadConfig::default()
        };
        let rows = run(&cfg).unwrap();
        for r in &rows {
            assert!(r.completed > 0, "{r:?}");
            assert!(r.p50_us <= r.p95_us, "{r:?}");
            assert!(r.p95_us <= r.p99_us, "{r:?}");
        }
        let summary =
            crate::obs::chrome::validate_file(dir.join("service_load.trace.json")).unwrap();
        assert!(summary.events > 0, "traced burst produced no events");
        let prom = std::fs::read_to_string(dir.join("service_metrics.prom")).unwrap();
        assert!(prom.contains("fft_jobs_completed_total{tenant=\"tenant-0\"}"), "{prom}");
        assert!(prom.contains("fft_job_latency_us_bucket"), "{prom}");
    }

    #[test]
    fn two_locality_mix_skips_oversized_entries() {
        let cfg = LoadConfig { localities: 2, ..LoadConfig::default() };
        assert!(menu(&cfg).iter().all(|r| {
            let t = r.clone().build().unwrap();
            t.localities() <= 2
        }));
    }
}
