//! Repetition driver: warmup + N measured reps → [`RunStats`].

use crate::metrics::RunStats;

/// Run `f` (returning a duration in µs) `warmup + reps` times; keep the
/// last `reps` as statistics — the paper's "averaged over 50 runs".
pub fn measure(warmup: usize, reps: usize, mut f: impl FnMut() -> f64) -> RunStats {
    assert!(reps > 0, "need at least one measured rep");
    for _ in 0..warmup {
        let _ = f();
    }
    RunStats::new((0..reps).map(|_| f()).collect())
}

/// Time a closure's wall clock in µs.
pub fn time_us(f: impl FnOnce()) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_reps() {
        let mut calls = 0;
        let stats = measure(2, 10, || {
            calls += 1;
            calls as f64
        });
        assert_eq!(calls, 12);
        assert_eq!(stats.n(), 10);
        // Warmup values (1, 2) excluded: samples are 3..=12.
        assert_eq!(stats.mean(), 7.5);
    }

    #[test]
    fn time_us_positive() {
        let us = time_us(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(us >= 2000.0, "{us}");
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_reps_rejected() {
        measure(0, 0, || 0.0);
    }
}
