//! Fig. 3 — chunk-size scaling of the *scatter* collective on two nodes.
//!
//! "In our chunk size benchmark, we use the scatter collective to
//! simulate two separate one-way communication channels between two
//! nodes." Per parcelport and chunk size, rank 0 scatters a chunk to
//! rank 1; the runtime is the root's scatter wall clock, averaged over
//! `reps` runs with 95% CI — the paper's exact methodology.
//!
//! Two modes per port:
//! - **live hybrid**: the real transport protocol (copies, framing,
//!   handshakes) plus the calibrated IB-HDR wire model;
//! - **model**: the closed-form cost-model prediction — the line the
//!   calibration in DESIGN.md §6 was fitted to.

use super::plot::{log_log_plot, Series};
use super::runner::measure;
use crate::collectives::Communicator;
use crate::config::BenchConfig;
use crate::hpx::parcel::Payload;
use crate::hpx::runtime::Cluster;
use crate::metrics::{csv::write_csv, RunStats};
use crate::parcelport::{NetModel, PortKind};

/// One measured point.
#[derive(Clone, Debug)]
pub struct ChunkPoint {
    pub port: PortKind,
    pub bytes: u64,
    pub live: RunStats,
    pub model_us: f64,
}

/// Run the full Fig. 3 sweep.
pub fn run(config: &BenchConfig) -> anyhow::Result<Vec<ChunkPoint>> {
    let net = NetModel::infiniband_hdr();
    let mut points = Vec::new();
    for port in PortKind::ALL {
        let cluster = Cluster::new(2, port, Some(net))?;
        for &bytes in &config.chunk_sizes {
            let stats = measure(config.warmup, config.reps, || {
                let times = cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    let t0 = std::time::Instant::now();
                    let chunks = (ctx.rank == 0).then(|| {
                        vec![Payload::new(vec![0u8; 8]), Payload::new(vec![0u8; bytes as usize])]
                    });
                    let _mine = comm.scatter(0, chunks);
                    t0.elapsed().as_secs_f64() * 1e6
                });
                // The root's send-side wall clock (channel view).
                times[0]
            });
            let model_us = net.message_time_us(&port.cost_model(), bytes);
            points.push(ChunkPoint { port, bytes, live: stats, model_us });
        }
    }
    Ok(points)
}

/// Paper-style report: table + ASCII figure + CSV.
pub fn report(points: &[ChunkPoint], out_dir: &str) -> anyhow::Result<String> {
    let mut table = crate::metrics::table::Table::new(&[
        "port", "chunk", "live mean", "±95% CI", "model",
    ]);
    let mut rows = Vec::new();
    for p in points {
        table.row(&[
            p.port.name().into(),
            human_bytes(p.bytes),
            format!("{:.1} µs", p.live.mean()),
            format!("{:.1}", p.live.ci95()),
            format!("{:.1} µs", p.model_us),
        ]);
        rows.push(vec![
            p.port.name().to_string(),
            p.bytes.to_string(),
            p.live.mean().to_string(),
            p.live.ci95().to_string(),
            p.model_us.to_string(),
        ]);
    }
    write_csv(
        format!("{out_dir}/fig3_chunk_size.csv"),
        &["port", "bytes", "live_mean_us", "live_ci95_us", "model_us"],
        &rows,
    )?;

    let series: Vec<Series> = PortKind::ALL
        .iter()
        .map(|&port| Series {
            label: format!("{port} (live hybrid)"),
            symbol: port.name().chars().next().unwrap().to_ascii_uppercase(),
            points: points
                .iter()
                .filter(|p| p.port == port)
                .map(|p| (p.bytes as f64, p.live.mean()))
                .collect(),
        })
        .collect();
    let mut out = String::new();
    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&log_log_plot(
        "Fig. 3 — chunk-size scaling, scatter on 2 nodes",
        "chunk size [bytes]",
        "runtime [µs]",
        &series,
    ));
    Ok(out)
}

pub fn human_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{} MiB", b >> 20)
    } else if b >= 1 << 10 {
        format!("{} KiB", b >> 10)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> BenchConfig {
        BenchConfig {
            reps: 3,
            warmup: 1,
            chunk_sizes: vec![1024, 64 * 1024],
            ..BenchConfig::quick()
        }
    }

    #[test]
    fn sweep_produces_all_points() {
        let points = run(&tiny_config()).unwrap();
        assert_eq!(points.len(), 3 * 2); // 3 ports × 2 sizes
        for p in &points {
            assert!(p.live.mean() > 0.0);
            assert!(p.model_us > 0.0);
        }
    }

    #[test]
    fn lci_fastest_at_small_chunks() {
        // The paper's Fig. 3 finding, in the live hybrid measurement.
        let points = run(&tiny_config()).unwrap();
        let t = |port: PortKind, bytes: u64| {
            points
                .iter()
                .find(|p| p.port == port && p.bytes == bytes)
                .unwrap()
                .live
                .mean()
        };
        assert!(t(PortKind::Lci, 1024) < t(PortKind::Tcp, 1024));
    }

    #[test]
    fn report_renders_and_writes_csv() {
        let dir = std::env::temp_dir().join(format!("hpxfft-fig3-{}", std::process::id()));
        let points = run(&tiny_config()).unwrap();
        let text = report(&points, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("Fig. 3"));
        assert!(dir.join("fig3_chunk_size.csv").exists());
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2 KiB");
        assert_eq!(human_bytes(16 << 20), "16 MiB");
    }
}
