//! Fig. 3 — chunk-size scaling of the *scatter* collective on two nodes.
//!
//! "In our chunk size benchmark, we use the scatter collective to
//! simulate two separate one-way communication channels between two
//! nodes." Per parcelport and chunk size, rank 0 scatters a chunk to
//! rank 1; the runtime is the root's scatter wall clock, averaged over
//! `reps` runs with 95% CI — the paper's exact methodology.
//!
//! Two modes per port:
//! - **live hybrid**: the real transport protocol (copies, framing,
//!   handshakes) plus the calibrated IB-HDR wire model;
//! - **model**: the closed-form cost-model prediction — the line the
//!   calibration in DESIGN.md §6 was fitted to.
//!
//! Each (port × payload size) point is measured for every
//! [`ScatterAlgo`]: `linear` is the paper's monolithic scatter, and
//! `pipelined` splits the payload into `config.pipeline.chunk_bytes`
//! wire chunks drained by the send pool — showing where pipelining
//! amortizes the per-message overheads the sweep exists to expose.

use super::plot::{log_log_plot, Series};
use super::runner::measure;
use crate::collectives::{Communicator, ScatterAlgo};
use crate::config::BenchConfig;
use crate::dist_fft::driver::ExecutionMode;
use crate::hpx::parcel::Payload;
use crate::hpx::runtime::Cluster;
use crate::metrics::{csv::write_csv, RunStats};
use crate::parcelport::{NetModel, PortKind};

/// One measured point.
#[derive(Clone, Debug)]
pub struct ChunkPoint {
    /// Parcelport measured.
    pub port: PortKind,
    /// Scatter algorithm measured (monolithic or pipelined).
    pub algo: ScatterAlgo,
    /// Execution mode measured (blocking call vs posted future).
    pub exec: ExecutionMode,
    /// Payload size, bytes.
    pub bytes: u64,
    /// Live hybrid measurement statistics.
    pub live: RunStats,
    /// Mean wall time between the async posting returning and the
    /// transfer completing — the window a caller could fill with compute,
    /// i.e. the wire time the port can hide. 0 in blocking mode, where
    /// the caller is parked for the whole transfer.
    pub overlap_us: f64,
    /// Closed-form cost-model prediction, µs.
    pub model_us: f64,
}

/// Run the full Fig. 3 sweep in the configured execution mode.
pub fn run(config: &BenchConfig) -> anyhow::Result<Vec<ChunkPoint>> {
    let net = NetModel::infiniband_hdr();
    let pipeline = config.pipeline;
    let exec = config.exec;
    let mut points = Vec::new();
    for port in PortKind::ALL {
        let cluster = Cluster::new(2, port, Some(net))?;
        for &bytes in &config.chunk_sizes {
            for algo in ScatterAlgo::ALL {
                // (total, posted) per rep, root's view: `posted` is when
                // control returned to the caller.
                let mut windows: Vec<f64> = Vec::new();
                let stats = measure(config.warmup, config.reps, || {
                    let times: Vec<(f64, f64)> = cluster.run(|ctx| {
                        let comm = Communicator::from_ctx(ctx);
                        comm.set_chunk_policy(pipeline);
                        // Spawn the send pool before the timer: thread
                        // creation is a communicator-lifetime cost, not
                        // per-scatter protocol work, and would otherwise
                        // dominate the µs-scale small-payload points.
                        comm.warm_chunk_pool();
                        let t0 = std::time::Instant::now();
                        let chunks = (ctx.rank == 0).then(|| {
                            vec![
                                Payload::new(vec![0u8; 8]),
                                Payload::new(vec![0u8; bytes as usize]),
                            ]
                        });
                        match exec {
                            ExecutionMode::Blocking => {
                                let _mine = comm.scatter_with_algo(0, chunks, algo);
                                let total = t0.elapsed().as_secs_f64() * 1e6;
                                (total, total)
                            }
                            ExecutionMode::Async => {
                                let coll = comm.scatter_async(0, chunks, algo);
                                let posted = t0.elapsed().as_secs_f64() * 1e6;
                                let _mine = coll.get();
                                (t0.elapsed().as_secs_f64() * 1e6, posted)
                            }
                        }
                    });
                    // The root's send-side wall clock (channel view).
                    let (total, posted) = times[0];
                    windows.push(total - posted);
                    total
                });
                // Match the RunStats discipline: warmup reps (recorded by
                // the closure like every call) are excluded from the mean.
                let measured = &windows[config.warmup.min(windows.len())..];
                let overlap_us =
                    measured.iter().sum::<f64>() / measured.len().max(1) as f64;
                let model_us = net.message_time_us(&port.cost_model(), bytes);
                points.push(ChunkPoint {
                    port,
                    algo,
                    exec,
                    bytes,
                    live: stats,
                    overlap_us,
                    model_us,
                });
            }
        }
    }
    Ok(points)
}

/// Paper-style report: table + ASCII figure + CSV.
pub fn report(points: &[ChunkPoint], out_dir: &str) -> anyhow::Result<String> {
    let mut table = crate::metrics::table::Table::new(&[
        "port", "algo", "exec", "chunk", "live mean", "±95% CI", "overlap", "model",
    ]);
    let mut rows = Vec::new();
    for p in points {
        table.row(&[
            p.port.name().into(),
            p.algo.name().into(),
            p.exec.name().into(),
            human_bytes(p.bytes),
            format!("{:.1} µs", p.live.mean()),
            format!("{:.1}", p.live.ci95()),
            crate::metrics::table::fmt_us(p.overlap_us),
            format!("{:.1} µs", p.model_us),
        ]);
        rows.push(vec![
            p.port.name().to_string(),
            p.algo.name().to_string(),
            p.exec.name().to_string(),
            p.bytes.to_string(),
            p.live.mean().to_string(),
            p.live.ci95().to_string(),
            p.overlap_us.to_string(),
            p.model_us.to_string(),
        ]);
    }
    write_csv(
        format!("{out_dir}/fig3_chunk_size.csv"),
        &["port", "algo", "exec", "bytes", "live_mean_us", "live_ci95_us", "overlap_us", "model_us"],
        &rows,
    )?;

    // One series per (port, algo): uppercase symbols for the monolithic
    // scatter, lowercase for the pipelined one.
    let mut series = Vec::new();
    for port in PortKind::ALL {
        for algo in ScatterAlgo::ALL {
            let symbol = port.name().chars().next().unwrap_or('?');
            series.push(Series {
                label: format!("{port}/{} (live hybrid)", algo.name()),
                symbol: if algo == ScatterAlgo::Linear {
                    symbol.to_ascii_uppercase()
                } else {
                    symbol
                },
                points: points
                    .iter()
                    .filter(|p| p.port == port && p.algo == algo)
                    .map(|p| (p.bytes as f64, p.live.mean()))
                    .collect(),
            });
        }
    }
    let mut out = String::new();
    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&log_log_plot(
        "Fig. 3 — chunk-size scaling, scatter on 2 nodes",
        "chunk size [bytes]",
        "runtime [µs]",
        &series,
    ));

    // Async sweeps: show how much of each port's wire time the posted
    // collective hides, at the largest measured payload.
    let async_points: Vec<&ChunkPoint> =
        points.iter().filter(|p| p.exec == ExecutionMode::Async).collect();
    if let Some(max_bytes) = async_points.iter().map(|p| p.bytes).max() {
        let bars: Vec<(String, f64, f64)> = async_points
            .iter()
            .filter(|p| p.bytes == max_bytes)
            .map(|p| {
                (format!("{}/{}", p.port.name(), p.algo.name()), p.overlap_us, p.live.mean())
            })
            .collect();
        out.push('\n');
        out.push_str(&super::plot::overlap_bars(
            &format!("wire time hidden by async posting @ {}", human_bytes(max_bytes)),
            &bars,
        ));
    }
    Ok(out)
}

/// Human-readable byte count (`512 B`, `2 KiB`, `16 MiB`).
pub fn human_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{} MiB", b >> 20)
    } else if b >= 1 << 10 {
        format!("{} KiB", b >> 10)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> BenchConfig {
        BenchConfig {
            reps: 3,
            warmup: 1,
            chunk_sizes: vec![1024, 64 * 1024],
            ..BenchConfig::quick()
        }
    }

    #[test]
    fn sweep_produces_all_points() {
        let points = run(&tiny_config()).unwrap();
        assert_eq!(points.len(), 3 * 2 * 2); // 3 ports × 2 sizes × 2 algos
        for p in &points {
            assert!(p.live.mean() > 0.0);
            assert!(p.model_us > 0.0);
        }
    }

    #[test]
    fn lci_fastest_at_small_chunks() {
        // The paper's Fig. 3 finding, in the live hybrid measurement.
        let points = run(&tiny_config()).unwrap();
        let t = |port: PortKind, bytes: u64| {
            points
                .iter()
                .find(|p| p.port == port && p.bytes == bytes && p.algo == ScatterAlgo::Linear)
                .unwrap()
                .live
                .mean()
        };
        assert!(t(PortKind::Lci, 1024) < t(PortKind::Tcp, 1024));
    }

    #[test]
    fn both_algorithms_measured_per_point() {
        let points = run(&tiny_config()).unwrap();
        for port in PortKind::ALL {
            for algo in ScatterAlgo::ALL {
                assert!(
                    points.iter().any(|p| p.port == port && p.algo == algo),
                    "missing {port}/{}",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn report_renders_and_writes_csv() {
        let dir = std::env::temp_dir().join(format!("hpxfft-fig3-{}", std::process::id()));
        let points = run(&tiny_config()).unwrap();
        let text = report(&points, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("Fig. 3"));
        assert!(dir.join("fig3_chunk_size.csv").exists());
        let csv = std::fs::read_to_string(dir.join("fig3_chunk_size.csv")).unwrap();
        assert!(csv.starts_with("port,algo,exec,bytes"), "{csv}");
        assert!(csv.contains("overlap_us"), "{csv}");
    }

    #[test]
    fn async_sweep_reports_posting_window() {
        let cfg = BenchConfig { exec: ExecutionMode::Async, ..tiny_config() };
        let points = run(&cfg).unwrap();
        assert!(points.iter().all(|p| p.exec == ExecutionMode::Async));
        // Posting returns before the transfer completes, so some window
        // must be visible at the 64 KiB point on at least one port.
        assert!(
            points.iter().any(|p| p.bytes == 64 * 1024 && p.overlap_us > 0.0),
            "no posting window measured: {points:?}"
        );
        let dir = std::env::temp_dir().join(format!("hpxfft-fig3a-{}", std::process::id()));
        let text = report(&points, dir.to_str().unwrap()).unwrap();
        assert!(text.contains("hidden"), "async report shows the overlap bars");
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2 KiB");
        assert_eq!(human_bytes(16 << 20), "16 MiB");
    }
}
