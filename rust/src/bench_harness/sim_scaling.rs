//! Cluster-scale slope validation of the event-engine simulator.
//!
//! Runs the real protocol machines (via
//! [`crate::simnet::collective_sim`]) at 512–4096 simulated localities
//! on the paper's strong-scaling problems and compares the scaling
//! *slope* against the closed-form [`crate::simnet::sim`] engine on the
//! same communication pattern:
//!
//! - `fig4` — the HPX root-funneled all-to-all (incast-bound) on the
//!   2^14 × 2^14 transpose,
//! - `fig5` — the paper's N-scatter (per-rank pipelined fan-out),
//! - `fig6` — the 3-D pencil transposes: two pairwise rounds within
//!   row/column sub-communicator groups on a near-square process grid
//!   over the 2^9³ grid.
//!
//! The closed-form reference is run with a zeroed [`ComputeModel`]
//! ([`comm_only`]) so both engines predict pure communication over the
//! identical [`crate::parcelport::cost`] model; what must then agree is
//! the log₂-log₂ slope between consecutive locality counts
//! ([`validate_slopes`]). Absolute times still differ slightly (the
//! event engine charges the machines' actual message schedules and
//! framing headers), which is why the check is on slopes, not values.
//!
//! Results land in `sim_scaling.csv` with one row per (figure,
//! locality-count) point; columns are documented on
//! [`SimScalingRow::COLUMNS`] and in the README.

use anyhow::{ensure, Context};

use super::plot::{log_log_plot, Series};
use crate::collectives::{AllToAllAlgo, ChunkPolicy};
use crate::dist_fft::grid3::{Grid3, PencilDims, ProcGrid};
use crate::metrics::csv::write_csv;
use crate::metrics::table::Table;
use crate::parcelport::{NetModel, PortKind};
use crate::simnet::adversary::AdversaryConfig;
use crate::simnet::collective_sim::{run_sim, SimCollective, SimConfig, SimData};
use crate::simnet::compute::ComputeModel;
use crate::simnet::engine::EngineStats;
use crate::simnet::fft_model::{
    predict_fft, predict_pencil3, FftModelParams, ModelVariant, Pencil3ModelParams,
};

/// Which figure's communication pattern a point simulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimFig {
    /// Root-funneled all-to-all (paper Fig. 4).
    Fig4,
    /// N-scatter (paper Fig. 5).
    Fig5,
    /// Pencil transpose rounds (paper Fig. 6).
    Fig6,
}

impl SimFig {
    /// All figures, in presentation order.
    pub const ALL: [SimFig; 3] = [SimFig::Fig4, SimFig::Fig5, SimFig::Fig6];

    /// CSV/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            SimFig::Fig4 => "fig4",
            SimFig::Fig5 => "fig5",
            SimFig::Fig6 => "fig6",
        }
    }
}

impl std::str::FromStr for SimFig {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fig4" | "all-to-all" => Ok(SimFig::Fig4),
            "fig5" | "scatter" => Ok(SimFig::Fig5),
            "fig6" | "pencil" => Ok(SimFig::Fig6),
            other => Err(format!("unknown sim figure '{other}' (fig4|fig5|fig6)")),
        }
    }
}

/// One harness invocation.
#[derive(Clone, Debug)]
pub struct SimScalingOpts {
    /// Figures to sweep.
    pub figs: Vec<SimFig>,
    /// Simulated locality counts (powers of two dividing 2^14).
    pub localities: Vec<usize>,
    /// Port cost model to charge.
    pub port: PortKind,
    /// Adversary applied to every point (its seed is the run seed).
    pub adversary: AdversaryConfig,
    /// Directory for `sim_scaling.csv` (skipped when `None`).
    pub out_dir: Option<String>,
}

impl Default for SimScalingOpts {
    fn default() -> Self {
        Self {
            figs: SimFig::ALL.to_vec(),
            localities: vec![512, 1024, 2048],
            port: PortKind::Lci,
            adversary: AdversaryConfig::none(42),
            out_dir: None,
        }
    }
}

/// One (figure, locality-count) point.
#[derive(Clone, Debug)]
pub struct SimScalingRow {
    /// Figure pattern simulated.
    pub fig: SimFig,
    /// Simulated locality count.
    pub localities: usize,
    /// Bytes each pair exchanges in the simulated collective.
    pub per_pair_bytes: u64,
    /// Event-engine makespan and counters.
    pub stats: EngineStats,
    /// Closed-form comm-only prediction for the same pattern, µs.
    pub model_us: f64,
}

impl SimScalingRow {
    /// `sim_scaling.csv` column order: figure name, port, locality
    /// count, adversary seed, adversary summary
    /// (`delay/dup/drop/slow` percentages), per-pair payload bytes,
    /// event-engine makespan (µs), closed-form comm-only makespan (µs),
    /// wire bytes, retransmitted bytes, duplicates dropped, drops
    /// injected, heap events processed, and the run's trace hash (hex).
    pub const COLUMNS: [&'static str; 14] = [
        "fig",
        "port",
        "localities",
        "seed",
        "adversary",
        "per_pair_bytes",
        "makespan_us",
        "model_us",
        "wire_bytes",
        "retransmitted_bytes",
        "duplicates_dropped",
        "drops_injected",
        "events",
        "trace_hash",
    ];

    /// Render this row for `sim_scaling.csv`, in [`Self::COLUMNS`]
    /// order.
    pub fn csv_cells(&self, opts: &SimScalingOpts) -> Vec<String> {
        let a = &opts.adversary;
        vec![
            self.fig.name().to_string(),
            opts.port.to_string(),
            self.localities.to_string(),
            a.seed.to_string(),
            format!(
                "delay{}/dup{}/drop{}/slow{}",
                a.delay_prob_pct, a.dup_prob_pct, a.drop_prob_pct, a.slow_rank_pct
            ),
            self.per_pair_bytes.to_string(),
            self.stats.makespan_us.to_string(),
            self.model_us.to_string(),
            self.stats.wire_bytes.to_string(),
            self.stats.retransmitted_bytes.to_string(),
            self.stats.duplicates_dropped.to_string(),
            self.stats.drops_injected.to_string(),
            self.stats.events.to_string(),
            format!("{:016x}", self.stats.trace_hash),
        ]
    }
}

/// A compute model that charges (effectively) nothing, turning the
/// closed-form predictions into pure-communication references the
/// comm-only event engine can be slope-compared against.
fn comm_only() -> ComputeModel {
    ComputeModel { flops_per_core: 1e30, cores: 1, parallel_efficiency: 1.0, copy_gbps: 1e30 }
}

/// Largest power-of-two `pr ≤ √n` dividing `n` — the near-square
/// process grid the pencil sweep uses.
fn near_square(n: usize) -> ProcGrid {
    let mut pr = 1usize;
    let mut best = 1usize;
    while pr <= n {
        if n % pr == 0 && pr * pr <= n {
            best = pr;
        }
        pr *= 2;
    }
    ProcGrid::new(best, n / best)
}

fn sim_cfg(coll: SimCollective, n: usize, per_pair: u64, opts: &SimScalingOpts) -> SimConfig {
    SimConfig {
        localities: n,
        port: opts.port,
        net: NetModel::infiniband_hdr(),
        // One wire chunk per transfer at cluster scale: event counts
        // stay linear in the message count, sizes stay exact.
        policy: ChunkPolicy::new(per_pair.max(1) as usize, 4),
        adversary: opts.adversary,
        collective: coll,
        data: SimData::Uniform(per_pair),
    }
}

fn sim_one(coll: SimCollective, n: usize, per_pair: u64, opts: &SimScalingOpts) -> EngineStats {
    run_sim(&sim_cfg(coll, n, per_pair, opts)).stats
}

/// Capture and export the wire timeline of one representative sweep
/// point — the first requested figure at the *smallest* requested
/// locality count (512 under the default list, still cluster scale but
/// bounding the capture: fig5's N-scatter is O(n²) messages). Returns
/// the written path. The traced run is a separate engine instance, so
/// the sweep's own rows — and `sim_scaling.csv` — are untouched.
pub fn export_trace(opts: &SimScalingOpts, dir: &str) -> anyhow::Result<String> {
    use crate::simnet::collective_sim::run_sim_traced;
    ensure!(!opts.figs.is_empty() && !opts.localities.is_empty(), "nothing swept");
    let fig = opts.figs[0];
    let n = *opts
        .localities
        .iter()
        .min()
        .ok_or_else(|| anyhow::anyhow!("--localities-list must name at least one count"))?;
    let cfg = match fig {
        SimFig::Fig4 => {
            let per_pair = FftModelParams::paper(n).chunk_bytes();
            sim_cfg(SimCollective::AllToAll(AllToAllAlgo::HpxRoot), n, per_pair, opts)
        }
        SimFig::Fig5 => {
            let per_pair = FftModelParams::paper(n).chunk_bytes();
            sim_cfg(SimCollective::NScatter, n, per_pair, opts)
        }
        SimFig::Fig6 => {
            // The row-transpose round within one sub-communicator group
            // (disjoint groups are identical and parallel).
            let proc = near_square(n);
            let dims = PencilDims::new(Grid3::new(1 << 9, 1 << 9, 1 << 9), proc)
                .with_context(|| format!("--localities-list value {n}: pencil grid {proc}"))?;
            let t1 = (dims.t1_chunk_elems() * 8) as u64;
            sim_cfg(SimCollective::AllToAll(AllToAllAlgo::Pairwise), proc.pc, t1, opts)
        }
    };
    let (_, events) = run_sim_traced(&cfg);
    let path = format!("{dir}/sim_{}_{n}.trace.json", fig.name());
    crate::obs::chrome::export(&events, &path)
        .with_context(|| format!("writing sim trace {path}"))?;
    Ok(path)
}

fn point(fig: SimFig, n: usize, opts: &SimScalingOpts) -> anyhow::Result<SimScalingRow> {
    Ok(match fig {
        SimFig::Fig4 => {
            let mut params = FftModelParams::paper(n);
            params.compute = comm_only();
            let per_pair = params.chunk_bytes();
            let coll = SimCollective::AllToAll(AllToAllAlgo::HpxRoot);
            let stats = sim_one(coll, n, per_pair, opts);
            let variant = ModelVariant::AllToAll(AllToAllAlgo::HpxRoot);
            let model_us = predict_fft(&params, opts.port, variant).makespan_us;
            SimScalingRow { fig, localities: n, per_pair_bytes: per_pair, stats, model_us }
        }
        SimFig::Fig5 => {
            let mut params = FftModelParams::paper(n);
            params.compute = comm_only();
            let per_pair = params.chunk_bytes();
            let stats = sim_one(SimCollective::NScatter, n, per_pair, opts);
            let model_us = predict_fft(&params, opts.port, ModelVariant::Scatter).makespan_us;
            SimScalingRow { fig, localities: n, per_pair_bytes: per_pair, stats, model_us }
        }
        SimFig::Fig6 => {
            // Two transpose rounds, each a pairwise exchange within its
            // sub-communicator group; disjoint groups run in parallel,
            // so simulating one group per round is exact. Chunk sizes
            // come straight from the pencil decomposition.
            let proc = near_square(n);
            let dims = PencilDims::new(Grid3::new(1 << 9, 1 << 9, 1 << 9), proc)
                .with_context(|| format!("--localities-list value {n}: pencil grid {proc}"))?;
            let t1 = (dims.t1_chunk_elems() * 8) as u64;
            let t2 = (dims.t2_chunk_elems() * 8) as u64;
            let coll = SimCollective::AllToAll(AllToAllAlgo::Pairwise);
            let row_round = sim_one(coll, proc.pc, t1, opts);
            let col_round = sim_one(coll, proc.pr, t2, opts);
            let mut stats = row_round;
            stats.makespan_us += col_round.makespan_us;
            stats.max_blocked_us += col_round.max_blocked_us;
            stats.wire_bytes += col_round.wire_bytes;
            stats.retransmitted_bytes += col_round.retransmitted_bytes;
            stats.duplicates_dropped += col_round.duplicates_dropped;
            stats.drops_injected += col_round.drops_injected;
            stats.events += col_round.events;
            stats.trace_hash ^= col_round.trace_hash.rotate_left(1);
            let params =
                Pencil3ModelParams { compute: comm_only(), ..Pencil3ModelParams::paper(proc) };
            let model_us = predict_pencil3(&params, opts.port).makespan_us;
            SimScalingRow { fig, localities: n, per_pair_bytes: t1, stats, model_us }
        }
    })
}

/// log₂-log₂ slope between two `(n, t)` points.
fn slope(a: (usize, f64), b: (usize, f64)) -> f64 {
    (b.1 / a.1).log2() / (b.0 as f64 / a.0 as f64).log2()
}

/// Check that each figure's simulated scaling slope tracks the
/// closed-form comm-only model's slope within `tol` (log₂ units)
/// between every consecutive pair of locality counts.
pub fn validate_slopes(rows: &[SimScalingRow], tol: f64) -> anyhow::Result<()> {
    for fig in SimFig::ALL {
        let mut pts: Vec<&SimScalingRow> = rows.iter().filter(|r| r.fig == fig).collect();
        pts.sort_by_key(|r| r.localities);
        for w in pts.windows(2) {
            let sim = slope(
                (w[0].localities, w[0].stats.makespan_us),
                (w[1].localities, w[1].stats.makespan_us),
            );
            let model = slope((w[0].localities, w[0].model_us), (w[1].localities, w[1].model_us));
            ensure!(
                (sim - model).abs() <= tol,
                "{} slope diverges from the model between n={} and n={}: \
                 event-engine {sim:.3} vs closed-form {model:.3} (tol {tol})",
                fig.name(),
                w[0].localities,
                w[1].localities,
            );
        }
    }
    Ok(())
}

/// Run the sweep, print the paper-style table and log-log plot, and
/// write `sim_scaling.csv` when an output directory is given.
pub fn run(opts: &SimScalingOpts) -> anyhow::Result<Vec<SimScalingRow>> {
    ensure!(!opts.localities.is_empty(), "need at least one locality count");
    ensure!(!opts.figs.is_empty(), "need at least one figure (fig4|fig5|fig6)");
    for &n in &opts.localities {
        ensure!(
            n >= 2 && n.is_power_of_two() && (1usize << 14) % n == 0,
            "locality count {n} must be a power of two dividing 2^14"
        );
    }

    let mut rows = Vec::new();
    for &fig in &opts.figs {
        for &n in &opts.localities {
            rows.push(point(fig, n, opts)?);
        }
    }

    let mut table = Table::new(&[
        "fig", "localities", "sim [ms]", "model [ms]", "wire", "retrans", "dups", "events",
    ]);
    for r in &rows {
        table.row(&[
            r.fig.name().to_string(),
            r.localities.to_string(),
            format!("{:.3}", r.stats.makespan_us / 1e3),
            format!("{:.3}", r.model_us / 1e3),
            super::fig3::human_bytes(r.stats.wire_bytes),
            super::fig3::human_bytes(r.stats.retransmitted_bytes),
            r.stats.duplicates_dropped.to_string(),
            r.stats.events.to_string(),
        ]);
    }
    println!("{}", table.render());

    let series: Vec<Series> = opts
        .figs
        .iter()
        .map(|&fig| Series {
            label: format!("{} (event engine)", fig.name()),
            symbol: match fig {
                SimFig::Fig4 => 'o',
                SimFig::Fig5 => 'x',
                SimFig::Fig6 => '#',
            },
            points: rows
                .iter()
                .filter(|r| r.fig == fig)
                .map(|r| (r.localities as f64, r.stats.makespan_us))
                .collect(),
        })
        .collect();
    println!(
        "{}",
        log_log_plot("event-engine scaling sweep", "localities", "makespan [µs]", &series)
    );

    if let Some(dir) = &opts.out_dir {
        let cells: Vec<Vec<String>> = rows.iter().map(|r| r.csv_cells(opts)).collect();
        let path = format!("{dir}/sim_scaling.csv");
        write_csv(&path, &SimScalingRow::COLUMNS, &cells)
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts_for(figs: Vec<SimFig>, localities: Vec<usize>) -> SimScalingOpts {
        SimScalingOpts {
            figs,
            localities,
            port: PortKind::Lci,
            adversary: AdversaryConfig::none(42),
            out_dir: None,
        }
    }

    #[test]
    fn slopes_track_the_comm_only_model_at_cluster_scale() {
        let opts = opts_for(vec![SimFig::Fig4, SimFig::Fig6], vec![512, 1024]);
        let rows = run(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        validate_slopes(&rows, 0.5).unwrap();
    }

    #[test]
    #[ignore = "full three-figure 512-2048 sweep; run with --ignored --release"]
    fn full_sweep_slopes_all_figures() {
        let rows = run(&opts_for(SimFig::ALL.to_vec(), vec![512, 1024, 2048])).unwrap();
        validate_slopes(&rows, 0.5).unwrap();
    }

    /// Satellite regression: the same seed and config must produce the
    /// identical `sim_scaling.csv` row — trace hash included — across
    /// two full harness runs.
    #[test]
    fn csv_rows_are_bit_identical_across_runs() {
        let opts = SimScalingOpts {
            adversary: AdversaryConfig::hostile(7),
            ..opts_for(vec![SimFig::Fig4, SimFig::Fig5], vec![16, 32])
        };
        let a: Vec<Vec<String>> = run(&opts).unwrap().iter().map(|r| r.csv_cells(&opts)).collect();
        let b: Vec<Vec<String>> = run(&opts).unwrap().iter().map(|r| r.csv_cells(&opts)).collect();
        assert_eq!(a, b, "sim_scaling.csv rows must be reproducible from the seed");
    }

    /// The representative-point trace export writes a valid Chrome
    /// trace and leaves the sweep itself untouched (it runs a separate
    /// engine instance).
    #[test]
    fn export_trace_writes_valid_chrome_json() {
        let dir = std::env::temp_dir().join(format!("hpxfft-simtr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = opts_for(vec![SimFig::Fig4], vec![16]);
        let path = export_trace(&opts, dir.to_str().unwrap()).unwrap();
        let summary = crate::obs::chrome::validate_file(&path).unwrap();
        assert!(summary.spans > 0, "a 16-rank all-to-all must record wire spans");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn near_square_grids() {
        assert_eq!(near_square(512), ProcGrid::new(16, 32));
        assert_eq!(near_square(1024), ProcGrid::new(32, 32));
        assert_eq!(near_square(2048), ProcGrid::new(32, 64));
        assert_eq!(near_square(4096), ProcGrid::new(64, 64));
    }

    #[test]
    fn rejects_bad_locality_counts() {
        let mut opts = opts_for(vec![SimFig::Fig4], vec![48]);
        assert!(run(&opts).is_err());
        opts.localities = vec![];
        assert!(run(&opts).is_err());
    }
}
