//! Broadcast — the blocking wrapper over the futures engine.
//!
//! The binomial-tree schedule itself lives in
//! [`crate::collectives::nonblocking`] (`broadcast_async`); keeping a
//! second copy here invited silent divergence between the blocking and
//! async trees, so the blocking call is just `.get()` on the posted one.

use super::comm::Communicator;
use crate::hpx::parcel::Payload;

impl Communicator {
    /// Binomial-tree broadcast from `root`. Non-roots pass `None`.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::broadcast_async`]`.get()`.
    pub fn broadcast(&self, root: usize, data: Option<Payload>) -> Payload {
        self.broadcast_async(root, data).get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    fn bcast_n(n: usize, root: usize, kind: PortKind) {
        let cluster = Cluster::new(n, kind, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let data = (ctx.rank == root).then(|| Payload::from_f32(&[root as f32, 42.0]));
            comm.broadcast(root, data).to_f32()
        });
        for g in got {
            assert_eq!(g, vec![root as f32, 42.0]);
        }
    }

    #[test]
    fn bcast_all_roots_pow2() {
        for root in 0..4 {
            bcast_n(4, root, PortKind::Lci);
        }
    }

    #[test]
    fn bcast_all_roots_non_pow2() {
        for root in 0..5 {
            bcast_n(5, root, PortKind::Lci);
        }
    }

    #[test]
    fn bcast_over_mpi_and_tcp() {
        bcast_n(6, 2, PortKind::Mpi);
        bcast_n(3, 1, PortKind::Tcp);
    }

    #[test]
    fn bcast_single_rank() {
        bcast_n(1, 0, PortKind::Lci);
    }

    #[test]
    fn bcast_large_payload() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        let lens = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let data = (ctx.rank == 0).then(|| Payload::new(vec![7u8; 300_000]));
            comm.broadcast(0, data).len()
        });
        assert_eq!(lens, vec![300_000; 4]);
    }
}
