//! Broadcast and gather-family collectives (binomial tree / linear).

use super::comm::Communicator;
use crate::hpx::parcel::Payload;

impl Communicator {
    /// Binomial-tree broadcast from `root`. Non-roots pass `None`.
    pub fn broadcast(&self, root: usize, data: Option<Payload>) -> Payload {
        assert!(root < self.size(), "root {root} out of range");
        let tag = self.alloc_tags();
        let n = self.size();
        // Rotate ranks so the root sits at virtual rank 0.
        let vrank = (self.rank() + n - root) % n;

        let mut payload = if self.rank() == root {
            Some(data.expect("root must provide data"))
        } else {
            assert!(data.is_none(), "non-root rank {} passed data", self.rank());
            None
        };

        // Receive from parent: vrank with its highest set bit cleared.
        // (Tree invariant: child c = parent + 2^k with 2^k > parent, so
        // clearing c's top bit recovers the parent uniquely.)
        if vrank != 0 {
            let mask = 1 << (usize::BITS - 1 - vrank.leading_zeros());
            let parent = ((vrank ^ mask) + root) % n;
            payload = Some(self.recv(parent, tag));
        }

        // Forward to children: vrank + 2^k for 2^k > vrank's highest bit.
        let payload = payload.expect("broadcast payload resolved");
        let start = if vrank == 0 {
            1
        } else {
            1 << (usize::BITS - vrank.leading_zeros()) // next power of two above vrank
        };
        let mut step = start;
        while vrank + step < n {
            let child = ((vrank + step) + root) % n;
            self.send(child, tag, payload.clone());
            step <<= 1;
        }
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    fn bcast_n(n: usize, root: usize, kind: PortKind) {
        let cluster = Cluster::new(n, kind, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let data = (ctx.rank == root).then(|| Payload::from_f32(&[root as f32, 42.0]));
            comm.broadcast(root, data).to_f32()
        });
        for g in got {
            assert_eq!(g, vec![root as f32, 42.0]);
        }
    }

    #[test]
    fn bcast_all_roots_pow2() {
        for root in 0..4 {
            bcast_n(4, root, PortKind::Lci);
        }
    }

    #[test]
    fn bcast_all_roots_non_pow2() {
        for root in 0..5 {
            bcast_n(5, root, PortKind::Lci);
        }
    }

    #[test]
    fn bcast_over_mpi_and_tcp() {
        bcast_n(6, 2, PortKind::Mpi);
        bcast_n(3, 1, PortKind::Tcp);
    }

    #[test]
    fn bcast_single_rank() {
        bcast_n(1, 0, PortKind::Lci);
    }

    #[test]
    fn bcast_large_payload() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        let lens = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let data = (ctx.rank == 0).then(|| Payload::new(vec![7u8; 300_000]));
            comm.broadcast(0, data).len()
        });
        assert_eq!(lens, vec![300_000; 4]);
    }
}
