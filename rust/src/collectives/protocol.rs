//! Event-driven protocol state machines for the collective algorithms.
//!
//! Each collective endpoint (one rank's half of a linear, pairwise,
//! Bruck, root-funneled, or chunk-pipelined exchange) is expressed as a
//! [`Machine`]: a resumable state machine that asks its driver to
//! perform one [`Action`] at a time — send a message, wait for a
//! matched receive, or hand a completed chunk to the application. The
//! blocking collectives in [`super::all_to_all`], [`super::scatter`],
//! and [`super::chunked`] run these machines against the live
//! parcelport fabric via [`drive`]; the discrete-event simulator
//! ([`crate::simnet::collective_sim`]) runs the *same* machines against
//! simulated NICs and links, so a protocol bug caught under a hostile
//! simulated schedule is a bug in the code real runs execute.
//!
//! Messages are abstracted behind [`Wire`] so the live driver moves
//! real [`Payload`] bytes while the simulator can either carry bytes
//! (for oracle validation) or just sizes (for cluster-scale timing
//! runs). The framing methods on [`Wire`] reproduce the existing wire
//! formats byte-for-byte — Bruck's indexed blocks, the root-funnel's
//! row/column lists, and the 8-byte chunked-transfer header.

use std::sync::Arc;

use super::all_to_all::pairwise_peers;
use super::chunked::ChunkPolicy;
use super::comm::Communicator;
use super::tags::CHUNK_TAG_SPAN;
use crate::hpx::parcel::{actions, Parcel, Payload, Tag};
use crate::task::TaskFuture;
use crate::util::bytes::{get_u32, get_u64, put_u32, put_u64};

/// A message body a protocol machine can move: real bytes on the live
/// fabric, bytes-or-sizes in the simulator.
pub trait Wire: Clone + Sized {
    /// The empty message.
    fn empty() -> Self;

    /// Bytes this message occupies on the wire.
    fn wire_len(&self) -> usize;

    /// Sub-range view of `len` bytes starting at `off` (zero-copy for
    /// [`Payload`]).
    fn slice(&self, off: usize, len: usize) -> Self;

    /// Reassemble ordered parts: zero parts yield the empty message,
    /// one part passes through unchanged (zero-copy), several are
    /// concatenated byte-wise.
    fn concat(parts: Vec<Self>) -> Self;

    /// The 8-byte chunked-transfer header announcing `total` bytes.
    fn header(total: u64) -> Self;

    /// Total length recorded in a header built by [`Wire::header`].
    fn header_total(&self) -> u64;

    /// Bruck frame: `[count u32]`, then `[index u32][len u64][bytes]`
    /// per block.
    fn frame_indexed(blocks: &[(u32, Self)]) -> Self;

    /// Decode a [`Wire::frame_indexed`] frame.
    fn unframe_indexed(&self) -> Vec<(u32, Self)>;

    /// Row/column frame: `[count u32]`, then `[len u64][bytes]` per
    /// part.
    fn frame_list(parts: &[Self]) -> Self;

    /// Decode a [`Wire::frame_list`] frame.
    fn unframe_list(&self) -> Vec<Self>;
}

impl Wire for Payload {
    fn empty() -> Self {
        Payload::empty()
    }

    fn wire_len(&self) -> usize {
        self.len()
    }

    fn slice(&self, off: usize, len: usize) -> Self {
        Payload::slice(self, off, len)
    }

    fn concat(mut parts: Vec<Self>) -> Self {
        match parts.len() {
            0 => Payload::empty(),
            1 => parts.pop().expect("one part"),
            _ => {
                let total = parts.iter().map(Payload::len).sum();
                let mut buf = Vec::with_capacity(total);
                for p in &parts {
                    buf.extend_from_slice(p.as_bytes());
                }
                Payload::new(buf)
            }
        }
    }

    fn header(total: u64) -> Self {
        let mut h = Vec::with_capacity(8);
        put_u64(&mut h, total);
        Payload::new(h)
    }

    fn header_total(&self) -> u64 {
        let mut off = 0;
        get_u64(self.as_bytes(), &mut off)
    }

    fn frame_indexed(blocks: &[(u32, Self)]) -> Self {
        let mut frame = Vec::new();
        put_u32(&mut frame, blocks.len() as u32);
        for (j, b) in blocks {
            put_u32(&mut frame, *j);
            put_u64(&mut frame, b.len() as u64);
            frame.extend_from_slice(b.as_bytes());
        }
        Payload::new(frame)
    }

    fn unframe_indexed(&self) -> Vec<(u32, Self)> {
        let buf = self.as_bytes();
        let mut off = 0;
        let count = get_u32(buf, &mut off) as usize;
        (0..count)
            .map(|_| {
                let j = get_u32(buf, &mut off);
                let len = get_u64(buf, &mut off) as usize;
                let part = Payload::new(buf[off..off + len].to_vec());
                off += len;
                (j, part)
            })
            .collect()
    }

    fn frame_list(parts: &[Self]) -> Self {
        let mut frame = Vec::new();
        put_u32(&mut frame, parts.len() as u32);
        for p in parts {
            put_u64(&mut frame, p.len() as u64);
            frame.extend_from_slice(p.as_bytes());
        }
        Payload::new(frame)
    }

    fn unframe_list(&self) -> Vec<Self> {
        let buf = self.as_bytes();
        let mut off = 0;
        let count = get_u32(buf, &mut off) as usize;
        (0..count)
            .map(|_| {
                let len = get_u64(buf, &mut off) as usize;
                let part = Payload::new(buf[off..off + len].to_vec());
                off += len;
                part
            })
            .collect()
    }
}

/// One instruction a protocol machine asks its driver to perform.
#[derive(Debug)]
pub enum Action<B> {
    /// Transmit `msg` to rank `to` on `tag`. `bulk` marks chunk-data
    /// sends the live driver dispatches through the communicator's
    /// chunk pool; headers and monolithic messages go inline so
    /// per-pair protocol ordering is preserved.
    Send {
        /// Destination rank within the communicator.
        to: usize,
        /// Wire tag.
        tag: Tag,
        /// Message to transmit.
        msg: B,
        /// Pool-dispatched chunk data (`true`) vs inline protocol
        /// message (`false`).
        bulk: bool,
    },
    /// Block until the message from `from` on `tag` arrives, then hand
    /// it to [`Machine::deliver`]. A machine re-emits the same `Recv`
    /// until the delivery happens, so drivers may park it and re-step
    /// later.
    Recv {
        /// Source rank within the communicator.
        from: usize,
        /// Wire tag.
        tag: Tag,
    },
    /// Wait for whichever listed `(from, tag)` message arrives first
    /// and deliver it — the N-scatter drain pattern. The candidate
    /// list is in deterministic rank order.
    RecvAny(Vec<(usize, Tag)>),
    /// Emit an application-level chunk: data belonging to slot `src`
    /// at byte offset `off` — the streaming hand-off of the chunked
    /// protocols.
    Chunk {
        /// Source rank the data belongs to.
        src: usize,
        /// Byte offset within that source's full message.
        off: usize,
        /// The chunk itself.
        msg: B,
    },
    /// The machine has finished; call [`Machine::finish`].
    Done,
}

/// An event-driven collective protocol endpoint for one rank.
///
/// Drivers repeatedly call [`Machine::step`] and perform the returned
/// [`Action`]; after a `Recv`/`RecvAny` they must hand the matched
/// message to [`Machine::deliver`] before stepping again. Receive
/// states are idempotent — stepping again without a delivery re-asks
/// for the same message — which lets the simulator park a machine and
/// resume it when the event engine delivers. Send-emitting steps
/// advance state before returning, so each send happens exactly once.
pub trait Machine<B: Wire> {
    /// What the collective returns on this rank.
    type Output;

    /// Next action for the driver.
    fn step(&mut self) -> Action<B>;

    /// Hand a matched message to the machine.
    fn deliver(&mut self, from: usize, tag: Tag, msg: B);

    /// Consume the machine after [`Action::Done`].
    fn finish(self) -> Self::Output;
}

/// [`super::AllToAllAlgo::Linear`] endpoint: post every send on one
/// shared tag, then receive per source in rank order.
pub struct LinearA2a<B> {
    me: usize,
    n: usize,
    tag: Tag,
    chunks: Vec<Option<B>>,
    out: Vec<Option<B>>,
    cursor: usize,
}

impl<B: Wire> LinearA2a<B> {
    /// Endpoint for rank `me` of `n`, exchanging `chunks` on `tag`.
    pub fn new(me: usize, n: usize, tag: Tag, chunks: Vec<B>) -> Self {
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        let mut chunks: Vec<Option<B>> = chunks.into_iter().map(Some).collect();
        let mut out: Vec<Option<B>> = (0..n).map(|_| None).collect();
        out[me] = chunks[me].take();
        Self { me, n, tag, chunks, out, cursor: 0 }
    }
}

impl<B: Wire> Machine<B> for LinearA2a<B> {
    type Output = Vec<B>;

    fn step(&mut self) -> Action<B> {
        while self.cursor < self.n {
            let dst = self.cursor;
            self.cursor += 1;
            if dst != self.me {
                let msg = self.chunks[dst].take().expect("chunk unsent");
                return Action::Send { to: dst, tag: self.tag, msg, bulk: false };
            }
        }
        while self.cursor < 2 * self.n {
            let src = self.cursor - self.n;
            if src == self.me {
                self.cursor += 1;
                continue;
            }
            return Action::Recv { from: src, tag: self.tag };
        }
        Action::Done
    }

    fn deliver(&mut self, from: usize, _tag: Tag, msg: B) {
        debug_assert_eq!(from, self.cursor - self.n);
        self.out[from] = Some(msg);
        self.cursor += 1;
    }

    fn finish(self) -> Vec<B> {
        self.out.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

/// [`super::AllToAllAlgo::Pairwise`] endpoint: `n - 1` rounds of
/// send/recv against XOR (power-of-two) or ring-offset peers, one tag
/// per round.
pub struct PairwiseA2a<B> {
    me: usize,
    n: usize,
    tag: Tag,
    chunks: Vec<Option<B>>,
    out: Vec<Option<B>>,
    round: usize,
    sent: bool,
}

impl<B: Wire> PairwiseA2a<B> {
    /// Endpoint for rank `me` of `n`, exchanging `chunks` on the tag
    /// block starting at `tag`.
    pub fn new(me: usize, n: usize, tag: Tag, chunks: Vec<B>) -> Self {
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        let mut chunks: Vec<Option<B>> = chunks.into_iter().map(Some).collect();
        let mut out: Vec<Option<B>> = (0..n).map(|_| None).collect();
        out[me] = chunks[me].take();
        Self { me, n, tag, chunks, out, round: 1, sent: false }
    }
}

impl<B: Wire> Machine<B> for PairwiseA2a<B> {
    type Output = Vec<B>;

    fn step(&mut self) -> Action<B> {
        if self.round >= self.n {
            return Action::Done;
        }
        let (to, from) = pairwise_peers(self.me, self.n, self.round);
        let tag = self.tag + self.round as Tag;
        if !self.sent {
            self.sent = true;
            let msg = self.chunks[to].take().expect("chunk unsent");
            return Action::Send { to, tag, msg, bulk: false };
        }
        Action::Recv { from, tag }
    }

    fn deliver(&mut self, from: usize, _tag: Tag, msg: B) {
        self.out[from] = Some(msg);
        self.round += 1;
        self.sent = false;
    }

    fn finish(self) -> Vec<B> {
        self.out.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

/// [`super::AllToAllAlgo::Bruck`] endpoint: log₂(n) rounds of framed
/// block exchange over rotated slots, with the inverse rotation applied
/// at [`Machine::finish`].
pub struct BruckA2a<B> {
    me: usize,
    n: usize,
    tag: Tag,
    slots: Vec<B>,
    step_size: usize,
    round: Tag,
    sent: bool,
}

impl<B: Wire> BruckA2a<B> {
    /// Endpoint for rank `me` of `n`, exchanging `chunks` on the tag
    /// block starting at `tag`.
    pub fn new(me: usize, n: usize, tag: Tag, chunks: Vec<B>) -> Self {
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        // Rotate so slot j holds the chunk destined for rank (me + j) % n.
        let slots = (0..n).map(|j| chunks[(me + j) % n].clone()).collect();
        Self { me, n, tag, slots, step_size: 1, round: 0, sent: false }
    }
}

impl<B: Wire> Machine<B> for BruckA2a<B> {
    type Output = Vec<B>;

    fn step(&mut self) -> Action<B> {
        if self.step_size >= self.n {
            return Action::Done;
        }
        let tag = self.tag + self.round;
        if !self.sent {
            self.sent = true;
            let to = (self.me + self.step_size) % self.n;
            let moving: Vec<(u32, B)> = (0..self.n)
                .filter(|&j| j & self.step_size != 0)
                .map(|j| (j as u32, self.slots[j].clone()))
                .collect();
            return Action::Send { to, tag, msg: B::frame_indexed(&moving), bulk: false };
        }
        let from = (self.me + self.n - self.step_size) % self.n;
        Action::Recv { from, tag }
    }

    fn deliver(&mut self, _from: usize, _tag: Tag, msg: B) {
        for (j, part) in msg.unframe_indexed() {
            self.slots[j as usize] = part;
        }
        self.step_size <<= 1;
        self.round += 1;
        self.sent = false;
    }

    fn finish(self) -> Vec<B> {
        let (me, n) = (self.me, self.n);
        let mut out: Vec<Option<B>> = (0..n).map(|_| None).collect();
        for (j, b) in self.slots.into_iter().enumerate() {
            out[(me + n - j) % n] = Some(b);
        }
        out.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

/// State of a [`HpxRootA2a`] endpoint.
enum HpxState {
    /// Leaf: send the framed row to root 0.
    SendRow,
    /// Root: receiving framed rows, next from this source.
    RecvRow(usize),
    /// Root: transposed; sending framed columns, next to this rank.
    SendCol(usize),
    /// Leaf: waiting for the root's framed column.
    RecvCol,
    /// Exchange complete.
    Finished,
}

/// [`super::AllToAllAlgo::HpxRoot`] endpoint: the root-funneled
/// variant modeling HPX's communicator-based collective. Leaves frame
/// their whole row and send it to rank 0 on the gather tag; the root
/// decodes all rows, transposes, re-frames per-destination columns and
/// scatters them on the scatter tag.
pub struct HpxRootA2a<B> {
    n: usize,
    gather_tag: Tag,
    scatter_tag: Tag,
    row: Option<B>,
    rows: Vec<Option<B>>,
    cols: Vec<Option<B>>,
    state: HpxState,
    result: Option<Vec<B>>,
}

impl<B: Wire> HpxRootA2a<B> {
    /// Endpoint for rank `me` of `n`. `gather_tag` carries the leaf →
    /// root rows, `scatter_tag` the root → leaf columns (two separate
    /// blocks, matching the live tag-allocation order).
    pub fn new(me: usize, n: usize, gather_tag: Tag, scatter_tag: Tag, chunks: Vec<B>) -> Self {
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        let row = B::frame_list(&chunks);
        let mut rows: Vec<Option<B>> = (0..n).map(|_| None).collect();
        let (row, state) = if me == 0 {
            rows[0] = Some(row);
            (None, HpxState::RecvRow(1))
        } else {
            (Some(row), HpxState::SendRow)
        };
        Self { n, gather_tag, scatter_tag, row, rows, cols: Vec::new(), state, result: None }
    }

    /// Root only: decode every gathered row, transpose, and frame the
    /// per-destination columns.
    fn transpose(&mut self) {
        let rows: Vec<Vec<B>> =
            self.rows.iter_mut().map(|r| r.take().expect("row gathered").unframe_list()).collect();
        self.cols = (0..self.n)
            .map(|dst| {
                let col: Vec<B> = rows.iter().map(|row| row[dst].clone()).collect();
                Some(B::frame_list(&col))
            })
            .collect();
    }
}

impl<B: Wire> Machine<B> for HpxRootA2a<B> {
    type Output = Vec<B>;

    fn step(&mut self) -> Action<B> {
        loop {
            match self.state {
                HpxState::SendRow => {
                    self.state = HpxState::RecvCol;
                    let msg = self.row.take().expect("row framed");
                    return Action::Send { to: 0, tag: self.gather_tag, msg, bulk: false };
                }
                HpxState::RecvRow(next) => {
                    if next < self.n {
                        return Action::Recv { from: next, tag: self.gather_tag };
                    }
                    self.transpose();
                    self.state = HpxState::SendCol(1);
                }
                HpxState::SendCol(dst) => {
                    if dst < self.n {
                        self.state = HpxState::SendCol(dst + 1);
                        let msg = self.cols[dst].take().expect("column framed");
                        return Action::Send { to: dst, tag: self.scatter_tag, msg, bulk: false };
                    }
                    let own = self.cols[0].take().expect("own column");
                    self.result = Some(own.unframe_list());
                    self.state = HpxState::Finished;
                }
                HpxState::RecvCol => return Action::Recv { from: 0, tag: self.scatter_tag },
                HpxState::Finished => return Action::Done,
            }
        }
    }

    fn deliver(&mut self, from: usize, _tag: Tag, msg: B) {
        match self.state {
            HpxState::RecvRow(next) => {
                debug_assert_eq!(from, next);
                self.rows[from] = Some(msg);
                self.state = HpxState::RecvRow(next + 1);
            }
            HpxState::RecvCol => {
                self.result = Some(msg.unframe_list());
                self.state = HpxState::Finished;
            }
            _ => unreachable!("unexpected delivery"),
        }
    }

    fn finish(self) -> Vec<B> {
        self.result.expect("exchange complete")
    }
}

/// State of a [`PairwiseChunkedA2a`] endpoint within its current round.
enum CpState {
    /// Hand the rank's own chunk to the application.
    EmitOwn,
    /// Send this round's 8-byte header.
    SendHeader,
    /// Send this round's wire chunks.
    SendChunks,
    /// Wait for the peer's header.
    RecvHeader,
    /// Wait for the peer's wire chunks.
    RecvChunks,
    /// All rounds complete.
    Finished,
}

/// [`super::AllToAllAlgo::PairwiseChunked`] endpoint: the streaming
/// pairwise exchange where every round is a full chunked transfer
/// (header on the round's block base, chunks above it) and received
/// chunks surface immediately as [`Action::Chunk`] — the
/// transpose-on-arrival hook the FFT overlaps compute on.
pub struct PairwiseChunkedA2a<B> {
    me: usize,
    n: usize,
    base: Tag,
    policy: ChunkPolicy,
    chunks: Vec<Option<B>>,
    state: CpState,
    round: usize,
    outgoing: Option<B>,
    out_len: usize,
    sent_chunks: usize,
    recv_total: usize,
    got_chunks: usize,
    pending: Option<(usize, usize, B)>,
}

impl<B: Wire> PairwiseChunkedA2a<B> {
    /// Endpoint for rank `me` of `n` under `policy`, with one
    /// [`CHUNK_TAG_SPAN`] block per round starting at `base`.
    pub fn new(me: usize, n: usize, base: Tag, policy: ChunkPolicy, chunks: Vec<B>) -> Self {
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        Self {
            me,
            n,
            base,
            policy,
            chunks: chunks.into_iter().map(Some).collect(),
            state: CpState::EmitOwn,
            round: 1,
            outgoing: None,
            out_len: 0,
            sent_chunks: 0,
            recv_total: 0,
            got_chunks: 0,
            pending: None,
        }
    }

    fn round_tag(&self) -> Tag {
        self.base + self.round as Tag * CHUNK_TAG_SPAN
    }
}

impl<B: Wire> Machine<B> for PairwiseChunkedA2a<B> {
    type Output = ();

    fn step(&mut self) -> Action<B> {
        loop {
            if let Some((src, off, msg)) = self.pending.take() {
                return Action::Chunk { src, off, msg };
            }
            match self.state {
                CpState::EmitOwn => {
                    let own = self.chunks[self.me].take().expect("own chunk");
                    self.state = if self.n == 1 { CpState::Finished } else { CpState::SendHeader };
                    return Action::Chunk { src: self.me, off: 0, msg: own };
                }
                CpState::SendHeader => {
                    let (to, _) = pairwise_peers(self.me, self.n, self.round);
                    let out = self.chunks[to].take().expect("chunk unsent");
                    self.out_len = out.wire_len();
                    self.outgoing = Some(out);
                    self.sent_chunks = 0;
                    self.state = CpState::SendChunks;
                    let msg = B::header(self.out_len as u64);
                    return Action::Send { to, tag: self.round_tag(), msg, bulk: false };
                }
                CpState::SendChunks => {
                    if self.sent_chunks < self.policy.n_chunks(self.out_len) {
                        let i = self.sent_chunks;
                        self.sent_chunks += 1;
                        let off = i * self.policy.chunk_bytes;
                        let len = self.policy.chunk_bytes.min(self.out_len - off);
                        let msg = self.outgoing.as_ref().expect("in transfer").slice(off, len);
                        let (to, _) = pairwise_peers(self.me, self.n, self.round);
                        let tag = self.round_tag() + 1 + i as Tag;
                        return Action::Send { to, tag, msg, bulk: true };
                    }
                    self.outgoing = None;
                    self.state = CpState::RecvHeader;
                }
                CpState::RecvHeader => {
                    let (_, from) = pairwise_peers(self.me, self.n, self.round);
                    return Action::Recv { from, tag: self.round_tag() };
                }
                CpState::RecvChunks => {
                    if self.got_chunks < self.policy.n_chunks(self.recv_total) {
                        let (_, from) = pairwise_peers(self.me, self.n, self.round);
                        let tag = self.round_tag() + 1 + self.got_chunks as Tag;
                        return Action::Recv { from, tag };
                    }
                    self.round += 1;
                    self.state =
                        if self.round == self.n { CpState::Finished } else { CpState::SendHeader };
                }
                CpState::Finished => return Action::Done,
            }
        }
    }

    fn deliver(&mut self, from: usize, _tag: Tag, msg: B) {
        match self.state {
            CpState::RecvHeader => {
                self.recv_total = msg.header_total() as usize;
                self.got_chunks = 0;
                self.state = CpState::RecvChunks;
            }
            CpState::RecvChunks => {
                self.pending = Some((from, self.got_chunks * self.policy.chunk_bytes, msg));
                self.got_chunks += 1;
            }
            _ => unreachable!("unexpected delivery"),
        }
    }

    fn finish(self) {}
}

/// [`super::ScatterAlgo::Linear`] endpoint: the root sends each leaf
/// its chunk inline on one shared tag, in destination order.
pub struct LinearScatter<B> {
    root: usize,
    me: usize,
    n: usize,
    tag: Tag,
    chunks: Vec<Option<B>>,
    next_dst: usize,
    result: Option<B>,
}

impl<B: Wire> LinearScatter<B> {
    /// Endpoint for rank `me` of `n` scattering from `root` on `tag`.
    /// `chunks` is `Some` (one per rank) on the root, `None` on leaves.
    pub fn new(root: usize, me: usize, n: usize, tag: Tag, chunks: Option<Vec<B>>) -> Self {
        let chunks = chunks.map_or_else(Vec::new, |c| c.into_iter().map(Some).collect());
        debug_assert!(me != root || chunks.len() == n);
        Self { root, me, n, tag, chunks, next_dst: 0, result: None }
    }
}

impl<B: Wire> Machine<B> for LinearScatter<B> {
    type Output = B;

    fn step(&mut self) -> Action<B> {
        if self.me == self.root {
            while self.next_dst < self.n {
                let dst = self.next_dst;
                self.next_dst += 1;
                let msg = self.chunks[dst].take().expect("chunk per rank");
                if dst == self.me {
                    self.result = Some(msg);
                    continue;
                }
                return Action::Send { to: dst, tag: self.tag, msg, bulk: false };
            }
            return Action::Done;
        }
        if self.result.is_none() {
            return Action::Recv { from: self.root, tag: self.tag };
        }
        Action::Done
    }

    fn deliver(&mut self, _from: usize, _tag: Tag, msg: B) {
        self.result = Some(msg);
    }

    fn finish(self) -> B {
        self.result.expect("scatter chunk")
    }
}

/// [`super::ScatterAlgo::Pipelined`] endpoint: the root streams each
/// leaf a chunked transfer (inline header, pool-dispatched wire chunks)
/// on one shared chunk-tag block; each leaf reassembles its own
/// transfer.
pub struct PipelinedScatter<B> {
    root: usize,
    me: usize,
    n: usize,
    tag: Tag,
    policy: ChunkPolicy,
    chunks: Vec<Option<B>>,
    next_dst: usize,
    outgoing: Option<B>,
    out_len: usize,
    sent_chunks: usize,
    total: Option<u64>,
    got_chunks: usize,
    parts: Vec<B>,
    result: Option<B>,
}

impl<B: Wire> PipelinedScatter<B> {
    /// Endpoint for rank `me` of `n` scattering from `root` under
    /// `policy`, on the chunk-tag block at `tag`. `chunks` is `Some`
    /// (one per rank) on the root, `None` on leaves.
    pub fn new(
        root: usize,
        me: usize,
        n: usize,
        tag: Tag,
        policy: ChunkPolicy,
        chunks: Option<Vec<B>>,
    ) -> Self {
        let chunks = chunks.map_or_else(Vec::new, |c| c.into_iter().map(Some).collect());
        debug_assert!(me != root || chunks.len() == n);
        Self {
            root,
            me,
            n,
            tag,
            policy,
            chunks,
            next_dst: 0,
            outgoing: None,
            out_len: 0,
            sent_chunks: 0,
            total: None,
            got_chunks: 0,
            parts: Vec::new(),
            result: None,
        }
    }
}

impl<B: Wire> Machine<B> for PipelinedScatter<B> {
    type Output = B;

    fn step(&mut self) -> Action<B> {
        if self.me == self.root {
            loop {
                if self.outgoing.is_some() {
                    if self.sent_chunks < self.policy.n_chunks(self.out_len) {
                        let i = self.sent_chunks;
                        self.sent_chunks += 1;
                        let off = i * self.policy.chunk_bytes;
                        let len = self.policy.chunk_bytes.min(self.out_len - off);
                        let msg = self.outgoing.as_ref().expect("in transfer").slice(off, len);
                        let dst = self.next_dst - 1;
                        let tag = self.tag + 1 + i as Tag;
                        return Action::Send { to: dst, tag, msg, bulk: true };
                    }
                    self.outgoing = None;
                }
                if self.next_dst >= self.n {
                    return Action::Done;
                }
                let dst = self.next_dst;
                self.next_dst += 1;
                let out = self.chunks[dst].take().expect("chunk per rank");
                if dst == self.me {
                    self.result = Some(out);
                    continue;
                }
                self.out_len = out.wire_len();
                self.outgoing = Some(out);
                self.sent_chunks = 0;
                let msg = B::header(self.out_len as u64);
                return Action::Send { to: dst, tag: self.tag, msg, bulk: false };
            }
        }
        match self.total {
            None => Action::Recv { from: self.root, tag: self.tag },
            Some(total) => {
                if self.got_chunks < self.policy.n_chunks(total as usize) {
                    let tag = self.tag + 1 + self.got_chunks as Tag;
                    return Action::Recv { from: self.root, tag };
                }
                if self.result.is_none() {
                    self.result = Some(B::concat(std::mem::take(&mut self.parts)));
                }
                Action::Done
            }
        }
    }

    fn deliver(&mut self, _from: usize, _tag: Tag, msg: B) {
        if self.total.is_none() {
            self.total = Some(msg.header_total());
        } else {
            self.parts.push(msg);
            self.got_chunks += 1;
        }
    }

    fn finish(self) -> B {
        self.result.expect("scatter chunk")
    }
}

/// The paper's N-scatter pattern (fig5): every rank roots one pipelined
/// scatter of its row on its own chunk-tag block and concurrently
/// drains the other `n - 1` roots' transfers, taking whichever header
/// or next-needed chunk arrives first via [`Action::RecvAny`]. Chunks
/// surface as [`Action::Chunk`] for transpose-on-arrival.
pub struct NScatter<B> {
    me: usize,
    n: usize,
    base: Tag,
    policy: ChunkPolicy,
    row: Vec<Option<B>>,
    next_dst: usize,
    outgoing: Option<B>,
    out_len: usize,
    sent_chunks: usize,
    emitted_own: bool,
    /// Per root: `None` until its header arrives, then
    /// `(total_bytes, chunks_received)`.
    progress: Vec<Option<(usize, usize)>>,
    done_roots: usize,
    pending: Option<(usize, usize, B)>,
}

impl<B: Wire> NScatter<B> {
    /// Endpoint for rank `me` of `n` under `policy`. `base` is the
    /// first of `n` consecutive [`CHUNK_TAG_SPAN`] blocks (root `r`
    /// transfers on block `base + r * CHUNK_TAG_SPAN`); `row` is this
    /// rank's per-destination chunks.
    pub fn new(me: usize, n: usize, base: Tag, policy: ChunkPolicy, row: Vec<B>) -> Self {
        assert_eq!(row.len(), n, "need one chunk per rank");
        Self {
            me,
            n,
            base,
            policy,
            row: row.into_iter().map(Some).collect(),
            next_dst: 0,
            outgoing: None,
            out_len: 0,
            sent_chunks: 0,
            emitted_own: false,
            progress: (0..n).map(|_| None).collect(),
            done_roots: 0,
            pending: None,
        }
    }

    fn root_tag(&self, root: usize) -> Tag {
        self.base + root as Tag * CHUNK_TAG_SPAN
    }
}

impl<B: Wire> Machine<B> for NScatter<B> {
    type Output = ();

    fn step(&mut self) -> Action<B> {
        loop {
            if let Some((src, off, msg)) = self.pending.take() {
                return Action::Chunk { src, off, msg };
            }
            if !self.emitted_own {
                self.emitted_own = true;
                let own = self.row[self.me].take().expect("own chunk");
                return Action::Chunk { src: self.me, off: 0, msg: own };
            }
            if self.outgoing.is_some() {
                if self.sent_chunks < self.policy.n_chunks(self.out_len) {
                    let i = self.sent_chunks;
                    self.sent_chunks += 1;
                    let off = i * self.policy.chunk_bytes;
                    let len = self.policy.chunk_bytes.min(self.out_len - off);
                    let msg = self.outgoing.as_ref().expect("in transfer").slice(off, len);
                    let dst = self.next_dst - 1;
                    let tag = self.root_tag(self.me) + 1 + i as Tag;
                    return Action::Send { to: dst, tag, msg, bulk: true };
                }
                self.outgoing = None;
            }
            if self.next_dst < self.n {
                let dst = self.next_dst;
                self.next_dst += 1;
                if dst == self.me {
                    continue;
                }
                let out = self.row[dst].take().expect("chunk unsent");
                self.out_len = out.wire_len();
                self.outgoing = Some(out);
                self.sent_chunks = 0;
                let msg = B::header(self.out_len as u64);
                return Action::Send { to: dst, tag: self.root_tag(self.me), msg, bulk: false };
            }
            if self.done_roots == self.n - 1 {
                return Action::Done;
            }
            let mut want = Vec::new();
            for root in 0..self.n {
                if root == self.me {
                    continue;
                }
                match self.progress[root] {
                    None => want.push((root, self.root_tag(root))),
                    Some((total, got)) => {
                        if got < self.policy.n_chunks(total) {
                            want.push((root, self.root_tag(root) + 1 + got as Tag));
                        }
                    }
                }
            }
            return Action::RecvAny(want);
        }
    }

    fn deliver(&mut self, from: usize, tag: Tag, msg: B) {
        match self.progress[from] {
            None => {
                debug_assert_eq!(tag, self.root_tag(from));
                let total = msg.header_total() as usize;
                self.progress[from] = Some((total, 0));
                if self.policy.n_chunks(total) == 0 {
                    self.done_roots += 1;
                }
            }
            Some((total, got)) => {
                debug_assert_eq!(tag, self.root_tag(from) + 1 + got as Tag);
                self.pending = Some((from, got * self.policy.chunk_bytes, msg));
                self.progress[from] = Some((total, got + 1));
                if got + 1 == self.policy.n_chunks(total) {
                    self.done_roots += 1;
                }
            }
        }
    }

    fn finish(self) {}
}

/// Run `machine` against the live fabric through `comm`: inline sends
/// go straight out, bulk sends are dispatched on the communicator's
/// chunk pool and drained before finishing, and chunk emissions stream
/// through `on_chunk(src, off, chunk)`.
pub(crate) fn drive<M, F>(comm: &Communicator, mut machine: M, mut on_chunk: F) -> M::Output
where
    M: Machine<Payload>,
    F: FnMut(usize, usize, Payload),
{
    let mut pending: Vec<TaskFuture<()>> = Vec::new();
    loop {
        match machine.step() {
            Action::Send { to, tag, msg, bulk } => {
                if bulk {
                    pending.push(send_pooled(comm, to, tag, msg));
                } else {
                    comm.send(to, tag, msg);
                }
            }
            Action::Recv { from, tag } => {
                let msg = comm.recv(from, tag);
                machine.deliver(from, tag, msg);
            }
            Action::RecvAny(want) => {
                let (from, tag, msg) = 'poll: loop {
                    for &(from, tag) in &want {
                        if let Some(msg) = comm.try_recv(from, tag) {
                            break 'poll (from, tag, msg);
                        }
                    }
                    std::thread::yield_now();
                };
                machine.deliver(from, tag, msg);
            }
            Action::Chunk { src, off, msg } => on_chunk(src, off, msg),
            Action::Done => break,
        }
    }
    for f in pending {
        f.get();
    }
    machine.finish()
}

/// Queue one already-sliced message to communicator rank `dest` on the
/// chunk pool, returning its completion future — the bulk-send
/// primitive behind every pipelined chunk transfer.
pub(crate) fn send_pooled(
    comm: &Communicator,
    dest: usize,
    tag: Tag,
    payload: Payload,
) -> TaskFuture<()> {
    let fabric = Arc::clone(comm.fabric());
    let src = comm.my_global();
    let dest = comm.global_rank(dest);
    let (token, cid) = (comm.conf_token(), comm.conf_cid());
    comm.chunk_pool().spawn(move || {
        let bytes = payload.len() as i64;
        let _span =
            crate::obs::span_args("wire", "chunk", src, tag as i64, crate::obs::NO_ARG, bytes);
        // Recorded before the fabric delivery so an armed conformance
        // checker never sees a matched receive outrun its send.
        super::conformance::on_send(token, cid, src, dest, tag);
        fabric.send(Parcel::new(src, dest, actions::COLLECTIVE, tag, payload));
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn payload_wire_framing_roundtrips() {
        let blocks = vec![
            (3u32, Payload::new(vec![1, 2, 3])),
            (7u32, Payload::new(vec![])),
            (1u32, Payload::new(vec![9; 5])),
        ];
        let frame = Payload::frame_indexed(&blocks);
        let back = frame.unframe_indexed();
        assert_eq!(back.len(), 3);
        for ((j, b), (j2, b2)) in blocks.iter().zip(&back) {
            assert_eq!(j, j2);
            assert_eq!(b.as_bytes(), b2.as_bytes());
        }

        let parts = vec![Payload::new(vec![4, 5]), Payload::new(vec![]), Payload::new(vec![6])];
        let frame = Payload::frame_list(&parts);
        let back = frame.unframe_list();
        assert_eq!(back.len(), 3);
        for (p, p2) in parts.iter().zip(&back) {
            assert_eq!(p.as_bytes(), p2.as_bytes());
        }

        let h = Payload::header(0xDEAD_BEEF_u64);
        assert_eq!(h.len(), 8);
        assert_eq!(h.header_total(), 0xDEAD_BEEF_u64);
    }

    #[test]
    fn payload_concat_is_zero_copy_for_single_part() {
        let p = Payload::new(vec![1, 2, 3, 4]);
        let single = Wire::concat(vec![p.clone()]);
        assert!(p.shares_storage(&single));
        let empty: Payload = Wire::concat(Vec::new());
        assert!(empty.is_empty());
        let multi = Wire::concat(vec![p.slice(0, 2), p.slice(2, 2)]);
        assert_eq!(multi.as_bytes(), p.as_bytes());
    }

    /// The N-scatter machine — the simulator's fig5 workload — must
    /// also run on the live fabric, proving sim and real runs share one
    /// protocol implementation (and exercising the driver's `RecvAny`
    /// polling arm).
    #[test]
    fn n_scatter_machine_runs_on_the_live_fabric() {
        let n = 4;
        for kind in [PortKind::Lci, PortKind::Mpi] {
            let cluster = Cluster::new(n, kind, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(5, 2));
                let base = comm.alloc_chunk_tags(n);
                let row: Vec<Payload> =
                    (0..n).map(|dst| Payload::new(vec![(ctx.rank * n + dst) as u8; 13])).collect();
                let sm = NScatter::new(ctx.rank, n, base, comm.chunk_policy(), row);
                let mut parts: Vec<Vec<Payload>> = (0..n).map(|_| Vec::new()).collect();
                drive(&comm, sm, |src, _off, chunk| parts[src].push(chunk));
                parts
                    .into_iter()
                    .map(|ps| Wire::concat(ps).as_bytes().to_vec())
                    .collect::<Vec<_>>()
            });
            for (rank, rows) in got.iter().enumerate() {
                for (src, bytes) in rows.iter().enumerate() {
                    assert_eq!(
                        bytes,
                        &vec![(src * n + rank) as u8; 13],
                        "{kind:?} rank {rank} src {src}"
                    );
                }
            }
        }
    }
}
