//! Chunked, pipelined payload transfer — the engine under
//! [`crate::collectives::AllToAllAlgo::PairwiseChunked`] and
//! [`crate::collectives::ScatterAlgo::Pipelined`].
//!
//! The paper's Fig. 3 sweeps the collective chunk size because the choice
//! trades per-message overhead (α, software cost — dominant for small
//! chunks) against pipelining (large monolithic messages serialize the
//! sender's protocol work, the wire, and the receiver's unpack). This
//! module implements that trade-off as real code:
//!
//! - a per-rank message is split into [`ChunkPolicy::chunk_bytes`]-sized
//!   wire chunks via [`crate::hpx::parcel::Payload::slice`] — an Arc-level
//!   sub-view, so splitting costs **zero copies**; whether the *port*
//!   copies each chunk is exactly the LCI-vs-MPI/TCP difference, now
//!   visible per chunk in [`crate::parcelport::PortStats`];
//! - chunk sends are dispatched to a communicator-owned
//!   [`crate::task::ThreadPool`] of [`ChunkPolicy::inflight`] workers, so
//!   up to `inflight` chunks progress concurrently while the caller is
//!   already blocked in the matched receive of the opposite direction —
//!   rounds overlap instead of barriering;
//! - the receive side consumes chunks in arrival order, which lets the
//!   distributed-FFT driver transpose-unpack chunk *k* while chunk *k+1*
//!   is still on the wire (see [`crate::dist_fft::all_to_all_variant`]).
//!
//! ## Wire protocol
//!
//! One chunked transfer occupies a contiguous tag block of
//! [`CHUNK_TAG_SPAN`] tags starting at a base tag both sides derive from
//! the communicator's lock-step allocator:
//!
//! ```text
//! base         : header — payload total length (u64 LE)
//! base + 1 + i : chunk i, bytes [i·chunk_bytes, (i+1)·chunk_bytes)
//! ```
//!
//! The receiver derives the chunk count from the header and its own
//! `ChunkPolicy` — the SPMD discipline requires sender and receiver to
//! run the same policy, just as they must call the same collectives in
//! the same order.

use super::comm::Communicator;
use crate::hpx::parcel::{actions, LocalityId, Parcel, Payload, Tag};
use crate::parcelport::Parcelport;
use crate::task::TaskFuture;
use std::sync::Arc;

pub use super::tags::CHUNK_TAG_SPAN;

/// How a chunked collective splits and pipelines per-rank messages.
///
/// `chunk_bytes` is the wire-chunk size (the x-axis of the paper's
/// Fig. 3); `inflight` bounds how many chunk sends progress concurrently
/// (the communicator's send-pool width). Both must be non-zero.
///
/// ```
/// use hpx_fft::collectives::ChunkPolicy;
///
/// // 1 MiB wire chunks, 4 in flight — the Fig. 3 sweet spot for
/// // multi-MiB messages on the modeled IB-HDR link.
/// let policy = ChunkPolicy::new(1 << 20, 4);
/// // A 4 MiB per-rank message splits into 4 pipelined wire chunks.
/// assert_eq!(policy.n_chunks(4 << 20), 4);
/// // Typed payloads round the chunk edge down to the element size, so
/// // a wire chunk never splits a complex number.
/// assert_eq!(ChunkPolicy::new(100, 2).aligned(8).chunk_bytes, 96);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPolicy {
    /// Wire-chunk size in bytes; messages shorter than this travel whole.
    pub chunk_bytes: usize,
    /// Maximum concurrently in-flight chunk sends per communicator.
    pub inflight: usize,
}

impl Default for ChunkPolicy {
    /// 1 MiB chunks × 4 in flight — the sweet spot of the Fig. 3 sweep
    /// for multi-MiB per-rank buffers on the modeled IB-HDR link.
    fn default() -> Self {
        Self { chunk_bytes: 1 << 20, inflight: 4 }
    }
}

impl ChunkPolicy {
    /// # Panics
    /// If either knob is zero.
    pub fn new(chunk_bytes: usize, inflight: usize) -> Self {
        assert!(chunk_bytes > 0, "chunk_bytes must be positive");
        assert!(inflight > 0, "inflight must be positive");
        Self { chunk_bytes, inflight }
    }

    /// Reject a hand-built zero policy with an actionable error — the
    /// single home of the rule every driver entry point enforces before
    /// any wire protocol runs ([`ChunkPolicy::new`] panics instead; the
    /// CLI and config file report the offending flag at parse time).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.chunk_bytes > 0 && self.inflight > 0,
            "chunk policy must be positive: chunk_bytes = {} / inflight = {} \
             (set --chunk-bytes and --inflight to values ≥ 1)",
            self.chunk_bytes,
            self.inflight
        );
        Ok(())
    }

    /// Round `chunk_bytes` down to a multiple of `align` (at least
    /// `align`). Typed consumers use this so wire chunks never split an
    /// element — the FFT path aligns to `size_of::<Complex32>()`.
    pub fn aligned(self, align: usize) -> Self {
        assert!(align > 0, "alignment must be positive");
        Self { chunk_bytes: (self.chunk_bytes / align).max(1) * align, ..self }
    }

    /// Number of wire chunks a message of `len` bytes splits into.
    ///
    /// A zero `chunk_bytes` is a configuration error, rejected at every
    /// construction point (CLI flags, config files, the driver configs,
    /// [`ChunkPolicy::new`]); the clamp below only keeps a hand-built
    /// zero struct from dividing by zero in release builds, and trips
    /// this assertion in debug builds.
    pub fn n_chunks(&self, len: usize) -> usize {
        debug_assert!(
            self.chunk_bytes > 0,
            "ChunkPolicy.chunk_bytes must be positive (rejected at config/CLI parse time)"
        );
        len.div_ceil(self.chunk_bytes.max(1))
    }
}

/// Blocking fabric-level receive of a headered chunked transfer at
/// locality `at`, reassembled into one payload. Single-chunk transfers
/// are passed through without copy (so on LCI the whole path stays
/// zero-copy); multi-chunk transfers are concatenated at the application
/// layer, which is reassembly, not a port protocol copy — it does not
/// appear in `PortStats`. Factored free of [`Communicator`] so the
/// nonblocking layer's posted-receive jobs (which run on pool workers,
/// away from the `!Sync` communicator) can share the wire protocol.
pub(crate) fn recv_chunked_via(
    fabric: &Arc<dyn Parcelport>,
    at: LocalityId,
    src: LocalityId,
    base_tag: Tag,
    policy: ChunkPolicy,
) -> Payload {
    let header = fabric.recv(at, src, actions::COLLECTIVE, base_tag);
    let mut off = 0;
    let total = crate::util::bytes::get_u64(header.as_bytes(), &mut off) as usize;
    match policy.n_chunks(total) {
        0 => Payload::empty(),
        1 => fabric.recv(at, src, actions::COLLECTIVE, base_tag + 1),
        n => {
            let mut buf = Vec::with_capacity(total);
            for i in 0..n {
                let chunk = fabric.recv(at, src, actions::COLLECTIVE, base_tag + 1 + i as Tag);
                if super::conformance::armed() {
                    // Per-transfer chunk-index monotonicity check.
                    super::conformance::on_chunk_recv(
                        fabric.uid() as usize,
                        at,
                        src,
                        base_tag,
                        i as u64,
                    );
                }
                buf.extend_from_slice(chunk.as_bytes());
            }
            debug_assert_eq!(buf.len(), total, "chunked transfer length mismatch");
            Payload::new(buf)
        }
    }
}

impl Communicator {
    /// Split `payload` into policy-sized chunks and queue them to `dest`
    /// on the communicator's send pool. Returns immediately with one
    /// future per chunk; the caller may proceed to its matched receives
    /// while the chunks drain (the pipelining), and should eventually
    /// `get()` the futures to bound the collective.
    ///
    /// The header message (total length) is sent inline so it can never
    /// be reordered behind pool scheduling on ports that preserve
    /// per-pair order.
    pub(crate) fn send_chunked(
        &self,
        dest: LocalityId,
        base_tag: Tag,
        payload: Payload,
    ) -> Vec<TaskFuture<()>> {
        let mut header = Vec::with_capacity(8);
        crate::util::bytes::put_u64(&mut header, payload.len() as u64);
        self.send(dest, base_tag, Payload::new(header));
        self.send_chunked_sized(dest, base_tag, payload)
    }

    /// The chunk half of a transfer, without the header — for transfers
    /// whose length the receiver can derive locally (e.g. the FFT slab
    /// exchange, where every rank computes the chunk geometry from the
    /// grid). Chunk `i` travels on the same tag `base_tag + 1 + i` as in
    /// the headered protocol; pair with [`Communicator::try_recv_chunk`].
    pub(crate) fn send_chunked_sized(
        &self,
        dest: LocalityId,
        base_tag: Tag,
        payload: Payload,
    ) -> Vec<TaskFuture<()>> {
        let policy = self.chunk_policy();
        let total = payload.len();
        let n_chunks = policy.n_chunks(total);
        let pool = self.chunk_pool();
        let src = self.my_global();
        let dest = self.global_rank(dest);
        let (token, cid) = (self.conf_token(), self.conf_cid());
        let mut pending = Vec::with_capacity(n_chunks);
        for i in 0..n_chunks {
            let off = i * policy.chunk_bytes;
            let len = policy.chunk_bytes.min(total - off);
            let chunk = payload.slice(off, len); // zero-copy sub-view
            let fabric = Arc::clone(self.fabric());
            let tag = base_tag + 1 + i as Tag;
            crate::obs::instant_args("chunk", "post", src, tag as i64, i as i64, len as i64);
            pending.push(pool.spawn(move || {
                let _span =
                    crate::obs::span_args("wire", "chunk", src, tag as i64, i as i64, len as i64);
                // Recorded before delivery: an armed conformance checker
                // must never see a matched receive outrun its send.
                super::conformance::on_send(token, cid, src, dest, tag);
                fabric.send(Parcel::new(src, dest, actions::COLLECTIVE, tag, chunk));
            }));
        }
        pending
    }

    /// Non-blocking matched receive of wire chunk `index` of a chunked
    /// transfer on `base_tag` — the polling counterpart of
    /// [`Communicator::recv_chunked_each`] for known-size transfers, so
    /// protocol knowledge (tag layout) stays in this module.
    pub(crate) fn try_recv_chunk(
        &self,
        src: LocalityId,
        base_tag: Tag,
        index: usize,
    ) -> Option<Payload> {
        let got = self.try_recv(src, base_tag + 1 + index as Tag);
        if let Some(p) = &got {
            crate::obs::instant_args(
                "chunk",
                "arrive",
                self.my_global(),
                (base_tag + 1 + index as Tag) as i64,
                index as i64,
                p.len() as i64,
            );
        }
        got
    }

    /// Receive the header of a chunked transfer: the payload total length.
    fn recv_chunk_header(&self, src: LocalityId, base_tag: Tag) -> usize {
        let header = self.recv(src, base_tag);
        let mut off = 0;
        crate::util::bytes::get_u64(header.as_bytes(), &mut off) as usize
    }

    /// Queue wire chunk `index` of a known-size chunked transfer to
    /// `dest` on the communicator's send pool, returning its completion
    /// future — the single-chunk posting primitive the async FFT variants
    /// use to stream a slab band the moment its first-dimension FFT
    /// finishes. The chunk travels on the same `base_tag + 1 + index`
    /// tag as in [`Communicator::send_chunked_sized`], so it pairs with
    /// [`Communicator::try_recv_chunk`].
    pub(crate) fn send_wire_chunk(
        &self,
        dest: LocalityId,
        base_tag: Tag,
        index: usize,
        payload: Payload,
    ) -> TaskFuture<()> {
        let tag = base_tag + 1 + index as Tag;
        crate::obs::instant_args(
            "chunk",
            "post",
            self.my_global(),
            tag as i64,
            index as i64,
            payload.len() as i64,
        );
        super::protocol::send_pooled(self, dest, tag, payload)
    }

    /// Streaming receive of a chunked transfer: `on_chunk(byte_offset,
    /// chunk)` fires for every wire chunk in offset order, as soon as it
    /// is matched — the hook the FFT driver uses to overlap unpack of
    /// chunk *k* with communication of chunk *k+1*. Returns the total
    /// transfer length.
    pub fn recv_chunked_each(
        &self,
        src: LocalityId,
        base_tag: Tag,
        mut on_chunk: impl FnMut(usize, Payload),
    ) -> usize {
        let policy = self.chunk_policy();
        let total = self.recv_chunk_header(src, base_tag);
        for i in 0..policy.n_chunks(total) {
            let chunk = self.recv(src, base_tag + 1 + i as Tag);
            if super::conformance::armed() {
                // Per-transfer chunk-index monotonicity check.
                super::conformance::on_chunk_recv(
                    self.conf_token(),
                    self.my_global(),
                    self.global_rank(src),
                    base_tag,
                    i as u64,
                );
            }
            crate::obs::instant_args(
                "chunk",
                "arrive",
                self.my_global(),
                (base_tag + 1 + i as Tag) as i64,
                i as i64,
                chunk.len() as i64,
            );
            on_chunk(i * policy.chunk_bytes, chunk);
        }
        total
    }

    /// Pairwise-chunked all-to-all with a streaming receive: the chunk
    /// schedule of [`super::AllToAllAlgo::PairwiseChunked`], but every
    /// arriving wire chunk is handed to `on_chunk(src_rank, byte_offset,
    /// chunk)` instead of being buffered — own-rank data included, as a
    /// single chunk at offset 0. The callback for chunk *k* runs while
    /// chunk *k+1* (and the next rounds' sends) are still in flight.
    pub fn all_to_all_chunked_each(
        &self,
        chunks: Vec<Payload>,
        on_chunk: impl FnMut(usize, usize, Payload),
    ) {
        let n = self.size();
        assert_eq!(chunks.len(), n, "need one chunk per rank");
        let base = self.alloc_chunk_tags(n);
        let sm = super::protocol::PairwiseChunkedA2a::new(
            self.rank(),
            n,
            base,
            self.chunk_policy(),
            chunks,
        );
        super::protocol::drive(self, sm, on_chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn n_chunks_covers_lengths() {
        let p = ChunkPolicy::new(64, 2);
        assert_eq!(p.n_chunks(0), 0);
        assert_eq!(p.n_chunks(1), 1);
        assert_eq!(p.n_chunks(64), 1);
        assert_eq!(p.n_chunks(65), 2);
        assert_eq!(p.n_chunks(640), 10);
    }

    #[test]
    fn aligned_rounds_down_with_floor() {
        assert_eq!(ChunkPolicy::new(100, 1).aligned(8).chunk_bytes, 96);
        assert_eq!(ChunkPolicy::new(8, 1).aligned(8).chunk_bytes, 8);
        assert_eq!(ChunkPolicy::new(3, 1).aligned(8).chunk_bytes, 8);
    }

    #[test]
    #[should_panic(expected = "chunk_bytes")]
    fn zero_chunk_bytes_rejected() {
        ChunkPolicy::new(0, 1);
    }

    #[test]
    fn validate_rejects_hand_built_zero_policies() {
        assert!(ChunkPolicy::new(64, 2).validate().is_ok());
        for policy in [
            ChunkPolicy { chunk_bytes: 0, inflight: 2 },
            ChunkPolicy { chunk_bytes: 64, inflight: 0 },
        ] {
            let err = policy.validate().unwrap_err().to_string();
            assert!(err.contains("chunk policy must be positive"), "{err}");
        }
    }

    #[test]
    fn chunked_roundtrip_multi_chunk() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(ChunkPolicy::new(7, 2)); // odd size: exercises ragged tail
            let base = comm.alloc_chunk_tags(1);
            let peer = 1 - ctx.rank;
            let data: Vec<u8> = (0..100).map(|i| (ctx.rank * 100 + i) as u8).collect();
            let pending = comm.send_chunked(peer, base, Payload::new(data));
            let got = recv_chunked_via(comm.fabric(), ctx.rank, peer, base, comm.chunk_policy())
                .as_bytes()
                .to_vec();
            for f in pending {
                f.get();
            }
            got
        });
        for (rank, bytes) in got.iter().enumerate() {
            let peer = 1 - rank;
            let expect: Vec<u8> = (0..100).map(|i| (peer * 100 + i) as u8).collect();
            assert_eq!(bytes, &expect, "rank {rank}");
        }
    }

    #[test]
    fn single_chunk_transfer_stays_zero_copy_on_lci() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let shared = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            // Policy larger than the payload → exactly one wire chunk.
            comm.set_chunk_policy(ChunkPolicy::new(1 << 20, 2));
            let base = comm.alloc_chunk_tags(1);
            let peer = 1 - ctx.rank;
            let payload = Payload::new(vec![ctx.rank as u8; 4096]);
            let pending = comm.send_chunked(peer, base, payload);
            let got = recv_chunked_via(comm.fabric(), ctx.rank, peer, base, comm.chunk_policy());
            for f in pending {
                f.get();
            }
            // Aliasing against the peer's buffer can't be checked from
            // this thread; the fabric-wide copy counter below pins the
            // zero-copy property instead.
            got.as_bytes() == &vec![peer as u8; 4096][..]
        });
        assert!(shared.iter().all(|&ok| ok));
        assert_eq!(cluster.fabric().stats().bytes_copied, 0, "LCI chunked path must not copy");
    }

    #[test]
    fn empty_payload_chunked() {
        let cluster = Cluster::new(2, PortKind::Mpi, None).unwrap();
        let lens = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(ChunkPolicy::new(16, 1));
            let base = comm.alloc_chunk_tags(1);
            let peer = 1 - ctx.rank;
            let pending = comm.send_chunked(peer, base, Payload::empty());
            let len =
                recv_chunked_via(comm.fabric(), ctx.rank, peer, base, comm.chunk_policy()).len();
            for f in pending {
                f.get();
            }
            len
        });
        assert_eq!(lens, vec![0, 0]);
    }

    #[test]
    fn streaming_offsets_are_contiguous() {
        let cluster = Cluster::new(2, PortKind::Tcp, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(ChunkPolicy::new(10, 2));
            let base = comm.alloc_chunk_tags(1);
            let peer = 1 - ctx.rank;
            let data: Vec<u8> = (0u8..=41).collect();
            let pending = comm.send_chunked(peer, base, Payload::new(data.clone()));
            let mut next_off = 0;
            let mut buf = Vec::new();
            let total = comm.recv_chunked_each(peer, base, |off, p| {
                assert_eq!(off, next_off, "chunks must stream in offset order");
                next_off += p.len();
                buf.extend_from_slice(p.as_bytes());
            });
            assert_eq!(total, 42);
            assert_eq!(buf, data);
            for f in pending {
                f.get();
            }
        });
    }
}
