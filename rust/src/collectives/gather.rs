//! Gather and all-gather.

use super::comm::Communicator;
use crate::hpx::parcel::Payload;

impl Communicator {
    /// Linear gather to `root`: every rank contributes one payload; the
    /// root receives them in rank order (`Some(vec)`), others get `None`.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::gather_async`]`.get()`.
    pub fn gather(&self, root: usize, data: Payload) -> Option<Vec<Payload>> {
        self.gather_async(root, data).get()
    }

    /// Ring all-gather: after `size - 1` rounds every rank holds every
    /// contribution, in rank order. Bandwidth-optimal (each byte crosses
    /// each link once).
    pub fn all_gather(&self, data: Payload) -> Vec<Payload> {
        let n = self.size();
        let tag = self.alloc_tags();
        let mut slots: Vec<Option<Payload>> = (0..n).map(|_| None).collect();
        slots[self.rank()] = Some(data);

        let next = (self.rank() + 1) % n;
        let prev = (self.rank() + n - 1) % n;
        // Round r: forward the block that originated at rank - r.
        for r in 0..n.saturating_sub(1) {
            let send_origin = (self.rank() + n - r) % n;
            let recv_origin = (self.rank() + n - r - 1) % n;
            let outgoing =
                slots[send_origin].as_ref().expect("ring invariant: block present").clone();
            self.send(next, tag + r as u64, outgoing);
            slots[recv_origin] = Some(self.recv(prev, tag + r as u64));
        }
        slots.into_iter().map(|s| s.expect("all blocks filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn gather_collects_in_rank_order() {
        let cluster = Cluster::new(4, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.gather(3, Payload::from_f32(&[ctx.rank as f32]))
                .map(|v| v.iter().map(|p| p.to_f32()[0]).collect::<Vec<_>>())
        });
        assert_eq!(got[3], Some(vec![0.0, 1.0, 2.0, 3.0]));
        for r in 0..3 {
            assert!(got[r].is_none());
        }
    }

    #[test]
    fn all_gather_every_rank_sees_all() {
        for n in [1usize, 2, 3, 5, 8] {
            let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                let all = comm.all_gather(Payload::from_f32(&[ctx.rank as f32 * 2.0]));
                all.iter().map(|p| p.to_f32()[0]).collect::<Vec<_>>()
            });
            let expect: Vec<f32> = (0..n).map(|i| i as f32 * 2.0).collect();
            for g in got {
                assert_eq!(g, expect, "n={n}");
            }
        }
    }

    #[test]
    fn gather_over_tcp_and_mpi() {
        for kind in [PortKind::Tcp, PortKind::Mpi] {
            let cluster = Cluster::new(3, kind, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.gather(0, Payload::new(vec![ctx.rank as u8; ctx.rank + 1]))
                    .map(|v| v.iter().map(|p| p.len()).collect::<Vec<_>>())
            });
            assert_eq!(got[0], Some(vec![1, 2, 3]), "{kind}");
        }
    }

    #[test]
    fn all_gather_varied_sizes() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let all = comm.all_gather(Payload::new(vec![ctx.rank as u8; (ctx.rank + 1) * 100]));
            all.iter().map(|p| p.len()).collect::<Vec<_>>()
        });
        for g in got {
            assert_eq!(g, vec![100, 200, 300, 400]);
        }
    }
}
