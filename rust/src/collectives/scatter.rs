//! Scatter and the paper's N-scatter building block.
//!
//! HPX's `scatter_to`/`scatter_from` is a linear collective: the root
//! sends chunk `i` to participant `i`. The FFT scatter variant issues one
//! such scatter per root locality; [`Communicator::scatter_tags`] /
//! [`Communicator::scatter_chunk_tags`] pre-allocate the tags so
//! receivers can poll many outstanding scatters and process whichever
//! arrives first (the comm/compute overlap the paper proposes).
//!
//! [`ScatterAlgo::Pipelined`] additionally splits every per-rank payload
//! into [`crate::collectives::ChunkPolicy`]-sized wire chunks that
//! pipeline through the communicator's send pool — the root starts
//! serving rank `i+1` while rank `i`'s chunks are still on the wire,
//! instead of serializing one monolithic message per rank.

use super::comm::Communicator;
use super::protocol;
use crate::hpx::parcel::{Payload, Tag};

/// Algorithm selector for [`Communicator::scatter_with_algo`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScatterAlgo {
    /// One monolithic message per rank (HPX's `scatter_to` semantics).
    Linear,
    /// Chunked, pipelined sends under the communicator's `ChunkPolicy`.
    Pipelined,
}

impl ScatterAlgo {
    /// Both algorithms, in presentation order.
    pub const ALL: [ScatterAlgo; 2] = [ScatterAlgo::Linear, ScatterAlgo::Pipelined];

    /// Lowercase algorithm name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ScatterAlgo::Linear => "linear",
            ScatterAlgo::Pipelined => "pipelined",
        }
    }
}

impl std::str::FromStr for ScatterAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(ScatterAlgo::Linear),
            "pipelined" | "chunked" => Ok(ScatterAlgo::Pipelined),
            other => Err(format!("unknown scatter algorithm {other:?}")),
        }
    }
}

impl Communicator {
    /// Linear scatter: the root provides one payload per rank (in rank
    /// order) and every rank receives its chunk. Non-roots pass `None`.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::scatter_async`]`.get()`; use the async form
    /// directly to overlap the wait with compute.
    ///
    /// # Panics
    /// If the root's chunk count differs from the communicator size, or a
    /// non-root passes data.
    pub fn scatter(&self, root: usize, chunks: Option<Vec<Payload>>) -> Payload {
        self.scatter_async(root, chunks, ScatterAlgo::Linear).get()
    }

    /// Scatter on an explicit pre-allocated tag (for overlapping many
    /// scatters; pair with [`Communicator::scatter_tags`]).
    pub fn scatter_with_tag(
        &self,
        root: usize,
        chunks: Option<Vec<Payload>>,
        tag: Tag,
    ) -> Payload {
        assert!(root < self.size(), "root {root} out of range");
        if self.rank() == root {
            let c = chunks.as_ref().expect("root must provide chunks");
            assert_eq!(c.len(), self.size(), "need exactly one chunk per rank");
        } else {
            assert!(chunks.is_none(), "non-root rank {} passed chunks", self.rank());
        }
        // The root's own chunk never hits the fabric — the machine hands
        // it straight back.
        let sm = protocol::LinearScatter::new(root, self.rank(), self.size(), tag, chunks);
        protocol::drive(self, sm, |_, _, _| {})
    }

    /// Pre-allocate tags for `k` upcoming scatters (SPMD: all ranks call
    /// this identically). Returns the base tags in call order.
    pub fn scatter_tags(&self, k: usize) -> Vec<Tag> {
        (0..k).map(|_| self.alloc_tags()).collect()
    }

    /// Scatter under an explicit algorithm choice — the blocking `get()`
    /// wrapper over [`Communicator::scatter_async`].
    pub fn scatter_with_algo(
        &self,
        root: usize,
        chunks: Option<Vec<Payload>>,
        algo: ScatterAlgo,
    ) -> Payload {
        self.scatter_async(root, chunks, algo).get()
    }

    /// Pipelined chunked scatter on a pre-reserved chunk-tag block (from
    /// [`Communicator::scatter_chunk_tags`]). The root's per-rank
    /// payloads are split into policy-sized zero-copy slices and drained
    /// through the send pool; the root returns once every chunk is on the
    /// wire (its own chunk, as ever, never touches the fabric).
    ///
    /// # Panics
    /// Same contract as [`Communicator::scatter_with_tag`].
    pub fn scatter_pipelined_with_tag(
        &self,
        root: usize,
        chunks: Option<Vec<Payload>>,
        tag: Tag,
    ) -> Payload {
        assert!(root < self.size(), "root {root} out of range");
        if self.rank() == root {
            let c = chunks.as_ref().expect("root must provide chunks");
            assert_eq!(c.len(), self.size(), "need exactly one chunk per rank");
        } else {
            assert!(chunks.is_none(), "non-root rank {} passed chunks", self.rank());
        }
        // Tag matching is per destination mailbox, so every destination
        // shares the same chunk-tag block; the root's own chunk never
        // hits the fabric. The driver drains the pooled chunk sends
        // before returning.
        let sm = protocol::PipelinedScatter::new(
            root,
            self.rank(),
            self.size(),
            tag,
            self.chunk_policy(),
            chunks,
        );
        protocol::drive(self, sm, |_, _, _| {})
    }

    /// Pre-allocate chunk-tag blocks for `k` upcoming pipelined scatters
    /// (SPMD: all ranks call this identically).
    pub fn scatter_chunk_tags(&self, k: usize) -> Vec<Tag> {
        (0..k).map(|_| self.alloc_chunk_tags(1)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn scatter_delivers_rank_chunks() {
        let cluster = Cluster::new(4, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let chunks = (ctx.rank == 2)
                .then(|| (0..4).map(|i| Payload::from_f32(&[i as f32 * 10.0])).collect());
            comm.scatter(2, chunks).to_f32()[0]
        });
        assert_eq!(got, vec![0.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn scatter_root_zero() {
        let cluster = Cluster::new(3, PortKind::Mpi, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let chunks =
                (ctx.rank == 0).then(|| (0..3).map(|i| Payload::new(vec![i as u8; 4])).collect());
            comm.scatter(0, chunks).as_bytes()[0]
        });
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn overlapped_scatters_with_explicit_tags() {
        // N concurrent scatters (one per root) — the FFT pattern.
        let n = 4;
        let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
        let sums = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let tags = comm.scatter_tags(n);
            let mut received = vec![0.0f32; n];
            for (root, &tag) in tags.iter().enumerate() {
                let chunks = (ctx.rank == root).then(|| {
                    (0..n).map(|dst| Payload::from_f32(&[(root * n + dst) as f32])).collect()
                });
                received[root] = comm.scatter_with_tag(root, chunks, tag).to_f32()[0];
            }
            received.iter().sum::<f32>()
        });
        // Rank r receives root*n + r from each root.
        for (r, s) in sums.iter().enumerate() {
            let expect: f32 = (0..n).map(|root| (root * n + r) as f32).sum();
            assert_eq!(*s, expect);
        }
    }

    #[test]
    fn single_rank_scatter_is_identity() {
        let cluster = Cluster::new(1, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.scatter(0, Some(vec![Payload::from_f32(&[9.0])])).to_f32()[0]
        });
        assert_eq!(got, vec![9.0]);
    }

    #[test]
    #[should_panic]
    fn root_without_chunks_panics() {
        // Single-rank cluster: a panicking locality with peers blocked in
        // recv would deadlock the join scope, so the misuse is probed
        // where no peer can be left waiting.
        let cluster = Cluster::new(1, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.scatter(0, None); // root passes None → panics
        });
    }

    #[test]
    fn pipelined_scatter_all_ports() {
        for kind in PortKind::ALL {
            let cluster = Cluster::new(4, kind, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                // 80-byte payloads over 24-byte chunks: 4 wire chunks each.
                comm.set_chunk_policy(crate::collectives::ChunkPolicy::new(24, 2));
                let chunks = (ctx.rank == 1).then(|| {
                    (0..4).map(|i| Payload::new(vec![i as u8; 80])).collect()
                });
                let mine = comm.scatter_with_algo(1, chunks, ScatterAlgo::Pipelined);
                assert_eq!(mine.len(), 80);
                mine.as_bytes()[0]
            });
            assert_eq!(got, vec![0, 1, 2, 3], "{kind}");
        }
    }

    #[test]
    fn pipelined_matches_linear_ragged_sizes() {
        let cluster = Cluster::new(3, PortKind::Lci, None).unwrap();
        for algo in ScatterAlgo::ALL {
            let lens = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(crate::collectives::ChunkPolicy::new(700, 2));
                let chunks = (ctx.rank == 0).then(|| {
                    (0..3).map(|i| Payload::new(vec![i as u8; i * 1000])).collect()
                });
                let mine = comm.scatter_with_algo(0, chunks, algo);
                assert!(mine.as_bytes().iter().all(|&b| b == ctx.rank as u8));
                mine.len()
            });
            assert_eq!(lens, vec![0, 1000, 2000], "{algo:?}");
        }
    }

    #[test]
    fn overlapped_pipelined_scatters_with_explicit_tags() {
        // The FFT pattern, chunk-pipelined: N concurrent scatters.
        let n = 4;
        let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
        let sums = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(crate::collectives::ChunkPolicy::new(8, 2));
            let tags = comm.scatter_chunk_tags(n);
            let mut received = vec![0.0f32; n];
            for (root, &tag) in tags.iter().enumerate() {
                let chunks = (ctx.rank == root).then(|| {
                    (0..n)
                        .map(|dst| Payload::from_f32(&vec![(root * n + dst) as f32; 5]))
                        .collect()
                });
                received[root] =
                    comm.scatter_pipelined_with_tag(root, chunks, tag).to_f32()[0];
            }
            received.iter().sum::<f32>()
        });
        for (r, s) in sums.iter().enumerate() {
            let expect: f32 = (0..n).map(|root| (root * n + r) as f32).sum();
            assert_eq!(*s, expect);
        }
    }

    #[test]
    fn scatter_algo_parse() {
        assert_eq!("linear".parse::<ScatterAlgo>().unwrap(), ScatterAlgo::Linear);
        assert_eq!("pipelined".parse::<ScatterAlgo>().unwrap(), ScatterAlgo::Pipelined);
        assert_eq!("chunked".parse::<ScatterAlgo>().unwrap(), ScatterAlgo::Pipelined);
        assert!("tree".parse::<ScatterAlgo>().is_err());
    }

    #[test]
    fn payload_sizes_preserved() {
        let cluster = Cluster::new(3, PortKind::Tcp, None).unwrap();
        let lens = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let chunks = (ctx.rank == 0)
                .then(|| (0..3).map(|i| Payload::new(vec![0u8; (i + 1) * 1000])).collect());
            comm.scatter(0, chunks).len()
        });
        assert_eq!(lens, vec![1000, 2000, 3000]);
    }
}
