//! The communicator: rank + size + fabric handle + tag discipline.

use super::chunked::ChunkPolicy;
use super::conformance;
use super::tags::{collective_span, CHUNK_TAG_SPAN};
use crate::hpx::parcel::{actions, LocalityId, Parcel, Payload, Tag};
use crate::hpx::runtime::LocalityCtx;
use crate::parcelport::Parcelport;
use crate::task::ThreadPool;
use std::cell::{Cell, RefCell};
use std::fmt;
use std::sync::Arc;

/// Typed error: a bounded communicator's tag space cannot fit the
/// requested reservation. Returned by the `try_` tag-allocation entry
/// points ([`Communicator::try_split`] and friends) so callers like the
/// FFT service can surface exhaustion as a job error instead of a
/// panic; the panicking entry points format exactly this error.
///
/// The communicator stays usable after the failed reservation — the
/// lock-step counter is only advanced on success, so SPMD discipline is
/// preserved (every rank sees the same failure at the same point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TagSpaceExhausted {
    /// Tags the failed reservation asked for.
    pub requested: Tag,
    /// Where the counter would have landed (`current + requested`).
    pub next: Tag,
    /// The communicator's exclusive tag-space limit.
    pub limit: Tag,
}

impl fmt::Display for TagSpaceExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "communicator tag space exhausted: {} > {} (span {})",
            self.next, self.limit, self.requested
        )
    }
}

impl std::error::Error for TagSpaceExhausted {}

/// A per-locality handle for collective operations.
///
/// Not `Sync` by design: one communicator belongs to one locality thread
/// (clone-per-thread, like an `MPI_Comm` rank handle). Tags for successive
/// collectives come from a local counter that stays in lock-step across
/// ranks under the SPMD calling discipline.
///
/// A communicator need not span the whole fabric:
/// [`Communicator::split`] builds sub-communicators whose ranks
/// `0..size` map onto an arbitrary subset of localities. The `members`
/// table carries that mapping (identity for whole-fabric communicators);
/// every fabric-level send and matched receive translates communicator
/// ranks through it.
///
/// The communicator also carries the [`ChunkPolicy`] the chunked
/// collectives run under, plus a lazily created send pool of
/// `policy.inflight` workers that pipelines their wire chunks.
pub struct Communicator {
    fabric: Arc<dyn Parcelport>,
    rank: LocalityId,
    size: usize,
    /// `members[r]` = global locality id of communicator rank `r`.
    members: Arc<Vec<LocalityId>>,
    next_tag: Cell<Tag>,
    /// Exclusive upper bound of this communicator's tag space. Split
    /// sub-communicators are bounded to the span their parent reserved;
    /// whole-fabric communicators are unbounded.
    tag_limit: Option<Tag>,
    /// Conformance identity for the runtime checker (0 = unregistered;
    /// see [`super::conformance`]). Split communicators register their
    /// span under this id; shadow and scoped copies inherit it.
    cid: u64,
    /// Fabric identity token the conformance checker keys its per-fabric
    /// state by. Captured at construction and *inherited* by scoped
    /// copies ([`Communicator::with_stats_scope`] wraps the fabric in a
    /// decorator), so one logical fabric's traffic is never split across
    /// two tokens.
    conf_token: usize,
    chunk_policy: Cell<ChunkPolicy>,
    chunk_pool: RefCell<Option<Arc<ThreadPool>>>,
    /// Send pool handed to shadow communicators (offloaded multi-round
    /// collectives). Kept separate from `chunk_pool` — whose workers run
    /// the offloaded jobs themselves — so a job's own chunk sends can
    /// never be starved by the job occupying the only worker; memoized
    /// here so repeated offloaded collectives don't spawn/join a pool
    /// per invocation.
    shadow_send_pool: RefCell<Option<Arc<ThreadPool>>>,
}

impl Communicator {
    /// Handle for `rank` of a `size`-rank group over `fabric`, with the
    /// identity rank ↔ locality mapping.
    pub fn new(fabric: Arc<dyn Parcelport>, rank: LocalityId, size: usize) -> Self {
        assert!(rank < size, "rank {rank} out of range for size {size}");
        assert!(size <= fabric.n_localities(), "communicator larger than fabric");
        let members = Arc::new((0..size).collect());
        let conf_token = fabric.uid() as usize;
        Self {
            fabric,
            rank,
            size,
            members,
            next_tag: Cell::new(0),
            tag_limit: None,
            cid: 0,
            conf_token,
            chunk_policy: Cell::new(ChunkPolicy::default()),
            chunk_pool: RefCell::new(None),
            shadow_send_pool: RefCell::new(None),
        }
    }

    /// Handle for `rank` of the group whose rank → locality mapping is
    /// `members`, with a tag counter bounded to `[tag_base, tag_limit)`.
    /// The construction path of [`Communicator::split`].
    pub(crate) fn from_members(
        fabric: Arc<dyn Parcelport>,
        rank: usize,
        members: Arc<Vec<LocalityId>>,
        tag_base: Tag,
        tag_limit: Tag,
        policy: ChunkPolicy,
    ) -> Self {
        assert!(rank < members.len(), "rank {rank} out of range for {} members", members.len());
        for &m in members.iter() {
            assert!(m < fabric.n_localities(), "member locality {m} outside fabric");
        }
        let size = members.len();
        let conf_token = fabric.uid() as usize;
        // Register the bounded span with the conformance checker (a
        // no-op unless a test armed it): overlapping spans with shared
        // members on one fabric are a tag collision, caught here at
        // construction rather than as corrupted traffic later.
        let cid = conformance::next_comm_id();
        conformance::on_comm_created(conf_token, cid, tag_base, tag_limit, &members);
        Self {
            fabric,
            rank,
            size,
            members,
            next_tag: Cell::new(tag_base),
            tag_limit: Some(tag_limit),
            cid,
            conf_token,
            chunk_policy: Cell::new(policy),
            chunk_pool: RefCell::new(None),
            shadow_send_pool: RefCell::new(None),
        }
    }

    /// Communicator spanning the whole cluster of an SPMD closure's
    /// locality context.
    pub fn from_ctx(ctx: &LocalityCtx) -> Self {
        Self::new(Arc::clone(ctx.fabric()), ctx.rank, ctx.n)
    }

    /// This locality's rank within the communicator.
    pub fn rank(&self) -> LocalityId {
        self.rank
    }

    /// Number of participating ranks.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The underlying parcelport fabric.
    pub fn fabric(&self) -> &Arc<dyn Parcelport> {
        &self.fabric
    }

    /// Global locality id of communicator rank `r`.
    pub fn global_rank(&self, r: usize) -> LocalityId {
        self.members[r]
    }

    /// This rank's global locality id.
    pub(crate) fn my_global(&self) -> LocalityId {
        self.members[self.rank]
    }

    /// The rank → locality mapping (shared so posted jobs can translate
    /// off the `!Sync` communicator).
    pub(crate) fn members_arc(&self) -> Arc<Vec<LocalityId>> {
        Arc::clone(&self.members)
    }

    /// The rank → global locality mapping, in rank order.
    pub fn members(&self) -> &[LocalityId] {
        &self.members
    }

    /// The chunking policy the chunked collectives run under.
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.chunk_policy.get()
    }

    /// Install a new chunking policy. SPMD discipline: every rank must
    /// set the same policy before a chunked collective, since receivers
    /// derive chunk boundaries from their own copy.
    pub fn set_chunk_policy(&self, policy: ChunkPolicy) {
        self.chunk_policy.set(policy);
    }

    /// The communicator's chunk-send pool, created on first use and
    /// re-created if the policy's `inflight` width changed since.
    pub(crate) fn chunk_pool(&self) -> Arc<ThreadPool> {
        let want = self.chunk_policy.get().inflight.max(1);
        let mut slot = self.chunk_pool.borrow_mut();
        match slot.as_ref() {
            Some(pool) if pool.size() == want => Arc::clone(pool),
            _ => {
                let pool = Arc::new(ThreadPool::new(want));
                *slot = Some(Arc::clone(&pool));
                pool
            }
        }
    }

    /// Pre-spawn the chunk-send pool for the current policy, so its
    /// one-off thread-creation cost lands outside measured regions
    /// (benchmark warm-up; a no-op if the pool already matches).
    pub fn warm_chunk_pool(&self) {
        let _ = self.chunk_pool();
    }

    /// Advance the lock-step counter by `span`, returning the block base
    /// — or a typed [`TagSpaceExhausted`] if the communicator's bound
    /// would be exceeded (split sub-communicators must stay inside the
    /// span their parent reserved — see [`crate::collectives::tags`]).
    /// On failure the counter is untouched, so the communicator remains
    /// usable and in lock-step.
    fn try_bump_tags(&self, span: Tag) -> Result<Tag, TagSpaceExhausted> {
        let t = self.next_tag.get();
        let next = t.checked_add(span).expect("tag counter overflow");
        if let Some(limit) = self.tag_limit {
            if next > limit {
                return Err(TagSpaceExhausted { requested: span, next, limit });
            }
        }
        self.next_tag.set(next);
        Ok(t)
    }

    /// Panicking wrapper of [`Communicator::try_bump_tags`] for the
    /// infallible internal allocation paths.
    fn bump_tags(&self, span: Tag) -> Tag {
        self.try_bump_tags(span).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Reserve `groups` blocks of [`CHUNK_TAG_SPAN`] tags for chunked
    /// transfers (same lock-step counter as [`Communicator::alloc_tags`]).
    pub(crate) fn alloc_chunk_tags(&self, groups: usize) -> Tag {
        self.bump_tags(groups as Tag * CHUNK_TAG_SPAN)
    }

    /// Allocate the base tag for one collective invocation. Each
    /// collective may use a contiguous block of `self.size` tags starting
    /// here (rounds, per-peer slots).
    pub(crate) fn alloc_tags(&self) -> Tag {
        // Reserve a generous block so algorithms can derive per-round /
        // per-peer tags without collision.
        self.bump_tags(collective_span(self.size))
    }

    /// Reserve a contiguous block of `span` tags from the lock-step
    /// allocator and return its base. Offloaded collectives run a shadow
    /// communicator inside such a block (see
    /// [`Communicator::shadow_at`]), and [`Communicator::split`] carves
    /// each sub-communicator's whole tag space this way; SPMD discipline
    /// keeps the reservation identical across ranks.
    pub(crate) fn reserve_tag_span(&self, span: Tag) -> Tag {
        self.bump_tags(span)
    }

    /// Fallible variant of [`Communicator::reserve_tag_span`]: returns a
    /// typed [`TagSpaceExhausted`] instead of panicking, leaving the
    /// counter (and therefore SPMD lock-step) untouched on failure.
    pub(crate) fn try_reserve_tag_span(&self, span: Tag) -> Result<Tag, TagSpaceExhausted> {
        self.try_bump_tags(span)
    }

    /// Tag span a [`Communicator::split`] sub-communicator carves out of
    /// this communicator: the full [`super::tags::SPLIT_TAG_SPAN`] on an
    /// unbounded (whole-fabric) communicator; on a bounded one (itself a
    /// split), half the remaining space rounded down to whole chunk
    /// blocks — so nested splits always leave the parent room to keep
    /// allocating. Lock-step: the counter state this derives from is
    /// identical across ranks under the SPMD discipline.
    pub(crate) fn split_span(&self) -> Tag {
        self.try_split_span()
            .unwrap_or_else(|e| panic!("communicator tag space too depleted to split: {e}"))
    }

    /// Fallible variant of [`Communicator::split_span`]: the typed
    /// [`TagSpaceExhausted`] names the minimum viable reservation (one
    /// chunk block) the depleted space could not fit.
    pub(crate) fn try_split_span(&self) -> Result<Tag, TagSpaceExhausted> {
        match self.tag_limit {
            None => Ok(super::tags::SPLIT_TAG_SPAN),
            Some(limit) => {
                let next = self.next_tag.get();
                let remaining = limit.saturating_sub(next);
                let span = remaining / 2 / CHUNK_TAG_SPAN * CHUNK_TAG_SPAN;
                if span >= CHUNK_TAG_SPAN {
                    Ok(span)
                } else {
                    Err(TagSpaceExhausted {
                        requested: CHUNK_TAG_SPAN,
                        next: next.saturating_add(CHUNK_TAG_SPAN),
                        limit,
                    })
                }
            }
        }
    }

    /// The memoized pool shadow communicators send chunks from (created
    /// on first use, re-created if the policy's `inflight` changed).
    fn shadow_pool_handle(&self) -> Arc<ThreadPool> {
        let want = self.chunk_policy.get().inflight.max(1);
        let mut slot = self.shadow_send_pool.borrow_mut();
        match slot.as_ref() {
            Some(pool) if pool.size() == want => Arc::clone(pool),
            _ => {
                let pool = Arc::new(ThreadPool::new(want));
                *slot = Some(Arc::clone(&pool));
                pool
            }
        }
    }

    /// Build a shadow communicator sharing this one's fabric, rank, size,
    /// member mapping, and chunk policy, with its own tag counter starting
    /// at `base` (the caller must have reserved the span via
    /// [`Communicator::reserve_tag_span`]). Its send pool is this
    /// communicator's memoized shadow pool, so repeated offloaded
    /// collectives reuse one set of worker threads. The nonblocking layer
    /// uses shadows to run blocking multi-round collectives off the SPMD
    /// thread without breaking the lock-step tag discipline.
    pub(crate) fn shadow_at(&self, base: Tag) -> Communicator {
        Communicator {
            fabric: Arc::clone(&self.fabric),
            rank: self.rank,
            size: self.size,
            members: Arc::clone(&self.members),
            next_tag: Cell::new(base),
            tag_limit: self.tag_limit,
            cid: self.cid,
            conf_token: self.conf_token,
            chunk_policy: Cell::new(self.chunk_policy.get()),
            chunk_pool: RefCell::new(Some(self.shadow_pool_handle())),
            shadow_send_pool: RefCell::new(None),
        }
    }

    /// Rebuild this communicator over a stats-scoping fabric decorator
    /// ([`crate::parcelport::ScopedPort`]), returning the scoped
    /// communicator and the private [`PortStats`] its sends are counted
    /// into. Rank, size, member mapping, tag counter position, tag
    /// bound, and chunk policy all carry over unchanged, so the scoped
    /// communicator is a drop-in *replacement*: the caller must stop
    /// using `self` afterwards (both share one tag space — interleaving
    /// allocations between them would collide). Every send path —
    /// direct, chunked, offload shadows — clones the communicator's
    /// fabric handle, so the scope sees all of the replacement's wire
    /// traffic. The FFT service wraps each job's sub-communicator this
    /// way to attribute bytes per job/tenant.
    pub fn with_stats_scope(&self) -> (Communicator, Arc<crate::parcelport::PortStats>) {
        let (fabric, scope) = crate::parcelport::ScopedPort::wrap(Arc::clone(&self.fabric));
        let comm = Communicator {
            fabric,
            rank: self.rank,
            size: self.size,
            members: Arc::clone(&self.members),
            next_tag: Cell::new(self.next_tag.get()),
            tag_limit: self.tag_limit,
            cid: self.cid,
            conf_token: self.conf_token,
            chunk_policy: Cell::new(self.chunk_policy.get()),
            chunk_pool: RefCell::new(None),
            shadow_send_pool: RefCell::new(None),
        };
        (comm, scope)
    }

    /// Pre-install this communicator's chunk-send and shadow-send pools
    /// (instead of letting first use create fresh ones). The FFT service
    /// leases pool pairs to jobs and installs them here, so worker
    /// threads are reused across the lifetime of the service rather than
    /// spawned per job. The pools must match the width the communicator
    /// will ask for (`chunk_policy().inflight.max(1)`) — a mismatched
    /// pool is silently replaced on first use, wasting the lease.
    pub(crate) fn install_pools(&self, chunk: Arc<ThreadPool>, shadow: Arc<ThreadPool>) {
        *self.chunk_pool.borrow_mut() = Some(chunk);
        *self.shadow_send_pool.borrow_mut() = Some(shadow);
    }

    /// Conformance identity of this communicator (0 = unregistered).
    pub(crate) fn conf_cid(&self) -> u64 {
        self.cid
    }

    /// Fabric identity token the conformance checker keys by.
    pub(crate) fn conf_token(&self) -> usize {
        self.conf_token
    }

    /// Send a collective-action parcel to communicator rank `dest`
    /// (translated to its global locality).
    pub(crate) fn send(&self, dest: LocalityId, tag: Tag, payload: Payload) {
        let (src, dst) = (self.my_global(), self.global_rank(dest));
        conformance::on_send(self.conf_token, self.cid, src, dst, tag);
        self.fabric.send(Parcel::new(src, dst, actions::COLLECTIVE, tag, payload));
    }

    /// Blocking matched receive of a collective-action parcel from
    /// communicator rank `src`.
    pub(crate) fn recv(&self, src: LocalityId, tag: Tag) -> Payload {
        let (dst, from) = (self.my_global(), self.global_rank(src));
        let _wait = conformance::on_recv_enter(self.conf_token, self.cid, dst, from, tag);
        self.fabric.recv(dst, from, actions::COLLECTIVE, tag)
    }

    /// Non-blocking matched receive (used by overlap-hungry callers).
    pub(crate) fn try_recv(&self, src: LocalityId, tag: Tag) -> Option<Payload> {
        self.fabric.try_recv(self.my_global(), self.global_rank(src), actions::COLLECTIVE, tag)
    }

    /// Expose a matched receive for application-level overlap (the
    /// N-scatter FFT variant polls for whichever root's chunk lands
    /// first).
    pub fn try_recv_tagged(&self, src: LocalityId, tag: Tag) -> Option<Payload> {
        self.try_recv(src, tag)
    }

    /// Blocking variant of [`Communicator::try_recv_tagged`].
    pub fn recv_tagged(&self, src: LocalityId, tag: Tag) -> Payload {
        self.recv(src, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parcelport::{lci::LciParcelport, PortKind};

    fn fabric(n: usize) -> Arc<dyn Parcelport> {
        Arc::new(LciParcelport::new(n, None))
    }

    #[test]
    fn construction_and_accessors() {
        let comm = Communicator::new(fabric(4), 2, 4);
        assert_eq!(comm.rank(), 2);
        assert_eq!(comm.size(), 4);
        assert_eq!(comm.fabric().kind(), PortKind::Lci);
        assert_eq!(comm.members(), &[0, 1, 2, 3], "whole-fabric mapping is the identity");
    }

    #[test]
    fn tag_blocks_do_not_overlap() {
        let comm = Communicator::new(fabric(4), 0, 4);
        let a = comm.alloc_tags();
        let b = comm.alloc_tags();
        assert!(b - a >= 4 * 4 + 8, "blocks must not overlap: {a} {b}");
    }

    #[test]
    fn tag_sequences_identical_across_ranks() {
        let f = fabric(2);
        let c0 = Communicator::new(Arc::clone(&f), 0, 2);
        let c1 = Communicator::new(Arc::clone(&f), 1, 2);
        for _ in 0..10 {
            assert_eq!(c0.alloc_tags(), c1.alloc_tags());
        }
    }

    #[test]
    fn chunk_tag_blocks_stay_in_lockstep() {
        let f = fabric(2);
        let c0 = Communicator::new(Arc::clone(&f), 0, 2);
        let c1 = Communicator::new(Arc::clone(&f), 1, 2);
        // Mixed small and chunked reservations must stay identical.
        assert_eq!(c0.alloc_tags(), c1.alloc_tags());
        assert_eq!(c0.alloc_chunk_tags(3), c1.alloc_chunk_tags(3));
        let a = c0.alloc_tags();
        assert_eq!(a, c1.alloc_tags());
        assert!(a >= 3 * CHUNK_TAG_SPAN, "chunk blocks must be reserved: {a}");
    }

    #[test]
    fn chunk_policy_roundtrip_and_pool_resize() {
        let comm = Communicator::new(fabric(2), 0, 2);
        assert_eq!(comm.chunk_policy(), ChunkPolicy::default());
        comm.set_chunk_policy(ChunkPolicy::new(4096, 2));
        assert_eq!(comm.chunk_policy().chunk_bytes, 4096);
        let p1 = comm.chunk_pool();
        assert_eq!(p1.size(), 2);
        assert!(Arc::ptr_eq(&p1, &comm.chunk_pool()), "pool is memoized");
        comm.set_chunk_policy(ChunkPolicy::new(4096, 3));
        assert_eq!(comm.chunk_pool().size(), 3, "pool follows inflight");
    }

    #[test]
    fn shadow_tags_stay_in_lockstep() {
        let f = fabric(2);
        let c0 = Communicator::new(Arc::clone(&f), 0, 2);
        let c1 = Communicator::new(Arc::clone(&f), 1, 2);
        let b0 = c0.reserve_tag_span(1000);
        let b1 = c1.reserve_tag_span(1000);
        assert_eq!(b0, b1, "reservations must match across ranks");
        let s0 = c0.shadow_at(b0);
        let s1 = c1.shadow_at(b1);
        assert_eq!(s0.alloc_tags(), s1.alloc_tags());
        assert_eq!(s0.chunk_policy(), c0.chunk_policy(), "shadow inherits policy");
        // Parent allocation resumes beyond the reserved span.
        assert!(c0.alloc_tags() >= b0 + 1000);
    }

    #[test]
    fn bounded_communicator_enforces_its_span() {
        let f = fabric(4);
        let members = Arc::new(vec![1usize, 3]);
        let sub = Communicator::from_members(
            Arc::clone(&f),
            0,
            members,
            500,
            500 + 10 * CHUNK_TAG_SPAN,
            ChunkPolicy::default(),
        );
        assert_eq!(sub.size(), 2);
        assert_eq!(sub.global_rank(1), 3);
        let first = sub.alloc_tags();
        assert_eq!(first, 500, "allocation starts at the reserved base");
        // Exhausting the span must trip the bound.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for _ in 0..11 {
                sub.alloc_chunk_tags(1);
            }
        }));
        assert!(result.is_err(), "allocating past the span must panic");
    }

    #[test]
    fn exhausted_reservation_is_typed_and_leaves_comm_usable() {
        let f = fabric(2);
        let sub = Communicator::from_members(
            Arc::clone(&f),
            0,
            Arc::new(vec![0, 1]),
            0,
            2 * CHUNK_TAG_SPAN,
            ChunkPolicy::default(),
        );
        let err = sub.try_reserve_tag_span(3 * CHUNK_TAG_SPAN).unwrap_err();
        assert_eq!(err.limit, 2 * CHUNK_TAG_SPAN);
        assert_eq!(err.requested, 3 * CHUNK_TAG_SPAN);
        assert!(err.to_string().contains("communicator tag space exhausted"), "{err}");
        // The failed reservation did not advance the counter: the
        // communicator keeps allocating inside its span, in lock-step.
        assert_eq!(sub.alloc_chunk_tags(1), 0);
        assert_eq!(sub.alloc_chunk_tags(1), CHUNK_TAG_SPAN);
    }

    #[test]
    fn stats_scope_preserves_identity_and_counts_sends() {
        let f = fabric(2);
        let c0 = Communicator::new(Arc::clone(&f), 0, 2);
        c0.set_chunk_policy(ChunkPolicy::new(4096, 2));
        let t = c0.alloc_tags();
        let (scoped, scope) = c0.with_stats_scope();
        assert_eq!(scoped.rank(), 0);
        assert_eq!(scoped.size(), 2);
        assert_eq!(scoped.members(), &[0, 1]);
        assert_eq!(scoped.chunk_policy(), ChunkPolicy::new(4096, 2));
        // The tag counter carries over: the scoped communicator resumes
        // where the original stopped (it *replaces* the original).
        assert!(scoped.alloc_tags() > t);
        scoped.send(1, 77, Payload::from_f32(&[1.0; 8]));
        let s = scope.snapshot();
        assert_eq!(s.msgs_sent, 1);
        assert_eq!(s.bytes_sent, 32);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_rank_panics() {
        Communicator::new(fabric(2), 2, 2);
    }

    #[test]
    #[should_panic(expected = "larger than fabric")]
    fn oversized_comm_panics() {
        Communicator::new(fabric(2), 0, 3);
    }

    #[test]
    #[should_panic(expected = "outside fabric")]
    fn member_outside_fabric_rejected() {
        let f = fabric(2);
        Communicator::from_members(
            f,
            0,
            Arc::new(vec![0, 5]),
            0,
            CHUNK_TAG_SPAN,
            ChunkPolicy::default(),
        );
    }
}
