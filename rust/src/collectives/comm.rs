//! The communicator: rank + size + fabric handle + tag discipline.

use crate::hpx::parcel::{actions, LocalityId, Parcel, Payload, Tag};
use crate::hpx::runtime::LocalityCtx;
use crate::parcelport::Parcelport;
use std::cell::Cell;
use std::sync::Arc;

/// A per-locality handle for collective operations.
///
/// Not `Sync` by design: one communicator belongs to one locality thread
/// (clone-per-thread, like an `MPI_Comm` rank handle). Tags for successive
/// collectives come from a local counter that stays in lock-step across
/// ranks under the SPMD calling discipline.
pub struct Communicator {
    fabric: Arc<dyn Parcelport>,
    rank: LocalityId,
    size: usize,
    next_tag: Cell<Tag>,
}

impl Communicator {
    pub fn new(fabric: Arc<dyn Parcelport>, rank: LocalityId, size: usize) -> Self {
        assert!(rank < size, "rank {rank} out of range for size {size}");
        assert!(size <= fabric.n_localities(), "communicator larger than fabric");
        Self { fabric, rank, size, next_tag: Cell::new(0) }
    }

    pub fn from_ctx(ctx: &LocalityCtx) -> Self {
        Self::new(Arc::clone(ctx.fabric()), ctx.rank, ctx.n)
    }

    pub fn rank(&self) -> LocalityId {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn fabric(&self) -> &Arc<dyn Parcelport> {
        &self.fabric
    }

    /// Allocate the base tag for one collective invocation. Each
    /// collective may use a contiguous block of `self.size` tags starting
    /// here (rounds, per-peer slots).
    pub(crate) fn alloc_tags(&self) -> Tag {
        let t = self.next_tag.get();
        // Reserve a generous block so algorithms can derive per-round /
        // per-peer tags without collision.
        self.next_tag.set(t + 4 * self.size as Tag + 8);
        t
    }

    /// Send a collective-action parcel.
    pub(crate) fn send(&self, dest: LocalityId, tag: Tag, payload: Payload) {
        self.fabric.send(Parcel::new(self.rank, dest, actions::COLLECTIVE, tag, payload));
    }

    /// Blocking matched receive of a collective-action parcel.
    pub(crate) fn recv(&self, src: LocalityId, tag: Tag) -> Payload {
        self.fabric.recv(self.rank, src, actions::COLLECTIVE, tag)
    }

    /// Non-blocking matched receive (used by overlap-hungry callers).
    pub(crate) fn try_recv(&self, src: LocalityId, tag: Tag) -> Option<Payload> {
        self.fabric.try_recv(self.rank, src, actions::COLLECTIVE, tag)
    }

    /// Expose a matched receive for application-level overlap (the
    /// N-scatter FFT variant polls for whichever root's chunk lands
    /// first).
    pub fn try_recv_tagged(&self, src: LocalityId, tag: Tag) -> Option<Payload> {
        self.try_recv(src, tag)
    }

    /// Blocking variant of [`Communicator::try_recv_tagged`].
    pub fn recv_tagged(&self, src: LocalityId, tag: Tag) -> Payload {
        self.recv(src, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parcelport::{lci::LciParcelport, PortKind};

    fn fabric(n: usize) -> Arc<dyn Parcelport> {
        Arc::new(LciParcelport::new(n, None))
    }

    #[test]
    fn construction_and_accessors() {
        let comm = Communicator::new(fabric(4), 2, 4);
        assert_eq!(comm.rank(), 2);
        assert_eq!(comm.size(), 4);
        assert_eq!(comm.fabric().kind(), PortKind::Lci);
    }

    #[test]
    fn tag_blocks_do_not_overlap() {
        let comm = Communicator::new(fabric(4), 0, 4);
        let a = comm.alloc_tags();
        let b = comm.alloc_tags();
        assert!(b - a >= 4 * 4 + 8, "blocks must not overlap: {a} {b}");
    }

    #[test]
    fn tag_sequences_identical_across_ranks() {
        let f = fabric(2);
        let c0 = Communicator::new(Arc::clone(&f), 0, 2);
        let c1 = Communicator::new(Arc::clone(&f), 1, 2);
        for _ in 0..10 {
            assert_eq!(c0.alloc_tags(), c1.alloc_tags());
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_rank_panics() {
        Communicator::new(fabric(2), 2, 2);
    }

    #[test]
    #[should_panic(expected = "larger than fabric")]
    fn oversized_comm_panics() {
        Communicator::new(fabric(2), 0, 3);
    }
}
