//! Reduce / all-reduce on f32 vectors (binomial tree + broadcast).

use super::comm::Communicator;
use crate::hpx::parcel::Payload;

/// Element-wise reduction operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// Element-wise sum.
    Sum,
    /// Element-wise maximum.
    Max,
    /// Element-wise minimum.
    Min,
}

impl ReduceOp {
    #[inline]
    fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        assert_eq!(acc.len(), other.len(), "reduce length mismatch");
        for (a, &b) in acc.iter_mut().zip(other) {
            *a = self.apply(*a, b);
        }
    }
}

impl Communicator {
    /// Binomial-tree reduce to `root`. Every rank contributes `data`;
    /// the root returns `Some(result)`, others `None`.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::reduce_async`]`.get()` — the futures engine is the
    /// only engine, so blocking and async reductions cannot diverge.
    pub fn reduce(&self, root: usize, data: &[f32], op: ReduceOp) -> Option<Vec<f32>> {
        self.reduce_async(root, data, op).get()
    }

    /// The round-paced blocking reduce tree. The nonblocking layer runs
    /// this on a shadow communicator inside a single pool job (see
    /// [`Communicator::reduce_async`]).
    pub(crate) fn reduce_blocking(
        &self,
        root: usize,
        data: &[f32],
        op: ReduceOp,
    ) -> Option<Vec<f32>> {
        assert!(root < self.size(), "root {root} out of range");
        let tag = self.alloc_tags();
        let n = self.size();
        let vrank = (self.rank() + n - root) % n;
        let mut acc = data.to_vec();

        // Mirror of the binomial broadcast tree, edges reversed: receive
        // from children (vrank + 2^k), then send to parent.
        let start = if vrank == 0 { 1 } else { 1 << (usize::BITS - vrank.leading_zeros()) };
        // Children must be combined in *descending* step order to mirror
        // their own completion order; any fixed order is deterministic
        // for Sum/Max/Min, so ascending is fine and simpler.
        let mut step = start;
        while vrank + step < n {
            let child = ((vrank + step) + root) % n;
            let contrib = self.recv(child, tag).to_f32();
            op.combine(&mut acc, &contrib);
            step <<= 1;
        }
        if vrank != 0 {
            let mask = 1 << (usize::BITS - 1 - vrank.leading_zeros());
            let parent = ((vrank ^ mask) + root) % n;
            self.send(parent, tag, Payload::from_f32(&acc));
            None
        } else {
            Some(acc)
        }
    }

    /// All-reduce = reduce to rank 0 + broadcast.
    pub fn all_reduce(&self, data: &[f32], op: ReduceOp) -> Vec<f32> {
        let reduced = self.reduce(0, data, op);
        let payload = reduced.map(|v| Payload::from_f32(&v));
        self.broadcast(0, if self.rank() == 0 { payload } else { None }).to_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn sum_reduce_all_roots() {
        let n = 5;
        for root in 0..n {
            let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.reduce(root, &[ctx.rank as f32, 1.0], ReduceOp::Sum)
            });
            let expect = vec![(n * (n - 1) / 2) as f32, n as f32];
            for (r, g) in got.iter().enumerate() {
                if r == root {
                    assert_eq!(g.as_ref().unwrap(), &expect);
                } else {
                    assert!(g.is_none());
                }
            }
        }
    }

    #[test]
    fn max_and_min() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let v = [ctx.rank as f32, -(ctx.rank as f32)];
            let mx = comm.all_reduce(&v, ReduceOp::Max);
            let mn = comm.all_reduce(&v, ReduceOp::Min);
            (mx, mn)
        });
        for (mx, mn) in got {
            assert_eq!(mx, vec![3.0, 0.0]);
            assert_eq!(mn, vec![0.0, -3.0]);
        }
    }

    #[test]
    fn all_reduce_consistent_across_ranks() {
        let cluster = Cluster::new(7, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.all_reduce(&[1.0; 3], ReduceOp::Sum)
        });
        for g in got {
            assert_eq!(g, vec![7.0; 3]);
        }
    }

    #[test]
    fn single_rank_reduce() {
        let cluster = Cluster::new(1, PortKind::Lci, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.reduce(0, &[5.0], ReduceOp::Sum).unwrap()
        });
        assert_eq!(got[0], vec![5.0]);
    }
}
