//! All-to-all: five algorithms with one semantic.
//!
//! Semantics (MPI_Alltoall / `hpx::collectives::all_to_all`): rank `i`
//! provides `chunks[j]` for every `j`; afterwards rank `i` holds, in slot
//! `j`, the chunk rank `j` addressed to `i`. Equivalently, the global
//! chunk matrix is transposed.
//!
//! | algorithm | traffic | when it wins |
//! |---|---|---|
//! | [`AllToAllAlgo::Linear`] | N² eager sends, all at once | small N, big messages |
//! | [`AllToAllAlgo::Pairwise`] | N−1 balanced exchange rounds | the classic MPI large-message algorithm (used by our FFTW3-like baseline) |
//! | [`AllToAllAlgo::PairwiseChunked`] | N−1 rounds, each message split into [`crate::collectives::ChunkPolicy`]-sized pipelined wire chunks | large messages whose protocol/wire work benefits from overlap — the paper's chunk-size experiment |
//! | [`AllToAllAlgo::Bruck`] | ⌈log2 N⌉ rounds of aggregated chunks | small messages, large N |
//! | [`AllToAllAlgo::HpxRoot`] | gather-to-root + scatter-from-root | never — it models HPX's root-funneled collective, the overhead the paper measures against |
//!
//! The paper's Fig. 4 uses HPX's collective (→ `HpxRoot` here); Fig. 5
//! replaces it with N overlapped scatters (see
//! [`crate::dist_fft::scatter_variant`]).

use super::comm::Communicator;
use super::protocol;
use crate::hpx::parcel::Payload;

/// Algorithm selector for [`Communicator::all_to_all`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllToAllAlgo {
    /// N² eager sends, all posted at once.
    Linear,
    /// N−1 balanced exchange rounds (the classic MPI large-message
    /// algorithm).
    Pairwise,
    /// Pairwise schedule, but each per-rank message travels as pipelined
    /// wire chunks under the communicator's
    /// [`crate::collectives::ChunkPolicy`].
    PairwiseChunked,
    /// ⌈log2 N⌉ rounds of aggregated chunks (small-message algorithm).
    Bruck,
    /// Gather-to-root + scatter-from-root — models HPX's root-funneled
    /// collective, the overhead the paper measures against.
    HpxRoot,
}

impl AllToAllAlgo {
    /// Every algorithm, in presentation order.
    pub const ALL: [AllToAllAlgo; 5] = [
        AllToAllAlgo::Linear,
        AllToAllAlgo::Pairwise,
        AllToAllAlgo::PairwiseChunked,
        AllToAllAlgo::Bruck,
        AllToAllAlgo::HpxRoot,
    ];

    /// Lowercase algorithm name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            AllToAllAlgo::Linear => "linear",
            AllToAllAlgo::Pairwise => "pairwise",
            AllToAllAlgo::PairwiseChunked => "pairwise-chunked",
            AllToAllAlgo::Bruck => "bruck",
            AllToAllAlgo::HpxRoot => "hpx-root",
        }
    }
}

impl std::str::FromStr for AllToAllAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(AllToAllAlgo::Linear),
            "pairwise" => Ok(AllToAllAlgo::Pairwise),
            "pairwise-chunked" | "pairwise_chunked" | "chunked" => {
                Ok(AllToAllAlgo::PairwiseChunked)
            }
            "bruck" => Ok(AllToAllAlgo::Bruck),
            "hpx-root" | "hpx_root" | "hpxroot" => Ok(AllToAllAlgo::HpxRoot),
            other => Err(format!("unknown all-to-all algorithm {other:?}")),
        }
    }
}

/// Peer pairing for pairwise-exchange round `r` (`1 <= r < n`): the XOR
/// schedule on power-of-two sizes, ring offsets otherwise. Returns
/// `(send_to, recv_from)`.
pub(crate) fn pairwise_peers(me: usize, n: usize, r: usize) -> (usize, usize) {
    if n.is_power_of_two() {
        (me ^ r, me ^ r)
    } else {
        ((me + r) % n, (me + n - r) % n)
    }
}

impl Communicator {
    /// Exchange `chunks` (one per destination rank, in rank order);
    /// returns one payload per source rank, in rank order.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::all_to_all_async`]`.get()` — the futures engine is
    /// the only engine, so blocking and async callers cannot diverge.
    pub fn all_to_all(&self, chunks: Vec<Payload>, algo: AllToAllAlgo) -> Vec<Payload> {
        self.all_to_all_async(chunks, algo).get()
    }

    /// The round-paced blocking schedules, dispatched by algorithm. The
    /// nonblocking layer runs these on a shadow communicator inside a
    /// single pool job to preserve each algorithm's pacing (the property
    /// the benchmark measures) while still posting in O(1).
    pub(crate) fn all_to_all_blocking(
        &self,
        chunks: Vec<Payload>,
        algo: AllToAllAlgo,
    ) -> Vec<Payload> {
        assert_eq!(chunks.len(), self.size(), "need one chunk per rank");
        match algo {
            AllToAllAlgo::Linear => self.a2a_linear(chunks),
            AllToAllAlgo::Pairwise => self.a2a_pairwise(chunks),
            AllToAllAlgo::PairwiseChunked => self.a2a_pairwise_chunked(chunks),
            AllToAllAlgo::Bruck => self.a2a_bruck(chunks),
            AllToAllAlgo::HpxRoot => self.a2a_hpx_root(chunks),
        }
    }

    /// Post everything, then drain: maximal overlap, N² in-flight parcels.
    /// Runs the [`protocol::LinearA2a`] machine against the live fabric —
    /// the same machine the discrete-event simulator schedules.
    fn a2a_linear(&self, chunks: Vec<Payload>) -> Vec<Payload> {
        let sm = protocol::LinearA2a::new(self.rank(), self.size(), self.alloc_tags(), chunks);
        protocol::drive(self, sm, |_, _, _| {})
    }

    /// N−1 rounds; in round `r` exchange with `rank ^ r` (power-of-two
    /// sizes) or `rank ± r` (general). One send + one recv in flight per
    /// rank per round — the bandwidth-friendly schedule, expressed as the
    /// [`protocol::PairwiseA2a`] machine.
    fn a2a_pairwise(&self, chunks: Vec<Payload>) -> Vec<Payload> {
        let sm = protocol::PairwiseA2a::new(self.rank(), self.size(), self.alloc_tags(), chunks);
        protocol::drive(self, sm, |_, _, _| {})
    }

    /// The pairwise schedule with each per-rank message split into
    /// policy-sized wire chunks that pipeline through the communicator's
    /// send pool: while this rank blocks in the matched receive of round
    /// `r`, its outgoing chunks for round `r` (and any still queued from
    /// earlier rounds) keep draining — no per-round barrier. Splitting
    /// uses [`Payload::slice`], so the send side performs zero copies.
    ///
    /// A buffering adapter over
    /// [`Communicator::all_to_all_chunked_each`]: single-chunk transfers
    /// (and the own-rank payload) pass through without copy, so the LCI
    /// path stays zero-copy end to end; multi-chunk transfers are
    /// concatenated at the application layer, which is reassembly, not a
    /// port protocol copy — port statistics stay untouched by it.
    fn a2a_pairwise_chunked(&self, chunks: Vec<Payload>) -> Vec<Payload> {
        let n = self.size();
        let mut parts: Vec<Vec<Payload>> = (0..n).map(|_| Vec::new()).collect();
        self.all_to_all_chunked_each(chunks, |src, _off, p| parts[src].push(p));
        parts
            .into_iter()
            .map(|mut ps| match ps.len() {
                0 => Payload::empty(),
                1 => ps.pop().expect("one chunk"),
                _ => {
                    let total: usize = ps.iter().map(Payload::len).sum();
                    let mut buf = Vec::with_capacity(total);
                    for p in &ps {
                        buf.extend_from_slice(p.as_bytes());
                    }
                    Payload::new(buf)
                }
            })
            .collect()
    }

    /// Bruck's algorithm: ⌈log2 n⌉ rounds, each moving aggregated blocks
    /// of chunks. Latency-optimal for small messages; the aggregation
    /// concatenates payloads with the
    /// [`protocol::Wire::frame_indexed`] length-prefixed framing.
    /// Rotation, rounds, and the inverse
    /// rotation all live in the [`protocol::BruckA2a`] machine.
    fn a2a_bruck(&self, chunks: Vec<Payload>) -> Vec<Payload> {
        let sm = protocol::BruckA2a::new(self.rank(), self.size(), self.alloc_tags(), chunks);
        protocol::drive(self, sm, |_, _, _| {})
    }

    /// HPX's communicator-based collective funnels contributions through
    /// the communicator root: gather all N×N chunks to rank 0, transpose
    /// there, scatter back out. Synchronized and root-bottlenecked —
    /// which is precisely the overhead the paper's N-scatter variant
    /// avoids. The whole funnel (row framing, root transpose, column
    /// scatter) is the [`protocol::HpxRootA2a`] machine; it stays inline
    /// on this thread (which may be a pool worker running the offloaded
    /// root-funnel), so it never re-enters the async engine. Two tag
    /// blocks are allocated — gather then scatter — preserving the
    /// historical lock-step numbering.
    fn a2a_hpx_root(&self, chunks: Vec<Payload>) -> Vec<Payload> {
        let gather_tag = self.alloc_tags();
        let scatter_tag = self.alloc_tags();
        let sm =
            protocol::HpxRootA2a::new(self.rank(), self.size(), gather_tag, scatter_tag, chunks);
        protocol::drive(self, sm, |_, _, _| {})
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ChunkPolicy;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::check;

    /// The defining property: all_to_all == transpose of the chunk matrix.
    fn transpose_property(n: usize, algo: AllToAllAlgo, kind: PortKind, chunk_len: usize) {
        let cluster = Cluster::new(n, kind, None).unwrap();
        let results = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let send: Vec<Payload> = (0..n)
                .map(|dst| Payload::from_f32(&vec![(ctx.rank * n + dst) as f32; chunk_len]))
                .collect();
            comm.all_to_all(send, algo)
        });
        for (i, recv) in results.iter().enumerate() {
            for (j, p) in recv.iter().enumerate() {
                assert_eq!(
                    p.to_f32(),
                    vec![(j * n + i) as f32; chunk_len],
                    "algo {algo:?}: rank {i} slot {j}"
                );
            }
        }
    }

    #[test]
    fn all_algorithms_pow2() {
        for algo in AllToAllAlgo::ALL {
            transpose_property(4, algo, PortKind::Lci, 8);
        }
    }

    #[test]
    fn all_algorithms_non_pow2() {
        for algo in AllToAllAlgo::ALL {
            transpose_property(5, algo, PortKind::Lci, 3);
        }
    }

    #[test]
    fn all_algorithms_n2_and_n1() {
        for algo in AllToAllAlgo::ALL {
            transpose_property(2, algo, PortKind::Lci, 4);
            transpose_property(1, algo, PortKind::Lci, 4);
        }
    }

    #[test]
    fn pairwise_over_mpi_rendezvous_sizes() {
        // 70 KiB chunks push the MPI port onto the rendezvous path.
        transpose_property(4, AllToAllAlgo::Pairwise, PortKind::Mpi, 70 * 1024 / 4);
    }

    /// Same defining property, with a wire-chunk size small enough that
    /// every per-rank message splits into several pipelined chunks.
    fn chunked_transpose_property(n: usize, kind: PortKind, chunk_len: usize, policy: ChunkPolicy) {
        let cluster = Cluster::new(n, kind, None).unwrap();
        let results = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(policy);
            let send: Vec<Payload> = (0..n)
                .map(|dst| Payload::from_f32(&vec![(ctx.rank * n + dst) as f32; chunk_len]))
                .collect();
            comm.all_to_all(send, AllToAllAlgo::PairwiseChunked)
        });
        for (i, recv) in results.iter().enumerate() {
            for (j, p) in recv.iter().enumerate() {
                assert_eq!(p.to_f32(), vec![(j * n + i) as f32; chunk_len], "rank {i} slot {j}");
            }
        }
    }

    #[test]
    fn pairwise_chunked_multi_chunk_all_ports() {
        for kind in PortKind::ALL {
            // 256-byte messages over 36-byte chunks: 8 chunks inc. a
            // ragged tail, 2 in flight.
            chunked_transpose_property(4, kind, 64, ChunkPolicy::new(36, 2));
        }
    }

    #[test]
    fn pairwise_chunked_non_pow2_and_single_inflight() {
        chunked_transpose_property(5, PortKind::Lci, 13, ChunkPolicy::new(8, 1));
        chunked_transpose_property(3, PortKind::Mpi, 40, ChunkPolicy::new(64, 3));
    }

    #[test]
    fn pairwise_chunked_over_mpi_rendezvous_chunks() {
        // Chunks above the eager threshold: every wire chunk takes the
        // RTS/CTS path.
        chunked_transpose_property(
            2,
            PortKind::Mpi,
            96 * 1024 / 4,
            ChunkPolicy::new(80 * 1024, 2),
        );
    }

    /// The satellite acceptance check: chunking splits the wire traffic
    /// but must never change the result — for every algorithm, on every
    /// parcelport, against the monolithic pairwise reference.
    #[test]
    fn chunked_matches_monolithic_every_algo_every_port() {
        let n = 4;
        let chunk_len = 48; // 192 B per message → 4 wire chunks of 48 B
        for kind in PortKind::ALL {
            let mut reference: Option<Vec<Vec<Vec<u8>>>> = None;
            for algo in AllToAllAlgo::ALL {
                let cluster = Cluster::new(n, kind, None).unwrap();
                let results = cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    comm.set_chunk_policy(ChunkPolicy::new(48, 2));
                    let send: Vec<Payload> = (0..n)
                        .map(|dst| {
                            Payload::from_f32(&vec![(ctx.rank * n + dst) as f32; chunk_len])
                        })
                        .collect();
                    comm.all_to_all(send, algo)
                        .into_iter()
                        .map(|p| p.as_bytes().to_vec())
                        .collect::<Vec<_>>()
                });
                match &reference {
                    None => reference = Some(results),
                    Some(r) => assert_eq!(r, &results, "{kind} {algo:?} deviates"),
                }
            }
        }
    }

    #[test]
    fn linear_over_tcp() {
        transpose_property(3, AllToAllAlgo::Linear, PortKind::Tcp, 16);
    }

    #[test]
    fn algorithms_agree_randomized() {
        // Property: every algorithm produces identical results on random
        // ragged payloads.
        check(
            0xA2A,
            8,
            |rng| {
                let n = rng.range(2, 6);
                let lens: Vec<Vec<usize>> =
                    (0..n).map(|_| (0..n).map(|_| rng.range(0, 64)).collect()).collect();
                (n, lens)
            },
            |(n, lens)| {
                let n = *n;
                let mut reference: Option<Vec<Vec<Vec<u8>>>> = None;
                for algo in AllToAllAlgo::ALL {
                    let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
                    let lens = lens.clone();
                    let results = cluster.run(move |ctx| {
                        let comm = Communicator::from_ctx(ctx);
                        // Tiny, unaligned wire chunks stress the ragged
                        // reassembly path of the chunked algorithm.
                        comm.set_chunk_policy(ChunkPolicy::new(7, 2));
                        let send: Vec<Payload> = (0..n)
                            .map(|dst| {
                                let len = lens[ctx.rank][dst];
                                let mut rng =
                                    Pcg32::with_stream(99, (ctx.rank * n + dst) as u64);
                                Payload::new(
                                    (0..len).map(|_| rng.next_u32() as u8).collect(),
                                )
                            })
                            .collect();
                        comm.all_to_all(send, algo)
                            .into_iter()
                            .map(|p| p.as_bytes().to_vec())
                            .collect::<Vec<_>>()
                    });
                    match &reference {
                        None => reference = Some(results),
                        Some(r) => assert_eq!(r, &results, "algo {algo:?} deviates"),
                    }
                }
            },
        );
    }

    #[test]
    fn algo_parse() {
        assert_eq!("bruck".parse::<AllToAllAlgo>().unwrap(), AllToAllAlgo::Bruck);
        assert_eq!("hpx-root".parse::<AllToAllAlgo>().unwrap(), AllToAllAlgo::HpxRoot);
        assert_eq!(
            "pairwise-chunked".parse::<AllToAllAlgo>().unwrap(),
            AllToAllAlgo::PairwiseChunked
        );
        assert_eq!("chunked".parse::<AllToAllAlgo>().unwrap(), AllToAllAlgo::PairwiseChunked);
        assert!("magic".parse::<AllToAllAlgo>().is_err());
    }
}
