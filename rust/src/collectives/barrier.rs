//! Dissemination barrier.

use super::comm::Communicator;
use crate::hpx::parcel::Payload;

impl Communicator {
    /// Dissemination barrier: ⌈log2 n⌉ rounds; in round `k` each rank
    /// signals `rank + 2^k` and waits for `rank - 2^k` (mod n). No rank
    /// exits before every rank has entered.
    ///
    /// A thin blocking wrapper over
    /// [`Communicator::barrier_async`]`.get()`.
    pub fn barrier(&self) {
        self.barrier_async().get()
    }

    /// The round-paced blocking dissemination schedule. The nonblocking
    /// layer runs this on a shadow communicator inside a single pool job
    /// (see [`Communicator::barrier_async`]).
    pub(crate) fn barrier_blocking(&self) {
        let n = self.size();
        let tag = self.alloc_tags();
        if n <= 1 {
            return;
        }
        let mut step = 1;
        let mut round = 0u64;
        while step < n {
            let to = (self.rank() + step) % n;
            let from = (self.rank() + n - step) % n;
            self.send(to, tag + round, Payload::empty());
            self.recv(from, tag + round);
            step <<= 1;
            round += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn barrier_completes_all_sizes() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
            cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.barrier();
            });
        }
    }

    #[test]
    fn barrier_actually_synchronizes() {
        // No rank may observe fewer than n arrivals after the barrier.
        let n = 6;
        let arrivals = AtomicUsize::new(0);
        let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            // Stagger entry to make missed synchronization observable.
            std::thread::sleep(std::time::Duration::from_millis(ctx.rank as u64 * 3));
            arrivals.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            assert_eq!(arrivals.load(Ordering::SeqCst), n, "rank {} exited early", ctx.rank);
        });
    }

    #[test]
    fn repeated_barriers_do_not_cross_talk() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            for _ in 0..20 {
                comm.barrier();
            }
        });
    }

    #[test]
    fn barrier_over_tcp() {
        let cluster = Cluster::new(3, PortKind::Tcp, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.barrier();
            comm.barrier();
        });
    }
}
