//! Collective operations over a parcelport fabric.
//!
//! The paper's FFT exercises two collectives — *scatter* and *all-to-all*
//! — but a usable communication layer needs the full family, so this
//! module provides: scatter (linear and chunk-pipelined), gather,
//! broadcast, all-gather, reduce, all-reduce, barrier, and all-to-all
//! with five algorithms (including [`AllToAllAlgo::HpxRoot`], the
//! root-funneled variant modeling HPX's communicator-based collective,
//! whose synchronization cost is the reason the paper's N-scatter
//! approach wins, and [`AllToAllAlgo::PairwiseChunked`], the pipelined
//! chunked exchange built on [`ChunkPolicy`] and zero-copy payload
//! slices — see [`chunked`]).
//!
//! All collectives are SPMD: every rank of a [`Communicator`] must call
//! the same collectives in the same order (tags are allocated from a
//! per-rank counter that stays in lock-step under that discipline — the
//! same contract MPI imposes on communicator operations).
//!
//! The collective engine is **futures-first** ([`nonblocking`]):
//! `Communicator::{all_to_all_async, scatter_async, gather_async,
//! broadcast_async, reduce_async, barrier_async}` post receives into the
//! mailbox and drive sends from the communicator's chunk pool, returning
//! a [`crate::task::CollectiveFuture`] within O(posting) time. Their
//! blocking entry points (`all_to_all`, `scatter`, `gather`, `broadcast`,
//! `reduce`, `barrier`) are thin `get()` wrappers over them; only
//! all-gather remains direct (it is the bootstrap [`split`] itself rides
//! on).
//!
//! Communicators need not span the whole fabric:
//! [`Communicator::split`] carves sub-communicators with disjoint tag
//! spaces (see [`tags`]) and their own chunk pools — the capability the
//! 3-D pencil FFT's row/column exchanges are built on.
//!
//! Every blocking algorithm is implemented once, as an event-driven
//! state machine in [`protocol`], and merely *driven* here against the
//! live fabric. The discrete-event simulator
//! ([`crate::simnet::collective_sim`]) schedules the same machines over
//! simulated NICs under adversarial orderings, so the protocol logic
//! exercised at 4 in-process ranks and at 4096 simulated localities is
//! the same code.

pub mod all_to_all;
pub mod barrier;
pub mod broadcast;
pub mod chunked;
pub mod comm;
pub mod conformance;
pub mod gather;
pub mod nonblocking;
pub mod protocol;
pub mod reduce;
pub mod scatter;
pub mod split;
pub mod tags;

pub use all_to_all::AllToAllAlgo;
pub use chunked::ChunkPolicy;
pub use comm::{Communicator, TagSpaceExhausted};
pub use reduce::ReduceOp;
pub use scatter::ScatterAlgo;

#[cfg(test)]
mod tests {
    //! Cross-port, cross-algorithm equivalence tests: every collective
    //! must produce identical results over TCP, MPI, and LCI fabrics.

    use super::*;
    use crate::hpx::runtime::Cluster;
    use crate::hpx::parcel::Payload;
    use crate::parcelport::PortKind;
    use crate::util::rng::Pcg32;

    fn rank_data(rank: usize, len: usize) -> Vec<f32> {
        let mut rng = Pcg32::with_stream(0x5EED, rank as u64 + 1);
        (0..len).map(|_| rng.next_signal()).collect()
    }

    fn full_suite(kind: PortKind, n: usize) {
        let cluster = Cluster::new(n, kind, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            // Tiny wire chunks so the chunked algorithms exercise their
            // multi-chunk path on every port.
            comm.set_chunk_policy(ChunkPolicy::new(5, 2));

            // Broadcast from every root in turn.
            for root in 0..n {
                let mine = if ctx.rank == root {
                    Some(Payload::from_f32(&rank_data(root, 17)))
                } else {
                    None
                };
                let got = comm.broadcast(root, mine);
                assert_eq!(got.to_f32(), rank_data(root, 17), "bcast root {root} at {}", ctx.rank);
            }

            // Scatter/gather roundtrip from root 1 (if it exists).
            let root = 1.min(n - 1);
            let chunks = if ctx.rank == root {
                Some((0..n).map(|i| Payload::from_f32(&rank_data(i, 9))).collect())
            } else {
                None
            };
            let mine = comm.scatter(root, chunks);
            assert_eq!(mine.to_f32(), rank_data(ctx.rank, 9));
            let gathered = comm.gather(root, mine);
            if ctx.rank == root {
                let gathered = gathered.unwrap();
                for (i, p) in gathered.iter().enumerate() {
                    assert_eq!(p.to_f32(), rank_data(i, 9), "gather slot {i}");
                }
            }

            // All-gather.
            let all = comm.all_gather(Payload::from_f32(&rank_data(ctx.rank, 5)));
            for (i, p) in all.iter().enumerate() {
                assert_eq!(p.to_f32(), rank_data(i, 5), "all_gather slot {i}");
            }

            // Reduce (sum) to root 0 + all_reduce.
            let contrib: Vec<f32> = vec![ctx.rank as f32 + 1.0; 4];
            let reduced = comm.reduce(0, &contrib, ReduceOp::Sum);
            let expect_sum = (n * (n + 1) / 2) as f32;
            if ctx.rank == 0 {
                assert_eq!(reduced.unwrap(), vec![expect_sum; 4]);
            }
            let all_red = comm.all_reduce(&contrib, ReduceOp::Sum);
            assert_eq!(all_red, vec![expect_sum; 4]);

            // Barrier (just must not hang / cross rounds).
            comm.barrier();

            // All-to-all, every algorithm.
            for algo in AllToAllAlgo::ALL {
                let send: Vec<Payload> = (0..n)
                    .map(|dst| Payload::from_f32(&vec![(ctx.rank * n + dst) as f32; 3]))
                    .collect();
                let recv = comm.all_to_all(send, algo);
                for (src, p) in recv.iter().enumerate() {
                    assert_eq!(
                        p.to_f32(),
                        vec![(src * n + ctx.rank) as f32; 3],
                        "all_to_all {algo:?} from {src} at {}",
                        ctx.rank
                    );
                }
            }
        });
    }

    #[test]
    fn suite_lci_4() {
        full_suite(PortKind::Lci, 4);
    }

    #[test]
    fn suite_mpi_4() {
        full_suite(PortKind::Mpi, 4);
    }

    #[test]
    fn suite_tcp_4() {
        full_suite(PortKind::Tcp, 4);
    }

    #[test]
    fn suite_lci_non_pow2() {
        full_suite(PortKind::Lci, 5);
    }

    #[test]
    fn suite_mpi_non_pow2() {
        full_suite(PortKind::Mpi, 3);
    }

    #[test]
    fn suite_single_rank() {
        full_suite(PortKind::Lci, 1);
    }

    #[test]
    fn suite_two_ranks() {
        full_suite(PortKind::Tcp, 2);
    }
}
