//! Runtime protocol-conformance and deadlock detection for the
//! collective layer.
//!
//! The collectives rely on invariants the type system cannot see: tags
//! stay inside the owning communicator's reserved span, wire chunks of a
//! transfer arrive in index order, sub-communicator spans never collide,
//! and no set of ranks ends up mutually blocked on messages none of them
//! will ever send (the PR 6 cross-job pool-lease deadlock). This module
//! checks all four at runtime:
//!
//! - **Wait-for graph.** Every blocking matched receive on a
//!   [`super::Communicator`] registers a `waiter → src (tag)` edge,
//!   *unless* the awaited message is already on the wire (every send
//!   that can feed a blocking receive — `Communicator::send` and the
//!   pooled chunk-send closures — is recorded, so a receive that merely
//!   trails its send never looks blocked). A send that satisfies a live
//!   edge clears it. If inserting an edge closes a cycle, the inserting
//!   thread panics with a typed [`DeadlockDiagnosis`] — cycle edges,
//!   held pool-lease labels, open obs spans — instead of blocking
//!   forever; [`crate::util::testkit::with_watchdog`] also queries
//!   [`diagnose`] on timeout. The nonblocking offload layer is
//!   deliberately invisible to the graph: its sends and receives run on
//!   pool workers, pair only with each other on tags no blocking
//!   receive waits on, and never block the SPMD thread.
//! - **Per-message conformance.** Sends and receives on a registered
//!   (split) communicator are checked against its tag span; chunked
//!   receives are checked for monotonic chunk indices per transfer; and
//!   registering a sub-communicator whose span overlaps another
//!   registered span with intersecting members (and is not a nested
//!   parent/child reservation) is flagged as a tag collision.
//!
//! The checker is compiled only under `debug_assertions` or the
//! `conformance` feature ([`ACTIVE`]) and does nothing until a test
//! [`arm`]s it, so release builds pay zero cost — asserted by the
//! `conformance hook` row in `benches/hotpath.rs` — and unarmed debug
//! runs pay one relaxed atomic load per hook.

use crate::hpx::parcel::Tag;
use crate::obs::OpenSpan;
use std::fmt;

/// Whether the detector is compiled into this build (`debug_assertions`
/// or the `conformance` feature). When `false` every hook in this
/// module is an empty inline stub.
pub const ACTIVE: bool = cfg!(any(debug_assertions, feature = "conformance"));

/// One blocked rank in the wait-for graph: `waiter` sits in a blocking
/// matched receive for a message from `src` on `tag` that has not been
/// sent.
#[derive(Clone, Debug)]
pub struct WaitEdge {
    /// Identity token of the fabric the edge belongs to.
    pub fabric: usize,
    /// Global locality blocked in the receive.
    pub waiter: usize,
    /// Global locality the waiter expects the message from.
    pub src: usize,
    /// Wire tag of the awaited message.
    pub tag: Tag,
    /// Pool-lease labels held by the blocked thread when it blocked
    /// (see [`lease`]) — names the jobs involved in a cross-job
    /// pool-lease deadlock.
    pub leases: Vec<String>,
}

/// Typed dump produced when the wait-for graph closes a cycle.
#[derive(Clone, Debug)]
pub struct DeadlockDiagnosis {
    /// The cycle's edges in walk order (last edge returns to the first
    /// edge's waiter).
    pub cycle: Vec<WaitEdge>,
    /// Obs spans open at detection time (empty unless tracing is on).
    pub open_spans: Vec<OpenSpan>,
}

impl fmt::Display for DeadlockDiagnosis {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wait-for cycle across {} rank(s)", self.cycle.len())?;
        for e in &self.cycle {
            write!(f, "\n  rank {} waits on rank {} (tag {})", e.waiter, e.src, e.tag)?;
            if !e.leases.is_empty() {
                write!(f, " holding [{}]", e.leases.join(", "))?;
            }
        }
        for s in &self.open_spans {
            write!(
                f,
                "\n  open span: {}/{} rank {} tag {} chunk {}",
                s.cat, s.name, s.rank, s.tag, s.chunk
            )?;
        }
        Ok(())
    }
}

/// A per-message protocol-conformance violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// A send or receive used a tag outside its communicator's span.
    TagOutsideSpan {
        /// Conformance id of the offending communicator.
        cid: u64,
        /// The out-of-span tag.
        tag: Tag,
        /// Inclusive base of the communicator's span.
        base: Tag,
        /// Exclusive limit of the communicator's span.
        limit: Tag,
    },
    /// Two registered communicators share member ranks over overlapping
    /// tag spans that are not a nested parent/child reservation.
    TagCollision {
        /// Conformance id of the earlier-registered communicator.
        a: u64,
        /// Conformance id of the later-registered communicator.
        b: u64,
        /// Base of the overlapping region.
        base: Tag,
        /// Exclusive limit of the overlapping region.
        limit: Tag,
    },
    /// Wire chunks of one chunked transfer arrived out of index order.
    NonMonotonicChunk {
        /// Sending global locality.
        src: usize,
        /// Receiving global locality.
        dst: usize,
        /// Base tag of the transfer's chunk block.
        base_tag: Tag,
        /// Next index the receiver should have seen.
        expected: u64,
        /// Index that actually arrived.
        got: u64,
    },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::TagOutsideSpan { cid, tag, base, limit } => {
                write!(f, "tag {tag} outside communicator {cid}'s span [{base}, {limit})")
            }
            Violation::TagCollision { a, b, base, limit } => write!(
                f,
                "communicators {a} and {b} share member ranks over \
                 overlapping tag span [{base}, {limit})"
            ),
            Violation::NonMonotonicChunk { src, dst, base_tag, expected, got } => write!(
                f,
                "chunked transfer {src}→{dst} on base tag {base_tag}: \
                 chunk {got} arrived, expected {expected}"
            ),
        }
    }
}

#[cfg(any(debug_assertions, feature = "conformance"))]
mod imp {
    use super::{DeadlockDiagnosis, Violation, WaitEdge};
    use crate::hpx::parcel::Tag;
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    /// `(fabric token, dst locality, src locality, tag)` — the identity
    /// the fabrics match messages by (action is always COLLECTIVE here).
    type MsgKey = (usize, usize, usize, Tag);

    struct CommReg {
        fabric: usize,
        cid: u64,
        base: Tag,
        limit: Tag,
        members: Vec<usize>,
    }

    struct EdgeRec {
        id: u64,
        edge: WaitEdge,
    }

    #[derive(Default)]
    struct Registry {
        next_edge: u64,
        comms: Vec<CommReg>,
        sent: HashMap<MsgKey, u32>,
        edges: Vec<EdgeRec>,
        chunks: HashMap<MsgKey, u64>,
        last_deadlock: Option<DeadlockDiagnosis>,
        last_violation: Option<Violation>,
    }

    static ARMED: AtomicBool = AtomicBool::new(false);
    static NEXT_COMM_ID: AtomicU64 = AtomicU64::new(1);
    static ARM_SERIAL: Mutex<()> = Mutex::new(());
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

    thread_local! {
        static LEASES: RefCell<Vec<String>> = RefCell::new(Vec::new());
    }

    fn registry() -> MutexGuard<'static, Registry> {
        // Poison-tolerant: conformance panics unwind through test
        // threads by design and must not wedge later lock users.
        REGISTRY
            .get_or_init(|| Mutex::new(Registry::default()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// Whether the detector is currently recording (armed by a test).
    #[inline]
    pub fn armed() -> bool {
        ARMED.load(Ordering::Relaxed)
    }

    /// Arm the detector for the guard's lifetime, clearing all recorded
    /// state. Tests that arm are serialized against each other so their
    /// graphs cannot interleave.
    pub fn arm() -> ArmGuard {
        let serial = ARM_SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
        *registry() = Registry::default();
        ARMED.store(true, Ordering::SeqCst);
        ArmGuard { _serial: serial }
    }

    /// Disarms the detector on drop. Recorded state (last diagnosis /
    /// violation) stays readable until the next [`arm`].
    #[must_use]
    pub struct ArmGuard {
        _serial: MutexGuard<'static, ()>,
    }

    impl Drop for ArmGuard {
        fn drop(&mut self) {
            ARMED.store(false, Ordering::SeqCst);
        }
    }

    /// Fresh conformance identity for a communicator (0 = unregistered).
    pub fn next_comm_id() -> u64 {
        NEXT_COMM_ID.fetch_add(1, Ordering::Relaxed)
    }

    fn span_violation(reg: &Registry, fabric: usize, cid: u64, tag: Tag) -> Option<Violation> {
        let c = reg.comms.iter().find(|c| c.fabric == fabric && c.cid == cid)?;
        if tag >= c.base && tag < c.limit {
            None
        } else {
            Some(Violation::TagOutsideSpan { cid, tag, base: c.base, limit: c.limit })
        }
    }

    /// Register a bounded (split) communicator's tag span and members;
    /// panics with a typed [`Violation::TagCollision`] if the span can
    /// collide with an already-registered one.
    pub fn on_comm_created(fabric: usize, cid: u64, base: Tag, limit: Tag, members: &[usize]) {
        if !armed() {
            return;
        }
        let mut reg = registry();
        let mut clash = None;
        for c in &reg.comms {
            if c.fabric != fabric || c.cid == cid {
                continue;
            }
            if base >= c.limit || c.base >= limit {
                continue; // disjoint spans
            }
            let same = (base, limit) == (c.base, c.limit);
            if same && c.members == members {
                // The same logical communicator, registered by another
                // rank's handle (every rank of a split constructs one).
                continue;
            }
            let nested = !same
                && ((base >= c.base && limit <= c.limit) || (c.base >= base && c.limit <= limit));
            if nested {
                continue; // parent/child reservation carving
            }
            if members.iter().any(|m| c.members.contains(m)) {
                clash = Some(Violation::TagCollision {
                    a: c.cid,
                    b: cid,
                    base: base.max(c.base),
                    limit: limit.min(c.limit),
                });
                break;
            }
        }
        if let Some(v) = clash {
            reg.last_violation = Some(v.clone());
            drop(reg);
            panic!("conformance: {v}");
        }
        reg.comms.push(CommReg { fabric, cid, base, limit, members: members.to_vec() });
    }

    /// Record a collective-action send: checks the owning span, then
    /// either satisfies a live wait edge or parks the message in the
    /// sent-map so a trailing receive never looks blocked.
    pub fn on_send(fabric: usize, cid: u64, src: usize, dst: usize, tag: Tag) {
        if !armed() {
            return;
        }
        let mut reg = registry();
        if let Some(v) = span_violation(&reg, fabric, cid, tag) {
            reg.last_violation = Some(v.clone());
            drop(reg);
            panic!("conformance: {v}");
        }
        let hit = reg.edges.iter().position(|e| {
            e.edge.fabric == fabric && e.edge.waiter == dst && e.edge.src == src && e.edge.tag == tag
        });
        match hit {
            Some(pos) => {
                reg.edges.swap_remove(pos);
            }
            None => *reg.sent.entry((fabric, dst, src, tag)).or_insert(0) += 1,
        }
    }

    /// Enter a blocking matched receive: checks the owning span, and if
    /// the awaited message is not on the wire, records a wait edge and
    /// runs cycle detection — panicking with a typed
    /// [`DeadlockDiagnosis`] if this receive completes a cycle. The
    /// returned guard removes the edge when the receive returns.
    pub fn on_recv_enter(fabric: usize, cid: u64, dst: usize, src: usize, tag: Tag) -> RecvGuard {
        if !armed() {
            return RecvGuard { edge: None };
        }
        let mut reg = registry();
        if let Some(v) = span_violation(&reg, fabric, cid, tag) {
            reg.last_violation = Some(v.clone());
            drop(reg);
            panic!("conformance: {v}");
        }
        let key = (fabric, dst, src, tag);
        if let Some(n) = reg.sent.get_mut(&key) {
            // Already sent: this receive cannot participate in a
            // deadlock, it will be matched by the fabric.
            *n -= 1;
            if *n == 0 {
                reg.sent.remove(&key);
            }
            return RecvGuard { edge: None };
        }
        let id = reg.next_edge;
        reg.next_edge += 1;
        let leases = LEASES.with(|l| l.borrow().clone());
        reg.edges.push(EdgeRec { id, edge: WaitEdge { fabric, waiter: dst, src, tag, leases } });
        if let Some(cycle) = find_cycle(&reg.edges, fabric, dst) {
            let diag =
                DeadlockDiagnosis { cycle, open_spans: crate::obs::open_spans() };
            reg.last_deadlock = Some(diag.clone());
            drop(reg);
            panic!("conformance deadlock: {diag}");
        }
        RecvGuard { edge: Some(id) }
    }

    /// Removes its wait edge (if one was recorded) when the blocking
    /// receive returns.
    #[must_use]
    pub struct RecvGuard {
        edge: Option<u64>,
    }

    impl Drop for RecvGuard {
        fn drop(&mut self) {
            if let Some(id) = self.edge {
                let mut reg = registry();
                if let Some(pos) = reg.edges.iter().position(|e| e.id == id) {
                    reg.edges.swap_remove(pos);
                }
            }
        }
    }

    /// DFS from `start` over `waiter → src` edges of one fabric; returns
    /// the edge path of a cycle back to `start`, if any.
    fn find_cycle(edges: &[EdgeRec], fabric: usize, start: usize) -> Option<Vec<WaitEdge>> {
        fn dfs(
            edges: &[EdgeRec],
            fabric: usize,
            at: usize,
            start: usize,
            path: &mut Vec<WaitEdge>,
            seen: &mut Vec<usize>,
        ) -> bool {
            for e in edges.iter().filter(|e| e.edge.fabric == fabric && e.edge.waiter == at) {
                if e.edge.src == start {
                    path.push(e.edge.clone());
                    return true;
                }
                if seen.contains(&e.edge.src) {
                    continue;
                }
                seen.push(e.edge.src);
                path.push(e.edge.clone());
                if dfs(edges, fabric, e.edge.src, start, path, seen) {
                    return true;
                }
                path.pop();
            }
            false
        }
        let mut path = Vec::new();
        let mut seen = vec![start];
        dfs(edges, fabric, start, start, &mut path, &mut seen).then_some(path)
    }

    /// Check one wire chunk of a chunked transfer for monotonic index
    /// order; panics with a typed [`Violation::NonMonotonicChunk`] on
    /// reordering.
    pub fn on_chunk_recv(fabric: usize, dst: usize, src: usize, base_tag: Tag, index: u64) {
        if !armed() {
            return;
        }
        let mut reg = registry();
        let key = (fabric, dst, src, base_tag);
        let expected = reg.chunks.get(&key).copied().unwrap_or(0);
        if index != expected {
            let v = Violation::NonMonotonicChunk { src, dst, base_tag, expected, got: index };
            reg.last_violation = Some(v.clone());
            drop(reg);
            panic!("conformance: {v}");
        }
        reg.chunks.insert(key, expected + 1);
    }

    /// Push a pool-lease label onto this thread's stack for the guard's
    /// lifetime; wait edges recorded while it is held carry the label,
    /// naming the lease holders in a cross-job deadlock diagnosis.
    pub fn lease(label: &str) -> LeaseGuard {
        if !armed() {
            return LeaseGuard { pushed: false };
        }
        LEASES.with(|l| l.borrow_mut().push(label.to_string()));
        LeaseGuard { pushed: true }
    }

    /// Pops its lease label on drop.
    #[must_use]
    pub struct LeaseGuard {
        pushed: bool,
    }

    impl Drop for LeaseGuard {
        fn drop(&mut self) {
            if self.pushed {
                LEASES.with(|l| {
                    l.borrow_mut().pop();
                });
            }
        }
    }

    /// The most recent deadlock diagnosis, if any (kept until re-armed).
    pub fn last_deadlock() -> Option<DeadlockDiagnosis> {
        registry().last_deadlock.clone()
    }

    /// The most recent conformance violation, if any (kept until
    /// re-armed).
    pub fn last_violation() -> Option<Violation> {
        registry().last_violation.clone()
    }

    /// Search the current wait-for graph for a cycle (the watchdog's
    /// timeout query). Returns the stored diagnosis if a cycle already
    /// panicked a thread. `None` unless armed.
    pub fn diagnose() -> Option<DeadlockDiagnosis> {
        if !armed() {
            return None;
        }
        let reg = registry();
        if let Some(d) = &reg.last_deadlock {
            return Some(d.clone());
        }
        let starts: Vec<(usize, usize)> =
            reg.edges.iter().map(|e| (e.edge.fabric, e.edge.waiter)).collect();
        for (fabric, start) in starts {
            if let Some(cycle) = find_cycle(&reg.edges, fabric, start) {
                return Some(DeadlockDiagnosis { cycle, open_spans: crate::obs::open_spans() });
            }
        }
        None
    }

    /// Number of live wait-for edges (test sequencing aid).
    pub fn wait_edge_count() -> usize {
        registry().edges.len()
    }

    /// Benchmark entry: the exact cost a disabled hook pays (one
    /// relaxed atomic load when compiled in; nothing when compiled out).
    #[inline]
    pub fn probe() {
        let _ = armed();
    }
}

#[cfg(not(any(debug_assertions, feature = "conformance")))]
mod imp {
    use super::{DeadlockDiagnosis, Violation};
    use crate::hpx::parcel::Tag;

    /// Whether the detector is currently recording (never, compiled out).
    #[inline(always)]
    pub fn armed() -> bool {
        false
    }

    /// Disarms the detector on drop (no-op, compiled out).
    #[must_use]
    pub struct ArmGuard {}

    /// Arm the detector (no-op, compiled out).
    pub fn arm() -> ArmGuard {
        ArmGuard {}
    }

    /// Fresh conformance identity (always 0, compiled out).
    #[inline(always)]
    pub fn next_comm_id() -> u64 {
        0
    }

    /// Register a communicator span (no-op, compiled out).
    #[inline(always)]
    pub fn on_comm_created(_fabric: usize, _cid: u64, _base: Tag, _limit: Tag, _members: &[usize]) {
    }

    /// Record a send (no-op, compiled out).
    #[inline(always)]
    pub fn on_send(_fabric: usize, _cid: u64, _src: usize, _dst: usize, _tag: Tag) {}

    /// Removes its wait edge on drop (no-op, compiled out).
    #[must_use]
    pub struct RecvGuard {}

    /// Enter a blocking receive (no-op, compiled out).
    #[inline(always)]
    pub fn on_recv_enter(_fabric: usize, _cid: u64, _dst: usize, _src: usize, _tag: Tag) -> RecvGuard {
        RecvGuard {}
    }

    /// Check one wire chunk (no-op, compiled out).
    #[inline(always)]
    pub fn on_chunk_recv(_fabric: usize, _dst: usize, _src: usize, _base_tag: Tag, _index: u64) {}

    /// Pops its lease label on drop (no-op, compiled out).
    #[must_use]
    pub struct LeaseGuard {}

    /// Push a pool-lease label (no-op, compiled out).
    #[inline(always)]
    pub fn lease(_label: &str) -> LeaseGuard {
        LeaseGuard {}
    }

    /// The most recent deadlock diagnosis (never any, compiled out).
    #[inline(always)]
    pub fn last_deadlock() -> Option<DeadlockDiagnosis> {
        None
    }

    /// The most recent violation (never any, compiled out).
    #[inline(always)]
    pub fn last_violation() -> Option<Violation> {
        None
    }

    /// Search for a wait-for cycle (never any, compiled out).
    #[inline(always)]
    pub fn diagnose() -> Option<DeadlockDiagnosis> {
        None
    }

    /// Number of live wait edges (always 0, compiled out).
    #[inline(always)]
    pub fn wait_edge_count() -> usize {
        0
    }

    /// Benchmark entry (no-op, compiled out).
    #[inline(always)]
    pub fn probe() {}
}

pub use imp::{
    arm, armed, diagnose, last_deadlock, last_violation, lease, next_comm_id, on_chunk_recv,
    on_comm_created, on_recv_enter, on_send, probe, wait_edge_count, ArmGuard, LeaseGuard,
    RecvGuard,
};

#[cfg(all(test, any(debug_assertions, feature = "conformance")))]
mod tests {
    use super::*;
    use crate::collectives::{ChunkPolicy, Communicator};
    use crate::parcelport::{lci::LciParcelport, Parcelport};
    use crate::util::testkit::with_watchdog;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;
    use std::time::Duration;

    const FAB: usize = 0xFAB;

    #[test]
    fn disarmed_hooks_record_nothing() {
        // No arm guard: hooks must be inert (other tests may be armed
        // concurrently, so only assert when nothing is armed).
        if !armed() {
            on_send(FAB, 0, 0, 1, 7);
            let _g = on_recv_enter(FAB, 0, 0, 1, 7);
            assert_eq!(wait_edge_count(), 0);
        }
    }

    #[test]
    fn cycle_detection_yields_typed_diagnosis() {
        let _arm = arm();
        let _e1 = on_recv_enter(FAB, 0, 0, 1, 7); // rank 0 waits on rank 1
        let _l = lease("job-b shadow pool");
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _e2 = on_recv_enter(FAB, 0, 1, 0, 9); // closes the cycle
        }))
        .expect_err("closing the cycle must panic with a diagnosis");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("wait-for cycle"), "{msg}");
        let diag = last_deadlock().expect("diagnosis stored");
        assert_eq!(diag.cycle.len(), 2, "{diag}");
        let ranks: Vec<usize> = diag.cycle.iter().map(|e| e.waiter).collect();
        assert!(ranks.contains(&0) && ranks.contains(&1), "{diag}");
        assert!(
            diag.cycle.iter().any(|e| e.leases.iter().any(|l| l.contains("job-b"))),
            "the closing edge must carry the held lease: {diag}"
        );
        assert!(diagnose().is_some(), "the stored diagnosis stays queryable");
    }

    #[test]
    fn sent_messages_suppress_wait_edges() {
        let _arm = arm();
        on_send(FAB, 0, 1, 0, 7); // rank 1 already sent tag 7 to rank 0
        let _e1 = on_recv_enter(FAB, 0, 0, 1, 7); // trailing recv: no edge
        assert_eq!(wait_edge_count(), 0);
        // The reverse direction has no sent message, so it records an
        // edge — and must NOT report a cycle (no counter-edge exists).
        let _e2 = on_recv_enter(FAB, 0, 1, 0, 9);
        assert_eq!(wait_edge_count(), 1);
        assert!(last_deadlock().is_none());
    }

    #[test]
    fn a_send_clears_the_matching_edge() {
        let _arm = arm();
        let g = on_recv_enter(FAB, 0, 0, 1, 7);
        assert_eq!(wait_edge_count(), 1);
        on_send(FAB, 0, 1, 0, 7); // satisfies the wait
        assert_eq!(wait_edge_count(), 0);
        drop(g); // guard drop after send-clear is a no-op
        assert_eq!(wait_edge_count(), 0);
    }

    #[test]
    fn chunk_reordering_yields_typed_violation() {
        let _arm = arm();
        on_chunk_recv(FAB, 0, 1, 100, 0);
        on_chunk_recv(FAB, 0, 1, 100, 1);
        let err = catch_unwind(AssertUnwindSafe(|| {
            on_chunk_recv(FAB, 0, 1, 100, 1); // replay of chunk 1
        }))
        .expect_err("reordered chunk must panic");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("chunk 1 arrived, expected 2"), "{msg}");
        match last_violation() {
            Some(Violation::NonMonotonicChunk { expected: 2, got: 1, .. }) => {}
            v => panic!("wrong violation: {v:?}"),
        }
    }

    #[test]
    fn nested_and_sibling_spans_are_not_collisions() {
        let _arm = arm();
        on_comm_created(FAB, 1, 0, 1000, &[0, 1, 2, 3]);
        // Nested child reservation (a split of the split): allowed.
        on_comm_created(FAB, 2, 0, 500, &[0, 1]);
        // Sibling of the same split call: same span, disjoint members.
        on_comm_created(FAB, 3, 0, 500, &[2, 3]);
        // Another rank's handle of the same logical communicator.
        on_comm_created(FAB, 4, 0, 500, &[0, 1]);
        assert!(last_violation().is_none());
    }

    #[test]
    fn overlapping_spans_with_shared_members_collide() {
        let _arm = arm();
        on_comm_created(FAB, 1, 100, 200, &[0, 1]);
        let err = catch_unwind(AssertUnwindSafe(|| {
            on_comm_created(FAB, 2, 150, 250, &[1, 2]); // straddles, shares rank 1
        }))
        .expect_err("straddling spans with shared members must panic");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("overlapping tag span"), "{msg}");
        match last_violation() {
            Some(Violation::TagCollision { a: 1, b: 2, base: 150, limit: 200 }) => {}
            v => panic!("wrong violation: {v:?}"),
        }
    }

    #[test]
    fn tag_collision_through_split_communicators_is_typed_not_a_hang() {
        // The realistic construction: two bounded communicators built
        // over one fabric whose spans straddle with shared members —
        // the carving bug the registry exists to catch. with_watchdog
        // bounds the whole construction.
        with_watchdog("tag-collision", Duration::from_secs(30), || {
            let _arm = arm();
            let f: Arc<dyn Parcelport> = Arc::new(LciParcelport::new(2, None));
            let span = crate::collectives::tags::CHUNK_TAG_SPAN;
            let _a = Communicator::from_members(
                Arc::clone(&f),
                0,
                Arc::new(vec![0, 1]),
                0,
                4 * span,
                ChunkPolicy::default(),
            );
            let err = catch_unwind(AssertUnwindSafe(|| {
                Communicator::from_members(
                    Arc::clone(&f),
                    0,
                    Arc::new(vec![0, 1]),
                    2 * span,
                    6 * span,
                    ChunkPolicy::default(),
                )
            }))
            .expect_err("overlapping sibling span must be rejected");
            let msg = err.downcast_ref::<String>().expect("string payload");
            assert!(msg.contains("overlapping tag span"), "{msg}");
            assert!(matches!(last_violation(), Some(Violation::TagCollision { .. })));
        });
    }

    #[test]
    fn out_of_span_tag_is_typed() {
        let _arm = arm();
        let f: Arc<dyn Parcelport> = Arc::new(LciParcelport::new(2, None));
        let comm = Communicator::from_members(
            Arc::clone(&f),
            0,
            Arc::new(vec![0, 1]),
            1000,
            2000,
            ChunkPolicy::default(),
        );
        let err = catch_unwind(AssertUnwindSafe(|| {
            comm.send(1, 5000, crate::hpx::parcel::Payload::from_f32(&[1.0]));
        }))
        .expect_err("a tag outside the span must be rejected");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("outside communicator"), "{msg}");
        match last_violation() {
            Some(Violation::TagOutsideSpan { tag: 5000, base: 1000, limit: 2000, .. }) => {}
            v => panic!("wrong violation: {v:?}"),
        }
    }

    #[test]
    fn cross_job_pool_lease_deadlock_yields_diagnosis_not_a_hang() {
        // The PR 6 scenario, synthesized: two "jobs" on one fabric, each
        // holding a pool lease, each blocked in a matched receive for a
        // message the other will never send (it would only send after
        // its own receive returned). The detector must convert this
        // into a typed diagnosis instead of a hang; with_watchdog
        // bounds the whole test. The blocked thread is detached and
        // leaks by design — it can never be woken.
        let diag = with_watchdog("cross-job-deadlock", Duration::from_secs(60), || {
            let _arm = arm();
            let f: Arc<dyn Parcelport> = Arc::new(LciParcelport::new(2, None));
            let fa = Arc::clone(&f);
            let a = std::thread::Builder::new()
                .name("job-a-r0".into())
                .spawn(move || {
                    let _lease = lease("job-a chunk pool");
                    let comm = Communicator::new(fa, 0, 2);
                    let _ = comm.recv(1, 7); // blocks forever: rank 1 never sends 7
                })
                .expect("spawn job-a");
            drop(a); // detached: it can never be joined
            // Wait until job A's edge is on the graph so the cycle is
            // closed deterministically by job B below.
            while wait_edge_count() < 1 {
                std::thread::sleep(Duration::from_millis(2));
            }
            let fb = Arc::clone(&f);
            let b = std::thread::Builder::new()
                .name("job-b-r1".into())
                .spawn(move || {
                    let _lease = lease("job-b chunk pool");
                    let comm = Communicator::new(fb, 1, 2);
                    // Closes the cycle: panics with the diagnosis
                    // instead of blocking; swallow the panic (the
                    // panic *is* the detection).
                    let _ = catch_unwind(AssertUnwindSafe(|| comm.recv(0, 9)));
                })
                .expect("spawn job-b");
            drop(b);
            loop {
                if let Some(d) = last_deadlock() {
                    return d;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        assert_eq!(diag.cycle.len(), 2, "{diag}");
        let leases: Vec<&String> = diag.cycle.iter().flat_map(|e| &e.leases).collect();
        assert!(
            leases.iter().any(|l| l.contains("job-a")) && leases.iter().any(|l| l.contains("job-b")),
            "the diagnosis must name both jobs' pool leases: {diag}"
        );
        let rendered = diag.to_string();
        assert!(rendered.contains("wait-for cycle"), "{rendered}");
    }
}
