//! Tag-space hygiene: the one place the 64-bit collective tag space is
//! partitioned.
//!
//! Every communicator hands out tags from a lock-step counter (see
//! [`crate::collectives::Communicator`]); what keeps concurrent traffic
//! from colliding is that each *derived* tag region — chunked-transfer
//! blocks, offload-shadow blocks, split sub-communicator spaces — is
//! carved out of its parent's counter in SPMD lock-step, with the span
//! constants centralized here so the reservations cannot drift apart
//! between call sites:
//!
//! ```text
//! world counter ──┬── plain collective blocks (4·size + 8 tags each)
//!                 ├── chunk blocks        (CHUNK_TAG_SPAN each)
//!                 ├── shadow blocks       (shadow_span(size) each)
//!                 └── split spaces        (SPLIT_TAG_SPAN each)
//!                        └── a sub-communicator's own counter starts at
//!                            the space base and may carve all of the
//!                            above (including its *own* shadows and
//!                            further splits) out of its span — the
//!                            allocator enforces the bound at runtime.
//! ```
//!
//! The compile-time assertions below pin the containment relations the
//! scheme relies on: a split space holds many chunk and shadow blocks,
//! and a shadow block for any plausible communicator size fits inside a
//! split space with room to spare — so a `split` sub-communicator can
//! never collide with a shadow communicator of its parent (disjoint
//! reservations) nor overflow into its sibling's space (allocator
//! bound).

use crate::hpx::parcel::Tag;

/// Tags reserved per chunked transfer: one header plus up to
/// `CHUNK_TAG_SPAN - 1` wire chunks. Tag space is 64-bit, so reserving
/// 2³² tags per transfer is free and removes any realistic collision
/// risk.
pub const CHUNK_TAG_SPAN: Tag = 1 << 32;

/// Tag space reserved for one `Communicator::split` sub-communicator.
/// Carved from the parent's lock-step counter at split time; the
/// sub-communicator's own allocations are bounded to this span.
pub const SPLIT_TAG_SPAN: Tag = 1 << 48;

/// Largest communicator size the shadow-block maths below is asserted
/// for (far above any realistic locality count in this test fabric).
pub const MAX_SHADOW_RANKS: usize = 1 << 13;

/// Tags reserved for one offload-shadow block: generous enough for any
/// blocking algorithm's internal allocations on a `size`-rank
/// communicator, including `size` chunk-tag blocks for the
/// pairwise-chunked exchange.
pub const fn shadow_span(size: usize) -> Tag {
    (size as Tag + 2) * CHUNK_TAG_SPAN
}

/// Tags reserved per plain collective invocation on a `size`-rank
/// communicator: room for every per-round / per-peer tag an algorithm
/// derives from the block base. Centralized here because two allocators
/// advance by this span in lock-step — the live
/// [`crate::collectives::Communicator`] counter and the event-engine
/// simulator's replica allocator
/// ([`crate::simnet::collective_sim`]) — and they must never drift.
pub const fn collective_span(size: usize) -> Tag {
    4 * size as Tag + 8
}

/// A split space subdivides into whole chunk blocks, so chunk-tag
/// reservations inside a sub-communicator stay aligned to its span.
pub const fn split_space_subdivides_into_chunk_blocks() -> bool {
    SPLIT_TAG_SPAN % CHUNK_TAG_SPAN == 0
}

/// A split space holds at least 2¹⁶ chunk blocks, so a sub-communicator
/// has ample room for its own chunked collectives before the runtime
/// bound trips.
pub const fn split_space_holds_many_chunk_blocks() -> bool {
    SPLIT_TAG_SPAN / CHUNK_TAG_SPAN >= 1 << 16
}

/// A shadow block for a `size`-rank communicator fits at least four
/// times inside one split space: sub-communicators can offload
/// multi-round collectives onto shadows of their own without ever
/// reaching a sibling split's tags.
pub const fn shadow_block_fits_in_split_space(size: usize) -> bool {
    shadow_span(size) * 4 <= SPLIT_TAG_SPAN
}

// The containment relations above are pinned at compile time through
// the same predicates the test-suite exercises, so the two can't drift.
const _: () = assert!(split_space_subdivides_into_chunk_blocks());
const _: () = assert!(split_space_holds_many_chunk_blocks());
const _: () = assert!(shadow_block_fits_in_split_space(MAX_SHADOW_RANKS));

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_nested_cleanly() {
        // Same predicates the `const` asserts pin at compile time.
        assert!(split_space_subdivides_into_chunk_blocks());
        assert!(split_space_holds_many_chunk_blocks());
        assert!(shadow_span(1) >= 3 * CHUNK_TAG_SPAN);
    }

    #[test]
    fn shadow_blocks_fit_at_every_plausible_size() {
        // The compile-time assert pins the extreme; spot-check the
        // predicate across the sizes the test fabrics actually use.
        for size in [0, 1, 2, 4, 64, 1024, MAX_SHADOW_RANKS] {
            assert!(shadow_block_fits_in_split_space(size), "size {size}");
        }
        assert!(
            !shadow_block_fits_in_split_space(2 * MAX_SHADOW_RANKS),
            "the predicate must actually bound the size"
        );
    }

    #[test]
    fn shadow_span_scales_with_size() {
        assert_eq!(shadow_span(0), 2 * CHUNK_TAG_SPAN);
        assert_eq!(shadow_span(8), 10 * CHUNK_TAG_SPAN);
    }
}
