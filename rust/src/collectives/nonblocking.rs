//! Nonblocking, futures-first collectives — `hpx::collectives` semantics.
//!
//! Every `*_async` method returns a [`CollectiveFuture`] within
//! *O(posting)* time: tags are allocated on the calling (SPMD) thread,
//! receives are posted as jobs that block in the destination mailbox on
//! the communicator's chunk pool, and sends drain through the same pool —
//! the caller never waits for remote completion. The blocking collective
//! entry points ([`Communicator::all_to_all`], [`Communicator::scatter`],
//! …) are thin `get()` wrappers over these, so the futures engine is the
//! *only* engine and blocking-vs-async cannot diverge.
//!
//! ## Posting discipline (deadlock freedom)
//!
//! Jobs are posted **sends before receives** within one collective, and
//! collectives are posted in SPMD order. The pool starts jobs FIFO, so on
//! every rank all send jobs of collective *k* begin (and, since fabric
//! sends never block on the remote side, finish) before any receive job
//! of collective *k* blocks a worker; a blocked receive therefore only
//! ever waits on a peer's send job that the peer is guaranteed to reach.
//! This is the same argument that makes MPI's nonblocking
//! `Isend`/`Irecv`+`Waitall` pattern safe.
//!
//! ## Algorithm fidelity
//!
//! Single-phase schedules (linear all-to-all, linear/pipelined scatter,
//! gather, broadcast) are posted natively with per-peer (and, for the
//! chunked paths, per-wire-chunk) completion futures. Multi-round
//! schedules (pairwise, Bruck, HPX-root, pairwise-chunked) keep their
//! round pacing — the thing the benchmark measures — by running the
//! blocking algorithm on a *shadow communicator* inside a single pool
//! job: the shadow shares the fabric and a pre-reserved lock-step tag
//! block, so posting still returns immediately and tags still match
//! across ranks.

use super::all_to_all::AllToAllAlgo;
use super::chunked::recv_chunked_via;
use super::comm::Communicator;
use super::reduce::ReduceOp;
use super::scatter::ScatterAlgo;
use super::tags;
use crate::hpx::parcel::{actions, Parcel, Payload};
use crate::task::{when_all_async, CollectiveFuture, Promise, TaskFuture};
use std::sync::Arc;

impl Communicator {
    /// Reserve a lock-step tag block and build the shadow communicator an
    /// offloaded multi-round collective runs on. The span
    /// ([`tags::shadow_span`]) is generous enough for any blocking
    /// algorithm's internal allocations (including `size` chunk-tag
    /// blocks for the pairwise-chunked exchange).
    fn offload_shadow(&self) -> Communicator {
        let base = self.reserve_tag_span(tags::shadow_span(self.size()));
        self.shadow_at(base)
    }

    /// Run a blocking collective body on a shadow communicator in a
    /// single pool job; returns immediately.
    fn offload<T: Send + 'static>(
        &self,
        body: impl FnOnce(&Communicator) -> T + Send + 'static,
    ) -> CollectiveFuture<T> {
        let shadow = self.offload_shadow();
        let result = self.chunk_pool().spawn(move || body(&shadow));
        CollectiveFuture::new(result, Vec::new())
    }

    /// Nonblocking all-to-all: returns a future for the received chunks
    /// (one per source rank, in rank order) plus per-chunk send
    /// completions. Same semantics as [`Communicator::all_to_all`], which
    /// is now `all_to_all_async(..).get()`.
    ///
    /// # Panics
    /// If the chunk count differs from the communicator size.
    pub fn all_to_all_async(
        &self,
        chunks: Vec<Payload>,
        algo: AllToAllAlgo,
    ) -> CollectiveFuture<Vec<Payload>> {
        assert_eq!(chunks.len(), self.size(), "need one chunk per rank");
        crate::obs::instant("coll", "all_to_all", self.my_global());
        match algo {
            AllToAllAlgo::Linear => self.a2a_async_linear(chunks),
            // Round-paced schedules keep their pacing on a shadow.
            _ => self.offload(move |shadow| shadow.all_to_all_blocking(chunks, algo)),
        }
    }

    /// Linear all-to-all, posted natively: N−1 send jobs, then N−1
    /// receive jobs, result combined with `when_all_async`.
    fn a2a_async_linear(&self, mut chunks: Vec<Payload>) -> CollectiveFuture<Vec<Payload>> {
        let tag = self.alloc_tags();
        let n = self.size();
        let me = self.rank();
        let me_g = self.my_global();
        let pool = self.chunk_pool();
        let own = std::mem::replace(&mut chunks[me], Payload::empty());

        // Sends first (posting discipline, see module docs).
        let mut sends = Vec::with_capacity(n.saturating_sub(1));
        for (dst, chunk) in chunks.into_iter().enumerate() {
            if dst == me {
                continue;
            }
            let dst_g = self.global_rank(dst);
            let fabric = Arc::clone(self.fabric());
            sends.push(pool.spawn(move || {
                let bytes = chunk.len() as i64;
                let _span = crate::obs::span_args(
                    "wire",
                    "a2a",
                    me_g,
                    tag as i64,
                    crate::obs::NO_ARG,
                    bytes,
                );
                fabric.send(Parcel::new(me_g, dst_g, actions::COLLECTIVE, tag, chunk));
            }));
        }

        // Receives: one job per source, combined in rank order.
        let mut per_src = Vec::with_capacity(n);
        for src in 0..n {
            if src == me {
                per_src.push(TaskFuture::ready(own.clone()));
            } else {
                let src_g = self.global_rank(src);
                let fabric = Arc::clone(self.fabric());
                per_src.push(
                    pool.spawn(move || fabric.recv(me_g, src_g, actions::COLLECTIVE, tag)),
                );
            }
        }
        CollectiveFuture::new(when_all_async(per_src), sends)
    }

    /// Nonblocking scatter rooted at `root`. The root's result (its own
    /// chunk) is ready immediately with one completion future per posted
    /// wire chunk; non-roots get a future fulfilled by a posted mailbox
    /// receive. [`ScatterAlgo::Pipelined`] ships policy-sized wire chunks
    /// through the send pool exactly like the blocking pipelined scatter.
    ///
    /// # Panics
    /// Same contract as [`Communicator::scatter`].
    pub fn scatter_async(
        &self,
        root: usize,
        chunks: Option<Vec<Payload>>,
        algo: ScatterAlgo,
    ) -> CollectiveFuture<Payload> {
        assert!(root < self.size(), "root {root} out of range");
        crate::obs::instant("coll", "scatter", self.my_global());
        match algo {
            ScatterAlgo::Linear => {
                let tag = self.alloc_tags();
                if self.rank() == root {
                    let chunks = chunks.expect("root must provide chunks");
                    assert_eq!(chunks.len(), self.size(), "need exactly one chunk per rank");
                    let pool = self.chunk_pool();
                    let me = self.rank();
                    let me_g = self.my_global();
                    let mut mine = None;
                    let mut sends = Vec::with_capacity(self.size().saturating_sub(1));
                    for (dst, chunk) in chunks.into_iter().enumerate() {
                        if dst == me {
                            mine = Some(chunk); // never hits the fabric
                        } else {
                            let dst_g = self.global_rank(dst);
                            let fabric = Arc::clone(self.fabric());
                            sends.push(pool.spawn(move || {
                                fabric.send(Parcel::new(
                                    me_g,
                                    dst_g,
                                    actions::COLLECTIVE,
                                    tag,
                                    chunk,
                                ));
                            }));
                        }
                    }
                    CollectiveFuture::new(
                        TaskFuture::ready(mine.expect("root chunk present")),
                        sends,
                    )
                } else {
                    assert!(chunks.is_none(), "non-root rank {} passed chunks", self.rank());
                    let fabric = Arc::clone(self.fabric());
                    let me_g = self.my_global();
                    let root_g = self.global_rank(root);
                    let recv = self
                        .chunk_pool()
                        .spawn(move || fabric.recv(me_g, root_g, actions::COLLECTIVE, tag));
                    CollectiveFuture::new(recv, Vec::new())
                }
            }
            ScatterAlgo::Pipelined => {
                let tag = self.alloc_chunk_tags(1);
                if self.rank() == root {
                    let chunks = chunks.expect("root must provide chunks");
                    assert_eq!(chunks.len(), self.size(), "need exactly one chunk per rank");
                    let mut mine = None;
                    let mut sends = Vec::new();
                    for (dst, chunk) in chunks.into_iter().enumerate() {
                        if dst == self.rank() {
                            mine = Some(chunk);
                        } else {
                            // Every destination shares the chunk-tag
                            // block (per-mailbox matching).
                            sends.append(&mut self.send_chunked(dst, tag, chunk));
                        }
                    }
                    CollectiveFuture::new(
                        TaskFuture::ready(mine.expect("root chunk present")),
                        sends,
                    )
                } else {
                    assert!(chunks.is_none(), "non-root rank {} passed chunks", self.rank());
                    let fabric = Arc::clone(self.fabric());
                    let me_g = self.my_global();
                    let root_g = self.global_rank(root);
                    let policy = self.chunk_policy();
                    let recv = self
                        .chunk_pool()
                        .spawn(move || recv_chunked_via(&fabric, me_g, root_g, tag, policy));
                    CollectiveFuture::new(recv, Vec::new())
                }
            }
        }
    }

    /// Nonblocking gather to `root`: non-roots post their send and get a
    /// ready `None`; the root posts one receive per peer and gets a
    /// future for the rank-ordered contributions.
    ///
    /// # Panics
    /// If `root` is out of range.
    pub fn gather_async(
        &self,
        root: usize,
        data: Payload,
    ) -> CollectiveFuture<Option<Vec<Payload>>> {
        assert!(root < self.size(), "root {root} out of range");
        crate::obs::instant("coll", "gather", self.my_global());
        let tag = self.alloc_tags();
        let me = self.rank();
        let me_g = self.my_global();
        let pool = self.chunk_pool();
        if me == root {
            let mut per_src = Vec::with_capacity(self.size());
            for src in 0..self.size() {
                if src == me {
                    per_src.push(TaskFuture::ready(data.clone()));
                } else {
                    let src_g = self.global_rank(src);
                    let fabric = Arc::clone(self.fabric());
                    per_src.push(
                        pool.spawn(move || fabric.recv(me_g, src_g, actions::COLLECTIVE, tag)),
                    );
                }
            }
            let (p, out) = Promise::new();
            when_all_async(per_src).then_inline(move |v: &Vec<Payload>| p.set(Some(v.clone())));
            CollectiveFuture::new(out, Vec::new())
        } else {
            let root_g = self.global_rank(root);
            let fabric = Arc::clone(self.fabric());
            let send = pool.spawn(move || {
                fabric.send(Parcel::new(me_g, root_g, actions::COLLECTIVE, tag, data));
            });
            CollectiveFuture::new(TaskFuture::ready(None), vec![send])
        }
    }

    /// Nonblocking binomial-tree broadcast from `root`: the root's result
    /// is ready immediately (its own payload) with one completion future
    /// per child send; every other rank posts a single job that receives
    /// from its tree parent, forwards to its children, and fulfils the
    /// result.
    ///
    /// # Panics
    /// Same contract as [`Communicator::broadcast`].
    pub fn broadcast_async(
        &self,
        root: usize,
        data: Option<Payload>,
    ) -> CollectiveFuture<Payload> {
        assert!(root < self.size(), "root {root} out of range");
        crate::obs::instant("coll", "broadcast", self.my_global());
        let tag = self.alloc_tags();
        let n = self.size();
        let me = self.rank();
        let vrank = (me + n - root) % n;
        let pool = self.chunk_pool();
        let members = self.members_arc();
        if me == root {
            let payload = data.expect("root must provide data");
            let me_g = self.my_global();
            let mut sends = Vec::new();
            let mut step = 1;
            while step < n {
                let child_g = members[(step + root) % n];
                let fabric = Arc::clone(self.fabric());
                let chunk = payload.clone();
                sends.push(pool.spawn(move || {
                    fabric.send(Parcel::new(me_g, child_g, actions::COLLECTIVE, tag, chunk));
                }));
                step <<= 1;
            }
            CollectiveFuture::new(TaskFuture::ready(payload), sends)
        } else {
            assert!(data.is_none(), "non-root rank {me} passed data");
            let fabric = Arc::clone(self.fabric());
            let result = pool.spawn(move || {
                let me_g = members[me];
                // Parent: vrank with its highest set bit cleared.
                let mask = 1 << (usize::BITS - 1 - vrank.leading_zeros());
                let parent_g = members[((vrank ^ mask) + root) % n];
                let payload = fabric.recv(me_g, parent_g, actions::COLLECTIVE, tag);
                // Forward to children before fulfilling, so the subtree
                // makes progress even if no one consumes this future.
                let mut step = 1 << (usize::BITS - vrank.leading_zeros());
                while vrank + step < n {
                    let child_g = members[((vrank + step) + root) % n];
                    fabric.send(Parcel::new(
                        me_g,
                        child_g,
                        actions::COLLECTIVE,
                        tag,
                        payload.clone(),
                    ));
                    step <<= 1;
                }
                payload
            });
            CollectiveFuture::new(result, Vec::new())
        }
    }

    /// Nonblocking binomial-tree reduce to `root`: returns within
    /// O(posting) with a future for the root's reduced vector (`Some` at
    /// the root, `None` elsewhere). The tree is a multi-round schedule,
    /// so it runs the blocking algorithm on an offload shadow — the same
    /// pattern as the round-paced all-to-alls. The blocking
    /// [`Communicator::reduce`] is now `reduce_async(..).get()`.
    ///
    /// # Panics
    /// If `root` is out of range (surfaced when the future is consumed).
    pub fn reduce_async(
        &self,
        root: usize,
        data: &[f32],
        op: ReduceOp,
    ) -> CollectiveFuture<Option<Vec<f32>>> {
        crate::obs::instant("coll", "reduce", self.my_global());
        let data = data.to_vec();
        self.offload(move |shadow| shadow.reduce_blocking(root, &data, op))
    }

    /// Nonblocking dissemination barrier: posting returns immediately;
    /// the future completes once every rank has entered the barrier. The
    /// ⌈log₂ n⌉ signal rounds run on an offload shadow. The blocking
    /// [`Communicator::barrier`] is now `barrier_async().get()`.
    pub fn barrier_async(&self) -> CollectiveFuture<()> {
        crate::obs::instant("coll", "barrier", self.my_global());
        self.offload(move |shadow| shadow.barrier_blocking())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ChunkPolicy;
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;
    use std::time::{Duration, Instant};

    #[test]
    fn a2a_async_matches_blocking_semantics() {
        let n = 4;
        for algo in [AllToAllAlgo::Linear, AllToAllAlgo::PairwiseChunked] {
            let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
            let results = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(16, 2));
                let send: Vec<Payload> = (0..n)
                    .map(|dst| Payload::from_f32(&vec![(ctx.rank * n + dst) as f32; 9]))
                    .collect();
                comm.all_to_all_async(send, algo).get()
            });
            for (i, recv) in results.iter().enumerate() {
                for (j, p) in recv.iter().enumerate() {
                    assert_eq!(p.to_f32(), vec![(j * n + i) as f32; 9], "{algo:?} {i}/{j}");
                }
            }
        }
    }

    #[test]
    fn scatter_async_root_returns_before_remote_completion() {
        // O(posting): the root gets its CollectiveFuture back while the
        // non-root has not even entered the collective yet.
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let posted_us = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.warm_chunk_pool();
            if ctx.rank == 0 {
                let t0 = Instant::now();
                let coll = comm.scatter_async(
                    0,
                    Some(vec![Payload::new(vec![1u8; 8]), Payload::new(vec![2u8; 1 << 20])]),
                    ScatterAlgo::Linear,
                );
                let posted = t0.elapsed().as_secs_f64() * 1e6;
                assert!(coll.is_ready(), "root's own chunk is ready at posting time");
                let mine = coll.get();
                assert_eq!(mine.as_bytes()[0], 1);
                posted
            } else {
                // Receiver deliberately arrives late.
                std::thread::sleep(Duration::from_millis(50));
                let got =
                    comm.scatter_async(0, None, ScatterAlgo::Linear).get();
                assert_eq!(got.len(), 1 << 20);
                0.0
            }
        });
        // Posting must not have waited the ~50 ms for the receiver.
        assert!(posted_us[0] < 40_000.0, "posting took {} µs", posted_us[0]);
    }

    #[test]
    fn scatter_async_pipelined_carries_chunk_send_futures() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let counts = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(ChunkPolicy::new(64, 2));
            let chunks = (ctx.rank == 0).then(|| {
                vec![Payload::new(vec![0u8; 8]), Payload::new(vec![7u8; 256])]
            });
            let coll = comm.scatter_async(0, chunks, ScatterAlgo::Pipelined);
            let n_sends = coll.chunk_sends().len();
            let mine = coll.get();
            if ctx.rank == 1 {
                assert_eq!(mine.as_bytes(), &[7u8; 256][..]);
            }
            n_sends
        });
        // Root posted 256 B over 64 B wire chunks → 4 chunk futures.
        assert_eq!(counts[0], 4);
        assert_eq!(counts[1], 0);
    }

    #[test]
    fn gather_async_collects_in_rank_order() {
        let cluster = Cluster::new(3, PortKind::Mpi, None).unwrap();
        let got = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.gather_async(1, Payload::from_f32(&[ctx.rank as f32]))
                .get()
                .map(|v| v.iter().map(|p| p.to_f32()[0]).collect::<Vec<_>>())
        });
        assert_eq!(got[1], Some(vec![0.0, 1.0, 2.0]));
        assert!(got[0].is_none() && got[2].is_none());
    }

    #[test]
    fn broadcast_async_all_roots_all_ports() {
        for kind in PortKind::ALL {
            let n = 5;
            let cluster = Cluster::new(n, kind, None).unwrap();
            for root in 0..n {
                let got = cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    let data =
                        (ctx.rank == root).then(|| Payload::from_f32(&[root as f32, 1.5]));
                    comm.broadcast_async(root, data).get().to_f32()
                });
                for g in got {
                    assert_eq!(g, vec![root as f32, 1.5], "{kind} root {root}");
                }
            }
        }
    }

    #[test]
    fn offloaded_algorithms_still_transpose() {
        let n = 3;
        for algo in [AllToAllAlgo::Pairwise, AllToAllAlgo::Bruck, AllToAllAlgo::HpxRoot] {
            let cluster = Cluster::new(n, PortKind::Tcp, None).unwrap();
            let results = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                let send: Vec<Payload> = (0..n)
                    .map(|dst| Payload::from_f32(&[(ctx.rank * n + dst) as f32]))
                    .collect();
                comm.all_to_all_async(send, algo).get()
            });
            for (i, recv) in results.iter().enumerate() {
                for (j, p) in recv.iter().enumerate() {
                    assert_eq!(p.to_f32(), vec![(j * n + i) as f32], "{algo:?} {i}/{j}");
                }
            }
        }
    }

    #[test]
    fn mixed_async_collectives_stay_in_lockstep() {
        // Posting several async collectives before consuming any: tags
        // stay lock-step and every future resolves.
        let n = 3;
        let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let bcast = comm.broadcast_async(
                0,
                (ctx.rank == 0).then(|| Payload::from_f32(&[42.0])),
            );
            let scat = comm.scatter_async(
                1,
                (ctx.rank == 1)
                    .then(|| (0..n).map(|i| Payload::from_f32(&[i as f32])).collect()),
                ScatterAlgo::Linear,
            );
            let gath = comm.gather_async(2, Payload::from_f32(&[ctx.rank as f32 * 2.0]));
            assert_eq!(bcast.get().to_f32(), vec![42.0]);
            assert_eq!(scat.get().to_f32(), vec![ctx.rank as f32]);
            let gathered = gath.get();
            if ctx.rank == 2 {
                let v: Vec<f32> =
                    gathered.unwrap().iter().map(|p| p.to_f32()[0]).collect();
                assert_eq!(v, vec![0.0, 2.0, 4.0]);
            }
        });
    }

    #[test]
    fn reduce_async_matches_blocking_semantics() {
        use crate::collectives::ReduceOp;
        let n = 5;
        for root in [0usize, 3] {
            let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
            let got = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.reduce_async(root, &[ctx.rank as f32, 1.0], ReduceOp::Sum).get()
            });
            let expect = vec![(n * (n - 1) / 2) as f32, n as f32];
            for (r, g) in got.iter().enumerate() {
                if r == root {
                    assert_eq!(g.as_ref().unwrap(), &expect, "root {root}");
                } else {
                    assert!(g.is_none(), "root {root} rank {r}");
                }
            }
        }
    }

    #[test]
    fn barrier_async_posting_returns_before_stragglers() {
        // O(posting): rank 0 posts the barrier and gets its future back
        // while rank 1 is still asleep; the *future* only resolves once
        // everyone has entered.
        let cluster = Cluster::new(2, PortKind::Mpi, None).unwrap();
        let posted_us = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.warm_chunk_pool();
            if ctx.rank == 1 {
                std::thread::sleep(Duration::from_millis(40));
            }
            let t0 = Instant::now();
            let fut = comm.barrier_async();
            let posted = t0.elapsed().as_secs_f64() * 1e6;
            fut.get();
            posted
        });
        assert!(posted_us[0] < 30_000.0, "posting took {} µs", posted_us[0]);
    }

    #[test]
    fn mixed_reduce_and_barrier_async_stay_in_lockstep() {
        use crate::collectives::ReduceOp;
        let n = 4;
        let cluster = Cluster::new(n, PortKind::Tcp, None).unwrap();
        cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let red = comm.reduce_async(0, &[1.0f32], ReduceOp::Sum);
            let bar = comm.barrier_async();
            let bc = comm.broadcast_async(
                1,
                (ctx.rank == 1).then(|| Payload::from_f32(&[9.0])),
            );
            if ctx.rank == 0 {
                assert_eq!(red.get().unwrap(), vec![n as f32]);
            } else {
                assert!(red.get().is_none());
            }
            bar.get();
            assert_eq!(bc.get().to_f32(), vec![9.0]);
        });
    }
}
