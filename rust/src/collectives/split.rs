//! Communicator splitting — `MPI_Comm_split` semantics over the parcel
//! fabric.
//!
//! [`Communicator::split`] partitions a communicator by `color`: every
//! rank that passed the same color lands in the same sub-communicator,
//! ordered by `key` (ties broken by parent rank — MPI's rule). The 3-D
//! pencil FFT uses two splits of the world communicator to build its row
//! and column communicators over a `Pr × Pc` process grid (see
//! [`crate::dist_fft::pencil`]).
//!
//! ## Isolation guarantees
//!
//! - **Disjoint tag spaces.** Each `split` call reserves one
//!   [`super::tags::SPLIT_TAG_SPAN`]-sized block from the *parent's*
//!   lock-step tag counter — the same mechanism the offload shadows use
//!   (a nested split grants half its remaining space instead) — so a
//!   sub-communicator's traffic can never collide with the parent's
//!   collectives, the parent's shadow communicators, or the
//!   sub-communicators of any *other* split call. Sub-communicators of
//!   the *same* call share a base tag but have pairwise-disjoint member
//!   pairs (colors partition the ranks), which the fabric's
//!   `(dest, src, tag)` matching keeps apart. The sub-communicator's own
//!   allocator is bounded to its span, so exhaustion trips an assertion
//!   instead of silently bleeding into a sibling's tags.
//! - **Own chunk pools.** A sub-communicator starts with empty
//!   `chunk_pool`/`shadow_send_pool` slots: its pipelined chunk sends and
//!   offloaded collectives drain through workers of its own, so row- and
//!   column-communicator traffic of the pencil FFT progress
//!   independently instead of queueing behind one shared pool.
//!
//! ## Calling discipline
//!
//! `split` is itself a collective: **every** rank of the parent must call
//! it at the same point in the SPMD program (the color/key exchange rides
//! on an `all_gather`, and the tag-space reservation must stay in
//! lock-step). The returned communicator inherits the parent's
//! [`super::ChunkPolicy`].

use super::comm::{Communicator, TagSpaceExhausted};
use crate::hpx::parcel::{Payload, Tag};
use crate::util::bytes::{get_u64, put_u64};
use std::sync::Arc;

impl Communicator {
    /// Partition this communicator into sub-communicators by `color`;
    /// within a group, ranks are ordered by `key` (ties broken by parent
    /// rank). Returns this rank's sub-communicator handle.
    ///
    /// Collective: every rank of the parent must call `split` at the same
    /// point with its own `(color, key)`.
    pub fn split(&self, color: u64, key: u64) -> Communicator {
        // A whole-fabric parent grants the full SPLIT_TAG_SPAN; a
        // bounded parent (itself a split) grants half its remaining
        // space, so splits nest.
        self.split_with_span(color, key, self.split_span())
    }

    /// [`Communicator::split`] with an explicit tag-space grant: the
    /// sub-communicator's whole tag budget is the `span` tags reserved
    /// here instead of the default [`super::tags::SPLIT_TAG_SPAN`]-sized
    /// block. The FFT service carves its per-job sub-communicators with
    /// a configurable span so a long-lived world communicator admits a
    /// predictable number of jobs — and so tests can provoke tag-space
    /// exhaustion inside one job without running the counter for hours.
    ///
    /// Collective, like `split`: every rank must pass the same `span` at
    /// the same point (SPMD discipline keeps the reservation in
    /// lock-step).
    pub fn split_with_span(&self, color: u64, key: u64, span: Tag) -> Communicator {
        self.try_split_with_span(color, key, span).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`Communicator::split`]: returns a typed
    /// [`TagSpaceExhausted`] instead of panicking when this (itself
    /// split) communicator's remaining tag space is too depleted to
    /// grant a nested split. The communicator stays fully usable after a
    /// failed split — SPMD lock-step is preserved because every rank
    /// fails the same deterministic check at the same point, before any
    /// counter movement for the grant.
    pub fn try_split(&self, color: u64, key: u64) -> Result<Communicator, TagSpaceExhausted> {
        let span = self.try_split_span()?;
        self.try_split_with_span(color, key, span)
    }

    /// Fallible [`Communicator::split_with_span`]; see
    /// [`Communicator::try_split`] for the error contract.
    pub fn try_split_with_span(
        &self,
        color: u64,
        key: u64,
        span: Tag,
    ) -> Result<Communicator, TagSpaceExhausted> {
        // Exchange (color, key) so every rank derives the same grouping
        // without a central coordinator. The exchange runs before the
        // reservation check: its own (small) tag block advances the
        // counter identically on every rank whether or not the grant
        // below succeeds, so a failed split leaves the group in
        // lock-step.
        let mut mine = Vec::with_capacity(16);
        put_u64(&mut mine, color);
        put_u64(&mut mine, key);
        let all = self.all_gather(Payload::new(mine));

        // My group: parent ranks sharing my color, ordered by (key, rank).
        let mut group: Vec<(u64, usize)> = Vec::new();
        for (r, p) in all.iter().enumerate() {
            let mut off = 0;
            let c = get_u64(p.as_bytes(), &mut off);
            let k = get_u64(p.as_bytes(), &mut off);
            if c == color {
                group.push((k, r));
            }
        }
        group.sort_unstable();
        let sub_rank = group
            .iter()
            .position(|&(_, r)| r == self.rank())
            .expect("calling rank belongs to its own color group");
        let members: Vec<_> = group.iter().map(|&(_, r)| self.global_rank(r)).collect();

        // Every parent rank reserves the same span here (lock-step), so
        // the sub-communicator's tag space is identical across its
        // members and disjoint from everything else on the parent. On
        // exhaustion the counter is untouched and the parent usable.
        let base = self.try_reserve_tag_span(span)?;
        Ok(Communicator::from_members(
            Arc::clone(self.fabric()),
            sub_rank,
            Arc::new(members),
            base,
            base + span,
            self.chunk_policy(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{AllToAllAlgo, ChunkPolicy, ReduceOp};
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    #[test]
    fn split_groups_by_color_and_orders_by_key() {
        let n = 6;
        let cluster = Cluster::new(n, PortKind::Lci, None).unwrap();
        let views = cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            // Colors 0/1 by parity; keys reverse the parent order.
            let sub = world.split((ctx.rank % 2) as u64, (n - ctx.rank) as u64);
            (sub.rank(), sub.size(), sub.members().to_vec())
        });
        // Even group reversed by key: members [4, 2, 0]; odd: [5, 3, 1].
        assert_eq!(views[0], (2, 3, vec![4, 2, 0]));
        assert_eq!(views[4], (0, 3, vec![4, 2, 0]));
        assert_eq!(views[1], (2, 3, vec![5, 3, 1]));
        assert_eq!(views[5], (0, 3, vec![5, 3, 1]));
    }

    #[test]
    fn sub_communicator_collectives_work_all_ports() {
        for kind in PortKind::ALL {
            let (pr, pc) = (2usize, 2usize);
            let cluster = Cluster::new(pr * pc, kind, None).unwrap();
            let got = cluster.run(|ctx| {
                let world = Communicator::from_ctx(ctx);
                let (r, c) = (ctx.rank / pc, ctx.rank % pc);
                let row = world.split(r as u64, c as u64);
                // All-to-all within the row: rank i sends i*10+j to j.
                let send: Vec<Payload> = (0..row.size())
                    .map(|j| Payload::from_f32(&[(row.rank() * 10 + j) as f32]))
                    .collect();
                let recv = row.all_to_all(send, AllToAllAlgo::Pairwise);
                let vals: Vec<f32> = recv.iter().map(|p| p.to_f32()[0]).collect();
                // Reduce over the row as well (offload-shadow path).
                let sum = row.all_reduce(&[row.rank() as f32], ReduceOp::Sum);
                (vals, sum)
            });
            for (rank, (vals, sum)) in got.iter().enumerate() {
                let me = rank % pc;
                let expect: Vec<f32> = (0..pc).map(|j| (j * 10 + me) as f32).collect();
                assert_eq!(vals, &expect, "{kind} rank {rank}");
                assert_eq!(sum, &vec![1.0], "{kind} rank {rank}");
            }
        }
    }

    #[test]
    fn row_and_column_comms_do_not_cross_deliver() {
        // The satellite isolation test: concurrent collectives on the row
        // and column communicators of the same fabric, posted before
        // either is consumed, must deliver only within their own group —
        // PairwiseChunked with tiny wire chunks, so the *chunked* wire
        // protocol (multi-chunk transfers on CHUNK_TAG_SPAN blocks,
        // drained by each sub-communicator's own send pool) really runs
        // on both comms at once.
        let (pr, pc) = (2usize, 2usize);
        for kind in PortKind::ALL {
            let cluster = Cluster::new(pr * pc, kind, None).unwrap();
            let got = cluster.run(|ctx| {
                let world = Communicator::from_ctx(ctx);
                world.set_chunk_policy(ChunkPolicy::new(8, 2));
                let (r, c) = (ctx.rank / pc, ctx.rank % pc);
                let row = world.split(r as u64, c as u64);
                let col = world.split(c as u64, r as u64);
                // Distinguishable payloads: row traffic is 1000-coded,
                // column traffic 2000-coded; same lengths, same posting
                // instant, interleaved in flight (7 f32 over 8-byte wire
                // chunks → 4 chunks per transfer).
                let row_send: Vec<Payload> = (0..row.size())
                    .map(|j| Payload::from_f32(&vec![(1000 + ctx.rank * 10 + j) as f32; 7]))
                    .collect();
                let col_send: Vec<Payload> = (0..col.size())
                    .map(|j| Payload::from_f32(&vec![(2000 + ctx.rank * 10 + j) as f32; 7]))
                    .collect();
                let row_fut = row.all_to_all_async(row_send, AllToAllAlgo::PairwiseChunked);
                let col_fut = col.all_to_all_async(col_send, AllToAllAlgo::PairwiseChunked);
                let row_got: Vec<f32> =
                    row_fut.get().iter().map(|p| p.to_f32()[0]).collect();
                let col_got: Vec<f32> =
                    col_fut.get().iter().map(|p| p.to_f32()[0]).collect();
                (row_got, col_got)
            });
            for (rank, (row_got, col_got)) in got.iter().enumerate() {
                let (r, c) = (rank / pc, rank % pc);
                // Row peer j has global rank r*pc + j and addressed me by
                // my in-row rank c.
                let row_expect: Vec<f32> =
                    (0..pc).map(|j| (1000 + (r * pc + j) * 10 + c) as f32).collect();
                // Column peer j has global rank j*pc + c and addressed me
                // by my in-column rank r.
                let col_expect: Vec<f32> =
                    (0..pr).map(|j| (2000 + (j * pc + c) * 10 + r) as f32).collect();
                assert_eq!(row_got, &row_expect, "{kind} rank {rank} row traffic");
                assert_eq!(col_got, &col_expect, "{kind} rank {rank} column traffic");
            }
        }
    }

    #[test]
    fn split_tag_spaces_are_disjoint_across_calls() {
        use crate::collectives::tags::SPLIT_TAG_SPAN;
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            let a = world.split(0, ctx.rank as u64);
            let b = world.split(0, ctx.rank as u64);
            let ta = a.alloc_tags();
            let tb = b.alloc_tags();
            assert!(
                tb >= ta + SPLIT_TAG_SPAN,
                "second split must sit in a later span: {ta} vs {tb}"
            );
            // The parent's next allocation clears both spans.
            assert!(world.alloc_tags() >= tb);
        });
    }

    #[test]
    fn split_with_span_bounds_the_sub_communicator() {
        use crate::collectives::tags::CHUNK_TAG_SPAN;
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            let span = 4 * CHUNK_TAG_SPAN;
            let sub = world.split_with_span(0, ctx.rank as u64, span);
            let base = sub.alloc_tags();
            // Exhausting the explicit grant trips the sub-communicator's
            // bound instead of bleeding into the parent's tag space.
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for _ in 0..5 {
                    sub.alloc_chunk_tags(1);
                }
            }));
            assert!(res.is_err(), "allocating past the explicit span must panic");
            // The parent's next allocation clears the whole grant.
            assert!(world.alloc_tags() >= base + span);
        });
    }

    #[test]
    fn nested_split_exhaustion_is_typed_and_leaves_parent_usable() {
        use crate::collectives::tags::CHUNK_TAG_SPAN;
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            // One chunk block is the minimum viable grant: a nested
            // split can never be carved out of it.
            let sub = world.split_with_span(0, ctx.rank as u64, CHUNK_TAG_SPAN);
            let err = sub.try_split(0, ctx.rank as u64).unwrap_err();
            assert!(err.to_string().contains("tag space exhausted"), "{err}");
            // The failed split consumed nothing: the sub-communicator's
            // collectives still work, in lock-step, inside its span.
            let all = sub.all_gather(Payload::from_f32(&[ctx.rank as f32]));
            let vals: Vec<f32> = all.iter().map(|p| p.to_f32()[0]).collect();
            assert_eq!(vals, vec![0.0, 1.0]);

            // Explicit-span variant: the grant itself does not fit.
            let sub2 = world.split_with_span(0, ctx.rank as u64, 2 * CHUNK_TAG_SPAN);
            let err = sub2.try_split_with_span(0, ctx.rank as u64, 2 * CHUNK_TAG_SPAN);
            let err = err.expect_err("a grant as large as the whole span cannot fit");
            assert!(err.next > err.limit, "{err}");
            assert!(err.to_string().contains("tag space exhausted"), "{err}");
            let all = sub2.all_gather(Payload::from_f32(&[(10 + ctx.rank) as f32]));
            let vals: Vec<f32> = all.iter().map(|p| p.to_f32()[0]).collect();
            assert_eq!(vals, vec![10.0, 11.0]);
        });
    }

    #[test]
    fn split_of_split_nests() {
        let cluster = Cluster::new(4, PortKind::Mpi, None).unwrap();
        let sums = cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            // First split: halves {0,1} and {2,3}.
            let half = world.split((ctx.rank / 2) as u64, ctx.rank as u64);
            // Second split: singletons.
            let solo = half.split(half.rank() as u64, 0);
            assert_eq!(solo.size(), 1);
            // A singleton reduce is the identity.
            let r = half.all_reduce(&[ctx.rank as f32], ReduceOp::Sum);
            r[0]
        });
        assert_eq!(sums, vec![1.0, 1.0, 5.0, 5.0]);
    }

    #[test]
    fn singleton_and_whole_splits() {
        let cluster = Cluster::new(3, PortKind::Tcp, None).unwrap();
        cluster.run(|ctx| {
            let world = Communicator::from_ctx(ctx);
            // Everyone same color, key = rank: order preserved.
            let whole = world.split(7, ctx.rank as u64);
            assert_eq!(whole.size(), 3);
            assert_eq!(whole.rank(), ctx.rank);
            let all = whole.all_gather(Payload::from_f32(&[ctx.rank as f32]));
            let vals: Vec<f32> = all.iter().map(|p| p.to_f32()[0]).collect();
            assert_eq!(vals, vec![0.0, 1.0, 2.0]);
        });
    }
}
