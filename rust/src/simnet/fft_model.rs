//! Schedule builders: the distributed-FFT communication patterns at
//! cluster scale, fed to the DES engine.
//!
//! These mirror, action for action, what the live drivers do — the same
//! four steps, the same collective traffic, the same overlap structure —
//! so a simnet prediction and a live hybrid run disagree only in scale,
//! not in shape.

use super::compute::ComputeModel;
use super::sim::{Schedule, SimNet, SimReport};
use crate::collectives::AllToAllAlgo;
use crate::dist_fft::driver::Domain;
use crate::dist_fft::grid3::{Grid3, PencilDims, ProcGrid};
use crate::parcelport::{NetModel, PortKind};

/// Problem + platform for one prediction.
#[derive(Clone, Copy, Debug)]
pub struct FftModelParams {
    /// Global grid rows.
    pub rows: usize,
    /// Global grid columns.
    pub cols: usize,
    /// Locality count.
    pub nodes: usize,
    /// Input domain: real-input (r2c) runs transpose the packed
    /// `cols/2`-bin half-spectrum, so the modeled wire volume — the
    /// dominant cost the communication study measures — halves, and the
    /// first FFT sweep runs at the packed length.
    pub domain: Domain,
    /// Per-node compute-rate model.
    pub compute: ComputeModel,
    /// Wire model.
    pub net: NetModel,
}

impl FftModelParams {
    /// The paper's strong-scaling problem: 2^14 × 2^14 on buran
    /// (complex domain).
    pub fn paper(nodes: usize) -> Self {
        Self {
            rows: 1 << 14,
            cols: 1 << 14,
            nodes,
            domain: Domain::Complex,
            compute: ComputeModel::buran(),
            net: NetModel::infiniband_hdr(),
        }
    }

    fn local_rows(&self) -> usize {
        self.rows / self.nodes
    }

    /// Columns of the spectral slab the transpose rounds actually move:
    /// `cols` for the complex domain, the packed `cols/2` for r2c.
    fn spectral_cols(&self) -> usize {
        match self.domain {
            Domain::Complex => self.cols,
            Domain::Real => self.cols / 2,
        }
    }

    fn chunk_cols(&self) -> usize {
        self.spectral_cols() / self.nodes
    }

    /// One all-to-all chunk, bytes (complex64 elements of the spectral
    /// slab — half the complex volume in the real domain).
    pub fn chunk_bytes(&self) -> u64 {
        (self.local_rows() * self.chunk_cols() * 8) as u64
    }

    /// One locality's whole spectral slab, bytes.
    pub fn slab_bytes(&self) -> u64 {
        (self.local_rows() * self.spectral_cols() * 8) as u64
    }
}

/// Which system is being predicted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelVariant {
    /// HPX all-to-all collective (Fig. 4): the root-funneled collective
    /// unless another algorithm is selected explicitly.
    AllToAll(AllToAllAlgo),
    /// HPX N-scatter with overlapped transposes (Fig. 5).
    Scatter,
    /// FFTW3 MPI+pthreads: synchronous pairwise all-to-all, no overlap —
    /// always on the MPI cost model regardless of `port`.
    FftwBaseline,
}

/// Predict one run; returns the DES report (makespan = the figure's y).
pub fn predict_fft(params: &FftModelParams, port: PortKind, variant: ModelVariant) -> SimReport {
    assert!(
        params.rows % params.nodes == 0 && params.spectral_cols() % params.nodes == 0,
        "grid must divide over the nodes (spectral columns included)"
    );
    let (cost, schedules) = match variant {
        ModelVariant::AllToAll(algo) => (port.cost_model(), all_to_all_schedules(params, algo)),
        ModelVariant::Scatter => (port.cost_model(), scatter_schedules(params)),
        ModelVariant::FftwBaseline => {
            (PortKind::Mpi.cost_model(), all_to_all_schedules(params, AllToAllAlgo::Pairwise))
        }
    };
    SimNet::new(params.net, cost).run(&schedules)
}

/// Shared prologue: step-1 FFT sweep + chunk packing. Real-domain runs
/// charge the packed half-length sweep (the r2c trick is one `C/2`-point
/// complex FFT plus an O(C) recombination per row) and pack half the
/// bytes.
fn prologue(params: &FftModelParams, sched: &mut Schedule) {
    let lr = params.local_rows();
    sched.compute(params.compute.fft_rows_us(lr, params.spectral_cols()), "fft1");
    sched.compute(params.compute.transpose_us(params.slab_bytes()), "pack");
}

/// Shared epilogue: step-4 FFT sweep.
fn epilogue(params: &FftModelParams, sched: &mut Schedule) {
    let cw = params.chunk_cols();
    sched.compute(params.compute.fft_rows_us(cw, params.rows), "fft2");
}

/// Synchronized all-to-all variants: exchange fully, then transpose.
fn all_to_all_schedules(params: &FftModelParams, algo: AllToAllAlgo) -> Vec<Schedule> {
    let n = params.nodes;
    let chunk = params.chunk_bytes();
    let mut schedules: Vec<Schedule> = (0..n).map(|_| Schedule::default()).collect();

    for (me, sched) in schedules.iter_mut().enumerate() {
        prologue(params, sched);
        match algo {
            AllToAllAlgo::Linear | AllToAllAlgo::Bruck => {
                // Post everything, then drain. (Bruck's aggregation gains
                // matter only for tiny chunks; at FFT sizes its traffic
                // is linear-equivalent, so it shares the linear model.)
                for dst in 0..n {
                    if dst != me {
                        sched.send(dst, chunk, (me * n + dst) as u64);
                    }
                }
                for src in 0..n {
                    if src != me {
                        sched.recv(src, (src * n + me) as u64);
                    }
                }
            }
            AllToAllAlgo::Pairwise | AllToAllAlgo::PairwiseChunked => {
                // The chunked flavour shares the pairwise round schedule;
                // its intra-round chunk pipelining is a live-fabric
                // effect (send-pool overlap) the per-message DES does not
                // subdivide further.
                for r in 1..n {
                    // Same pairing as the live collective, by construction.
                    let (peer, from) = crate::collectives::all_to_all::pairwise_peers(me, n, r);
                    sched.send(peer, chunk, (r * n * n + me * n + peer) as u64);
                    sched.recv(from, (r * n * n + from * n + me) as u64);
                }
            }
            AllToAllAlgo::HpxRoot => {
                // Gather whole rows at the root, repack, scatter columns.
                let row_bytes = params.slab_bytes();
                if me != 0 {
                    sched.send(0, row_bytes, (1_000_000 + me) as u64);
                } else {
                    for src in 1..n {
                        sched.recv(src, (1_000_000 + src) as u64);
                    }
                    // Root repacks the full n×n chunk matrix.
                    sched.compute(
                        params.compute.transpose_us(row_bytes * n as u64),
                        "root-repack",
                    );
                    for dst in 1..n {
                        sched.send(dst, row_bytes, (2_000_000 + dst) as u64);
                    }
                }
                if me != 0 {
                    sched.recv(0, (2_000_000 + me) as u64);
                }
            }
        }
        // Synchronized variants: all transposes after the exchange.
        sched.compute(
            params.compute.transpose_us(chunk * n as u64),
            "transpose-all",
        );
        epilogue(params, sched);
    }
    schedules
}

/// N-scatter variant: per-root scatters, transpose-on-arrival.
fn scatter_schedules(params: &FftModelParams) -> Vec<Schedule> {
    let n = params.nodes;
    let chunk = params.chunk_bytes();
    let mut schedules: Vec<Schedule> = (0..n).map(|_| Schedule::default()).collect();

    for (me, sched) in schedules.iter_mut().enumerate() {
        prologue(params, sched);
        // My own scatter: ship a chunk to every peer.
        for dst in 0..n {
            if dst != me {
                sched.send(dst, chunk, (me * n + dst) as u64);
            }
        }
        // Own chunk transposes immediately — free overlap.
        sched.compute(params.compute.transpose_us(chunk), "transpose-own");
        // Then drain the other roots, transposing each on arrival. Order
        // approximates arrival order (nearest ring neighbours first).
        for k in 1..n {
            let root = (me + k) % n;
            sched.recv(root, (root * n + me) as u64);
            sched.compute(params.compute.transpose_us(chunk), "transpose-chunk");
        }
        epilogue(params, sched);
    }
    schedules
}

/// Problem + platform for one 3-D pencil prediction (the fig6 model).
#[derive(Clone, Copy, Debug)]
pub struct Pencil3ModelParams {
    /// Global 3-D grid extents.
    pub grid: Grid3,
    /// Process grid (`pr × pc` nodes).
    pub proc: ProcGrid,
    /// Per-node compute-rate model.
    pub compute: ComputeModel,
    /// Wire model.
    pub net: NetModel,
}

impl Pencil3ModelParams {
    /// The paper-scale 3-D problem: a 512³ cube on the buran model.
    pub fn paper(proc: ProcGrid) -> Self {
        Self {
            grid: Grid3::new(1 << 9, 1 << 9, 1 << 9),
            proc,
            compute: ComputeModel::buran(),
            net: NetModel::infiniband_hdr(),
        }
    }
}

/// Predict one 3-D pencil run: the five phases of
/// [`crate::dist_fft::pencil`], with each transpose round as a pairwise
/// exchange *within its sub-communicator group* — row groups first,
/// column groups second — so the DES charges exactly the
/// sub-communicator-scoped traffic the live pipeline generates.
///
/// # Panics
/// If the grid does not divide over the process grid (callers validate
/// via [`PencilDims::new`] first).
pub fn predict_pencil3(params: &Pencil3ModelParams, port: PortKind) -> SimReport {
    let dims = PencilDims::new(params.grid, params.proc).expect("divisible pencil dims");
    let (pr, pc) = (params.proc.pr, params.proc.pc);
    let n = params.proc.n();
    let t1_chunk = (dims.t1_chunk_elems() * 8) as u64;
    let t2_chunk = (dims.t2_chunk_elems() * 8) as u64;
    let local_bytes = (dims.local_elems() * 8) as u64;
    // Unique (src, dst, round) tags; the two rounds use disjoint bases.
    let tag1 = |src: usize, dst: usize, k: usize| (10_000_000 + (k * n + src) * n + dst) as u64;
    let tag2 = |src: usize, dst: usize, k: usize| (20_000_000 + (k * n + src) * n + dst) as u64;

    let mut schedules: Vec<Schedule> = (0..n).map(|_| Schedule::default()).collect();
    for (me, sched) in schedules.iter_mut().enumerate() {
        let (ri, ci) = params.proc.coords(me);
        // Phase 1: FFT(z) sweep + wire packing.
        sched.compute(params.compute.fft_rows_us(dims.d0 * dims.d1c, params.grid.n2), "fft-z");
        sched.compute(params.compute.transpose_us(local_bytes), "pack-1");
        // Round 1: ring-pairwise within the row group (Pc peers); own
        // chunk transposes while the first sends fly.
        sched.compute(params.compute.transpose_us(t1_chunk), "transpose-own-1");
        for k in 1..pc {
            let peer = params.proc.rank_of(ri, (ci + k) % pc);
            let from = params.proc.rank_of(ri, (ci + pc - k) % pc);
            sched.send(peer, t1_chunk, tag1(me, peer, k));
            sched.recv(from, tag1(from, me, k));
            sched.compute(params.compute.transpose_us(t1_chunk), "transpose-1");
        }
        // Phase 3: FFT(y) + packing.
        sched.compute(params.compute.fft_rows_us(dims.d0 * dims.d2c, params.grid.n1), "fft-y");
        sched.compute(params.compute.transpose_us(local_bytes), "pack-2");
        // Round 2: ring-pairwise within the column group (Pr peers).
        sched.compute(params.compute.transpose_us(t2_chunk), "transpose-own-2");
        for k in 1..pr {
            let peer = params.proc.rank_of((ri + k) % pr, ci);
            let from = params.proc.rank_of((ri + pr - k) % pr, ci);
            sched.send(peer, t2_chunk, tag2(me, peer, k));
            sched.recv(from, tag2(from, me, k));
            sched.compute(params.compute.transpose_us(t2_chunk), "transpose-2");
        }
        // Phase 5: FFT(x).
        sched.compute(params.compute.fft_rows_us(dims.d2c * dims.d1r, params.grid.n0), "fft-x");
    }
    SimNet::new(params.net, port.cost_model()).run(&schedules)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FftModelParams {
        FftModelParams { nodes: 4, ..FftModelParams::paper(4) }
    }

    #[test]
    fn all_variants_complete() {
        let p = small();
        for port in PortKind::ALL {
            for variant in [
                ModelVariant::AllToAll(AllToAllAlgo::HpxRoot),
                ModelVariant::AllToAll(AllToAllAlgo::Pairwise),
                ModelVariant::AllToAll(AllToAllAlgo::Linear),
                ModelVariant::Scatter,
                ModelVariant::FftwBaseline,
            ] {
                let r = predict_fft(&p, port, variant);
                assert!(r.makespan_us > 0.0, "{port} {variant:?}");
                assert!(r.makespan_us.is_finite());
            }
        }
    }

    #[test]
    fn scatter_beats_hpx_all_to_all() {
        // The paper's core finding (Figs. 4 vs 5): the N-scatter variant
        // is faster than HPX's (root-funneled) all-to-all collective.
        let p = FftModelParams::paper(16);
        for port in PortKind::ALL {
            let a2a =
                predict_fft(&p, port, ModelVariant::AllToAll(AllToAllAlgo::HpxRoot)).makespan_us;
            let scatter = predict_fft(&p, port, ModelVariant::Scatter).makespan_us;
            assert!(
                scatter < a2a,
                "{port}: scatter {scatter} should beat hpx all-to-all {a2a}"
            );
        }
    }

    #[test]
    fn lci_beats_mpi_beats_nothing_weird() {
        let p = FftModelParams::paper(16);
        let t = |port| predict_fft(&p, port, ModelVariant::Scatter).makespan_us;
        assert!(t(PortKind::Lci) <= t(PortKind::Mpi));
    }

    #[test]
    fn lci_scatter_beats_fftw_baseline() {
        // The headline claim: HPX+LCI up to 3× faster than FFTW3 MPI+X.
        let p = FftModelParams::paper(16);
        let lci = predict_fft(&p, PortKind::Lci, ModelVariant::Scatter).makespan_us;
        let fftw = predict_fft(&p, PortKind::Lci, ModelVariant::FftwBaseline).makespan_us;
        assert!(lci < fftw, "lci {lci} vs fftw {fftw}");
    }

    #[test]
    fn strong_scaling_decreases_runtime() {
        // More nodes → shorter runtime (the problem is compute-heavy
        // enough at 2^14² to keep scaling to 16 nodes, as in the paper).
        let t = |nodes| {
            predict_fft(&FftModelParams::paper(nodes), PortKind::Lci, ModelVariant::Scatter)
                .makespan_us
        };
        let (t2, t4, t8, t16) = (t(2), t(4), t(8), t(16));
        assert!(t2 > t4 && t4 > t8 && t8 > t16, "{t2} {t4} {t8} {t16}");
    }

    #[test]
    fn chunk_bytes_formula() {
        let p = FftModelParams::paper(16);
        // (2^14/16) × (2^14/16) × 8 = 1024·1024·8 = 8 MiB.
        assert_eq!(p.chunk_bytes(), 8 << 20);
        assert_eq!(p.slab_bytes(), 128 << 20);
    }

    /// The r2c traffic model: a real-domain run moves exactly half the
    /// complex-domain wire bytes on every variant, and never more wall
    /// time.
    #[test]
    fn real_domain_halves_modeled_wire_traffic() {
        let complex = FftModelParams::paper(16);
        let real = FftModelParams { domain: Domain::Real, ..complex };
        for variant in [
            ModelVariant::Scatter,
            ModelVariant::AllToAll(AllToAllAlgo::Pairwise),
            ModelVariant::AllToAll(AllToAllAlgo::HpxRoot),
            ModelVariant::FftwBaseline,
        ] {
            for port in PortKind::ALL {
                let c = predict_fft(&complex, port, variant);
                let r = predict_fft(&real, port, variant);
                assert_eq!(r.wire_bytes * 2, c.wire_bytes, "{port} {variant:?}");
                assert!(
                    r.makespan_us <= c.makespan_us,
                    "{port} {variant:?}: real {} vs complex {}",
                    r.makespan_us,
                    c.makespan_us
                );
            }
        }
    }

    #[test]
    fn real_chunk_bytes_are_half() {
        let p = FftModelParams { domain: Domain::Real, ..FftModelParams::paper(16) };
        assert_eq!(p.chunk_bytes(), 4 << 20);
        assert_eq!(p.slab_bytes(), 64 << 20);
    }

    #[test]
    fn hpx_root_funnels_more_bytes() {
        // The root-funneled collective moves ~2·(n-1)·slab bytes vs
        // (n-1)·chunk·n for pairwise — visible in wire accounting.
        let p = small();
        let root = predict_fft(&p, PortKind::Lci, ModelVariant::AllToAll(AllToAllAlgo::HpxRoot));
        let pair = predict_fft(&p, PortKind::Lci, ModelVariant::AllToAll(AllToAllAlgo::Pairwise));
        assert!(root.wire_bytes > pair.wire_bytes);
    }

    #[test]
    fn single_node_has_no_wire_traffic() {
        let p = FftModelParams::paper(1);
        let r = predict_fft(&p, PortKind::Lci, ModelVariant::Scatter);
        assert_eq!(r.wire_bytes, 0);
        assert!(r.makespan_us > 0.0);
    }

    #[test]
    fn pencil3_completes_all_shapes_and_ports() {
        for (pr, pc) in [(1, 4), (2, 2), (4, 1), (1, 1)] {
            let p = Pencil3ModelParams::paper(ProcGrid::new(pr, pc));
            for port in PortKind::ALL {
                let r = predict_pencil3(&p, port);
                assert!(r.makespan_us > 0.0 && r.makespan_us.is_finite(), "{port} {pr}x{pc}");
            }
        }
    }

    #[test]
    fn pencil3_wire_volume_matches_formula() {
        // Round 1 ships (Pc−1) chunks per node, round 2 (Pr−1): total
        // wire traffic is exactly the two-transpose volume.
        let p = Pencil3ModelParams::paper(ProcGrid::new(2, 2));
        let dims = PencilDims::new(p.grid, p.proc).unwrap();
        let r = predict_pencil3(&p, PortKind::Lci);
        let expect = (p.proc.n()
            * ((p.proc.pc - 1) * dims.t1_chunk_elems() * 8
                + (p.proc.pr - 1) * dims.t2_chunk_elems() * 8)) as u64;
        assert_eq!(r.wire_bytes, expect);
    }

    #[test]
    fn pencil3_single_node_no_wire() {
        let p = Pencil3ModelParams::paper(ProcGrid::new(1, 1));
        let r = predict_pencil3(&p, PortKind::Lci);
        assert_eq!(r.wire_bytes, 0);
        assert!(r.makespan_us > 0.0);
    }

    #[test]
    fn pencil3_lci_no_slower_than_tcp() {
        let p = Pencil3ModelParams::paper(ProcGrid::new(2, 2));
        let t = |port| predict_pencil3(&p, port).makespan_us;
        assert!(t(PortKind::Lci) <= t(PortKind::Tcp));
    }
}
