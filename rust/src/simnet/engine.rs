//! The discrete-event core: a `(tick, seq)` min-heap of deliverable
//! events over per-rank CPUs and NICs.
//!
//! Unlike the closed-form [`crate::simnet::sim`] engine — which walks
//! straight-line schedules and resolves arrival times arithmetically —
//! this engine is *reactive*: protocol machines post sends whenever they
//! step, the engine computes each message's wire occupancy and arrival
//! using exactly the same cost formulas, and delivery order is decided
//! by popping the heap. The heap key is `(tick, seq)` with `seq` a
//! monotonically increasing sequence number, so events at colliding
//! timestamps pop in insertion order — a total order with **no reliance
//! on `BinaryHeap`'s unstable behavior for equal keys**, which is what
//! keeps runs bit-reproducible.
//!
//! The seeded [`crate::simnet::adversary`] perturbs the schedule between
//! the modeled arrival computation and the heap: extra delays, duplicate
//! deliveries (deduplicated here, counted), and first-transmission drops
//! recovered by a retransmission timer that re-reserves both NICs for
//! the repeat transfer.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashSet};

use super::adversary::AdversaryConfig;
use super::components::{fold_hash, ticks_to_us, us_to_ticks, Nic, RankCpu, SimMsg, Tick};
use crate::collectives::protocol::Wire;
use crate::hpx::parcel::Tag;
use crate::parcelport::{CostModel, NetModel};

/// How long after the modeled (lost) arrival the sender's retransmission
/// timer fires. Fixed and generous — recovery correctness is what is
/// under test, not RTO tuning.
pub const RETRANSMIT_RTO_US: f64 = 50.0;

/// A message in flight through the simulated fabric.
#[derive(Clone, Debug)]
pub struct WireMsg {
    /// Unique id (assignment order); adversary plans key off it and
    /// duplicate deliveries are deduplicated by it.
    pub id: u64,
    /// Sending rank.
    pub src: usize,
    /// Receiving rank.
    pub dst: usize,
    /// Message tag (same tag space the live communicator uses).
    pub tag: Tag,
    /// Modeled on-wire size in bytes.
    pub size: u64,
    /// The body, delivered to the destination machine.
    pub msg: SimMsg,
}

/// A message popped off the heap, ready to hand to its destination
/// machine.
#[derive(Clone, Debug)]
pub struct Delivery {
    /// Arrival tick (the destination blocks until here).
    pub tick: Tick,
    /// The arrived message.
    pub msg: WireMsg,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EventKind {
    Arrival,
    Retransmit,
}

/// Heap entry ordered **only** by `(tick, seq)`. The manual `Ord` makes
/// the tie-break explicit: equal ticks pop in insertion order, never in
/// whatever order the heap's internal sift happens to leave them.
#[derive(Clone, Debug)]
struct HeapEntry {
    tick: Tick,
    seq: u64,
    kind: EventKind,
    msg: WireMsg,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.tick == other.tick && self.seq == other.seq
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.tick, self.seq).cmp(&(other.tick, other.seq))
    }
}

/// Counters and the schedule fingerprint of a finished run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineStats {
    /// Latest rank clock, µs — the simulated collective's runtime.
    pub makespan_us: f64,
    /// Total bytes that crossed the wire (retransmissions included).
    pub wire_bytes: u64,
    /// Bytes re-sent by the retransmission timer.
    pub retransmitted_bytes: u64,
    /// Duplicate deliveries the engine discarded.
    pub duplicates_dropped: u64,
    /// First transmissions the adversary dropped.
    pub drops_injected: u64,
    /// Heap events processed.
    pub events: u64,
    /// Order-sensitive hash of every processed event: two runs are
    /// schedule-identical iff these agree.
    pub trace_hash: u64,
    /// Largest per-rank blocked time, µs.
    pub max_blocked_us: f64,
}

/// The event engine: rank CPUs + NICs + the deliverable-event heap.
pub struct EventEngine {
    net: NetModel,
    cost: CostModel,
    adversary: AdversaryConfig,
    cpus: Vec<RankCpu>,
    nics: Vec<Nic>,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    next_seq: u64,
    next_msg_id: u64,
    delivered: HashSet<u64>,
    wire_bytes: u64,
    retransmitted_bytes: u64,
    duplicates_dropped: u64,
    drops_injected: u64,
    events: u64,
    trace_hash: u64,
    /// Optional timeline capture (see [`Self::enable_trace`]). `None`
    /// (the default) records nothing and perturbs nothing — the capture
    /// only *reads* ticks the engine computed anyway, so `trace_hash`
    /// and every counter are bit-identical with and without it.
    trace: Option<Vec<crate::obs::Event>>,
}

impl EventEngine {
    /// An engine for `n` ranks. Slow-rank factors are drawn from the
    /// adversary up front so they apply to every charge a rank makes.
    pub fn new(n: usize, net: NetModel, cost: CostModel, adversary: AdversaryConfig) -> Self {
        let cpus = (0..n).map(|r| RankCpu::new(adversary.slow_factor_for(r))).collect();
        Self {
            net,
            cost,
            adversary,
            cpus,
            nics: vec![Nic::default(); n],
            heap: BinaryHeap::new(),
            next_seq: 0,
            next_msg_id: 0,
            delivered: HashSet::new(),
            wire_bytes: 0,
            retransmitted_bytes: 0,
            duplicates_dropped: 0,
            drops_injected: 0,
            events: 0,
            trace_hash: 0,
            trace: None,
        }
    }

    /// Start capturing the run's timeline as [`crate::obs::Event`]s —
    /// one `wire` span per transfer (start tick → wire end, original
    /// sends named `send`, timer-driven repeats `retransmit`) and one
    /// `arrival` instant per consumed delivery. Ticks are already
    /// nanoseconds, so they map 1:1 onto `Event::ts_ns` and the capture
    /// exports through the same [`crate::obs::chrome`] pipeline as live
    /// traces: pid = simulated rank, spans on tid 0.
    pub fn enable_trace(&mut self) {
        self.trace.get_or_insert_with(Vec::new);
    }

    /// Take the captured timeline (empty if [`Self::enable_trace`] was
    /// never called). Capture continues into a fresh buffer.
    pub fn take_trace(&mut self) -> Vec<crate::obs::Event> {
        match self.trace.as_mut() {
            Some(buf) => std::mem::take(buf),
            None => Vec::new(),
        }
    }

    /// Record a wire-occupancy span for `msg` if capture is on.
    fn trace_wire(&mut self, start: Tick, end: Tick, name: &'static str, msg: &WireMsg) {
        let Some(buf) = self.trace.as_mut() else { return };
        buf.push(crate::obs::Event {
            ts_ns: start,
            kind: crate::obs::EventKind::Span { dur_ns: end.saturating_sub(start) },
            cat: "wire",
            name,
            rank: msg.src as u32,
            tid: 0,
            tag: msg.tag as i64,
            chunk: msg.id as i64,
            bytes: msg.size as i64,
        });
    }

    /// Record a delivery-consumed instant at the destination if capture
    /// is on.
    fn trace_arrival(&mut self, tick: Tick, msg: &WireMsg) {
        let Some(buf) = self.trace.as_mut() else { return };
        buf.push(crate::obs::Event {
            ts_ns: tick,
            kind: crate::obs::EventKind::Instant,
            cat: "wire",
            name: "arrival",
            rank: msg.dst as u32,
            tid: 0,
            tag: msg.tag as i64,
            chunk: msg.id as i64,
            bytes: msg.size as i64,
        });
    }

    /// Number of simulated ranks.
    pub fn ranks(&self) -> usize {
        self.cpus.len()
    }

    /// Mutable access to a rank's CPU (the executor charges compute and
    /// waits through this).
    pub fn cpu(&mut self, rank: usize) -> &mut RankCpu {
        &mut self.cpus[rank]
    }

    fn push(&mut self, tick: Tick, kind: EventKind, msg: WireMsg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(HeapEntry { tick, seq, kind, msg }));
    }

    /// Reserve both NICs for a transfer starting no earlier than
    /// `ready`; returns the `(wire-start, wire-end)` ticks (the start is
    /// what the trace capture draws as the span's left edge). Mirrors
    /// the closed-form engine's store-and-forward charge.
    fn reserve_wire(&mut self, src: usize, dst: usize, ready: Tick, size: u64) -> (Tick, Tick) {
        let start = ready.max(self.nics[src].egress_free).max(self.nics[dst].ingress_free);
        let end = start + us_to_ticks(size as f64 / self.net.beta_gbps / 1e3);
        self.nics[src].egress_free = end;
        self.nics[dst].ingress_free = end;
        self.wire_bytes += size;
        (start, end)
    }

    /// Post a send from `src`'s machine: charge the sender's software
    /// half, model the wire, apply the adversary's plan, and schedule
    /// the arrival event(s).
    pub fn post_send(&mut self, src: usize, dst: usize, tag: Tag, msg: SimMsg) {
        debug_assert_ne!(src, dst, "protocol machines never self-send");
        let size = msg.wire_len() as u64;
        self.cpus[src].charge_us(self.cost.sw_time_us(size) / 2.0);

        let id = self.next_msg_id;
        self.next_msg_id += 1;
        let plan = self.adversary.plan(id);

        // Rendezvous handshake delays wire entry without occupying the
        // sender's CPU — same as the closed-form engine.
        let handshake = if self.cost.is_rendezvous(size) {
            us_to_ticks(self.cost.rendezvous_rtts as f64 * 2.0 * self.net.alpha_us)
        } else {
            0
        };
        let ready = self.cpus[src].now + handshake;
        let (start, end) = self.reserve_wire(src, dst, ready, size);
        let arrival = end + us_to_ticks(self.net.alpha_us) + plan.extra_delay;

        let wmsg = WireMsg { id, src, dst, tag, size, msg };
        self.trace_wire(start, end, "send", &wmsg);
        if plan.drop_first {
            // The bytes occupied the wire but the packet is lost; the
            // sender's timer notices and retransmits.
            self.drops_injected += 1;
            self.push(arrival + us_to_ticks(RETRANSMIT_RTO_US), EventKind::Retransmit, wmsg);
        } else {
            let dup = plan.duplicate_after;
            self.push(arrival, EventKind::Arrival, wmsg.clone());
            if let Some(gap) = dup {
                self.push(arrival + gap, EventKind::Arrival, wmsg);
            }
        }
    }

    /// Pop the next deliverable message. Retransmission timers are
    /// resolved internally (the repeat transfer re-reserves both NICs);
    /// duplicate deliveries are discarded and counted. `None` means the
    /// fabric is drained — if machines are still unfinished then, the
    /// run has deadlocked.
    pub fn next_delivery(&mut self) -> Option<Delivery> {
        while let Some(Reverse(entry)) = self.heap.pop() {
            self.events += 1;
            self.fold_event(&entry);
            match entry.kind {
                EventKind::Retransmit => {
                    let (src, dst, size) = (entry.msg.src, entry.msg.dst, entry.msg.size);
                    let (start, end) = self.reserve_wire(src, dst, entry.tick, size);
                    self.retransmitted_bytes += size;
                    self.trace_wire(start, end, "retransmit", &entry.msg);
                    let arrival = end + us_to_ticks(self.net.alpha_us);
                    self.push(arrival, EventKind::Arrival, entry.msg);
                }
                EventKind::Arrival => {
                    if !self.delivered.insert(entry.msg.id) {
                        self.duplicates_dropped += 1;
                        continue;
                    }
                    self.trace_arrival(entry.tick, &entry.msg);
                    return Some(Delivery { tick: entry.tick, msg: entry.msg });
                }
            }
        }
        None
    }

    /// Account a machine consuming a delivery: the destination blocks
    /// until the arrival tick, then pays the receive-side software half.
    pub fn consume(&mut self, dst: usize, arrival: Tick) {
        let half = self.cost.sw_overhead_us / 2.0;
        let cpu = &mut self.cpus[dst];
        cpu.wait_until(arrival);
        cpu.charge_us(half);
    }

    fn fold_event(&mut self, e: &HeapEntry) {
        let mut h = self.trace_hash;
        h = fold_hash(h, e.tick);
        h = fold_hash(h, e.seq);
        h = fold_hash(h, matches!(e.kind, EventKind::Retransmit) as u64);
        h = fold_hash(h, e.msg.id);
        h = fold_hash(h, ((e.msg.src as u64) << 32) | e.msg.dst as u64);
        h = fold_hash(h, e.msg.tag);
        h = fold_hash(h, e.msg.size);
        self.trace_hash = h;
    }

    /// Snapshot the run's counters and fingerprint.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            makespan_us: self.cpus.iter().map(|c| c.now).max().map_or(0.0, ticks_to_us),
            wire_bytes: self.wire_bytes,
            retransmitted_bytes: self.retransmitted_bytes,
            duplicates_dropped: self.duplicates_dropped,
            drops_injected: self.drops_injected,
            events: self.events,
            trace_hash: self.trace_hash,
            max_blocked_us: self.cpus.iter().map(|c| c.blocked).max().map_or(0.0, ticks_to_us),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parcelport::PortKind;
    use crate::simnet::sim::{Schedule, SimNet};

    fn msg(id: u64, size: u64) -> WireMsg {
        WireMsg { id, src: 0, dst: 1, tag: 0, size, msg: SimMsg::Size(size) }
    }

    /// Satellite regression for the (tick, seq) tie-break: events pushed
    /// at a colliding timestamp must pop in insertion order, bracketed
    /// by earlier/later ticks popping strictly by time.
    #[test]
    fn colliding_timestamps_pop_in_insertion_order() {
        let entry = |tick: Tick, seq: u64| {
            Reverse(HeapEntry { tick, seq, kind: EventKind::Arrival, msg: msg(seq, 1) })
        };
        let mut heap: BinaryHeap<Reverse<HeapEntry>> = BinaryHeap::new();
        for seq in 0..16u64 {
            heap.push(entry(100, seq));
        }
        // Later-inserted but earlier/later ticks must sort by tick.
        heap.push(entry(99, 50));
        heap.push(entry(101, 16));

        let order: Vec<(Tick, u64)> =
            std::iter::from_fn(|| heap.pop()).map(|Reverse(e)| (e.tick, e.seq)).collect();
        let mut expect: Vec<(Tick, u64)> = vec![(99, 50)];
        expect.extend((0..16).map(|s| (100, s)));
        expect.push((101, 16));
        assert_eq!(order, expect);
    }

    /// With the adversary off, a single message reproduces the
    /// closed-form engine's makespan to nanosecond rounding.
    #[test]
    fn benign_single_message_matches_closed_form() {
        for kind in PortKind::ALL {
            for size in [1u64 << 10, 64 * 1024 + 1, 1 << 20] {
                let net = NetModel::infiniband_hdr();
                let cost = kind.cost_model();
                let mut eng = EventEngine::new(2, net, cost, AdversaryConfig::none(0));
                eng.post_send(0, 1, 7, SimMsg::Size(size));
                let d = eng.next_delivery().expect("one arrival");
                eng.consume(1, d.tick);
                assert!(eng.next_delivery().is_none());

                let mut a = Schedule::default();
                a.send(1, size, 7);
                let mut b = Schedule::default();
                b.recv(0, 7);
                let closed = SimNet::new(net, cost).run(&[a, b]);
                let got = eng.stats().makespan_us;
                assert!(
                    (got - closed.makespan_us).abs() < 0.01,
                    "{kind} size {size}: event {got} vs closed {}",
                    closed.makespan_us
                );
                assert_eq!(eng.stats().wire_bytes, closed.wire_bytes);
            }
        }
    }

    #[test]
    fn incast_serializes_on_the_receiver_nic() {
        let net = NetModel::infiniband_hdr();
        let mut eng = EventEngine::new(5, net, CostModel::lci(), AdversaryConfig::none(0));
        let size = 1u64 << 20;
        for src in 1..5 {
            eng.post_send(src, 0, src as Tag, SimMsg::Size(size));
        }
        while let Some(d) = eng.next_delivery() {
            eng.consume(d.msg.dst, d.tick);
        }
        let wire_each = size as f64 / net.beta_gbps / 1e3;
        assert!(eng.stats().makespan_us >= 4.0 * wire_each);
    }

    #[test]
    fn dropped_message_is_retransmitted_and_counted() {
        // 100% drop probability: every message goes through the timer
        // exactly once (retransmissions themselves are not re-dropped).
        let mut adv = AdversaryConfig::none(3);
        adv.drop_prob_pct = 100;
        let mut eng = EventEngine::new(2, NetModel::infiniband_hdr(), CostModel::lci(), adv);
        eng.post_send(0, 1, 0, SimMsg::Size(4096));
        let d = eng.next_delivery().expect("recovered by retransmission");
        assert_eq!(d.msg.size, 4096);
        eng.consume(1, d.tick);
        assert!(eng.next_delivery().is_none());
        let stats = eng.stats();
        assert_eq!(stats.drops_injected, 1);
        assert_eq!(stats.retransmitted_bytes, 4096);
        assert_eq!(stats.wire_bytes, 2 * 4096, "both transmissions occupy the wire");
        assert!(stats.makespan_us > RETRANSMIT_RTO_US);
    }

    #[test]
    fn duplicates_are_delivered_once() {
        let mut adv = AdversaryConfig::none(5);
        adv.dup_prob_pct = 100;
        let mut eng = EventEngine::new(2, NetModel::infiniband_hdr(), CostModel::lci(), adv);
        eng.post_send(0, 1, 0, SimMsg::Size(64));
        let first = eng.next_delivery().expect("original copy");
        eng.consume(1, first.tick);
        assert!(eng.next_delivery().is_none(), "duplicate must be swallowed");
        assert_eq!(eng.stats().duplicates_dropped, 1);
    }

    #[test]
    fn trace_hash_is_reproducible_and_seed_sensitive() {
        let run = |seed: u64| {
            let adv = AdversaryConfig::hostile(seed);
            let mut eng = EventEngine::new(4, NetModel::infiniband_hdr(), CostModel::mpi(), adv);
            for src in 0..4usize {
                for dst in 0..4usize {
                    if src != dst {
                        eng.post_send(src, dst, (src * 4 + dst) as Tag, SimMsg::Size(100_000));
                    }
                }
            }
            while let Some(d) = eng.next_delivery() {
                eng.consume(d.msg.dst, d.tick);
            }
            eng.stats()
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b, "same seed must reproduce the run bit-for-bit");
        let c = run(43);
        assert_ne!(a.trace_hash, c.trace_hash, "different seed must change the schedule");
    }

    /// Trace capture is a pure observer: enabling it changes neither the
    /// schedule fingerprint nor any counter, and the captured spans
    /// cover exactly the transmissions the counters claim (one `send`
    /// per post, one `retransmit` per timer fire, spans summing to
    /// `wire_bytes`).
    #[test]
    fn trace_capture_does_not_perturb_the_run() {
        let run = |trace: bool| {
            let mut adv = AdversaryConfig::hostile(11);
            adv.drop_prob_pct = 40;
            let mut eng = EventEngine::new(3, NetModel::infiniband_hdr(), CostModel::lci(), adv);
            if trace {
                eng.enable_trace();
            }
            for src in 0..3usize {
                for dst in 0..3usize {
                    if src != dst {
                        eng.post_send(src, dst, (src * 3 + dst) as Tag, SimMsg::Size(50_000));
                    }
                }
            }
            while let Some(d) = eng.next_delivery() {
                eng.consume(d.msg.dst, d.tick);
            }
            let events = eng.take_trace();
            (eng.stats(), events)
        };
        let (plain, none) = run(false);
        let (traced, events) = run(true);
        assert_eq!(plain, traced, "capture must not perturb the schedule");
        assert!(none.is_empty(), "no capture without enable_trace");

        let spans: Vec<_> = events.iter().filter(|e| e.is_span()).collect();
        assert_eq!(spans.len(), 6 + plain.drops_injected as usize, "one span per transmission");
        let traced_bytes: u64 = spans.iter().map(|e| e.bytes as u64).sum();
        assert_eq!(traced_bytes, plain.wire_bytes, "span bytes must cover wire_bytes");
        let arrivals = events.iter().filter(|e| !e.is_span()).count();
        assert_eq!(arrivals, 6, "one arrival instant per consumed delivery");
    }

    #[test]
    fn slow_rank_inflates_its_software_charges() {
        let mut adv = AdversaryConfig::none(0);
        adv.slow_rank_pct = 100;
        adv.slow_factor = 8.0;
        let net = NetModel::infiniband_hdr();
        let mut slow_eng = EventEngine::new(2, net, CostModel::tcp(), adv);
        let mut fast_eng = EventEngine::new(2, net, CostModel::tcp(), AdversaryConfig::none(0));
        for eng in [&mut slow_eng, &mut fast_eng] {
            eng.post_send(0, 1, 0, SimMsg::Size(1 << 20));
            let d = eng.next_delivery().expect("arrival");
            eng.consume(1, d.tick);
        }
        assert!(slow_eng.stats().makespan_us > fast_eng.stats().makespan_us * 2.0);
    }
}
