//! Deterministic network simulator — the 16-node InfiniBand cluster we
//! do not have.
//!
//! The paper's Figs. 3–5 were measured on "buran": 16 nodes, InfiniBand
//! HDR 200 Gb/s, dual EPYC 7352. This module predicts those figures with
//! a discrete-event simulation that executes the *same communication
//! schedules* the live code runs, against the *same cost model* the live
//! hybrid mode charges ([`crate::parcelport::cost`]):
//!
//! - every locality runs a sequential schedule of [`sim::Action`]s
//!   (compute / send / recv) — straight-line SPMD, exactly mirroring the
//!   live drivers;
//! - a message occupies the sender egress and receiver ingress NIC for
//!   `size/β` (store-and-forward postal model — this is what penalizes
//!   the HPX-root collective's incast), plus link latency α, plus the
//!   port's software overhead split across the two endpoints, plus the
//!   rendezvous RTT where the port's protocol says so;
//! - compute durations come from a [`ComputeModel`] calibrated against
//!   the native kernel's measured throughput (scaled to the paper's
//!   24-core nodes in `config`).
//!
//! Calibration discipline (DESIGN.md §6): constants are fitted on the
//! Fig. 3 chunk-size sweep only; Figs. 4–5 are then *predictions*.
//!
//! [`fft_model`] builds the schedules for both 2-D FFT variants, every
//! parcelport, and the FFTW3-like baseline — in either input domain
//! ([`crate::dist_fft::Domain`]: real-input runs model the packed
//! half-spectrum transposes, exactly half the complex wire bytes) —
//! plus the 3-D pencil pipeline's two sub-communicator-scoped transpose
//! rounds ([`fft_model::predict_pencil3`] — the fig6 prediction).
//!
//! Two engines share that cost model:
//!
//! 1. [`sim`] — the original closed-form engine: straight-line
//!    [`sim::Schedule`]s resolved arithmetically. Fast, but it can only
//!    replay the one schedule it was given.
//! 2. [`engine`] / [`collective_sim`] — the event engine: a
//!    `(tick, seq)` min-heap over per-rank CPUs ([`components`]) on
//!    which the **real protocol machines** from
//!    [`crate::collectives::protocol`] execute, while a seeded
//!    [`adversary`] perturbs delivery order (delays, duplicates, drops
//!    with retransmission, slow ranks) without breaking
//!    bit-reproducibility. Completed collectives are validated bitwise
//!    against the serial oracles in [`crate::dist_fft::verify`].

pub mod adversary;
pub mod collective_sim;
pub mod components;
pub mod compute;
pub mod engine;
pub mod fft_model;
pub mod sim;

pub use adversary::AdversaryConfig;
pub use collective_sim::{run_sim, run_sim_traced, SimCollective, SimConfig, SimData, SimRunReport};
pub use compute::ComputeModel;
pub use engine::{EngineStats, EventEngine};
pub use fft_model::{predict_fft, predict_pencil3, FftModelParams, Pencil3ModelParams};
pub use sim::{Action, Schedule, SimNet, SimReport};
