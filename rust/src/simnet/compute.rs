//! Compute-time model for the simulated cluster nodes.
//!
//! Durations for the FFT sweeps and chunk transposes on a simulated
//! "buran" node (2× EPYC 7352, 48 cores — the paper runs 24 worker
//! cores per locality). Calibrated from the native kernel's *measured*
//! single-core throughput on this machine, scaled by a configurable
//! factor; absolute times therefore track this testbed, while the
//! comm/compute ratio — which determines the figures' shapes — follows
//! the cost model.

use crate::fft::batch::measure_row_throughput;

/// Node compute-rate model.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Effective FFT throughput per core, FLOP/s (5·n·log2 n accounting).
    pub flops_per_core: f64,
    /// Worker cores per locality.
    pub cores: usize,
    /// Thread-scaling efficiency (memory-bound FFT sweeps do not scale
    /// linearly; 0.7 matches FFTW-on-EPYC folklore and our own
    /// `fft_rows_parallel` scaling measurements).
    pub parallel_efficiency: f64,
    /// Memory copy bandwidth for transpose/unpack work, GB/s.
    pub copy_gbps: f64,
}

impl ComputeModel {
    /// The paper's node: 24 cores per locality (one socket's worth).
    pub fn buran() -> Self {
        Self {
            // EPYC 7352 @2.3 GHz, single-core radix-2 f32 FFT ≈ 2 GFLOP/s
            // sustained (memory-bound at large n).
            flops_per_core: 2.0e9,
            cores: 24,
            parallel_efficiency: 0.7,
            copy_gbps: 12.0,
        }
    }

    /// Calibrate the per-core rate from the native kernel on *this*
    /// machine (used by `repro bench --calibrate`).
    pub fn calibrated(cores: usize) -> Self {
        let measured = measure_row_throughput(4096, 50);
        Self { flops_per_core: measured, cores, ..Self::buran() }
    }

    /// Time to FFT `rows` rows of length `len` with all cores, µs.
    pub fn fft_rows_us(&self, rows: usize, len: usize) -> f64 {
        if rows == 0 || len <= 1 {
            return 0.0;
        }
        let flops = 5.0 * (rows * len) as f64 * (len as f64).log2();
        let rate = self.flops_per_core * self.cores as f64 * self.parallel_efficiency;
        flops / rate * 1e6
    }

    /// Time to transpose/unpack `bytes` of chunk data, µs (memcpy-bound).
    pub fn transpose_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.copy_gbps / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buran_rates_sane() {
        let m = ComputeModel::buran();
        // One 16384-point row: 5·16384·14 ≈ 1.15 MFLOP; at 33.6 GFLOP/s
        // effective ≈ 34 µs... per-node it is trivially small; assert
        // scale only.
        let t = m.fft_rows_us(1, 16384);
        assert!(t > 1.0 && t < 1000.0, "{t}");
    }

    #[test]
    fn fft_time_scales_linearly_in_rows() {
        let m = ComputeModel::buran();
        let t1 = m.fft_rows_us(1024, 4096);
        let t2 = m.fft_rows_us(2048, 4096);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn transpose_is_bandwidth_bound() {
        let m = ComputeModel::buran();
        assert!((m.transpose_us(12_000_000_000 / 1000) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn zero_work_is_zero_time() {
        let m = ComputeModel::buran();
        assert_eq!(m.fft_rows_us(0, 1024), 0.0);
        assert_eq!(m.fft_rows_us(8, 1), 0.0);
        assert_eq!(m.transpose_us(0), 0.0);
    }

    #[test]
    fn calibrated_uses_positive_measurement() {
        let m = ComputeModel::calibrated(8);
        assert!(m.flops_per_core > 1e7, "{}", m.flops_per_core);
        assert_eq!(m.cores, 8);
    }
}
