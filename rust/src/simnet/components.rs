//! Simulated cluster components: virtual time, rank CPUs, NICs, and the
//! sized-or-bytes message body the protocol machines move through the
//! event engine.
//!
//! Virtual time is integer nanoseconds ([`Tick`]) so that event ordering
//! is exact — float timestamps would make heap order depend on rounding.
//! The conversion helpers round to the nearest nanosecond, which keeps
//! the engine's timings within 0.001 µs of the closed-form
//! [`crate::simnet::sim`] model they mirror.

use crate::collectives::protocol::Wire;
use crate::util::bytes::get_u64;

/// Virtual time in integer nanoseconds.
pub type Tick = u64;

/// Convert model microseconds to ticks (nearest nanosecond).
pub fn us_to_ticks(us: f64) -> Tick {
    (us * 1000.0).round() as Tick
}

/// Convert ticks back to microseconds for reporting.
pub fn ticks_to_us(t: Tick) -> f64 {
    t as f64 / 1000.0
}

/// Fold one value into a running trace hash (SplitMix64 finalizer).
/// Used to fingerprint the exact event sequence of a simulation run:
/// two runs are schedule-identical iff their folded hashes agree.
pub fn fold_hash(h: u64, v: u64) -> u64 {
    let mut x = h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// One simulated rank's CPU: a clock, blocked-time accounting, and the
/// adversary's slow-rank factor applied to every software charge.
#[derive(Clone, Copy, Debug)]
pub struct RankCpu {
    /// Current virtual time of this rank.
    pub now: Tick,
    /// Total time spent blocked waiting for arrivals.
    pub blocked: Tick,
    /// Software-cost multiplier (1.0 = nominal; the adversary marks
    /// slow ranks with a factor > 1).
    pub slow: f64,
}

impl RankCpu {
    /// A CPU at time zero with the given slow factor.
    pub fn new(slow: f64) -> Self {
        Self { now: 0, blocked: 0, slow }
    }

    /// Charge `us` microseconds of software time, scaled by the slow
    /// factor.
    pub fn charge_us(&mut self, us: f64) {
        self.now += us_to_ticks(us * self.slow);
    }

    /// Advance the clock to `t` if it is in the future, accounting the
    /// gap as blocked time.
    pub fn wait_until(&mut self, t: Tick) {
        if t > self.now {
            self.blocked += t - self.now;
            self.now = t;
        }
    }
}

/// One simulated rank's NIC: store-and-forward link ends. A transfer
/// holds the sender's egress and the receiver's ingress for its full
/// wire time — the contention model that penalizes incast (and that the
/// closed-form [`crate::simnet::sim`] engine charges identically).
#[derive(Clone, Copy, Debug, Default)]
pub struct Nic {
    /// Earliest tick the egress side is free.
    pub egress_free: Tick,
    /// Earliest tick the ingress side is free.
    pub ingress_free: Tick,
}

/// The message body protocol machines move through the simulator.
///
/// `Bytes` carries real data (oracle-validated fuzz runs); `Size`
/// carries only a byte count (cluster-scale timing runs, where 4096
/// ranks' worth of real buffers would be pointless). The framing
/// variants are symbolic — they keep the framed parts intact instead of
/// serializing them — but [`Wire::wire_len`] accounts for the exact
/// on-wire framing overhead, so simulated wire bytes match what the
/// live [`crate::hpx::parcel::Payload`] framing would transmit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimMsg {
    /// Real bytes.
    Bytes(Vec<u8>),
    /// A byte count only.
    Size(u64),
    /// An 8-byte chunked-transfer header carrying a total length.
    Header(u64),
    /// A [`Wire::frame_indexed`] frame (Bruck blocks).
    FramedIdx(Vec<(u32, SimMsg)>),
    /// A [`Wire::frame_list`] frame (root-funnel rows/columns).
    FramedList(Vec<SimMsg>),
}

impl SimMsg {
    /// The raw bytes of a `Bytes` message.
    ///
    /// # Panics
    /// If the message is sized-only or framed.
    pub fn into_bytes(self) -> Vec<u8> {
        match self {
            SimMsg::Bytes(b) => b,
            other => panic!("expected byte-carrying sim message, got {other:?}"),
        }
    }
}

impl Wire for SimMsg {
    fn empty() -> Self {
        SimMsg::Bytes(Vec::new())
    }

    fn wire_len(&self) -> usize {
        match self {
            SimMsg::Bytes(b) => b.len(),
            SimMsg::Size(s) => *s as usize,
            SimMsg::Header(_) => 8,
            // [count u32] + per block [index u32][len u64][bytes].
            SimMsg::FramedIdx(parts) => {
                4 + parts.iter().map(|(_, p)| 12 + p.wire_len()).sum::<usize>()
            }
            // [count u32] + per part [len u64][bytes].
            SimMsg::FramedList(parts) => 4 + parts.iter().map(|p| 8 + p.wire_len()).sum::<usize>(),
        }
    }

    fn slice(&self, off: usize, len: usize) -> Self {
        match self {
            SimMsg::Bytes(b) => SimMsg::Bytes(b[off..off + len].to_vec()),
            SimMsg::Size(_) => SimMsg::Size(len as u64),
            other => panic!("cannot slice framed sim message {other:?}"),
        }
    }

    fn concat(mut parts: Vec<Self>) -> Self {
        match parts.len() {
            0 => SimMsg::Bytes(Vec::new()),
            1 => parts.pop().expect("one part"),
            _ => {
                if parts.iter().all(|p| matches!(p, SimMsg::Bytes(_))) {
                    let mut buf = Vec::new();
                    for p in parts {
                        buf.extend_from_slice(match &p {
                            SimMsg::Bytes(b) => b,
                            _ => unreachable!(),
                        });
                    }
                    SimMsg::Bytes(buf)
                } else {
                    SimMsg::Size(parts.iter().map(|p| p.wire_len() as u64).sum())
                }
            }
        }
    }

    fn header(total: u64) -> Self {
        SimMsg::Header(total)
    }

    fn header_total(&self) -> u64 {
        match self {
            SimMsg::Header(t) => *t,
            SimMsg::Bytes(b) => {
                let mut off = 0;
                get_u64(b, &mut off)
            }
            other => panic!("no header total in {other:?}"),
        }
    }

    fn frame_indexed(blocks: &[(u32, Self)]) -> Self {
        SimMsg::FramedIdx(blocks.to_vec())
    }

    fn unframe_indexed(&self) -> Vec<(u32, Self)> {
        match self {
            SimMsg::FramedIdx(parts) => parts.clone(),
            other => panic!("not an indexed frame: {other:?}"),
        }
    }

    fn frame_list(parts: &[Self]) -> Self {
        SimMsg::FramedList(parts.to_vec())
    }

    fn unframe_list(&self) -> Vec<Self> {
        match self {
            SimMsg::FramedList(parts) => parts.clone(),
            other => panic!("not a list frame: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_conversion_roundtrips() {
        assert_eq!(us_to_ticks(1.5), 1500);
        assert_eq!(us_to_ticks(0.0), 0);
        assert!((ticks_to_us(us_to_ticks(41.94)) - 41.94).abs() < 1e-3);
    }

    #[test]
    fn wire_len_matches_live_framing_overhead() {
        // Live Bruck framing: 4 (count) + per block 4 (index) + 8 (len)
        // + payload.
        let framed = SimMsg::frame_indexed(&[
            (0, SimMsg::Size(100)),
            (2, SimMsg::Bytes(vec![1, 2, 3])),
        ]);
        assert_eq!(framed.wire_len(), 4 + (12 + 100) + (12 + 3));
        // Live row framing: 4 (count) + per part 8 (len) + payload.
        let listed = SimMsg::frame_list(&[SimMsg::Size(10), SimMsg::Size(20)]);
        assert_eq!(listed.wire_len(), 4 + (8 + 10) + (8 + 20));
        assert_eq!(SimMsg::header(7).wire_len(), 8);
    }

    #[test]
    fn sized_messages_slice_and_concat_arithmetically() {
        let m = SimMsg::Size(100);
        assert_eq!(m.slice(64, 36).wire_len(), 36);
        let back = SimMsg::concat(vec![SimMsg::Size(64), SimMsg::Size(36)]);
        assert_eq!(back.wire_len(), 100);
    }

    #[test]
    fn byte_messages_concat_exactly() {
        let whole = SimMsg::Bytes((0u8..50).collect());
        let parts: Vec<SimMsg> = (0..5).map(|i| whole.slice(i * 10, 10)).collect();
        assert_eq!(SimMsg::concat(parts), whole);
    }

    #[test]
    fn slow_rank_scales_charges() {
        let mut nominal = RankCpu::new(1.0);
        let mut slow = RankCpu::new(3.0);
        nominal.charge_us(10.0);
        slow.charge_us(10.0);
        assert_eq!(nominal.now, 10_000);
        assert_eq!(slow.now, 30_000);
        slow.wait_until(35_000);
        assert_eq!(slow.blocked, 5_000);
        slow.wait_until(10_000); // past: no-op
        assert_eq!(slow.now, 35_000);
    }

    #[test]
    fn fold_hash_is_order_sensitive() {
        let a = fold_hash(fold_hash(0, 1), 2);
        let b = fold_hash(fold_hash(0, 2), 1);
        assert_ne!(a, b);
        assert_eq!(a, fold_hash(fold_hash(0, 1), 2));
    }
}
