//! Run the real collective protocol machines on the simulated fabric.
//!
//! This is the tentpole of the simulator: the **same**
//! [`crate::collectives::protocol`] state machines that the live
//! communicator drives over TCP/MPI/LCI fabrics are scheduled here over
//! [`crate::simnet::engine::EventEngine`] NICs instead. Each simulated
//! rank owns one machine and a per-`(src, tag)` mailbox; sends become
//! engine events, receives park the machine until the matching delivery
//! pops, and the adversary's delays/reorders/faults exercise protocol
//! interleavings a real 4-rank test run can never reach — at 4096
//! simulated localities if asked.
//!
//! Tag allocation replicates the live communicator's per-rank counter
//! (see [`crate::collectives::tags::collective_span`]): every simulated
//! collective consumes exactly the spans the live one would, which is
//! asserted by the fuzz matrix's tag-teardown checks.
//!
//! In [`SimData::Bytes`] mode the machines move real bytes and the
//! result is validated bitwise against the serial oracles in
//! [`crate::dist_fft::verify`]; in [`SimData::Uniform`] mode only sizes
//! flow, which is what the cluster-scale benchmark harness uses.

use std::collections::{BTreeMap, VecDeque};

use super::adversary::AdversaryConfig;
use super::components::{SimMsg, Tick};
use super::engine::{EngineStats, EventEngine};
use crate::collectives::protocol::{
    Action, BruckA2a, HpxRootA2a, LinearA2a, LinearScatter, Machine, NScatter, PairwiseA2a,
    PairwiseChunkedA2a, PipelinedScatter,
};
use crate::collectives::tags::{collective_span, CHUNK_TAG_SPAN};
use crate::collectives::{AllToAllAlgo, ChunkPolicy, ScatterAlgo};
use crate::hpx::parcel::Tag;
use crate::parcelport::{NetModel, PortKind};
use crate::util::rng::Pcg32;

/// Which collective to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimCollective {
    /// All-to-all with the given live algorithm.
    AllToAll(AllToAllAlgo),
    /// Root-0 scatter with the given live algorithm.
    Scatter(ScatterAlgo),
    /// The paper's N-scatter: every rank roots one pipelined scatter
    /// and drains the other `n - 1` concurrently.
    NScatter,
}

impl SimCollective {
    /// Every simulatable collective (the fuzz matrix iterates this).
    pub fn all() -> Vec<SimCollective> {
        let mut v: Vec<SimCollective> =
            AllToAllAlgo::ALL.iter().map(|&a| SimCollective::AllToAll(a)).collect();
        v.push(SimCollective::Scatter(ScatterAlgo::Linear));
        v.push(SimCollective::Scatter(ScatterAlgo::Pipelined));
        v.push(SimCollective::NScatter);
        v
    }
}

/// What the machines carry.
#[derive(Clone, Debug)]
pub enum SimData {
    /// Real per-pair buffers, indexed `[src][dst]`; outputs are
    /// reassembled and oracle-checkable.
    Bytes(Vec<Vec<Vec<u8>>>),
    /// Sized-only messages of this many bytes per pair (timing runs).
    Uniform(u64),
}

/// One simulated collective run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Number of simulated ranks.
    pub localities: usize,
    /// Port whose cost model the engine charges.
    pub port: PortKind,
    /// Wire model.
    pub net: NetModel,
    /// Chunk policy for the chunked protocols.
    pub policy: ChunkPolicy,
    /// Seeded schedule perturbations.
    pub adversary: AdversaryConfig,
    /// Which collective to run.
    pub collective: SimCollective,
    /// What flows through it.
    pub data: SimData,
}

/// Result of a simulated collective.
#[derive(Clone, Debug)]
pub struct SimRunReport {
    /// Engine counters and the schedule fingerprint.
    pub stats: EngineStats,
    /// Per-rank, per-source reassembled bytes ([`SimData::Bytes`] runs
    /// only). For scatters each rank has a single entry: its chunk.
    pub outputs: Option<Vec<Vec<Vec<u8>>>>,
    /// Where the replica tag allocator ended — must equal the live
    /// communicator's consumption for the same collective.
    pub final_tag: Tag,
}

/// Replica of the live communicator's per-rank tag counter. All ranks
/// allocate in lock-step, so one counter serves the whole simulation.
struct TagAlloc {
    next: Tag,
}

impl TagAlloc {
    fn collective(&mut self, size: usize) -> Tag {
        let t = self.next;
        self.next += collective_span(size);
        t
    }

    fn chunk(&mut self, groups: usize) -> Tag {
        let t = self.next;
        self.next += groups as Tag * CHUNK_TAG_SPAN;
        t
    }
}

/// Closed set of machine types the simulator can schedule.
enum AnyMachine {
    Linear(LinearA2a<SimMsg>),
    Pairwise(PairwiseA2a<SimMsg>),
    Bruck(BruckA2a<SimMsg>),
    HpxRoot(HpxRootA2a<SimMsg>),
    PairwiseChunked(PairwiseChunkedA2a<SimMsg>),
    LinearScatter(LinearScatter<SimMsg>),
    PipelinedScatter(PipelinedScatter<SimMsg>),
    NScatter(NScatter<SimMsg>),
}

impl AnyMachine {
    fn step(&mut self) -> Action<SimMsg> {
        match self {
            AnyMachine::Linear(m) => m.step(),
            AnyMachine::Pairwise(m) => m.step(),
            AnyMachine::Bruck(m) => m.step(),
            AnyMachine::HpxRoot(m) => m.step(),
            AnyMachine::PairwiseChunked(m) => m.step(),
            AnyMachine::LinearScatter(m) => m.step(),
            AnyMachine::PipelinedScatter(m) => m.step(),
            AnyMachine::NScatter(m) => m.step(),
        }
    }

    fn deliver(&mut self, from: usize, tag: Tag, msg: SimMsg) {
        match self {
            AnyMachine::Linear(m) => m.deliver(from, tag, msg),
            AnyMachine::Pairwise(m) => m.deliver(from, tag, msg),
            AnyMachine::Bruck(m) => m.deliver(from, tag, msg),
            AnyMachine::HpxRoot(m) => m.deliver(from, tag, msg),
            AnyMachine::PairwiseChunked(m) => m.deliver(from, tag, msg),
            AnyMachine::LinearScatter(m) => m.deliver(from, tag, msg),
            AnyMachine::PipelinedScatter(m) => m.deliver(from, tag, msg),
            AnyMachine::NScatter(m) => m.deliver(from, tag, msg),
        }
    }

    /// Per-source outputs. Chunk-streaming machines return nothing here
    /// (their data surfaced as [`Action::Chunk`]); scatters return a
    /// single entry.
    fn finish(self) -> Vec<SimMsg> {
        match self {
            AnyMachine::Linear(m) => m.finish(),
            AnyMachine::Pairwise(m) => m.finish(),
            AnyMachine::Bruck(m) => m.finish(),
            AnyMachine::HpxRoot(m) => m.finish(),
            AnyMachine::PairwiseChunked(m) => {
                m.finish();
                Vec::new()
            }
            AnyMachine::LinearScatter(m) => vec![m.finish()],
            AnyMachine::PipelinedScatter(m) => vec![m.finish()],
            AnyMachine::NScatter(m) => {
                m.finish();
                Vec::new()
            }
        }
    }
}

/// One simulated rank: its machine (until done), mailbox, and streamed
/// chunk parts.
struct RankSlot {
    sm: Option<AnyMachine>,
    mailbox: BTreeMap<(usize, Tag), VecDeque<(SimMsg, Tick)>>,
    /// Per source rank: `(byte offset, chunk)` as emitted by
    /// [`Action::Chunk`].
    parts: Vec<Vec<(usize, SimMsg)>>,
    outputs: Option<Vec<SimMsg>>,
}

impl RankSlot {
    fn new(sm: AnyMachine, n: usize) -> Self {
        Self {
            sm: Some(sm),
            mailbox: BTreeMap::new(),
            parts: (0..n).map(|_| Vec::new()).collect(),
            outputs: None,
        }
    }

    fn pop_mail(&mut self, from: usize, tag: Tag) -> Option<(SimMsg, Tick)> {
        let queue = self.mailbox.get_mut(&(from, tag))?;
        let got = queue.pop_front();
        if queue.is_empty() {
            self.mailbox.remove(&(from, tag));
        }
        got
    }
}

fn rank_row(data: &SimData, rank: usize, n: usize) -> Vec<SimMsg> {
    match data {
        SimData::Bytes(m) => m[rank].iter().map(|b| SimMsg::Bytes(b.clone())).collect(),
        SimData::Uniform(s) => vec![SimMsg::Size(*s); n],
    }
}

fn build_machines(cfg: &SimConfig, alloc: &mut TagAlloc) -> Vec<AnyMachine> {
    let n = cfg.localities;
    let row = |me: usize| rank_row(&cfg.data, me, n);
    match cfg.collective {
        SimCollective::AllToAll(AllToAllAlgo::Linear) => {
            let tag = alloc.collective(n);
            (0..n).map(|me| AnyMachine::Linear(LinearA2a::new(me, n, tag, row(me)))).collect()
        }
        SimCollective::AllToAll(AllToAllAlgo::Pairwise) => {
            let tag = alloc.collective(n);
            (0..n).map(|me| AnyMachine::Pairwise(PairwiseA2a::new(me, n, tag, row(me)))).collect()
        }
        SimCollective::AllToAll(AllToAllAlgo::Bruck) => {
            let tag = alloc.collective(n);
            (0..n).map(|me| AnyMachine::Bruck(BruckA2a::new(me, n, tag, row(me)))).collect()
        }
        SimCollective::AllToAll(AllToAllAlgo::HpxRoot) => {
            // Two spans, gather then scatter — same as the live path.
            let gather = alloc.collective(n);
            let scatter = alloc.collective(n);
            (0..n)
                .map(|me| AnyMachine::HpxRoot(HpxRootA2a::new(me, n, gather, scatter, row(me))))
                .collect()
        }
        SimCollective::AllToAll(AllToAllAlgo::PairwiseChunked) => {
            let base = alloc.chunk(n);
            (0..n)
                .map(|me| {
                    AnyMachine::PairwiseChunked(PairwiseChunkedA2a::new(
                        me,
                        n,
                        base,
                        cfg.policy,
                        row(me),
                    ))
                })
                .collect()
        }
        SimCollective::Scatter(ScatterAlgo::Linear) => {
            let tag = alloc.collective(n);
            (0..n)
                .map(|me| {
                    let chunks = (me == 0).then(|| row(0));
                    AnyMachine::LinearScatter(LinearScatter::new(0, me, n, tag, chunks))
                })
                .collect()
        }
        SimCollective::Scatter(ScatterAlgo::Pipelined) => {
            let tag = alloc.chunk(1);
            (0..n)
                .map(|me| {
                    let chunks = (me == 0).then(|| row(0));
                    let sm = PipelinedScatter::new(0, me, n, tag, cfg.policy, chunks);
                    AnyMachine::PipelinedScatter(sm)
                })
                .collect()
        }
        SimCollective::NScatter => {
            let base = alloc.chunk(n);
            (0..n)
                .map(|me| AnyMachine::NScatter(NScatter::new(me, n, base, cfg.policy, row(me))))
                .collect()
        }
    }
}

/// Step `rank`'s machine until it parks on an unsatisfied receive or
/// finishes.
fn run_rank(engine: &mut EventEngine, slots: &mut [RankSlot], rank: usize) {
    loop {
        let Some(sm) = slots[rank].sm.as_mut() else { return };
        match sm.step() {
            Action::Send { to, tag, msg, .. } => engine.post_send(rank, to, tag, msg),
            Action::Recv { from, tag } => {
                let Some((msg, tick)) = slots[rank].pop_mail(from, tag) else { return };
                engine.consume(rank, tick);
                slots[rank].sm.as_mut().expect("machine present").deliver(from, tag, msg);
            }
            Action::RecvAny(want) => {
                let mut hit = None;
                for (from, tag) in want {
                    if let Some((msg, tick)) = slots[rank].pop_mail(from, tag) {
                        hit = Some((from, tag, msg, tick));
                        break;
                    }
                }
                let Some((from, tag, msg, tick)) = hit else { return };
                engine.consume(rank, tick);
                slots[rank].sm.as_mut().expect("machine present").deliver(from, tag, msg);
            }
            Action::Chunk { src, off, msg } => slots[rank].parts[src].push((off, msg)),
            Action::Done => {
                let sm = slots[rank].sm.take().expect("machine present");
                slots[rank].outputs = Some(sm.finish());
                return;
            }
        }
    }
}

/// Drive every machine to completion over the engine.
///
/// # Panics
/// With a message containing `"deadlock"` if the fabric drains while
/// some machine still waits, and if any rank finishes with unconsumed
/// mailbox messages (a tag-space leak).
fn drive_all(engine: &mut EventEngine, slots: &mut [RankSlot]) {
    for rank in 0..slots.len() {
        run_rank(engine, slots, rank);
    }
    while let Some(d) = engine.next_delivery() {
        let dst = d.msg.dst;
        let key = (d.msg.src, d.msg.tag);
        slots[dst].mailbox.entry(key).or_default().push_back((d.msg.msg, d.tick));
        run_rank(engine, slots, dst);
    }

    let stalled: Vec<usize> =
        slots.iter().enumerate().filter(|(_, s)| s.sm.is_some()).map(|(r, _)| r).collect();
    assert!(
        stalled.is_empty(),
        "simulated collective deadlock: fabric drained with ranks {stalled:?} still waiting"
    );
    for (rank, slot) in slots.iter().enumerate() {
        let leftover: usize = slot.mailbox.values().map(VecDeque::len).sum();
        assert_eq!(leftover, 0, "rank {rank} finished with {leftover} unconsumed message(s)");
    }
}

fn assemble(slot: &mut RankSlot) -> Vec<Vec<u8>> {
    let outs = slot.outputs.take().expect("finished rank");
    if !outs.is_empty() {
        return outs.into_iter().map(SimMsg::into_bytes).collect();
    }
    // Chunk-streaming machine: order each source's parts by offset and
    // concatenate — the simulator-side equivalent of the live
    // transpose-on-arrival callback.
    let mut result = Vec::with_capacity(slot.parts.len());
    for src_parts in &mut slot.parts {
        src_parts.sort_by_key(|(off, _)| *off);
        let mut buf = Vec::new();
        for (_, m) in src_parts.drain(..) {
            buf.extend_from_slice(&m.into_bytes());
        }
        result.push(buf);
    }
    result
}

/// Simulate one collective to completion.
///
/// Bit-reproducible: the same `cfg` (including the adversary seed)
/// yields the same [`SimRunReport`], trace hash included.
///
/// # Panics
/// On deadlock (message contains `"deadlock"`) or unconsumed messages
/// at teardown — both indicate a protocol bug, which is exactly what
/// the fuzz matrix hunts.
pub fn run_sim(cfg: &SimConfig) -> SimRunReport {
    run_sim_impl(cfg, false).0
}

/// Simulate one collective and capture its wire timeline as
/// [`crate::obs::Event`]s (simulated ticks are nanoseconds, so the
/// capture exports through [`crate::obs::chrome`] exactly like a live
/// trace: pid = simulated rank, one `wire` span per transmission,
/// `arrival` instants at the destinations).
///
/// The capture is a pure observer — the returned report is
/// bit-identical to [`run_sim`]'s for the same `cfg`, trace hash
/// included (asserted by the engine's perturbation test).
///
/// # Panics
/// As [`run_sim`].
pub fn run_sim_traced(cfg: &SimConfig) -> (SimRunReport, Vec<crate::obs::Event>) {
    run_sim_impl(cfg, true)
}

fn run_sim_impl(cfg: &SimConfig, trace: bool) -> (SimRunReport, Vec<crate::obs::Event>) {
    let n = cfg.localities;
    assert!(n > 0, "need at least one locality");
    if let SimData::Bytes(m) = &cfg.data {
        assert_eq!(m.len(), n, "need one row per rank");
        for row in m {
            assert_eq!(row.len(), n, "need one buffer per peer");
        }
    }

    let mut engine = EventEngine::new(n, cfg.net, cfg.port.cost_model(), cfg.adversary);
    if trace {
        engine.enable_trace();
    }
    let mut alloc = TagAlloc { next: 0 };
    let machines = build_machines(cfg, &mut alloc);
    let mut slots: Vec<RankSlot> = machines.into_iter().map(|sm| RankSlot::new(sm, n)).collect();

    drive_all(&mut engine, &mut slots);

    let outputs = match &cfg.data {
        SimData::Bytes(_) => Some(slots.iter_mut().map(assemble).collect()),
        SimData::Uniform(_) => None,
    };
    let events = engine.take_trace();
    (SimRunReport { stats: engine.stats(), outputs, final_tag: alloc.next }, events)
}

/// Deterministic random `[src][dst]` buffers for fuzz runs: lengths in
/// `0..=max_len` (empties included on purpose), contents keyed by
/// `(seed, src, dst)` only.
pub fn random_matrix(seed: u64, n: usize, max_len: usize) -> Vec<Vec<Vec<u8>>> {
    (0..n)
        .map(|src| {
            (0..n)
                .map(|dst| {
                    let mut rng = Pcg32::with_stream(seed, (src * n + dst) as u64);
                    let len = rng.next_below(max_len as u32 + 1) as usize;
                    (0..len).map(|_| rng.next_u32() as u8).collect()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::verify::{oracle_all_to_all, oracle_scatter};

    fn cfg(collective: SimCollective, port: PortKind, n: usize, seed: u64) -> SimConfig {
        SimConfig {
            localities: n,
            port,
            net: NetModel::infiniband_hdr(),
            policy: ChunkPolicy::new(7, 3),
            adversary: AdversaryConfig::hostile(seed),
            collective,
            data: SimData::Bytes(random_matrix(seed ^ 0xDA7A_F00D, n, 23)),
        }
    }

    fn expected(c: SimCollective, data: &SimData) -> Vec<Vec<Vec<u8>>> {
        let SimData::Bytes(m) = data else { panic!("bytes mode") };
        match c {
            SimCollective::AllToAll(_) | SimCollective::NScatter => oracle_all_to_all(m),
            SimCollective::Scatter(_) => oracle_scatter(&m[0]),
        }
    }

    fn fuzz_one(collective: SimCollective, port: PortKind, n: usize, seed: u64) {
        let c = cfg(collective, port, n, seed);
        let report = run_sim(&c);
        let got = report.outputs.expect("bytes mode");
        let want = expected(collective, &c.data);
        assert_eq!(
            got, want,
            "FAILING SEED {seed}: {collective:?} over {port} n={n} diverged from oracle"
        );
    }

    /// Tier-1 smoke slice of the fuzz matrix: 50 hostile seeds across
    /// every machine on two ports at a non-power-of-two size. The
    /// failing seed is printed by the assert for replay.
    #[test]
    fn seed_fuzz_smoke_50() {
        for seed in 0..50u64 {
            for collective in SimCollective::all() {
                for port in [PortKind::Lci, PortKind::Mpi] {
                    fuzz_one(collective, port, 5, seed);
                }
            }
        }
    }

    /// The full satellite matrix: 200 seeds × every collective × every
    /// port × two non-power-of-two sizes. Run explicitly with
    /// `cargo test --release seed_fuzz_full -- --ignored`.
    #[test]
    #[ignore = "full 200-seed matrix; run with --ignored"]
    fn seed_fuzz_full_200() {
        for seed in 0..200u64 {
            for collective in SimCollective::all() {
                for port in PortKind::ALL {
                    for n in [5usize, 7] {
                        fuzz_one(collective, port, n, seed);
                    }
                }
            }
        }
    }

    /// Satellite regression: the same seed and config reproduce the
    /// identical event trace (hash) and counters, twice.
    #[test]
    fn determinism_same_seed_same_trace() {
        for collective in SimCollective::all() {
            let a = run_sim(&cfg(collective, PortKind::Mpi, 6, 42));
            let b = run_sim(&cfg(collective, PortKind::Mpi, 6, 42));
            assert_eq!(a.stats, b.stats, "{collective:?} not reproducible");
            assert_eq!(a.outputs, b.outputs);
            let c = run_sim(&cfg(collective, PortKind::Mpi, 6, 43));
            assert_ne!(
                a.stats.trace_hash, c.stats.trace_hash,
                "{collective:?} trace hash ignores the seed"
            );
        }
    }

    /// The replica tag allocator must consume exactly what the live
    /// communicator's counter would for each collective.
    #[test]
    fn tag_spans_match_live_allocation() {
        let n = 5usize;
        let span = collective_span(n);
        let cases = [
            (SimCollective::AllToAll(AllToAllAlgo::Linear), span),
            (SimCollective::AllToAll(AllToAllAlgo::Pairwise), span),
            (SimCollective::AllToAll(AllToAllAlgo::Bruck), span),
            (SimCollective::AllToAll(AllToAllAlgo::HpxRoot), 2 * span),
            (SimCollective::AllToAll(AllToAllAlgo::PairwiseChunked), n as Tag * CHUNK_TAG_SPAN),
            (SimCollective::Scatter(ScatterAlgo::Linear), span),
            (SimCollective::Scatter(ScatterAlgo::Pipelined), CHUNK_TAG_SPAN),
            (SimCollective::NScatter, n as Tag * CHUNK_TAG_SPAN),
        ];
        for (collective, want) in cases {
            let report = run_sim(&cfg(collective, PortKind::Lci, n, 1));
            assert_eq!(report.final_tag, want, "{collective:?}");
        }
    }

    /// The traced entry point is a pure observer over the same run:
    /// identical stats and outputs, plus a non-empty wire timeline.
    #[test]
    fn traced_run_matches_untraced_and_captures_wire_spans() {
        let c = cfg(SimCollective::AllToAll(AllToAllAlgo::Pairwise), PortKind::Lci, 6, 9);
        let plain = run_sim(&c);
        let (traced, events) = run_sim_traced(&c);
        assert_eq!(plain.stats, traced.stats, "capture must not perturb the schedule");
        assert_eq!(plain.outputs, traced.outputs);
        assert!(events.iter().any(|e| e.is_span()), "a 6-rank all-to-all must cross the wire");
        let traced_bytes: u64 = events.iter().filter(|e| e.is_span()).map(|e| e.bytes as u64).sum();
        assert_eq!(traced_bytes, plain.stats.wire_bytes);
    }

    /// A benign single-rank run degenerates to local hand-off.
    #[test]
    fn single_rank_runs_locally() {
        for collective in SimCollective::all() {
            let mut c = cfg(collective, PortKind::Lci, 1, 0);
            c.adversary = AdversaryConfig::none(0);
            let report = run_sim(&c);
            assert_eq!(report.stats.wire_bytes, 0, "{collective:?}");
            let SimData::Bytes(m) = &c.data else { unreachable!() };
            assert_eq!(report.outputs.unwrap(), vec![vec![m[0][0].clone()]]);
        }
    }

    /// Fault accounting reaches the report: hostile runs with drops
    /// must show retransmissions, and their recovered outputs still
    /// match the oracle (covered by the fuzz assert inside).
    #[test]
    fn faults_are_accounted_and_recovered() {
        let mut saw_retransmit = false;
        let mut saw_dup = false;
        for seed in 0..20u64 {
            let c = cfg(SimCollective::AllToAll(AllToAllAlgo::Pairwise), PortKind::Lci, 6, seed);
            let report = run_sim(&c);
            saw_retransmit |= report.stats.retransmitted_bytes > 0;
            saw_dup |= report.stats.duplicates_dropped > 0;
            assert_eq!(report.outputs.unwrap(), expected(c.collective, &c.data));
        }
        assert!(saw_retransmit, "20 hostile seeds never dropped a message");
        assert!(saw_dup, "20 hostile seeds never duplicated a message");
    }

    /// The executor's deadlock detector fires (message contains
    /// "deadlock") when a machine waits for a message no one sends —
    /// here forced by driving a 2-rank machine against a 1-rank peer
    /// set.
    #[test]
    #[should_panic(expected = "deadlock")]
    fn drained_fabric_with_waiting_machine_is_a_deadlock() {
        let mut engine = EventEngine::new(
            2,
            NetModel::infiniband_hdr(),
            PortKind::Lci.cost_model(),
            AdversaryConfig::none(0),
        );
        let row = vec![SimMsg::Size(8), SimMsg::Size(8)];
        let starved = AnyMachine::Linear(LinearA2a::new(0, 2, 0, row));
        // Rank 1 finishes immediately without ever sending to rank 0 (a
        // single-rank scatter hands its chunk over locally).
        let own = Some(vec![SimMsg::Size(1)]);
        let mute = AnyMachine::LinearScatter(LinearScatter::new(0, 0, 1, 0, own));
        let mut slots = vec![RankSlot::new(starved, 2), RankSlot::new(mute, 2)];
        drive_all(&mut engine, &mut slots);
    }
}
