//! The discrete-event engine: sequential per-node schedules over shared
//! NIC resources.
//!
//! Nodes execute straight-line action lists. The engine advances
//! whichever node can make progress; a `Recv` blocks until the matching
//! message has been *sent* (its arrival time computed), which the
//! round-robin progress loop resolves in dependency order. Determinism:
//! no randomness anywhere — identical inputs give identical timelines.

use crate::parcelport::{CostModel, NetModel};
use std::collections::HashMap;

/// One step of a node's schedule.
#[derive(Clone, Debug)]
pub enum Action {
    /// Busy CPU for `us` microseconds (FFT sweep, chunk transpose, ...).
    Compute { us: f64, label: &'static str },
    /// Post a message (non-blocking, like the live ports).
    Send { dst: usize, size: u64, tag: u64 },
    /// Block until the matching message arrives, then pay receive-side
    /// software cost.
    Recv { src: usize, tag: u64 },
}

/// Per-node action list.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// The node's straight-line program, executed in order.
    pub actions: Vec<Action>,
}

impl Schedule {
    /// Append a compute step of `us` microseconds.
    pub fn compute(&mut self, us: f64, label: &'static str) -> &mut Self {
        self.actions.push(Action::Compute { us, label });
        self
    }

    /// Append a non-blocking send.
    pub fn send(&mut self, dst: usize, size: u64, tag: u64) -> &mut Self {
        self.actions.push(Action::Send { dst, size, tag });
        self
    }

    /// Append a blocking matched receive.
    pub fn recv(&mut self, src: usize, tag: u64) -> &mut Self {
        self.actions.push(Action::Recv { src, tag });
        self
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Per-node completion time, µs.
    pub node_finish_us: Vec<f64>,
    /// max over nodes — the benchmark's reported runtime.
    pub makespan_us: f64,
    /// Total bytes that crossed the wire.
    pub wire_bytes: u64,
    /// Time each node spent blocked in `Recv`, µs (comm visibility).
    pub node_blocked_us: Vec<f64>,
}

/// The simulated fabric.
pub struct SimNet {
    /// Wire model (α + s/β postal model).
    pub net: NetModel,
    /// Per-message software cost model.
    pub cost: CostModel,
}

impl SimNet {
    /// Fabric from a wire model and a port cost model.
    pub fn new(net: NetModel, cost: CostModel) -> Self {
        Self { net, cost }
    }

    /// Run one schedule per node to completion.
    ///
    /// # Panics
    /// If the schedules deadlock (a `Recv` whose `Send` never happens).
    pub fn run(&self, schedules: &[Schedule]) -> SimReport {
        let n = schedules.len();
        let mut node_clock = vec![0.0f64; n];
        let mut node_blocked = vec![0.0f64; n];
        let mut pc = vec![0usize; n]; // program counter per node
        let mut egress_free = vec![0.0f64; n];
        let mut ingress_free = vec![0.0f64; n];
        // (dst, src, tag) → arrival time.
        let mut arrivals: HashMap<(usize, usize, u64), f64> = HashMap::new();
        let mut wire_bytes = 0u64;

        let sw_half = |size: u64| self.cost.sw_time_us(size) / 2.0;

        loop {
            let mut progressed = false;
            let mut all_done = true;
            for node in 0..n {
                let sched = &schedules[node].actions;
                // Advance this node as far as possible.
                while pc[node] < sched.len() {
                    all_done = false;
                    match &sched[pc[node]] {
                        Action::Compute { us, .. } => {
                            node_clock[node] += us;
                            pc[node] += 1;
                            progressed = true;
                        }
                        Action::Send { dst, size, tag } => {
                            let (dst, size, tag) = (*dst, *size, *tag);
                            // CPU-side software cost of posting the send.
                            node_clock[node] += sw_half(size);
                            if dst == node {
                                // Self-delivery: a local copy, no wire.
                                arrivals.insert((dst, node, tag), node_clock[node]);
                            } else {
                                // Rendezvous handshake delays wire entry
                                // by the protocol RTTs.
                                let hs = if self.cost.is_rendezvous(size) {
                                    self.cost.rendezvous_rtts as f64 * 2.0 * self.net.alpha_us
                                } else {
                                    0.0
                                };
                                // Store-and-forward: the transfer holds
                                // both NICs for size/β.
                                let ready = node_clock[node] + hs;
                                let start =
                                    ready.max(egress_free[node]).max(ingress_free[dst]);
                                let trans = size as f64 / self.net.beta_gbps / 1e3;
                                let end = start + trans;
                                egress_free[node] = end;
                                ingress_free[dst] = end;
                                arrivals.insert((dst, node, tag), end + self.net.alpha_us);
                                wire_bytes += size;
                            }
                            pc[node] += 1;
                            progressed = true;
                        }
                        Action::Recv { src, tag } => {
                            if let Some(&arrival) = arrivals.get(&(node, *src, *tag)) {
                                if arrival > node_clock[node] {
                                    node_blocked[node] += arrival - node_clock[node];
                                    node_clock[node] = arrival;
                                }
                                // Receive-side software cost. The size is
                                // unknown here; the sender charged its
                                // half — charge the fixed overhead half.
                                node_clock[node] += self.cost.sw_overhead_us / 2.0;
                                arrivals.remove(&(node, *src, *tag));
                                pc[node] += 1;
                                progressed = true;
                            } else {
                                break; // blocked: try other nodes
                            }
                        }
                    }
                }
            }
            if all_done {
                break;
            }
            assert!(
                progressed,
                "simnet deadlock: every node blocked in Recv; pcs = {pc:?}"
            );
        }

        let makespan = node_clock.iter().copied().fold(0.0, f64::max);
        SimReport {
            node_finish_us: node_clock,
            makespan_us: makespan,
            wire_bytes,
            node_blocked_us: node_blocked,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parcelport::PortKind;

    fn net() -> NetModel {
        NetModel::infiniband_hdr()
    }

    fn sim(kind: PortKind) -> SimNet {
        SimNet::new(net(), kind.cost_model())
    }

    #[test]
    fn single_message_closed_form() {
        let s = sim(PortKind::Lci);
        let mut a = Schedule::default();
        a.send(1, 1 << 20, 0);
        let mut b = Schedule::default();
        b.recv(0, 0);
        let report = s.run(&[a, b]);
        // sender half sw + wire + α + receiver half overhead.
        let cost = PortKind::Lci.cost_model();
        let expect = cost.sw_time_us(1 << 20) / 2.0
            + (1u64 << 20) as f64 / net().beta_gbps / 1e3
            + net().alpha_us
            + cost.sw_overhead_us / 2.0;
        assert!(
            (report.makespan_us - expect).abs() < 1e-6,
            "got {} expect {expect}",
            report.makespan_us
        );
        assert_eq!(report.wire_bytes, 1 << 20);
    }

    #[test]
    fn compute_only_sums() {
        let s = sim(PortKind::Lci);
        let mut a = Schedule::default();
        a.compute(10.0, "x").compute(15.0, "y");
        let report = s.run(&[a]);
        assert_eq!(report.makespan_us, 25.0);
    }

    #[test]
    fn egress_serializes_fanout() {
        // One node sending k messages back-to-back: wire times add up on
        // its egress even though receivers are distinct.
        let s = sim(PortKind::Lci);
        let k = 4;
        let size = 1u64 << 20;
        let mut root = Schedule::default();
        for dst in 1..=k {
            root.send(dst, size, dst as u64);
        }
        let mut scheds = vec![root];
        for dst in 1..=k {
            let mut r = Schedule::default();
            r.recv(0, dst as u64);
            scheds.push(r);
        }
        let report = s.run(&scheds);
        let wire_each = size as f64 / net().beta_gbps / 1e3;
        assert!(
            report.makespan_us >= k as f64 * wire_each,
            "fanout must serialize: {} < {}",
            report.makespan_us,
            k as f64 * wire_each
        );
    }

    #[test]
    fn ingress_serializes_incast() {
        // k nodes sending to one: the receiver NIC is the bottleneck.
        let s = sim(PortKind::Lci);
        let k = 4;
        let size = 1u64 << 20;
        let mut scheds: Vec<Schedule> = (0..=k)
            .map(|node| {
                let mut sch = Schedule::default();
                if node > 0 {
                    sch.send(0, size, node as u64);
                }
                sch
            })
            .collect();
        for srcnode in 1..=k {
            scheds[0].recv(srcnode, srcnode as u64);
        }
        let report = s.run(&scheds);
        let wire_each = size as f64 / net().beta_gbps / 1e3;
        assert!(report.makespan_us >= k as f64 * wire_each);
    }

    #[test]
    fn disjoint_pairs_run_in_parallel() {
        // (0→1) and (2→3) share nothing: makespan ≈ one message.
        let s = sim(PortKind::Lci);
        let size = 1u64 << 20;
        let mut s0 = Schedule::default();
        s0.send(1, size, 0);
        let mut s1 = Schedule::default();
        s1.recv(0, 0);
        let mut s2 = Schedule::default();
        s2.send(3, size, 0);
        let mut s3 = Schedule::default();
        s3.recv(2, 0);
        let one_pair = s.run(&[s0.clone(), s1.clone()]).makespan_us;
        let two_pairs = s.run(&[s0, s1, s2, s3]).makespan_us;
        assert!((two_pairs - one_pair).abs() < 1e-9);
    }

    #[test]
    fn rendezvous_adds_rtt() {
        let mpi = sim(PortKind::Mpi);
        let mk = |size: u64| {
            let mut a = Schedule::default();
            a.send(1, size, 0);
            let mut b = Schedule::default();
            b.recv(0, 0);
            mpi.run(&[a, b]).makespan_us
        };
        let eager = mk(64 * 1024);
        let rdv = mk(64 * 1024 + 1);
        // Crossing the threshold trades the eager copy for one handshake
        // RTT: the protocols must be *continuous* there (within 10%) —
        // MPI implementations pick the threshold precisely so the switch
        // is near-neutral.
        assert!(
            (rdv - eager).abs() / eager < 0.10,
            "protocol discontinuity at threshold: eager {eager} rdv {rdv}"
        );
        // And the handshake is really charged: a rendezvous message can
        // never beat the pure postal bound + its RTT.
        let cost = PortKind::Mpi.cost_model();
        let size = 1u64 << 20;
        let floor = cost.sw_overhead_us / 2.0
            + 2.0 * net().alpha_us
            + size as f64 / net().beta_gbps / 1e3
            + net().alpha_us;
        assert!(mk(size) >= floor, "{} < floor {floor}", mk(size));
    }

    #[test]
    fn port_ordering_holds_in_sim() {
        // LCI < MPI < TCP for a 1 MiB exchange — the Fig. 3 invariant.
        let times: Vec<f64> = PortKind::ALL
            .iter()
            .map(|&kind| {
                let s = sim(kind);
                let mut a = Schedule::default();
                a.send(1, 1 << 20, 0);
                let mut b = Schedule::default();
                b.recv(0, 0);
                s.run(&[a, b]).makespan_us
            })
            .collect();
        let (tcp, mpi, lci) = (times[0], times[1], times[2]);
        assert!(lci < mpi && mpi < tcp, "tcp {tcp} mpi {mpi} lci {lci}");
    }

    #[test]
    fn blocked_time_is_tracked() {
        let s = sim(PortKind::Lci);
        let mut a = Schedule::default();
        a.compute(100.0, "slow").send(1, 1024, 0);
        let mut b = Schedule::default();
        b.recv(0, 0);
        let report = s.run(&[a, b]);
        assert!(report.node_blocked_us[1] >= 100.0);
        assert!(report.node_blocked_us[0] == 0.0);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn deadlock_detected() {
        let s = sim(PortKind::Lci);
        let mut a = Schedule::default();
        a.recv(1, 0);
        let mut b = Schedule::default();
        b.recv(0, 0);
        s.run(&[a, b]);
    }

    #[test]
    fn determinism() {
        let s = sim(PortKind::Mpi);
        let build = || {
            let mut scheds: Vec<Schedule> = (0..4).map(|_| Schedule::default()).collect();
            for i in 0..4usize {
                for j in 0..4usize {
                    if i != j {
                        scheds[i].send(j, 100_000, (i * 4 + j) as u64);
                    }
                }
                for j in 0..4usize {
                    if i != j {
                        scheds[i].recv(j, (j * 4 + i) as u64);
                    }
                }
            }
            scheds
        };
        let r1 = s.run(&build());
        let r2 = s.run(&build());
        assert_eq!(r1.node_finish_us, r2.node_finish_us);
        assert_eq!(r1.makespan_us, r2.makespan_us);
    }
}
