//! Seeded adversarial schedules for the discrete-event engine.
//!
//! The adversary perturbs a simulation run — extra per-message delays,
//! duplicate deliveries, first-transmission drops, and slow ranks —
//! while staying **bit-reproducible from its seed**. Every decision is
//! drawn from a PCG stream keyed by `(seed, message id)` or
//! `(seed, rank)`, never from a shared sequential stream, so the plan
//! for a message does not depend on the order messages happen to be
//! posted in. Two runs with the same seed and configuration therefore
//! produce the same perturbations, the same event order, and the same
//! trace hash.

use super::components::Tick;
use crate::util::rng::Pcg32;

/// Stream-key offset separating per-rank draws from per-message draws
/// (message ids are sequential from zero and never reach 2^40).
const RANK_STREAM_BASE: u64 = 1 << 40;

/// What the adversary does to one message's delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsgPlan {
    /// Extra latency added after the modeled arrival time.
    pub extra_delay: Tick,
    /// If set, a duplicate copy arrives this many ticks after the
    /// original (the engine must drop it exactly once).
    pub duplicate_after: Option<Tick>,
    /// The first transmission is lost; the sender's retransmission
    /// timer recovers it.
    pub drop_first: bool,
}

impl MsgPlan {
    /// The no-perturbation plan.
    pub fn benign() -> Self {
        Self { extra_delay: 0, duplicate_after: None, drop_first: false }
    }
}

/// Adversary configuration: seed plus perturbation intensities.
/// Probabilities are integer percentages so configurations hash and
/// compare exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversaryConfig {
    /// Seed all decision streams are keyed from.
    pub seed: u64,
    /// Percent of messages that receive an extra delivery delay.
    pub delay_prob_pct: u32,
    /// Maximum extra delay, in microseconds.
    pub max_delay_us: u32,
    /// Percent of messages delivered twice.
    pub dup_prob_pct: u32,
    /// Percent of messages whose first transmission is dropped.
    pub drop_prob_pct: u32,
    /// Percent of ranks that run slow.
    pub slow_rank_pct: u32,
    /// Software-time multiplier applied to slow ranks.
    pub slow_factor: f64,
}

impl AdversaryConfig {
    /// No perturbations at all: the engine reproduces the closed-form
    /// model's schedule exactly.
    pub fn none(seed: u64) -> Self {
        Self {
            seed,
            delay_prob_pct: 0,
            max_delay_us: 0,
            dup_prob_pct: 0,
            drop_prob_pct: 0,
            slow_rank_pct: 0,
            slow_factor: 1.0,
        }
    }

    /// Mild jitter: occasional delays and reorders, no faults.
    pub fn light(seed: u64) -> Self {
        Self {
            seed,
            delay_prob_pct: 25,
            max_delay_us: 40,
            dup_prob_pct: 0,
            drop_prob_pct: 0,
            slow_rank_pct: 0,
            slow_factor: 1.0,
        }
    }

    /// Everything at once: heavy delays, duplicates, drops, and slow
    /// ranks. The fuzz matrix's default.
    pub fn hostile(seed: u64) -> Self {
        Self {
            seed,
            delay_prob_pct: 60,
            max_delay_us: 200,
            dup_prob_pct: 15,
            drop_prob_pct: 10,
            slow_rank_pct: 25,
            slow_factor: 4.0,
        }
    }

    /// Look up a named preset (`none`, `light`, `hostile`).
    pub fn preset(name: &str, seed: u64) -> Result<Self, String> {
        match name {
            "none" => Ok(Self::none(seed)),
            "light" => Ok(Self::light(seed)),
            "hostile" => Ok(Self::hostile(seed)),
            other => Err(format!("unknown adversary preset '{other}' (none|light|hostile)")),
        }
    }

    /// Enable individual fault classes from a comma-separated spec, e.g.
    /// `--faults drop,slow`. Classes: `delay`, `dup`, `drop`, `slow`.
    /// Starts from [`AdversaryConfig::none`] and switches each named
    /// class on at its [`AdversaryConfig::hostile`] intensity.
    pub fn from_fault_spec(spec: &str, seed: u64) -> Result<Self, String> {
        let hostile = Self::hostile(seed);
        let mut cfg = Self::none(seed);
        for class in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            match class {
                "delay" => {
                    cfg.delay_prob_pct = hostile.delay_prob_pct;
                    cfg.max_delay_us = hostile.max_delay_us;
                }
                "dup" => cfg.dup_prob_pct = hostile.dup_prob_pct,
                "drop" => cfg.drop_prob_pct = hostile.drop_prob_pct,
                "slow" => {
                    cfg.slow_rank_pct = hostile.slow_rank_pct;
                    cfg.slow_factor = hostile.slow_factor;
                }
                other => {
                    return Err(format!("unknown fault class '{other}' (delay|dup|drop|slow)"))
                }
            }
        }
        Ok(cfg)
    }

    /// The perturbation plan for message `msg_id`. Pure function of
    /// `(seed, msg_id)` — independent of posting order.
    pub fn plan(&self, msg_id: u64) -> MsgPlan {
        let mut rng = Pcg32::with_stream(self.seed, msg_id);
        // Always draw in a fixed order so a plan depends only on the
        // configuration values, not on which gates happen to be open.
        let delay_roll = rng.next_below(100);
        let delay_ticks = rng.next_below(self.max_delay_us.saturating_mul(1000).max(1)) as Tick;
        let dup_roll = rng.next_below(100);
        let dup_after = 1 + rng.next_below(5_000) as Tick;
        let drop_roll = rng.next_below(100);

        MsgPlan {
            extra_delay: if delay_roll < self.delay_prob_pct { delay_ticks } else { 0 },
            duplicate_after: (dup_roll < self.dup_prob_pct).then_some(dup_after),
            drop_first: drop_roll < self.drop_prob_pct,
        }
    }

    /// The software-time multiplier for `rank` (1.0 unless the rank is
    /// chosen as slow). Pure function of `(seed, rank)`.
    pub fn slow_factor_for(&self, rank: usize) -> f64 {
        if self.slow_rank_pct == 0 {
            return 1.0;
        }
        let mut rng = Pcg32::with_stream(self.seed, RANK_STREAM_BASE + rank as u64);
        if rng.next_below(100) < self.slow_rank_pct {
            self.slow_factor
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::components::us_to_ticks;

    #[test]
    fn plans_are_reproducible_and_order_independent() {
        let adv = AdversaryConfig::hostile(42);
        let forward: Vec<MsgPlan> = (0..64).map(|id| adv.plan(id)).collect();
        let backward: Vec<MsgPlan> = (0..64).rev().map(|id| adv.plan(id)).collect();
        for (i, p) in forward.iter().enumerate() {
            assert_eq!(*p, backward[63 - i], "msg {i}");
        }
        // And a different seed actually changes something.
        let other = AdversaryConfig::hostile(43);
        assert!((0..64).any(|id| adv.plan(id) != other.plan(id)));
    }

    #[test]
    fn none_preset_is_benign() {
        let adv = AdversaryConfig::none(7);
        for id in 0..32 {
            assert_eq!(adv.plan(id), MsgPlan::benign());
        }
        for rank in 0..32 {
            assert_eq!(adv.slow_factor_for(rank), 1.0);
        }
    }

    #[test]
    fn hostile_preset_exercises_every_class() {
        let adv = AdversaryConfig::hostile(1);
        let plans: Vec<MsgPlan> = (0..256).map(|id| adv.plan(id)).collect();
        assert!(plans.iter().any(|p| p.extra_delay > 0), "no delays drawn");
        assert!(plans.iter().any(|p| p.duplicate_after.is_some()), "no dups drawn");
        assert!(plans.iter().any(|p| p.drop_first), "no drops drawn");
        assert!((0..64).any(|r| adv.slow_factor_for(r) > 1.0), "no slow ranks drawn");
        assert!((0..64).any(|r| adv.slow_factor_for(r) == 1.0), "all ranks slow");
    }

    #[test]
    fn fault_spec_parses_classes() {
        let cfg = AdversaryConfig::from_fault_spec("drop,slow", 9).unwrap();
        assert!(cfg.drop_prob_pct > 0 && cfg.slow_rank_pct > 0);
        assert_eq!(cfg.dup_prob_pct, 0);
        assert_eq!(cfg.delay_prob_pct, 0);
        assert_eq!(
            AdversaryConfig::from_fault_spec("", 9).unwrap(),
            AdversaryConfig::none(9)
        );
        assert!(AdversaryConfig::from_fault_spec("gamma-rays", 9).is_err());
        assert!(AdversaryConfig::preset("hostile", 3).is_ok());
        assert!(AdversaryConfig::preset("cosmic", 3).is_err());
    }

    #[test]
    fn delay_amounts_respect_the_bound() {
        let adv = AdversaryConfig::hostile(11);
        let bound = us_to_ticks(adv.max_delay_us as f64);
        for id in 0..512 {
            assert!(adv.plan(id).extra_delay <= bound);
        }
    }
}
