//! FFTW3 MPI+pthreads reference — the paper's comparison baseline.

pub mod fftw_like;

pub use fftw_like::{run as run_fftw_like, FftwLikeConfig};
