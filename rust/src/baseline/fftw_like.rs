//! The FFTW3-MPI+pthreads analog (`fftw_mpi_plan_dft_2d` with
//! `FFTW_MPI_TRANSPOSED_OUT`, threads enabled).
//!
//! Structure of the real thing, preserved here:
//!
//! - slab decomposition by rows, one MPI process per node ("locality"),
//!   with the "+X" threaded 1-D sweeps genuinely threaded: row batches
//!   of cached mixed-radix plans fan out over the shared
//!   [`crate::task::ThreadPool`] (any row length, not just powers of
//!   two — FFTW's own planner is mixed-radix too);
//! - the global transpose is a **synchronous `MPI_Alltoall`** — pairwise
//!   exchange, the large-message algorithm MPI implementations select;
//! - **no communication/computation overlap**: compute, then exchange,
//!   then unpack — the property that lets the paper's N-scatter HPX
//!   variant win;
//! - barrier-delimited, as MPI benchmark harnesses time collectives.
//!
//! The transport is the MPI-semantics parcelport, so eager/rendezvous
//! behaviour matches what OpenMPI would do with the same chunk sizes.

use crate::collectives::{AllToAllAlgo, Communicator};
use crate::dist_fft::driver::{NativeRowFft, RowFft, StepTimings};
use crate::dist_fft::partition::Slab;
use crate::dist_fft::transpose::place_chunk_transposed;
use crate::dist_fft::verify::{rel_error, serial_fft2_transposed};
use crate::fft::complex::{from_le_bytes, Complex32};
use crate::hpx::parcel::Payload;
use crate::hpx::runtime::Cluster;
use crate::parcelport::{NetModel, PortKind};
use std::time::Instant;

/// Baseline configuration.
#[derive(Clone, Debug)]
pub struct FftwLikeConfig {
    /// Global grid rows (any length, multiple of `localities`).
    pub rows: usize,
    /// Global grid columns (any length, multiple of `localities`).
    pub cols: usize,
    /// MPI processes ("nodes").
    pub localities: usize,
    /// pthreads per MPI process.
    pub threads: usize,
    /// Optional hybrid wire model.
    pub net: Option<NetModel>,
    /// Compare the result against the serial reference.
    pub verify: bool,
}

impl Default for FftwLikeConfig {
    fn default() -> Self {
        Self { rows: 256, cols: 256, localities: 4, threads: 2, net: None, verify: true }
    }
}

/// Baseline report: timings + optional verification error.
#[derive(Clone, Debug)]
pub struct FftwLikeReport {
    /// Per-process step timings, rank order.
    pub per_rank: Vec<StepTimings>,
    /// Element-wise max across processes.
    pub critical_path: StepTimings,
    /// Relative L2 error vs. the serial reference (if verified).
    pub rel_error: Option<f64>,
}

/// One synchronous MPI+threads 2-D FFT (transposed output).
pub fn run(config: &FftwLikeConfig) -> anyhow::Result<FftwLikeReport> {
    let cluster = Cluster::new(config.localities, PortKind::Mpi, config.net)?;
    run_on(&cluster, config)
}

/// Run on an existing cluster (the benchmark harness reuses fabrics).
pub fn run_on(cluster: &Cluster, config: &FftwLikeConfig) -> anyhow::Result<FftwLikeReport> {
    anyhow::ensure!(
        cluster.fabric().kind() == PortKind::Mpi,
        "the FFTW3 baseline is MPI+X by definition; got {} fabric",
        cluster.fabric().kind()
    );
    let results: Vec<(Vec<Complex32>, StepTimings)> = cluster.run(|ctx| {
        let comm = Communicator::from_ctx(ctx);
        // The collective engine is futures-first and drives its blocking
        // wrappers through the send pool; spawn it before the barrier so
        // thread creation never lands in the timed section.
        comm.warm_chunk_pool();
        let slab = Slab::synthetic(config.rows, config.cols, config.localities, ctx.rank);
        fftw_like_transform(&comm, &slab, config.threads)
    });

    let per_rank: Vec<StepTimings> = results.iter().map(|(_, t)| *t).collect();
    let critical_path = StepTimings::max(&per_rank);
    let rel_err = if config.verify {
        let mut assembled = Vec::with_capacity(config.rows * config.cols);
        for (piece, _) in &results {
            assembled.extend_from_slice(piece);
        }
        let reference = serial_fft2_transposed(
            &Slab::whole(config.rows, config.cols).data,
            config.rows,
            config.cols,
        );
        Some(rel_error(&assembled, &reference))
    } else {
        None
    };

    Ok(FftwLikeReport { per_rank, critical_path, rel_error: rel_err })
}

/// The per-process transform, structured exactly like
/// `fftw_mpi_execute_dft`: threaded sweep → synchronous all-to-all →
/// unpack → threaded sweep.
fn fftw_like_transform(
    comm: &Communicator,
    slab: &Slab,
    threads: usize,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    let lr = slab.local_rows();
    let cw = Slab::cols_per_chunk(slab.global_cols, n);
    let r_total = slab.global_rows;
    let mut t = StepTimings::default();
    let t_start = Instant::now();

    // MPI benchmark discipline: enter timed section together.
    comm.barrier();

    // Threaded row sweep (length C).
    let t0 = Instant::now();
    let mut work = slab.data.clone();
    NativeRowFft.fft_rows(&mut work, slab.global_cols, threads);
    t.fft1_us = t0.elapsed().as_secs_f64() * 1e6;

    // Synchronous MPI_Alltoall (pairwise exchange), then unpack. No
    // overlap: the unpack loop starts only after the collective returns.
    let t0 = Instant::now();
    let tmp = Slab {
        global_rows: slab.global_rows,
        global_cols: slab.global_cols,
        parts: slab.parts,
        rank: slab.rank,
        data: work,
    }; // §Perf: field-wise construction — `..slab.clone()` would clone and
       // immediately drop the slab's full data buffer.
    let chunks: Vec<Payload> =
        (0..n).map(|j| Payload::new(tmp.extract_chunk_bytes(j))).collect();
    let received = comm.all_to_all(chunks, AllToAllAlgo::Pairwise);
    t.comm_us = t0.elapsed().as_secs_f64() * 1e6;

    let t0 = Instant::now();
    let mut next = vec![Complex32::ZERO; cw * r_total];
    for (j, payload) in received.into_iter().enumerate() {
        let chunk = from_le_bytes(payload.as_bytes());
        place_chunk_transposed(&chunk, lr, cw, &mut next, r_total, j * lr);
    }
    t.transpose_us = t0.elapsed().as_secs_f64() * 1e6;

    // Threaded row sweep (length R).
    let t0 = Instant::now();
    NativeRowFft.fft_rows(&mut next, r_total, threads);
    t.fft2_us = t0.elapsed().as_secs_f64() * 1e6;

    t.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_verifies() {
        let report = run(&FftwLikeConfig {
            rows: 32,
            cols: 32,
            localities: 4,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
        assert_eq!(report.per_rank.len(), 4);
    }

    #[test]
    fn baseline_matches_hpx_variants() {
        // Same arithmetic ⇒ same results, bitwise.
        let cfg = FftwLikeConfig { rows: 16, cols: 16, localities: 2, threads: 1, ..Default::default() };
        let cluster = Cluster::new(2, PortKind::Mpi, None).unwrap();
        let baseline = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(cfg.rows, cfg.cols, 2, ctx.rank);
            fftw_like_transform(&comm, &slab, 1).0
        });
        let cluster2 = Cluster::new(2, PortKind::Lci, None).unwrap();
        let hpx = cluster2.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(cfg.rows, cfg.cols, 2, ctx.rank);
            crate::dist_fft::scatter_variant::run(&comm, &slab, 1, &NativeRowFft).0
        });
        assert_eq!(baseline, hpx);
    }

    #[test]
    fn rejects_non_mpi_fabric() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let cfg = FftwLikeConfig { rows: 16, cols: 16, localities: 2, ..Default::default() };
        assert!(run_on(&cluster, &cfg).is_err());
    }

    #[test]
    fn single_locality() {
        let report = run(&FftwLikeConfig {
            rows: 16,
            cols: 16,
            localities: 1,
            threads: 2,
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
    }

    #[test]
    fn non_pow2_grid_verifies() {
        // 12×96 over 4 MPI processes, 2 threads each — the FFTW3
        // baseline runs the same mixed-radix grids the HPX variants do.
        let report = run(&FftwLikeConfig {
            rows: 12,
            cols: 96,
            localities: 4,
            threads: 2,
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
    }
}
