//! Minimal CLI argument parser (offline stand-in for `clap`).
//!
//! Grammar: `repro <subcommand> [--key value | --key=value | --flag]`.
//! A `--key` followed by a token that does not start with `--` takes it
//! as its value; otherwise it is a boolean flag.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Subcommand words, in order.
    pub positional: Vec<String>,
    options: HashMap<String, String>,
}

impl Args {
    /// Parse an argument vector (without the program name).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("stray `--`");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.set(k, v)?;
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.set(key, &v)?;
                } else {
                    out.set(key, "true")?;
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        if self.options.insert(key.to_string(), value.to_string()).is_some() {
            bail!("flag --{key} given twice");
        }
        Ok(())
    }

    /// Raw value of `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Whether boolean flag `--key` was given (or set truthy).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => {
                raw.parse().map_err(|e| anyhow::anyhow!("--{key} {raw:?}: {e}"))
            }
        }
    }

    /// Unknown-flag guard: every provided option must be in `allowed`.
    pub fn check_known(&self, allowed: &[&str]) -> Result<()> {
        for key in self.options.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!("unknown flag --{key}; allowed: {}", allowed.join(", --"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("bench chunk-size --reps 10 --port=lci --quick");
        assert_eq!(a.positional, vec!["bench", "chunk-size"]);
        assert_eq!(a.get("reps"), Some("10"));
        assert_eq!(a.get("port"), Some("lci"));
        assert!(a.get_bool("quick"));
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("--rows 64");
        assert_eq!(a.get_or("rows", 0usize).unwrap(), 64);
        assert_eq!(a.get_or("cols", 32usize).unwrap(), 32);
        assert!(a.get_or::<usize>("rows", 0).is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("--rows abc");
        assert!(a.get_or::<usize>("rows", 0).is_err());
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(["--x".into(), "1".into(), "--x".into(), "2".into()]).is_err());
    }

    #[test]
    fn unknown_flag_guard() {
        let a = parse("--rows 1 --bogus 2");
        assert!(a.check_known(&["rows"]).is_err());
        assert!(a.check_known(&["rows", "bogus"]).is_ok());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("run --verify");
        assert!(a.get_bool("verify"));
    }
}
