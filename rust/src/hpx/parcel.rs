//! The parcel abstraction.
//!
//! In HPX terms a parcel is "an active message: a destination global
//! address, an action, and its arguments". For the collective workloads
//! in this benchmark the action set is small and static, so actions are
//! plain `u32` identifiers (see [`actions`]) and arguments travel as an
//! opaque byte payload plus a 64-bit matching tag.
//!
//! [`Payload`] is the single payload representation shared by all three
//! parcelports: an `Arc<Vec<u8>>`. Whether a port *clones the bytes* or
//! *clones the Arc* is exactly the copy-semantics difference between the
//! MPI/TCP ports and the LCI port that the paper measures.

use std::sync::Arc;

/// Locality (node) identifier — dense, `0..n_localities`.
pub type LocalityId = usize;

/// Action identifier — names the remote operation a parcel invokes.
pub type ActionId = u32;

/// Matching tag within an action namespace.
pub type Tag = u64;

/// Well-known action ids.
pub mod actions {
    use super::ActionId;

    /// Collective data traffic (scatter / all-to-all / ... chunks).
    pub const COLLECTIVE: ActionId = 1;
    /// Point-to-point user payloads (examples, tests).
    pub const P2P: ActionId = 2;
    /// AGAS registration gossip (runtime-internal).
    pub const AGAS: ActionId = 3;
    /// Rendezvous ready-to-send control message (MPI port internal).
    pub const CTRL_RTS: ActionId = 0xFFF1;
    /// Rendezvous clear-to-send control message (MPI port internal).
    pub const CTRL_CTS: ActionId = 0xFFF2;
    /// Runtime shutdown signal.
    pub const SHUTDOWN: ActionId = 0xFFFF;
}

/// Reference-counted byte payload: an `Arc`-backed buffer plus a
/// `[off, off + len)` window into it.
///
/// `Payload::clone` is O(1) (Arc bump), and so is [`Payload::slice`],
/// which produces a sub-view sharing the same allocation — the mechanism
/// that lets the chunked collectives split a rank's buffer into wire
/// chunks with zero copies on the LCI path. Ports that model copying
/// transports call [`Payload::deep_copy`] instead, which duplicates the
/// bytes and is counted in port statistics.
#[derive(Clone, Debug)]
pub struct Payload {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl Payload {
    /// Wrap owned bytes (no copy).
    pub fn new(bytes: Vec<u8>) -> Self {
        let len = bytes.len();
        Self { buf: Arc::new(bytes), off: 0, len }
    }

    /// Zero-length payload.
    pub fn empty() -> Self {
        Self::new(Vec::new())
    }

    /// Serialize an `f32` slice to little-endian wire bytes.
    pub fn from_f32(xs: &[f32]) -> Self {
        Self::new(crate::util::bytes::f32_to_bytes(xs))
    }

    /// Length of this payload's window in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the window is zero-length.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The window's bytes.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }

    /// Parse the window as little-endian `f32`s.
    pub fn to_f32(&self) -> Vec<f32> {
        crate::util::bytes::bytes_to_f32(self.as_bytes())
    }

    /// Zero-copy sub-view of `[offset, offset + len)` within this
    /// payload: an Arc bump, no byte is touched. The slice keeps the
    /// whole backing buffer alive for as long as it exists — acceptable
    /// for wire chunks, whose lifetime ends at delivery.
    ///
    /// ```
    /// use hpx_fft::hpx::parcel::Payload;
    ///
    /// let message = Payload::new(vec![7u8; 1024]);
    /// let chunk = message.slice(256, 128); // wire chunk 2 of a 128 B policy
    /// assert_eq!(chunk.len(), 128);
    /// // Same allocation — splitting a message into chunks copies nothing.
    /// assert!(chunk.shares_storage(&message));
    /// ```
    ///
    /// # Panics
    /// If `offset + len` exceeds the payload length.
    pub fn slice(&self, offset: usize, len: usize) -> Payload {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len),
            "slice [{offset}, {offset}+{len}) out of bounds for payload of {} bytes",
            self.len
        );
        Self { buf: Arc::clone(&self.buf), off: self.off + offset, len }
    }

    /// Duplicate the underlying bytes (a real memcpy) — used by ports
    /// whose protocol implies a copy (TCP framing, MPI eager buffers).
    pub fn deep_copy(&self) -> Self {
        Self::new(self.as_bytes().to_vec())
    }

    /// Take the bytes out, cloning only if other references exist or this
    /// payload is a sub-view.
    pub fn into_vec(self) -> Vec<u8> {
        if self.off == 0 && self.len == self.buf.len() {
            Arc::try_unwrap(self.buf).unwrap_or_else(|arc| arc.as_ref().clone())
        } else {
            self.as_bytes().to_vec()
        }
    }

    /// True if this payload shares storage with `other` (zero-copy check).
    /// Sub-views created by [`Payload::slice`] share their parent's
    /// storage even though they expose different windows.
    pub fn shares_storage(&self, other: &Payload) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }
}

/// An active message.
#[derive(Clone, Debug)]
pub struct Parcel {
    /// Sending locality.
    pub src: LocalityId,
    /// Destination locality.
    pub dest: LocalityId,
    /// Remote operation this parcel invokes.
    pub action: ActionId,
    /// Matching tag within the action namespace.
    pub tag: Tag,
    /// Argument bytes.
    pub payload: Payload,
}

impl Parcel {
    /// Assemble a parcel from its parts.
    pub fn new(
        src: LocalityId,
        dest: LocalityId,
        action: ActionId,
        tag: Tag,
        payload: Payload,
    ) -> Self {
        Self { src, dest, action, tag, payload }
    }

    /// Wire-encode (used by the TCP port): fixed header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(Self::HEADER_LEN + self.payload.len());
        crate::util::bytes::put_u32(&mut buf, self.src as u32);
        crate::util::bytes::put_u32(&mut buf, self.dest as u32);
        crate::util::bytes::put_u32(&mut buf, self.action);
        crate::util::bytes::put_u64(&mut buf, self.tag);
        crate::util::bytes::put_u64(&mut buf, self.payload.len() as u64);
        buf.extend_from_slice(self.payload.as_bytes());
        buf
    }

    /// Header size of the wire encoding.
    pub const HEADER_LEN: usize = 4 + 4 + 4 + 8 + 8;

    /// Decode a wire frame produced by [`Parcel::encode`].
    ///
    /// # Panics
    /// On a malformed frame (framing guarantees length on the TCP path).
    pub fn decode(frame: &[u8]) -> Self {
        let mut off = 0;
        let src = crate::util::bytes::get_u32(frame, &mut off) as LocalityId;
        let dest = crate::util::bytes::get_u32(frame, &mut off) as LocalityId;
        let action = crate::util::bytes::get_u32(frame, &mut off);
        let tag = crate::util::bytes::get_u64(frame, &mut off);
        let len = crate::util::bytes::get_u64(frame, &mut off) as usize;
        assert_eq!(frame.len(), off + len, "frame length mismatch");
        Self { src, dest, action, tag, payload: Payload::new(frame[off..].to_vec()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_clone_is_shallow() {
        let p = Payload::from_f32(&[1.0, 2.0]);
        let q = p.clone();
        assert!(p.shares_storage(&q));
    }

    #[test]
    fn deep_copy_is_deep() {
        let p = Payload::from_f32(&[1.0, 2.0]);
        let q = p.deep_copy();
        assert!(!p.shares_storage(&q));
        assert_eq!(p.as_bytes(), q.as_bytes());
    }

    #[test]
    fn f32_payload_roundtrip() {
        let xs = vec![0.5f32, -1.25, 3.0];
        assert_eq!(Payload::from_f32(&xs).to_f32(), xs);
    }

    #[test]
    fn into_vec_avoids_copy_when_unique() {
        let p = Payload::new(vec![1, 2, 3]);
        let ptr = p.as_bytes().as_ptr();
        let v = p.into_vec();
        assert_eq!(v.as_ptr(), ptr, "unique payload should move, not copy");
    }

    #[test]
    fn slice_is_zero_copy_view() {
        let p = Payload::new((0u8..100).collect());
        let s = p.slice(10, 25);
        assert!(s.shares_storage(&p), "slice must alias the parent allocation");
        assert_eq!(s.len(), 25);
        assert_eq!(s.as_bytes(), &(10u8..35).collect::<Vec<_>>()[..]);
        // The parent window is untouched.
        assert_eq!(p.len(), 100);
    }

    #[test]
    fn nested_slices_compose_offsets() {
        let p = Payload::new((0u8..64).collect());
        let s = p.slice(16, 32).slice(8, 8);
        assert!(s.shares_storage(&p));
        assert_eq!(s.as_bytes(), &(24u8..32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn full_and_empty_slices() {
        let p = Payload::new(vec![1, 2, 3]);
        assert_eq!(p.slice(0, 3).as_bytes(), p.as_bytes());
        assert!(p.slice(3, 0).is_empty());
        assert!(p.slice(1, 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_beyond_end_panics() {
        Payload::new(vec![0; 8]).slice(4, 5);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_overflowing_offset_panics() {
        Payload::new(vec![0; 8]).slice(usize::MAX, 2);
    }

    #[test]
    fn deep_copy_of_slice_is_windowed() {
        let p = Payload::new((0u8..16).collect());
        let s = p.slice(4, 8);
        let d = s.deep_copy();
        assert!(!d.shares_storage(&p));
        assert_eq!(d.as_bytes(), s.as_bytes());
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn into_vec_of_slice_copies_window_only() {
        let p = Payload::new((0u8..16).collect());
        let v = p.slice(2, 5).into_vec();
        assert_eq!(v, (2u8..7).collect::<Vec<_>>());
    }

    #[test]
    fn sliced_payload_encodes_window() {
        let payload = Payload::new((0u8..32).collect()).slice(8, 16);
        let p = Parcel::new(0, 1, actions::P2P, 5, payload);
        let q = Parcel::decode(&p.encode());
        assert_eq!(q.payload.as_bytes(), &(8u8..24).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn parcel_encode_decode_roundtrip() {
        let p = Parcel::new(3, 7, actions::COLLECTIVE, 0xABCD_EF01_2345, Payload::new(vec![9; 100]));
        let frame = p.encode();
        assert_eq!(frame.len(), Parcel::HEADER_LEN + 100);
        let q = Parcel::decode(&frame);
        assert_eq!(q.src, 3);
        assert_eq!(q.dest, 7);
        assert_eq!(q.action, actions::COLLECTIVE);
        assert_eq!(q.tag, 0xABCD_EF01_2345);
        assert_eq!(q.payload.as_bytes(), p.payload.as_bytes());
    }

    #[test]
    fn empty_payload_roundtrip() {
        let p = Parcel::new(0, 1, actions::P2P, 0, Payload::empty());
        let q = Parcel::decode(&p.encode());
        assert!(q.payload.is_empty());
    }

    #[test]
    #[should_panic(expected = "frame length mismatch")]
    fn truncated_frame_panics() {
        let p = Parcel::new(0, 1, actions::P2P, 0, Payload::new(vec![1, 2, 3, 4]));
        let frame = p.encode();
        Parcel::decode(&frame[..frame.len() - 1]);
    }
}
