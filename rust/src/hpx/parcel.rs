//! The parcel abstraction.
//!
//! In HPX terms a parcel is "an active message: a destination global
//! address, an action, and its arguments". For the collective workloads
//! in this benchmark the action set is small and static, so actions are
//! plain `u32` identifiers (see [`actions`]) and arguments travel as an
//! opaque byte payload plus a 64-bit matching tag.
//!
//! [`Payload`] is the single payload representation shared by all three
//! parcelports: an `Arc<Vec<u8>>`. Whether a port *clones the bytes* or
//! *clones the Arc* is exactly the copy-semantics difference between the
//! MPI/TCP ports and the LCI port that the paper measures.

use std::sync::Arc;

/// Locality (node) identifier — dense, `0..n_localities`.
pub type LocalityId = usize;

/// Action identifier — names the remote operation a parcel invokes.
pub type ActionId = u32;

/// Matching tag within an action namespace.
pub type Tag = u64;

/// Well-known action ids.
pub mod actions {
    use super::ActionId;

    /// Collective data traffic (scatter / all-to-all / ... chunks).
    pub const COLLECTIVE: ActionId = 1;
    /// Point-to-point user payloads (examples, tests).
    pub const P2P: ActionId = 2;
    /// AGAS registration gossip (runtime-internal).
    pub const AGAS: ActionId = 3;
    /// Rendezvous ready-to-send control message (MPI port internal).
    pub const CTRL_RTS: ActionId = 0xFFF1;
    /// Rendezvous clear-to-send control message (MPI port internal).
    pub const CTRL_CTS: ActionId = 0xFFF2;
    /// Runtime shutdown signal.
    pub const SHUTDOWN: ActionId = 0xFFFF;
}

/// Reference-counted byte payload.
///
/// `Payload::clone` is O(1) (Arc bump). Ports that model copying
/// transports call [`Payload::deep_copy`] instead, which duplicates the
/// bytes and is counted in port statistics.
#[derive(Clone, Debug)]
pub struct Payload(Arc<Vec<u8>>);

impl Payload {
    pub fn new(bytes: Vec<u8>) -> Self {
        Self(Arc::new(bytes))
    }

    pub fn empty() -> Self {
        Self(Arc::new(Vec::new()))
    }

    pub fn from_f32(xs: &[f32]) -> Self {
        Self::new(crate::util::bytes::f32_to_bytes(xs))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.0
    }

    pub fn to_f32(&self) -> Vec<f32> {
        crate::util::bytes::bytes_to_f32(&self.0)
    }

    /// Duplicate the underlying bytes (a real memcpy) — used by ports
    /// whose protocol implies a copy (TCP framing, MPI eager buffers).
    pub fn deep_copy(&self) -> Self {
        Self(Arc::new(self.0.as_ref().clone()))
    }

    /// Take the bytes out, cloning only if other references exist.
    pub fn into_vec(self) -> Vec<u8> {
        Arc::try_unwrap(self.0).unwrap_or_else(|arc| arc.as_ref().clone())
    }

    /// True if this payload shares storage with `other` (zero-copy check).
    pub fn shares_storage(&self, other: &Payload) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

/// An active message.
#[derive(Clone, Debug)]
pub struct Parcel {
    pub src: LocalityId,
    pub dest: LocalityId,
    pub action: ActionId,
    pub tag: Tag,
    pub payload: Payload,
}

impl Parcel {
    pub fn new(
        src: LocalityId,
        dest: LocalityId,
        action: ActionId,
        tag: Tag,
        payload: Payload,
    ) -> Self {
        Self { src, dest, action, tag, payload }
    }

    /// Wire-encode (used by the TCP port): fixed header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(Self::HEADER_LEN + self.payload.len());
        crate::util::bytes::put_u32(&mut buf, self.src as u32);
        crate::util::bytes::put_u32(&mut buf, self.dest as u32);
        crate::util::bytes::put_u32(&mut buf, self.action);
        crate::util::bytes::put_u64(&mut buf, self.tag);
        crate::util::bytes::put_u64(&mut buf, self.payload.len() as u64);
        buf.extend_from_slice(self.payload.as_bytes());
        buf
    }

    /// Header size of the wire encoding.
    pub const HEADER_LEN: usize = 4 + 4 + 4 + 8 + 8;

    /// Decode a wire frame produced by [`Parcel::encode`].
    ///
    /// # Panics
    /// On a malformed frame (framing guarantees length on the TCP path).
    pub fn decode(frame: &[u8]) -> Self {
        let mut off = 0;
        let src = crate::util::bytes::get_u32(frame, &mut off) as LocalityId;
        let dest = crate::util::bytes::get_u32(frame, &mut off) as LocalityId;
        let action = crate::util::bytes::get_u32(frame, &mut off);
        let tag = crate::util::bytes::get_u64(frame, &mut off);
        let len = crate::util::bytes::get_u64(frame, &mut off) as usize;
        assert_eq!(frame.len(), off + len, "frame length mismatch");
        Self { src, dest, action, tag, payload: Payload::new(frame[off..].to_vec()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_clone_is_shallow() {
        let p = Payload::from_f32(&[1.0, 2.0]);
        let q = p.clone();
        assert!(p.shares_storage(&q));
    }

    #[test]
    fn deep_copy_is_deep() {
        let p = Payload::from_f32(&[1.0, 2.0]);
        let q = p.deep_copy();
        assert!(!p.shares_storage(&q));
        assert_eq!(p.as_bytes(), q.as_bytes());
    }

    #[test]
    fn f32_payload_roundtrip() {
        let xs = vec![0.5f32, -1.25, 3.0];
        assert_eq!(Payload::from_f32(&xs).to_f32(), xs);
    }

    #[test]
    fn into_vec_avoids_copy_when_unique() {
        let p = Payload::new(vec![1, 2, 3]);
        let ptr = p.as_bytes().as_ptr();
        let v = p.into_vec();
        assert_eq!(v.as_ptr(), ptr, "unique payload should move, not copy");
    }

    #[test]
    fn parcel_encode_decode_roundtrip() {
        let p = Parcel::new(3, 7, actions::COLLECTIVE, 0xABCD_EF01_2345, Payload::new(vec![9; 100]));
        let frame = p.encode();
        assert_eq!(frame.len(), Parcel::HEADER_LEN + 100);
        let q = Parcel::decode(&frame);
        assert_eq!(q.src, 3);
        assert_eq!(q.dest, 7);
        assert_eq!(q.action, actions::COLLECTIVE);
        assert_eq!(q.tag, 0xABCD_EF01_2345);
        assert_eq!(q.payload.as_bytes(), p.payload.as_bytes());
    }

    #[test]
    fn empty_payload_roundtrip() {
        let p = Parcel::new(0, 1, actions::P2P, 0, Payload::empty());
        let q = Parcel::decode(&p.encode());
        assert!(q.payload.is_empty());
    }

    #[test]
    #[should_panic(expected = "frame length mismatch")]
    fn truncated_frame_panics() {
        let p = Parcel::new(0, 1, actions::P2P, 0, Payload::new(vec![1, 2, 3, 4]));
        let frame = p.encode();
        Parcel::decode(&frame[..frame.len() - 1]);
    }
}
