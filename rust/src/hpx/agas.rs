//! AGAS-style symbolic name registry.
//!
//! HPX's Active Global Address Space lets any locality resolve a symbolic
//! name ("/fft/partition#3") to the global address of a component,
//! wherever it lives. Our benchmark uses it the same way HPX collectives
//! do internally: participants register their per-rank communicator
//! endpoints under a basename, and `resolve` blocks until the peer has
//! registered — which doubles as the registration barrier HPX performs
//! when creating a collective.

use super::parcel::LocalityId;
use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A resolved global address: which locality owns the component, plus a
/// component-local id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GlobalAddress {
    /// Locality owning the component.
    pub locality: LocalityId,
    /// Component-local identifier.
    pub component: u64,
}

/// The name service. One instance is shared by all localities of a
/// cluster (in real HPX it is itself distributed; the service semantics —
/// register once, resolve from anywhere, block until present — are what
/// the collectives depend on).
pub struct Agas {
    names: Mutex<HashMap<String, GlobalAddress>>,
    cv: Condvar,
}

impl Agas {
    /// Empty registry.
    pub fn new() -> Self {
        Self { names: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }

    /// Register `name`. Re-registering an existing name is a logic error.
    ///
    /// # Panics
    /// If the name is already registered with a different address.
    pub fn register(&self, name: &str, addr: GlobalAddress) {
        let mut names = self.names.lock().unwrap();
        if let Some(prev) = names.insert(name.to_string(), addr) {
            assert_eq!(prev, addr, "AGAS name {name:?} re-registered with a different address");
        }
        self.cv.notify_all();
    }

    /// Block until `name` is registered and return its address.
    pub fn resolve(&self, name: &str) -> GlobalAddress {
        let mut names = self.names.lock().unwrap();
        loop {
            if let Some(&addr) = names.get(name) {
                return addr;
            }
            names = self.cv.wait(names).unwrap();
        }
    }

    /// Non-blocking resolve.
    pub fn try_resolve(&self, name: &str) -> Option<GlobalAddress> {
        self.names.lock().unwrap().get(name).copied()
    }

    /// Blocking resolve with timeout.
    pub fn resolve_timeout(&self, name: &str, timeout: Duration) -> Option<GlobalAddress> {
        let deadline = std::time::Instant::now() + timeout;
        let mut names = self.names.lock().unwrap();
        loop {
            if let Some(&addr) = names.get(name) {
                return Some(addr);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (n, res) = self.cv.wait_timeout(names, deadline - now).unwrap();
            names = n;
            if res.timed_out() {
                return names.get(name).copied();
            }
        }
    }

    /// Unregister (component teardown).
    pub fn unregister(&self, name: &str) -> Option<GlobalAddress> {
        self.names.lock().unwrap().remove(name)
    }

    /// Number of registered names.
    pub fn len(&self) -> usize {
        self.names.lock().unwrap().len()
    }

    /// True when no names are registered.
    pub fn is_empty(&self) -> bool {
        self.names.lock().unwrap().is_empty()
    }
}

impl Default for Agas {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn register_resolve() {
        let agas = Agas::new();
        agas.register("/fft/root", GlobalAddress { locality: 2, component: 9 });
        assert_eq!(agas.resolve("/fft/root"), GlobalAddress { locality: 2, component: 9 });
    }

    #[test]
    fn resolve_blocks_until_registered() {
        let agas = Arc::new(Agas::new());
        let a2 = Arc::clone(&agas);
        let h = thread::spawn(move || a2.resolve("/late"));
        thread::sleep(Duration::from_millis(10));
        agas.register("/late", GlobalAddress { locality: 1, component: 0 });
        assert_eq!(h.join().unwrap().locality, 1);
    }

    #[test]
    fn try_resolve_nonblocking() {
        let agas = Agas::new();
        assert!(agas.try_resolve("/nope").is_none());
        agas.register("/yes", GlobalAddress { locality: 0, component: 1 });
        assert!(agas.try_resolve("/yes").is_some());
    }

    #[test]
    fn resolve_timeout_expires() {
        let agas = Agas::new();
        assert!(agas.resolve_timeout("/never", Duration::from_millis(5)).is_none());
    }

    #[test]
    fn idempotent_reregistration_ok() {
        let agas = Agas::new();
        let addr = GlobalAddress { locality: 3, component: 3 };
        agas.register("/dup", addr);
        agas.register("/dup", addr); // same address: fine
    }

    #[test]
    #[should_panic(expected = "re-registered")]
    fn conflicting_registration_panics() {
        let agas = Agas::new();
        agas.register("/x", GlobalAddress { locality: 0, component: 0 });
        agas.register("/x", GlobalAddress { locality: 1, component: 0 });
    }

    #[test]
    fn unregister_removes() {
        let agas = Agas::new();
        agas.register("/tmp", GlobalAddress { locality: 0, component: 0 });
        assert!(agas.unregister("/tmp").is_some());
        assert!(agas.try_resolve("/tmp").is_none());
        assert!(agas.is_empty());
    }

    #[test]
    fn many_concurrent_registrations() {
        let agas = Arc::new(Agas::new());
        let handles: Vec<_> = (0..8)
            .map(|loc| {
                let agas = Arc::clone(&agas);
                thread::spawn(move || {
                    agas.register(
                        &format!("/rank/{loc}"),
                        GlobalAddress { locality: loc, component: 0 },
                    );
                    // Everyone resolves everyone (the collective-creation
                    // pattern).
                    for peer in 0..8 {
                        let addr = agas.resolve(&format!("/rank/{peer}"));
                        assert_eq!(addr.locality, peer);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(agas.len(), 8);
    }
}
