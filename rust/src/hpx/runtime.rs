//! Cluster bootstrap: spawn localities, wire the fabric, run SPMD code.
//!
//! `hpx_main` on a real cluster starts one process per node; here a
//! [`Cluster`] owns the chosen parcelport fabric and an AGAS instance and
//! runs an SPMD closure on one OS thread per locality, returning each
//! locality's result. This is the entry point every example, benchmark,
//! and the CLI use.

use super::agas::Agas;
use super::parcel::{actions, LocalityId, Parcel, Payload, Tag};
use crate::parcelport::{self, NetModel, Parcelport, PortKind};
use std::sync::Arc;

/// A wired-up set of localities.
pub struct Cluster {
    fabric: Arc<dyn Parcelport>,
    agas: Arc<Agas>,
    n: usize,
}

impl Cluster {
    /// Build a cluster of `n` localities over the given parcelport.
    /// `net = Some(...)` enables the hybrid wire model (cluster-like
    /// timings); `None` measures raw local transport behaviour.
    pub fn new(n: usize, kind: PortKind, net: Option<NetModel>) -> anyhow::Result<Self> {
        Ok(Self { fabric: parcelport::build(kind, n, net)?, agas: Arc::new(Agas::new()), n })
    }

    /// Wrap an existing fabric (tests, custom ports).
    pub fn with_fabric(fabric: Arc<dyn Parcelport>) -> Self {
        let n = fabric.n_localities();
        Self { fabric, agas: Arc::new(Agas::new()), n }
    }

    /// Number of localities in this cluster.
    pub fn n_localities(&self) -> usize {
        self.n
    }

    /// The parcelport fabric all localities share.
    pub fn fabric(&self) -> &Arc<dyn Parcelport> {
        &self.fabric
    }

    /// The cluster's name service.
    pub fn agas(&self) -> &Arc<Agas> {
        &self.agas
    }

    /// Run `f` as SPMD code: one thread per locality. Returns per-rank
    /// results in rank order. Panics in any locality propagate.
    pub fn run<T: Send>(&self, f: impl Fn(&LocalityCtx) -> T + Sync) -> Vec<T> {
        let mut slots: Vec<Option<T>> = (0..self.n).map(|_| None).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = slots
                .iter_mut()
                .enumerate()
                .map(|(rank, slot)| {
                    let ctx = LocalityCtx {
                        rank,
                        n: self.n,
                        fabric: Arc::clone(&self.fabric),
                        agas: Arc::clone(&self.agas),
                    };
                    let f = &f;
                    s.spawn(move || {
                        *slot = Some(f(&ctx));
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("locality panicked");
            }
        });
        slots.into_iter().map(|s| s.expect("locality produced no result")).collect()
    }
}

/// Per-locality execution context handed to SPMD closures.
pub struct LocalityCtx {
    /// This locality's rank, `0..n`.
    pub rank: LocalityId,
    /// Total number of localities.
    pub n: usize,
    fabric: Arc<dyn Parcelport>,
    /// The shared name service.
    pub agas: Arc<Agas>,
}

impl LocalityCtx {
    /// The parcelport fabric.
    pub fn fabric(&self) -> &Arc<dyn Parcelport> {
        &self.fabric
    }

    /// Point-to-point send (action [`actions::P2P`]).
    pub fn send(&self, dest: LocalityId, tag: Tag, payload: Payload) {
        self.fabric.send(Parcel::new(self.rank, dest, actions::P2P, tag, payload));
    }

    /// Blocking point-to-point receive.
    pub fn recv(&self, src: LocalityId, tag: Tag) -> Payload {
        self.fabric.recv(self.rank, src, actions::P2P, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::agas::GlobalAddress;

    #[test]
    fn run_returns_rank_ordered_results() {
        let cluster = Cluster::new(4, PortKind::Lci, None).unwrap();
        let results = cluster.run(|ctx| ctx.rank * 2);
        assert_eq!(results, vec![0, 2, 4, 6]);
    }

    #[test]
    fn ring_exchange_over_runtime() {
        let cluster = Cluster::new(4, PortKind::Lci, None).unwrap();
        let sums = cluster.run(|ctx| {
            let next = (ctx.rank + 1) % ctx.n;
            let prev = (ctx.rank + ctx.n - 1) % ctx.n;
            ctx.send(next, 0, Payload::from_f32(&[ctx.rank as f32]));
            ctx.recv(prev, 0).to_f32()[0]
        });
        assert_eq!(sums, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn agas_shared_across_localities() {
        let cluster = Cluster::new(3, PortKind::Mpi, None).unwrap();
        let resolved = cluster.run(|ctx| {
            ctx.agas.register(
                &format!("/worker/{}", ctx.rank),
                GlobalAddress { locality: ctx.rank, component: 7 },
            );
            // Resolve a peer's name (blocks until that peer registers).
            let peer = (ctx.rank + 1) % ctx.n;
            ctx.agas.resolve(&format!("/worker/{peer}")).locality
        });
        assert_eq!(resolved, vec![1, 2, 0]);
    }

    #[test]
    fn run_works_over_tcp() {
        let cluster = Cluster::new(3, PortKind::Tcp, None).unwrap();
        let results = cluster.run(|ctx| {
            let next = (ctx.rank + 1) % ctx.n;
            ctx.send(next, 1, Payload::new(vec![ctx.rank as u8; 8]));
            let prev = (ctx.rank + ctx.n - 1) % ctx.n;
            ctx.recv(prev, 1).as_bytes()[0] as usize
        });
        assert_eq!(results, vec![2, 0, 1]);
    }

    #[test]
    fn single_locality_cluster() {
        let cluster = Cluster::new(1, PortKind::Lci, None).unwrap();
        let r = cluster.run(|ctx| {
            ctx.send(0, 0, Payload::from_f32(&[1.5]));
            ctx.recv(0, 0).to_f32()[0]
        });
        assert_eq!(r, vec![1.5]);
    }

    #[test]
    fn multiple_runs_reuse_fabric() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        for round in 0..3u64 {
            let r = cluster.run(|ctx| {
                let peer = 1 - ctx.rank;
                ctx.send(peer, round, Payload::new(vec![round as u8]));
                ctx.recv(peer, round).as_bytes()[0]
            });
            assert_eq!(r, vec![round as u8, round as u8]);
        }
    }
}
