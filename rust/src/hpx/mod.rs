//! HPX-analog distributed substrate.
//!
//! HPX programs run on *localities* (one process per node) that exchange
//! *parcels* (an active-message abstraction: destination + action +
//! arguments) over a *parcelport*, and name remote entities through the
//! Active Global Address Space (AGAS). This module rebuilds those
//! abstractions for the benchmark:
//!
//! - [`parcel`] — the parcel type, action/tag namespaces, and the shared
//!   payload representation (`Arc`-backed so the LCI port can hand it
//!   over without copying),
//! - [`mailbox`] — per-locality matched receive queues (the parcel
//!   decoding/dispatch layer),
//! - [`agas`] — symbolic name → global address registry,
//! - [`runtime`] — cluster bootstrap: spawn N localities on OS threads,
//!   wire them with the chosen parcelport, run an SPMD closure, collect
//!   results.
//!
//! Localities are threads in one process rather than processes on
//! separate nodes; the parcelports (see [`crate::parcelport`]) preserve
//! each backend's protocol costs, and cluster-scale wire time comes from
//! the calibrated network model / simnet.

pub mod agas;
pub mod mailbox;
pub mod parcel;
pub mod runtime;

pub use parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
pub use runtime::{Cluster, LocalityCtx};
