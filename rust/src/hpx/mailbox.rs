//! Per-locality matched receive queues.
//!
//! Every locality owns one [`Mailbox`]. Incoming parcels are filed under
//! their `(src, action, tag)` key; receivers block on an exact-match key
//! (collectives always know who they expect). Out-of-order arrival is
//! handled by queueing per key, preserving per-(src,key) FIFO order —
//! the same matching semantics MPI guarantees per (source, tag, comm).

use super::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

type Key = (LocalityId, ActionId, Tag);

/// A matched-receive queue for one locality.
pub struct Mailbox {
    inner: Mutex<HashMap<Key, VecDeque<Payload>>>,
    cv: Condvar,
}

impl Mailbox {
    /// Empty mailbox.
    pub fn new() -> Self {
        Self { inner: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }

    /// File an incoming parcel.
    pub fn deliver(&self, parcel: Parcel) {
        let key = (parcel.src, parcel.action, parcel.tag);
        self.inner.lock().unwrap().entry(key).or_default().push_back(parcel.payload);
        self.cv.notify_all();
    }

    /// Blocking matched receive.
    pub fn recv(&self, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        let key = (src, action, tag);
        let mut map = self.inner.lock().unwrap();
        loop {
            if let Some(q) = map.get_mut(&key) {
                if let Some(p) = q.pop_front() {
                    if q.is_empty() {
                        map.remove(&key);
                    }
                    return p;
                }
            }
            map = self.cv.wait(map).unwrap();
        }
    }

    /// Blocking matched receive with timeout (tests / failure injection).
    pub fn recv_timeout(
        &self,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
        timeout: Duration,
    ) -> Option<Payload> {
        let key = (src, action, tag);
        let deadline = std::time::Instant::now() + timeout;
        let mut map = self.inner.lock().unwrap();
        loop {
            if let Some(q) = map.get_mut(&key) {
                if let Some(p) = q.pop_front() {
                    if q.is_empty() {
                        map.remove(&key);
                    }
                    return Some(p);
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (m, res) = self.cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
            if res.timed_out() {
                // Loop once more to drain anything that raced the timeout.
                if let Some(q) = map.get_mut(&key) {
                    if let Some(p) = q.pop_front() {
                        if q.is_empty() {
                            map.remove(&key);
                        }
                        return Some(p);
                    }
                }
                return None;
            }
        }
    }

    /// Non-blocking matched receive.
    pub fn try_recv(&self, src: LocalityId, action: ActionId, tag: Tag) -> Option<Payload> {
        let key = (src, action, tag);
        let mut map = self.inner.lock().unwrap();
        let q = map.get_mut(&key)?;
        let p = q.pop_front();
        if q.is_empty() {
            map.remove(&key);
        }
        p
    }

    /// Number of queued payloads (diagnostics).
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().values().map(|q| q.len()).sum()
    }
}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;
    use std::sync::Arc;
    use std::thread;

    fn parcel(src: usize, tag: Tag, byte: u8) -> Parcel {
        Parcel::new(src, 0, actions::P2P, tag, Payload::new(vec![byte]))
    }

    #[test]
    fn deliver_then_recv() {
        let mb = Mailbox::new();
        mb.deliver(parcel(1, 7, 42));
        assert_eq!(mb.recv(1, actions::P2P, 7).as_bytes(), &[42]);
    }

    #[test]
    fn recv_blocks_until_delivery() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = Arc::clone(&mb);
        let h = thread::spawn(move || mb2.recv(2, actions::P2P, 1).as_bytes()[0]);
        thread::sleep(Duration::from_millis(10));
        mb.deliver(parcel(2, 1, 99));
        assert_eq!(h.join().unwrap(), 99);
    }

    #[test]
    fn matching_is_exact() {
        let mb = Mailbox::new();
        mb.deliver(parcel(1, 1, 10));
        mb.deliver(parcel(2, 1, 20));
        mb.deliver(parcel(1, 2, 30));
        assert_eq!(mb.recv(1, actions::P2P, 2).as_bytes(), &[30]);
        assert_eq!(mb.recv(2, actions::P2P, 1).as_bytes(), &[20]);
        assert_eq!(mb.recv(1, actions::P2P, 1).as_bytes(), &[10]);
    }

    #[test]
    fn per_key_fifo_order() {
        let mb = Mailbox::new();
        for b in 0..10u8 {
            mb.deliver(parcel(3, 5, b));
        }
        for b in 0..10u8 {
            assert_eq!(mb.recv(3, actions::P2P, 5).as_bytes(), &[b]);
        }
    }

    #[test]
    fn try_recv_returns_none_when_empty() {
        let mb = Mailbox::new();
        assert!(mb.try_recv(0, actions::P2P, 0).is_none());
        mb.deliver(parcel(0, 0, 1));
        assert!(mb.try_recv(0, actions::P2P, 0).is_some());
        assert!(mb.try_recv(0, actions::P2P, 0).is_none());
    }

    #[test]
    fn recv_timeout_times_out() {
        let mb = Mailbox::new();
        let got = mb.recv_timeout(0, actions::P2P, 0, Duration::from_millis(5));
        assert!(got.is_none());
    }

    #[test]
    fn recv_timeout_gets_value() {
        let mb = Mailbox::new();
        mb.deliver(parcel(0, 0, 77));
        let got = mb.recv_timeout(0, actions::P2P, 0, Duration::from_millis(5));
        assert_eq!(got.unwrap().as_bytes(), &[77]);
    }

    #[test]
    fn pending_counts() {
        let mb = Mailbox::new();
        assert_eq!(mb.pending(), 0);
        mb.deliver(parcel(0, 0, 1));
        mb.deliver(parcel(0, 1, 2));
        assert_eq!(mb.pending(), 2);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let mb = Arc::new(Mailbox::new());
        let producers: Vec<_> = (0..4)
            .map(|src| {
                let mb = Arc::clone(&mb);
                thread::spawn(move || {
                    for i in 0..50u64 {
                        mb.deliver(Parcel::new(
                            src,
                            0,
                            actions::P2P,
                            i,
                            Payload::new(vec![src as u8]),
                        ));
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|src| {
                let mb = Arc::clone(&mb);
                thread::spawn(move || {
                    for i in 0..50u64 {
                        let p = mb.recv(src, actions::P2P, i);
                        assert_eq!(p.as_bytes(), &[src as u8]);
                    }
                })
            })
            .collect();
        for h in producers.into_iter().chain(consumers) {
            h.join().unwrap();
        }
        assert_eq!(mb.pending(), 0);
    }
}
