//! Mixed-radix Cooley–Tukey engine — the planner's general-length path.
//!
//! A length `n = r₀·r₁·…·r_{L−1}·(P)` is factorized into radix-4,
//! radix-2, then odd-prime stages ([`factorize`]); an unfactorable
//! remainder `P` (a prime above [`NAIVE_PRIME_MAX`]) becomes a
//! [`BluesteinPlan`] base case. Execution is the textbook recursive
//! decimation-in-time:
//!
//! ```text
//! X[m·q + k] = Σ_i w_r^{i·q} · ( w_len^{i·k} · Y_i[k] )      (r = r₀, m = len/r)
//! ```
//!
//! where `Y_i` is the length-`m` sub-transform of the stride-`r`
//! subsequence starting at offset `i`. Every level's twiddle table
//! (`w_len^{i·k}`, `len` entries) and its `r×r` combine matrix
//! (`w_r^{i·q}`) are precomputed once per plan and shared by all
//! sub-transforms at that level, so execution does no trigonometry. The
//! radix-2 and radix-4 combines are specialized (their twiddle-free
//! lanes and ±i rotations need no general multiply) and run through the
//! lane-parallel [`super::simd`] butterflies — dispatched to AVX2/NEON
//! at runtime, bitwise-equal to the scalar formulas; larger radices go
//! through the generic matrix.
//!
//! Direction is baked into the tables (conjugated for the inverse); the
//! `1/n` inverse normalization is applied once by [`crate::fft::Plan`],
//! after all stages.

use super::bluestein::BluesteinPlan;
use super::complex::Complex32;
use super::simd;
use super::twiddle;

/// Largest prime executed as a direct O(r²) combine stage. Trial
/// division stops here: any remainder whose prime factors all exceed
/// this bound — one large prime, a repeated one, or a product of
/// several — goes to Bluestein whole, whose O(m log m) convolution wins
/// well before the quadratic combine (and its r² twiddle matrix) hurts.
pub(crate) const NAIVE_PRIME_MAX: usize = 61;

/// Split `n` into Cooley–Tukey radix stages: factors of 4 first, then a
/// leftover 2, then odd primes ≤ [`NAIVE_PRIME_MAX`] ascending. Returns
/// the stage list and, if a remainder with only large prime factors is
/// left, that remainder (the Bluestein base case — it need not be
/// prime itself).
pub(crate) fn factorize(mut n: usize) -> (Vec<usize>, Option<usize>) {
    let mut stages = Vec::new();
    while n % 4 == 0 {
        stages.push(4);
        n /= 4;
    }
    if n % 2 == 0 {
        stages.push(2);
        n /= 2;
    }
    let mut d = 3;
    while d * d <= n && d <= NAIVE_PRIME_MAX {
        while n % d == 0 {
            stages.push(d);
            n /= d;
        }
        d += 2;
    }
    if n == 1 {
        (stages, None)
    } else if n <= NAIVE_PRIME_MAX {
        stages.push(n);
        (stages, None)
    } else {
        (stages, Some(n))
    }
}

/// One recursion level: all sub-transforms of length `len` share these
/// tables.
struct Level {
    /// Sub-transform length at this level.
    len: usize,
    /// Radix split off at this level.
    radix: usize,
    /// `w_len^{i·k}` for `i in 0..radix`, `k in 0..len/radix`, indexed
    /// `i·(len/radix) + k` — the same layout the combine loop walks.
    twiddles: Vec<Complex32>,
    /// `radix × radix` DFT matrix `w_radix^{i·q}`, indexed `i·radix + q`.
    radix_dft: Vec<Complex32>,
}

impl Level {
    fn new(len: usize, radix: usize, inverse: bool) -> Self {
        debug_assert!(radix >= 2 && len % radix == 0);
        let m = len / radix;
        let mut twiddles = Vec::with_capacity(len);
        for i in 0..radix {
            for k in 0..m {
                twiddles.push(twiddle::unit(i * k, len, inverse));
            }
        }
        let mut radix_dft = Vec::with_capacity(radix * radix);
        for i in 0..radix {
            for q in 0..radix {
                radix_dft.push(twiddle::unit(i * q, radix, inverse));
            }
        }
        Self { len, radix, twiddles, radix_dft }
    }
}

/// The base case the recursion bottoms out in.
enum Base {
    /// Fully factored: the length-1 transform is the identity.
    One,
    /// Remainder whose prime factors all exceed [`NAIVE_PRIME_MAX`]
    /// (a large prime, or a product of large primes).
    Bluestein(BluesteinPlan),
}

/// A prepared mixed-radix transform: the stage schedule plus every table
/// execution needs. Unnormalized in both directions (the plan owns the
/// inverse `1/n`).
pub(crate) struct MixedPlan {
    n: usize,
    inverse: bool,
    levels: Vec<Level>,
    base: Base,
    /// Largest stage radix — sizes the combine scratch.
    max_radix: usize,
}

impl MixedPlan {
    /// Factorize `n` and precompute all stage tables.
    pub(crate) fn new(n: usize, inverse: bool) -> Self {
        assert!(n >= 2, "MixedPlan requires n >= 2, got {n}");
        let (factors, big_prime) = factorize(n);
        let mut levels = Vec::with_capacity(factors.len());
        let mut len = n;
        for &r in &factors {
            levels.push(Level::new(len, r, inverse));
            len /= r;
        }
        let base = match big_prime {
            Some(p) => {
                debug_assert_eq!(len, p, "factorization remainder mismatch");
                Base::Bluestein(BluesteinPlan::new(p, inverse))
            }
            None => {
                debug_assert_eq!(len, 1, "factorization did not reach 1");
                Base::One
            }
        };
        let max_radix = factors.iter().copied().max().unwrap_or(1);
        Self { n, inverse, levels, base, max_radix }
    }

    /// Transform length.
    pub(crate) fn len(&self) -> usize {
        self.n
    }

    /// The stage schedule, e.g. `[4, 2, 3, 3, 5]` for `n = 360`.
    pub(crate) fn radices(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.radix).collect()
    }

    /// Whether the plan bottoms out in a Bluestein convolution.
    pub(crate) fn uses_bluestein(&self) -> bool {
        matches!(self.base, Base::Bluestein(_))
    }

    /// Transform `x` in place (unnormalized, direction baked into the
    /// tables). `work`/`temp`/`conv` are caller-owned scratch buffers,
    /// grown on demand and reusable across calls.
    pub(crate) fn execute(
        &self,
        x: &mut [Complex32],
        work: &mut Vec<Complex32>,
        temp: &mut Vec<Complex32>,
        conv: &mut Vec<Complex32>,
    ) {
        debug_assert_eq!(x.len(), self.n);
        work.clear();
        work.extend_from_slice(x);
        temp.clear();
        temp.resize(self.max_radix, Complex32::ZERO);
        rec(&self.levels, &self.base, self.inverse, &work[..], 1, x, temp, conv);
    }
}

/// Recursive DIT step: transform the strided view
/// `src[0], src[stride], …` into the contiguous `dst`, consuming one
/// level per call. Bounds invariant: `src.len() ≥ (dst.len()−1)·stride + 1`.
#[allow(clippy::too_many_arguments)]
fn rec(
    levels: &[Level],
    base: &Base,
    inverse: bool,
    src: &[Complex32],
    stride: usize,
    dst: &mut [Complex32],
    temp: &mut [Complex32],
    conv: &mut Vec<Complex32>,
) {
    let Some((level, rest)) = levels.split_first() else {
        match base {
            Base::One => dst[0] = src[0],
            Base::Bluestein(b) => {
                debug_assert_eq!(dst.len(), b.len());
                b.exec(src, stride, dst, conv);
            }
        }
        return;
    };
    let r = level.radix;
    let m = level.len / r;

    // Sub-transforms: residue class i of the strided input lands in
    // dst[i·m .. (i+1)·m].
    for i in 0..r {
        rec(rest, base, inverse, &src[i * stride..], stride * r, &mut dst[i * m..(i + 1) * m], temp, conv);
    }

    // Combine: at each output index k, an r-point DFT across the
    // twiddled sub-results. Lane i = 0 always carries twiddle 1. The
    // radix-2/-4 arms run the lane-parallel SIMD butterflies over the
    // contiguous lane-i twiddle rows (layout `i·m + k` means row i is
    // exactly `twiddles[i·m..(i+1)·m]`).
    match r {
        2 => {
            let (lo, hi) = dst.split_at_mut(m);
            simd::butterfly_radix2(lo, hi, &level.twiddles[m..2 * m]);
        }
        4 => {
            let (d0, rest) = dst.split_at_mut(m);
            let (d1, rest) = rest.split_at_mut(m);
            let (d2, d3) = rest.split_at_mut(m);
            let tw = &level.twiddles;
            simd::butterfly_radix4(
                d0,
                d1,
                d2,
                d3,
                &tw[m..2 * m],
                &tw[2 * m..3 * m],
                &tw[3 * m..4 * m],
                inverse,
            );
        }
        _ => {
            let temp = &mut temp[..r];
            for k in 0..m {
                for (i, t) in temp.iter_mut().enumerate() {
                    *t = dst[i * m + k] * level.twiddles[i * m + k];
                }
                for q in 0..r {
                    let mut acc = temp[0];
                    for i in 1..r {
                        acc += temp[i] * level.radix_dft[i * r + q];
                    }
                    dst[q * m + k] = acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_signal(seed: u64, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    fn run_mixed(x: &[Complex32]) -> Vec<Complex32> {
        let plan = MixedPlan::new(x.len(), false);
        let mut out = x.to_vec();
        let (mut w, mut t, mut c) = (Vec::new(), Vec::new(), Vec::new());
        plan.execute(&mut out, &mut w, &mut t, &mut c);
        out
    }

    #[test]
    fn factorize_known_values() {
        assert_eq!(factorize(12), (vec![4, 3], None));
        assert_eq!(factorize(96), (vec![4, 4, 2, 3], None));
        assert_eq!(factorize(360), (vec![4, 2, 3, 3, 5], None));
        assert_eq!(factorize(1000), (vec![4, 2, 5, 5, 5], None));
        assert_eq!(factorize(1013), (vec![], Some(1013)));
        assert_eq!(factorize(7), (vec![7], None));
        // Large primes never become combine stages, even when repeated
        // or paired with a small cofactor: the remainder goes to
        // Bluestein whole (it need not be prime).
        assert_eq!(factorize(4489), (vec![], Some(4489))); // 67²
        assert_eq!(factorize(2 * 67), (vec![2], Some(67)));
        assert_eq!(factorize(59 * 67), (vec![59], Some(67)));
    }

    #[test]
    fn stage_product_reconstructs_n() {
        for n in 2..200usize {
            let (stages, rem) = factorize(n);
            let product: usize = stages.iter().product::<usize>() * rem.unwrap_or(1);
            assert_eq!(product, n, "n={n}");
        }
    }

    #[test]
    fn matches_oracle_assorted_lengths() {
        // Composite, odd, prime-with-stages, and generic-radix lengths.
        for &n in &[2usize, 3, 4, 6, 8, 9, 10, 12, 15, 21, 25, 36, 49, 60, 96, 100, 360] {
            let x = random_signal(n as u64, n);
            assert_close(&flat(&run_mixed(&x)), &flat(&dft(&x)), 1e-3, 1e-3);
        }
    }

    #[test]
    fn matches_oracle_bluestein_composite() {
        // 4 · 101: a Bluestein base case under a radix-4 level.
        let n = 4 * 101;
        let x = random_signal(7, n);
        assert_close(&flat(&run_mixed(&x)), &flat(&dft(&x)), 1e-3, 1e-3);
        let plan = MixedPlan::new(n, false);
        assert!(plan.uses_bluestein());
        assert_eq!(plan.radices(), vec![4]);
    }

    #[test]
    fn composite_large_prime_remainder_roundtrips() {
        // 67² = 4489: all prime factors > NAIVE_PRIME_MAX, so the whole
        // remainder runs as one Bluestein convolution (Bluestein does
        // not require a prime length). Roundtrip rather than the O(n²)
        // oracle keeps this cheap in debug builds.
        let n = 4489;
        let fwd = MixedPlan::new(n, false);
        assert!(fwd.uses_bluestein());
        assert!(fwd.radices().is_empty());
        let inv = MixedPlan::new(n, true);
        let x = random_signal(13, n);
        let mut buf = x.clone();
        let (mut w, mut t, mut c) = (Vec::new(), Vec::new(), Vec::new());
        fwd.execute(&mut buf, &mut w, &mut t, &mut c);
        inv.execute(&mut buf, &mut w, &mut t, &mut c);
        let scale = 1.0 / n as f32;
        for v in buf.iter_mut() {
            *v = v.scale(scale);
        }
        assert_close(&flat(&buf), &flat(&x), 1e-2, 1e-2);
    }

    #[test]
    fn inverse_roundtrip() {
        for &n in &[12usize, 45, 100, 101] {
            let x = random_signal(n as u64 + 1, n);
            let fwd = MixedPlan::new(n, false);
            let inv = MixedPlan::new(n, true);
            let mut buf = x.clone();
            let (mut w, mut t, mut c) = (Vec::new(), Vec::new(), Vec::new());
            fwd.execute(&mut buf, &mut w, &mut t, &mut c);
            inv.execute(&mut buf, &mut w, &mut t, &mut c);
            let scale = 1.0 / n as f32;
            for v in buf.iter_mut() {
                *v = v.scale(scale);
            }
            assert_close(&flat(&buf), &flat(&x), 1e-3, 1e-3);
        }
    }

    #[test]
    fn scratch_reuse_across_lengths_is_safe() {
        let (mut w, mut t, mut c) = (Vec::new(), Vec::new(), Vec::new());
        for &n in &[360usize, 12, 101, 96] {
            let x = random_signal(n as u64 + 9, n);
            let plan = MixedPlan::new(n, false);
            let mut out = x.clone();
            plan.execute(&mut out, &mut w, &mut t, &mut c);
            assert_close(&flat(&out), &flat(&dft(&x)), 1e-3, 1e-3);
        }
    }
}
