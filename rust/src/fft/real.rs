//! Real-input (r2c) and real-output (c2r) transforms.
//!
//! The paper's FFTW3+MPI reference transforms *real* input — the
//! workload of the companion case study (Strack et al., "Experiences
//! Porting Distributed Applications to Asynchronous Tasks: A
//! Multidimensional FFT Case-study") — so a real grid should cost half
//! the wire traffic of a complex one. This module provides that
//! substrate on top of the existing mixed-radix [`Plan`] engine:
//!
//! - [`rfft`] / [`irfft`] — the r2c transform to the `n/2 + 1`
//!   Hermitian-unique bins and its c2r inverse. Even lengths run the
//!   **packed half-complex trick** (one `n/2`-point complex FFT of the
//!   even/odd-interleaved samples plus an O(n) twiddle recombination);
//!   odd lengths fall back to a complex transform of the real signal,
//!   which routes primes > 61 through the Bluestein engine exactly like
//!   any other plan.
//! - [`RealPlan`] — the reusable even-length r2c plan (half-length
//!   complex plan + recombination twiddles), memoized process-wide in
//!   [`RealPlanCache`] like the complex plans.
//! - the **packed half-spectrum** ([`rfft_packed`],
//!   [`unpack_half_spectrum`], [`pack_half_spectrum`]): for even `n`,
//!   bins 0 and `n/2` are purely real, so the `n/2 + 1` bins fit in
//!   exactly `n/2` complex slots — slot 0 carries `(X[0].re, X[n/2].re)`
//!   and slots `1..n/2` carry `X[k]` verbatim. The distributed FFT ships
//!   this layout over the wire: a real `R × C` grid moves `C/2` spectral
//!   columns instead of `C`, halving every transpose round's payload.
//! - [`rfft_rows_packed`] / [`rfft_rows_packed_into`] — row batches of
//!   packed transforms, fanned over the shared worker pool like
//!   [`crate::fft::batch::fft_rows_parallel`].

use super::complex::Complex32;
use super::plan::{Direction, FftScratch, Plan, PlanCache};
use super::twiddle::TwiddleCache;
use crate::task::parallel_chunks_mut;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Number of Hermitian-unique bins of an `n`-point real transform:
/// `n/2 + 1`.
pub fn spectrum_len(n: usize) -> usize {
    n / 2 + 1
}

/// A reusable r2c plan for one even length `n`: the `n/2`-point complex
/// plan (from the global [`PlanCache`]) plus the recombination twiddles
/// `e^{-2πik/n}`. Executing it performs the packed half-complex trick:
/// the real samples are viewed as `n/2` complex numbers, transformed
/// once, and recombined in O(n).
pub struct RealPlan {
    n: usize,
    half: Arc<Plan>,
    /// `w^k = e^{-2πik/n}` for `k = 0..n/2` — the forward half-circle
    /// table of length `n`, shared through [`TwiddleCache`] (same values
    /// the old per-plan loop computed: f64 phase, rounded once).
    twiddles: Arc<Vec<Complex32>>,
}

impl RealPlan {
    /// Plan an `n`-point r2c transform. `n` must be even and ≥ 2 (odd
    /// lengths go through the [`rfft`] complex fallback instead).
    pub fn new(n: usize) -> Self {
        assert!(n >= 2 && n % 2 == 0, "RealPlan requires even n >= 2, got {n}");
        let m = n / 2;
        let twiddles = TwiddleCache::global().half(n, false);
        Self { n, half: PlanCache::global().plan(m, Direction::Forward), twiddles }
    }

    /// Real transform length.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Always `false` — real plans have length ≥ 2 (API symmetry with
    /// `len`).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Packed output length, `n/2` complex slots.
    pub fn packed_len(&self) -> usize {
        self.n / 2
    }

    /// r2c of one length-`n` real row into the packed half-spectrum
    /// (`n/2` slots, slot 0 = `(X[0].re, X[n/2].re)`), reusing
    /// caller-owned scratch. `out` doubles as the half-length complex
    /// staging buffer, so the transform allocates nothing.
    ///
    /// # Panics
    /// If `x.len() != n` or `out.len() != n/2`.
    pub fn execute_packed(&self, x: &[f32], out: &mut [Complex32], scratch: &mut FftScratch) {
        let m = self.n / 2;
        assert_eq!(x.len(), self.n, "input length {} != plan length {}", x.len(), self.n);
        assert_eq!(out.len(), m, "output length {} != packed length {m}", out.len());

        // Pack: z[j] = x[2j] + i·x[2j+1], then one m-point complex FFT.
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = Complex32::new(x[2 * j], x[2 * j + 1]);
        }
        self.half.execute_with_scratch(out, scratch);

        // Recombine in place. With E/O the (Hermitian) spectra of the
        // even/odd sample streams: X[k] = E[k] + w^k·O[k], and the
        // (k, m−k) pair is computed together from Z[k], Z[m−k].
        let z0 = out[0];
        out[0] = Complex32::new(z0.re + z0.im, z0.re - z0.im); // (X[0], X[m])
        for k in 1..=m / 2 {
            let j = m - k;
            if k == j {
                // Mid-bin (m even): w^{m/2} = −i collapses to a conjugate.
                out[k] = out[k].conj();
            } else {
                let (zk, zj) = (out[k], out[j]);
                let e = (zk + zj.conj()).scale(0.5);
                let o = (zk - zj.conj()).mul_neg_i().scale(0.5);
                out[k] = e + self.twiddles[k] * o;
                out[j] = e.conj() + self.twiddles[j] * o.conj();
            }
        }
    }
}

/// Memoized per-length [`RealPlan`]s, shared across threads — the r2c
/// counterpart of [`PlanCache`].
pub struct RealPlanCache {
    plans: Mutex<HashMap<usize, Arc<RealPlan>>>,
}

impl RealPlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self { plans: Mutex::new(HashMap::new()) }
    }

    /// Process-wide cache.
    pub fn global() -> &'static RealPlanCache {
        static CACHE: OnceLock<RealPlanCache> = OnceLock::new();
        CACHE.get_or_init(RealPlanCache::new)
    }

    /// The memoized plan for even length `n`, building it on first
    /// request (built outside the lock, first insert wins — the same
    /// discipline as [`PlanCache::plan`]).
    pub fn plan(&self, n: usize) -> Arc<RealPlan> {
        if let Some(plan) = self.plans.lock().unwrap().get(&n) {
            return Arc::clone(plan);
        }
        let built = Arc::new(RealPlan::new(n));
        match self.plans.lock().unwrap().entry(n) {
            Entry::Occupied(e) => Arc::clone(e.get()),
            Entry::Vacant(e) => Arc::clone(e.insert(built)),
        }
    }
}

impl Default for RealPlanCache {
    fn default() -> Self {
        Self::new()
    }
}

/// r2c of one real row to the packed half-spectrum (`n/2` slots). Even
/// lengths only; loops should plan once via [`RealPlanCache`] and use
/// [`RealPlan::execute_packed`].
pub fn rfft_packed(x: &[f32]) -> Vec<Complex32> {
    let plan = RealPlanCache::global().plan(x.len());
    let mut out = vec![Complex32::ZERO; plan.packed_len()];
    FftScratch::with_thread_local(|scratch| plan.execute_packed(x, &mut out, scratch));
    out
}

/// Expand a packed half-spectrum (`n/2` slots) to the full `n/2 + 1`
/// Hermitian-unique bins: slot 0 splits into the purely real DC and
/// Nyquist bins.
pub fn unpack_half_spectrum(packed: &[Complex32]) -> Vec<Complex32> {
    let m = packed.len();
    assert!(m >= 1, "packed spectrum must be non-empty");
    let mut out = Vec::with_capacity(m + 1);
    out.push(Complex32::new(packed[0].re, 0.0));
    out.extend_from_slice(&packed[1..]);
    out.push(Complex32::new(packed[0].im, 0.0));
    out
}

/// Inverse of [`unpack_half_spectrum`]: fold `n/2 + 1` bins back into
/// `n/2` packed slots (the DC and Nyquist imaginary parts, zero for any
/// real input's spectrum, are dropped).
pub fn pack_half_spectrum(spec: &[Complex32]) -> Vec<Complex32> {
    assert!(spec.len() >= 2, "need at least the DC and Nyquist bins");
    let m = spec.len() - 1;
    let mut out = Vec::with_capacity(m);
    out.push(Complex32::new(spec[0].re, spec[m].re));
    out.extend_from_slice(&spec[1..m]);
    out
}

/// r2c transform of a real signal to its `n/2 + 1` Hermitian-unique
/// bins. Even lengths run the packed half-complex trick; odd lengths
/// (including primes — the Bluestein path for primes > 61) run a
/// complex transform of the real signal and keep the unique half.
///
/// ```
/// use hpx_fft::fft::real::{irfft, rfft};
///
/// let x = [1.0f32, 2.0, 3.0, 4.0, 3.0, 1.0];
/// let spec = rfft(&x);
/// assert_eq!(spec.len(), 4); // 6/2 + 1 bins
/// assert!(spec[0].im.abs() < 1e-6 && spec[3].im.abs() < 1e-6);
/// let back = irfft(&spec, 6);
/// for (a, b) in back.iter().zip(&x) {
///     assert!((a - b).abs() < 1e-5);
/// }
/// ```
pub fn rfft(x: &[f32]) -> Vec<Complex32> {
    let n = x.len();
    assert!(n >= 1, "rfft requires a non-empty signal");
    if n == 1 {
        return vec![Complex32::new(x[0], 0.0)];
    }
    if n % 2 == 0 {
        return unpack_half_spectrum(&rfft_packed(x));
    }
    // Odd lengths: complex transform of the real signal (primes > 61 hit
    // the Bluestein engine), keep bins 0..n/2.
    let mut buf: Vec<Complex32> = x.iter().map(|&v| Complex32::new(v, 0.0)).collect();
    PlanCache::global().plan(n, Direction::Forward).execute(&mut buf);
    buf.truncate(spectrum_len(n));
    buf
}

/// c2r inverse of [`rfft`]: reconstruct the length-`n` real signal from
/// its `n/2 + 1` Hermitian-unique bins (the mirrored half is derived by
/// conjugate symmetry, then one `1/n`-normalized inverse plan runs).
pub fn irfft(spec: &[Complex32], n: usize) -> Vec<f32> {
    assert!(n >= 1, "irfft requires n >= 1");
    assert_eq!(spec.len(), spectrum_len(n), "expected {} bins for n = {n}", spectrum_len(n));
    if n == 1 {
        return vec![spec[0].re];
    }
    let mut full = vec![Complex32::ZERO; n];
    full[..spec.len()].copy_from_slice(spec);
    for j in spec.len()..n {
        full[j] = spec[n - j].conj();
    }
    PlanCache::global().plan(n, Direction::Inverse).execute(&mut full);
    full.into_iter().map(|c| c.re).collect()
}

/// r2c every length-`n` real row of `src` (`rows × n`, row-major) into
/// packed half-spectra written to `out` (`rows × n/2`, row-major),
/// fanning contiguous row bands over the shared worker pool — the
/// real-domain counterpart of [`crate::fft::batch::fft_rows_parallel`],
/// and the stage-1 kernel of the real-domain distributed FFT. Rows are
/// independent, so results are bitwise identical for any band split and
/// thread count.
pub fn rfft_rows_packed_into(src: &[f32], n: usize, out: &mut [Complex32], nthreads: usize) {
    assert!(n >= 2 && n % 2 == 0, "packed row batches need even n >= 2, got {n}");
    assert!(src.len() % n == 0, "source not a whole number of rows");
    let rows = src.len() / n;
    let m = n / 2;
    assert_eq!(out.len(), rows * m, "output must be rows × n/2");
    if rows == 0 {
        return;
    }
    let plan = RealPlanCache::global().plan(n);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let nthreads = nthreads.min(hw).max(1);
    if nthreads == 1 || rows == 1 {
        FftScratch::with_thread_local(|scratch| {
            for (r, orow) in out.chunks_exact_mut(m).enumerate() {
                plan.execute_packed(&src[r * n..(r + 1) * n], orow, scratch);
            }
        });
        return;
    }
    let rows_per_chunk = rows.div_ceil(nthreads);
    parallel_chunks_mut(out, rows_per_chunk * m, nthreads, |band_idx, band| {
        // Each worker thread reuses its own persistent scratch.
        FftScratch::with_thread_local(|scratch| {
            for (k, orow) in band.chunks_exact_mut(m).enumerate() {
                let r = band_idx * rows_per_chunk + k;
                plan.execute_packed(&src[r * n..(r + 1) * n], orow, scratch);
            }
        });
    });
}

/// Allocating convenience wrapper over [`rfft_rows_packed_into`]
/// (single-threaded — serial references and tests).
pub fn rfft_rows_packed(src: &[f32], n: usize) -> Vec<Complex32> {
    let rows = src.len() / n;
    let mut out = vec![Complex32::ZERO; rows * (n / 2)];
    rfft_rows_packed_into(src, n, &mut out, 1);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::util::rng::Pcg32;

    fn random_real(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| rng.next_signal()).collect()
    }

    /// O(n²) f64 oracle: complexify, DFT, keep the unique half.
    fn oracle_half(x: &[f32]) -> Vec<Complex32> {
        let cx: Vec<Complex32> = x.iter().map(|&v| Complex32::new(v, 0.0)).collect();
        let mut full = dft(&cx);
        full.truncate(spectrum_len(x.len()));
        full
    }

    /// `atol + rtol·|expected|` per component (the [`assert_close`]
    /// convention of `util::testkit`).
    fn assert_spec_close(a: &[Complex32], b: &[Complex32], tol: f32, ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: bin count");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let tol_re = tol + tol * y.re.abs();
            let tol_im = tol + tol * y.im.abs();
            assert!(
                (x.re - y.re).abs() < tol_re && (x.im - y.im).abs() < tol_im,
                "{ctx}: bin {i}: {x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn rfft_matches_oracle_even_lengths() {
        for &n in &[2usize, 4, 6, 8, 12, 24, 64, 96, 1000] {
            let x = random_real(n as u64, n);
            assert_spec_close(&rfft(&x), &oracle_half(&x), 2e-3, &format!("n={n}"));
        }
    }

    /// The satellite edge case: odd first-axis lengths, including a
    /// prime > 61 that routes the fallback through the Bluestein engine.
    #[test]
    fn rfft_matches_oracle_odd_and_bluestein_lengths() {
        use crate::fft::plan::Plan;
        assert!(Plan::new(67, Direction::Forward).uses_bluestein());
        for &n in &[3usize, 5, 9, 13, 15, 67, 101] {
            let x = random_real(1000 + n as u64, n);
            assert_spec_close(&rfft(&x), &oracle_half(&x), 2e-3, &format!("n={n}"));
        }
    }

    /// The other satellite edge case: n = 1 rows are the identity.
    #[test]
    fn rfft_length_one_is_identity() {
        let spec = rfft(&[4.5]);
        assert_eq!(spec, vec![Complex32::new(4.5, 0.0)]);
        assert_eq!(irfft(&spec, 1), vec![4.5]);
    }

    #[test]
    fn dc_and_nyquist_bins_are_real() {
        for &n in &[2usize, 8, 12, 96] {
            let x = random_real(7 + n as u64, n);
            let spec = rfft(&x);
            assert!(spec[0].im.abs() < 1e-5, "n={n}: DC bin must be real");
            assert!(spec[n / 2].im.abs() < 1e-5, "n={n}: Nyquist bin must be real");
        }
    }

    #[test]
    fn roundtrip_even_and_odd() {
        for &n in &[1usize, 2, 3, 8, 12, 13, 24, 67, 96] {
            let x = random_real(55 + n as u64, n);
            let back = irfft(&rfft(&x), n);
            for (i, (a, b)) in back.iter().zip(&x).enumerate() {
                assert!((a - b).abs() < 1e-4, "n={n} sample {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_layout_roundtrips() {
        let x = random_real(3, 24);
        let packed = rfft_packed(&x);
        assert_eq!(packed.len(), 12);
        let spec = unpack_half_spectrum(&packed);
        assert_eq!(spec.len(), 13);
        assert_spec_close(&spec, &rfft(&x), 1e-6, "unpacked == rfft");
        assert_eq!(pack_half_spectrum(&spec), packed);
    }

    #[test]
    fn impulse_transforms_to_flat_spectrum() {
        let mut x = vec![0.0f32; 16];
        x[0] = 1.0;
        for bin in rfft(&x) {
            assert!((bin.re - 1.0).abs() < 1e-6 && bin.im.abs() < 1e-6);
        }
    }

    #[test]
    fn row_batches_match_per_row_any_thread_count() {
        let (rows, n) = (7, 24);
        let src = random_real(11, rows * n);
        let serial = rfft_rows_packed(&src, n);
        for nthreads in [1usize, 2, 4, 8] {
            let mut out = vec![Complex32::ZERO; rows * (n / 2)];
            rfft_rows_packed_into(&src, n, &mut out, nthreads);
            assert_eq!(out, serial, "nthreads={nthreads}");
        }
        // Band splits (the async wire-chunk schedule) are bitwise stable.
        for band in [1usize, 2, 3, 5] {
            let mut banded = vec![Complex32::ZERO; rows * (n / 2)];
            let mut r = 0;
            while r < rows {
                let hi = (r + band).min(rows);
                rfft_rows_packed_into(
                    &src[r * n..hi * n],
                    n,
                    &mut banded[r * (n / 2)..hi * (n / 2)],
                    2,
                );
                r = hi;
            }
            assert_eq!(banded, serial, "band={band}");
        }
    }

    #[test]
    fn real_plan_cache_memoizes() {
        let a = RealPlanCache::global().plan(48);
        let b = RealPlanCache::global().plan(48);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 48);
        assert_eq!(a.packed_len(), 24);
        assert!(!a.is_empty());
    }

    #[test]
    #[should_panic(expected = "even n")]
    fn real_plan_rejects_odd_length() {
        RealPlan::new(9);
    }

    #[test]
    fn spectrum_len_formula() {
        assert_eq!(spectrum_len(1), 1);
        assert_eq!(spectrum_len(2), 2);
        assert_eq!(spectrum_len(7), 4);
        assert_eq!(spectrum_len(8), 5);
    }
}
