//! Native FFT substrate — the FFTW3 stand-in.
//!
//! The paper's compute building block is FFTW3's 1-D complex transform,
//! applied row-wise to a 2-D grid. This module provides that substrate
//! from scratch:
//!
//! - [`Complex32`] — `repr(C)` complex type, byte-compatible with
//!   interleaved `f32` pairs on the wire,
//! - [`Plan`] — per-length plan (twiddle table + bit-reversal permutation),
//!   mirroring `fftw_plan`, cached in [`plan::PlanCache`],
//! - iterative radix-2 DIT kernel ([`radix2`]),
//! - [`dft`] — the O(n²) oracle used only by tests,
//! - [`batch`] — thread-parallel row-batched transforms (the "+pthreads"
//!   in the paper's FFTW3 MPI+pthreads reference).
//!
//! All transforms are unnormalized forward / `1/n`-normalized inverse,
//! matching both FFTW and `jnp.fft` conventions so the three compute
//! engines (native, PJRT artifact, python reference) agree to f32
//! tolerance.

pub mod batch;
pub mod complex;
pub mod dft;
pub mod plan;
pub mod radix2;
pub mod twiddle;

pub use batch::fft_rows_parallel;
pub use complex::Complex32;
pub use plan::{Direction, Plan, PlanCache};
