//! Native FFT substrate — the FFTW3 stand-in.
//!
//! The paper's compute building block is FFTW3's 1-D complex transform,
//! applied row-wise to a 2-D grid. This module provides that substrate
//! from scratch, for **any transform length** (the planner is
//! mixed-radix, not radix-2-only):
//!
//! - [`Complex32`] — `repr(C)` complex type, byte-compatible with
//!   interleaved `f32` pairs on the wire,
//! - [`Plan`] — per-`(length, direction)` plan mirroring `fftw_plan`:
//!   powers of two run a split-radix kernel over the lane-parallel
//!   [`simd`] butterflies (AVX2/NEON dispatched at runtime, scalar
//!   fallback — [`radix2`] keeps the iterative reference kernel), every
//!   other length is factorized into radix-4 / radix-2 / odd-prime
//!   Cooley–Tukey stages (the private `mixed` engine) with a Bluestein
//!   chirp-z fallback for large prime factors (`bluestein`); plans are
//!   memoized in the process-wide [`plan::PlanCache`], and twiddle
//!   tables are shared across plans via [`twiddle::TwiddleCache`],
//! - [`dft`] — the O(n²) oracle used only by tests,
//! - [`batch`] — row-batched transforms executed in parallel on the
//!   shared [`crate::task::ThreadPool`] (the "+pthreads" in the paper's
//!   FFTW3 MPI+pthreads reference),
//! - [`real`] — r2c/c2r transforms: the packed half-complex trick over
//!   the same plan engine, so real-input grids (the paper's reference
//!   workload) ship half the spectral payload.
//!
//! All transforms are unnormalized forward / `1/n`-normalized inverse,
//! matching both FFTW and `jnp.fft` conventions so the three compute
//! engines (native, PJRT artifact, python reference) agree to f32
//! tolerance.

pub mod batch;
pub mod complex;
pub mod dft;
pub mod plan;
pub mod radix2;
pub mod real;
pub mod simd;
pub mod twiddle;

mod bluestein;
mod mixed;
mod splitradix;

pub use batch::fft_rows_parallel;
pub use complex::Complex32;
pub use plan::{Direction, FftScratch, Plan, PlanCache};
pub use real::{irfft, rfft, RealPlan, RealPlanCache};
