//! Naive O(n²) DFT — the correctness oracle.
//!
//! Direct evaluation of `X[k] = Σ_j x[j]·e^{-2πijk/n}` with f64
//! accumulation. Never used on any hot path; only by tests comparing the
//! fast kernels against ground truth.

use super::complex::Complex32;

/// Forward DFT (unnormalized), any length.
pub fn dft(x: &[Complex32]) -> Vec<Complex32> {
    transform(x, -1.0, 1.0)
}

/// Inverse DFT (1/n-normalized), any length.
pub fn idft(x: &[Complex32]) -> Vec<Complex32> {
    let n = x.len().max(1);
    transform(x, 1.0, 1.0 / n as f64)
}

fn transform(x: &[Complex32], sign: f64, norm: f64) -> Vec<Complex32> {
    let n = x.len();
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let mut re = 0.0f64;
        let mut im = 0.0f64;
        for (j, &v) in x.iter().enumerate() {
            let theta = sign * 2.0 * std::f64::consts::PI * (j * k % n.max(1)) as f64 / n as f64;
            let (s, c) = theta.sin_cos();
            re += v.re as f64 * c - v.im as f64 * s;
            im += v.re as f64 * s + v.im as f64 * c;
        }
        out.push(Complex32::new((re * norm) as f32, (im * norm) as f32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    #[test]
    fn impulse_gives_constant() {
        let mut x = vec![Complex32::ZERO; 8];
        x[0] = Complex32::ONE;
        let y = dft(&x);
        for v in y {
            assert!((v.re - 1.0).abs() < 1e-6 && v.im.abs() < 1e-6);
        }
    }

    #[test]
    fn constant_gives_impulse() {
        let x = vec![Complex32::ONE; 8];
        let y = dft(&x);
        assert!((y[0].re - 8.0).abs() < 1e-5);
        for v in &y[1..] {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn single_tone_lands_in_bin() {
        let n = 16;
        let bin = 3;
        let x: Vec<Complex32> = (0..n)
            .map(|j| {
                let theta = 2.0 * std::f64::consts::PI * (bin * j) as f64 / n as f64;
                Complex32::new(theta.cos() as f32, theta.sin() as f32)
            })
            .collect();
        let y = dft(&x);
        assert!((y[bin].re - n as f32).abs() < 1e-3, "bin energy {}", y[bin].re);
        for (k, v) in y.iter().enumerate() {
            if k != bin {
                assert!(v.abs() < 1e-3, "leak at {k}: {}", v.abs());
            }
        }
    }

    #[test]
    fn idft_inverts_dft() {
        let x: Vec<Complex32> =
            (0..12).map(|i| Complex32::new(i as f32 * 0.5 - 2.0, (i * i) as f32 * 0.1)).collect();
        let back = idft(&dft(&x));
        assert_close(&flat(&back), &flat(&x), 1e-4, 1e-4);
    }

    #[test]
    fn linearity() {
        let a: Vec<Complex32> = (0..10).map(|i| Complex32::new(i as f32, -(i as f32))).collect();
        let b: Vec<Complex32> = (0..10).map(|i| Complex32::new(1.0, i as f32 * 0.3)).collect();
        let sum: Vec<Complex32> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
        let lhs = dft(&sum);
        let rhs: Vec<Complex32> =
            dft(&a).iter().zip(dft(&b).iter()).map(|(&x, &y)| x + y).collect();
        assert_close(&flat(&lhs), &flat(&rhs), 1e-4, 1e-4);
    }

    #[test]
    fn empty_input() {
        assert!(dft(&[]).is_empty());
        assert!(idft(&[]).is_empty());
    }

    #[test]
    fn non_power_of_two_length_works() {
        // The oracle handles any n — as does the planned fast path now;
        // the plan/mixed/bluestein tests pin the two against each other.
        let x: Vec<Complex32> = (0..7).map(|i| Complex32::new(i as f32, 0.0)).collect();
        let back = idft(&dft(&x));
        assert_close(&flat(&back), &flat(&x), 1e-4, 1e-4);
    }
}
