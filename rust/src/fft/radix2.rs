//! Iterative radix-2 decimation-in-time FFT kernel (the power-of-two
//! fast path of the mixed-radix planner).
//!
//! Classic Cooley–Tukey: bit-reversal permutation, then `log2 n` butterfly
//! stages over a precomputed half-circle twiddle table. The first two
//! stages are specialized (twiddles 1 and ±i need no multiplies), which is
//! where most of the win over the textbook loop comes from — see
//! EXPERIMENTS.md §Perf.
//!
//! Operates in place on `&mut [Complex32]`; the caller owns planning
//! (tables come from [`crate::fft::Plan`]). Both directions run the same
//! butterfly network: the inverse uses a conjugated twiddle table
//! ([`crate::fft::twiddle::half_table`] with `inverse = true`) via
//! [`fft_in_place_dir`], with the `1/n` normalization applied by the
//! plan, not here.

use super::complex::Complex32;
use super::simd;
use super::twiddle::TwiddleCache;

/// Precomputed iterative radix-2 kernel: shared-cache twiddle tables,
/// a cache-blocked bit-reversal swap list, and per-stage *contiguous*
/// twiddle tables sized for [`simd::butterfly_radix2`].
///
/// This is the planned counterpart of [`fft_in_place_dir`] and computes
/// bitwise-identical results (asserted in the tests below): the swap
/// list applies the same disjoint transpositions, the specialized
/// first two stages are copied verbatim, and the SIMD butterfly uses a
/// mul/addsub complex product that rounds exactly like the scalar
/// formula. Bluestein's convolution kernel builds on this, which keeps
/// chirp-z results bit-identical to the legacy path.
///
/// Direction is baked in at build time; no normalization is applied
/// (the planner scales inverse results once).
pub struct Radix2Tables {
    n: usize,
    inverse: bool,
    /// Bit-reversal as disjoint `i < j` transpositions, sorted by
    /// destination cache line (`j / 64`) so the scattered side of each
    /// swap walks memory mostly forward instead of hopping across the
    /// whole array in bit-reversed order.
    swaps: Vec<(u32, u32)>,
    /// `stage_tw[s][k] = w^{k·(n/len)}` for stage `len = 8 << s` — the
    /// stage's twiddles de-strided into a contiguous table so the SIMD
    /// butterfly streams them with unit stride.
    stage_tw: Vec<Vec<Complex32>>,
}

impl Radix2Tables {
    /// Build tables for power-of-two `n >= 2`; twiddle and bit-reversal
    /// tables are shared through the process-wide
    /// [`TwiddleCache`].
    pub fn new(n: usize, inverse: bool) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "radix-2 tables need power-of-two n >= 2, got {n}");
        let cache = TwiddleCache::global();
        let bitrev = cache.bitrev(n);
        let mut swaps: Vec<(u32, u32)> = bitrev
            .iter()
            .enumerate()
            .filter(|&(i, &j)| (i as u32) < j)
            .map(|(i, &j)| (i as u32, j))
            .collect();
        swaps.sort_by_key(|&(i, j)| (j / 64, i));
        let half = cache.half(n, inverse);
        let mut stage_tw = Vec::new();
        let mut len = 8;
        while len <= n {
            let tstride = n / len;
            stage_tw.push((0..len / 2).map(|k| half[k * tstride]).collect());
            len <<= 1;
        }
        Self { n, inverse, swaps, stage_tw }
    }

    /// Transform length the tables were built for.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Always false — a plan for `n >= 2` transforms at least two points.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// In-place transform of exactly [`Radix2Tables::len`] points.
    /// Unnormalized in both directions, like [`fft_in_place_dir`].
    pub fn execute(&self, x: &mut [Complex32]) {
        assert_eq!(x.len(), self.n, "radix-2 tables are for length {}, got {}", self.n, x.len());
        for &(i, j) in &self.swaps {
            x.swap(i as usize, j as usize);
        }

        // Stage 1 (len=2): butterflies with twiddle 1.
        for pair in x.chunks_exact_mut(2) {
            let (a, b) = (pair[0], pair[1]);
            pair[0] = a + b;
            pair[1] = a - b;
        }
        if self.n == 2 {
            return;
        }

        // Stage 2 (len=4): twiddles are 1 and ∓i (direction-dependent).
        for quad in x.chunks_exact_mut(4) {
            let (a, b) = (quad[0], quad[2]);
            quad[0] = a + b;
            quad[2] = a - b;
            let rot = if self.inverse { quad[3].mul_i() } else { quad[3].mul_neg_i() };
            let (c, d) = (quad[1], rot);
            quad[1] = c + d;
            quad[3] = c - d;
        }

        // General stages (len = 8, 16, ..., n): lane-parallel butterflies
        // over contiguous per-stage twiddle tables.
        let mut len = 8;
        for tw in &self.stage_tw {
            for block in x.chunks_exact_mut(len) {
                let (lo, hi) = block.split_at_mut(len / 2);
                simd::butterfly_radix2(lo, hi, tw);
            }
            len <<= 1;
        }
    }
}

/// In-place forward FFT. `twiddles` is `forward_table(n)`, `bitrev` is
/// `bit_reverse_table(n)`.
pub fn fft_in_place(x: &mut [Complex32], twiddles: &[Complex32], bitrev: &[u32]) {
    butterflies::<false>(x, twiddles, bitrev);
}

/// Direction-explicit in-place transform. `twiddles` must be the
/// direction-matched half-circle table (`half_table(n, inverse)`). No
/// normalization is applied in either direction — the planner scales
/// inverse results by `1/n` once, after all stages.
pub fn fft_in_place_dir(
    x: &mut [Complex32],
    twiddles: &[Complex32],
    bitrev: &[u32],
    inverse: bool,
) {
    if inverse {
        butterflies::<true>(x, twiddles, bitrev);
    } else {
        butterflies::<false>(x, twiddles, bitrev);
    }
}

fn butterflies<const INVERSE: bool>(x: &mut [Complex32], twiddles: &[Complex32], bitrev: &[u32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(twiddles.len(), n / 2);
    debug_assert_eq!(bitrev.len(), n);
    if n <= 1 {
        return;
    }

    permute(x, bitrev);

    // Stage 1 (len=2): butterflies with twiddle 1.
    let mut pair = 0;
    while pair < n {
        let (a, b) = (x[pair], x[pair + 1]);
        x[pair] = a + b;
        x[pair + 1] = a - b;
        pair += 2;
    }
    if n == 2 {
        return;
    }

    // Stage 2 (len=4): twiddles are 1 and ∓i (direction-dependent).
    let mut base = 0;
    while base < n {
        let (a, b) = (x[base], x[base + 2]);
        x[base] = a + b;
        x[base + 2] = a - b;
        let rot = if INVERSE { x[base + 3].mul_i() } else { x[base + 3].mul_neg_i() };
        let (c, d) = (x[base + 1], rot);
        x[base + 1] = c + d;
        x[base + 3] = c - d;
        base += 4;
    }

    // General stages (len = 8, 16, ..., n).
    //
    // §Perf: two layouts per stage (see EXPERIMENTS.md §Perf L3-1).
    // Early stages (many small blocks) walk `off` in the OUTER loop so
    // each twiddle is loaded once and reused across every block — the
    // naive inner-`off` order strides the twiddle table by n/len and
    // takes a cache miss per butterfly when blocks are small. Late
    // stages (few big blocks) keep `off` inner, where the twiddle stride
    // n/len is small and the x-access pattern is contiguous. Split
    // borrows (`split_at_mut`) drop the bounds checks from the inner
    // loops.
    let mut len = 8;
    while len <= n {
        let half = len / 2;
        let tstride = n / len;
        if len <= 64 && tstride > 1 {
            // off outer, blocks inner: one twiddle load per `off`.
            for off in 0..half {
                let w = twiddles[off * tstride];
                let mut base = 0;
                while base < n {
                    let a = x[base + off];
                    let b = x[base + off + half] * w;
                    x[base + off] = a + b;
                    x[base + off + half] = a - b;
                    base += len;
                }
            }
        } else {
            for block in x.chunks_exact_mut(len) {
                let (lo, hi) = block.split_at_mut(half);
                let mut tidx = 0;
                for (a_ref, b_ref) in lo.iter_mut().zip(hi.iter_mut()) {
                    let w = twiddles[tidx];
                    let a = *a_ref;
                    let b = *b_ref * w;
                    *a_ref = a + b;
                    *b_ref = a - b;
                    tidx += tstride;
                }
            }
        }
        len <<= 1;
    }
}

/// In-place inverse FFT (1/n-normalized) via the conjugation identity:
/// `ifft(x) = conj(fft(conj(x))) / n`. Takes the *forward* tables; the
/// planner's direct inverse path ([`fft_in_place_dir`] over a conjugated
/// table) computes the same result with two fewer passes over the data.
pub fn ifft_in_place(x: &mut [Complex32], twiddles: &[Complex32], bitrev: &[u32]) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    for v in x.iter_mut() {
        *v = v.conj();
    }
    fft_in_place(x, twiddles, bitrev);
    let scale = 1.0 / n as f32;
    for v in x.iter_mut() {
        *v = v.conj().scale(scale);
    }
}

#[inline]
fn permute(x: &mut [Complex32], bitrev: &[u32]) {
    for (i, &j) in bitrev.iter().enumerate() {
        let j = j as usize;
        if i < j {
            x.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::{dft, idft};
    use crate::fft::twiddle::{bit_reverse_table, forward_table};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::{assert_close, check};

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_signal(rng: &mut Pcg32, n: usize) -> Vec<Complex32> {
        (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    fn run_fft(x: &[Complex32]) -> Vec<Complex32> {
        let n = x.len();
        let (tw, br) = (forward_table(n), bit_reverse_table(n));
        let mut y = x.to_vec();
        fft_in_place(&mut y, &tw, &br);
        y
    }

    #[test]
    fn matches_oracle_all_small_sizes() {
        check(
            0xF0F0,
            40,
            |rng| {
                let log2n = rng.range(1, 10); // n in 2..512
                random_signal(rng, 1 << log2n)
            },
            |x| {
                let fast = run_fft(x);
                let slow = dft(x);
                assert_close(&flat(&fast), &flat(&slow), 1e-3, 1e-3);
            },
        );
    }

    #[test]
    fn roundtrip_identity() {
        check(
            0xBEEF,
            30,
            |rng| { let n = 1 << rng.range(1, 12); random_signal(rng, n) },
            |x| {
                let n = x.len();
                let (tw, br) = (forward_table(n), bit_reverse_table(n));
                let mut y = x.clone();
                fft_in_place(&mut y, &tw, &br);
                ifft_in_place(&mut y, &tw, &br);
                assert_close(&flat(&y), &flat(x), 1e-4, 1e-3);
            },
        );
    }

    #[test]
    fn parseval_energy_conservation() {
        check(
            0xCAFE,
            20,
            |rng| { let n = 1 << rng.range(2, 11); random_signal(rng, n) },
            |x| {
                let y = run_fft(x);
                let ex: f64 = x.iter().map(|c| c.norm_sqr() as f64).sum();
                let ey: f64 = y.iter().map(|c| c.norm_sqr() as f64).sum::<f64>() / x.len() as f64;
                assert!(
                    (ex - ey).abs() <= 1e-3 * ex.max(1.0),
                    "Parseval violated: time {ex} vs freq {ey}"
                );
            },
        );
    }

    #[test]
    fn linearity_property() {
        check(
            0x11,
            20,
            |rng| {
                let n = 1 << rng.range(2, 9);
                (random_signal(rng, n), random_signal(rng, n), rng.next_signal())
            },
            |(a, b, alpha)| {
                let combo: Vec<Complex32> =
                    a.iter().zip(b).map(|(&x, &y)| x.scale(*alpha) + y).collect();
                let lhs = run_fft(&combo);
                let fa = run_fft(a);
                let fb = run_fft(b);
                let rhs: Vec<Complex32> =
                    fa.iter().zip(&fb).map(|(&x, &y)| x.scale(*alpha) + y).collect();
                assert_close(&flat(&lhs), &flat(&rhs), 1e-3, 1e-2);
            },
        );
    }

    #[test]
    fn shift_theorem() {
        // x[(j+1) mod n] ⇒ X[k]·e^{+2πik/n}
        let mut rng = Pcg32::new(77);
        let n = 64;
        let x = random_signal(&mut rng, n);
        let mut shifted = x.clone();
        shifted.rotate_left(1);
        let fx = run_fft(&x);
        let fs = run_fft(&shifted);
        for k in 0..n {
            let phase = Complex32::cis_f64(2.0 * std::f64::consts::PI * k as f64 / n as f64);
            let expect = fx[k] * phase;
            assert!(
                (expect - fs[k]).abs() < 1e-3,
                "bin {k}: {:?} vs {:?}",
                expect,
                fs[k]
            );
        }
    }

    #[test]
    fn size_one_and_two() {
        let (tw1, br1) = (forward_table(2), bit_reverse_table(2));
        let mut one = vec![Complex32::new(3.0, -1.0)];
        fft_in_place(&mut one, &[], &[0]);
        assert_eq!(one[0], Complex32::new(3.0, -1.0));

        let mut two = vec![Complex32::new(1.0, 0.0), Complex32::new(2.0, 0.0)];
        fft_in_place(&mut two, &tw1, &br1);
        assert_close(&flat(&two), &[3.0, 0.0, -1.0, 0.0], 1e-6, 0.0);
    }

    #[test]
    fn ifft_matches_oracle() {
        let mut rng = Pcg32::new(5);
        let x = random_signal(&mut rng, 128);
        let (tw, br) = (forward_table(128), bit_reverse_table(128));
        let mut y = x.clone();
        ifft_in_place(&mut y, &tw, &br);
        let slow = idft(&x);
        assert_close(&flat(&y), &flat(&slow), 1e-4, 1e-3);
    }

    #[test]
    fn planned_tables_bitwise_match_legacy_kernel() {
        use crate::fft::twiddle::half_table;
        let mut rng = Pcg32::new(9);
        for log2n in [1usize, 2, 3, 4, 7, 10] {
            let n = 1 << log2n;
            for inverse in [false, true] {
                let x = random_signal(&mut rng, n);
                let tables = Radix2Tables::new(n, inverse);
                assert_eq!(tables.len(), n);
                assert!(!tables.is_empty());
                let mut planned = x.clone();
                tables.execute(&mut planned);
                let mut legacy = x.clone();
                let (tw, br) = (half_table(n, inverse), bit_reverse_table(n));
                fft_in_place_dir(&mut legacy, &tw, &br, inverse);
                assert_eq!(flat(&planned), flat(&legacy), "n={n} inverse={inverse}");
            }
        }
    }

    #[test]
    fn direction_explicit_inverse_matches_conjugation_wrapper() {
        use crate::fft::twiddle::half_table;
        let mut rng = Pcg32::new(6);
        for log2n in [1usize, 2, 3, 5, 8] {
            let n = 1 << log2n;
            let x = random_signal(&mut rng, n);
            let br = bit_reverse_table(n);

            // Reference: conjugation identity over the forward table.
            let mut via_conj = x.clone();
            ifft_in_place(&mut via_conj, &forward_table(n), &br);

            // Direct: conjugated table, direction flag, manual 1/n scale.
            let mut direct = x.clone();
            fft_in_place_dir(&mut direct, &half_table(n, true), &br, true);
            let scale = 1.0 / n as f32;
            for v in direct.iter_mut() {
                *v = v.scale(scale);
            }
            assert_close(&flat(&direct), &flat(&via_conj), 1e-4, 1e-4);
        }
    }
}
