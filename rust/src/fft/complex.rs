//! `Complex32`: interleaved single-precision complex numbers.
//!
//! `repr(C)` with `re` first, so a `&[Complex32]` has exactly the memory
//! layout of interleaved `f32` pairs — the wire format of FFT chunk
//! payloads and the layout FFTW uses for `fftwf_complex`.

use std::ops::{Add, AddAssign, Mul, Neg, Sub, SubAssign};

/// A single-precision complex number.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct Complex32 {
    /// Real part.
    pub re: f32,
    /// Imaginary part.
    pub im: f32,
}

impl Complex32 {
    /// The additive identity, `0 + 0i`.
    pub const ZERO: Complex32 = Complex32 { re: 0.0, im: 0.0 };
    /// The multiplicative identity, `1 + 0i`.
    pub const ONE: Complex32 = Complex32 { re: 1.0, im: 0.0 };
    /// The imaginary unit, `0 + 1i`.
    pub const I: Complex32 = Complex32 { re: 0.0, im: 1.0 };

    /// Construct from real and imaginary parts.
    #[inline]
    pub const fn new(re: f32, im: f32) -> Self {
        Self { re, im }
    }

    /// e^{iθ} = cos θ + i sin θ.
    #[inline]
    pub fn cis(theta: f32) -> Self {
        Self { re: theta.cos(), im: theta.sin() }
    }

    /// Higher-precision unit phasor from an f64 angle (twiddle tables are
    /// computed in f64 and rounded once — matches FFTW's practice).
    #[inline]
    pub fn cis_f64(theta: f64) -> Self {
        Self { re: theta.cos() as f32, im: theta.sin() as f32 }
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    /// Squared modulus `re² + im²`.
    #[inline]
    pub fn norm_sqr(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus (absolute value).
    #[inline]
    pub fn abs(self) -> f32 {
        self.norm_sqr().sqrt()
    }

    /// Multiply by a real scalar.
    #[inline]
    pub fn scale(self, s: f32) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    /// Multiply by i (90° rotation) without a full complex multiply.
    #[inline]
    pub fn mul_i(self) -> Self {
        Self { re: -self.im, im: self.re }
    }

    /// Multiply by -i.
    #[inline]
    pub fn mul_neg_i(self) -> Self {
        Self { re: self.im, im: -self.re }
    }
}

impl Add for Complex32 {
    type Output = Complex32;
    #[inline]
    fn add(self, o: Complex32) -> Complex32 {
        Complex32 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for Complex32 {
    #[inline]
    fn add_assign(&mut self, o: Complex32) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for Complex32 {
    type Output = Complex32;
    #[inline]
    fn sub(self, o: Complex32) -> Complex32 {
        Complex32 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl SubAssign for Complex32 {
    #[inline]
    fn sub_assign(&mut self, o: Complex32) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl Mul for Complex32 {
    type Output = Complex32;
    #[inline]
    fn mul(self, o: Complex32) -> Complex32 {
        Complex32 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for Complex32 {
    type Output = Complex32;
    #[inline]
    fn neg(self) -> Complex32 {
        Complex32 { re: -self.re, im: -self.im }
    }
}

/// View a complex slice as interleaved f32s (zero-copy; layout guaranteed
/// by `repr(C)`).
pub fn as_f32_slice(xs: &[Complex32]) -> &[f32] {
    // SAFETY: Complex32 is repr(C) { f32, f32 } — size 8, align 4; any
    // [Complex32; n] is bit-identical to [f32; 2n].
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const f32, xs.len() * 2) }
}

/// Mutable interleaved view.
pub fn as_f32_slice_mut(xs: &mut [Complex32]) -> &mut [f32] {
    // SAFETY: see `as_f32_slice`.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut f32, xs.len() * 2) }
}

/// Interpret interleaved f32s as complex numbers (copies).
pub fn from_interleaved(xs: &[f32]) -> Vec<Complex32> {
    assert!(xs.len() % 2 == 0, "interleaved buffer must have even length");
    xs.chunks_exact(2).map(|p| Complex32::new(p[0], p[1])).collect()
}

/// View a complex slice as raw bytes (zero-copy). On little-endian
/// targets this is bit-identical to the wire format (interleaved f32 LE
/// pairs) — the send path exploits that to serialize with a single
/// memcpy (§Perf).
#[cfg(target_endian = "little")]
pub fn as_byte_slice(xs: &[Complex32]) -> &[u8] {
    // SAFETY: Complex32 is repr(C) plain-old-data; u8 has alignment 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs)) }
}

/// Serialize a complex slice to wire bytes in one pass.
pub fn to_wire_bytes(xs: &[Complex32]) -> Vec<u8> {
    #[cfg(target_endian = "little")]
    {
        as_byte_slice(xs).to_vec()
    }
    #[cfg(not(target_endian = "little"))]
    {
        crate::util::bytes::f32_to_bytes(as_f32_slice(xs))
    }
}

/// Parse a little-endian wire buffer straight into complex numbers —
/// one pass, one allocation (§Perf: replaces the bytes→f32→Complex32
/// double conversion on the chunk receive path).
pub fn from_le_bytes(bytes: &[u8]) -> Vec<Complex32> {
    assert!(bytes.len() % 8 == 0, "complex wire buffer must be a multiple of 8 bytes");
    bytes
        .chunks_exact(8)
        .map(|p| {
            Complex32::new(
                f32::from_le_bytes([p[0], p[1], p[2], p[3]]),
                f32::from_le_bytes([p[4], p[5], p[6], p[7]]),
            )
        })
        .collect()
}

/// Split an AoS complex buffer into separate re/im planes (the layout the
/// PJRT artifact consumes).
pub fn to_planes(xs: &[Complex32]) -> (Vec<f32>, Vec<f32>) {
    (xs.iter().map(|c| c.re).collect(), xs.iter().map(|c| c.im).collect())
}

/// Rebuild an AoS complex buffer from re/im planes.
pub fn from_planes(re: &[f32], im: &[f32]) -> Vec<Complex32> {
    assert_eq!(re.len(), im.len(), "plane length mismatch");
    re.iter().zip(im).map(|(&r, &i)| Complex32::new(r, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let a = Complex32::new(1.0, 2.0);
        let b = Complex32::new(-3.0, 0.5);
        assert_eq!(a + b - b, a);
        assert_eq!(a * Complex32::ONE, a);
        assert_eq!(a * Complex32::ZERO, Complex32::ZERO);
        assert_eq!(-(-a), a);
    }

    #[test]
    fn mul_matches_formula() {
        let a = Complex32::new(2.0, 3.0);
        let b = Complex32::new(4.0, -5.0);
        let c = a * b; // (8+15) + i(-10+12)
        assert_eq!(c, Complex32::new(23.0, 2.0));
    }

    #[test]
    fn mul_i_is_rotation() {
        let a = Complex32::new(3.0, 4.0);
        assert_eq!(a.mul_i(), a * Complex32::I);
        assert_eq!(a.mul_neg_i(), a * Complex32::new(0.0, -1.0));
    }

    #[test]
    fn conj_and_norm() {
        let a = Complex32::new(3.0, 4.0);
        assert_eq!(a.norm_sqr(), 25.0);
        assert_eq!(a.abs(), 5.0);
        assert_eq!((a * a.conj()).re, 25.0);
        assert!((a * a.conj()).im.abs() < 1e-6);
    }

    #[test]
    fn cis_on_unit_circle() {
        for k in 0..8 {
            let theta = 2.0 * std::f32::consts::PI * k as f32 / 8.0;
            let w = Complex32::cis(theta);
            assert!((w.abs() - 1.0).abs() < 1e-6);
        }
        assert_eq!(Complex32::cis(0.0), Complex32::ONE);
    }

    #[test]
    fn interleaved_view_layout() {
        let xs = vec![Complex32::new(1.0, 2.0), Complex32::new(3.0, 4.0)];
        assert_eq!(as_f32_slice(&xs), &[1.0, 2.0, 3.0, 4.0]);
        let back = from_interleaved(as_f32_slice(&xs));
        assert_eq!(back, xs);
    }

    #[test]
    fn mutable_view_writes_through() {
        let mut xs = vec![Complex32::ZERO; 2];
        as_f32_slice_mut(&mut xs)[3] = 7.0;
        assert_eq!(xs[1].im, 7.0);
    }

    #[test]
    fn planes_roundtrip() {
        let xs = vec![Complex32::new(1.0, -1.0), Complex32::new(2.0, -2.0)];
        let (re, im) = to_planes(&xs);
        assert_eq!(re, vec![1.0, 2.0]);
        assert_eq!(im, vec![-1.0, -2.0]);
        assert_eq!(from_planes(&re, &im), xs);
    }
}
