//! Twiddle-factor tables.
//!
//! Forward transform uses `w_n^k = e^{-2πik/n}` (inverse conjugates the
//! sign); tables are computed in f64 and rounded once to f32 (FFTW does
//! the same) so accumulated phase error stays below f32 epsilon per
//! stage. The power-of-two half-circle tables feed the radix-2 kernel;
//! [`unit`] is the arbitrary-denominator root the mixed-radix planner's
//! stage tables are built from.

use super::complex::Complex32;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Half-size twiddle table for an n-point transform (`n` a power of
/// two): `table[k] = e^{∓2πik/n}` for `k in 0..n/2` — minus sign for the
/// forward transform, plus for the inverse.
///
/// The radix-2 kernel only ever needs the first half of the circle; the
/// second half is `-table[k - n/2]`.
pub fn half_table(n: usize, inverse: bool) -> Vec<Complex32> {
    assert!(n.is_power_of_two() && n >= 2, "twiddle table needs power-of-two n >= 2, got {n}");
    build_half(n, inverse)
}

/// Table builder shared by [`half_table`] and [`TwiddleCache`]: any even
/// `n >= 2` (the cache also serves the real-FFT unpack tables, whose `n`
/// is even but not necessarily a power of two).
fn build_half(n: usize, inverse: bool) -> Vec<Complex32> {
    let half = n / 2;
    let sign = if inverse { 2.0 } else { -2.0 };
    let step = sign * std::f64::consts::PI / n as f64;
    (0..half).map(|k| Complex32::cis_f64(step * k as f64)).collect()
}

/// Process-wide cache of half-circle twiddle tables and bit-reversal
/// permutations, shared across every plan in the process.
///
/// Tables are keyed by `(n, inverse)` and handed out as `Arc`s, so a
/// size-n plan and the size-n/2 sub-plans of a split-radix or real-input
/// factorization all point at memory computed once. When the `2n` table
/// is already resident, the `n` table is *derived* from it by taking
/// every second entry — `e^{∓2πi(2k)/2n} = e^{∓2πik/n}` and the f64
/// phase `step·k` is identical under exact power-of-two scaling, so the
/// derived table is bitwise equal to a directly computed one (asserted
/// in the tests below).
///
/// Counters distinguish `hits` (table already resident), `computed`
/// (built from `sin`/`cos`), and `derived` (strided copy of a resident
/// parent) so cache-sharing behaviour is testable.
pub struct TwiddleCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    computed: AtomicU64,
    derived: AtomicU64,
}

#[derive(Default)]
struct CacheInner {
    halves: HashMap<(usize, bool), Arc<Vec<Complex32>>>,
    bitrevs: HashMap<usize, Arc<Vec<u32>>>,
}

impl TwiddleCache {
    /// New empty cache (the process normally uses [`TwiddleCache::global`]).
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(CacheInner::default()),
            hits: AtomicU64::new(0),
            computed: AtomicU64::new(0),
            derived: AtomicU64::new(0),
        }
    }

    /// The process-wide shared instance.
    pub fn global() -> &'static TwiddleCache {
        static GLOBAL: OnceLock<TwiddleCache> = OnceLock::new();
        GLOBAL.get_or_init(TwiddleCache::new)
    }

    /// Shared half-circle table `e^{∓2πik/n}`, `k in 0..n/2`, for any
    /// even `n >= 2`. Bitwise identical to [`half_table`] for
    /// power-of-two `n`.
    pub fn half(&self, n: usize, inverse: bool) -> Arc<Vec<Complex32>> {
        assert!(n >= 2 && n % 2 == 0, "twiddle cache needs even n >= 2, got {n}");
        {
            let inner = self.inner.lock().unwrap();
            if let Some(t) = inner.halves.get(&(n, inverse)) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(t);
            }
            if let Some(parent) = inner.halves.get(&(2 * n, inverse)) {
                // Derive without dropping the lock: a strided copy is
                // cheaper than recomputing n/2 sin/cos pairs.
                let t: Arc<Vec<Complex32>> = Arc::new(parent.iter().step_by(2).copied().collect());
                drop(inner);
                self.derived.fetch_add(1, Ordering::Relaxed);
                let mut inner = self.inner.lock().unwrap();
                let entry = inner.halves.entry((n, inverse)).or_insert(t);
                return Arc::clone(entry);
            }
        }
        // Compute outside the lock; racing builders produce identical
        // tables and the first insert wins.
        let t = Arc::new(build_half(n, inverse));
        self.computed.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.halves.entry((n, inverse)).or_insert(t);
        Arc::clone(entry)
    }

    /// Shared bit-reversal permutation for power-of-two `n`.
    pub fn bitrev(&self, n: usize) -> Arc<Vec<u32>> {
        {
            let inner = self.inner.lock().unwrap();
            if let Some(t) = inner.bitrevs.get(&n) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(t);
            }
        }
        let t = Arc::new(bit_reverse_table(n));
        self.computed.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.bitrevs.entry(n).or_insert(t);
        Arc::clone(entry)
    }

    /// Lookups that found a resident table.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Tables built from scratch (trig evaluation).
    pub fn computed(&self) -> u64 {
        self.computed.load(Ordering::Relaxed)
    }

    /// Tables derived from a resident double-size parent (strided copy).
    pub fn derived(&self) -> u64 {
        self.derived.load(Ordering::Relaxed)
    }
}

impl Default for TwiddleCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Forward half-circle table — [`half_table`] with the forward sign.
pub fn forward_table(n: usize) -> Vec<Complex32> {
    half_table(n, false)
}

/// Direction-signed unit root `e^{∓2πi·num/den}` for any denominator
/// (minus = forward). `num` is reduced mod `den` before the angle is
/// formed, keeping the f64 phase argument small — the precision trick
/// the mixed-radix stage tables rely on at large `i·k` products.
pub fn unit(num: usize, den: usize, inverse: bool) -> Complex32 {
    debug_assert!(den > 0, "unit root needs a positive denominator");
    let sign = if inverse { 2.0 } else { -2.0 };
    let theta = sign * std::f64::consts::PI * (num % den) as f64 / den as f64;
    Complex32::cis_f64(theta)
}

/// Full DFT matrix twiddle `w_n^{jk}` row generator used by the oracle and
/// by the four-step factorization checks: returns `e^{-2πi·jk/n}`.
pub fn w(n: usize, jk: usize) -> Complex32 {
    let step = -2.0 * std::f64::consts::PI / n as f64;
    Complex32::cis_f64(step * (jk % n) as f64)
}

/// Bit-reversal permutation table for length `n = 2^log2n`.
pub fn bit_reverse_table(n: usize) -> Vec<u32> {
    assert!(n.is_power_of_two(), "bit reversal needs power-of-two n, got {n}");
    let bits = n.trailing_zeros();
    (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_starts_at_one() {
        let t = forward_table(8);
        assert_eq!(t.len(), 4);
        assert!((t[0].re - 1.0).abs() < 1e-7 && t[0].im.abs() < 1e-7);
    }

    #[test]
    fn table_quarter_is_minus_i() {
        let t = forward_table(8);
        // w_8^2 = e^{-iπ/2} = -i
        assert!(t[2].re.abs() < 1e-6 && (t[2].im + 1.0).abs() < 1e-6);
    }

    #[test]
    fn all_entries_unit_modulus() {
        for &n in &[2usize, 4, 16, 256, 1024] {
            for w in forward_table(n) {
                assert!((w.abs() - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn w_is_periodic() {
        let a = w(16, 5);
        let b = w(16, 5 + 16);
        assert!((a.re - b.re).abs() < 1e-7 && (a.im - b.im).abs() < 1e-7);
    }

    #[test]
    fn bitrev_is_involution() {
        for &n in &[2usize, 8, 64, 1024] {
            let t = bit_reverse_table(n);
            for i in 0..n {
                assert_eq!(t[t[i] as usize] as usize, i, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn bitrev_known_n8() {
        assert_eq!(bit_reverse_table(8), vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        forward_table(12);
    }

    #[test]
    fn inverse_table_is_conjugate() {
        let fwd = half_table(16, false);
        let inv = half_table(16, true);
        for (f, i) in fwd.iter().zip(&inv) {
            assert!((f.re - i.re).abs() < 1e-7 && (f.im + i.im).abs() < 1e-7);
        }
    }

    #[test]
    fn cache_shares_tables_by_pointer() {
        let cache = TwiddleCache::new();
        let a = cache.half(64, false);
        let b = cache.half(64, false);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the resident table");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.computed(), 1);
        // Direction is part of the key.
        let c = cache.half(64, true);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn cache_derives_half_size_from_parent_bitwise() {
        let cache = TwiddleCache::new();
        for inverse in [false, true] {
            let _parent = cache.half(1024, inverse);
            let derived = cache.half(512, inverse);
            let direct = half_table(512, inverse);
            assert_eq!(derived.as_slice(), direct.as_slice(), "inverse={inverse}");
        }
        assert_eq!(cache.derived(), 2, "both half-size tables must come from the parent");
    }

    #[test]
    fn cache_serves_even_non_pow2_real_unpack_tables() {
        let cache = TwiddleCache::new();
        let t = cache.half(12, false);
        assert_eq!(t.len(), 6);
        for (k, w) in t.iter().enumerate() {
            let step = -2.0 * std::f64::consts::PI / 12.0;
            let reference = Complex32::cis_f64(step * k as f64);
            assert_eq!((w.re, w.im), (reference.re, reference.im), "k={k}");
        }
    }

    #[test]
    fn cache_bitrev_shared_and_correct() {
        let cache = TwiddleCache::new();
        let a = cache.bitrev(8);
        assert_eq!(a.as_slice(), &[0, 4, 2, 6, 1, 5, 3, 7]);
        let b = cache.bitrev(8);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn global_cache_counters_are_monotonic() {
        let cache = TwiddleCache::global();
        let h0 = cache.hits();
        let _a = cache.half(256, false);
        let _b = cache.half(256, false);
        // Other tests share the global cache, so only assert deltas are
        // at least what this thread contributed.
        assert!(cache.hits() >= h0 + 1);
    }

    #[test]
    fn unit_matches_w_and_reduces() {
        for &(num, den) in &[(0usize, 5usize), (3, 7), (7 + 3, 7), (11 * 13, 13)] {
            let u = unit(num, den, false);
            let reference = w(den, num % den);
            assert!((u.re - reference.re).abs() < 1e-7 && (u.im - reference.im).abs() < 1e-7);
            // Inverse root is the conjugate.
            let ui = unit(num, den, true);
            assert!((u.re - ui.re).abs() < 1e-7 && (u.im + ui.im).abs() < 1e-7);
        }
    }
}
