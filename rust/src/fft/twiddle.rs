//! Twiddle-factor tables.
//!
//! Forward transform uses `w_n^k = e^{-2πik/n}`; tables are computed in
//! f64 and rounded once to f32 (FFTW does the same) so accumulated phase
//! error stays below f32 epsilon per stage.

use super::complex::Complex32;

/// Half-size twiddle table for an n-point transform:
/// `table[k] = e^{-2πik/n}` for `k in 0..n/2`.
///
/// The radix-2 kernel only ever needs the first half of the circle; the
/// second half is `-table[k - n/2]`.
pub fn forward_table(n: usize) -> Vec<Complex32> {
    assert!(n.is_power_of_two() && n >= 2, "twiddle table needs power-of-two n >= 2, got {n}");
    let half = n / 2;
    let step = -2.0 * std::f64::consts::PI / n as f64;
    (0..half).map(|k| Complex32::cis_f64(step * k as f64)).collect()
}

/// Full DFT matrix twiddle `w_n^{jk}` row generator used by the oracle and
/// by the four-step factorization checks: returns `e^{-2πi·jk/n}`.
pub fn w(n: usize, jk: usize) -> Complex32 {
    let step = -2.0 * std::f64::consts::PI / n as f64;
    Complex32::cis_f64(step * (jk % n) as f64)
}

/// Bit-reversal permutation table for length `n = 2^log2n`.
pub fn bit_reverse_table(n: usize) -> Vec<u32> {
    assert!(n.is_power_of_two(), "bit reversal needs power-of-two n, got {n}");
    let bits = n.trailing_zeros();
    (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_starts_at_one() {
        let t = forward_table(8);
        assert_eq!(t.len(), 4);
        assert!((t[0].re - 1.0).abs() < 1e-7 && t[0].im.abs() < 1e-7);
    }

    #[test]
    fn table_quarter_is_minus_i() {
        let t = forward_table(8);
        // w_8^2 = e^{-iπ/2} = -i
        assert!(t[2].re.abs() < 1e-6 && (t[2].im + 1.0).abs() < 1e-6);
    }

    #[test]
    fn all_entries_unit_modulus() {
        for &n in &[2usize, 4, 16, 256, 1024] {
            for w in forward_table(n) {
                assert!((w.abs() - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn w_is_periodic() {
        let a = w(16, 5);
        let b = w(16, 5 + 16);
        assert!((a.re - b.re).abs() < 1e-7 && (a.im - b.im).abs() < 1e-7);
    }

    #[test]
    fn bitrev_is_involution() {
        for &n in &[2usize, 8, 64, 1024] {
            let t = bit_reverse_table(n);
            for i in 0..n {
                assert_eq!(t[t[i] as usize] as usize, i, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn bitrev_known_n8() {
        assert_eq!(bit_reverse_table(8), vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        forward_table(12);
    }
}
