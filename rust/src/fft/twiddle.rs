//! Twiddle-factor tables.
//!
//! Forward transform uses `w_n^k = e^{-2πik/n}` (inverse conjugates the
//! sign); tables are computed in f64 and rounded once to f32 (FFTW does
//! the same) so accumulated phase error stays below f32 epsilon per
//! stage. The power-of-two half-circle tables feed the radix-2 kernel;
//! [`unit`] is the arbitrary-denominator root the mixed-radix planner's
//! stage tables are built from.

use super::complex::Complex32;

/// Half-size twiddle table for an n-point transform (`n` a power of
/// two): `table[k] = e^{∓2πik/n}` for `k in 0..n/2` — minus sign for the
/// forward transform, plus for the inverse.
///
/// The radix-2 kernel only ever needs the first half of the circle; the
/// second half is `-table[k - n/2]`.
pub fn half_table(n: usize, inverse: bool) -> Vec<Complex32> {
    assert!(n.is_power_of_two() && n >= 2, "twiddle table needs power-of-two n >= 2, got {n}");
    let half = n / 2;
    let sign = if inverse { 2.0 } else { -2.0 };
    let step = sign * std::f64::consts::PI / n as f64;
    (0..half).map(|k| Complex32::cis_f64(step * k as f64)).collect()
}

/// Forward half-circle table — [`half_table`] with the forward sign.
pub fn forward_table(n: usize) -> Vec<Complex32> {
    half_table(n, false)
}

/// Direction-signed unit root `e^{∓2πi·num/den}` for any denominator
/// (minus = forward). `num` is reduced mod `den` before the angle is
/// formed, keeping the f64 phase argument small — the precision trick
/// the mixed-radix stage tables rely on at large `i·k` products.
pub fn unit(num: usize, den: usize, inverse: bool) -> Complex32 {
    debug_assert!(den > 0, "unit root needs a positive denominator");
    let sign = if inverse { 2.0 } else { -2.0 };
    let theta = sign * std::f64::consts::PI * (num % den) as f64 / den as f64;
    Complex32::cis_f64(theta)
}

/// Full DFT matrix twiddle `w_n^{jk}` row generator used by the oracle and
/// by the four-step factorization checks: returns `e^{-2πi·jk/n}`.
pub fn w(n: usize, jk: usize) -> Complex32 {
    let step = -2.0 * std::f64::consts::PI / n as f64;
    Complex32::cis_f64(step * (jk % n) as f64)
}

/// Bit-reversal permutation table for length `n = 2^log2n`.
pub fn bit_reverse_table(n: usize) -> Vec<u32> {
    assert!(n.is_power_of_two(), "bit reversal needs power-of-two n, got {n}");
    let bits = n.trailing_zeros();
    (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_starts_at_one() {
        let t = forward_table(8);
        assert_eq!(t.len(), 4);
        assert!((t[0].re - 1.0).abs() < 1e-7 && t[0].im.abs() < 1e-7);
    }

    #[test]
    fn table_quarter_is_minus_i() {
        let t = forward_table(8);
        // w_8^2 = e^{-iπ/2} = -i
        assert!(t[2].re.abs() < 1e-6 && (t[2].im + 1.0).abs() < 1e-6);
    }

    #[test]
    fn all_entries_unit_modulus() {
        for &n in &[2usize, 4, 16, 256, 1024] {
            for w in forward_table(n) {
                assert!((w.abs() - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn w_is_periodic() {
        let a = w(16, 5);
        let b = w(16, 5 + 16);
        assert!((a.re - b.re).abs() < 1e-7 && (a.im - b.im).abs() < 1e-7);
    }

    #[test]
    fn bitrev_is_involution() {
        for &n in &[2usize, 8, 64, 1024] {
            let t = bit_reverse_table(n);
            for i in 0..n {
                assert_eq!(t[t[i] as usize] as usize, i, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn bitrev_known_n8() {
        assert_eq!(bit_reverse_table(8), vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        forward_table(12);
    }

    #[test]
    fn inverse_table_is_conjugate() {
        let fwd = half_table(16, false);
        let inv = half_table(16, true);
        for (f, i) in fwd.iter().zip(&inv) {
            assert!((f.re - i.re).abs() < 1e-7 && (f.im + i.im).abs() < 1e-7);
        }
    }

    #[test]
    fn unit_matches_w_and_reduces() {
        for &(num, den) in &[(0usize, 5usize), (3, 7), (7 + 3, 7), (11 * 13, 13)] {
            let u = unit(num, den, false);
            let reference = w(den, num % den);
            assert!((u.re - reference.re).abs() < 1e-7 && (u.im - reference.im).abs() < 1e-7);
            // Inverse root is the conjugate.
            let ui = unit(num, den, true);
            assert!((u.re - ui.re).abs() < 1e-7 && (u.im + ui.im).abs() < 1e-7);
        }
    }
}
