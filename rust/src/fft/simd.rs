//! Runtime-dispatched SIMD micro-kernels for the complex combines.
//!
//! Every butterfly in the kernel layer bottoms out in one of a handful
//! of lane-parallel operations on interleaved [`Complex32`] buffers:
//! the twiddled radix-2 butterfly, the radix-4 combine, the split-radix
//! combine, the pointwise spectrum multiply (Bluestein), and the inverse
//! `1/n` scale. This module provides each of them three ways:
//!
//! - an **AVX2** path (x86-64, 4 complex values per 256-bit vector),
//!   selected at runtime with `is_x86_feature_detected!("avx2")`,
//! - a **NEON** path (aarch64, 2 complex values per 128-bit vector),
//!   always available on that target,
//! - the **scalar** path, which is both the fallback and the reference
//!   the property tests compare against.
//!
//! # Bitwise equivalence
//!
//! The SIMD paths are *bitwise identical* to the scalar path, not merely
//! close: the complex multiply is implemented as two lane products and an
//! add/sub — `(a·c − b·d, a·d + b·c)` with exactly one rounding per
//! operation, the same sequence the scalar [`Complex32`] `Mul` performs —
//! and deliberately does **not** use FMA contraction, which would change
//! the rounding. Rust never auto-contracts float expressions, so scalar
//! and vector lanes round identically and `tests/simd_equivalence.rs`
//! asserts equality with `==`, not a tolerance.
//!
//! The dispatched tier can be forced to the scalar path by setting the
//! environment variable `HPXFFT_SIMD=scalar` before first use (the tier
//! is detected once and cached); `repro kernels` prints the active tier.

use super::complex::Complex32;
use std::sync::OnceLock;

/// Instruction-set tier the dispatched kernels run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// AVX2 256-bit vectors — 4 interleaved complex values per operation.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON 128-bit vectors — 2 interleaved complex values per operation.
    #[cfg(target_arch = "aarch64")]
    Neon,
    /// Portable scalar fallback (also the property-test reference).
    Scalar,
}

impl SimdTier {
    /// Human-readable tier name for CSV rows and `repro kernels`.
    pub fn name(self) -> &'static str {
        match self {
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            SimdTier::Neon => "neon",
            SimdTier::Scalar => "scalar",
        }
    }

    /// Complex values processed per vector operation.
    pub fn lanes(self) -> usize {
        match self {
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => 4,
            #[cfg(target_arch = "aarch64")]
            SimdTier::Neon => 2,
            SimdTier::Scalar => 1,
        }
    }
}

/// The tier every dispatched kernel in this module uses. Detected once
/// per process (CPUID on x86-64) and cached; `HPXFFT_SIMD=scalar` forces
/// the scalar path for A/B runs and CI equivalence sweeps.
pub fn tier() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(detect)
}

fn detect() -> SimdTier {
    if std::env::var("HPXFFT_SIMD").map(|v| v == "scalar").unwrap_or(false) {
        return SimdTier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdTier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdTier::Neon;
    }
    #[allow(unreachable_code)]
    SimdTier::Scalar
}

/// Twiddled radix-2 butterfly over equal-length slices:
/// `(lo[k], hi[k]) ← (lo[k] + hi[k]·tw[k], lo[k] − hi[k]·tw[k])`.
// xtask: hot_path
pub fn butterfly_radix2(lo: &mut [Complex32], hi: &mut [Complex32], tw: &[Complex32]) {
    debug_assert!(lo.len() == hi.len() && hi.len() == tw.len());
    match tier() {
        // SAFETY: `tier()` returned this arm, so the CPU supports the
        // kernel's target feature; slice lengths were just asserted equal.
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::butterfly_radix2(lo, hi, tw) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal.
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::butterfly_radix2(lo, hi, tw) },
        SimdTier::Scalar => butterfly_radix2_scalar(lo, hi, tw),
    }
}

/// Scalar reference for [`butterfly_radix2`] (bitwise-identical).
// xtask: hot_path
pub fn butterfly_radix2_scalar(lo: &mut [Complex32], hi: &mut [Complex32], tw: &[Complex32]) {
    for ((a, b), w) in lo.iter_mut().zip(hi.iter_mut()).zip(tw) {
        let t = *b * *w;
        let av = *a;
        *a = av + t;
        *b = av - t;
    }
}

/// Twiddled radix-4 combine over four equal-length lanes — the
/// mixed-radix engine's `r = 4` stage. Lane 0 carries twiddle 1; lanes
/// 1–3 are multiplied by `w1`/`w2`/`w3` first, then the 4-point DFT
/// (`±1, ∓i` rotations only) combines them in place.
#[allow(clippy::too_many_arguments)]
// xtask: hot_path
pub fn butterfly_radix4(
    d0: &mut [Complex32],
    d1: &mut [Complex32],
    d2: &mut [Complex32],
    d3: &mut [Complex32],
    w1: &[Complex32],
    w2: &[Complex32],
    w3: &[Complex32],
    inverse: bool,
) {
    debug_assert!(d0.len() == d1.len() && d1.len() == d2.len() && d2.len() == d3.len());
    debug_assert!(w1.len() == d0.len() && w2.len() == d0.len() && w3.len() == d0.len());
    match tier() {
        // SAFETY: `tier()` returned this arm, so the CPU supports the
        // kernel's target feature; slice lengths were just asserted equal.
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::butterfly_radix4(d0, d1, d2, d3, w1, w2, w3, inverse) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal.
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::butterfly_radix4(d0, d1, d2, d3, w1, w2, w3, inverse) },
        SimdTier::Scalar => butterfly_radix4_scalar(d0, d1, d2, d3, w1, w2, w3, inverse),
    }
}

/// Scalar reference for [`butterfly_radix4`] (bitwise-identical).
#[allow(clippy::too_many_arguments)]
// xtask: hot_path
pub fn butterfly_radix4_scalar(
    d0: &mut [Complex32],
    d1: &mut [Complex32],
    d2: &mut [Complex32],
    d3: &mut [Complex32],
    w1: &[Complex32],
    w2: &[Complex32],
    w3: &[Complex32],
    inverse: bool,
) {
    for k in 0..d0.len() {
        let t0 = d0[k];
        let t1 = d1[k] * w1[k];
        let t2 = d2[k] * w2[k];
        let t3 = d3[k] * w3[k];
        let s02 = t0 + t2;
        let d02 = t0 - t2;
        let s13 = t1 + t3;
        let d13 = if inverse { (t1 - t3).mul_i() } else { (t1 - t3).mul_neg_i() };
        d0[k] = s02 + s13;
        d1[k] = d02 + d13;
        d2[k] = s02 - s13;
        d3[k] = d02 - d13;
    }
}

/// Split-radix combine: given the length-`n/2` even sub-transform `U`
/// (split as `u0`/`u1`, `n/4` entries each) and the two length-`n/4` odd
/// sub-transforms `z1` (`x[4j+1]`) and `z3` (`x[4j+3]`), produce the four
/// output quarters in place:
///
/// ```text
/// t1 = w¹ᵏ·Z[k]   t3 = w³ᵏ·Z'[k]
/// X[k]        = U[k]     + (t1 + t3)        → u0[k]
/// X[k + n/2]  = U[k]     − (t1 + t3)        → z1[k]
/// X[k + n/4]  = U[k+n/4] ∓ i·(t1 − t3)      → u1[k]
/// X[k + 3n/4] = U[k+n/4] ± i·(t1 − t3)      → z3[k]
/// ```
///
/// (upper signs forward, lower inverse).
#[allow(clippy::too_many_arguments)]
// xtask: hot_path
pub fn split_radix_combine(
    u0: &mut [Complex32],
    u1: &mut [Complex32],
    z1: &mut [Complex32],
    z3: &mut [Complex32],
    w1: &[Complex32],
    w3: &[Complex32],
    inverse: bool,
) {
    debug_assert!(u0.len() == u1.len() && u1.len() == z1.len() && z1.len() == z3.len());
    debug_assert!(w1.len() == u0.len() && w3.len() == u0.len());
    match tier() {
        // SAFETY: `tier()` returned this arm, so the CPU supports the
        // kernel's target feature; slice lengths were just asserted equal.
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::split_radix_combine(u0, u1, z1, z3, w1, w3, inverse) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal.
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::split_radix_combine(u0, u1, z1, z3, w1, w3, inverse) },
        SimdTier::Scalar => split_radix_combine_scalar(u0, u1, z1, z3, w1, w3, inverse),
    }
}

/// Scalar reference for [`split_radix_combine`] (bitwise-identical).
#[allow(clippy::too_many_arguments)]
// xtask: hot_path
pub fn split_radix_combine_scalar(
    u0: &mut [Complex32],
    u1: &mut [Complex32],
    z1: &mut [Complex32],
    z3: &mut [Complex32],
    w1: &[Complex32],
    w3: &[Complex32],
    inverse: bool,
) {
    for k in 0..u0.len() {
        let t1 = z1[k] * w1[k];
        let t3 = z3[k] * w3[k];
        let s = t1 + t3;
        let d = t1 - t3;
        let rot = if inverse { d.mul_i() } else { d.mul_neg_i() };
        let a = u0[k];
        let b = u1[k];
        u0[k] = a + s;
        z1[k] = a - s;
        u1[k] = b + rot;
        z3[k] = b - rot;
    }
}

/// Pointwise complex multiply `a[k] ← a[k]·b[k]` — the Bluestein
/// convolution's spectrum product.
// xtask: hot_path
pub fn pointwise_mul(a: &mut [Complex32], b: &[Complex32]) {
    debug_assert_eq!(a.len(), b.len());
    match tier() {
        // SAFETY: `tier()` returned this arm, so the CPU supports the
        // kernel's target feature; slice lengths were just asserted equal.
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::pointwise_mul(a, b) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal.
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::pointwise_mul(a, b) },
        SimdTier::Scalar => pointwise_mul_scalar(a, b),
    }
}

/// Scalar reference for [`pointwise_mul`] (bitwise-identical).
// xtask: hot_path
pub fn pointwise_mul_scalar(a: &mut [Complex32], b: &[Complex32]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x = *x * *y;
    }
}

/// Real-scalar scale `x[k] ← x[k]·s` — the inverse transform's `1/n`
/// normalization pass.
// xtask: hot_path
pub fn scale_in_place(x: &mut [Complex32], s: f32) {
    match tier() {
        // SAFETY: `tier()` returned this arm, so the CPU supports the
        // kernel's target feature; no length preconditions.
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::scale_in_place(x, s) },
        // SAFETY: NEON is baseline on aarch64; no length preconditions.
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { neon::scale_in_place(x, s) },
        SimdTier::Scalar => scale_in_place_scalar(x, s),
    }
}

/// Scalar reference for [`scale_in_place`] (bitwise-identical).
// xtask: hot_path
pub fn scale_in_place_scalar(x: &mut [Complex32], s: f32) {
    for v in x.iter_mut() {
        *v = v.scale(s);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 lane kernels. Each `__m256` holds 4 interleaved complex
    //! values `[re0, im0, re1, im1, re2, im2, re3, im3]`; loads go
    //! through the `repr(C)` layout guarantee of [`Complex32`]. The
    //! complex multiply is mul + addsub (no FMA) so every lane rounds
    //! exactly like the scalar `Complex32` operators — see the module
    //! docs on bitwise equivalence.

    use super::Complex32;
    use std::arch::x86_64::*;

    /// `a·b` per complex lane with scalar-identical rounding:
    /// `re = a.re·b.re − a.im·b.im`, `im = a.re·b.im + a.im·b.re`.
    #[inline]
    unsafe fn cmul(a: __m256, b: __m256) -> __m256 {
        // SAFETY: register-to-register AVX arithmetic, no memory access;
        // callers are `#[target_feature(enable = "avx2")]` kernels only
        // entered after the runtime `tier()` check.
        unsafe {
            let ar = _mm256_moveldup_ps(a); // [a.re, a.re, ...]
            let ai = _mm256_movehdup_ps(a); // [a.im, a.im, ...]
            let bsw = _mm256_permute_ps::<0xB1>(b); // [b.im, b.re, ...]
            // addsub: even lanes subtract, odd lanes add — exactly the
            // scalar (re, im) formula, one rounding per op, no contraction.
            _mm256_addsub_ps(_mm256_mul_ps(ar, b), _mm256_mul_ps(ai, bsw))
        }
    }

    /// `−i·v` per lane: `(re, im) → (im, −re)` — swap pairs, negate odd
    /// lanes (sign-bit xor, exact — matches `Complex32::mul_neg_i`).
    #[inline]
    unsafe fn mul_neg_i(v: __m256) -> __m256 {
        // SAFETY: register-only AVX ops; avx2-guaranteed callers (above).
        unsafe {
            let sw = _mm256_permute_ps::<0xB1>(v);
            _mm256_xor_ps(sw, _mm256_set_ps(-0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0))
        }
    }

    /// `i·v` per lane: `(re, im) → (−im, re)` — swap pairs, negate even
    /// lanes.
    #[inline]
    unsafe fn mul_i(v: __m256) -> __m256 {
        // SAFETY: register-only AVX ops; avx2-guaranteed callers (above).
        unsafe {
            let sw = _mm256_permute_ps::<0xB1>(v);
            _mm256_xor_ps(sw, _mm256_set_ps(0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0))
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn butterfly_radix2(
        lo: &mut [Complex32],
        hi: &mut [Complex32],
        tw: &[Complex32],
    ) {
        let m = lo.len();
        let quads = m / 4;
        let lp = lo.as_mut_ptr() as *mut f32;
        let hp = hi.as_mut_ptr() as *mut f32;
        let tp = tw.as_ptr() as *const f32;
        // SAFETY: `quads·4 ≤ m` and the three slices are equal-length
        // (asserted at the dispatch site), so every `off + 8`-float
        // access stays inside its slice; `Complex32` is `repr(C)` of two
        // `f32`s, making the pointer casts layout-sound; unaligned
        // loads/stores are used throughout. The avx2 target feature is
        // guaranteed by this fn's attribute.
        unsafe {
            for q in 0..quads {
                let off = q * 8;
                let a = _mm256_loadu_ps(lp.add(off));
                let b = _mm256_loadu_ps(hp.add(off));
                let w = _mm256_loadu_ps(tp.add(off));
                let t = cmul(b, w);
                _mm256_storeu_ps(lp.add(off), _mm256_add_ps(a, t));
                _mm256_storeu_ps(hp.add(off), _mm256_sub_ps(a, t));
            }
        }
        let done = quads * 4;
        super::butterfly_radix2_scalar(&mut lo[done..], &mut hi[done..], &tw[done..]);
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn butterfly_radix4(
        d0: &mut [Complex32],
        d1: &mut [Complex32],
        d2: &mut [Complex32],
        d3: &mut [Complex32],
        w1: &[Complex32],
        w2: &[Complex32],
        w3: &[Complex32],
        inverse: bool,
    ) {
        let m = d0.len();
        let quads = m / 4;
        let p0 = d0.as_mut_ptr() as *mut f32;
        let p1 = d1.as_mut_ptr() as *mut f32;
        let p2 = d2.as_mut_ptr() as *mut f32;
        let p3 = d3.as_mut_ptr() as *mut f32;
        let q1 = w1.as_ptr() as *const f32;
        let q2 = w2.as_ptr() as *const f32;
        let q3 = w3.as_ptr() as *const f32;
        // SAFETY: all seven slices are equal-length (asserted at the
        // dispatch site) and `quads·4 ≤ m`, so every 8-float access is
        // in bounds; `Complex32` is `repr(C)` of two `f32`s, so the
        // casts are layout-sound; unaligned loads/stores throughout.
        unsafe {
            for q in 0..quads {
                let off = q * 8;
                let t0 = _mm256_loadu_ps(p0.add(off));
                let t1 = cmul(_mm256_loadu_ps(p1.add(off)), _mm256_loadu_ps(q1.add(off)));
                let t2 = cmul(_mm256_loadu_ps(p2.add(off)), _mm256_loadu_ps(q2.add(off)));
                let t3 = cmul(_mm256_loadu_ps(p3.add(off)), _mm256_loadu_ps(q3.add(off)));
                let s02 = _mm256_add_ps(t0, t2);
                let d02 = _mm256_sub_ps(t0, t2);
                let s13 = _mm256_add_ps(t1, t3);
                let d = _mm256_sub_ps(t1, t3);
                let d13 = if inverse { mul_i(d) } else { mul_neg_i(d) };
                _mm256_storeu_ps(p0.add(off), _mm256_add_ps(s02, s13));
                _mm256_storeu_ps(p1.add(off), _mm256_add_ps(d02, d13));
                _mm256_storeu_ps(p2.add(off), _mm256_sub_ps(s02, s13));
                _mm256_storeu_ps(p3.add(off), _mm256_sub_ps(d02, d13));
            }
        }
        let done = quads * 4;
        super::butterfly_radix4_scalar(
            &mut d0[done..],
            &mut d1[done..],
            &mut d2[done..],
            &mut d3[done..],
            &w1[done..],
            &w2[done..],
            &w3[done..],
            inverse,
        );
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn split_radix_combine(
        u0: &mut [Complex32],
        u1: &mut [Complex32],
        z1: &mut [Complex32],
        z3: &mut [Complex32],
        w1: &[Complex32],
        w3: &[Complex32],
        inverse: bool,
    ) {
        let m = u0.len();
        let quads = m / 4;
        let pu0 = u0.as_mut_ptr() as *mut f32;
        let pu1 = u1.as_mut_ptr() as *mut f32;
        let pz1 = z1.as_mut_ptr() as *mut f32;
        let pz3 = z3.as_mut_ptr() as *mut f32;
        let pw1 = w1.as_ptr() as *const f32;
        let pw3 = w3.as_ptr() as *const f32;
        // SAFETY: all six slices are equal-length (asserted at the
        // dispatch site) and `quads·4 ≤ m`, so every 8-float access is
        // in bounds; `Complex32` is `repr(C)` of two `f32`s, so the
        // casts are layout-sound; unaligned loads/stores throughout.
        unsafe {
            for q in 0..quads {
                let off = q * 8;
                let t1 = cmul(_mm256_loadu_ps(pz1.add(off)), _mm256_loadu_ps(pw1.add(off)));
                let t3 = cmul(_mm256_loadu_ps(pz3.add(off)), _mm256_loadu_ps(pw3.add(off)));
                let s = _mm256_add_ps(t1, t3);
                let d = _mm256_sub_ps(t1, t3);
                let rot = if inverse { mul_i(d) } else { mul_neg_i(d) };
                let a = _mm256_loadu_ps(pu0.add(off));
                let b = _mm256_loadu_ps(pu1.add(off));
                _mm256_storeu_ps(pu0.add(off), _mm256_add_ps(a, s));
                _mm256_storeu_ps(pz1.add(off), _mm256_sub_ps(a, s));
                _mm256_storeu_ps(pu1.add(off), _mm256_add_ps(b, rot));
                _mm256_storeu_ps(pz3.add(off), _mm256_sub_ps(b, rot));
            }
        }
        let done = quads * 4;
        super::split_radix_combine_scalar(
            &mut u0[done..],
            &mut u1[done..],
            &mut z1[done..],
            &mut z3[done..],
            &w1[done..],
            &w3[done..],
            inverse,
        );
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pointwise_mul(a: &mut [Complex32], b: &[Complex32]) {
        let quads = a.len() / 4;
        let pa = a.as_mut_ptr() as *mut f32;
        let pb = b.as_ptr() as *const f32;
        // SAFETY: `a` and `b` are equal-length (asserted at the dispatch
        // site) and `quads·4 ≤ a.len()`, so every 8-float access is in
        // bounds; `Complex32` is `repr(C)` of two `f32`s; unaligned
        // loads/stores throughout.
        unsafe {
            for q in 0..quads {
                let off = q * 8;
                let va = _mm256_loadu_ps(pa.add(off));
                let vb = _mm256_loadu_ps(pb.add(off));
                _mm256_storeu_ps(pa.add(off), cmul(va, vb));
            }
        }
        let done = quads * 4;
        super::pointwise_mul_scalar(&mut a[done..], &b[done..]);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_in_place(x: &mut [Complex32], s: f32) {
        let quads = x.len() / 4;
        let px = x.as_mut_ptr() as *mut f32;
        // SAFETY: `quads·4 ≤ x.len()`, so every 8-float access is in
        // bounds; `Complex32` is `repr(C)` of two `f32`s; unaligned
        // loads/stores throughout.
        unsafe {
            let vs = _mm256_set1_ps(s);
            for q in 0..quads {
                let off = q * 8;
                _mm256_storeu_ps(px.add(off), _mm256_mul_ps(_mm256_loadu_ps(px.add(off)), vs));
            }
        }
        let done = quads * 4;
        super::scale_in_place_scalar(&mut x[done..], s);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON lane kernels (2 interleaved complex values per 128-bit
    //! vector). Same mul + add/sub structure as the AVX2 path — no FMA,
    //! so lanes round exactly like the scalar operators.

    use super::Complex32;
    use std::arch::aarch64::*;

    /// Flip the sign bit of the even (real-slot) lanes.
    #[inline]
    unsafe fn negate_even(v: float32x4_t) -> float32x4_t {
        // SAFETY: the mask load reads 4 u32 from a local array of
        // exactly 4; the rest is register-only NEON (baseline aarch64).
        unsafe {
            const M: [u32; 4] = [0x8000_0000, 0, 0x8000_0000, 0];
            let mask = vld1q_u32(M.as_ptr());
            vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), mask))
        }
    }

    /// Flip the sign bit of the odd (imag-slot) lanes.
    #[inline]
    unsafe fn negate_odd(v: float32x4_t) -> float32x4_t {
        // SAFETY: the mask load reads 4 u32 from a local array of
        // exactly 4; the rest is register-only NEON (baseline aarch64).
        unsafe {
            const M: [u32; 4] = [0, 0x8000_0000, 0, 0x8000_0000];
            let mask = vld1q_u32(M.as_ptr());
            vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), mask))
        }
    }

    /// `a·b` per complex lane, scalar-identical rounding.
    #[inline]
    unsafe fn cmul(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        // SAFETY: register-only NEON arithmetic (baseline on aarch64).
        unsafe {
            let ar = vtrn1q_f32(a, a); // [a0.re, a0.re, a1.re, a1.re]
            let ai = vtrn2q_f32(a, a); // [a0.im, a0.im, a1.im, a1.im]
            let bsw = vrev64q_f32(b); // [b0.im, b0.re, b1.im, b1.re]
            // p1 ± p2 with the even lane subtracted: negate p2's even lanes,
            // then a single add — one rounding per op, like the scalar Mul.
            vaddq_f32(vmulq_f32(ar, b), negate_even(vmulq_f32(ai, bsw)))
        }
    }

    /// `−i·v` per lane: `(re, im) → (im, −re)`.
    #[inline]
    unsafe fn mul_neg_i(v: float32x4_t) -> float32x4_t {
        // SAFETY: register-only NEON (baseline on aarch64).
        unsafe { negate_odd(vrev64q_f32(v)) }
    }

    /// `i·v` per lane: `(re, im) → (−im, re)`.
    #[inline]
    unsafe fn mul_i(v: float32x4_t) -> float32x4_t {
        // SAFETY: register-only NEON (baseline on aarch64).
        unsafe { negate_even(vrev64q_f32(v)) }
    }

    pub(super) unsafe fn butterfly_radix2(
        lo: &mut [Complex32],
        hi: &mut [Complex32],
        tw: &[Complex32],
    ) {
        let pairs = lo.len() / 2;
        let lp = lo.as_mut_ptr() as *mut f32;
        let hp = hi.as_mut_ptr() as *mut f32;
        let tp = tw.as_ptr() as *const f32;
        // SAFETY: `pairs·2 ≤ lo.len()` and the three slices are
        // equal-length (asserted at the dispatch site), so every
        // `off + 4`-float access is in bounds; `Complex32` is `repr(C)`
        // of two `f32`s, so the pointer casts are layout-sound.
        unsafe {
            for q in 0..pairs {
                let off = q * 4;
                let a = vld1q_f32(lp.add(off));
                let b = vld1q_f32(hp.add(off));
                let w = vld1q_f32(tp.add(off));
                let t = cmul(b, w);
                vst1q_f32(lp.add(off), vaddq_f32(a, t));
                vst1q_f32(hp.add(off), vsubq_f32(a, t));
            }
        }
        let done = pairs * 2;
        super::butterfly_radix2_scalar(&mut lo[done..], &mut hi[done..], &tw[done..]);
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn butterfly_radix4(
        d0: &mut [Complex32],
        d1: &mut [Complex32],
        d2: &mut [Complex32],
        d3: &mut [Complex32],
        w1: &[Complex32],
        w2: &[Complex32],
        w3: &[Complex32],
        inverse: bool,
    ) {
        let pairs = d0.len() / 2;
        let p0 = d0.as_mut_ptr() as *mut f32;
        let p1 = d1.as_mut_ptr() as *mut f32;
        let p2 = d2.as_mut_ptr() as *mut f32;
        let p3 = d3.as_mut_ptr() as *mut f32;
        let q1 = w1.as_ptr() as *const f32;
        let q2 = w2.as_ptr() as *const f32;
        let q3 = w3.as_ptr() as *const f32;
        // SAFETY: all seven slices are equal-length (asserted at the
        // dispatch site) and `pairs·2 ≤ d0.len()`, so every 4-float
        // access is in bounds; `Complex32` is `repr(C)` of two `f32`s.
        unsafe {
            for q in 0..pairs {
                let off = q * 4;
                let t0 = vld1q_f32(p0.add(off));
                let t1 = cmul(vld1q_f32(p1.add(off)), vld1q_f32(q1.add(off)));
                let t2 = cmul(vld1q_f32(p2.add(off)), vld1q_f32(q2.add(off)));
                let t3 = cmul(vld1q_f32(p3.add(off)), vld1q_f32(q3.add(off)));
                let s02 = vaddq_f32(t0, t2);
                let d02 = vsubq_f32(t0, t2);
                let s13 = vaddq_f32(t1, t3);
                let d = vsubq_f32(t1, t3);
                let d13 = if inverse { mul_i(d) } else { mul_neg_i(d) };
                vst1q_f32(p0.add(off), vaddq_f32(s02, s13));
                vst1q_f32(p1.add(off), vaddq_f32(d02, d13));
                vst1q_f32(p2.add(off), vsubq_f32(s02, s13));
                vst1q_f32(p3.add(off), vsubq_f32(d02, d13));
            }
        }
        let done = pairs * 2;
        super::butterfly_radix4_scalar(
            &mut d0[done..],
            &mut d1[done..],
            &mut d2[done..],
            &mut d3[done..],
            &w1[done..],
            &w2[done..],
            &w3[done..],
            inverse,
        );
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn split_radix_combine(
        u0: &mut [Complex32],
        u1: &mut [Complex32],
        z1: &mut [Complex32],
        z3: &mut [Complex32],
        w1: &[Complex32],
        w3: &[Complex32],
        inverse: bool,
    ) {
        let pairs = u0.len() / 2;
        let pu0 = u0.as_mut_ptr() as *mut f32;
        let pu1 = u1.as_mut_ptr() as *mut f32;
        let pz1 = z1.as_mut_ptr() as *mut f32;
        let pz3 = z3.as_mut_ptr() as *mut f32;
        let pw1 = w1.as_ptr() as *const f32;
        let pw3 = w3.as_ptr() as *const f32;
        // SAFETY: all six slices are equal-length (asserted at the
        // dispatch site) and `pairs·2 ≤ u0.len()`, so every 4-float
        // access is in bounds; `Complex32` is `repr(C)` of two `f32`s.
        unsafe {
            for q in 0..pairs {
                let off = q * 4;
                let t1 = cmul(vld1q_f32(pz1.add(off)), vld1q_f32(pw1.add(off)));
                let t3 = cmul(vld1q_f32(pz3.add(off)), vld1q_f32(pw3.add(off)));
                let s = vaddq_f32(t1, t3);
                let d = vsubq_f32(t1, t3);
                let rot = if inverse { mul_i(d) } else { mul_neg_i(d) };
                let a = vld1q_f32(pu0.add(off));
                let b = vld1q_f32(pu1.add(off));
                vst1q_f32(pu0.add(off), vaddq_f32(a, s));
                vst1q_f32(pz1.add(off), vsubq_f32(a, s));
                vst1q_f32(pu1.add(off), vaddq_f32(b, rot));
                vst1q_f32(pz3.add(off), vsubq_f32(b, rot));
            }
        }
        let done = pairs * 2;
        super::split_radix_combine_scalar(
            &mut u0[done..],
            &mut u1[done..],
            &mut z1[done..],
            &mut z3[done..],
            &w1[done..],
            &w3[done..],
            inverse,
        );
    }

    pub(super) unsafe fn pointwise_mul(a: &mut [Complex32], b: &[Complex32]) {
        let pairs = a.len() / 2;
        let pa = a.as_mut_ptr() as *mut f32;
        let pb = b.as_ptr() as *const f32;
        // SAFETY: `a` and `b` are equal-length (asserted at the dispatch
        // site) and `pairs·2 ≤ a.len()`, so every 4-float access is in
        // bounds; `Complex32` is `repr(C)` of two `f32`s.
        unsafe {
            for q in 0..pairs {
                let off = q * 4;
                vst1q_f32(pa.add(off), cmul(vld1q_f32(pa.add(off)), vld1q_f32(pb.add(off))));
            }
        }
        let done = pairs * 2;
        super::pointwise_mul_scalar(&mut a[done..], &b[done..]);
    }

    pub(super) unsafe fn scale_in_place(x: &mut [Complex32], s: f32) {
        let pairs = x.len() / 2;
        let px = x.as_mut_ptr() as *mut f32;
        // SAFETY: `pairs·2 ≤ x.len()`, so every 4-float access is in
        // bounds; `Complex32` is `repr(C)` of two `f32`s.
        unsafe {
            let vs = vdupq_n_f32(s);
            for q in 0..pairs {
                let off = q * 4;
                vst1q_f32(px.add(off), vmulq_f32(vld1q_f32(px.add(off)), vs));
            }
        }
        let done = pairs * 2;
        super::scale_in_place_scalar(&mut x[done..], s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn signal(seed: u64, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn tier_is_cached_and_named() {
        let t = tier();
        assert_eq!(t, tier());
        assert!(!t.name().is_empty());
        assert!(t.lanes() >= 1);
    }

    #[test]
    fn radix2_dispatch_matches_scalar_bitwise() {
        // Lengths straddling the vector width exercise the tail path.
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 33, 1000] {
            let lo0 = signal(n as u64, n);
            let hi0 = signal(n as u64 + 1, n);
            let tw = signal(n as u64 + 2, n);
            let (mut lo_a, mut hi_a) = (lo0.clone(), hi0.clone());
            butterfly_radix2(&mut lo_a, &mut hi_a, &tw);
            let (mut lo_b, mut hi_b) = (lo0, hi0);
            butterfly_radix2_scalar(&mut lo_b, &mut hi_b, &tw);
            assert_eq!(lo_a, lo_b, "n={n}");
            assert_eq!(hi_a, hi_b, "n={n}");
        }
    }

    #[test]
    fn radix4_dispatch_matches_scalar_bitwise() {
        for n in [1usize, 3, 4, 6, 8, 17, 64] {
            for inverse in [false, true] {
                let lanes: Vec<Vec<Complex32>> =
                    (0..4).map(|i| signal(100 + n as u64 + i, n)).collect();
                let tws: Vec<Vec<Complex32>> =
                    (0..3).map(|i| signal(200 + n as u64 + i, n)).collect();
                let mut a: Vec<Vec<Complex32>> = lanes.clone();
                {
                    let (d0, rest) = a.split_at_mut(1);
                    let (d1, rest) = rest.split_at_mut(1);
                    let (d2, d3) = rest.split_at_mut(1);
                    butterfly_radix4(
                        &mut d0[0],
                        &mut d1[0],
                        &mut d2[0],
                        &mut d3[0],
                        &tws[0],
                        &tws[1],
                        &tws[2],
                        inverse,
                    );
                }
                let mut b: Vec<Vec<Complex32>> = lanes;
                {
                    let (d0, rest) = b.split_at_mut(1);
                    let (d1, rest) = rest.split_at_mut(1);
                    let (d2, d3) = rest.split_at_mut(1);
                    butterfly_radix4_scalar(
                        &mut d0[0],
                        &mut d1[0],
                        &mut d2[0],
                        &mut d3[0],
                        &tws[0],
                        &tws[1],
                        &tws[2],
                        inverse,
                    );
                }
                assert_eq!(a, b, "n={n} inverse={inverse}");
            }
        }
    }

    #[test]
    fn split_radix_dispatch_matches_scalar_bitwise() {
        for n in [1usize, 2, 4, 5, 8, 16, 63] {
            for inverse in [false, true] {
                let lanes: Vec<Vec<Complex32>> =
                    (0..4).map(|i| signal(300 + n as u64 + i, n)).collect();
                let w1 = signal(400 + n as u64, n);
                let w3 = signal(401 + n as u64, n);
                let mut a = lanes.clone();
                {
                    let (u0, rest) = a.split_at_mut(1);
                    let (u1, rest) = rest.split_at_mut(1);
                    let (z1, z3) = rest.split_at_mut(1);
                    split_radix_combine(
                        &mut u0[0], &mut u1[0], &mut z1[0], &mut z3[0], &w1, &w3, inverse,
                    );
                }
                let mut b = lanes;
                {
                    let (u0, rest) = b.split_at_mut(1);
                    let (u1, rest) = rest.split_at_mut(1);
                    let (z1, z3) = rest.split_at_mut(1);
                    split_radix_combine_scalar(
                        &mut u0[0], &mut u1[0], &mut z1[0], &mut z3[0], &w1, &w3, inverse,
                    );
                }
                assert_eq!(a, b, "n={n} inverse={inverse}");
            }
        }
    }

    #[test]
    fn pointwise_and_scale_match_scalar_bitwise() {
        for n in [0usize, 1, 5, 8, 100] {
            let a0 = signal(500 + n as u64, n);
            let b = signal(501 + n as u64, n);
            let mut a1 = a0.clone();
            pointwise_mul(&mut a1, &b);
            let mut a2 = a0.clone();
            pointwise_mul_scalar(&mut a2, &b);
            assert_eq!(a1, a2, "pointwise n={n}");

            let mut s1 = a0.clone();
            scale_in_place(&mut s1, 0.125);
            let mut s2 = a0;
            scale_in_place_scalar(&mut s2, 0.125);
            assert_eq!(s1, s2, "scale n={n}");
        }
    }
}
