//! Pool-parallel batched row transforms — the "+pthreads" half of the
//! paper's FFTW3 MPI+pthreads reference, and the per-locality compute
//! step of the HPX variants.
//!
//! Rows of a contiguous row-major `rows × n` buffer are transformed
//! independently. Bands of rows are dispatched to the process-wide
//! [`crate::task::ThreadPool`] via [`crate::task::parallel_chunks_mut`],
//! so concurrent localities share one core-sized worker pool instead of
//! each spawning OS threads per sweep. Each band worker runs against its
//! thread's persistent [`FftScratch`], so steady-state sweeps are
//! allocation-free — including the first row of later sweeps.

use super::complex::Complex32;
use super::plan::{Direction, FftScratch, Plan};
use crate::task::parallel_chunks_mut;

/// Transform every length-`n` row of `data` (`rows × n`, row-major) in
/// place, fanning the rows out over up to `nthreads` tasks of the shared
/// worker pool. The plan carries the direction; any row length the
/// planner supports (that is: any) is accepted.
pub fn fft_rows_parallel(data: &mut [Complex32], n: usize, plan: &Plan, nthreads: usize) {
    assert_eq!(plan.len(), n, "plan length mismatch");
    assert!(data.len() % n == 0, "buffer not a whole number of rows");
    let rows = data.len() / n;
    if rows == 0 {
        return;
    }
    // §Perf (EXPERIMENTS.md §Perf L3-3): clamp to the machine's actual
    // parallelism — oversubscribing a small host with per-locality
    // worker threads costs ~10% in scheduling overhead for zero gain.
    // (The global pool is core-sized anyway; the clamp keeps the task
    // count from fragmenting the rows into needlessly small bands.)
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let nthreads = nthreads.min(hw).max(1);
    if nthreads == 1 {
        plan.execute_rows(data);
        return;
    }
    // Give each worker a contiguous band of rows: one chunk = ceil(rows/T)
    // rows, so tasks never share a cache line mid-row, and one scratch
    // serves a whole band.
    let rows_per_chunk = rows.div_ceil(nthreads);
    parallel_chunks_mut(data, rows_per_chunk * n, nthreads, |_, band| {
        // Each pool worker reuses its own persistent thread-local
        // scratch, so repeated sweeps allocate nothing.
        FftScratch::with_thread_local(|scratch| {
            for row in band.chunks_exact_mut(n) {
                plan.execute_with_scratch(row, scratch);
            }
        });
    });
}

/// Measured single-core row-FFT throughput in FLOP/s for length `n`, used
/// to calibrate simnet compute times. Runs `reps` rows and returns
/// `5 n log2 n * reps / elapsed`.
pub fn measure_row_throughput(n: usize, reps: usize) -> f64 {
    let plan = Plan::new(n, Direction::Forward);
    let mut scratch = FftScratch::new();
    let mut row: Vec<Complex32> =
        (0..n).map(|i| Complex32::new((i % 7) as f32 - 3.0, (i % 5) as f32)).collect();
    // Warmup.
    plan.execute_with_scratch(&mut row, &mut scratch);
    let start = std::time::Instant::now();
    for _ in 0..reps {
        plan.execute_with_scratch(&mut row, &mut scratch);
    }
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    plan.flops() * reps as f64 / secs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_grid(seed: u64, rows: usize, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..rows * n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn parallel_matches_serial() {
        let n = 64;
        let rows = 33; // ragged vs thread count
        let data = random_grid(9, rows, n);
        let plan = Plan::new(n, Direction::Forward);

        let mut par = data.clone();
        fft_rows_parallel(&mut par, n, &plan, 4);

        let mut ser = data.clone();
        plan.execute_rows(&mut ser);

        assert_eq!(flat(&par), flat(&ser));
    }

    #[test]
    fn parallel_matches_serial_non_pow2() {
        let n = 96; // 4·4·2·3 — mixed-radix rows through the pool
        let rows = 17;
        let data = random_grid(12, rows, n);
        let plan = Plan::new(n, Direction::Forward);

        let mut par = data.clone();
        fft_rows_parallel(&mut par, n, &plan, 4);

        let mut ser = data.clone();
        plan.execute_rows(&mut ser);

        assert_eq!(flat(&par), flat(&ser));
    }

    #[test]
    fn parallel_roundtrip() {
        let n = 128;
        let rows = 16;
        let data = random_grid(10, rows, n);
        let fwd = Plan::new(n, Direction::Forward);
        let inv = Plan::new(n, Direction::Inverse);
        let mut buf = data.clone();
        fft_rows_parallel(&mut buf, n, &fwd, 3);
        fft_rows_parallel(&mut buf, n, &inv, 5);
        assert_close(&flat(&buf), &flat(&data), 1e-4, 1e-3);
    }

    #[test]
    fn single_row_single_thread() {
        let n = 32;
        let data = random_grid(11, 1, n);
        let plan = Plan::new(n, Direction::Forward);
        let mut a = data.clone();
        fft_rows_parallel(&mut a, n, &plan, 1);
        let mut b = data;
        plan.execute(&mut b);
        assert_eq!(flat(&a), flat(&b));
    }

    #[test]
    fn empty_grid_is_noop() {
        let plan = Plan::new(16, Direction::Forward);
        let mut empty: Vec<Complex32> = Vec::new();
        fft_rows_parallel(&mut empty, 16, &plan, 4);
    }

    #[test]
    fn throughput_measurement_is_positive() {
        let t = measure_row_throughput(256, 10);
        assert!(t > 0.0);
        let t_mixed = measure_row_throughput(360, 10);
        assert!(t_mixed > 0.0);
    }
}
