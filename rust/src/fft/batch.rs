//! Thread-parallel batched row transforms — the "+pthreads" half of the
//! paper's FFTW3 MPI+pthreads reference, and the per-locality compute step
//! of the HPX variants.
//!
//! Rows of a contiguous row-major `rows × n` buffer are transformed
//! independently across `nthreads` workers via [`crate::task::parallel_chunks_mut`].

use super::complex::Complex32;
use super::plan::{Direction, Plan};
use crate::task::parallel_chunks_mut;
use std::sync::Arc;

/// Transform every length-`n` row of `data` (`rows × n`, row-major) in
/// place using `nthreads` threads.
pub fn fft_rows_parallel(
    data: &mut [Complex32],
    n: usize,
    plan: &Arc<Plan>,
    dir: Direction,
    nthreads: usize,
) {
    assert_eq!(plan.len(), n, "plan length mismatch");
    assert!(data.len() % n == 0, "buffer not a whole number of rows");
    let rows = data.len() / n;
    if rows == 0 {
        return;
    }
    // §Perf (EXPERIMENTS.md §Perf L3-3): clamp to the machine's actual
    // parallelism — oversubscribing a small host with per-locality
    // worker threads costs ~10% in scheduling overhead for zero gain.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let nthreads = nthreads.min(hw);
    // Give each worker a contiguous band of rows: one chunk = ceil(rows/T)
    // rows, so threads never share a cache line mid-row.
    let rows_per_chunk = rows.div_ceil(nthreads.max(1));
    parallel_chunks_mut(data, rows_per_chunk * n, nthreads, |_, band| {
        for row in band.chunks_exact_mut(n) {
            plan.execute(row, dir);
        }
    });
}

/// Measured single-core row-FFT throughput in FLOP/s for length `n`, used
/// to calibrate simnet compute times. Runs `reps` rows and returns
/// `5 n log2 n * reps / elapsed`.
pub fn measure_row_throughput(n: usize, reps: usize) -> f64 {
    let plan = Plan::new(n);
    let mut row: Vec<Complex32> =
        (0..n).map(|i| Complex32::new((i % 7) as f32 - 3.0, (i % 5) as f32)).collect();
    // Warmup.
    plan.execute(&mut row, Direction::Forward);
    let start = std::time::Instant::now();
    for _ in 0..reps {
        plan.execute(&mut row, Direction::Forward);
    }
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    plan.flops() * reps as f64 / secs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_grid(seed: u64, rows: usize, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..rows * n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn parallel_matches_serial() {
        let n = 64;
        let rows = 33; // ragged vs thread count
        let data = random_grid(9, rows, n);
        let plan = Arc::new(Plan::new(n));

        let mut par = data.clone();
        fft_rows_parallel(&mut par, n, &plan, Direction::Forward, 4);

        let mut ser = data.clone();
        plan.execute_rows(&mut ser, Direction::Forward);

        assert_eq!(flat(&par), flat(&ser));
    }

    #[test]
    fn parallel_roundtrip() {
        let n = 128;
        let rows = 16;
        let data = random_grid(10, rows, n);
        let plan = Arc::new(Plan::new(n));
        let mut buf = data.clone();
        fft_rows_parallel(&mut buf, n, &plan, Direction::Forward, 3);
        fft_rows_parallel(&mut buf, n, &plan, Direction::Inverse, 5);
        assert_close(&flat(&buf), &flat(&data), 1e-4, 1e-3);
    }

    #[test]
    fn single_row_single_thread() {
        let n = 32;
        let data = random_grid(11, 1, n);
        let plan = Arc::new(Plan::new(n));
        let mut a = data.clone();
        fft_rows_parallel(&mut a, n, &plan, Direction::Forward, 1);
        let mut b = data;
        plan.execute(&mut b, Direction::Forward);
        assert_eq!(flat(&a), flat(&b));
    }

    #[test]
    fn empty_grid_is_noop() {
        let plan = Arc::new(Plan::new(16));
        let mut empty: Vec<Complex32> = Vec::new();
        fft_rows_parallel(&mut empty, 16, &plan, Direction::Forward, 4);
    }

    #[test]
    fn throughput_measurement_is_positive() {
        let t = measure_row_throughput(256, 10);
        assert!(t > 0.0);
    }
}
