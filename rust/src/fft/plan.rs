//! FFT plans and the process-wide plan cache (the `fftw_plan` analog).
//!
//! A [`Plan`] owns every table one transform length and direction needs:
//! creating it is the expensive step (factorization, twiddle and
//! bit-reversal tables, Bluestein kernels), executing it does no
//! trigonometry and — with a reused [`FftScratch`] — no allocation.
//! [`PlanCache`] memoizes plans per `(length, direction)` so the
//! distributed driver and the baseline both plan once and execute many
//! times, the same usage discipline FFTW requires.
//!
//! Any length `n ≥ 1` is supported. Powers of two dispatch to the
//! split-radix kernel (fewest twiddle multiplies of the power-of-two
//! algorithms, combined with the lane-parallel [`crate::fft::simd`]
//! butterflies); everything else goes through the mixed-radix
//! Cooley–Tukey engine (radix-4 / radix-2 / odd-prime stages) with a
//! Bluestein fallback for large prime factors. Twiddle tables are
//! shared across plans through [`crate::fft::twiddle::TwiddleCache`].

use super::complex::Complex32;
use super::mixed::MixedPlan;
use super::simd;
use super::splitradix::SplitRadixPlan;
use std::cell::RefCell;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Transform direction. Part of the plan-cache key: forward and inverse
/// plans precompute different (conjugated) twiddle tables, so the
/// inverse runs as a single direct pass plus the `1/n` scale instead of
/// the conjugate-transform-conjugate identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Unnormalized forward transform (`e^{-2πi...}`).
    Forward,
    /// `1/n`-normalized inverse transform.
    Inverse,
}

impl Direction {
    /// `true` for [`Direction::Inverse`].
    pub fn is_inverse(self) -> bool {
        matches!(self, Direction::Inverse)
    }
}

/// Reusable execution scratch. Executing a power-of-two plan never
/// touches it; mixed-radix plans stage the input and the Bluestein
/// convolution here. Buffers grow to the largest transform they have
/// served and are then reused allocation-free — batched row loops keep
/// one per worker.
#[derive(Default)]
pub struct FftScratch {
    /// Staging copy of the input (the recursion reads strided views of it).
    work: Vec<Complex32>,
    /// Combine-loop lane buffer, one slot per radix.
    temp: Vec<Complex32>,
    /// Bluestein convolution buffer.
    conv: Vec<Complex32>,
}

thread_local! {
    /// Per-thread scratch backing [`FftScratch::with_thread_local`].
    /// Const-initialized (empty `Vec`s), so touching it never allocates
    /// until a transform actually needs staging space.
    static SCRATCH: RefCell<FftScratch> =
        const { RefCell::new(FftScratch { work: Vec::new(), temp: Vec::new(), conv: Vec::new() }) };
}

impl FftScratch {
    /// Empty scratch; buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` against this thread's persistent scratch. Buffers stay
    /// warm across calls, so steady-state transforms through
    /// [`Plan::execute`] / [`Plan::execute_rows`] allocate nothing. If
    /// the scratch is already borrowed (a re-entrant transform inside
    /// `f`), the inner call falls back to a fresh scratch rather than
    /// panicking.
    pub fn with_thread_local<R>(f: impl FnOnce(&mut FftScratch) -> R) -> R {
        SCRATCH.with(|cell| match cell.try_borrow_mut() {
            Ok(mut scratch) => f(&mut scratch),
            Err(_) => f(&mut FftScratch::new()),
        })
    }
}

/// Which kernel a plan executes.
enum Kernel {
    /// `n == 1`: the transform is the identity.
    Identity,
    /// Power-of-two length: recursive split-radix over shared
    /// direction-signed twiddle tables.
    SplitRadix(SplitRadixPlan),
    /// General length: mixed-radix Cooley–Tukey (+ Bluestein base).
    Mixed(MixedPlan),
}

/// A reusable transform plan for one length and direction.
///
/// ```
/// use hpx_fft::fft::{Complex32, Direction, Plan};
///
/// // 12 = 4·3 — a mixed-radix length no radix-2-only engine accepts.
/// let plan = Plan::new(12, Direction::Forward);
/// assert_eq!(plan.radices(), vec![4, 3]);
///
/// let mut x = vec![Complex32::ZERO; 12];
/// x[0] = Complex32::ONE; // unit impulse …
/// plan.execute(&mut x);
/// for bin in &x {
///     // … transforms to a flat spectrum of ones.
///     assert!((bin.re - 1.0).abs() < 1e-6 && bin.im.abs() < 1e-6);
/// }
/// ```
pub struct Plan {
    n: usize,
    dir: Direction,
    kernel: Kernel,
}

impl Plan {
    /// Plan an `n`-point transform (`n ≥ 1`, any factorization) in the
    /// given direction.
    pub fn new(n: usize, dir: Direction) -> Self {
        assert!(n >= 1, "Plan requires n >= 1, got {n}");
        let kernel = if n == 1 {
            Kernel::Identity
        } else if n.is_power_of_two() {
            Kernel::SplitRadix(SplitRadixPlan::new(n, dir.is_inverse()))
        } else {
            let mp = MixedPlan::new(n, dir.is_inverse());
            debug_assert_eq!(mp.len(), n);
            Kernel::Mixed(mp)
        };
        Self { n, dir, kernel }
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Always `false` — plans have length ≥ 1 (kept for API symmetry
    /// with `len`).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The direction this plan was built for.
    pub fn direction(&self) -> Direction {
        self.dir
    }

    /// The Cooley–Tukey stage schedule, e.g. `[4, 2, 3, 3, 5]` for
    /// `n = 360` (a Bluestein base case is not listed — see
    /// [`Plan::uses_bluestein`]). Power-of-two lengths report the
    /// radix-2 kernel's `log2 n` stages.
    pub fn radices(&self) -> Vec<usize> {
        match &self.kernel {
            Kernel::Identity => Vec::new(),
            Kernel::SplitRadix(_) => vec![2; self.n.trailing_zeros() as usize],
            Kernel::Mixed(mp) => mp.radices(),
        }
    }

    /// Human-readable kernel label for diagnostics (`repro kernels`,
    /// bench CSV provenance): `"identity"`, `"split-radix"`,
    /// `"mixed-radix"`, or `"mixed-radix+bluestein"`.
    pub fn kernel_name(&self) -> &'static str {
        match &self.kernel {
            Kernel::Identity => "identity",
            Kernel::SplitRadix(_) => "split-radix",
            Kernel::Mixed(mp) if mp.uses_bluestein() => "mixed-radix+bluestein",
            Kernel::Mixed(_) => "mixed-radix",
        }
    }

    /// Whether this plan bottoms out in a Bluestein convolution (a
    /// remainder whose prime factors are all too large for direct
    /// combine stages — one big prime, or a product of them).
    pub fn uses_bluestein(&self) -> bool {
        matches!(&self.kernel, Kernel::Mixed(mp) if mp.uses_bluestein())
    }

    /// Execute in place against the thread-local scratch — steady-state
    /// calls allocate nothing once the thread's buffers have warmed up.
    /// Loops that manage their own scratch lifetime can use
    /// [`Plan::execute_with_scratch`] directly.
    ///
    /// # Panics
    /// If `x.len() != self.len()`.
    pub fn execute(&self, x: &mut [Complex32]) {
        FftScratch::with_thread_local(|scratch| self.execute_with_scratch(x, scratch));
    }

    /// Execute in place against caller-owned scratch — allocation-free
    /// once the scratch has warmed up to this plan's length.
    ///
    /// # Panics
    /// If `x.len() != self.len()`.
    pub fn execute_with_scratch(&self, x: &mut [Complex32], scratch: &mut FftScratch) {
        assert_eq!(x.len(), self.n, "buffer length {} != plan length {}", x.len(), self.n);
        match &self.kernel {
            Kernel::Identity => {}
            Kernel::SplitRadix(sr) => sr.execute(x, &mut scratch.work),
            Kernel::Mixed(mp) => {
                let FftScratch { work, temp, conv } = scratch;
                mp.execute(x, work, temp, conv);
            }
        }
        if self.dir.is_inverse() && self.n > 1 {
            simd::scale_in_place(x, 1.0 / self.n as f32);
        }
    }

    /// Execute every length-`n` row of a contiguous row-major buffer,
    /// reusing one scratch across the rows.
    ///
    /// # Panics
    /// If `data.len()` is not a multiple of the plan length.
    pub fn execute_rows(&self, data: &mut [Complex32]) {
        assert!(
            data.len() % self.n == 0,
            "buffer length {} not a multiple of row length {}",
            data.len(),
            self.n
        );
        FftScratch::with_thread_local(|scratch| {
            for row in data.chunks_exact_mut(self.n) {
                self.execute_with_scratch(row, scratch);
            }
        });
    }

    /// FLOP estimate for one execution (5 n log2 n — the standard FFT
    /// operation count used for throughput reporting, for any radix mix).
    pub fn flops(&self) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        5.0 * self.n as f64 * (self.n as f64).log2()
    }
}

/// Memoized per-`(length, direction)` plans, shared across threads, with
/// hit/miss accounting.
pub struct PlanCache {
    plans: Mutex<HashMap<(usize, Direction), Arc<Plan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self { plans: Mutex::new(HashMap::new()), hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    /// Process-wide cache (what `fftw` calls wisdom, minus the disk file).
    pub fn global() -> &'static PlanCache {
        static CACHE: OnceLock<PlanCache> = OnceLock::new();
        CACHE.get_or_init(PlanCache::new)
    }

    /// The memoized plan for `(n, dir)`, building it on first request.
    pub fn plan(&self, n: usize, dir: Direction) -> Arc<Plan> {
        if let Some(plan) = self.plans.lock().unwrap().get(&(n, dir)) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(plan);
        }
        // Build outside the lock: construction can be expensive (stage
        // tables, a Bluestein kernel FFT) and must not stall every other
        // locality's lookup. Racing builders waste one duplicate build;
        // the first insert wins, so pointer identity is preserved.
        let built = Arc::new(Plan::new(n, dir));
        let mut plans = self.plans.lock().unwrap();
        match plans.entry((n, dir)) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Arc::clone(e.get())
            }
            Entry::Vacant(e) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Arc::clone(e.insert(built))
            }
        }
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to build a new plan.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Cached `(length, direction)` keys, sorted by length.
    pub fn cached_keys(&self) -> Vec<(usize, Direction)> {
        let mut v: Vec<(usize, Direction)> =
            self.plans.lock().unwrap().keys().copied().collect();
        v.sort_unstable_by_key(|&(n, d)| (n, d.is_inverse()));
        v
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::{assert_close, rel_l2_error};

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_signal(seed: u64, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn plan_executes_forward() {
        let x = random_signal(1, 64);
        let plan = Plan::new(64, Direction::Forward);
        let mut y = x.clone();
        plan.execute(&mut y);
        assert_close(&flat(&y), &flat(&dft(&x)), 1e-3, 1e-3);
    }

    #[test]
    fn plan_roundtrip_pow2() {
        let x = random_signal(2, 256);
        let fwd = Plan::new(256, Direction::Forward);
        let inv = Plan::new(256, Direction::Inverse);
        let mut y = x.clone();
        fwd.execute(&mut y);
        inv.execute(&mut y);
        assert_close(&flat(&y), &flat(&x), 1e-4, 1e-3);
    }

    /// The satellite's headline matrix: planned FFT vs the naive-DFT
    /// oracle on non-power-of-two lengths — composite, highly composite,
    /// and prime (Bluestein).
    #[test]
    fn non_pow2_matches_dft_oracle() {
        for &n in &[12usize, 96, 360, 1000, 1013] {
            let x = random_signal(n as u64, n);
            let plan = Plan::new(n, Direction::Forward);
            let mut y = x.clone();
            plan.execute(&mut y);
            let oracle = dft(&x);
            assert_close(&flat(&y), &flat(&oracle), 1e-3, 1e-3);
            // Aggregate f32 accuracy: the planned transform tracks the
            // f64 oracle to ~1e-6 relative L2; assert with margin.
            let err = rel_l2_error(&flat(&y), &flat(&oracle));
            let bound = if plan.uses_bluestein() { 1e-4 } else { 1e-5 };
            assert!(err < bound, "n={n}: rel L2 err {err}");
        }
    }

    #[test]
    fn non_pow2_roundtrip() {
        for &n in &[12usize, 96, 360, 1000, 1013] {
            let x = random_signal(n as u64 + 77, n);
            let fwd = Plan::new(n, Direction::Forward);
            let inv = Plan::new(n, Direction::Inverse);
            let mut y = x.clone();
            fwd.execute(&mut y);
            inv.execute(&mut y);
            assert_close(&flat(&y), &flat(&x), 1e-3, 1e-3);
        }
    }

    #[test]
    fn stage_schedules() {
        assert_eq!(Plan::new(360, Direction::Forward).radices(), vec![4, 2, 3, 3, 5]);
        assert_eq!(Plan::new(1024, Direction::Forward).radices(), vec![2; 10]);
        assert!(!Plan::new(1000, Direction::Forward).uses_bluestein());
        assert!(Plan::new(1013, Direction::Forward).uses_bluestein());
        assert!(Plan::new(1013, Direction::Forward).radices().is_empty());
    }

    #[test]
    fn execute_rows_equals_per_row() {
        let rows = 5;
        let n = 36; // non-pow2 rows exercise the scratch reuse
        let data = random_signal(3, rows * n);
        let plan = Plan::new(n, Direction::Forward);

        let mut batched = data.clone();
        plan.execute_rows(&mut batched);

        let mut manual = data.clone();
        for r in 0..rows {
            plan.execute(&mut manual[r * n..(r + 1) * n]);
        }
        assert_eq!(flat(&batched), flat(&manual));
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        let plan_a = Plan::new(360, Direction::Forward);
        let plan_b = Plan::new(1013, Direction::Forward);
        let xa = random_signal(10, 360);
        let xb = random_signal(11, 1013);

        let mut shared = FftScratch::new();
        let mut ya = xa.clone();
        plan_a.execute_with_scratch(&mut ya, &mut shared);
        let mut yb = xb.clone();
        plan_b.execute_with_scratch(&mut yb, &mut shared);

        let mut ya2 = xa;
        plan_a.execute(&mut ya2);
        let mut yb2 = xb;
        plan_b.execute(&mut yb2);
        assert_eq!(flat(&ya), flat(&ya2));
        assert_eq!(flat(&yb), flat(&yb2));
    }

    #[test]
    fn plan_length_one_is_identity() {
        let plan = Plan::new(1, Direction::Forward);
        let mut x = vec![Complex32::new(4.0, 2.0)];
        plan.execute(&mut x);
        assert_eq!(x[0], Complex32::new(4.0, 2.0));
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn plan_rejects_wrong_length() {
        Plan::new(8, Direction::Forward).execute(&mut vec![Complex32::ZERO; 4]);
    }

    #[test]
    fn kernel_names_cover_all_paths() {
        assert_eq!(Plan::new(1, Direction::Forward).kernel_name(), "identity");
        assert_eq!(Plan::new(1024, Direction::Forward).kernel_name(), "split-radix");
        assert_eq!(Plan::new(360, Direction::Forward).kernel_name(), "mixed-radix");
        assert_eq!(Plan::new(1013, Direction::Forward).kernel_name(), "mixed-radix+bluestein");
    }

    #[test]
    fn thread_local_scratch_is_reentrant_safe() {
        // execute() inside a with_thread_local closure sees the scratch
        // already borrowed and must fall back to a fresh one, not panic.
        let x = random_signal(42, 360);
        let mut inner = x.clone();
        FftScratch::with_thread_local(|outer| {
            outer.work.clear();
            Plan::new(360, Direction::Forward).execute(&mut inner);
        });
        let mut reference = x;
        Plan::new(360, Direction::Forward).execute(&mut reference);
        assert_eq!(flat(&inner), flat(&reference));
    }

    #[test]
    fn cache_hit_returns_same_plan() {
        let cache = PlanCache::new();
        let a = cache.plan(128, Direction::Forward);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.plan(128, Direction::Forward);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "cache hit must reuse the plan");
        assert_eq!(cache.cached_keys(), vec![(128, Direction::Forward)]);
    }

    #[test]
    fn cache_keys_include_direction() {
        let cache = PlanCache::new();
        let f = cache.plan(60, Direction::Forward);
        let i = cache.plan(60, Direction::Inverse);
        assert!(!Arc::ptr_eq(&f, &i), "directions are distinct cache entries");
        assert_eq!(
            cache.cached_keys(),
            vec![(60, Direction::Forward), (60, Direction::Inverse)]
        );
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn global_cache_is_shared() {
        let a = PlanCache::global().plan(512, Direction::Forward);
        let b = PlanCache::global().plan(512, Direction::Forward);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn flops_estimate() {
        let plan = Plan::new(1024, Direction::Forward);
        assert_eq!(plan.flops(), 5.0 * 1024.0 * 10.0);
        assert!(Plan::new(1000, Direction::Forward).flops() > 0.0);
    }
}
