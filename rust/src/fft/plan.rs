//! FFT plans and the process-wide plan cache (the `fftw_plan` analog).
//!
//! A [`Plan`] owns the precomputed twiddle and bit-reversal tables for one
//! transform length; creating it is the expensive step, executing it is
//! allocation-free. [`PlanCache`] memoizes plans per length so the
//! distributed driver and the baseline both plan once and execute many
//! times — the same usage discipline FFTW requires.

use super::complex::Complex32;
use super::radix2;
use super::twiddle;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Transform direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Unnormalized forward transform (`e^{-2πi...}`).
    Forward,
    /// `1/n`-normalized inverse transform.
    Inverse,
}

/// A reusable transform plan for one power-of-two length.
pub struct Plan {
    n: usize,
    twiddles: Vec<Complex32>,
    bitrev: Vec<u32>,
}

impl Plan {
    /// Plan an `n`-point transform. `n` must be a power of two (callers
    /// with other sizes go through the oracle-grade `dft` module).
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 1, "Plan requires power-of-two n >= 1, got {n}");
        if n == 1 {
            return Self { n, twiddles: Vec::new(), bitrev: vec![0] };
        }
        Self { n, twiddles: twiddle::forward_table(n), bitrev: twiddle::bit_reverse_table(n) }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Execute in place.
    ///
    /// # Panics
    /// If `x.len() != self.len()`.
    pub fn execute(&self, x: &mut [Complex32], dir: Direction) {
        assert_eq!(x.len(), self.n, "buffer length {} != plan length {}", x.len(), self.n);
        match dir {
            Direction::Forward => radix2::fft_in_place(x, &self.twiddles, &self.bitrev),
            Direction::Inverse => radix2::ifft_in_place(x, &self.twiddles, &self.bitrev),
        }
    }

    /// Execute every length-`n` row of a contiguous row-major buffer.
    pub fn execute_rows(&self, data: &mut [Complex32], dir: Direction) {
        assert!(
            data.len() % self.n == 0,
            "buffer length {} not a multiple of row length {}",
            data.len(),
            self.n
        );
        for row in data.chunks_exact_mut(self.n) {
            self.execute(row, dir);
        }
    }

    /// FLOP estimate for one execution (5 n log2 n — the standard FFT
    /// operation count used for throughput reporting).
    pub fn flops(&self) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        5.0 * self.n as f64 * (self.n as f64).log2()
    }
}

/// Memoized per-length plans, shared across threads.
pub struct PlanCache {
    plans: Mutex<HashMap<usize, Arc<Plan>>>,
}

impl PlanCache {
    pub fn new() -> Self {
        Self { plans: Mutex::new(HashMap::new()) }
    }

    /// Process-wide cache (what `fftw` calls wisdom, minus the disk file).
    pub fn global() -> &'static PlanCache {
        static CACHE: OnceLock<PlanCache> = OnceLock::new();
        CACHE.get_or_init(PlanCache::new)
    }

    pub fn plan(&self, n: usize) -> Arc<Plan> {
        let mut plans = self.plans.lock().unwrap();
        Arc::clone(plans.entry(n).or_insert_with(|| Arc::new(Plan::new(n))))
    }

    pub fn cached_lengths(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.plans.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    #[test]
    fn plan_executes_forward() {
        let mut rng = Pcg32::new(1);
        let x: Vec<Complex32> =
            (0..64).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect();
        let plan = Plan::new(64);
        let mut y = x.clone();
        plan.execute(&mut y, Direction::Forward);
        assert_close(&flat(&y), &flat(&dft(&x)), 1e-3, 1e-3);
    }

    #[test]
    fn plan_roundtrip() {
        let mut rng = Pcg32::new(2);
        let x: Vec<Complex32> =
            (0..256).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect();
        let plan = Plan::new(256);
        let mut y = x.clone();
        plan.execute(&mut y, Direction::Forward);
        plan.execute(&mut y, Direction::Inverse);
        assert_close(&flat(&y), &flat(&x), 1e-4, 1e-3);
    }

    #[test]
    fn execute_rows_equals_per_row() {
        let mut rng = Pcg32::new(3);
        let rows = 5;
        let n = 32;
        let data: Vec<Complex32> =
            (0..rows * n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect();
        let plan = Plan::new(n);

        let mut batched = data.clone();
        plan.execute_rows(&mut batched, Direction::Forward);

        let mut manual = data.clone();
        for r in 0..rows {
            plan.execute(&mut manual[r * n..(r + 1) * n], Direction::Forward);
        }
        assert_eq!(flat(&batched), flat(&manual));
    }

    #[test]
    fn plan_length_one_is_identity() {
        let plan = Plan::new(1);
        let mut x = vec![Complex32::new(4.0, 2.0)];
        plan.execute(&mut x, Direction::Forward);
        assert_eq!(x[0], Complex32::new(4.0, 2.0));
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn plan_rejects_non_pow2() {
        Plan::new(24);
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn plan_rejects_wrong_length() {
        Plan::new(8).execute(&mut vec![Complex32::ZERO; 4], Direction::Forward);
    }

    #[test]
    fn cache_returns_same_plan() {
        let cache = PlanCache::new();
        let a = cache.plan(128);
        let b = cache.plan(128);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.cached_lengths(), vec![128]);
    }

    #[test]
    fn global_cache_is_shared() {
        let a = PlanCache::global().plan(512);
        let b = PlanCache::global().plan(512);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn flops_estimate() {
        let plan = Plan::new(1024);
        assert_eq!(plan.flops(), 5.0 * 1024.0 * 10.0);
    }
}
