//! Bluestein's chirp-z algorithm — O(n log n) DFT for lengths the
//! Cooley–Tukey factorizer cannot break down (large prime factors).
//!
//! The p-point DFT is rewritten as a circular convolution via the
//! identity `jk = (j² + k² − (k−j)²) / 2`:
//!
//! ```text
//! X[k] = c[k] · Σ_j (x[j]·c[j]) · conj(c)[k−j],   c[j] = e^{∓iπ j²/p}
//! ```
//!
//! The convolution is evaluated with a zero-padded power-of-two FFT of
//! length `M = next_pow2(2p − 1)` through the planned radix-2 kernel
//! ([`Radix2Tables`]), so the fallback reuses the same SIMD fast path as
//! every other plan. All tables (chirp, the kernel's forward spectrum
//! `B`, the kernel's swap list and stage twiddles) are precomputed at
//! plan time; execution only touches the caller-provided convolution
//! scratch buffer.
//!
//! Twiddle sharing happens at the table level, through the process-wide
//! [`crate::fft::twiddle::TwiddleCache`] inside [`Radix2Tables::new`] —
//! a plan build never re-enters the *plan* cache, so construction stays
//! self-contained while the convolution length's half-circle table is
//! still shared with any other plan that needs it.

use super::complex::Complex32;
use super::radix2::Radix2Tables;
use super::simd;
use super::twiddle;

/// A prepared Bluestein transform for one prime (or otherwise
/// unfactorable) length and one direction.
pub(crate) struct BluesteinPlan {
    /// Transform length.
    p: usize,
    /// Power-of-two convolution length, `≥ 2p − 1`.
    m: usize,
    /// Direction-signed chirp `c[j] = e^{∓iπ j²/p}`, `j in 0..p`.
    chirp: Vec<Complex32>,
    /// Forward FFT of the convolution kernel `conj(c)[±j]`, length `m`.
    b_fft: Vec<Complex32>,
    /// Planned *forward* length-`m` radix-2 kernel; the convolution's
    /// inverse runs through the conjugation identity, so one direction
    /// serves both.
    kernel: Radix2Tables,
}

impl BluesteinPlan {
    /// Precompute all tables for a `p`-point transform. The chirp
    /// `e^{∓iπ j²/p}` is the `2p`-th root of unity at exponent `j²`
    /// ([`twiddle::unit`] reduces the exponent mod `2p`, the chirp's
    /// true period, keeping the f64 angle small at large `j`).
    pub(crate) fn new(p: usize, inverse: bool) -> Self {
        assert!(p >= 2, "Bluestein needs p >= 2, got {p}");
        let m = (2 * p - 1).next_power_of_two();
        let chirp: Vec<Complex32> =
            (0..p).map(|j| twiddle::unit(j * j, 2 * p, inverse)).collect();
        let kernel = Radix2Tables::new(m, false);

        // Convolution kernel b[j] = conj(c[|j|]) for j in −(p−1)..p,
        // wrapped circularly into length m (m ≥ 2p−1, so the positive and
        // mirrored halves never collide).
        let mut b = vec![Complex32::ZERO; m];
        b[0] = chirp[0].conj();
        for j in 1..p {
            let v = chirp[j].conj();
            b[j] = v;
            b[m - j] = v;
        }
        kernel.execute(&mut b);

        Self { p, m, chirp, b_fft: b, kernel }
    }

    /// Transform length.
    pub(crate) fn len(&self) -> usize {
        self.p
    }

    /// Unnormalized `p`-point DFT (direction baked into the tables) of
    /// the strided sequence `src[0], src[stride], …, src[(p−1)·stride]`
    /// into `dst[..p]`. `conv` is the caller-owned convolution scratch,
    /// resized to the convolution length on every call.
    pub(crate) fn exec(
        &self,
        src: &[Complex32],
        stride: usize,
        dst: &mut [Complex32],
        conv: &mut Vec<Complex32>,
    ) {
        debug_assert!(src.len() >= (self.p - 1) * stride + 1, "strided source too short");
        debug_assert!(dst.len() >= self.p, "destination too short");
        conv.clear();
        conv.resize(self.m, Complex32::ZERO);
        for (j, c) in conv.iter_mut().take(self.p).enumerate() {
            *c = src[j * stride] * self.chirp[j];
        }
        self.kernel.execute(conv);
        simd::pointwise_mul(conv, &self.b_fft);
        // The inverse here is the convolution theorem's 1/m-normalized
        // one — unrelated to the outer transform's direction. It runs
        // through the conjugation identity over the forward kernel,
        // exactly like `radix2::ifft_in_place`.
        for v in conv.iter_mut() {
            *v = v.conj();
        }
        self.kernel.execute(conv);
        let scale = 1.0 / self.m as f32;
        for v in conv.iter_mut() {
            *v = v.conj().scale(scale);
        }
        for (k, d) in dst.iter_mut().take(self.p).enumerate() {
            *d = conv[k] * self.chirp[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn flat(xs: &[Complex32]) -> Vec<f32> {
        xs.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn random_signal(seed: u64, n: usize) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn matches_oracle_small_primes() {
        for &p in &[2usize, 3, 5, 7, 13, 31, 67] {
            let x = random_signal(p as u64, p);
            let plan = BluesteinPlan::new(p, false);
            let mut out = vec![Complex32::ZERO; p];
            let mut conv = Vec::new();
            plan.exec(&x, 1, &mut out, &mut conv);
            assert_close(&flat(&out), &flat(&dft(&x)), 1e-3, 1e-3);
        }
    }

    #[test]
    fn matches_oracle_large_prime() {
        let p = 1013;
        let x = random_signal(9, p);
        let plan = BluesteinPlan::new(p, false);
        let mut out = vec![Complex32::ZERO; p];
        let mut conv = Vec::new();
        plan.exec(&x, 1, &mut out, &mut conv);
        assert_close(&flat(&out), &flat(&dft(&x)), 1e-2, 1e-2);
    }

    #[test]
    fn strided_input_reads_the_subsequence() {
        let p = 11;
        let stride = 3;
        let padded = random_signal(4, (p - 1) * stride + 1);
        let contiguous: Vec<Complex32> = (0..p).map(|j| padded[j * stride]).collect();
        let plan = BluesteinPlan::new(p, false);
        let mut conv = Vec::new();
        let mut from_strided = vec![Complex32::ZERO; p];
        plan.exec(&padded, stride, &mut from_strided, &mut conv);
        let mut from_contiguous = vec![Complex32::ZERO; p];
        plan.exec(&contiguous, 1, &mut from_contiguous, &mut conv);
        assert_eq!(flat(&from_strided), flat(&from_contiguous));
    }

    #[test]
    fn inverse_tables_give_unnormalized_idft() {
        use crate::fft::dft::idft;
        let p = 17;
        let x = random_signal(5, p);
        let plan = BluesteinPlan::new(p, true);
        let mut out = vec![Complex32::ZERO; p];
        let mut conv = Vec::new();
        plan.exec(&x, 1, &mut out, &mut conv);
        // exec is unnormalized; idft normalizes by 1/p.
        let scale = 1.0 / p as f32;
        let scaled: Vec<Complex32> = out.iter().map(|v| v.scale(scale)).collect();
        assert_close(&flat(&scaled), &flat(&idft(&x)), 1e-3, 1e-3);
    }

    #[test]
    fn conv_length_is_large_enough() {
        for &p in &[2usize, 3, 97, 1013] {
            let plan = BluesteinPlan::new(p, false);
            assert!(plan.m >= 2 * p - 1);
            assert!(plan.m.is_power_of_two());
            assert_eq!(plan.len(), p);
        }
    }
}
