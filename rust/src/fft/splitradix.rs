//! Split-radix FFT for power-of-two lengths.
//!
//! The conjugate-pair-free "classic" split-radix decimation-in-time
//! recursion: an n-point DFT splits into one n/2 DFT over the even
//! samples and two n/4 DFTs over the `1 mod 4` / `3 mod 4` samples,
//! combined with one `w^k` and one `w^{3k}` twiddle multiply per output
//! group of four. That is ~33% fewer twiddle multiplies than radix-2
//! (4/3·n·log₂n real mul/adds asymptotically), and the combine loop is
//! exactly the lane-parallel shape [`super::simd::split_radix_combine`]
//! vectorizes.
//!
//! Twiddle tables come from the process-wide
//! [`super::twiddle::TwiddleCache`], so the size-n plan and every
//! size-n/2ᵏ recursion level share one `Arc`'d half-circle table per
//! level (the `w^{3k}` table is small — n/4 entries per level — and is
//! materialized per plan for a branch-free inner loop).
//!
//! The recursion reads strided input from a scratch copy and writes each
//! sub-DFT contiguously into its quarter of the output, so the combine
//! is in-place over four disjoint quarter-slices — no per-level
//! allocation, and the only scratch is the caller-provided work buffer.

use super::complex::Complex32;
use super::simd;
use super::twiddle::TwiddleCache;
use std::sync::Arc;

/// One recursion level's twiddle state, for combine length `4·q`.
struct SrLevel {
    /// Quarter length `len/4`; the combine walks `k in 0..q`.
    q: usize,
    /// Shared half-circle table for this level's length: `w^k`,
    /// `k in 0..len/2`. The combine uses the first `q` entries.
    half: Arc<Vec<Complex32>>,
    /// Materialized `w^{3k}` for `k in 0..q` (folds the `w^{len/2} = -1`
    /// wraparound so the inner loop stays branch-free).
    w3: Vec<Complex32>,
}

/// Precomputed split-radix plan for one `(length, direction)` pair.
pub(crate) struct SplitRadixPlan {
    n: usize,
    inverse: bool,
    /// Levels for combine lengths `n, n/2, …, 8` (lengths 4, 2, 1 are
    /// twiddle-free base cases). Empty for `n < 8`.
    levels: Vec<SrLevel>,
}

impl SplitRadixPlan {
    /// Build a plan for power-of-two `n >= 2`. `inverse` bakes the
    /// twiddle conjugation into the tables (scaling stays with the
    /// caller, matching the radix-2 kernel's convention).
    pub(crate) fn new(n: usize, inverse: bool) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "split-radix needs power-of-two n >= 2, got {n}");
        let cache = TwiddleCache::global();
        let mut levels = Vec::new();
        let mut len = n;
        while len >= 8 {
            let q = len / 4;
            let half = cache.half(len, inverse);
            let w3 = (0..q)
                .map(|k| {
                    let idx = 3 * k;
                    if idx < len / 2 {
                        half[idx]
                    } else {
                        -half[idx - len / 2]
                    }
                })
                .collect();
            levels.push(SrLevel { q, half, w3 });
            len /= 2;
        }
        Self { n, inverse, levels }
    }

    /// Transform `x` in place, using `work` as scratch (resized to `n`;
    /// contents clobbered). Unnormalized in both directions.
    pub(crate) fn execute(&self, x: &mut [Complex32], work: &mut Vec<Complex32>) {
        assert_eq!(x.len(), self.n, "split-radix plan is for length {}, got {}", self.n, x.len());
        work.clear();
        work.extend_from_slice(x);
        rec(&self.levels, self.inverse, work, 1, x);
    }
}

/// Recursive DIT step: DFT of `dst.len()` strided samples
/// `src[0], src[stride], …` written contiguously into `dst`.
///
/// `levels[0]` always corresponds to `dst.len()` when `dst.len() >= 8`
/// (the plan builds one level per halving down to 8, and the two `n/4`
/// sub-calls skip two levels).
fn rec(levels: &[SrLevel], inverse: bool, src: &[Complex32], stride: usize, dst: &mut [Complex32]) {
    match dst.len() {
        1 => dst[0] = src[0],
        2 => {
            let (a, b) = (src[0], src[stride]);
            dst[0] = a + b;
            dst[1] = a - b;
        }
        4 => {
            let (a, b) = (src[0], src[stride]);
            let (c, d) = (src[2 * stride], src[3 * stride]);
            let s02 = a + c;
            let d02 = a - c;
            let s13 = b + d;
            let rot = if inverse { (b - d).mul_i() } else { (b - d).mul_neg_i() };
            dst[0] = s02 + s13;
            dst[1] = d02 + rot;
            dst[2] = s02 - s13;
            dst[3] = d02 - rot;
        }
        len => {
            let q = len / 4;
            let lvl = &levels[0];
            debug_assert_eq!(lvl.q, q, "level table out of step with recursion depth");
            let (u, z) = dst.split_at_mut(len / 2);
            let (z1, z3) = z.split_at_mut(q);
            let rest1: &[SrLevel] = levels.get(1..).unwrap_or(&[]);
            let rest2: &[SrLevel] = levels.get(2..).unwrap_or(&[]);
            rec(rest1, inverse, src, stride * 2, u);
            rec(rest2, inverse, &src[stride..], stride * 4, z1);
            rec(rest2, inverse, &src[3 * stride..], stride * 4, z3);
            let (u0, u1) = u.split_at_mut(q);
            simd::split_radix_combine(u0, u1, z1, z3, &lvl.half[..q], &lvl.w3, inverse);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::fft::radix2;

    fn test_signal(n: usize) -> Vec<Complex32> {
        (0..n)
            .map(|i| {
                let t = i as f32;
                Complex32::new((0.3 * t).sin() + 0.1 * t, (0.7 * t).cos() - 0.05 * t)
            })
            .collect()
    }

    fn assert_close(a: &[Complex32], b: &[Complex32], tol: f32, ctx: &str) {
        let scale = b.iter().map(|c| c.abs()).fold(1.0f32, f32::max);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (*x - *y).abs() <= tol * scale,
                "{ctx}: index {i}: {x:?} vs {y:?} (scale {scale})"
            );
        }
    }

    #[test]
    fn forward_matches_dft_oracle() {
        for &n in &[2usize, 4, 8, 16, 32, 64, 256, 1024] {
            let x = test_signal(n);
            let plan = SplitRadixPlan::new(n, false);
            let mut y = x.clone();
            let mut work = Vec::new();
            plan.execute(&mut y, &mut work);
            assert_close(&y, &dft(&x), 1e-5, &format!("forward n={n}"));
        }
    }

    #[test]
    fn matches_legacy_radix2_both_directions() {
        for &n in &[2usize, 4, 8, 16, 128, 512, 2048] {
            for inverse in [false, true] {
                let x = test_signal(n);
                let plan = SplitRadixPlan::new(n, inverse);
                let mut y = x.clone();
                let mut work = Vec::new();
                plan.execute(&mut y, &mut work);
                let mut reference = x.clone();
                radix2::fft_in_place_dir(&mut reference, inverse);
                assert_close(&y, &reference, 1e-5, &format!("n={n} inverse={inverse}"));
            }
        }
    }

    #[test]
    fn inverse_roundtrip_recovers_input() {
        for &n in &[8usize, 64, 1024] {
            let x = test_signal(n);
            let fwd = SplitRadixPlan::new(n, false);
            let inv = SplitRadixPlan::new(n, true);
            let mut y = x.clone();
            let mut work = Vec::new();
            fwd.execute(&mut y, &mut work);
            inv.execute(&mut y, &mut work);
            let scale = 1.0 / n as f32;
            for v in &mut y {
                *v = v.scale(scale);
            }
            assert_close(&y, &x, 1e-5, &format!("roundtrip n={n}"));
        }
    }

    #[test]
    fn impulse_gives_flat_spectrum_exactly() {
        // The driver relies on this bitwise property for doctests: a unit
        // impulse transforms to exactly 1+0i everywhere (every twiddle
        // multiplies a zero or the table's exact leading 1).
        let n = 16;
        let mut x = vec![Complex32::ZERO; n];
        x[0] = Complex32::ONE;
        let plan = SplitRadixPlan::new(n, false);
        let mut work = Vec::new();
        plan.execute(&mut x, &mut work);
        for (k, v) in x.iter().enumerate() {
            assert_eq!((v.re, v.im), (1.0, 0.0), "bin {k}");
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        SplitRadixPlan::new(12, false);
    }
}
