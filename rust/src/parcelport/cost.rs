//! Cost models: per-port software overhead and the cluster wire model.
//!
//! Two distinct things are modeled:
//!
//! 1. [`CostModel`] — the *software* cost a parcelport adds per message
//!    (framing, matching, protocol bookkeeping). The constants are
//!    calibrated so the 2-node chunk-size sweep reproduces the shape of
//!    the paper's Fig. 3 (TCP ≫ MPI > LCI at small chunks); they are the
//!    analytic counterpart of the real protocol code the ports execute.
//! 2. [`NetModel`] — the *wire*: the postal model `T(s) = α + s/β` of one
//!    InfiniBand HDR link (Fig. 2: 200 Gb/s), charged per message-hop.
//!
//! In hybrid live runs the sending thread spins for the modeled time (µs
//! precision — `thread::sleep` is far too coarse); in simnet the same
//! formulas advance virtual time instead, so live and simulated runs are
//! calibrated by construction against the *same* model.

use std::time::{Duration, Instant};

/// Per-port software cost per message (calibrated; DESIGN.md §6).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Fixed software overhead per message send+recv, µs.
    pub sw_overhead_us: f64,
    /// Extra payload memcpys the protocol performs (framing, bounce
    /// buffers). Charged at [`NetModel::COPY_BANDWIDTH_GBPS`].
    pub protocol_copies: u32,
    /// Eager→rendezvous switchover (bytes); `u64::MAX` = never rendezvous.
    pub eager_threshold: u64,
    /// Extra round-trips for the rendezvous handshake above the eager
    /// threshold (RTS + CTS = 1 RTT).
    pub rendezvous_rtts: u32,
}

impl CostModel {
    /// TCP parcelport: serialization into stream frames, kernel
    /// crossings, ACK clocking. Dominant at small chunk sizes (Fig. 3).
    pub fn tcp() -> Self {
        Self {
            sw_overhead_us: 55.0,
            protocol_copies: 2,
            eager_threshold: u64::MAX,
            rendezvous_rtts: 0,
        }
    }

    /// MPI parcelport (OpenMPI-like): tag matching + progression, one
    /// bounce-buffer copy on the eager path, RTS/CTS rendezvous above
    /// 64 KiB.
    pub fn mpi() -> Self {
        Self {
            sw_overhead_us: 8.0,
            protocol_copies: 1,
            eager_threshold: 64 * 1024,
            rendezvous_rtts: 1,
        }
    }

    /// LCI parcelport: lightweight completion queues, zero-copy medium
    /// messages, no matching machinery.
    pub fn lci() -> Self {
        Self {
            sw_overhead_us: 2.5,
            protocol_copies: 0,
            eager_threshold: u64::MAX,
            rendezvous_rtts: 0,
        }
    }

    /// Software time for a message of `size` bytes, µs (excluding wire).
    ///
    /// Protocol copies are charged on the eager path only: the rendezvous
    /// path transfers directly from registered memory (RDMA), which is
    /// the point of the handshake. TCP never rendezvous, so its two
    /// stream copies apply at every size — the reason its runtimes stay
    /// bad even for large chunks in Fig. 3.
    pub fn sw_time_us(&self, size: u64) -> f64 {
        let copies = if self.is_rendezvous(size) { 0 } else { self.protocol_copies };
        let copy_us = copies as f64 * size as f64 / NetModel::COPY_BANDWIDTH_GBPS / 1e3;
        self.sw_overhead_us + copy_us
    }

    /// Whether a message of `size` takes the rendezvous path.
    pub fn is_rendezvous(&self, size: u64) -> bool {
        size > self.eager_threshold
    }
}

/// The postal wire model of one cluster link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetModel {
    /// One-way link latency, µs.
    pub alpha_us: f64,
    /// Link bandwidth, GB/s.
    pub beta_gbps: f64,
    /// Scale factor applied to modeled time when spinning in live mode
    /// (1.0 = real time; benchmarks use 1.0).
    pub time_scale: f64,
}

impl NetModel {
    /// Memory copy bandwidth used to charge protocol copies, GB/s.
    /// (Single-core memcpy on the EPYC 7352 era: ~12 GB/s.)
    pub const COPY_BANDWIDTH_GBPS: f64 = 12.0;

    /// InfiniBand HDR, as specified in the paper's Fig. 2: 200 Gb/s
    /// links, ~1.5 µs MPI-level latency.
    pub fn infiniband_hdr() -> Self {
        Self { alpha_us: 1.5, beta_gbps: 25.0, time_scale: 1.0 }
    }

    /// Wire time for `size` bytes over one link, µs.
    pub fn wire_time_us(&self, size: u64) -> f64 {
        self.alpha_us + size as f64 / self.beta_gbps / 1e3
    }

    /// Total modeled time for a message: port software + wire (+
    /// rendezvous RTTs where applicable), µs.
    pub fn message_time_us(&self, cost: &CostModel, size: u64) -> f64 {
        let mut t = cost.sw_time_us(size) + self.wire_time_us(size);
        if cost.is_rendezvous(size) {
            t += cost.rendezvous_rtts as f64 * 2.0 * self.alpha_us;
        }
        t
    }

    /// Spin the calling thread for the modeled duration (live hybrid
    /// mode). Spinning, not sleeping: the modeled times are single-digit
    /// µs and `thread::sleep` has ~50 µs granularity.
    pub fn charge(&self, cost: &CostModel, size: u64) -> f64 {
        let us = self.message_time_us(cost, size) * self.time_scale;
        spin_for(Duration::from_nanos((us * 1e3) as u64));
        us
    }
}

/// How long a wire-charging spin runs before ceding the core once.
/// Single-digit-µs charges (the common case: per-message software
/// overhead) never yield, so the hot path is a pure spin; multi-µs wire
/// charges periodically let the scheduler run mailbox progress threads —
/// on oversubscribed CI runners a long uninterrupted spin can otherwise
/// starve the very receiver the modeled message is addressed to.
const YIELD_EVERY: Duration = Duration::from_micros(5);

/// Busy-wait for `d` (µs-accurate), yielding the core every few µs so
/// concurrent progress threads keep running on oversubscribed hosts.
pub fn spin_for(d: Duration) {
    let end = Instant::now() + d;
    let mut next_yield = Instant::now() + YIELD_EVERY;
    loop {
        let now = Instant::now();
        if now >= end {
            return;
        }
        if now >= next_yield {
            std::thread::yield_now();
            next_yield = Instant::now() + YIELD_EVERY;
        } else {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_ordering_small_messages() {
        // The calibration invariant behind Fig. 3: at any size,
        // LCI < MPI < TCP in software cost.
        for size in [1u64 << 10, 1 << 14, 1 << 20, 1 << 24] {
            let tcp = CostModel::tcp().sw_time_us(size);
            let mpi = CostModel::mpi().sw_time_us(size);
            let lci = CostModel::lci().sw_time_us(size);
            assert!(lci < mpi && mpi < tcp, "size {size}: lci {lci} mpi {mpi} tcp {tcp}");
        }
    }

    #[test]
    fn tcp_overhead_dominates_small() {
        // At 1 KiB the TCP/LCI ratio must be large (paper: "big overhead
        // for small data chunks").
        let ratio = CostModel::tcp().sw_time_us(1024) / CostModel::lci().sw_time_us(1024);
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn wire_time_monotone_in_size() {
        let net = NetModel::infiniband_hdr();
        let mut prev = 0.0;
        for p in 10..25 {
            let t = net.wire_time_us(1 << p);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn wire_time_closed_form() {
        let net = NetModel::infiniband_hdr();
        // 25 GB/s: 1 MiB takes 1048576/25e9 s = 41.94 µs + 1.5 µs latency.
        let t = net.wire_time_us(1 << 20);
        assert!((t - (1.5 + 41.94)).abs() < 0.1, "{t}");
    }

    #[test]
    fn rendezvous_kicks_in_above_threshold() {
        let net = NetModel::infiniband_hdr();
        let mpi = CostModel::mpi();
        assert!(!mpi.is_rendezvous(64 * 1024));
        assert!(mpi.is_rendezvous(64 * 1024 + 1));
        // Crossing the threshold trades the eager copy (~5.5 µs at
        // 64 KiB) for one handshake RTT (3 µs): rendezvous must be the
        // cheaper protocol right at the crossover — that is why
        // implementations switch.
        let below = net.message_time_us(&mpi, 64 * 1024);
        let above = net.message_time_us(&mpi, 64 * 1024 + 1);
        assert!(above < below, "below {below} above {above}");
        // And the handshake RTT itself is visible: rendezvous time equals
        // sw overhead + wire + 2α.
        let size = 1u64 << 20;
        let t = net.message_time_us(&mpi, size);
        let expect = mpi.sw_overhead_us + net.wire_time_us(size) + 2.0 * net.alpha_us;
        assert!((t - expect).abs() < 1e-9, "t {t} expect {expect}");
    }

    #[test]
    fn lci_never_rendezvous() {
        assert!(!CostModel::lci().is_rendezvous(u64::MAX - 1));
    }

    #[test]
    fn spin_for_is_roughly_accurate() {
        let start = Instant::now();
        spin_for(Duration::from_micros(200));
        let took = start.elapsed().as_micros();
        assert!((200..5000).contains(&took), "spun for {took} µs");
    }

    #[test]
    fn spin_for_charges_within_tolerance_despite_yielding() {
        // The yield points must neither undershoot the modeled duration
        // nor blow it up: the charged wall time of a wire-scale spin
        // (500 µs crosses ~100 yield points) stays within a loose CI
        // tolerance of the request.
        let want = Duration::from_micros(500);
        let start = Instant::now();
        spin_for(want);
        let took = start.elapsed();
        assert!(took >= want, "undershot: {took:?} < {want:?}");
        assert!(
            took < Duration::from_millis(50),
            "yielding inflated the charge unreasonably: {took:?}"
        );
    }

    #[test]
    fn short_spins_stay_precise() {
        // Sub-yield-threshold charges (per-message software overheads)
        // must not pick up scheduler latency.
        for _ in 0..10 {
            let start = Instant::now();
            spin_for(Duration::from_micros(3));
            let took = start.elapsed().as_micros();
            assert!(took >= 3, "undershot: {took} µs");
        }
    }

    #[test]
    fn large_messages_converge_to_bandwidth() {
        // At 16 MiB the software-overhead difference between MPI and LCI
        // must be < 15% of total time (bandwidth-bound regime, Fig. 3's
        // right edge).
        let net = NetModel::infiniband_hdr();
        let size = 16 << 20;
        let mpi = net.message_time_us(&CostModel::mpi(), size);
        let lci = net.message_time_us(&CostModel::lci(), size);
        assert!((mpi - lci) / lci < 0.15, "mpi {mpi} lci {lci}");
    }
}
